"""Schema guard for ``BENCH_throughput.json`` (CI `kernels` job).

Extracted from the inline heredoc that used to live in
``.github/workflows/ci.yml`` so the guard is unit-testable
(``tests/test_check_bench.py``) and greppable.  The guard exists because the
benchmark artifact is the repo's perf trajectory: a PR that silently drops a
column (per-backend timings, the compile/steady split, the overlap-engine
efficiency numbers) hides a regression from every later PR.  Checks:

* ``backends`` — per-engine-backend compress/decompress timings
  (DESIGN.md §13): records for both ``reference`` and ``pallas``.
* ``records`` — the bucket × transport sweep (DESIGN.md §9/§14/§15):
  compile/steady split for looped AND stacked execution, the looped vs
  stacked modeled exchange (stacked must price ONE collective), and the
  overlap-engine columns — streamed step-visible exchange time, overlap
  efficiency (>0 on every streamable row: some exchange always hides behind
  a nonzero backward pass), and the auto policy's pick.
* ``schedules`` — the auto-policy profile sweep (DESIGN.md §15): at least
  one deep-model row must record ``auto_schedule == "streamed"`` with
  ``overlap_efficiency > 0`` — the acceptance evidence that the overlap
  engine's point (hiding exchange behind backprop) survives in the model.
* ``selectors`` — the selection-engine comparison (DESIGN.md §16): records
  for both the ``sort`` and ``sampled`` selectors at the large (64 MB)
  buffer, and the sampled selector's steady-state compress must not be
  slower than sort's — the acceptance evidence that O(n) sampled-threshold
  selection keeps steady-state compression kernel-bound.
* ``calibration`` — the measured cost model (DESIGN.md §17): an α–β fit for
  both collective families with positive coefficients, the measured stage
  throughputs and backprop rate, and per-profile calibrated-vs-static auto
  verdicts — the acceptance evidence that ``schedule=auto`` decisions are
  driven by measurements, not the static napkin constants.
* ``resilience`` — the exchange-guard overhead record (DESIGN.md §19):
  steady-state stacked compress with and without ``cheap`` payload
  validation, the measured overhead ratio, and the deterministic structural
  verdict (validation adds no sort/FFT/collective primitive and
  ``validate('off')`` stays free) — the acceptance evidence that resilience
  is effectively free on the hot path.
* ``topology`` — the two-level (nodes × local) sweep (DESIGN.md §18):
  per-axis wire bits and hierarchical-vs-flat exchange times per shape;
  the hierarchical per-worker inter-node wire must sit STRICTLY below the
  flat psum runtime wire on every record, and for a fixed node count it
  must strictly shrink as ``local`` grows — the ISSUE 8 acceptance
  evidence that growing an island shrinks each worker's fabric share.

* ``serve`` artifacts — a file whose top-level ``kind`` is ``"serve"``
  (``BENCH_serve.json``, benchmarks/serve_bench.py) is checked by
  ``check_serve`` instead: compressed weight deltas strictly cheaper than
  dense snapshots at every cadence, one-decompress summed-spectrum
  catch-up bitwise-equal to one-at-a-time replay, and the ring-wrap
  snapshot fallback demonstrated (DESIGN.md §20).

Usage: ``python tools/check_bench.py [artifact.json ...]`` (default
``BENCH_throughput.json``; each path is dispatched by its ``kind``); exits
nonzero listing every violation (not just the first).
"""

from __future__ import annotations

import json
import sys
from typing import List

RECORD_KEYS = (
    "host_compress_compile_us",
    "host_compress_steady_us",
    "host_compress_dispatch_us",
    "stacked_compress_compile_us",
    "stacked_compress_steady_us",
    "model_exchange_ms",
    "model_exchange_ms_stacked",
    "model_n_collectives",
    "model_n_collectives_stacked",
    # overlap engine (DESIGN.md §15)
    "model_backprop_ms",
    "model_exchange_ms_streamed",
    "model_n_collectives_streamed",
    "overlap_efficiency",
    "auto_schedule",
    # selection engine (DESIGN.md §16)
    "selector",
    "sample_rate",
    "tau_refine_iters",
)

BACKEND_KEYS = ("compress_us", "decompress_us", "n_elems")

SELECTOR_KEYS = (
    "selector",
    "sample_rate",
    "tau_refine_iters",
    "n_elems",
    "compress_compile_us",
    "compress_steady_us",
)

# the selector comparison's reference buffer: 16M floats = 64 MB
SELECTOR_N_ELEMS = 1 << 24

SCHEDULE_KEYS = (
    "profile",
    "n_params",
    "batch_tokens",
    "n_buckets",
    "model_backprop_ms",
    "model_step_ms_stacked",
    "model_step_ms_streamed",
    "overlap_efficiency",
    "auto_schedule",
)

SCHEDULE_NAMES = ("stacked", "streamed")

# calibration section (DESIGN.md §17): the measured cost model
CALIBRATION_FAMILIES = ("gather", "psum")

CALIBRATION_KEYS = (
    "platform",
    "jax_version",
    "fits",
    "throughputs",
    "backprop_flops_per_s",
    "decisions",
)

DECISION_KEYS = (
    "profile",
    "workers",
    "auto_static",
    "auto_calibrated",
    "model_step_ms_stacked_calibrated",
    "model_step_ms_streamed_calibrated",
)


def check_backends(data: dict) -> List[str]:
    errors = []
    backends = data.get("backends")
    if not backends:
        return ["missing 'backends' field (per-backend timing records)"]
    names = {r.get("backend") for r in backends}
    for missing in sorted({"reference", "pallas"} - names):
        errors.append(f"backends field lacks a record for {missing!r}")
    for r in backends:
        for key in BACKEND_KEYS:
            if key not in r:
                errors.append(f"backend record {r.get('backend')!r} lacks {key!r}")
    return errors


def check_records(data: dict) -> List[str]:
    errors = []
    records = data.get("records")
    if not records:
        return ["missing 'records' field (bucket x transport sweep)"]
    for r in records:
        tag = f"{r.get('transport')}/{r.get('bucket_mb')}"
        for key in RECORD_KEYS:
            if key not in r:
                errors.append(f"sweep record {tag} lacks {key!r}")
        if r.get("model_n_collectives_stacked") != 1:
            errors.append(
                f"sweep record {tag}: stacked exchange must price ONE "
                f"collective, got {r.get('model_n_collectives_stacked')!r}")
        if r.get("auto_schedule") not in SCHEDULE_NAMES:
            errors.append(
                f"sweep record {tag}: auto_schedule must resolve to one of "
                f"{SCHEDULE_NAMES}, got {r.get('auto_schedule')!r}")
        streamable = (r.get("n_buckets", 1) > 1
                      and r.get("transport") != "allgather")
        eff = r.get("overlap_efficiency")
        if streamable:
            if not isinstance(eff, (int, float)) or not 0.0 < eff < 1.0:
                errors.append(
                    f"sweep record {tag}: streamable row must record "
                    f"0 < overlap_efficiency < 1, got {eff!r}")
            if r.get("model_n_collectives_streamed") != r.get("n_buckets"):
                errors.append(
                    f"sweep record {tag}: streamed dispatch is one collective "
                    f"per bucket group, got "
                    f"{r.get('model_n_collectives_streamed')!r} for "
                    f"{r.get('n_buckets')!r} buckets")
        elif eff not in (0, 0.0):
            errors.append(
                f"sweep record {tag}: monolithic row must record "
                f"overlap_efficiency == 0, got {eff!r}")
    return errors


def check_schedules(data: dict) -> List[str]:
    errors = []
    schedules = data.get("schedules")
    if not schedules:
        return ["missing 'schedules' field (auto-policy profile sweep)"]
    for r in schedules:
        tag = r.get("profile", "?")
        for key in SCHEDULE_KEYS:
            if key not in r:
                errors.append(f"schedule record {tag} lacks {key!r}")
        if r.get("auto_schedule") not in SCHEDULE_NAMES:
            errors.append(
                f"schedule record {tag}: auto_schedule must be one of "
                f"{SCHEDULE_NAMES}, got {r.get('auto_schedule')!r}")
    deep_streamed = [
        r for r in schedules
        if r.get("auto_schedule") == "streamed"
        and isinstance(r.get("overlap_efficiency"), (int, float))
        and r.get("overlap_efficiency", 0) > 0
    ]
    if not deep_streamed:
        errors.append(
            "no schedule row picks 'streamed' with overlap_efficiency > 0 — "
            "the overlap engine's deep-model win disappeared from the model")
    return errors


def check_selectors(data: dict) -> List[str]:
    errors = []
    selectors = data.get("selectors")
    if not selectors:
        return ["missing 'selectors' field (selection-engine comparison)"]
    names = {r.get("selector") for r in selectors}
    for missing in sorted({"sort", "sampled"} - names):
        errors.append(f"selectors field lacks a record for {missing!r}")
    for r in selectors:
        for key in SELECTOR_KEYS:
            if key not in r:
                errors.append(
                    f"selector record {r.get('selector')!r} lacks {key!r}")
    big = {
        r.get("selector"): r for r in selectors
        if r.get("n_elems") == SELECTOR_N_ELEMS
    }
    if {"sort", "sampled"} - set(big):
        errors.append(
            f"selectors field lacks the sort/sampled pair at the "
            f"{SELECTOR_N_ELEMS}-element (64 MB) reference buffer")
    else:
        t_sort = big["sort"].get("compress_steady_us")
        t_samp = big["sampled"].get("compress_steady_us")
        if not all(isinstance(t, (int, float)) for t in (t_sort, t_samp)):
            errors.append(
                f"selector 64 MB records lack numeric compress_steady_us "
                f"(sort {t_sort!r}, sampled {t_samp!r})")
        elif t_samp > t_sort:
            errors.append(
                f"sampled selector steady-state compress ({t_samp:.0f} us) is "
                f"slower than sort ({t_sort:.0f} us) at 64 MB — the O(n) "
                f"selection win regressed")
    return errors


def check_calibration(data: dict) -> List[str]:
    errors = []
    cal = data.get("calibration")
    if not cal:
        return ["missing 'calibration' field (measured cost model, "
                "DESIGN.md §17)"]
    for key in CALIBRATION_KEYS:
        if key not in cal:
            errors.append(f"calibration section lacks {key!r}")
    fits = {f.get("family"): f for f in cal.get("fits", [])}
    for missing in sorted(set(CALIBRATION_FAMILIES) - set(fits)):
        errors.append(f"calibration fits lack the {missing!r} family")
    for family, f in sorted(fits.items()):
        for key in ("alpha_s", "beta_s_per_byte"):
            v = f.get(key)
            if not isinstance(v, (int, float)) or v <= 0:
                errors.append(
                    f"calibration fit {family!r}: {key} must be a positive "
                    f"number, got {v!r}")
    for d in cal.get("decisions", []):
        tag = d.get("profile", "?")
        for key in DECISION_KEYS:
            if key not in d:
                errors.append(f"calibration decision {tag} lacks {key!r}")
        for key in ("auto_static", "auto_calibrated"):
            if key in d and d.get(key) not in SCHEDULE_NAMES:
                errors.append(
                    f"calibration decision {tag}: {key} must be one of "
                    f"{SCHEDULE_NAMES}, got {d.get(key)!r}")
    if not cal.get("decisions"):
        errors.append(
            "calibration section records no calibrated-vs-static decisions")
    return errors


TOPOLOGY_KEYS = (
    "nodes",
    "local",
    "workers",
    "payload_bits",
    "intra_bits_per_worker",
    "inter_bits_per_node",
    "inter_bits_per_worker",
    "flat_wire_bits_per_worker",
    "model_exchange_ms_hierarchical",
    "model_exchange_ms_flat_psum",
    "auto_transport",
)

TRANSPORT_DECISIONS = ("psum", "hierarchical")


def check_topology(data: dict) -> List[str]:
    errors = []
    topo = data.get("topology")
    if not topo:
        return ["missing 'topology' field (two-level wire sweep, "
                "DESIGN.md §18)"]
    by_nodes: dict = {}
    for r in topo:
        tag = f"{r.get('nodes')}x{r.get('local')}"
        for key in TOPOLOGY_KEYS:
            if key not in r:
                errors.append(f"topology record {tag} lacks {key!r}")
        if r.get("auto_transport") not in TRANSPORT_DECISIONS:
            errors.append(
                f"topology record {tag}: auto_transport must be one of "
                f"{TRANSPORT_DECISIONS}, got {r.get('auto_transport')!r}")
        inter = r.get("inter_bits_per_worker")
        flat = r.get("flat_wire_bits_per_worker")
        if isinstance(inter, (int, float)) and isinstance(flat, (int, float)):
            if not inter < flat:
                errors.append(
                    f"topology record {tag}: hierarchical per-worker "
                    f"inter-node wire ({inter:.3e} bits) must be strictly "
                    f"below the flat psum runtime wire ({flat:.3e} bits)")
            if isinstance(r.get("nodes"), int) and isinstance(
                    r.get("local"), int):
                by_nodes.setdefault(r["nodes"], []).append(
                    (r["local"], inter))
    for nodes, shapes in sorted(by_nodes.items()):
        shapes.sort()
        for (l_prev, w_prev), (l_next, w_next) in zip(shapes, shapes[1:]):
            if not w_next < w_prev:
                errors.append(
                    f"topology nodes={nodes}: per-worker inter-node wire "
                    f"must strictly shrink as the island grows, but "
                    f"local={l_next} records {w_next:.3e} >= {w_prev:.3e} "
                    f"at local={l_prev}")
    return errors


RESILIENCE_KEYS = (
    "n_elems",
    "n_buckets",
    "validate_level",
    "unguarded_compress_steady_us",
    "guarded_compress_steady_us",
    "guard_overhead_ratio",
    "guard_slack",
    "deterministic_ok",
)


def check_resilience(data: dict) -> List[str]:
    errors = []
    res = data.get("resilience")
    if not res:
        return ["missing 'resilience' field (exchange-guard overhead, "
                "DESIGN.md §19)"]
    for key in RESILIENCE_KEYS:
        if key not in res:
            errors.append(f"resilience section lacks {key!r}")
    if res.get("validate_level") not in ("cheap", "full"):
        errors.append(
            f"resilience validate_level must measure a non-off level "
            f"(cheap|full), got {res.get('validate_level')!r}")
    ratio = res.get("guard_overhead_ratio")
    if not isinstance(ratio, (int, float)) or not ratio > 0:
        errors.append(
            f"resilience guard_overhead_ratio must be a positive number, "
            f"got {ratio!r}")
    if res.get("deterministic_ok") is not True:
        errors.append(
            "resilience record lacks deterministic_ok=true — the structural "
            "guard invariants (no expensive primitives, validate('off') "
            "free) did not hold when the artifact was written")
    return errors


SERVE_RECORD_KEYS = (
    "publish_every",
    "theta",
    "n_publishes",
    "n_elems",
    "n_buckets",
    "delta_bytes_total",
    "snapshot_bytes_total",
    "dense_bytes_at_cadence",
    "wire_savings",
    "staleness_steps",
    "staleness_rel_err",
    "mirror_bitwise_equal",
    "model",
    "catchup",
    "gap",
)

SERVE_CATCHUP_KEYS = ("lag", "decompress_count", "bitwise_equal",
                      "crosses_rebase")


def check_serve(data: dict) -> List[str]:
    """Guard for ``BENCH_serve.json`` (the publish path, DESIGN.md §20).

    The two ISSUE-10 acceptance criteria live here: compressed deltas must
    be STRICTLY cheaper than dense snapshots at the same cadence on every
    record, and a K-behind catch-up inside one snapshot interval must cost
    exactly ONE decompress while landing bitwise on the one-at-a-time
    replay replica.  Plus coverage (several cadences x thetas, at least one
    multi-delta catch-up, at least one ring-wrap snapshot fallback) so a
    later PR cannot quietly shrink the matrix to a cell that happens to
    pass.
    """
    errors = []
    records = data.get("records")
    if not records:
        return ["missing 'records' field (cadence x theta publish sweep)"]
    for r in records:
        tag = f"every={r.get('publish_every')}/theta={r.get('theta')}"
        for key in SERVE_RECORD_KEYS:
            if key not in r:
                errors.append(f"serve record {tag} lacks {key!r}")
        delta = r.get("delta_bytes_total")
        dense = r.get("dense_bytes_at_cadence")
        if isinstance(delta, (int, float)) and isinstance(dense, (int, float)):
            if not delta < dense:
                errors.append(
                    f"serve record {tag}: compressed deltas ({delta} B) must "
                    f"be STRICTLY cheaper than dense snapshots at the same "
                    f"cadence ({dense} B)")
        catchup = r.get("catchup") or {}
        for key in SERVE_CATCHUP_KEYS:
            if key not in catchup:
                errors.append(f"serve record {tag}: catchup lacks {key!r}")
        if catchup.get("crosses_rebase") is False:
            if catchup.get("decompress_count") != 1:
                errors.append(
                    f"serve record {tag}: a catch-up inside one snapshot "
                    f"interval must run exactly ONE decompress, got "
                    f"{catchup.get('decompress_count')!r}")
        if catchup.get("bitwise_equal") is not True:
            errors.append(
                f"serve record {tag}: summed-spectrum catch-up is not "
                f"bitwise-equal to one-at-a-time replay")
        if r.get("mirror_bitwise_equal") is not True:
            errors.append(
                f"serve record {tag}: publisher mirror and replay replica "
                f"disagree — the error-feedback contract broke")
        model = r.get("model") or {}
        savings = model.get("savings")
        if not isinstance(savings, (int, float)) or not savings > 1.0:
            errors.append(
                f"serve record {tag}: modeled savings must exceed 1.0 "
                f"(deltas cheaper than dense), got {savings!r}")
    cadences = {r.get("publish_every") for r in records}
    thetas = {r.get("theta") for r in records}
    if len(cadences) < 2 or len(thetas) < 2:
        errors.append(
            f"serve sweep must cover >= 2 cadences x >= 2 thetas, got "
            f"{sorted(cadences)} x {sorted(thetas)}")
    if not any((r.get("catchup") or {}).get("lag", 0) > 1 for r in records):
        errors.append(
            "no serve record demonstrates a multi-delta (lag > 1) catch-up")
    if not any((r.get("gap") or {}).get("detected")
               and (r.get("gap") or {}).get("bitwise_equal_after")
               for r in records):
        errors.append(
            "no serve record demonstrates the ring-wrap snapshot fallback "
            "(gap detected + bitwise-equal recovery)")
    return errors


def check(data: dict) -> List[str]:
    """All violations in one pass (empty list == schema ok).

    Dispatches on the artifact's ``kind``: ``serve`` artifacts
    (BENCH_serve.json) get :func:`check_serve`, everything else the full
    throughput-schema battery.
    """
    if data.get("kind") == "serve":
        return check_serve(data)
    return (check_backends(data) + check_records(data)
            + check_schedules(data) + check_selectors(data)
            + check_calibration(data) + check_topology(data)
            + check_resilience(data))


def _summarize(path: str, data: dict) -> None:
    if data.get("kind") == "serve":
        records = data.get("records", [])
        best = max((r.get("wire_savings", 0) for r in records), default=0)
        print(f"schema ok [{path}]: {len(records)} publish records, "
              f"best wire savings {best}x")
        return
    n_back = len(data.get("backends", []))
    n_rec = len(data.get("records", []))
    n_sched = len(data.get("schedules", []))
    n_sel = len(data.get("selectors", []))
    n_cal = len(data.get("calibration", {}).get("decisions", []))
    n_topo = len(data.get("topology", []))
    guard_x = data.get("resilience", {}).get("guard_overhead_ratio")
    print(f"schema ok [{path}]: {n_back} backend records, {n_rec} sweep "
          f"records, {n_sched} schedule-policy records, {n_sel} selector "
          f"records, {n_cal} calibration decisions, {n_topo} topology "
          f"records, guard overhead {guard_x}x")


def main(argv=None) -> int:
    args = sys.argv[1:] if argv is None else argv
    paths = args if args else ["BENCH_throughput.json"]
    failed = False
    for path in paths:
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"BENCH SCHEMA FAIL: cannot read {path}: {e}")
            failed = True
            continue
        errors = check(data)
        for e in errors:
            print(f"BENCH SCHEMA FAIL [{path}]: {e}")
        if errors:
            failed = True
        else:
            _summarize(path, data)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
