"""Paper Fig. 3/4: gradient distribution study.

Trains the paper-era convnet briefly on the synthetic image task, samples
gradients early vs late, and reports (mean, std, excess kurtosis, range) —
verifying the two observations the compression design rests on:
  1. gradients cluster around 0 (near-normal),
  2. the range shrinks as training progresses.
"""

from __future__ import annotations

import jax
import jax.flatten_util
import numpy as np

from benchmarks.common import Row
from repro.models.convnet import ConvConfig, ConvNet, synthetic_image_batch
from repro.optim import OptConfig, apply_updates, init_opt_state


def _stats(flat: np.ndarray) -> dict:
    mu = float(flat.mean())
    sd = float(flat.std())
    z = (flat - mu) / max(sd, 1e-12)
    kurt = float((z**4).mean() - 3.0)
    return {"mean": round(mu, 6), "std": round(sd, 6),
            "excess_kurtosis": round(kurt, 2),
            "range": round(float(np.abs(flat).max()), 4),
            "frac_within_1std": round(float((np.abs(z) < 1).mean()), 3)}


def run() -> list:
    cfg = ConvConfig(widths=(8, 16), blocks_per_stage=1, img_size=16)
    net = ConvNet(cfg)
    params = net.init(jax.random.PRNGKey(0))
    opt_cfg = OptConfig(kind="sgd", lr=0.05, momentum=0.9)
    opt = init_opt_state(opt_cfg, params)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(net.loss, has_aux=True)(params, batch)
        p2, o2 = apply_updates(opt_cfg, params, grads, opt)
        return p2, o2, grads

    rows: list = []
    snapshots = {}
    for i in range(81):
        batch = synthetic_image_batch(jax.random.PRNGKey(i), cfg, 64)
        params, opt, grads = step(params, opt, batch)
        if i in (0, 80):
            flat = np.asarray(jax.flatten_util.ravel_pytree(grads)[0])
            snapshots[i] = flat
            rows.append(Row(name=f"fig3_gradient_distribution_step{i}",
                            **_stats(flat)))
    shrink = snapshots[80].std() / max(snapshots[0].std(), 1e-12)
    rows.append(Row(name="fig4_range_shrinkage",
                    std_ratio_late_over_early=round(float(shrink), 3),
                    shrinks=bool(shrink < 1.0)))
    return rows
