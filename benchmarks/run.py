"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Select subsets with
``python -m benchmarks.run [module ...]``; default runs everything except the
roofline (which needs dry-run artifacts; it prints a hint if absent).
"""

from __future__ import annotations

import sys
import time

from benchmarks.common import emit

MODULES = [
    "distribution",     # Fig. 3/4
    "reconstruction",   # Fig. 6/7
    "quantizer_density",  # Fig. 8
    "breakeven",        # Fig. 9 / SIII-D
    "convergence",      # Fig. 11/12 + Table I
    "throughput",       # Fig. 13/15
    "scalability",      # Fig. 14
    "roofline",         # EXPERIMENTS.md SRoofline
]


def main() -> None:
    selected = sys.argv[1:] or MODULES
    print("name,us_per_call,derived")
    for mod_name in selected:
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t0 = time.time()
        rows = mod.run()
        emit(rows)
        print(f"# {mod_name}: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
