"""Serving publish-path benchmark: BENCH_serve.json (DESIGN.md §20).

Trains the tiny lab LM once, records the committed-weight trajectory, then
replays the publisher (serve/publish.py) over it at every (publish_every,
theta) cell of the matrix — each cell gets its own ring in a temp dir and a
small replica fleet:

* ``sub_a`` — syncs after EVERY delta (the one-at-a-time replay reference);
* ``sub_b`` — joins at snapshot v0 and first syncs K deltas behind, inside
  one snapshot interval: the summed-spectrum catch-up must run exactly ONE
  decompress and land bitwise on ``sub_a``'s weights at that version;
* ``sub_b`` again at the end — at cadence 1 the ring has wrapped past it,
  exercising the snapshot-fallback (gap) path.

Per cell the artifact records measured wire bytes (delta vs dense-at-the-
same-cadence — the acceptance comparison), the modeled account
(``cost_model.publish_wire_account``), replica staleness vs the trainer
(steps + relative weight error, bounded by ONE delta's codec error thanks
to the publisher's error-feedback mirror), and the catch-up/gap evidence.
Schema-guarded by ``tools/check_bench.py`` (``kind == "serve"``).

Run from the repo root:

    PYTHONPATH=src python -m benchmarks.serve_bench [--out BENCH_serve.json]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile

import numpy as np
import jax

from repro import jaxcompat as compat
from repro.comms import bucketing, cost_model
from repro.comms.reducers import flatten_tree
from repro.configs.base import ArchConfig
from repro.data import SyntheticConfig, SyntheticStream
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import LM
from repro.optim import OptConfig
from repro.serve import PublishConfig, ReplicaSubscriber, WeightDeltaPublisher
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train.step import StepConfig

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=64, remat="none")
STEPS = 24
CADENCES = (1, 4, 8)
THETAS = (0.0, 0.7, 0.9)
# small chunk + bucket so the tiny LM still exercises a MULTI-bucket stacked
# layout with a ragged tail (the codec's hard case)
CHUNK = 256
BUCKET_BYTES = 1 << 18
SNAPSHOT_EVERY = 8
CAPACITY = 12  # < the cadence-1 delta count, so that cell wraps the ring


def _train_trajectory():
    """One tiny-LM run; returns (params tree per committed step, init tree)."""
    model = LM(TINY)
    opt = OptConfig(kind="adamw", lr=3e-3)
    mesh = make_local_mesh()
    stream = SyntheticStream(SyntheticConfig(vocab_size=64, seq_len=32,
                                             global_batch=8))
    state = init_state(jax.random.PRNGKey(0), model, opt)
    init_params = jax.tree_util.tree_map(np.asarray, state["params"])
    traj = []

    def record(step, metrics, state):
        traj.append(jax.tree_util.tree_map(np.asarray, state["params"]))

    with compat.set_mesh(mesh):
        train_loop(model, opt, StepConfig(mode="pjit"), mesh, state, stream,
                   TrainLoopConfig(total_steps=STEPS, log_every=STEPS,
                                   metrics_hook=record))
    assert len(traj) == STEPS
    return traj, init_params


def _rel_err(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30))


def _run_cell(traj, init_params, publish_every: int, theta: float) -> dict:
    cfg = PublishConfig(publish_every=publish_every, capacity=CAPACITY,
                        snapshot_every=SNAPSHOT_EVERY, theta=theta,
                        chunk=CHUNK, bucket_bytes=BUCKET_BYTES,
                        quantize=True)
    with tempfile.TemporaryDirectory() as ring_dir:
        pub = WeightDeltaPublisher(ring_dir, init_params, cfg)
        n_publishes = len(range(0, STEPS, publish_every))
        # first catch-up stays inside one snapshot interval: versions
        # 1..v_catch fold with NO rebase boundary, so the summed-spectrum
        # path must cost exactly one decompress
        v_catch = min(SNAPSHOT_EVERY - 1, n_publishes)
        sub_a = ReplicaSubscriber(ring_dir)  # per-delta replay reference
        sub_b = ReplicaSubscriber(ring_dir)  # the laggard
        a_weights = {}
        catchup = None
        for step, params in enumerate(traj):
            if pub.on_step(step, params) is None:
                continue
            sub_a.sync()
            a_weights[pub.version] = np.asarray(sub_a.weights())
            if pub.version == v_catch:
                stats = sub_b.sync()
                catchup = {
                    "lag": stats.applied,
                    "decompress_count": stats.decompress_count,
                    "bitwise_equal": bool(np.array_equal(
                        sub_b.weights(), a_weights[v_catch])),
                    "crosses_rebase": stats.rebases > 0,
                }
        pub.close()
        final = np.asarray(pub.state.materialize())
        # the laggard's final sync: at cadence 1 the ring wrapped past v7 and
        # this walks the snapshot-fallback path
        gap_stats = sub_b.sync()
        gap = {
            "detected": gap_stats.gap_detected,
            "snapshot_loads": gap_stats.snapshot_loads,
            "bitwise_equal_after": bool(np.array_equal(
                sub_b.weights(), a_weights[pub.version])),
        }
        flat_final, _, _ = flatten_tree(traj[-1])
        flat_final = np.asarray(flat_final)
        last_pub_step = max(s for s in range(0, STEPS, publish_every))
        model = cost_model.publish_wire_account(
            pub.layout.total, pub.comp.wire_bits, pub.layout.sizes(),
            steps=STEPS, publish_every=publish_every,
            snapshot_every=SNAPSHOT_EVERY, chunk=CHUNK)
        return {
            "publish_every": publish_every,
            "theta": theta,
            "n_publishes": pub.version,
            "n_elems": pub.layout.total,
            "n_buckets": pub.layout.n_buckets,
            "delta_bytes_total": pub.delta_bytes_total,
            "snapshot_bytes_total": pub.snapshot_bytes_total,
            "dense_bytes_at_cadence": 4 * pub.layout.total * pub.version,
            "wire_savings": round(
                4 * pub.layout.total * pub.version
                / max(pub.delta_bytes_total, 1), 3),
            "staleness_steps": (STEPS - 1) - last_pub_step,
            "staleness_rel_err": _rel_err(a_weights[pub.version], flat_final),
            "mirror_bitwise_equal": bool(np.array_equal(
                np.asarray(sub_a.weights()), final)),
            "model": model.to_dict(),
            "catchup": catchup,
            "gap": gap,
        }


def run() -> dict:
    traj, init_params = _train_trajectory()
    n_elems = int(flatten_tree(init_params)[0].shape[0])
    records = []
    for publish_every in CADENCES:
        for theta in THETAS:
            r = _run_cell(traj, init_params, publish_every, theta)
            records.append(r)
            print(f"publish_every={publish_every} theta={theta}: "
                  f"{r['delta_bytes_total']} delta B vs "
                  f"{r['dense_bytes_at_cadence']} dense B "
                  f"({r['wire_savings']}x), stale {r['staleness_steps']} "
                  f"steps rel_err {r['staleness_rel_err']:.2e}, catchup "
                  f"lag {r['catchup']['lag']} -> "
                  f"{r['catchup']['decompress_count']} decompress")
    return {
        "kind": "serve",
        "meta": {
            "arch": TINY.name,
            "steps": STEPS,
            "n_elems": n_elems,
            "chunk": CHUNK,
            "bucket_bytes": BUCKET_BYTES,
            "n_buckets": bucketing.build_layout(
                n_elems, BUCKET_BYTES, CHUNK).n_buckets,
            "snapshot_every": SNAPSHOT_EVERY,
            "capacity": CAPACITY,
        },
        "records": records,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    args = ap.parse_args(argv)
    data = run()
    with open(args.out, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out} ({len(data['records'])} records)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
