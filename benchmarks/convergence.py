"""Paper Fig. 11/12 + Table I: convergence vs compression ratio.

Trains the same tiny LM on learnable markov data under every compressor —
dense, FFT at theta {0.3, 0.7, 0.9}, the paper's "mixed" schedule
(theta 0.9 -> 0 mid-run), Theorem-3.5 schedule, time-domain top-k, TernGrad,
QSGD — and reports final loss + compression ratio.  Claims validated:
  * theta <= 0.7 matches the no-compression baseline (Fig. 11),
  * theta = 0.9 static degrades, the mixed schedule repairs it (Thm 3.5),
  * frequency domain beats time domain at equal theta (Fig. 12).

CPU-sized by design: 2-layer d64 LM, 70 steps.  The same driver scales on
real hardware via examples/convergence_paper.py.

NOTE: this single-device benchmark predates the convergence lab
(``src/repro/lab``, DESIGN.md §12), which runs the same claim matrix as real
multi-worker end-to-end training with per-step evidence and executable
claim checks — prefer ``python -m repro.lab.run`` for validation; this
benchmark remains as the quick single-device Fig. 11/12 table.
"""

from __future__ import annotations

import math

import jax

from benchmarks.common import Row
from repro import jaxcompat as compat
from repro.comms.reducers import ReducerConfig
from repro.configs.base import ArchConfig
from repro.core import schedules
from repro.data import SyntheticConfig, SyntheticStream
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import LM
from repro.optim import OptConfig
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train.step import StepConfig

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=64, remat="none")
STEPS = 70


def _run(reducer_cfg, theta_schedule=None) -> float:
    model = LM(TINY)
    opt = OptConfig(kind="adamw", lr=3e-3)
    mesh = make_local_mesh()
    stream = SyntheticStream(SyntheticConfig(vocab_size=64, seq_len=32,
                                             global_batch=8))
    mode = "pjit" if reducer_cfg is None else "compressed_dp"
    step_cfg = StepConfig(mode=mode, reducer=reducer_cfg)
    state = init_state(jax.random.PRNGKey(0), model, opt)
    with compat.set_mesh(mesh):
        out = train_loop(model, opt, step_cfg, mesh, state, stream,
                         TrainLoopConfig(total_steps=STEPS, log_every=STEPS - 1,
                                         theta_schedule=theta_schedule))
    return out["history"][-1]["loss"]


def run() -> list:
    n_grad = 1 << 18  # representative gradient size for ratio accounting
    from repro.core.compressor import FFTCompressor, FFTCompressorConfig, TimeDomainCompressor
    from repro.core import baselines as B

    variants = [
        ("orig_no_compression", None, None, 1.0),
        ("fft_theta0.3", ReducerConfig(kind="fft", axis="data", theta=0.3), None,
         FFTCompressor(FFTCompressorConfig(theta=0.3)).ratio(n_grad)),
        ("fft_theta0.7", ReducerConfig(kind="fft", axis="data", theta=0.7), None,
         FFTCompressor(FFTCompressorConfig(theta=0.7)).ratio(n_grad)),
        ("fft_theta0.9", ReducerConfig(kind="fft", axis="data", theta=0.9), None,
         FFTCompressor(FFTCompressorConfig(theta=0.9)).ratio(n_grad)),
        ("fft_mixed_0.9_to_0", ReducerConfig(kind="fft", axis="data", theta=0.9),
         schedules.step_decay([(0, 0.9), (STEPS // 2, 0.0)]), "dynamic"),
        ("fft_thm35_schedule", ReducerConfig(kind="fft", axis="data", theta=0.5),
         schedules.thm35_schedule(1.0, lambda s: 3e-3 * 100), "dynamic"),
        ("timedomain_theta0.7", ReducerConfig(kind="timedomain", axis="data", theta=0.7),
         None, TimeDomainCompressor(FFTCompressorConfig(theta=0.7)).ratio(n_grad)),
        ("terngrad", ReducerConfig(kind="terngrad", axis="data"), None,
         B.TernGrad().ratio(n_grad)),
        ("qsgd_4bit", ReducerConfig(kind="qsgd", axis="data"), None,
         B.QSGD().ratio(n_grad)),
    ]
    floor = math.log(4)  # markov branching entropy
    rows = []
    baseline = None
    for name, cfg, sched, ratio in variants:
        loss = _run(cfg, sched)
        if baseline is None:
            baseline = loss
        rows.append(Row(
            name=f"fig11_12_convergence_{name}",
            final_loss=round(loss, 4),
            vs_dense=round(loss - baseline, 4),
            compression_ratio=(round(ratio, 1) if isinstance(ratio, float) else ratio),
            entropy_floor=round(floor, 3),
        ))
    return rows
