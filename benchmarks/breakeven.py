"""Paper Fig. 9 / §III-D: minimal beneficial compression ratio k vs network
bandwidth, for the paper's V100 primitive throughputs and this repo's TPU-v5e
kernel estimates."""

from __future__ import annotations

from benchmarks.common import Row
from repro.comms import cost_model as cm


def run() -> list:
    rows = []
    for hw_name, thr in (("v100_paper", cm.PAPER_V100), ("tpu_v5e", cm.TPU_V5E)):
        for net, bw in cm.NETWORKS.items():
            k = cm.k_min(bw, thr)
            rows.append(Row(
                name=f"fig9_kmin_{hw_name}_{net}",
                bandwidth_gbps=round(bw / 1e9, 1),
                k_min=("inf" if k == float("inf") else round(k, 3)),
                compression_pays=bool(k != float("inf")),
            ))
    # the paper's own example: 250MB AlexNet gradient on 56Gb FDR
    m = 250e6
    rows.append(Row(
        name="fig9_alexnet_fdr_example",
        comp_cost_ms=round(cm.compression_cost_s(m, cm.TPU_V5E) * 1e3, 2),
        saved_ms_at_k13=round(cm.saved_comm_s(m, cm.NETWORKS["56Gb-FDR"], 13) * 1e3, 2),
        beneficial=cm.is_beneficial(m, cm.NETWORKS["56Gb-FDR"], 13, cm.TPU_V5E),
    ))
    return rows
