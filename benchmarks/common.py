"""Shared benchmark utilities: timing + CSV row helpers."""

from __future__ import annotations

import time
from typing import Callable, List

import jax

Row = dict


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (jit-compatible)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: List[Row]) -> None:
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}")
