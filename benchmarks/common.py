"""Shared benchmark utilities: timing + CSV row helpers."""

from __future__ import annotations

import time
from typing import Callable, List

import jax

Row = dict


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (jit-compatible)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def time_compiled(fn: Callable, *args, iters: int = 3) -> tuple:
    """(compile_us, steady_us): the first call's wall time (trace + compile +
    first run) and the median steady-state wall time after warm-up, both with
    ``block_until_ready``.

    Reporting these SEPARATELY is the point (DESIGN.md §14): a jitted
    per-bucket loop compiles one subgraph per bucket, so its first-call cost
    grows with the bucket count while its steady state does not — a single
    conflated number is dominated by whichever effect the harness happened to
    trigger, which is how the pre-split benchmark recorded "absurd"
    host-compress figures.
    """
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    compile_us = (time.perf_counter() - t0) * 1e6
    jax.block_until_ready(fn(*args))  # warm-up: caches, allocator steady state
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return compile_us, times[len(times) // 2] * 1e6


def emit(rows: List[Row]) -> None:
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}")
