"""Paper Fig. 14: scalability 2 -> 32 workers with/without compression.

Analytic model over the paper's own workloads (AlexNet 250 MB, ResNet50
102 MB gradients) on a 56 Gb FDR-class fabric (6 GB/s practical):

    T(n) = T_compute + T_comm(n) [+ T_compress]
    ring allreduce:   T_comm = 2 * M * (n-1)/n / BW     (dense)
                      T_comm = 2 * (M/k) * (n-1)/n / BW (compressed)
    speedup(n) = n * T(1)_compute / T(n)

Compression ratios: ours k=13.4 (theta=0.7, 8-bit), TernGrad 16, DGC 1000.
Compute times per iteration from the paper's Fig. 1 proportions.
"""

from __future__ import annotations

from benchmarks.common import Row
from repro.comms import cost_model as cm

BW = 1.5e9  # 56Gb FDR practical 6 GB/s shared by 4 GPUs/node (paper setup)
WORKLOADS = {
    # (gradient MB, per-iteration compute seconds @batch in Fig.13)
    "alexnet": (250e6, 0.18),
    "resnet50": (102e6, 0.45),
}
METHODS = {
    "orig": (1.0, 0.0),
    "terngrad": (16.0, 0.004),
    "dgc": (1000.0, 0.006),
    "ours_fft_theta0.7": (13.4, None),  # compression cost from §III-D model
}


def run() -> list:
    rows = []
    for wname, (m_bytes, t_compute) in WORKLOADS.items():
        for mname, (k, t_comp) in METHODS.items():
            if t_comp is None:
                t_comp = 2 * cm.compression_cost_s(m_bytes, cm.TPU_V5E)
            speedups = {}
            for n in (2, 8, 16, 32):
                t_comm = 2 * (m_bytes / k) * (n - 1) / n / BW
                t_iter = t_compute + t_comm + (t_comp if k > 1 else 0.0)
                speedups[n] = n * t_compute / t_iter
            rows.append(Row(
                name=f"fig14_scalability_{wname}_{mname}",
                k=k,
                speedup_2=round(speedups[2], 2),
                speedup_8=round(speedups[8], 2),
                speedup_16=round(speedups[16], 2),
                speedup_32=round(speedups[32], 2),
            ))
    return rows
