"""Roofline table: reads launch/dryrun.py artifacts and prints per-cell terms.

Run the dry-run first:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def run() -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        tag = os.path.basename(path)[:-5]
        if d.get("status") == "skipped":
            rows.append(Row(name=f"roofline_{tag}", status="skipped",
                            reason=d["reason"]))
            continue
        r = d["roofline"]
        rows.append(Row(
            name=f"roofline_{tag}",
            compute_ms=round(r["compute_s"] * 1e3, 2),
            memory_ms=round(r["memory_s"] * 1e3, 2),
            collective_ms=round(r["collective_s"] * 1e3, 2),
            dominant=r["dominant"],
            useful_flops_ratio=round(r["useful_ratio"], 3),
            roofline_fraction=round(r["roofline_fraction"], 3),
            hbm_fit_gib=round(sum(d["memory"].values()), 1),
        ))
    if not rows:
        rows.append(Row(name="roofline_missing_artifacts",
                        hint="run python -m repro.launch.dryrun --all first"))
    return rows
