"""Paper Fig. 13/15: compression primitive cost breakdown + exchange sweep.

Times each stage of the pipeline (FFT, select, pack, quantize, and the
composed compress/decompress) on a 64 MB gradient, jit-compiled on this host,
and derives projected TPU-v5e stage times from the §III-D throughput model
(the CPU numbers validate plumbing; the v5e numbers feed the break-even
analysis and EXPERIMENTS.md §Perf).

Also sweeps bucket size × transport through the cost model (DESIGN.md §9/§11)
— per-worker wire bits (priced at the transport's payload granularity via
``cost_model.bucketed_payload_bits``), modeled exchange time for BOTH the
looped (one collective per bucket, α·n launch latency) and stacked (one
``StackedPayload`` collective, α·1) exchanges — plus measured host-side
compress times with the compile/steady-state SPLIT (DESIGN.md §14):

* ``host_compress_compile_us`` / ``host_compress_steady_us`` — the jitted
  per-bucket loop (one compiled subgraph per bucket: compile cost grows with
  the bucket count);
* ``host_compress_dispatch_us`` — the per-bucket Python-dispatch loop (one
  jitted call per bucket: the pre-executor eager-driver behavior);
* ``stacked_compress_compile_us`` / ``stacked_compress_steady_us`` — the
  batched executor: ONE cached jitted launch for all buckets
  (``comms.executor``).

It also times the composed compress/decompress under EVERY engine backend
(DESIGN.md §13), writing everything to ``BENCH_throughput.json`` at the repo
root so the perf trajectory is recorded per PR.

Overlap engine (DESIGN.md §15): every bucketed sweep row additionally prices
the STREAMED dispatch schedule — readiness-ordered groups interleaved with a
modeled backward pass — and records ``overlap_efficiency`` (the fraction of
modeled exchange time hidden behind backprop) plus the auto policy's pick.
A separate ``schedules`` section runs the policy over model-registry
profiles (tiny lab model -> deep registry archs), which is where the
"streamed wins on deep models, stacked on latency-bound ones" claim is
recorded per PR.

Calibrated cost model (DESIGN.md §17): a ``calibration`` section runs the
real profiling pass (``comms/calibrate.py``) on this host's mesh — fitted
α–β per collective family, measured stage throughputs — and records the
auto policy's verdict per model profile under the static constants vs under
the measured profile.  ``tools/check_bench.py`` schema-guards all of it in
CI.

Two-level topology (DESIGN.md §18): a ``topology`` section sweeps (nodes,
local) island shapes through the hierarchical cost model — per-axis wire
bits (intra-node dense-spectrum psum per worker, inter-node compressed
payloads per node AND per worker), flat psum vs hierarchical modeled
exchange time, and the auto transport policy's pick.  ``check_bench``
enforces the acceptance shape: per-worker inter-node wire strictly below
the flat psum runtime wire on every swept shape, strictly shrinking as the
island grows.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_compiled, time_fn
from repro.comms import bucketing, cost_model as cm, executor, scheduler
from repro.core import fft as cfft
from repro.core import packing, sparsify
from repro.core.compressor import FFTCompressor, FFTCompressorConfig
from repro.core.quantizer import RangeQuantConfig, encode, fit_quantizer

N = 1 << 24  # 16M floats = 64 MB

SWEEP_WORKERS = 8
SWEEP_BUCKET_MB = (None, 1, 4, 16)  # None = monolithic (seed behavior)
SWEEP_TRANSPORTS = ("allgather", "sequenced", "psum")
# two-level (nodes, local) island shapes (DESIGN.md §18) — all >= 4 nodes
# (the ISSUE 8 acceptance regime), with growing islands per node count so
# check_bench can assert the per-worker fabric share shrinks with `local`
TOPOLOGY_SHAPES = ((4, 2), (4, 4), (4, 8), (8, 2), (8, 4))
TOPOLOGY_BUCKET_MB = 4
# engine backends timed on a smaller buffer: off-TPU the pallas backend runs
# its kernels in interpret mode, so host numbers validate plumbing (and feed
# the schema), while TPU runs measure the real fused-vs-staged gap (H-K1)
BACKEND_NAMES = ("reference", "pallas")
N_BACKEND = 32 * 4096  # 512 KB
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_throughput.json")


def _backend_rows(theta: float) -> tuple:
    """Per-backend compress+decompress timings (EXPERIMENTS.md H-K1)."""
    g = jax.random.normal(jax.random.PRNGKey(2), (N_BACKEND,)) * 0.05
    rows, records = [], []
    for backend in BACKEND_NAMES:
        comp = FFTCompressor(FFTCompressorConfig(theta=theta, backend=backend))
        compress = jax.jit(comp.compress)
        c_us = time_fn(compress, g, warmup=1, iters=3)
        payload = compress(g)
        d_us = time_fn(jax.jit(comp.decompress), payload, warmup=1, iters=3)
        rows.append(Row(
            name=f"backend_{backend}",
            compress_us=round(c_us, 1),
            decompress_us=round(d_us, 1),
            host_gbps=round(4 * N_BACKEND / ((c_us + d_us) / 1e6) / 1e9, 3),
        ))
        records.append({
            "backend": backend,
            "n_elems": N_BACKEND,
            "interpret_mode": jax.default_backend() != "tpu",
            "compress_us": round(c_us, 1),
            "decompress_us": round(d_us, 1),
        })
    return rows, records


def _selector_rows(theta: float) -> tuple:
    """Selection-engine steady-state columns (DESIGN.md §16): the full jitted
    compress on the 64 MB buffer under the exact sort vs the O(n) sampled-
    threshold selector.  This is the tentpole's acceptance row —
    ``tools/check_bench.py`` enforces sampled steady <= sort steady on the
    ``n_elems == N`` record, and ``perf_smoke`` gates the same comparison
    with a deterministic no-sort-op jaxpr fallback."""
    g = jax.random.normal(jax.random.PRNGKey(3), (N,)) * 0.05
    rows, records = [], []
    for sel in ("sort", "sampled"):
        cfg = FFTCompressorConfig(theta=theta, selector=sel)
        comp = FFTCompressor(cfg)
        compile_us, steady_us = time_compiled(jax.jit(comp.compress), g)
        rows.append(Row(
            name=f"selector_{sel}_64mb",
            compile_us=round(compile_us, 1),
            steady_us=round(steady_us, 1),
            host_gbps=round(4 * N / (steady_us / 1e6) / 1e9, 3),
        ))
        records.append({
            "selector": sel,
            "sample_rate": cfg.sample_rate,
            "tau_refine_iters": cfg.tau_refine_iters,
            "n_elems": N,
            "compress_compile_us": round(compile_us, 1),
            "compress_steady_us": round(steady_us, 1),
        })
    return rows, records


def _compress_timings(comp: FFTCompressor, g, layout) -> dict:
    """Looped vs stacked host compress, compile and steady state split.

    ``looped`` is the pre-executor execution shape twice over: jitted as one
    program (its compile time pays one subgraph PER BUCKET) and as a
    per-bucket Python dispatch loop (one jitted call per bucket — what an
    eager driver paid per exchange).  ``stacked`` is the batched executor:
    one cached jitted launch for every bucket (``comms.executor``).
    """
    buckets = bucketing.split_buckets(g, layout)
    looped = executor.looped_compress_fn(comp, layout)
    looped_compile_us, looped_steady_us = time_compiled(looped, g)
    one = jax.jit(comp.compress)
    dispatch = lambda: [one(b) for b in buckets]
    _, dispatch_us = time_compiled(dispatch)
    stacked = executor.compress_fn(comp, layout, donate=False)
    stacked_compile_us, stacked_steady_us = time_compiled(stacked, g)
    return {
        "host_compress_compile_us": round(looped_compile_us, 1),
        "host_compress_steady_us": round(looped_steady_us, 1),
        "host_compress_dispatch_us": round(dispatch_us, 1),
        "stacked_compress_compile_us": round(stacked_compile_us, 1),
        "stacked_compress_steady_us": round(stacked_steady_us, 1),
    }


def _streamed_columns(layout, transport, stacked_bits, m_bytes,
                      backprop_s, plan_stacked) -> dict:
    """Overlap-engine columns for one sweep row (DESIGN.md §15): streamed
    step-visible exchange time, overlap efficiency, and the auto policy's
    pick.  Monolithic rows (one bucket / allgather) have nothing to stream:
    overlap efficiency 0, auto resolves stacked.  ``plan_stacked`` is the
    row's already-priced stacked exchange (same inputs, priced once)."""
    if layout.n_buckets == 1 or transport == "allgather":
        return {
            "model_backprop_ms": backprop_s * 1e3,
            "model_exchange_ms_streamed": plan_stacked.exchange_s * 1e3,
            "model_n_collectives_streamed": 1,
            "overlap_efficiency": 0.0,
            "auto_schedule": "stacked",
        }
    splan = scheduler.build_plan(layout)
    streamed = cm.streamed_exchange_time_s(
        m_bytes, stacked_bits, cm.NETWORKS["tpu-dcn-host"], cm.TPU_V5E,
        workers=SWEEP_WORKERS, transport=transport,
        group_fractions=splan.group_fractions(), backprop_s=backprop_s)
    decision = scheduler.choose_schedule(
        splan, m_bytes, stacked_bits, workers=SWEEP_WORKERS,
        transport=transport, backprop_s=backprop_s)
    return {
        "model_backprop_ms": backprop_s * 1e3,
        # step-visible comms time: the part of the exchange sticking out
        # past the modeled backward pass (the stacked column serializes
        # after backprop, so its whole exchange_s is step-visible)
        "model_exchange_ms_streamed": streamed.exposed_s * 1e3,
        "model_n_collectives_streamed": streamed.n_collectives,
        "overlap_efficiency": streamed.overlap_efficiency,
        "auto_schedule": decision.schedule,
    }


def _topology_rows(comp: FFTCompressor) -> tuple:
    """Two-level topology sweep (DESIGN.md §18): for each (nodes, local)
    island shape, the per-axis wire split of one hierarchical exchange —
    the intra-node dense-spectrum psum every island worker pays, the
    ``nodes`` compressed payloads each island's fabric endpoint lands, and
    each worker's share of that fabric hop — against the flat psum
    transport's runtime wire at the same worker count, plus both modeled
    exchange times and the auto transport policy's pick.  This is the
    hierarchical-vs-flat wire table EXPERIMENTS.md cites, and check_bench
    gates the acceptance shape on it."""
    m_bytes = 4.0 * N
    layout = bucketing.build_layout(N, TOPOLOGY_BUCKET_MB << 20)
    payload_bits = cm.bucketed_payload_bits(
        comp.wire_bits, layout.sizes(), "psum", stacked=True,
        chunk=layout.chunk)
    rows, records = [], []
    for nodes, local in TOPOLOGY_SHAPES:
        workers = nodes * local
        flat = cm.exchange_time_s(
            m_bytes, payload_bits, cm.NETWORKS["tpu-dcn-host"], cm.TPU_V5E,
            workers=workers, transport="psum", n_buckets=layout.n_buckets,
            stacked=True, wire_mode="runtime", chunk=layout.chunk)
        hier = cm.two_level_exchange_time_s(
            m_bytes, payload_bits, nodes=nodes, local=local,
            wire_mode="runtime", chunk=layout.chunk)
        decision = scheduler.choose_transport(
            N, payload_bits, nodes=nodes, local=local,
            n_buckets=layout.n_buckets, chunk=layout.chunk)
        rows.append(Row(
            name=f"topology_{nodes}x{local}",
            intra_mbits=round(hier.wire.intra_bits_per_worker / 1e6, 1),
            inter_mbits_node=round(hier.wire.inter_bits_per_node / 1e6, 1),
            inter_mbits_worker=round(
                hier.wire.inter_bits_per_worker / 1e6, 1),
            flat_mbits_worker=round(flat.wire_bits_per_worker / 1e6, 1),
            hier_ms=round(hier.exchange_s * 1e3, 3),
            flat_ms=round(flat.exchange_s * 1e3, 3),
            auto=decision.transport,
        ))
        records.append({
            "nodes": nodes,
            "local": local,
            "workers": workers,
            "n_buckets": layout.n_buckets,
            "payload_bits": payload_bits,
            "intra_bits_per_worker": hier.wire.intra_bits_per_worker,
            "inter_bits_per_node": hier.wire.inter_bits_per_node,
            "inter_bits_per_worker": hier.wire.inter_bits_per_worker,
            "flat_wire_bits_per_worker": flat.wire_bits_per_worker,
            "model_exchange_ms_hierarchical": hier.exchange_s * 1e3,
            "model_exchange_ms_flat_psum": flat.exchange_s * 1e3,
            "model_intra_ms": hier.intra_s * 1e3,
            "model_inter_ms": hier.inter_s * 1e3,
            "auto_transport": decision.transport,
        })
    return rows, records


def _sweep_rows(comp: FFTCompressor) -> list:
    """Bucket size × transport sweep: modeled wire/time + measured compress."""
    m_bytes = 4 * N
    g = jax.random.normal(jax.random.PRNGKey(1), (N,)) * 0.05
    # modeled backward pass covering this 64 MB (16M-param) exchange at the
    # policy's default token count — the streamed columns' overlap cover
    backprop_s = scheduler.modeled_backprop_s(N, scheduler.DEFAULT_BATCH_TOKENS)
    rows, records = [], []
    for bucket_mb in SWEEP_BUCKET_MB:
        bucket_bytes = None if bucket_mb is None else bucket_mb << 20
        layout = bucketing.build_layout(N, bucket_bytes)
        timings = _compress_timings(comp, g, layout)
        for transport in SWEEP_TRANSPORTS:
            if transport == "allgather" and layout.n_buckets > 1:
                continue  # monolithic by definition
            # payload priced at the transport's quantizer granularity:
            # per-bucket params for sequenced/psum, one global fit otherwise
            payload_bits = cm.bucketed_payload_bits(
                comp.wire_bits, layout.sizes(), transport)
            # the stacked payload bills every bucket at the padded row width
            # (== payload_bits here: the sweep's layouts are not ragged)
            stacked_bits = cm.bucketed_payload_bits(
                comp.wire_bits, layout.sizes(), transport, stacked=True,
                chunk=layout.chunk)
            plan = cm.exchange_time_s(
                m_bytes, payload_bits, cm.NETWORKS["tpu-dcn-host"], cm.TPU_V5E,
                workers=SWEEP_WORKERS, transport=transport,
                n_buckets=layout.n_buckets)
            plan_stacked = cm.exchange_time_s(
                m_bytes, stacked_bits, cm.NETWORKS["tpu-dcn-host"], cm.TPU_V5E,
                workers=SWEEP_WORKERS, transport=transport,
                n_buckets=layout.n_buckets, stacked=True)
            streamed_cols = _streamed_columns(
                layout, transport, stacked_bits, m_bytes, backprop_s,
                plan_stacked)
            label = "mono" if bucket_mb is None else f"{bucket_mb}mb"
            rows.append(Row(
                name=f"exchange_sweep_{transport}_{label}",
                us_per_call=timings["host_compress_steady_us"],
                stacked_us=timings["stacked_compress_steady_us"],
                n_buckets=layout.n_buckets,
                wire_mbits_per_worker=round(plan.wire_bits_per_worker / 1e6, 1),
                model_exchange_ms=round(plan.exchange_s * 1e3, 3),
                model_exchange_ms_stacked=round(
                    plan_stacked.exchange_s * 1e3, 3),
                model_exchange_ms_streamed=round(
                    streamed_cols["model_exchange_ms_streamed"], 3),
                overlap_eff=round(streamed_cols["overlap_efficiency"], 3),
                overlap=round(plan.overlap, 3),
            ))
            records.append({
                "transport": transport,
                "bucket_mb": bucket_mb,
                "n_buckets": layout.n_buckets,
                "workers": SWEEP_WORKERS,
                "message_mb": m_bytes / (1 << 20),
                # selection-engine decision behind the measured compress
                # columns (DESIGN.md §16; the sweep keeps the default sort
                # selector so the perf trajectory stays comparable across PRs)
                "selector": comp.config.selector,
                "sample_rate": comp.config.sample_rate,
                "tau_refine_iters": comp.config.tau_refine_iters,
                **timings,
                "payload_bits": payload_bits,
                "wire_bits_per_worker": plan.wire_bits_per_worker,
                "model_exchange_ms": plan.exchange_s * 1e3,
                "model_exchange_ms_stacked": plan_stacked.exchange_s * 1e3,
                "model_n_collectives": plan.n_collectives,
                "model_n_collectives_stacked": plan_stacked.n_collectives,
                "overlap_fraction": plan.overlap,
                **streamed_cols,
            })
    backend_rows, backend_records = _backend_rows(comp.config.theta)
    rows.extend(backend_rows)
    selector_rows, selector_records = _selector_rows(comp.config.theta)
    rows.extend(selector_rows)
    schedule_rows, schedule_records = _schedule_rows(comp)
    rows.extend(schedule_rows)
    calibration_rows, calibration_section = _calibration_rows(comp)
    rows.extend(calibration_rows)
    topology_rows, topology_records = _topology_rows(comp)
    rows.extend(topology_rows)
    with open(BENCH_JSON, "w") as f:
        json.dump({"benchmark": "throughput_exchange_sweep",
                   "theta": comp.config.theta,
                   "n_bits": comp.config.n_bits,
                   "records": records,
                   "backends": backend_records,
                   "selectors": selector_records,
                   "schedules": schedule_records,
                   "calibration": calibration_section,
                   "topology": topology_records}, f, indent=2)
    return rows


# auto-policy profiles: (name, n_params, batch_tokens, bucket_bytes).  The
# tiny profile is the convergence lab's LM at a fine bucket grain
# (latency-bound: alpha per group dwarfs what its sub-ms backprop could
# hide); the deep profiles approximate registry archs by parameter count
# (bandwidth-bound: backprop is long enough to hide the whole exchange).
# Parameter counts are the policy model's input, not a measurement —
# recorded in the row for honesty.
SCHEDULE_PROFILES = (
    ("lab_lm_tiny", 1 << 17, 512, 64 << 10),
    ("gemma2_2b_deep", 2_600_000_000, 8192, 16 << 20),
    ("qwen1_5_110b_deep", 110_000_000_000, 8192, 16 << 20),
)


def _schedule_rows(comp: FFTCompressor) -> tuple:
    """Auto-policy sweep over model profiles (DESIGN.md §15): stacked vs
    streamed step-visible exchange time per profile, with the decision and
    its overlap efficiency recorded — the per-PR trajectory of the
    "streamed wins on deep models" claim."""
    rows, records = [], []
    for name, n_params, batch_tokens, bucket_bytes in SCHEDULE_PROFILES:
        m_bytes = 4.0 * n_params
        layout = bucketing.build_layout(n_params, bucket_bytes)
        plan = scheduler.build_plan(layout)
        payload_bits = cm.bucketed_payload_bits(
            comp.wire_bits, layout.sizes(), "sequenced", stacked=True,
            chunk=layout.chunk)
        backprop_s = scheduler.modeled_backprop_s(n_params, batch_tokens)
        decision = scheduler.choose_schedule(
            plan, m_bytes, payload_bits, workers=SWEEP_WORKERS,
            transport="sequenced", backprop_s=backprop_s)
        streamed = cm.streamed_exchange_time_s(
            m_bytes, payload_bits, cm.NETWORKS["tpu-dcn-host"], cm.TPU_V5E,
            workers=SWEEP_WORKERS, transport="sequenced",
            group_fractions=plan.group_fractions(), backprop_s=backprop_s)
        rows.append(Row(
            name=f"schedule_policy_{name}",
            auto=decision.schedule,
            n_buckets=layout.n_buckets,
            backprop_ms=round(backprop_s * 1e3, 3),
            stacked_step_ms=round(decision.stacked_step_s * 1e3, 3),
            streamed_step_ms=round(decision.streamed_step_s * 1e3, 3),
            overlap_efficiency=round(streamed.overlap_efficiency, 4),
        ))
        records.append({
            "profile": name,
            "n_params": n_params,
            "batch_tokens": batch_tokens,
            "n_buckets": layout.n_buckets,
            "workers": SWEEP_WORKERS,
            "transport": "sequenced",
            "model_backprop_ms": backprop_s * 1e3,
            "model_step_ms_stacked": decision.stacked_step_s * 1e3,
            "model_step_ms_streamed": decision.streamed_step_s * 1e3,
            "model_exchange_ms_exposed_streamed": streamed.exposed_s * 1e3,
            "overlap_efficiency": streamed.overlap_efficiency,
            "auto_schedule": decision.schedule,
        })
    return rows, records


def _calibration_rows(comp: FFTCompressor) -> tuple:
    """Calibrated cost model (DESIGN.md §17): run the real profiling pass on
    this host's mesh and record (a) the fitted α–β per collective family,
    the measured stage throughputs and the backprop-rate default, and (b)
    the auto policy's verdict per model profile under the STATIC constants
    vs under the MEASURED profile — the per-PR record of where calibration
    changes the decision.  The whole section is schema-guarded by
    ``tools/check_bench.py`` (fitted α > 0, β > 0, both verdicts present).
    """
    import dataclasses

    from repro.comms import calibrate
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh()
    profile = calibrate.calibrate(
        mesh, "data", sizes_bytes=calibrate.SMOKE_SIZES_BYTES,
        throughput_elems=1 << 18)
    rows, decisions = [], []
    for name, n_params, batch_tokens, bucket_bytes in SCHEDULE_PROFILES:
        m_bytes = 4.0 * n_params
        layout = bucketing.build_layout(n_params, bucket_bytes)
        plan = scheduler.build_plan(layout)
        payload_bits = cm.bucketed_payload_bits(
            comp.wire_bits, layout.sizes(), "sequenced", stacked=True,
            chunk=layout.chunk)
        static = scheduler.choose_schedule(
            plan, m_bytes, payload_bits, workers=SWEEP_WORKERS,
            transport="sequenced",
            backprop_s=scheduler.modeled_backprop_s(n_params, batch_tokens))
        calibrated = scheduler.choose_schedule(
            plan, m_bytes, payload_bits, workers=SWEEP_WORKERS,
            transport="sequenced",
            backprop_s=profile.backprop_s(n_params, batch_tokens),
            profile=profile)
        rows.append(Row(
            name=f"calibration_decision_{name}",
            auto_static=static.schedule,
            auto_calibrated=calibrated.schedule,
            stacked_step_ms=round(calibrated.stacked_step_s * 1e3, 3),
            streamed_step_ms=round(calibrated.streamed_step_s * 1e3, 3),
        ))
        decisions.append({
            "profile": name,
            "n_params": n_params,
            "batch_tokens": batch_tokens,
            "workers": SWEEP_WORKERS,
            "transport": "sequenced",
            "auto_static": static.schedule,
            "auto_calibrated": calibrated.schedule,
            "model_step_ms_stacked_calibrated": calibrated.stacked_step_s * 1e3,
            "model_step_ms_streamed_calibrated": calibrated.streamed_step_s * 1e3,
            "overlap_efficiency_calibrated": calibrated.overlap_efficiency,
        })
    for fit in profile.fits:
        rows.append(Row(
            name=f"calibration_fit_{fit.family}",
            alpha_us=round(fit.alpha_s * 1e6, 2),
            link_gbps=round(fit.t_comm / 1e9, 3),
            n_points=fit.n_points,
        ))
    section = {
        "platform": profile.key.platform,
        "jax_version": profile.key.jax_version,
        "mesh": [list(ax) for ax in profile.key.mesh],
        "decision_workers": SWEEP_WORKERS,
        "fits": [f.to_dict() for f in profile.fits],
        "throughputs": dataclasses.asdict(profile.throughputs),
        "backprop_flops_per_s": profile.backprop_flops_per_s,
        "decisions": decisions,
    }
    return rows, section


def run() -> list:
    g = jax.random.normal(jax.random.PRNGKey(0), (N,)) * 0.05
    theta = 0.7
    comp = FFTCompressor(FFTCompressorConfig(theta=theta))
    rows = []

    fft_fn = jax.jit(lambda x: cfft.chunked_rfft(x)[0])
    freqs = fft_fn(g)
    k = sparsify.keep_count(freqs.shape[-1], theta)
    mag = jnp.abs(freqs)
    select_fn = jax.jit(lambda m: sparsify.topk_select(m, k))
    idx = select_fn(mag)
    pack_fn = jax.jit(lambda f, i: packing.pack_by_indices(f, i))
    q = fit_quantizer(-1.0, 1.0, RangeQuantConfig(8, 3))
    vals = jnp.real(pack_fn(freqs, idx))
    quant_fn = jax.jit(lambda v: encode(v, q))

    stages = [
        ("fft", fft_fn, (g,), 4 * N),
        ("topk_select", select_fn, (mag,), 4 * mag.size),
        ("pack", pack_fn, (freqs, idx), 8 * freqs.size),
        ("quantize", quant_fn, (vals,), 4 * vals.size),
        ("compress_total", jax.jit(comp.compress), (g,), 4 * N),
    ]
    payload = jax.jit(comp.compress)(g)
    stages.append(("decompress_total", jax.jit(comp.decompress), (payload,), 4 * N))

    for name, fn, args, bytes_in in stages:
        us = time_fn(fn, *args, warmup=1, iters=3)
        rows.append(Row(
            name=f"fig15_stage_{name}",
            us_per_call=round(us, 1),
            host_gbps=round(bytes_in / (us / 1e6) / 1e9, 2),
        ))

    # derived v5e stage times from the kernel throughput model (§III-D)
    m_bytes = 4 * N
    thr = cm.TPU_V5E
    rows.append(Row(
        name="fig13_v5e_projection_64MB",
        compress_ms=round(cm.compression_cost_s(m_bytes, thr) * 1e3, 3),
        wire_ms_dense_ici=round(m_bytes / cm.NETWORKS["tpu-ici-link"] * 1e3, 3),
        wire_ms_dense_dcn=round(m_bytes / cm.NETWORKS["tpu-dcn-host"] * 1e3, 3),
        wire_ms_k13_dcn=round(m_bytes / 13 / cm.NETWORKS["tpu-dcn-host"] * 1e3, 3),
        ratio=round(comp.ratio(N), 1),
    ))
    rows.extend(_sweep_rows(comp))
    return rows
