"""Paper Fig. 13/15: compression primitive cost breakdown.

Times each stage of the pipeline (FFT, select, pack, quantize, and the
composed compress/decompress) on a 64 MB gradient, jit-compiled on this host,
and derives projected TPU-v5e stage times from the §III-D throughput model
(the CPU numbers validate plumbing; the v5e numbers feed the break-even
analysis and EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.comms import cost_model as cm
from repro.core import fft as cfft
from repro.core import packing, sparsify
from repro.core.compressor import FFTCompressor, FFTCompressorConfig
from repro.core.quantizer import RangeQuantConfig, encode, fit_quantizer

N = 1 << 24  # 16M floats = 64 MB


def run() -> list:
    g = jax.random.normal(jax.random.PRNGKey(0), (N,)) * 0.05
    theta = 0.7
    comp = FFTCompressor(FFTCompressorConfig(theta=theta))
    rows = []

    fft_fn = jax.jit(lambda x: cfft.chunked_rfft(x)[0])
    freqs = fft_fn(g)
    k = sparsify.keep_count(freqs.shape[-1], theta)
    mag = jnp.abs(freqs)
    select_fn = jax.jit(lambda m: sparsify.topk_select(m, k))
    idx = select_fn(mag)
    pack_fn = jax.jit(lambda f, i: packing.pack_by_indices(f, i))
    q = fit_quantizer(-1.0, 1.0, RangeQuantConfig(8, 3))
    vals = jnp.real(pack_fn(freqs, idx))
    quant_fn = jax.jit(lambda v: encode(v, q))

    stages = [
        ("fft", fft_fn, (g,), 4 * N),
        ("topk_select", select_fn, (mag,), 4 * mag.size),
        ("pack", pack_fn, (freqs, idx), 8 * freqs.size),
        ("quantize", quant_fn, (vals,), 4 * vals.size),
        ("compress_total", jax.jit(comp.compress), (g,), 4 * N),
    ]
    payload = jax.jit(comp.compress)(g)
    stages.append(("decompress_total", jax.jit(comp.decompress), (payload,), 4 * N))

    for name, fn, args, bytes_in in stages:
        us = time_fn(fn, *args, warmup=1, iters=3)
        rows.append(Row(
            name=f"fig15_stage_{name}",
            us_per_call=round(us, 1),
            host_gbps=round(bytes_in / (us / 1e6) / 1e9, 2),
        ))

    # derived v5e stage times from the kernel throughput model (§III-D)
    m_bytes = 4 * N
    thr = cm.TPU_V5E
    rows.append(Row(
        name="fig13_v5e_projection_64MB",
        compress_ms=round(cm.compression_cost_s(m_bytes, thr) * 1e3, 3),
        wire_ms_dense_ici=round(m_bytes / cm.NETWORKS["tpu-ici-link"] * 1e3, 3),
        wire_ms_dense_dcn=round(m_bytes / cm.NETWORKS["tpu-dcn-host"] * 1e3, 3),
        wire_ms_k13_dcn=round(m_bytes / 13 / cm.NETWORKS["tpu-dcn-host"] * 1e3, 3),
        ratio=round(comp.ratio(N), 1),
    ))
    return rows
