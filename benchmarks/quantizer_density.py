"""Paper Fig. 8: distribution of representable numbers of the range-based
8-bit float for ranges [-1,1] and [-10,10], vs uniform 8-bit quantization.

Derived columns: density near zero vs near the boundary, and end-to-end SNR
on gaussian gradients for range-based vs uniform 8-bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core.quantizer import RangeQuantConfig, decode, encode, fit_quantizer, representable_values


def _density(vals: np.ndarray, lo: float, hi: float) -> float:
    return float(((vals >= lo) & (vals <= hi)).sum())


def run() -> list:
    rows = []
    cfg = RangeQuantConfig(8, 3)
    for lo, hi in ((-1.0, 1.0), (-10.0, 10.0)):
        q = fit_quantizer(lo, hi, cfg)
        vals = np.sort(np.asarray(representable_values(q)))
        span = hi - lo
        rows.append(Row(
            name=f"fig8_density_range[{lo},{hi}]",
            n_values=len(np.unique(vals)),
            within_1pct_of_zero=_density(vals, -0.01 * span, 0.01 * span),
            within_outer_10pct=_density(vals, hi - 0.1 * span, hi),
            eps=float(q.eps),
        ))

    # SNR comparison vs uniform 8-bit on gaussian gradients
    g = jax.random.normal(jax.random.PRNGKey(0), (100000,)) * 0.1
    q = fit_quantizer(g.min(), g.max(), cfg)
    gr = decode(encode(g, q), q)
    mse_range = float(jnp.mean((g - gr) ** 2))
    lo, hi = float(g.min()), float(g.max())
    gu = jnp.round((g - lo) / (hi - lo) * 255.0)
    gu = gu / 255.0 * (hi - lo) + lo
    mse_uniform = float(jnp.mean((g - gu) ** 2))
    var = float(jnp.var(g))
    rows.append(Row(
        name="fig8_snr_range_vs_uniform_8bit",
        snr_range_db=round(10 * np.log10(var / mse_range), 2),
        snr_uniform_db=round(10 * np.log10(var / mse_uniform), 2),
    ))
    return rows
