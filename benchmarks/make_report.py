"""Generate the EXPERIMENTS.md §Roofline table from dry-run artifacts.

    PYTHONPATH=src:. python -m benchmarks.make_report

Reads benchmarks/artifacts/dryrun (current) and dryrun_v1_baseline (pre
B1/B2 revisions), emits a markdown table + per-cell bottleneck notes, and
splices it between the ROOFLINE_TABLE markers in EXPERIMENTS.md.
"""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts")

MOVER = {
    "compute": "raise arithmetic intensity (bf16 matmuls already; reduce remat recompute / dispatch overhead)",
    "memory": "cut HBM round-trips: larger fused regions, smaller flash/CE tiles kept in VMEM, bf16 intermediates",
    "collective": "shrink or overlap the dominant exchange (compressed gradient sync / fewer reshards / EP layout)",
}


def _load(dirname):
    out = {}
    for p in sorted(glob.glob(os.path.join(ART, dirname, "*__single__pjit.json"))):
        d = json.load(open(p))
        out[(d["arch"], d["shape"])] = d
    return out


def _multi(dirname):
    out = {}
    for p in sorted(glob.glob(os.path.join(ART, dirname, "*__multi__*.json"))):
        d = json.load(open(p))
        out[(d["arch"], d["shape"])] = d
    return out


def build_table() -> str:
    cur = _load("dryrun")
    base = _load("dryrun_v1_baseline")
    # coverage union: cells not yet re-run after the B-series revisions fall
    # back to their v1 baseline numbers (marked v1)
    for key, d in base.items():
        cur.setdefault(key, dict(d, _v1_fallback=True))
    multi = _multi("dryrun")
    lines = [
        "| arch | shape | compute | memory | collective | dominant | useful | HBM GiB (v1→v2) | multi-pod |",
        "|---|---|---:|---:|---:|---|---:|---|---|",
    ]
    notes = []
    for (arch, shape), d in sorted(cur.items()):
        if d.get("status") == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | skipped | — | — | {d['reason'][:40]} |")
            continue
        r = d["roofline"]
        mem_v2 = sum(d["memory"].values())
        b = base.get((arch, shape))
        mem_v1 = sum(b["memory"].values()) if b and b.get("status") == "ok" else None
        mp = multi.get((arch, shape))
        mp_s = "OK" if mp and mp.get("status") == "ok" else ("skip" if mp and mp.get("status") == "skipped" else "—")
        fmt = lambda s: f"{s*1e3:.0f} ms" if s >= 1e-3 else f"{s*1e6:.0f} µs"
        mem_str = (f"{mem_v1:.0f}→{mem_v2:.0f}" if mem_v1 is not None else f"{mem_v2:.0f}")
        tag = " (v1)" if d.get("_v1_fallback") else ""
        lines.append(
            f"| {arch} | {shape}{tag} | {fmt(r['compute_s'])} | {fmt(r['memory_s'])} | "
            f"{fmt(r['collective_s'])} | {r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{mem_str} | {mp_s} |")
        notes.append(
            f"* **{arch} × {shape}** — {r['dominant']}-bound "
            f"(roofline fraction {r['roofline_fraction']:.2f}); to move it: "
            f"{MOVER[r['dominant']]}.")
    return "\n".join(lines) + "\n\nPer-cell bottleneck notes:\n\n" + "\n".join(notes)


def main():
    table = build_table()
    exp_path = os.path.join(os.path.dirname(__file__), "..", "docs", "EXPERIMENTS.md")
    text = open(exp_path).read()
    marker = "<!-- ROOFLINE_TABLE -->"
    head, _, tail = text.partition(marker)
    # replace everything from the marker to the next section header
    rest = tail.split("\n## ", 1)
    tail2 = ("\n## " + rest[1]) if len(rest) > 1 else ""
    open(exp_path, "w").write(head + marker + "\n\n" + table + "\n" + tail2)
    print(table)


if __name__ == "__main__":
    main()
