"""Paper Fig. 6/7: reconstruction quality, frequency vs time domain.

Reports relative L2 error, sign-agreement, and Assumption 3.1 margins across
theta for both domains on gradient-like (gaussian) and structured (smooth)
signals — the paper's qualitative claim quantified.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import theory
from repro.core.compressor import FFTCompressor, FFTCompressorConfig, TimeDomainCompressor


def _signals():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (65536,)) * 0.05
    t = jnp.arange(65536, dtype=jnp.float32)
    smooth = 0.05 * jnp.sin(t / 60.0) + 0.02 * jnp.sin(t / 7.0) + 0.01 * jax.random.normal(key, (65536,))
    return {"gaussian_grad": g, "structured_grad": smooth}


def run() -> list:
    rows = []
    for sig_name, v in _signals().items():
        for theta in (0.5, 0.7, 0.9):
            cfg = FFTCompressorConfig(theta=theta, quantize=False)
            for dom, comp in (("freq", FFTCompressor(cfg)),
                              ("time", TimeDomainCompressor(cfg))):
                v_hat = comp.decompress(comp.compress(v))
                err, norm_ratio = theory.assumption31_stats(v, v_hat)
                sign = float(jnp.mean(jnp.sign(v_hat) == jnp.sign(v)))
                rows.append(Row(
                    name=f"fig6_7_recon_{sig_name}_{dom}_theta{theta}",
                    rel_l2_err=round(float(err), 4),
                    sign_agreement=round(sign, 4),
                    norm_ratio=round(float(norm_ratio), 4),
                    assumption31_sqrt_bound=round(theta**0.5, 4),
                ))
    return rows
