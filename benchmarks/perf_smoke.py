"""CI perf smoke for the batched bucket executor (DESIGN.md §14).

Small enough for a CI runner (8 MB buffer, 8 buckets), strict enough to catch
the two regressions that would quietly undo the executor's point:

1. **steady state** — one stacked launch must not be slower than the jitted
   per-bucket loop (same math, fewer dispatches; tolerance covers timer
   noise on loaded runners);
2. **launch/compile overhead** — the stacked executable must build
   meaningfully faster than the per-bucket loop's one-subgraph-per-bucket
   program (this is the "one launch for all buckets" property: the looped
   program's build cost grows with the bucket count, the stacked one's does
   not).

Exits nonzero with a diagnostic on failure; run from the repo root (module
form, so the ``benchmarks`` package resolves):

    PYTHONPATH=src python -m benchmarks.perf_smoke
"""

from __future__ import annotations

import sys

import jax

from benchmarks.common import time_compiled
from repro.comms import bucketing, executor
from repro.core.compressor import FFTCompressor, FFTCompressorConfig

N = 1 << 21  # 2M floats = 8 MB
BUCKET_BYTES = 1 << 20  # 1 MB buckets -> 8 buckets
STEADY_SLACK = 1.25  # stacked steady <= looped steady * slack (timer noise)
COMPILE_RATIO = 2.0  # looped compile must exceed stacked compile by this


def main() -> int:
    g = jax.random.normal(jax.random.PRNGKey(0), (N,)) * 0.05
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    layout = bucketing.build_layout(N, BUCKET_BYTES)
    assert layout.n_buckets == 8, layout.n_buckets

    looped = executor.looped_compress_fn(comp, layout)
    looped_compile, looped_steady = time_compiled(looped, g)
    stacked = executor.compress_fn(comp, layout, donate=False)
    stacked_compile, stacked_steady = time_compiled(stacked, g)

    print(f"looped : compile {looped_compile / 1e3:9.1f} ms   "
          f"steady {looped_steady / 1e3:8.1f} ms   "
          f"({layout.n_buckets} buckets)")
    print(f"stacked: compile {stacked_compile / 1e3:9.1f} ms   "
          f"steady {stacked_steady / 1e3:8.1f} ms   (1 launch)")

    failures = []
    if stacked_steady > looped_steady * STEADY_SLACK:
        failures.append(
            f"stacked steady-state compress ({stacked_steady / 1e3:.1f} ms) is "
            f"slower than the per-bucket loop ({looped_steady / 1e3:.1f} ms) "
            f"beyond the {STEADY_SLACK}x noise slack")
    if looped_compile < stacked_compile * COMPILE_RATIO:
        failures.append(
            f"stacked executable build ({stacked_compile / 1e3:.1f} ms) is not "
            f">={COMPILE_RATIO}x cheaper than the per-bucket loop's "
            f"({looped_compile / 1e3:.1f} ms) — the one-launch win regressed")
    for f in failures:
        print("PERF SMOKE FAIL:", f)
    if not failures:
        print("PERF SMOKE OK: stacked executor holds both bounds")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
