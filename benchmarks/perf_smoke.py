"""CI perf smoke for the batched bucket executor (DESIGN.md §14) and the
selection engine (DESIGN.md §16).

Small enough for a CI runner (8 MB buffer, 8 buckets), strict enough to catch
the regressions that would quietly undo each subsystem's point:

1. **steady state** — one stacked launch must not be slower than the jitted
   per-bucket loop (same math, fewer dispatches; tolerance covers timer
   noise on loaded runners);
2. **launch/compile overhead** — the stacked executable must build
   meaningfully faster than the per-bucket loop's one-subgraph-per-bucket
   program (this is the "one launch for all buckets" property: the looped
   program's build cost grows with the bucket count, the stacked one's does
   not);
3. **selection** — the sampled selector's steady-state compress must beat
   the sort selector's (the O(n) threshold's entire point), with a
   deterministic structural fallback: the sampled compress jaxpr must
   contain NO sort-family primitive while the sort compress still does;
4. **guard overhead** (DESIGN.md §19) — stacked compress with ``cheap``
   payload validation must cost <= GUARD_SLACK x the unvalidated compress
   (validation is O(payload) elementwise work riding an O(n log n) kernel),
   with a deterministic structural fallback: validation must add NO
   sort/FFT/collective primitive, and ``validate('off')`` must add zero
   equations (resilience off = bit-for-bit the historical program).  The
   measured ratio is persisted as the ``resilience`` section of
   ``BENCH_throughput.json`` (guarded by ``tools/check_bench.py``).

Flake policy: both gates compare WALL-CLOCK ratios, which a loaded CI runner
can violate without any code regression (a noisy neighbor during exactly one
timing window).  A failed measurement is therefore RERUN ONCE with fresh
timings; if the rerun also fails, the gate falls back to DETERMINISTIC
assertions on modeled/structural quantities that cannot flake — the traced
looped program must grow with the bucket count while the stacked program
stays bucket-count independent, and the cost model must price one collective
launch for the stacked exchange vs one per bucket looped.  Only a
deterministic violation fails CI; a wall-clock-only miss is reported as
inconclusive (exit 0 with a warning), never as a red build.

Exits nonzero with a diagnostic on failure; run from the repo root (module
form, so the ``benchmarks`` package resolves):

    PYTHONPATH=src python -m benchmarks.perf_smoke
"""

from __future__ import annotations

import json
import os
import sys

import jax

from benchmarks.common import time_compiled
from repro.comms import bucketing, cost_model as cm, executor
from repro.core.compressor import FFTCompressor, FFTCompressorConfig

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_throughput.json")

N = 1 << 21  # 2M floats = 8 MB
BUCKET_BYTES = 1 << 20  # 1 MB buckets -> 8 buckets
STEADY_SLACK = 1.25  # stacked steady <= looped steady * slack (timer noise)
COMPILE_RATIO = 2.0  # looped compile must exceed stacked compile by this
# selection engine (DESIGN.md §16): the sampled selector's steady-state
# compress must beat the sort selector's (its entire point); the slack only
# absorbs timer noise, not a real loss
SELECTOR_SLACK = 1.0
# resilience (DESIGN.md §19): cheap payload validation on the stacked
# compress must stay within 5% of the unvalidated path
GUARD_SLACK = 1.05


def _measure(comp, layout, g):
    """One fresh wall-clock measurement of both execution shapes."""
    executor.clear_cache()  # fresh executables: compile cost must be real
    looped = executor.looped_compress_fn(comp, layout)
    looped_compile, looped_steady = time_compiled(looped, g)
    stacked = executor.compress_fn(comp, layout, donate=False)
    stacked_compile, stacked_steady = time_compiled(stacked, g)
    return {
        "looped_compile": looped_compile,
        "looped_steady": looped_steady,
        "stacked_compile": stacked_compile,
        "stacked_steady": stacked_steady,
    }


def _gate(t: dict, n_buckets: int) -> list:
    """Wall-clock gates -> list of failure strings (empty == pass)."""
    failures = []
    if t["stacked_steady"] > t["looped_steady"] * STEADY_SLACK:
        failures.append(
            f"stacked steady-state compress ({t['stacked_steady'] / 1e3:.1f} ms) "
            f"is slower than the per-bucket loop "
            f"({t['looped_steady'] / 1e3:.1f} ms) beyond the "
            f"{STEADY_SLACK}x noise slack")
    if t["looped_compile"] < t["stacked_compile"] * COMPILE_RATIO:
        failures.append(
            f"stacked executable build ({t['stacked_compile'] / 1e3:.1f} ms) is "
            f"not >={COMPILE_RATIO}x cheaper than the per-bucket loop's "
            f"({t['looped_compile'] / 1e3:.1f} ms) — the one-launch win "
            f"regressed (or the runner is loaded; deterministic fallback "
            f"decides)")
    del n_buckets
    return failures


def _deterministic_fallback(comp) -> list:
    """Structural + modeled assertions that cannot flake on a loaded runner.

    * program growth — the traced per-bucket loop's jaxpr gains equations
      with the bucket count (one subgraph per bucket); the stacked program's
      equation count is bucket-count independent (the rolled ``lax.map``
      grid).  This is the property the compile-time gate measures, asserted
      on the trace instead of the clock.
    * launch pricing — the cost model prices one collective launch stacked
      vs one per bucket looped; the stacked exchange must win once alpha
      dominates.  Pure arithmetic, no timers.
    """
    failures = []
    few = bucketing.build_layout(N, 4 * BUCKET_BYTES)  # 2 buckets
    many = bucketing.build_layout(N, BUCKET_BYTES)  # 8 buckets
    g = jax.ShapeDtypeStruct((N,), jax.numpy.float32)

    def eqns(fn):
        return len(jax.make_jaxpr(fn)(g).eqns)

    def looped(layout):
        return lambda flat: comp.compress_buckets(
            bucketing.split_buckets(flat, layout))

    def stacked(layout):
        return lambda flat: comp.compress_stacked(
            bucketing.stack_buckets(flat, layout), layout.sizes())

    looped_growth = eqns(looped(many)) - eqns(looped(few))
    stacked_growth = eqns(stacked(many)) - eqns(stacked(few))
    if looped_growth <= 0:
        failures.append(
            f"looped program no longer grows with the bucket count "
            f"({looped_growth:+d} eqns from 2 to 8 buckets) — the baseline "
            f"this gate compares against has changed shape")
    if stacked_growth != 0:
        failures.append(
            f"stacked program is no longer bucket-count independent "
            f"({stacked_growth:+d} eqns from 2 to 8 buckets) — the "
            f"one-launch property regressed structurally")

    kw = dict(workers=8, transport="sequenced", n_buckets=many.n_buckets)
    payload_bits = cm.bucketed_payload_bits(
        comp.wire_bits, many.sizes(), "sequenced")
    looped_plan = cm.exchange_time_s(
        4 * N, payload_bits, cm.NETWORKS["tpu-dcn-host"], cm.TPU_V5E, **kw)
    stacked_plan = cm.exchange_time_s(
        4 * N, payload_bits, cm.NETWORKS["tpu-dcn-host"], cm.TPU_V5E,
        stacked=True, **kw)
    if stacked_plan.n_collectives != 1 or looped_plan.n_collectives != many.n_buckets:
        failures.append(
            f"cost model stopped pricing one stacked collective vs one per "
            f"bucket ({stacked_plan.n_collectives} vs "
            f"{looped_plan.n_collectives})")
    if stacked_plan.launch_s >= looped_plan.launch_s:
        failures.append(
            "modeled stacked launch latency no longer beats the looped "
            "exchange's alpha*n_buckets")
    return failures


def _measure_selectors(g):
    """Fresh wall-clock steady-state compress per selector (DESIGN.md §16)."""
    out = {}
    for sel in ("sort", "sampled"):
        comp = FFTCompressor(FFTCompressorConfig(theta=0.7, selector=sel))
        _, steady = time_compiled(jax.jit(comp.compress), g)
        out[sel] = steady
    return out


def _gate_selectors(t: dict) -> list:
    if t["sampled"] > t["sort"] * SELECTOR_SLACK:
        return [
            f"sampled-selector steady-state compress ({t['sampled'] / 1e3:.1f} "
            f"ms) is not faster than the sort selector "
            f"({t['sort'] / 1e3:.1f} ms) — the O(n) selection win regressed "
            f"(or the runner is loaded; deterministic fallback decides)"]
    return []


def _jaxpr_primitives(fn, *avals) -> set:
    """All primitive names in a traced fn, nested jaxprs included."""
    names = set()

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            names.add(eqn.primitive.name)
            for v in eqn.params.values():
                for w in (v if isinstance(v, (list, tuple)) else (v,)):
                    if hasattr(w, "eqns"):
                        walk(w)
                    elif hasattr(w, "jaxpr"):
                        walk(w.jaxpr)

    walk(jax.make_jaxpr(fn)(*avals).jaxpr)
    return names


def _deterministic_selector_fallback() -> list:
    """Structural selector assertions that cannot flake (DESIGN.md §16).

    The sampled selector's entire claim is O(n) selection: its traced
    compress must contain NO sort-family primitive anywhere (the DGC bracket,
    the bisection refinement, and the count-and-compact binary search are all
    compare/count/gather ops), while the sort selector's compress must still
    contain one — if it stopped, this gate would be comparing sampled
    against itself and the wall-clock numbers mean nothing.
    """
    failures = []
    g = jax.ShapeDtypeStruct((N,), jax.numpy.float32)
    sort_family = {"sort", "top_k", "approx_top_k"}
    for sel, want_sort in (("sampled", False), ("sort", True)):
        comp = FFTCompressor(FFTCompressorConfig(theta=0.7, selector=sel))
        found = _jaxpr_primitives(comp.compress, g) & sort_family
        if want_sort and not found:
            failures.append(
                "sort-selector compress no longer contains a sort/top_k "
                "primitive — the baseline this gate compares against has "
                "changed shape")
        if not want_sort and found:
            failures.append(
                f"sampled-selector compress contains sort-family primitives "
                f"{sorted(found)} — the O(n) selection property regressed "
                f"structurally")
    return failures


def _guard_fns(comp, layout):
    """(unguarded, guarded) stacked-compress callables (DESIGN.md §19)."""

    def unguarded(flat):
        return comp.compress_stacked(
            bucketing.stack_buckets(flat, layout), layout.sizes())

    def guarded(flat):
        payload = comp.compress_stacked(
            bucketing.stack_buckets(flat, layout), layout.sizes())
        return payload, payload.validate("cheap")

    return unguarded, guarded


def _measure_guard(comp, layout, g):
    """Fresh wall-clock steady-state compress with/without validation."""
    unguarded, guarded = _guard_fns(comp, layout)
    _, t_un = time_compiled(jax.jit(unguarded), g)
    _, t_gu = time_compiled(jax.jit(guarded), g)
    return {"unguarded": t_un, "guarded": t_gu}


def _gate_guard(t: dict) -> list:
    if t["guarded"] > t["unguarded"] * GUARD_SLACK:
        return [
            f"guarded stacked compress ({t['guarded'] / 1e3:.1f} ms) exceeds "
            f"{GUARD_SLACK}x the unguarded path ({t['unguarded'] / 1e3:.1f} "
            f"ms) — cheap validation stopped being O(payload) elementwise "
            f"work (or the runner is loaded; deterministic fallback decides)"]
    return []


def _deterministic_guard_fallback(comp, layout) -> list:
    """Structural guard assertions that cannot flake (DESIGN.md §19).

    * ``validate('cheap')`` must add only elementwise/reduction work — no
      sort-family, FFT, or collective primitive may appear in the guarded
      program that the unguarded one lacks;
    * ``validate('off')`` must be FREE: identical equation count to the
      unvalidated program (resilience off keeps the historical program).
    """
    failures = []
    g = jax.ShapeDtypeStruct((N,), jax.numpy.float32)
    unguarded, guarded = _guard_fns(comp, layout)

    expensive = {"sort", "top_k", "approx_top_k", "fft",
                 "all_reduce", "all_gather", "reduce_scatter", "psum",
                 "all_to_all", "ppermute"}
    extra = (_jaxpr_primitives(guarded, g)
             - _jaxpr_primitives(unguarded, g)) & expensive
    if extra:
        failures.append(
            f"cheap validation adds expensive primitives {sorted(extra)} to "
            f"the stacked compress — the O(payload) guard property regressed "
            f"structurally")

    def guarded_off(flat):
        payload = comp.compress_stacked(
            bucketing.stack_buckets(flat, layout), layout.sizes())
        return payload, payload.validate("off")

    n_off = len(jax.make_jaxpr(guarded_off)(g).eqns)
    n_un = len(jax.make_jaxpr(unguarded)(g).eqns)
    if n_off != n_un:
        failures.append(
            f"validate('off') is no longer free: {n_off} eqns vs the "
            f"unvalidated program's {n_un} — resilience off must keep the "
            f"historical program")
    return failures


def _write_resilience(t: dict, deterministic_ok: bool, n_buckets: int) -> None:
    """Persist the guard-overhead evidence into BENCH_throughput.json."""
    try:
        with open(BENCH_JSON) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        print(f"PERF SMOKE: {BENCH_JSON} unreadable; resilience section "
              f"not persisted")
        return
    data["resilience"] = {
        "n_elems": N,
        "n_buckets": n_buckets,
        "validate_level": "cheap",
        "unguarded_compress_steady_us": round(t["unguarded"], 1),
        "guarded_compress_steady_us": round(t["guarded"], 1),
        "guard_overhead_ratio": round(
            t["guarded"] / max(t["unguarded"], 1e-9), 4),
        "guard_slack": GUARD_SLACK,
        "deterministic_ok": bool(deterministic_ok),
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(data, f, indent=1)
    print(f"PERF SMOKE: resilience section written to {BENCH_JSON}")


def main() -> int:
    g = jax.random.normal(jax.random.PRNGKey(0), (N,)) * 0.05
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    layout = bucketing.build_layout(N, BUCKET_BYTES)
    assert layout.n_buckets == 8, layout.n_buckets

    t = _measure(comp, layout, g)
    failures = _gate(t, layout.n_buckets)
    ts = _measure_selectors(g)
    sel_failures = _gate_selectors(ts)
    tg = _measure_guard(comp, layout, g)
    guard_failures = _gate_guard(tg)
    attempt = 1
    if failures or sel_failures or guard_failures:
        print("PERF SMOKE: wall-clock gate missed; rerunning once "
              "(loaded-runner tolerance):")
        for f in failures + sel_failures + guard_failures:
            print("  -", f)
        if failures:
            t = _measure(comp, layout, g)
            failures = _gate(t, layout.n_buckets)
        if sel_failures:
            ts = _measure_selectors(g)
            sel_failures = _gate_selectors(ts)
        if guard_failures:
            tg = _measure_guard(comp, layout, g)
            guard_failures = _gate_guard(tg)
        attempt = 2

    print(f"looped : compile {t['looped_compile'] / 1e3:9.1f} ms   "
          f"steady {t['looped_steady'] / 1e3:8.1f} ms   "
          f"({layout.n_buckets} buckets)")
    print(f"stacked: compile {t['stacked_compile'] / 1e3:9.1f} ms   "
          f"steady {t['stacked_steady'] / 1e3:8.1f} ms   (1 launch)")
    print(f"selector: sort steady {ts['sort'] / 1e3:8.1f} ms   "
          f"sampled steady {ts['sampled'] / 1e3:8.1f} ms   "
          f"({ts['sort'] / max(ts['sampled'], 1e-9):.2f}x)")
    print(f"guard   : unguarded {tg['unguarded'] / 1e3:8.1f} ms   "
          f"guarded {tg['guarded'] / 1e3:8.1f} ms   "
          f"({tg['guarded'] / max(tg['unguarded'], 1e-9):.3f}x, "
          f"slack {GUARD_SLACK}x)")

    if not failures and not sel_failures and not guard_failures:
        _write_resilience(tg, deterministic_ok=True,
                          n_buckets=layout.n_buckets)
        print(f"PERF SMOKE OK: stacked executor, sampled selector and "
              f"exchange guard hold their bounds (attempt {attempt})")
        return 0

    print("PERF SMOKE: wall-clock gates failed twice; falling back to "
          "deterministic modeled/structural assertions:")
    for f in failures + sel_failures + guard_failures:
        print("  - (timing)", f)
    det = []
    if failures:
        det += _deterministic_fallback(comp)
    if sel_failures:
        det += _deterministic_selector_fallback()
    guard_det = _deterministic_guard_fallback(comp, layout) if guard_failures else []
    det += guard_det
    for f in det:
        print("PERF SMOKE FAIL:", f)
    if det:
        return 1
    _write_resilience(tg, deterministic_ok=not guard_det,
                      n_buckets=layout.n_buckets)
    print("PERF SMOKE OK (deterministic): structural and modeled invariants "
          "hold; wall-clock miss attributed to runner load")
    return 0


if __name__ == "__main__":
    sys.exit(main())
