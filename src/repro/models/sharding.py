"""Logical-axis parameter sharding (MaxText-style rules).

Every parameter is declared as a :class:`ParamSpec` with *logical* axis names
(("vocab", "embed"), ("heads", "head_dim"), ...).  At mesh-bind time the rules
map logical axes to mesh axes, with two safety valves:

* divisibility — a logical axis only binds to a mesh axis whose size divides
  the dimension; otherwise that dim is replicated (e.g. kv_heads=5 on a
  model=16 mesh);
* fsdp — when ``fsdp=True`` the FIRST yet-unsharded large axis of each param
  additionally binds to the ``data`` axis (ZeRO-3-style parameter sharding;
  required for the 110B/141B/235B configs to fit 16 GB/chip HBM).

Gradient sync over the ``pod`` axis stays dense/compressed per the reducer —
parameters are never sharded over ``pod`` (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "ParamSpec",
    "DEFAULT_RULES",
    "TWO_LEVEL_DATA_AXES",
    "data_axes_for",
    "resolve_pspec",
    "spec_tree_to_pspecs",
    "init_params",
    "count_params",
]

# The two-level data topology's axis pair (DESIGN.md §18).  Parameters are
# NEVER sharded over these (like ``pod``): they are pure data-parallel axes,
# and the hierarchical transports own the gradient traffic across them.
TWO_LEVEL_DATA_AXES = ("node", "local")


def data_axes_for(mesh_axis_sizes: Dict[str, int]) -> Tuple[str, ...]:
    """The mesh's data-parallel (batch) axes, in mesh order.

    A two-level mesh carries ("node", "local"); a flat mesh carries
    ("data",) (plus a leading "pod" on multi-pod meshes).  This is the one
    place the batch-axes spelling is derived from a mesh, so the lab runner
    and the CLI agree with ``StepConfig.batch_axes``.
    """
    if all(a in mesh_axis_sizes for a in TWO_LEVEL_DATA_AXES):
        return tuple(a for a in mesh_axis_sizes
                     if a in TWO_LEVEL_DATA_AXES)
    axes = tuple(a for a in mesh_axis_sizes if a in ("pod", "data"))
    return axes if axes else ("data",)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape + logical axes + initializer."""

    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones
    scale: Optional[float] = None  # stddev; default 0.02 (normal)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (self.shape, self.logical_axes)


# logical axis -> preferred mesh axis ("model" = tensor-parallel axis).
# NOTE a head-count axis that cannot divide the model axis (gemma2's 8 q /
# 4 kv heads on 16-way TP) REPLICATES rather than falling back to head_dim:
# head_dim TP makes every score einsum all-reduce the full (q_chunk, kv_chunk)
# tile — measured at 1.2 TB/step/device on gemma2 train_4k (EXPERIMENTS.md
# §Perf, refuted hypothesis H-G1).  FSDP over 'data' keeps the replicated
# weights memory-cheap.
DEFAULT_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "ssm_inner": "model",
    "xlstm_inner": "model",
    "embed": None,  # fsdp may claim it
    "head_dim": None,
    "layers": None,
    "conv": None,
    "state": None,
}

# axes eligible for FSDP claiming, in preference order (largest-dim first is
# resolved per-param below; these are the axes allowed to carry it)
_FSDP_ELIGIBLE = ("embed", "ff", "vocab", "heads", "experts", "ssm_inner", "xlstm_inner")


def resolve_pspec(
    spec: ParamSpec,
    mesh_axis_sizes: Dict[str, int],
    rules: Dict[str, Optional[str]] = DEFAULT_RULES,
    fsdp: bool = False,
    fsdp_axis: str = "data",
) -> P:
    """ParamSpec -> PartitionSpec under the given mesh."""
    assignment: list = []
    used_mesh_axes = set()
    for dim, logical in zip(spec.shape, spec.logical_axes):
        mesh_axis = rules.get(logical) if logical else None
        if (
            mesh_axis
            and mesh_axis in mesh_axis_sizes
            and mesh_axis not in used_mesh_axes
            and dim % mesh_axis_sizes[mesh_axis] == 0
        ):
            assignment.append(mesh_axis)
            used_mesh_axes.add(mesh_axis)
        else:
            assignment.append(None)

    if fsdp and fsdp_axis in mesh_axis_sizes and fsdp_axis not in used_mesh_axes:
        # claim the largest eligible unsharded dim divisible by the fsdp axis
        best, best_dim = None, 0
        for i, (dim, logical) in enumerate(zip(spec.shape, spec.logical_axes)):
            if (
                assignment[i] is None
                and logical in _FSDP_ELIGIBLE
                and dim % mesh_axis_sizes[fsdp_axis] == 0
                and dim > best_dim
            ):
                best, best_dim = i, dim
        if best is not None:
            assignment[best] = fsdp_axis

    return P(*assignment)


def spec_tree_to_pspecs(spec_tree, mesh_axis_sizes, rules=DEFAULT_RULES, fsdp=False):
    return jax.tree_util.tree_map(
        lambda s: resolve_pspec(s, mesh_axis_sizes, rules, fsdp),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _init_one(key, spec: ParamSpec, dtype=jnp.float32):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    scale = spec.scale if spec.scale is not None else 0.02
    return (jax.random.normal(key, spec.shape) * scale).astype(dtype)


def init_params(key, spec_tree, dtype=jnp.float32):
    """Instantiate a ParamSpec tree into arrays (unique key per leaf)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    arrays = [_init_one(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrays)


def abstract_params(spec_tree, dtype=jnp.float32):
    """ShapeDtypeStruct tree (no allocation) — dry-run path."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def count_params(spec_tree) -> int:
    """Exact parameter count from the spec tree (authoritative for roofline)."""
    leaves = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    return sum(math.prod(s.shape) for s in leaves)
