"""Model assembly: layer-kind registry + scan-over-groups stacks + LM API.

Every assigned architecture is a sequence of *layer kinds* (ArchConfig
.layer_pattern()) repeated ``n_groups`` times.  Parameters for the repeating
group are **stacked** on a leading "layers" axis and the stack is walked with
``jax.lax.scan`` — HLO size and compile time are depth-independent (a 94-layer
qwen3 compiles the same graph as a 2-layer smoke model).  Heterogeneous
patterns (gemma2 [local, global], llama-vision [self x4, cross], xlstm
[mLSTM x7, sLSTM]) simply make the scanned group hold several kinds.

Three execution paths share the same parameters:
  * train/teacher-forced full-sequence forward (no caches),
  * prefill (full-sequence + emit caches, stacked per group),
  * decode_step (one token, caches threaded through the scan).

Activation sharding constraints are applied when a :class:`MeshCtx` is given
(inside pjit with an ambient mesh); smoke tests pass ``mesh_ctx=None``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.models.layers import (
    COMPUTE_DTYPE,
    embed,
    embedding_spec,
    mlp,
    mlp_spec,
    rmsnorm,
    rmsnorm_spec,
    softcap,
    unembed,
)
from repro.models.flags import scan_inner
from repro.models.sharding import ParamSpec

__all__ = ["LM", "MeshCtx"]


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    """Activation-sharding axes (None = no constraints, e.g. CPU smoke)."""

    batch: Tuple[str, ...] = ("data",)
    model: Optional[str] = "model"  # None on pure-DP meshes
    model_size: int = 16
    seq: Optional[str] = None  # long_500k: shard sequence instead of batch


def _constrain_bsd(x, ctx: Optional[MeshCtx]):
    """Interior (within-layer) constraint: batch over data, seq REPLICATED
    over model — attention/MLP internals stay free of seq-sharding (letting
    seq-sharding propagate into the flash tile scans was measured at 51k
    all-gathers / 6.6 TB/step on qwen1.5 train_4k; §Perf B1, first attempt)."""
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(ctx.batch, ctx.seq, None))


def _constrain_stream(x, ctx: Optional[MeshCtx]):
    """BOUNDARY (stored-carry) constraint — sequence parallelism (§Perf B1).

    The (B,S,D) stream the layer scan CARRIES (and remat therefore stores,
    n_groups copies of it) is sharded over the model axis on the sequence
    dim: qwen1.5 train_4k stored-input memory 62 GiB -> 3.9 GiB/device.  One
    allgather at group entry + one scatter at exit (Megatron-SP g/g-bar at
    group granularity)."""
    if ctx is None:
        return x
    seq_axes = ctx.seq
    if (seq_axes is None and ctx.model and x.ndim == 3
            and x.shape[1] % max(ctx.model_size, 1) == 0 and x.shape[1] > 1):
        seq_axes = ctx.model
    return jax.lax.with_sharding_constraint(x, P(ctx.batch, seq_axes, None))


def _constrain_cache(cache, ctx: Optional[MeshCtx], kv_heads_ok: bool):
    if ctx is None:
        return cache
    spec = P(ctx.batch, ctx.seq, ctx.model if kv_heads_ok else None, None)
    k = jax.lax.with_sharding_constraint(cache.k, spec)
    v = jax.lax.with_sharding_constraint(cache.v, spec)
    return A.KVCache(k, v, cache.pos, cache.ring)


# ---------------------------------------------------------------------------
# layer kinds
# ---------------------------------------------------------------------------


def _attn_window(cfg, kind: str) -> int:
    return cfg.sliding_window if "local" in kind else 0


def _layer_spec(cfg, kind: str) -> dict:
    d = cfg.d_model
    spec: Dict[str, Any] = {"norm1": rmsnorm_spec(d)}
    if kind.startswith("attn") or kind == "hybrid":
        spec["attn"] = A.attention_spec(cfg)
    if kind.startswith("cross_attn"):
        spec["cross"] = A.attention_spec(cfg, cross=True)
        spec["cross_gate"] = ParamSpec((1,), (None,), init="zeros")
    if kind == "dec_cross_mlp":
        spec["attn"] = A.attention_spec(cfg)
        spec["cross"] = A.attention_spec(cfg, cross=True)
        spec["norm_cross"] = rmsnorm_spec(d)
    if kind == "hybrid":
        spec["ssm"] = S.ssm_spec(cfg)
        spec["norm_attn_out"] = rmsnorm_spec(d)
        spec["norm_ssm_out"] = rmsnorm_spec(d)
    if kind == "mlstm":
        return {"norm1": rmsnorm_spec(d), "cell": X.mlstm_spec(cfg)}
    if kind == "slstm":
        return {"norm1": rmsnorm_spec(d), "cell": X.slstm_spec(cfg)}
    # mlp half
    if kind.endswith("moe"):
        spec["norm2"] = rmsnorm_spec(d)
        spec["moe"] = M.moe_spec(cfg)
    elif kind.endswith("mlp"):
        spec["norm2"] = rmsnorm_spec(d)
        spec["mlp"] = mlp_spec(d, cfg.d_ff, cfg.mlp_activation)
    return spec


def _self_attention_full(p, x, cfg, positions, window, cache, ctx):
    """Full-sequence self attention; returns (out, new_cache_or_None)."""
    q, k, v = A.project_qkv(
        p, x, x, q_positions=positions, kv_positions=positions,
        rope_theta=cfg.rope_theta,
    )
    new_cache = None
    if cache is not None:
        new_cache = A.update_kv_cache(cache, k, v, jnp.int32(0))
        new_cache = _constrain_cache(new_cache, ctx, cfg.n_kv_heads % 8 == 0)
    out = A.flash_attention(
        q, k, v, q_positions=positions, kv_positions=positions,
        causal=True, window=window, attn_softcap=cfg.attn_softcap,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
    )
    return A.attend(p, out), new_cache


def _self_attention_decode(p, x, cfg, pos, window, cache, ctx):
    q, k, v = A.project_qkv(
        p, x, x, q_positions=pos[None], kv_positions=pos[None],
        rope_theta=cfg.rope_theta,
    )
    cache = A.update_kv_cache(cache, k, v, pos)
    out = A.flash_attention(
        q, cache.k, cache.v, q_positions=pos[None], kv_positions=cache.pos,
        causal=True, window=window, attn_softcap=cfg.attn_softcap,
        q_chunk=1, kv_chunk=min(4096, cache.k.shape[1]),
    )
    return A.attend(p, out), cache


def _cross_attention(p, x, memory, cfg, cross_cache=None):
    """Cross attention; memory (B, Sm, D) or cached K/V."""
    if cross_cache is not None:
        dt = x.dtype
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
        b, sq, h, dh = q.shape
        kh = cross_cache.k.shape[2]
        q = q.reshape(b, sq, kh, h // kh, dh)
        k, v = cross_cache.k, cross_cache.v
        kv_pos = cross_cache.pos
    else:
        q, k, v = A.project_qkv(p, x, memory)  # no rope on cross
        kv_pos = jnp.arange(k.shape[1])
    out = A.flash_attention(
        q, k, v,
        q_positions=jnp.zeros((q.shape[1],), jnp.int32),
        kv_positions=kv_pos, causal=False,
        attn_softcap=0.0,
    )
    return A.attend(p, out)


# ---------------------------------------------------------------------------
# single-layer application (full sequence)
# ---------------------------------------------------------------------------


def _apply_layer_full(kind, p, x, cfg, positions, memory, ctx, cache=None):
    """Returns (x, aux, new_cache)."""
    window = _attn_window(cfg, kind)
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)

    if kind == "mlstm":
        out, new_state = X.mlstm_apply(p["cell"], h, cfg, cache)
        return x + out, aux, new_state
    if kind == "slstm":
        out, new_state = X.slstm_apply(p["cell"], h, cfg, cache)
        return x + out, aux, new_state

    if kind == "hybrid":
        kv_cache = cache[0] if cache is not None else None
        attn_out, new_kv = _self_attention_full(p["attn"], h, cfg, positions, window, kv_cache, ctx)
        ssm_out, new_ssm = S.ssm_apply(p["ssm"], h, cfg, cache[1] if cache is not None else None)
        fused = 0.5 * (
            rmsnorm(p["norm_attn_out"], attn_out, cfg.norm_eps)
            + rmsnorm(p["norm_ssm_out"], ssm_out, cfg.norm_eps)
        )
        x = x + fused
        new_cache = (new_kv, new_ssm) if cache is not None else None
    elif kind == "dec_cross_mlp":
        attn_out, new_self = _self_attention_full(p["attn"], h, cfg, positions, window, cache[0] if cache is not None else None, ctx)
        x = x + attn_out
        hc = rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        cross_cache = cache[1] if cache is not None else None
        x = x + _cross_attention(p["cross"], hc, memory, cfg, None)
        if cache is not None:
            # cache cross K/V once (memory is static through decode)
            _, ck, cv = A.project_qkv(p["cross"], hc, memory)
            new_cross = A.KVCache(ck, cv, jnp.arange(ck.shape[1], dtype=jnp.int32), False)
            new_cache = (new_self, new_cross)
        else:
            new_cache = None
    elif kind.startswith("cross_attn"):
        gate = jnp.tanh(p["cross_gate"].astype(jnp.float32))[0]
        x = x + gate.astype(x.dtype) * _cross_attention(p["cross"], h, memory, cfg)
        if cache is not None:
            _, ck, cv = A.project_qkv(p["cross"], h, memory)
            new_cache = A.KVCache(ck, cv, jnp.arange(ck.shape[1], dtype=jnp.int32), False)
        else:
            new_cache = None
    else:  # attn_*
        attn_out, new_cache = _self_attention_full(p["attn"], h, cfg, positions, window, cache, ctx)
        x = x + attn_out

    x = _constrain_bsd(x, ctx)
    if "moe" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        moe_out, aux = M.moe_apply(p["moe"], h2, cfg, ctx)
        x = x + moe_out
    elif "mlp" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h2, cfg.mlp_activation)
    return _constrain_bsd(x, ctx), aux, new_cache


def _apply_layer_decode(kind, p, x, cfg, pos, ctx, cache):
    """One-token step. Returns (x, new_cache)."""
    window = _attn_window(cfg, kind)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)

    if kind == "mlstm":
        out, new_state = X.mlstm_decode_step(p["cell"], h, cfg, cache)
        return x + out, new_state
    if kind == "slstm":
        out, new_state = X.slstm_decode_step(p["cell"], h, cfg, cache)
        return x + out, new_state

    if kind == "hybrid":
        attn_out, new_kv = _self_attention_decode(p["attn"], h, cfg, pos, window, cache[0], ctx)
        ssm_out, new_ssm = S.ssm_decode_step(p["ssm"], h, cfg, cache[1])
        fused = 0.5 * (
            rmsnorm(p["norm_attn_out"], attn_out, cfg.norm_eps)
            + rmsnorm(p["norm_ssm_out"], ssm_out, cfg.norm_eps)
        )
        x = x + fused
        new_cache = (new_kv, new_ssm)
    elif kind == "dec_cross_mlp":
        attn_out, new_self = _self_attention_decode(p["attn"], h, cfg, pos, window, cache[0], ctx)
        x = x + attn_out
        hc = rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        x = x + _cross_attention(p["cross"], hc, None, cfg, cross_cache=cache[1])
        new_cache = (new_self, cache[1])
    elif kind.startswith("cross_attn"):
        gate = jnp.tanh(p["cross_gate"].astype(jnp.float32))[0]
        x = x + gate.astype(x.dtype) * _cross_attention(p["cross"], h, None, cfg, cross_cache=cache)
        new_cache = cache
    else:
        attn_out, new_cache = _self_attention_decode(p["attn"], h, cfg, pos, window, cache, ctx)
        x = x + attn_out

    if "moe" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        moe_out, _ = M.moe_apply(p["moe"], h2, cfg, ctx)
        x = x + moe_out
    elif "mlp" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h2, cfg.mlp_activation)
    return x, new_cache


def _init_layer_cache(kind, cfg, batch, max_seq, dtype=COMPUTE_DTYPE):
    window = _attn_window(cfg, kind)
    kv = lambda w: A.init_kv_cache(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, window=w, dtype=dtype)
    if kind == "mlstm":
        return X.init_mlstm_state(batch, cfg, dtype)
    if kind == "slstm":
        return X.init_slstm_state(batch, cfg, dtype)
    if kind == "hybrid":
        return (kv(window), S.init_ssm_state(batch, cfg, dtype))
    if kind == "dec_cross_mlp":
        mem = cfg.n_frontend_tokens or max_seq
        cross = A.KVCache(
            jnp.zeros((batch, mem, cfg.n_kv_heads, cfg.head_dim), dtype),
            jnp.zeros((batch, mem, cfg.n_kv_heads, cfg.head_dim), dtype),
            jnp.arange(mem, dtype=jnp.int32), False,
        )
        return (kv(window), cross)
    if kind.startswith("cross_attn"):
        mem = cfg.n_frontend_tokens or max_seq
        return A.KVCache(
            jnp.zeros((batch, mem, cfg.n_kv_heads, cfg.head_dim), dtype),
            jnp.zeros((batch, mem, cfg.n_kv_heads, cfg.head_dim), dtype),
            jnp.arange(mem, dtype=jnp.int32), False,
        )
    return kv(window)


# ---------------------------------------------------------------------------
# chunked cross-entropy
# ---------------------------------------------------------------------------


def _chunked_ce(params, hidden, targets, cfg):
    """Cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks of ``ce_chunk`` positions; each chunk unembeds
    (B, c, D) -> (B, c, V) f32, softmaxes, gathers the target, and is
    checkpointed so backward recomputes the chunk instead of storing log-probs.
    Working set drops from O(S*V) to O(ce_chunk*V) per device — this is what
    keeps the train_4k cells inside 16 GB HBM at 152k-256k vocabs.
    """
    from repro.models import flags as _flags
    b, s, d = hidden.shape
    chunk = min(cfg.ce_chunk, s)
    if _flags.UNROLL_INNER:
        chunk = min(max(chunk, -(-s // 16)), s)
    pad = (-s) % chunk
    hp = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    tp = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = hp.shape[1] // chunk
    hp = hp.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    tp = tp.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(carry, inp):
        h_c, t_c = inp
        logits = softcap(unembed(params["embed"], h_c, cfg.vocab_size),
                         cfg.final_softcap)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        valid = t_c >= 0
        ce = -jnp.take_along_axis(logp, jnp.maximum(t_c, 0)[..., None], axis=-1)[..., 0]
        ce = jnp.where(valid, ce, 0.0)
        return (carry[0] + jnp.sum(ce), carry[1] + jnp.sum(valid)), None

    (total, count), _ = scan_inner(
        chunk_loss, (jnp.zeros(()), jnp.zeros(())), (hp, tp)
    )
    return total / jnp.maximum(count, 1.0)


# ---------------------------------------------------------------------------
# the LM
# ---------------------------------------------------------------------------


def _stack_spec(spec_tree, n: int):
    """Add a leading stacked 'layers' axis to every ParamSpec."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical_axes, s.init, s.scale),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _scan_groups(body, carry, xs, n: int, *, scan: bool):
    """lax.scan over the group stack, or an unrolled python loop.

    The unrolled path exists for the dry-run's cost sampling: XLA's
    cost_analysis visits a while-loop body ONCE regardless of trip count, so
    depth-cost sampling needs straight-line HLO (launch/dryrun.py).
    """
    if scan:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for g in range(n):
        x_g = jax.tree_util.tree_map(lambda leaf: leaf[g], xs)
        carry, y = body(carry, x_g)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *ys)
    else:
        ys = None
    return carry, ys


class LM:
    """A language model (decoder-only, enc-dec, vlm, ssm, hybrid, moe)."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.pattern = cfg.layer_pattern()
        self.n_groups = cfg.n_groups()

    # -- parameters ---------------------------------------------------------
    def spec(self) -> dict:
        cfg = self.cfg
        group = {
            f"l{i}_{kind}": _layer_spec(cfg, kind)
            for i, kind in enumerate(self.pattern)
        }
        out = {
            "embed": embedding_spec(cfg.vocab_size, cfg.d_model, cfg.tie_embeddings),
            "layers": _stack_spec(group, self.n_groups),
            "final_norm": rmsnorm_spec(cfg.d_model),
        }
        if cfg.n_encoder_layers:
            enc_layer = {
                "norm1": rmsnorm_spec(cfg.d_model),
                "attn": A.attention_spec(cfg),
                "norm2": rmsnorm_spec(cfg.d_model),
                "mlp": mlp_spec(cfg.d_model, cfg.d_ff, cfg.mlp_activation),
            }
            out["encoder"] = _stack_spec(enc_layer, cfg.n_encoder_layers)
            out["encoder_norm"] = rmsnorm_spec(cfg.d_model)
        return out

    def init(self, key, dtype=jnp.float32):
        from repro.models.sharding import init_params

        return init_params(key, self.spec(), dtype)

    # -- encoder (enc-dec only) ---------------------------------------------
    def encode(self, params, frames: jnp.ndarray, ctx: Optional[MeshCtx] = None):
        """frames: (B, S_enc, D) precomputed frontend embeddings (stub)."""
        cfg = self.cfg
        x = frames.astype(COMPUTE_DTYPE)
        positions = jnp.arange(x.shape[1])

        def body(x, p):
            h = rmsnorm(p["norm1"], x, cfg.norm_eps)
            q, k, v = A.project_qkv(p["attn"], h, h, q_positions=positions,
                                    kv_positions=positions, rope_theta=cfg.rope_theta)
            out = A.flash_attention(q, k, v, q_positions=positions,
                                    kv_positions=positions, causal=False)
            x = x + A.attend(p["attn"], out)
            h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
            x = x + mlp(p["mlp"], h2, cfg.mlp_activation)
            return _constrain_stream(x, ctx), None

        if cfg.remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = _scan_groups(body, x, params["encoder"],
                            cfg.n_encoder_layers, scan=cfg.scan_layers)
        return rmsnorm(params["encoder_norm"], x, cfg.norm_eps)

    # -- full-sequence forward (train) --------------------------------------
    def forward(self, params, tokens, *, memory=None, ctx: Optional[MeshCtx] = None,
                return_hidden: bool = False):
        """tokens (B,S) -> logits (B,S,V) f32; returns (logits, aux_loss).

        ``return_hidden`` skips the unembed and returns the final hidden
        states instead — the chunked-CE loss owns the unembed then (the
        (B,S,V) f32 logits tensor never materializes; see ``_chunked_ce``)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens)
        x = _constrain_stream(x, ctx)
        positions = jnp.arange(tokens.shape[1])

        def body(carry, p_group):
            x, aux = carry
            # pin the SAVED residual to the seq-sharded form (the constraint
            # on the raw input is what the remat residual buffer inherits),
            # THEN gather for the interior compute
            x = _constrain_stream(x, ctx)
            x = _constrain_bsd(x, ctx)
            for i, kind in enumerate(self.pattern):
                x, a, _ = _apply_layer_full(
                    kind, p_group[f"l{i}_{kind}"], x, cfg, positions, memory, ctx
                )
                aux = aux + a
            return (_constrain_stream(x, ctx), aux), None

        if cfg.remat != "none":
            # prevent_cse=False: safe under scan and avoids the duplicated
            # carry copy the CSE barrier otherwise forces (measured 2 GiB x
            # n_groups on qwen1.5 train_4k; §Perf B1)
            body = jax.checkpoint(
                body,
                prevent_cse=False,
                policy=None if cfg.remat == "full"
                else jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        (x, aux), _ = _scan_groups(
            body, (x, jnp.zeros((), jnp.float32)), params["layers"],
            self.n_groups, scan=cfg.scan_layers,
        )
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if return_hidden:
            return x, aux / max(cfg.n_layers, 1)
        logits = unembed(params["embed"], x, cfg.vocab_size)[..., : cfg.vocab_size]
        logits = softcap(logits, cfg.final_softcap)
        return logits, aux / max(cfg.n_layers, 1)

    # -- prefill -------------------------------------------------------------
    def init_caches(self, batch: int, max_seq: int, dtype=COMPUTE_DTYPE):
        """Stacked caches: each leaf has leading n_groups axis."""
        per_group = {
            f"l{i}_{kind}": _init_layer_cache(kind, self.cfg, batch, max_seq, dtype)
            for i, kind in enumerate(self.pattern)
        }
        return jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(leaf[None], (self.n_groups,) + leaf.shape).copy()
            if hasattr(leaf, "shape") else leaf,
            per_group,
        )

    def prefill(self, params, tokens, *, memory=None, ctx: Optional[MeshCtx] = None,
                max_seq: Optional[int] = None, last_only: bool = False):
        """Returns (logits, caches) with caches filled through S.

        ``last_only`` unembeds only the final position — (B,1,V) — which is
        what serving needs and avoids the (B,S,V) logits tensor at 32k."""
        cfg = self.cfg
        b, s = tokens.shape
        max_seq = max_seq or s
        x = embed(params["embed"], tokens)
        x = _constrain_stream(x, ctx)
        positions = jnp.arange(s)
        caches0 = self.init_caches(b, max_seq)

        def body(x, scanned):
            p_group, cache_group = scanned
            x = _constrain_stream(x, ctx)
            x = _constrain_bsd(x, ctx)
            new_caches = {}
            for i, kind in enumerate(self.pattern):
                key = f"l{i}_{kind}"
                x, _, new_cache = _apply_layer_full(
                    kind, p_group[key], x, cfg, positions, memory, ctx,
                    cache=cache_group[key],
                )
                new_caches[key] = new_cache
            return _constrain_stream(x, ctx), new_caches

        if cfg.remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        x, caches = _scan_groups(body, x, (params["layers"], caches0),
                                 self.n_groups, scan=cfg.scan_layers)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if last_only:
            x = x[:, -1:]
        logits = softcap(
            unembed(params["embed"], x, cfg.vocab_size)[..., : cfg.vocab_size],
            cfg.final_softcap)
        return logits, caches

    # -- decode --------------------------------------------------------------
    def decode_step(self, params, caches, token, pos, *, ctx: Optional[MeshCtx] = None):
        """token (B,1) int32, pos scalar int32 -> (logits (B,1,V), caches')."""
        cfg = self.cfg
        x = embed(params["embed"], token)

        def body(x, scanned):
            p_group, cache_group = scanned
            new_caches = {}
            for i, kind in enumerate(self.pattern):
                key = f"l{i}_{kind}"
                x, new_caches[key] = _apply_layer_decode(
                    kind, p_group[key], x, cfg, pos, ctx, cache_group[key]
                )
            return x, new_caches

        x, new_caches = _scan_groups(body, x, (params["layers"], caches),
                                     self.n_groups, scan=cfg.scan_layers)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = softcap(
            unembed(params["embed"], x, cfg.vocab_size)[..., : cfg.vocab_size],
            cfg.final_softcap)
        return logits, new_caches

    # -- loss ----------------------------------------------------------------
    def loss(self, params, batch, *, ctx: Optional[MeshCtx] = None):
        """batch: {tokens, targets[, frontend]} -> (loss, metrics)."""
        cfg = self.cfg
        memory = None
        if cfg.n_encoder_layers:
            memory = self.encode(params, batch["frontend"], ctx)
        elif cfg.frontend != "none":
            memory = batch["frontend"].astype(COMPUTE_DTYPE)
        hidden, aux = self.forward(
            params, batch["tokens"], memory=memory, ctx=ctx, return_hidden=True
        )
        loss = _chunked_ce(params, hidden, batch["targets"], cfg)
        total = loss + 0.01 * aux
        return total, {"ce": loss, "aux": aux}
