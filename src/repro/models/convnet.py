"""Paper-era convnet (ResNet-32/CIFAR class) for the faithful convergence
experiments (paper Fig. 11/12 trained AlexNet/VGG16/ResNet32 — the gradient
compressor is architecture-agnostic, so the paper's own model family is
reproduced with a compact residual CNN on synthetic 32x32 images).

Pure-JAX: lax.conv + batch-stat-free norm (groupnorm-ish) + residual blocks.
Used by benchmarks/convergence.py and tests; trains on CPU.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.sharding import ParamSpec

__all__ = ["ConvConfig", "ConvNet"]


@dataclasses.dataclass(frozen=True)
class ConvConfig:
    n_classes: int = 10
    widths: Tuple[int, ...] = (16, 32, 64)
    blocks_per_stage: int = 2  # resnet-32 analog: deeper if desired
    img_size: int = 32


def _conv_spec(cin, cout, k=3):
    return ParamSpec((k, k, cin, cout), (None, None, None, "ff"),
                     scale=(2.0 / (k * k * cin)) ** 0.5)


def _conv(params, x, stride=1):
    return jax.lax.conv_general_dilated(
        x, params, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _norm(x, eps=1e-5):
    mu = jnp.mean(x, axis=(1, 2), keepdims=True)
    var = jnp.var(x, axis=(1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


class ConvNet:
    def __init__(self, cfg: ConvConfig = ConvConfig()):
        self.cfg = cfg

    def spec(self):
        cfg = self.cfg
        spec = {"stem": _conv_spec(3, cfg.widths[0])}
        cin = cfg.widths[0]
        for s, w in enumerate(cfg.widths):
            for b in range(cfg.blocks_per_stage):
                spec[f"s{s}b{b}_c1"] = _conv_spec(cin if b == 0 else w, w)
                spec[f"s{s}b{b}_c2"] = _conv_spec(w, w)
                if b == 0 and cin != w:
                    spec[f"s{s}b{b}_proj"] = _conv_spec(cin, w, k=1)
            cin = w
        spec["head"] = ParamSpec((cfg.widths[-1], cfg.n_classes), ("embed", None))
        return spec

    def init(self, key, dtype=jnp.float32):
        from repro.models.sharding import init_params

        return init_params(key, self.spec(), dtype)

    def forward(self, params, images):
        cfg = self.cfg
        x = _conv(params["stem"], images)
        for s, w in enumerate(cfg.widths):
            for b in range(cfg.blocks_per_stage):
                stride = 2 if (b == 0 and s > 0) else 1
                h = jax.nn.relu(_norm(_conv(params[f"s{s}b{b}_c1"], x, stride)))
                h = _norm(_conv(params[f"s{s}b{b}_c2"], h))
                skip = x
                if f"s{s}b{b}_proj" in params:
                    skip = _conv(params[f"s{s}b{b}_proj"], x, stride)
                elif stride != 1:
                    skip = x[:, ::2, ::2]
                x = jax.nn.relu(h + skip)
        x = jnp.mean(x, axis=(1, 2))
        return x @ params["head"]

    def loss(self, params, batch, *, ctx=None):
        # ctx accepted for train-step compatibility (LM threads a MeshCtx for
        # sharding constraints); the convnet is pure data-parallel so the
        # constraint-free forward is already correct under shard_map
        logits = self.forward(params, batch["images"])
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)
        return jnp.mean(ce), {"acc": jnp.mean(
            (jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))}


def synthetic_image_batch(key, cfg: ConvConfig, batch: int):
    """Learnable synthetic task: class-conditional gaussian blobs + noise."""
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.randint(k1, (batch,), 0, cfg.n_classes)
    protos = jax.random.normal(
        jax.random.PRNGKey(7), (cfg.n_classes, cfg.img_size, cfg.img_size, 3))
    images = protos[labels] + 0.5 * jax.random.normal(
        k2, (batch, cfg.img_size, cfg.img_size, 3))
    return {"images": images, "labels": labels}
