"""Attention: GQA + RoPE + flash-style chunked softmax + KV caches.

Memory discipline: scores are never materialized beyond a
``(batch, kv_heads, q_groups, q_chunk, kv_chunk)`` tile — an online-softmax
scan over KV chunks (optionally nested in a scan over Q chunks) bounds the
working set for 32k prefill exactly like a flash kernel would on TPU.  The
per-tile compute is a well-shaped MXU einsum; XLA fuses the rescaling.

Features demanded by the assigned archs:
* GQA with any (n_heads, n_kv_heads) — kv heads are kept distinct and q heads
  grouped, so TP sharding binds to kv_heads when divisible;
* sliding-window masks (mixtral, gemma2 local layers) with **ring-buffer
  caches**: a local layer's cache is O(window), which is what makes the
  long_500k decode cell affordable for gemma2/mixtral;
* attention-logit softcap (gemma2);
* cross-attention (seamless decoder, llama-vision) — no causal mask, no rope
  on memory.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, rope, softcap
from repro.models.flags import scan_inner
from repro.models.sharding import ParamSpec

__all__ = [
    "attention_spec",
    "project_qkv",
    "flash_attention",
    "attend",
    "init_kv_cache",
    "update_kv_cache",
    "KVCache",
]

_NEG_INF = -1e30


def attention_spec(cfg, cross: bool = False) -> dict:
    d = cfg.d_model
    spec = {
        "wq": ParamSpec((d, cfg.n_heads, cfg.head_dim), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, cfg.n_kv_heads, cfg.head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, cfg.n_kv_heads, cfg.head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((cfg.n_heads, cfg.head_dim, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        spec["bq"] = ParamSpec((cfg.n_heads, cfg.head_dim), ("heads", "head_dim"), init="zeros")
        spec["bk"] = ParamSpec((cfg.n_kv_heads, cfg.head_dim), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = ParamSpec((cfg.n_kv_heads, cfg.head_dim), ("kv_heads", "head_dim"), init="zeros")
    return spec


def project_qkv(params, x_q, x_kv, q_positions=None, kv_positions=None, rope_theta=1e4):
    """x -> q (B,Sq,Kh,G,Dh), k/v (B,Skv,Kh,Dh); rope applied when positions given."""
    dt = x_q.dtype
    q = jnp.einsum("bsd,dhk->bshk", x_q, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x_kv, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x_kv, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if q_positions is not None:
        q = rope(q, q_positions, rope_theta)
    if kv_positions is not None:
        k = rope(k, kv_positions, rope_theta)
    b, sq, h, dh = q.shape
    kh = k.shape[2]
    q = q.reshape(b, sq, kh, h // kh, dh)
    return q, k, v


def _tile_scores(q_tile, k_tile, scale, cap):
    # q: (B, Qc, Kh, G, Dh), k: (B, Kc, Kh, Dh) -> (B, Kh, G, Qc, Kc)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q_tile, k_tile).astype(jnp.float32) * scale
    return softcap(s, cap)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    q_positions: jnp.ndarray,  # (Sq,) absolute positions of queries
    kv_positions: jnp.ndarray,  # (Skv,) absolute positions of keys (-1 invalid)
    causal: bool = True,
    window: int = 0,
    attn_softcap: float = 0.0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention over KV chunks (flash pattern, pure JAX).

    Returns (B, Sq, Kh, G, Dh) in q.dtype.
    """
    b, sq, kh, g, dh = q.shape
    skv = k.shape[1]
    scale = 1.0 / (dh**0.5)

    from repro.models import flags as _flags
    if _flags.UNROLL_INNER:
        # cost-sample mode: bound the unrolled tile count (total tile bytes
        # and FLOPs are tiling-invariant, so this is cost-exact)
        q_chunk = max(q_chunk, -(-sq // 8))
        kv_chunk = max(kv_chunk, -(-skv // 4))
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # pad sequence dims to chunk multiples
    q_pad = (-sq) % q_chunk
    kv_pad = (-skv) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, (0, q_pad), constant_values=-1)
    kpos = jnp.pad(kv_positions, (0, kv_pad), constant_values=-1)

    n_q = qp.shape[1] // q_chunk
    n_kv = kp.shape[1] // kv_chunk
    qp = qp.reshape(b, n_q, q_chunk, kh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    kp = kp.reshape(b, n_kv, kv_chunk, kh, dh).transpose(1, 0, 2, 3, 4)
    vp = vp.reshape(b, n_kv, kv_chunk, kh, dh).transpose(1, 0, 2, 3, 4)
    qpos = qpos.reshape(n_q, q_chunk)
    kpos = kpos.reshape(n_kv, kv_chunk)

    def q_block(carry, q_in):
        q_tile, qpos_tile = q_in  # (B,Qc,Kh,G,Dh), (Qc,)

        def kv_block(state, kv_in):
            m, l, acc = state
            k_tile, v_tile, kpos_tile = kv_in
            s = _tile_scores(q_tile, k_tile, scale, attn_softcap)  # (B,Kh,G,Qc,Kc)
            valid = kpos_tile[None, :] >= 0
            if causal:
                valid = valid & (qpos_tile[:, None] >= kpos_tile[None, :])
            if window:
                valid = valid & (qpos_tile[:, None] - kpos_tile[None, :] < window)
            s = jnp.where(valid[None, None, None, :, :], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_tile.dtype), v_tile)
            acc_new = acc * alpha[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, q_chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_chunk), jnp.float32)
        acc0 = jnp.zeros((b, kh, g, q_chunk, dh), jnp.float32)
        # checkpoint the tile body: backward recomputes the (Qc, Kc) score
        # tile instead of storing it per step — this is what bounds the
        # working set at 32k prefill (flash-attention memory discipline)
        (m, l, acc), _ = scan_inner(
            jax.checkpoint(kv_block), (m0, l0, acc0), (kp, vp, kpos)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B,Kh,G,Qc,Dh) -> (B,Qc,Kh,G,Dh)
        return carry, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    _, out = scan_inner(jax.checkpoint(q_block), None, (qp, qpos))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, n_q * q_chunk, kh, g, dh)
    return out[:, :sq]


def attend(params, attn_out: jnp.ndarray) -> jnp.ndarray:
    """(B,S,Kh,G,Dh) -> output projection -> (B,S,D)."""
    b, s, kh, g, dh = attn_out.shape
    merged = attn_out.reshape(b, s, kh * g, dh)
    return jnp.einsum("bshk,hkd->bsd", merged, params["wo"].astype(attn_out.dtype))


# ---------------------------------------------------------------------------
# KV cache (flat or ring-buffer for windowed layers)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVCache:
    k: jnp.ndarray  # (B, S_cache, Kh, Dh)
    v: jnp.ndarray
    pos: jnp.ndarray  # (S_cache,) absolute position per slot, -1 = empty
    ring: bool = dataclasses.field(metadata={"static": True})

    def tree_flatten(self):
        return (self.k, self.v, self.pos), (self.ring,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)


def init_kv_cache(batch: int, seq: int, kv_heads: int, head_dim: int, *,
                  window: int = 0, dtype=COMPUTE_DTYPE) -> KVCache:
    size = min(window, seq) if window else seq
    return KVCache(
        k=jnp.zeros((batch, size, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, size, kv_heads, head_dim), dtype),
        pos=jnp.full((size,), -1, jnp.int32),
        ring=bool(window and window < seq),
    )


def update_kv_cache(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                    start: jnp.ndarray) -> KVCache:
    """Write S_new entries at absolute positions start..start+S_new-1."""
    s_new = k_new.shape[1]
    size = cache.k.shape[1]
    if cache.ring and s_new > size:
        # only the last `size` entries can survive in a ring buffer; writing
        # duplicates into the same slot would be order-undefined under XLA
        k_new = k_new[:, -size:]
        v_new = v_new[:, -size:]
        start = start + (s_new - size)
        s_new = size
    positions = start + jnp.arange(s_new)
    if cache.ring:
        slots = positions % size
        k = cache.k.at[:, slots].set(k_new)
        v = cache.v.at[:, slots].set(v_new)
        pos = cache.pos.at[slots].set(positions)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, start, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, start, axis=1)
        pos = jax.lax.dynamic_update_slice_in_dim(
            cache.pos, positions.astype(jnp.int32), start, axis=0
        )
    return KVCache(k, v, pos, cache.ring)
