"""Architecture registry: name -> ArchConfig -> LM, plus input specs.

``input_specs`` produces ShapeDtypeStruct stand-ins for every model input of a
given (arch, shape) cell — weak-type-correct, shardable, zero allocation —
which is what the multi-pod dry-run lowers against.  ``make_batch`` produces
small concrete batches for CPU smoke tests.

Modality frontends are STUBS per the assignment: ``[audio]``/``[vlm]`` entries
receive precomputed frame/patch embeddings of shape (B, n_frontend, d_model).
"""

from __future__ import annotations

import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.transformer import LM

__all__ = ["ARCH_NAMES", "get_config", "build", "input_specs", "make_batch",
           "cell_is_supported"]

ARCH_NAMES = [
    "seamless_m4t_large_v2",
    "internlm2_20b",
    "qwen1_5_110b",
    "gemma2_2b",
    "phi3_medium_14b",
    "hymba_1_5b",
    "llama3_2_vision_11b",
    "xlstm_1_3b",
    "mixtral_8x22b",
    "qwen3_moe_235b_a22b",
]

# archs with sub-quadratic / bounded-window sequence mixing run long_500k
LONG_CONTEXT_OK = {"xlstm_1_3b", "hymba_1_5b", "gemma2_2b", "mixtral_8x22b"}


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def build(cfg_or_name) -> LM:
    cfg = get_config(cfg_or_name) if isinstance(cfg_or_name, str) else cfg_or_name
    return LM(cfg)


def cell_is_supported(name: str, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason (DESIGN.md §7)."""
    if shape.name == "long_500k" and name not in LONG_CONTEXT_OK:
        return "pure full-attention arch: 500k dense-KV decode out of scope"
    return None


def _frontend_len(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.frontend == "audio_frames":
        return seq_len  # encoder frames track the assigned sequence length
    if cfg.frontend == "vision_patches":
        return cfg.n_frontend_tokens or 1601
    return 0


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct tree for the (train|prefill|decode) step inputs."""
    b = shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
        fl = _frontend_len(cfg, s)
        if fl:
            specs["frontend"] = jax.ShapeDtypeStruct((b, fl, cfg.d_model), jnp.float32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        fl = _frontend_len(cfg, s)
        if fl:
            specs["frontend"] = jax.ShapeDtypeStruct((b, fl, cfg.d_model), jnp.float32)
        return specs
    # decode: one new token against caches of length seq_len
    model = LM(cfg)
    caches = jax.eval_shape(lambda: model.init_caches(b, s))
    specs = {
        "caches": caches,
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
    return specs


def make_batch(key, cfg: ArchConfig, batch: int, seq: int) -> Dict:
    """Concrete random batch (smoke tests / examples)."""
    k1, k2, k3 = jax.random.split(key, 3)
    out = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32),
        "targets": jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size, jnp.int32),
    }
    fl = _frontend_len(cfg, seq)
    if fl:
        out["frontend"] = jax.random.normal(k3, (batch, fl, cfg.d_model)) * 0.02
    return out
