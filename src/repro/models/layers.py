"""Shared neural layers: norms, MLPs, embeddings, RoPE, softcap.

Convention: params are nested dicts of arrays; every function takes the param
subtree as its first argument.  Activations flow in ``compute_dtype``
(bf16 by default), params are stored f32 and cast at use (mixed precision);
reductions (norms, softmax, loss) run in f32.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.sharding import ParamSpec

__all__ = [
    "COMPUTE_DTYPE",
    "rmsnorm_spec",
    "rmsnorm",
    "mlp_spec",
    "mlp",
    "embedding_spec",
    "embed",
    "unembed",
    "rope",
    "softcap",
]

COMPUTE_DTYPE = jnp.bfloat16


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """gemma2-style logit soft capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / ReLU)
# ---------------------------------------------------------------------------


def mlp_spec(d: int, f: int, activation: str = "swiglu") -> dict:
    spec = {
        "up": ParamSpec((d, f), ("embed", "ff")),
        "down": ParamSpec((f, d), ("ff", "embed")),
    }
    if activation in ("swiglu", "geglu"):
        spec["gate"] = ParamSpec((d, f), ("embed", "ff"))
    return spec


def mlp(params: dict, x: jnp.ndarray, activation: str = "swiglu") -> jnp.ndarray:
    dt = x.dtype
    up = x @ params["up"].astype(dt)
    if activation == "swiglu":
        gate = x @ params["gate"].astype(dt)
        h = jax.nn.silu(gate) * up
    elif activation == "geglu":
        gate = x @ params["gate"].astype(dt)
        h = jax.nn.gelu(gate, approximate=True) * up
    elif activation == "relu":
        h = jax.nn.relu(up)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return h @ params["down"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def padded_vocab(vocab: int, multiple: int = 128) -> int:
    """Megatron-style vocab padding so the vocab axis shards over the model
    axis (seamless 256206 and hymba 32001 are otherwise indivisible)."""
    return ((vocab + multiple - 1) // multiple) * multiple


def embedding_spec(vocab: int, d: int, tie: bool) -> dict:
    vp = padded_vocab(vocab)
    spec = {"table": ParamSpec((vp, d), ("vocab", "embed"))}
    if not tie:
        spec["head"] = ParamSpec((d, vp), ("embed", "vocab"))
    return spec


def embed(params: dict, tokens: jnp.ndarray, dtype=COMPUTE_DTYPE) -> jnp.ndarray:
    return params["table"].astype(dtype)[tokens]


def unembed(params: dict, x: jnp.ndarray, vocab: int = 0) -> jnp.ndarray:
    """Returns f32 logits over the PADDED vocab; pad columns are masked to
    -1e30 when the true ``vocab`` size is given (softmax then ignores them)."""
    if "head" in params:
        logits = (x @ params["head"].astype(x.dtype)).astype(jnp.float32)
    else:
        logits = (x @ params["table"].astype(x.dtype).T).astype(jnp.float32)
    vp = logits.shape[-1]
    if vocab and vocab < vp:
        mask = (jnp.arange(vp) < vocab)
        logits = jnp.where(mask, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = jnp.exp(
        -jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(theta) / half)
    )  # (half,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)
