"""Selective state-space (Mamba-style) mixer — the SSM half of hymba's blocks.

Train/prefill path: **chunked associative scan** — the (B, S, d_inner, state)
expanded tensor is never materialized beyond one sequence chunk
(``seq_chunk``); chunks are walked by ``lax.scan`` carrying the (B, d_inner,
state) hidden state, and within a chunk the recurrence

    h_t = exp(delta_t * A) h_{t-1} + delta_t * B_t * x_t

is a first-order linear scan solved with ``lax.associative_scan``.  Decode
path: single-step recurrence with (conv_state, ssm_state) carried in the
cache.

The causal depthwise conv preceding the SSM is a ``lax.conv_general_dilated``
with left padding; its (width-1)-deep tail is the conv cache at decode.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.flags import scan_inner
from repro.models.sharding import ParamSpec

__all__ = ["ssm_spec", "ssm_apply", "ssm_decode_step", "init_ssm_state", "SSMState"]

_DT_RANK = 16


def ssm_spec(cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    st = cfg.ssm_state
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((cfg.ssm_conv_width, di), ("conv", "ssm_inner")),
        "conv_b": ParamSpec((di,), ("ssm_inner",), init="zeros"),
        "x_proj": ParamSpec((di, _DT_RANK + 2 * st), ("ssm_inner", None)),
        "dt_proj": ParamSpec((_DT_RANK, di), (None, "ssm_inner")),
        "dt_bias": ParamSpec((di,), ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((di, st), ("ssm_inner", "state"), init="zeros"),
        "d_skip": ParamSpec((di,), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SSMState:
    conv: jnp.ndarray  # (B, conv_width-1, d_inner)
    h: jnp.ndarray  # (B, d_inner, state) f32

    def tree_flatten(self):
        return (self.conv, self.h), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def init_ssm_state(batch: int, cfg, dtype=jnp.bfloat16) -> SSMState:
    di = cfg.ssm_expand * cfg.d_model
    return SSMState(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, di), dtype),
        h=jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    )


def _causal_conv(params, x, prefix=None):
    """Depthwise causal conv along seq: x (B, S, di) -> (B, S, di)."""
    w = params["conv_w"].astype(x.dtype)  # (width, di)
    width = w.shape[0]
    if prefix is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    out = jax.lax.conv_general_dilated(
        xp,
        w[:, None, :],  # (width, 1, di)
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1],
    )
    return out + params["conv_b"].astype(x.dtype)


def _ssm_inner(params, xc, h0, cfg):
    """Run the selective scan on conv'd activations xc (B, S, di).

    Returns (y (B, S, di), h_final (B, di, state) f32)."""
    st = cfg.ssm_state
    proj = xc @ params["x_proj"].astype(xc.dtype)  # (B,S,dt_rank+2st)
    dt_in, b_t, c_t = jnp.split(proj, [_DT_RANK, _DT_RANK + st], axis=-1)
    delta = jax.nn.softplus(
        dt_in.astype(jnp.float32) @ params["dt_proj"].astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )  # (B,S,di) f32
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (di, st)

    from repro.models import flags as _flags
    seq_chunk = min(64, xc.shape[1])
    if _flags.UNROLL_INNER:
        seq_chunk = min(max(64, -(-xc.shape[1] // 8)), xc.shape[1])
    bsz, s, di = xc.shape
    pad = (-s) % seq_chunk
    xf = jnp.pad(xc.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    deltaf = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
    bf = jnp.pad(b_t.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    cf = jnp.pad(c_t.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    n_chunks = xf.shape[1] // seq_chunk

    def chunk_fn(h, inp):
        xck, dk, bk, ck = inp  # (B, L, ...) for this chunk
        da = jnp.exp(dk[..., None] * a)  # (B, L, di, st)
        dbx = dk[..., None] * bk[:, :, None, :] * xck[..., None]  # (B,L,di,st)
        # prepend carry as step 0 with decay 1
        da_all = jnp.concatenate([jnp.ones_like(da[:, :1]), da], axis=1)
        dbx_all = jnp.concatenate([h[:, None], dbx], axis=1)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2

        _, hs = jax.lax.associative_scan(combine, (da_all, dbx_all), axis=1)
        hs = hs[:, 1:]  # (B, L, di, st)
        yk = jnp.sum(hs * ck[:, :, None, :], axis=-1)  # (B, L, di)
        return hs[:, -1], yk

    xck = xf.reshape(bsz, n_chunks, seq_chunk, di).transpose(1, 0, 2, 3)
    dk = deltaf.reshape(bsz, n_chunks, seq_chunk, di).transpose(1, 0, 2, 3)
    bk = bf.reshape(bsz, n_chunks, seq_chunk, st).transpose(1, 0, 2, 3)
    ck = cf.reshape(bsz, n_chunks, seq_chunk, st).transpose(1, 0, 2, 3)
    h_final, ys = scan_inner(chunk_fn, h0, (xck, dk, bk, ck))
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, n_chunks * seq_chunk, di)[:, :s]
    y = y + xc.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    return y.astype(xc.dtype), h_final


def ssm_apply(params, x: jnp.ndarray, cfg, state: SSMState = None):
    """Full-sequence mixer: x (B, S, D) -> (y (B, S, D), final SSMState)."""
    dt = x.dtype
    xz = x @ params["in_proj"].astype(dt)
    xs, z = jnp.split(xz, 2, axis=-1)
    prefix = state.conv if state is not None else None
    xc = jax.nn.silu(_causal_conv(params, xs, prefix))
    h0 = (
        state.h
        if state is not None
        else jnp.zeros((x.shape[0], xs.shape[-1], cfg.ssm_state), jnp.float32)
    )
    y, h_final = _ssm_inner(params, xc, h0, cfg)
    out = (y * jax.nn.silu(z)) @ params["out_proj"].astype(dt)
    width = cfg.ssm_conv_width
    # carry the last (width-1) of [prefix ++ xs]: robust to S < width-1
    hist = xs if prefix is None else jnp.concatenate([prefix.astype(xs.dtype), xs], axis=1)
    new_state = SSMState(conv=hist[:, hist.shape[1] - (width - 1):].astype(jnp.bfloat16), h=h_final)
    return out, new_state


def ssm_decode_step(params, x: jnp.ndarray, cfg, state: SSMState):
    """One-token step: x (B, 1, D) -> (y (B, 1, D), state')."""
    dt = x.dtype
    xz = x @ params["in_proj"].astype(dt)
    xs, z = jnp.split(xz, 2, axis=-1)  # (B, 1, di)
    conv_in = jnp.concatenate([state.conv.astype(dt), xs], axis=1)  # (B, w, di)
    w = params["conv_w"].astype(dt)
    xc = jax.nn.silu(
        jnp.sum(conv_in * w[None], axis=1, keepdims=True) + params["conv_b"].astype(dt)
    )  # (B, 1, di)
    st = cfg.ssm_state
    proj = xc @ params["x_proj"].astype(dt)
    dt_in, b_t, c_t = jnp.split(proj, [_DT_RANK, _DT_RANK + st], axis=-1)
    delta = jax.nn.softplus(
        dt_in.astype(jnp.float32) @ params["dt_proj"].astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )[:, 0]  # (B, di)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(delta[..., None] * a)  # (B, di, st)
    dbx = delta[..., None] * b_t.astype(jnp.float32)[:, 0, None, :] * xc.astype(jnp.float32)[:, 0, :, None]
    h = da * state.h + dbx
    y = jnp.sum(h * c_t.astype(jnp.float32)[:, 0, None, :], axis=-1)  # (B, di)
    y = y + xc.astype(jnp.float32)[:, 0] * params["d_skip"].astype(jnp.float32)
    out = (y[:, None].astype(dt) * jax.nn.silu(z)) @ params["out_proj"].astype(dt)
    new_state = SSMState(conv=conv_in[:, 1:].astype(jnp.bfloat16), h=h)
    return out, new_state
