"""Global lowering flags (set by launch/dryrun.py in its own process).

``UNROLL_INNER`` — when True, the inner loops (flash-attention tiles, chunked
CE, MoE token groups, SSM sequence chunks) lower as straight-line HLO instead
of ``lax.scan``: XLA's cost_analysis visits a while body once regardless of
trip count, so the dry-run's depth-1/depth-2 cost samples must be scan-free to
count FLOPs/bytes/collectives correctly.  Production lowering keeps scans
(compact HLO, fast compiles).

The per-timestep mLSTM/sLSTM recurrences are exempt (unrolling 4096 steps is
not viable); the dry-run adds their analytic per-step FLOPs instead
(launch/dryrun.py::_recurrent_correction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

UNROLL_INNER = False


def scan_inner(body, carry, xs, length=None):
    """lax.scan unless UNROLL_INNER — then an unrolled python loop."""
    if not UNROLL_INNER:
        return jax.lax.scan(body, carry, xs, length=length)
    n = length
    if n is None:
        n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = None if xs is None else jax.tree_util.tree_map(lambda l: l[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *ys)
    else:
        ys = None
    return carry, ys
