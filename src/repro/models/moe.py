"""Mixture-of-Experts: GShard-style grouped one-hot dispatch (MXU-dense).

Why this formulation (DESIGN.md §4): TPU wants static shapes and matmuls.
Tokens are split into groups of ``moe_group_size`` (default 512); ALL groups
are processed by batched einsums — the group axis ``g`` is sharded over the
data axes (each device dispatches its own tokens) and the expert axis ``e``
over ``model`` (expert parallelism), so the ``gsec->egcd`` dispatch einsum is
exactly the GShard all-to-all.  Static capacity per expert per group:

    C = ceil(group_size * top_k / n_experts * capacity_factor)

with overflow dropped (capacity_factor 1.25 makes drops rare at balanced
load).  Dispatch-einsum FLOPs are counted as non-useful in the roofline's
MODEL_FLOPS/HLO_FLOPS ratio (EXPERIMENTS.md).

Returns the Switch-style load-balancing aux loss alongside the output.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.sharding import ParamSpec

__all__ = ["moe_spec", "moe_apply", "capacity"]


def moe_spec(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    spec = {
        "router": ParamSpec((d, e), ("embed", "experts"), scale=0.02 / math.sqrt(d)),
        "up": ParamSpec((e, d, f), ("experts", "embed", "ff")),
        "down": ParamSpec((e, f, d), ("experts", "ff", "embed")),
    }
    if cfg.mlp_activation in ("swiglu", "geglu"):
        spec["gate"] = ParamSpec((e, d, f), ("experts", "embed", "ff"))
    return spec


def capacity(cfg, group_size: Optional[int] = None) -> int:
    sg = group_size or cfg.moe_group_size
    c = math.ceil(sg * cfg.experts_per_token / cfg.n_experts * cfg.moe_capacity_factor)
    return max(4, c)


def _constrain(x, spec, ctx):
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def moe_apply(params, x: jnp.ndarray, cfg, ctx=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    sg = min(cfg.moe_group_size, b * s)
    cap = capacity(cfg, sg)
    dt = x.dtype
    batch_axes = ctx.batch if ctx is not None else None

    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    pad = (-t) % sg
    tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    groups = tokens.reshape(-1, sg, d)  # (G, Sg, D)
    groups = _constrain(groups, P(batch_axes, None, None), ctx)

    # router in f32 for stable softmax
    logits = jnp.einsum("gsd,de->gse", groups.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (G, Sg, k)
    if cfg.router_normalize_topk:
        top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # Switch aux loss: e * sum_e (fraction dispatched) * (mean prob)
    onehot_e = jax.nn.one_hot(top_e, e, dtype=jnp.float32)  # (G, Sg, k, E)
    f_e = jnp.mean(jnp.sum(onehot_e, axis=2), axis=(0, 1)) / k
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)

    # position of each (token, choice) within its expert, choice-major so
    # primary experts claim capacity first (GShard priority semantics)
    flat = onehot_e.transpose(0, 2, 1, 3).reshape(-1, k * sg, e)  # (G, k*Sg, E)
    pos = jnp.cumsum(flat, axis=1) - flat
    pos_tok = jnp.sum(pos * flat, axis=-1).reshape(-1, k, sg).transpose(0, 2, 1)
    within = pos_tok < cap  # (G, Sg, k)
    onehot_c = jax.nn.one_hot(pos_tok, cap, dtype=jnp.float32) * within[..., None]

    dispatch = jnp.einsum("gske,gskc->gsec", onehot_e, onehot_c).astype(dt)
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot_e, onehot_c,
                         top_p.astype(jnp.float32)).astype(dt)

    # all-to-all: (G sharded over data) x (E sharded over model)
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, groups.astype(dt))
    xe = _constrain(xe, P("model", batch_axes, None, None), ctx)
    w_up = params["up"].astype(dt)
    w_down = params["down"].astype(dt)
    up = jnp.einsum("egcd,edf->egcf", xe, w_up)
    if "gate" in params:
        gate = jnp.einsum("egcd,edf->egcf", xe, params["gate"].astype(dt))
        h = (jax.nn.silu(gate) if cfg.mlp_activation == "swiglu"
             else jax.nn.gelu(gate, approximate=True)) * up
    else:
        h = jax.nn.relu(up)
    ye = jnp.einsum("egcf,efd->egcd", h, w_down)
    ye = _constrain(ye, P("model", batch_axes, None, None), ctx)
    y = jnp.einsum("gsec,egcd->gsd", combine, ye)  # back to token layout
    out = y.reshape(-1, d)[: b * s].reshape(b, s, d)
    return out, aux.astype(jnp.float32)
