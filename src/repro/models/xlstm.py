"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory, exp gating).

Implemented in the numerically *stabilized recurrent* form of the xLSTM paper
(arXiv:2405.04517): both cells track a log-space stabilizer m_t so exponential
input gates never overflow:

    m_t = max(log f_t + m_{t-1}, log i_t)
    f'  = exp(log f_t + m_{t-1} - m_t),  i' = exp(log i_t - m_t)

mLSTM:  C_t = f' C_{t-1} + i' v_t k_t^T ;  n_t = f' n_{t-1} + i' k_t
        h_t = o_t * (C_t q_t) / max(|n_t . q_t|, 1)
sLSTM:  c_t = f' c_{t-1} + i' tanh(z_t) ; n_t = f' n_{t-1} + i'
        h_t = o_t * c_t / n_t

The sequence loop is a ``lax.scan`` (the state is the whole point of the
architecture — these cells are O(1)-state decoders, which is why xlstm-1.3b
runs the long_500k cell).  A chunkwise-parallel mLSTM is a known optimization;
the recurrent form is kept as the correctness baseline and the dry-run path
(FLOP-equivalent; see DESIGN.md §Arch-applicability).

x-gate precomputation: all input projections are batched matmuls over (B, S)
OUTSIDE the scan; only the recurrent term rides the carry.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.flags import scan_inner
from repro.models.layers import rmsnorm, rmsnorm_spec
from repro.models.sharding import ParamSpec

__all__ = [
    "mlstm_spec", "mlstm_apply", "mlstm_decode_step", "init_mlstm_state",
    "slstm_spec", "slstm_apply", "slstm_decode_step", "init_slstm_state",
    "MLSTMState", "SLSTMState",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _di(cfg) -> int:
    return int(cfg.xlstm_proj_factor * cfg.d_model)


def mlstm_spec(cfg) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    di = _di(cfg)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "xlstm_inner")),
        "conv_w": ParamSpec((cfg.ssm_conv_width, di), ("conv", "xlstm_inner")),
        "conv_b": ParamSpec((di,), ("xlstm_inner",), init="zeros"),
        "wq": ParamSpec((di, di), ("xlstm_inner", None)),
        "wk": ParamSpec((di, di), ("xlstm_inner", None)),
        "wv": ParamSpec((di, di), ("xlstm_inner", None)),
        "w_i": ParamSpec((di, h), ("xlstm_inner", "heads")),
        "b_i": ParamSpec((h,), ("heads",), init="zeros"),
        "w_f": ParamSpec((di, h), ("xlstm_inner", "heads")),
        "b_f": ParamSpec((h,), ("heads",), init="ones", scale=3.0),
        "w_o": ParamSpec((di, di), ("xlstm_inner", None)),
        "norm": rmsnorm_spec(di)["scale"],
        "down": ParamSpec((di, d), ("xlstm_inner", "embed")),
    }


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MLSTMState:
    c: jnp.ndarray  # (B, H, dh, dh) f32
    n: jnp.ndarray  # (B, H, dh) f32
    m: jnp.ndarray  # (B, H) f32
    conv: jnp.ndarray  # (B, width-1, di)

    def tree_flatten(self):
        return (self.c, self.n, self.m, self.conv), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def init_mlstm_state(batch: int, cfg, dtype=jnp.bfloat16) -> MLSTMState:
    h = cfg.n_heads
    di = _di(cfg)
    dh = di // h
    return MLSTMState(
        c=jnp.zeros((batch, h, dh, dh), jnp.float32),
        n=jnp.zeros((batch, h, dh), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, di), dtype),
    )


def _mlstm_gates_qkv(params, x, cfg, conv_prefix):
    """Shared projection path: x (B,S,D) -> (q,k,v,(logi,logf,o), conv_tail)."""
    dt = x.dtype
    h = cfg.n_heads
    di = _di(cfg)
    dh = di // h
    xz = x @ params["in_proj"].astype(dt)
    xm, z = jnp.split(xz, 2, axis=-1)  # (B, S, di) each
    w = params["conv_w"].astype(dt)
    width = w.shape[0]
    xp = jnp.concatenate([conv_prefix.astype(dt), xm], axis=1)
    conv = jax.lax.conv_general_dilated(
        xp, w[:, None, :], (1,), "VALID",
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=di,
    ) + params["conv_b"].astype(dt)
    xc = jax.nn.silu(conv)
    b, s = x.shape[0], x.shape[1]

    def heads(t):
        return t.reshape(b, s, h, dh)

    q = heads(xc @ params["wq"].astype(dt))
    k = heads(xc @ params["wk"].astype(dt)) / (dh**0.5)
    v = heads(xm @ params["wv"].astype(dt))
    log_i = (xm @ params["w_i"].astype(dt)).astype(jnp.float32) + params["b_i"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        (xm @ params["w_f"].astype(dt)).astype(jnp.float32) + params["b_f"].astype(jnp.float32)
    )
    o = jax.nn.sigmoid(xm @ params["w_o"].astype(dt))
    # conv state carries the last (width-1) of [prefix ++ xm] so it never
    # shrinks even when S < width-1 (single-token decode)
    return q, k, v, log_i, log_f, o, z, xp[:, xp.shape[1] - (width - 1):]


def _mlstm_step(state, q_t, k_t, v_t, li_t, lf_t):
    """One recurrence step; all f32. Shapes: q/k/v (B,H,dh), li/lf (B,H)."""
    c, n, m = state
    m_new = jnp.maximum(lf_t + m, li_t)
    fp = jnp.exp(lf_t + m - m_new)[..., None]
    ip = jnp.exp(li_t - m_new)[..., None]
    c_new = fp[..., None] * c + ip[..., None] * (v_t[..., :, None] * k_t[..., None, :])
    n_new = fp * n + ip * k_t
    h_num = jnp.einsum("bhij,bhj->bhi", c_new, q_t)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, q_t)), 1.0)
    h_t = h_num / h_den[..., None]
    return (c_new, n_new, m_new), h_t


def _mlstm_chunk_parallel(carry, inp, time_chunk: int):
    """Chunkwise-PARALLEL mLSTM (xLSTM paper's training form; §Perf B2).

    Naive per-step BPTT must store the (B, H, dh, dh) matrix memory at every
    timestep (4096 x 268 MB measured on xlstm train_4k).  The chunkwise form
    expresses all intra-chunk interactions as masked attention-like einsums
    (no per-step state materialized) and carries (C, n, m) only across chunk
    boundaries — autodiff stores S/L boundary states instead of S.

    With b_t = sum_{r<=t} log f_r (within the chunk) and boundary state
    (C0, n0, m0):
        m_t = max(b_t + m0, max_{j<=t}(b_t - b_j + li_j))
        C_t = e^{b_t+m0-m_t} C0 + sum_{j<=t} e^{b_t-b_j+li_j-m_t} v_j k_j^T
        y_t = C_t q_t ;  n_t analogous ;  h_t = y_t / max(|n_t . q_t|, 1)
    """
    c0, n0, m0 = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
    q, k, v, li, lf = inp  # (L,B,H,dh) x3, (L,B,H) x2
    L = time_chunk

    b_t = jnp.cumsum(lf, axis=0)  # (L,B,H) inclusive
    # intra-chunk stabilizer: max_j<=t (b_t - b_j + li_j) = b_t + max_j<=t(li_j - b_j)
    a_j = li - b_t  # (L,B,H): li_j - b_j
    run_max = jax.lax.associative_scan(jnp.maximum, a_j, axis=0)
    m_t = jnp.maximum(b_t + m0[None], b_t + run_max)  # (L,B,H)

    # decay matrix D[t,j] = exp(b_t - b_j + li_j - m_t) for j<=t; mask in
    # LOG space before exp so masked entries never produce inf (NaN-safe vjp)
    log_d = (b_t[:, None] - b_t[None, :] + li[None, :] - m_t[:, None])  # (L,L,B,H)
    causal = jnp.tril(jnp.ones((L, L), bool))
    log_d = jnp.where(causal[:, :, None, None], log_d, -1e30)
    d = jnp.exp(jnp.minimum(log_d, 30.0))  # m_t guarantees log_d <= 0; belt+braces

    scores = jnp.einsum("tbhd,jbhd->tjbh", q, k)  # (L,L,B,H)
    y_intra = jnp.einsum("tjbh,jbhd->tbhd", scores * d, v)
    n_intra = jnp.einsum("tjbh,jbhd->tbhd", d, k)

    inter_w = jnp.exp(b_t + m0[None] - m_t)  # (L,B,H)
    y_inter = jnp.einsum("bhij,tbhj->tbhi", c0, q) * inter_w[..., None]
    n_inter = n0[None] * inter_w[..., None]

    y = y_intra + y_inter
    n_t = n_intra + n_inter
    den = jnp.maximum(jnp.abs(jnp.einsum("tbhd,tbhd->tbh", n_t, q)), 1.0)
    h_t = y / den[..., None]  # (L,B,H,dh)

    # chunk-end state
    m1 = m_t[-1]
    w_end = jnp.exp(b_t[-1][None] - b_t + li - m1[None])  # (L,B,H)
    w_end = jnp.where(jnp.isfinite(w_end), w_end, 0.0)
    c1 = (jnp.exp(b_t[-1] + m0 - m1)[..., None, None] * c0
          + jnp.einsum("jbh,jbhd,jbhe->bhde", w_end, v, k))
    n1 = jnp.exp(b_t[-1] + m0 - m1)[..., None] * n0 + jnp.einsum(
        "jbh,jbhd->bhd", w_end, k)
    return (c1, n1, m1), h_t


def mlstm_apply(params, x: jnp.ndarray, cfg, state: MLSTMState = None):
    """x (B,S,D) -> (out (B,S,D), final state)."""
    import functools

    dt = x.dtype
    b, s, _ = x.shape
    h = cfg.n_heads
    di = _di(cfg)
    if state is None:
        state = init_mlstm_state(b, cfg, dt)
    q, k, v, log_i, log_f, o, z, conv_tail = _mlstm_gates_qkv(params, x, cfg, state.conv)

    xs = (
        q.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        log_i.transpose(1, 0, 2),
        log_f.transpose(1, 0, 2),
    )
    time_chunk = min(256, s)
    pad = (-s) % time_chunk
    if pad:
        # padded steps are inert: log_f = 0 (state kept), log_i = -inf
        xs = jax.tree_util.tree_map(
            lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1)), xs)
        xs = (xs[0], xs[1], xs[2], xs[3].at[s:].set(-1e30), xs[4])
    n_chunks = (s + pad) // time_chunk

    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape((n_chunks, time_chunk) + a.shape[1:]), xs)
    body = jax.checkpoint(
        functools.partial(_mlstm_chunk_parallel, time_chunk=time_chunk))
    (c, n, m), hs = scan_inner(body, (state.c, state.n, state.m), xs_c)
    hs = hs.reshape((n_chunks * time_chunk,) + hs.shape[2:])[:s]
    hs = hs.transpose(1, 0, 2, 3).reshape(b, s, di).astype(dt)  # (B,S,di)
    hs = rmsnorm({"scale": params["norm"]}, hs, cfg.norm_eps) * o
    out = (hs * jax.nn.silu(z)) @ params["down"].astype(dt)
    new_state = MLSTMState(c, n, m, conv_tail.astype(jnp.bfloat16))
    return out, new_state


def mlstm_decode_step(params, x: jnp.ndarray, cfg, state: MLSTMState):
    """x (B,1,D) one-token step."""
    out, new_state = mlstm_apply(params, x, cfg, state)
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_spec(cfg) -> dict:
    d = cfg.d_model
    f = max(1, int(d * 4 // 3))
    return {
        "w": ParamSpec((d, 4 * d), ("embed", None)),
        "r": ParamSpec((d, 4 * d), ("embed", None)),
        "b": ParamSpec((4 * d,), (None,), init="zeros"),
        "ffn_gate": ParamSpec((d, f), ("embed", "ff")),
        "ffn_up": ParamSpec((d, f), ("embed", "ff")),
        "ffn_down": ParamSpec((f, d), ("ff", "embed")),
        "ffn_norm": rmsnorm_spec(d)["scale"],
    }


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SLSTMState:
    c: jnp.ndarray  # (B, D) f32
    n: jnp.ndarray
    h: jnp.ndarray
    m: jnp.ndarray

    def tree_flatten(self):
        return (self.c, self.n, self.h, self.m), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def init_slstm_state(batch: int, cfg, dtype=jnp.bfloat16) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z + 1e-6, h=z, m=z - 1e30)


def _slstm_step(params, carry, xw_t):
    """xw_t: precomputed x @ W + b, (B, 4D) f32."""
    c, n, h, m = carry
    gates = xw_t + (h @ params["r"].astype(jnp.float32))
    zt, it, ft, ot = jnp.split(gates, 4, axis=-1)
    log_i = it
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, log_i)
    fp = jnp.exp(log_f + m - m_new)
    ip = jnp.exp(log_i - m_new)
    c_new = fp * c + ip * jnp.tanh(zt)
    n_new = fp * n + ip
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_apply(params, x: jnp.ndarray, cfg, state: SLSTMState = None):
    """x (B,S,D) -> (out (B,S,D), final state). Includes the post FFN."""
    dt = x.dtype
    b, s, d = x.shape
    if state is None:
        state = init_slstm_state(b, cfg, dt)
    xw = (x @ params["w"].astype(dt)).astype(jnp.float32) + params["b"].astype(jnp.float32)

    def step(carry, xw_t):
        return _slstm_step(params, carry, xw_t)

    (c, n, h, m), hs = jax.lax.scan(step, (state.c, state.n, state.h, state.m),
                                    xw.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(dt)  # (B,S,D)
    # post-FFN (proj factor 4/3 GLU) with its own pre-norm
    yn = rmsnorm({"scale": params["ffn_norm"]}, y, cfg.norm_eps)
    ff = (jax.nn.gelu(yn @ params["ffn_gate"].astype(dt), approximate=True)
          * (yn @ params["ffn_up"].astype(dt))) @ params["ffn_down"].astype(dt)
    out = y + ff
    return out, SLSTMState(c, n, h, m)


def slstm_decode_step(params, x: jnp.ndarray, cfg, state: SLSTMState):
    return slstm_apply(params, x, cfg, state)
