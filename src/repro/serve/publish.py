"""Training-side weight-delta publisher (DESIGN.md §20).

Every ``publish_every`` committed steps the trainer diffs its live params
against a local REPLICA MIRROR — the exact weights a subscriber that has
applied every published delta holds — compresses the diff through the same
``BucketLayout -> compress_stacked -> StackedPayload`` pipeline the gradient
exchange uses, and appends the bytecodec blob to the on-disk ring
(serve/ring.py).  Mirroring the subscriber instead of the previous params is
the DGC-style error-feedback trick (arXiv 1712.01887): whatever the lossy
codec dropped from delta v lands back in delta v+1, so replica staleness
error is bounded by ONE delta's compression error and never accumulates.

The replica state is deliberately NOT ``weights`` but the pair
``(base, spectrum_sum)``:

    weights == base + irfft(spectrum_sum)        # materialized lazily

FFT linearity (DESIGN.md §10) means folding a delta is one complex ADD of
its dequantized spectrum — no inverse FFT — and a replica that fell K
deltas behind catches up by summing K spectra before ONE irfft.  Because
every replica (the publisher's mirror included) folds the same spectra in
the same version order onto the same base, their materialized weights are
BITWISE identical no matter how they batched the catch-up: the irfft is a
pure function of ``(base, spectrum_sum)``.  Rebase points (snapshots, every
``snapshot_every`` deltas) collapse the pair to ``(weights, 0)`` at the
same versions on every replica, so equality survives snapshot boundaries —
including the fallback path that loads the snapshot file instead of
computing the rebase locally (the file holds the same materialized bits).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np
import jax.numpy as jnp

from repro.comms import bucketing
from repro.comms.reducers import flatten_tree
from repro.comms.transport import _irfft_rows
from repro.core.compressor import FFTCompressor, FFTCompressorConfig
from repro.serve.ring import RingWriter

__all__ = ["PublishConfig", "SpectrumReplicaState", "WeightDeltaPublisher"]


@dataclasses.dataclass(frozen=True)
class PublishConfig:
    """Static knobs of the publish path."""

    publish_every: int = 1  # trainer steps between deltas
    capacity: int = 64  # ring depth (deltas buffered for laggards)
    snapshot_every: int = 16  # deltas between snapshots/rebase points
    theta: float = 0.0  # spectrum drop-out of the delta codec
    n_bits: int = 8
    m_bits: int = 3
    chunk: int = 4096
    bucket_bytes: int = 4 << 20
    quantize: bool = True
    backend: str = "reference"

    def __post_init__(self):
        if self.publish_every < 1:
            raise ValueError(f"publish_every must be >= 1, got {self.publish_every}")
        if self.snapshot_every < 1:
            raise ValueError(f"snapshot_every must be >= 1, got {self.snapshot_every}")
        if self.capacity < self.snapshot_every:
            # a replica that wrapped must bridge snapshot -> latest from the
            # buffered deltas alone; a shallower ring could strand it
            raise ValueError(
                f"capacity ({self.capacity}) must be >= snapshot_every "
                f"({self.snapshot_every}) so the snapshot always reaches the "
                f"buffered tail")

    def compressor_config(self) -> FFTCompressorConfig:
        return FFTCompressorConfig(
            theta=self.theta, n_bits=self.n_bits, m_bits=self.m_bits,
            chunk=self.chunk, quantize=self.quantize, backend=self.backend)


class SpectrumReplicaState:
    """The ``(base, spectrum_sum)`` pair every replica folds deltas onto.

    ``fold`` is spectrum-only (one complex add per delta — no inverse FFT);
    ``materialize`` runs the ONE irfft and caches until the next fold;
    ``rebase`` collapses to ``(weights, 0)``.  ``decompress_count`` counts
    actual irfft materializations — the metric the catch-up acceptance
    criterion is stated in (BENCH_serve.json, tools/check_bench.py).
    """

    def __init__(self, base_flat, layout, comp):
        self.layout = layout
        self.comp = comp
        self.base = jnp.asarray(base_flat, jnp.float32)
        self._spectrum = None  # None == zero (no deltas since rebase)
        self._cached: Optional[jnp.ndarray] = self.base
        self.decompress_count = 0

    def fold(self, payload) -> None:
        """Accumulate one delta payload's dequantized spectrum."""
        spec = self.comp.decompress_spectrum(payload)
        self._spectrum = spec if self._spectrum is None \
            else self._spectrum + spec
        self._cached = None

    def materialize(self) -> jnp.ndarray:
        """Current replica weights: base + irfft(spectrum_sum), cached."""
        if self._cached is None:
            rows = _irfft_rows(self._spectrum, self.layout.chunk)
            delta = bucketing.unstack_buckets(rows, self.layout)
            self._cached = self.base + delta
            self.decompress_count += 1
        return self._cached

    def rebase(self) -> jnp.ndarray:
        """Collapse to (weights, 0) — the snapshot-version contract."""
        self.base = self.materialize()
        self._spectrum = None
        self._cached = self.base
        return self.base


class WeightDeltaPublisher:
    """Appends compressed weight deltas (and periodic snapshots) to a ring.

    Owns the single ``RingWriter``; versions are monotone, one per
    published delta.  Construction writes snapshot version 0 (the initial
    weights), so a subscriber can join before the first delta exists.
    """

    def __init__(self, ring_dir: str, init_params,
                 config: PublishConfig = PublishConfig(),
                 extra_meta: Optional[Dict] = None):
        self.config = config
        flat0, self._shapes, self._treedef = flatten_tree(init_params)
        total = int(flat0.shape[0])
        self.comp = FFTCompressor(config.compressor_config())
        self.layout = bucketing.build_layout(
            total, config.bucket_bytes, config.chunk)
        meta = {
            "flat_len": total,
            "bucket_bytes": int(config.bucket_bytes),
            "chunk": int(config.chunk),
            "snapshot_every": int(config.snapshot_every),
            "publish_every": int(config.publish_every),
            "compressor": {
                "theta": float(config.theta),
                "n_bits": int(config.n_bits),
                "m_bits": int(config.m_bits),
                "chunk": int(config.chunk),
                "quantize": bool(config.quantize),
                "backend": str(config.backend),
            },
        }
        if extra_meta:
            meta.update(extra_meta)
        self.writer = RingWriter(ring_dir, capacity=config.capacity, meta=meta)
        self.state = SpectrumReplicaState(flat0, self.layout, self.comp)
        self.writer.write_snapshot(np.asarray(self.state.base),
                                   version=0, step=-1)
        self.delta_bytes_total = 0
        self.snapshot_bytes_total = int(4 * total)  # the v0 snapshot

    @property
    def version(self) -> int:
        return self.writer.latest_version

    def publish(self, step: int, params) -> int:
        """Diff params against the replica mirror, append one delta; returns
        the new version."""
        flat, _, _ = flatten_tree(params)
        if int(flat.shape[0]) != self.layout.total:
            raise ValueError(
                f"param tree flattens to {int(flat.shape[0])} elements; "
                f"publisher was built for {self.layout.total}")
        delta = flat - self.state.materialize()
        payload = self.comp.compress_stacked(
            bucketing.stack_buckets(delta, self.layout), self.layout.sizes())
        blob = payload.to_bytes()
        version = self.writer.append_delta(
            blob, step=step, theta=self.config.theta)
        self.delta_bytes_total += len(blob)
        # fold AFTER the write: the mirror tracks what subscribers can read
        self.state.fold(payload)
        if version % self.config.snapshot_every == 0:
            weights = self.state.rebase()
            self.writer.write_snapshot(np.asarray(weights),
                                       version=version, step=step)
            self.snapshot_bytes_total += 4 * self.layout.total
        return version

    def on_step(self, step: int, params) -> Optional[int]:
        """Cadence filter: publish on every ``publish_every``-th step."""
        if step % self.config.publish_every == 0:
            return self.publish(step, params)
        return None

    def hook(self) -> Callable[[int, Dict], None]:
        """A ``TrainLoopConfig.publish_hook`` bound to this publisher."""
        def _hook(step: int, state: Dict) -> None:
            self.on_step(step, state["params"])
        return _hook

    def close(self) -> None:
        """Mark the ring closed so tailing subscribers can exit."""
        self.writer.close()
