"""Versioned on-disk ring buffer of compressed weight deltas (DESIGN.md §20).

The publisher/subscriber boundary is a DIRECTORY, not a socket: the training
job appends compressed delta payloads (``core.bytecodec`` blobs) plus
periodic dense snapshots, and any number of serving replicas tail the
directory from separate processes with no coordination beyond the
filesystem.  Layout:

    <ring_dir>/
      manifest.json        the only mutable file (written atomically)
      delta_0000042.rpay   bytecodec blob of delta version 42
      snapshot_0000040.f32 raw little-endian f32 flat weights at version 40

Consistency contract: payload/snapshot files are fully written and fsynced
BEFORE the manifest that references them is swapped into place
(tmp + ``os.replace``), so a reader that loads the manifest never sees a
torn entry; a reader that loads a file evicted after its manifest read gets
a clean ``FileNotFoundError`` and simply re-reads the manifest.  Versions
are monotone (one per delta, starting at 1); the ring holds the most recent
``capacity`` deltas and the most recent snapshot — older delta files are
unlinked on eviction.

The manifest's ``meta`` block carries everything a subscriber needs to
rebuild the decompression pipeline with no side channel: the flat length,
the bucket layout parameters, the compressor config, and the snapshot
cadence (the subscriber rebases at the same versions the publisher does —
see serve/subscribe.py).
"""

from __future__ import annotations

import json
import os
from typing import Optional, Tuple

import numpy as np

__all__ = ["RingWriter", "RingReader", "RING_FORMAT_VERSION", "MANIFEST_NAME"]

RING_FORMAT_VERSION = 1
MANIFEST_NAME = "manifest.json"


def _delta_name(version: int) -> str:
    return f"delta_{version:07d}.rpay"


def _snapshot_name(version: int) -> str:
    return f"snapshot_{version:07d}.f32"


def _write_file(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class RingWriter:
    """Single-writer append side of the ring (the training job owns it)."""

    def __init__(self, ring_dir: str, *, capacity: int, meta: dict):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.ring_dir = str(ring_dir)
        self.capacity = int(capacity)
        os.makedirs(self.ring_dir, exist_ok=True)
        self._manifest = {
            "format_version": RING_FORMAT_VERSION,
            "capacity": self.capacity,
            "latest_version": 0,
            "closed": False,
            "meta": dict(meta),
            "deltas": [],  # oldest -> newest, at most `capacity` entries
            "snapshot": None,  # {"version", "step", "path", "nbytes"}
        }
        self._flush_manifest()

    # -- internals ----------------------------------------------------------

    def _flush_manifest(self) -> None:
        _write_file(os.path.join(self.ring_dir, MANIFEST_NAME),
                    json.dumps(self._manifest, indent=1).encode("utf-8"))

    # -- append API ---------------------------------------------------------

    @property
    def latest_version(self) -> int:
        return self._manifest["latest_version"]

    def append_delta(self, blob: bytes, *, step: int, theta: float) -> int:
        """Write one compressed delta; returns its (monotone) version."""
        if self._manifest["closed"]:
            raise RuntimeError("ring is closed")
        version = self._manifest["latest_version"] + 1
        name = _delta_name(version)
        _write_file(os.path.join(self.ring_dir, name), blob)
        self._manifest["deltas"].append(
            {"version": version, "step": int(step), "path": name,
             "nbytes": len(blob), "theta": float(theta)})
        evicted = self._manifest["deltas"][:-self.capacity]
        self._manifest["deltas"] = self._manifest["deltas"][-self.capacity:]
        self._manifest["latest_version"] = version
        self._flush_manifest()  # manifest stops referencing evictees first
        for entry in evicted:
            try:
                os.unlink(os.path.join(self.ring_dir, entry["path"]))
            except FileNotFoundError:
                pass
        return version

    def write_snapshot(self, flat: np.ndarray, *, version: int,
                       step: int) -> None:
        """Dense f32 weights AT ``version`` (after that delta was applied)."""
        if self._manifest["closed"]:
            raise RuntimeError("ring is closed")
        data = np.ascontiguousarray(
            np.asarray(flat, dtype="<f4")).tobytes(order="C")
        name = _snapshot_name(version)
        _write_file(os.path.join(self.ring_dir, name), data)
        old = self._manifest["snapshot"]
        self._manifest["snapshot"] = {
            "version": int(version), "step": int(step), "path": name,
            "nbytes": len(data)}
        self._flush_manifest()
        if old is not None and old["path"] != name:
            try:
                os.unlink(os.path.join(self.ring_dir, old["path"]))
            except FileNotFoundError:
                pass

    def close(self) -> None:
        """Mark the stream finished: tailing subscribers can exit."""
        if not self._manifest["closed"]:
            self._manifest["closed"] = True
            self._flush_manifest()


class RingReader:
    """Read side: re-reads the manifest on demand (any number of these)."""

    def __init__(self, ring_dir: str):
        self.ring_dir = str(ring_dir)

    def manifest(self) -> dict:
        path = os.path.join(self.ring_dir, MANIFEST_NAME)
        with open(path, "rb") as f:
            m = json.loads(f.read().decode("utf-8"))
        version = m.get("format_version")
        if version != RING_FORMAT_VERSION:
            raise ValueError(
                f"unsupported ring format version {version!r} "
                f"(this reader supports {RING_FORMAT_VERSION})")
        return m

    def read_delta(self, manifest: dict, version: int) -> bytes:
        for entry in manifest["deltas"]:
            if entry["version"] == version:
                with open(os.path.join(self.ring_dir, entry["path"]),
                          "rb") as f:
                    return f.read()
        raise KeyError(f"delta version {version} is not in the ring "
                       f"(tail has wrapped past it)")

    def read_snapshot(self, manifest: dict) -> Tuple[int, int, np.ndarray]:
        """-> (version, step, flat f32 weights)."""
        snap = manifest.get("snapshot")
        if snap is None:
            raise KeyError("ring has no snapshot yet")
        with open(os.path.join(self.ring_dir, snap["path"]), "rb") as f:
            data = f.read()
        flat = np.frombuffer(data, dtype="<f4").astype(np.float32)
        return int(snap["version"]), int(snap["step"]), flat

    def tail_version(self, manifest: dict) -> Optional[int]:
        """Oldest delta version still buffered (None when the ring is empty)."""
        deltas = manifest["deltas"]
        return int(deltas[0]["version"]) if deltas else None
