"""Serving-side replica subscriber (DESIGN.md §20).

Tails a delta ring (serve/ring.py) and folds each compressed delta into the
same ``(base, spectrum_sum)`` replica state the publisher mirrors
(serve/publish.py).  The decompress-heavy half of the paper's asymmetric
train->serve traffic: the subscriber never compresses — it dequantizes
spectra, sums them (FFT linearity), and runs ONE inverse FFT per
materialization no matter how many deltas the sync covered.

Catch-up ladder, per ``sync()``:

1. up to date — nothing to do;
2. the buffered deltas reach back to our version — replay them in version
   order (spectrum adds only), rebase locally at every ``snapshot_every``
   boundary (same versions as the publisher — bitwise the same collapse),
   one irfft at the end;
3. GAP — the ring's tail wrapped past ``version + 1``: reload the latest
   snapshot (``gap_detected``/``snapshot_loads`` in the stats), then replay
   the buffered deltas after it.  ``capacity >= snapshot_every`` (enforced
   by ``PublishConfig``) guarantees the snapshot always reaches the tail.

The decompression pipeline (compressor config + bucket layout) is rebuilt
from the manifest's ``meta`` block — a subscriber process needs the ring
directory and nothing else.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.comms import bucketing
from repro.core.compressor import FFTCompressor, FFTCompressorConfig, StackedPayload
from repro.serve.publish import SpectrumReplicaState
from repro.serve.ring import RingReader

__all__ = ["SyncStats", "ReplicaSubscriber"]


@dataclasses.dataclass
class SyncStats:
    """What one ``sync()`` call did (the acceptance criteria's vocabulary)."""

    applied: int = 0  # deltas folded this sync
    decompress_count: int = 0  # irfft materializations this sync
    rebases: int = 0  # local snapshot-boundary collapses
    snapshot_loads: int = 0  # full-weight fallbacks (gap path)
    gap_detected: bool = False
    bytes_read: int = 0
    version: int = 0  # replica version after the sync
    closed: bool = False  # publisher marked the stream finished


class ReplicaSubscriber:
    """One serving replica's view of the ring."""

    def __init__(self, ring_dir: str):
        self.reader = RingReader(ring_dir)
        manifest = self.reader.manifest()
        meta = manifest["meta"]
        self.comp = FFTCompressor(FFTCompressorConfig(**meta["compressor"]))
        self.layout = bucketing.build_layout(
            int(meta["flat_len"]), int(meta["bucket_bytes"]),
            int(meta["chunk"]))
        self.snapshot_every = int(meta["snapshot_every"])
        self.meta = meta
        version, _, flat = self.reader.read_snapshot(manifest)
        self.state = SpectrumReplicaState(flat, self.layout, self.comp)
        self.version = version

    # -- catch-up ------------------------------------------------------------

    def sync(self) -> SyncStats:
        """Fold every ring delta newer than ``self.version``; one irfft."""
        stats = SyncStats()
        count0 = self.state.decompress_count
        manifest = self.reader.manifest()
        stats.closed = bool(manifest.get("closed", False))
        latest = int(manifest["latest_version"])
        if latest > self.version:
            tail = self.reader.tail_version(manifest)
            start = self.version + 1
            if tail is None or start < tail:
                # the ring wrapped past us: snapshot fallback
                stats.gap_detected = True
                snap_v, _, flat = self.reader.read_snapshot(manifest)
                if tail is not None and snap_v + 1 < tail:
                    raise RuntimeError(
                        f"ring wrapped past its own snapshot (snapshot v"
                        f"{snap_v}, tail v{tail}): capacity < snapshot_every?")
                self.state = SpectrumReplicaState(
                    flat, self.layout, self.comp)
                count0 = 0  # fresh state: its counter restarts at zero
                self.version = snap_v
                stats.snapshot_loads += 1
                stats.bytes_read += 4 * self.layout.total
                start = snap_v + 1
            for v in range(start, latest + 1):
                blob = self.reader.read_delta(manifest, v)
                stats.bytes_read += len(blob)
                self.state.fold(StackedPayload.from_bytes(blob))
                stats.applied += 1
                self.version = v
                if v % self.snapshot_every == 0:
                    # the publisher collapsed (base, S) at this version;
                    # collapse identically so bitwise equality survives the
                    # boundary (no file read — the rebase is local)
                    self.state.rebase()
                    stats.rebases += 1
            self.state.materialize()  # the ONE catch-up irfft
        stats.decompress_count = self.state.decompress_count - count0
        stats.version = self.version
        return stats

    def follow(self, *, poll_s: float = 0.2,
               timeout_s: Optional[float] = None,
               on_sync=None) -> int:
        """Tail the ring until the publisher closes it; returns the final
        version.  ``on_sync(stats)`` fires after every sync that advanced."""
        deadline = None if timeout_s is None else time.monotonic() + timeout_s
        while True:
            stats = self.sync()
            if on_sync is not None and stats.applied:
                on_sync(stats)
            if stats.closed and stats.version >= 0 and stats.applied == 0:
                return self.version
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"ring not closed after {timeout_s}s (at v{self.version})")
            if stats.applied == 0:
                time.sleep(poll_s)

    # -- weight access -------------------------------------------------------

    def weights(self) -> np.ndarray:
        """Flat f32 replica weights at ``self.version`` (cached)."""
        return np.asarray(self.state.materialize())

    def params_like(self, params_template):
        """Unflatten :meth:`weights` into the template's tree structure."""
        from repro.comms.reducers import flatten_tree, unflatten_tree

        _, shapes, treedef = flatten_tree(params_template)
        import jax.numpy as jnp

        return unflatten_tree(
            jnp.asarray(self.weights()), shapes, treedef)
