from repro.serve.engine import ServeConfig, Engine
from repro.serve.publish import (
    PublishConfig,
    SpectrumReplicaState,
    WeightDeltaPublisher,
)
from repro.serve.ring import RingReader, RingWriter
from repro.serve.subscribe import ReplicaSubscriber, SyncStats

__all__ = [
    "ServeConfig",
    "Engine",
    "PublishConfig",
    "SpectrumReplicaState",
    "WeightDeltaPublisher",
    "RingReader",
    "RingWriter",
    "ReplicaSubscriber",
    "SyncStats",
]
