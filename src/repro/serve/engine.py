"""Serving engine: batched prefill + decode with KV/SSM caches.

``prefill_step`` and ``decode_step`` are the two functions the decode-shape
dry-run cells lower (``decode_32k``/``long_500k`` lower decode_step against a
cache of the assigned sequence length, per the assignment).

The engine implements simple batched serving: requests are padded into a
fixed batch, prefilled together, then decoded token-by-token with greedy or
temperature sampling.  Continuous batching (slot reuse on completion) is a
thin layer on top — ``Engine.generate`` exposes the batch API the examples
use.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import LM, MeshCtx

__all__ = ["ServeConfig", "Engine", "build_prefill_step", "build_decode_step"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 2048
    batch: int = 8
    temperature: float = 0.0  # 0 = greedy
    eos_token: int = -1  # -1: never stop early


def build_prefill_step(model: LM, ctx: Optional[MeshCtx] = None, max_seq=None):
    def prefill_step(params, batch):
        memory = None
        if model.cfg.n_encoder_layers:
            memory = model.encode(params, batch["frontend"], ctx)
        elif model.cfg.frontend != "none":
            memory = batch["frontend"].astype(jnp.bfloat16)
        logits, caches = model.prefill(
            params, batch["tokens"], memory=memory, ctx=ctx, max_seq=max_seq,
            last_only=True,
        )
        return logits, caches

    return prefill_step


def build_decode_step(model: LM, ctx: Optional[MeshCtx] = None):
    def decode_step(params, caches, token, pos):
        return model.decode_step(params, caches, token, pos, ctx=ctx)

    return decode_step


class Engine:
    """Batched generation on top of prefill/decode."""

    def __init__(self, model: LM, params, config: ServeConfig,
                 ctx: Optional[MeshCtx] = None):
        self.model = model
        self.params = params
        self.config = config
        self._prefill = jax.jit(build_prefill_step(model, ctx, config.max_seq))
        self._decode = jax.jit(build_decode_step(model, ctx))

    def _sample(self, logits, key):
        if self.config.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        scaled = logits[:, -1] / self.config.temperature
        return jax.random.categorical(key, scaled).astype(jnp.int32)

    def generate(self, prompts: jnp.ndarray, max_new_tokens: int,
                 key=None, frontend=None) -> jnp.ndarray:
        """prompts (B, S_prompt) int32 -> (B, S_prompt + max_new) tokens."""
        key = key if key is not None else jax.random.PRNGKey(0)
        b, s = prompts.shape
        batch = {"tokens": prompts}
        if frontend is not None:
            batch["frontend"] = frontend
        logits, caches = self._prefill(self.params, batch)
        tokens = [prompts]
        tok = self._sample(logits, key)[:, None]
        for i in range(max_new_tokens):
            tokens.append(tok)
            if i == max_new_tokens - 1:
                break
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, caches, tok, jnp.int32(s + i))
            tok = self._sample(logits, sub)[:, None]
        return jnp.concatenate(tokens, axis=1)
