"""Post-optimization HLO parsing: per-device collective bytes by op kind.

cost_analysis() does not expose collective traffic, so the roofline's
collective term is derived here: every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute instruction in
``compiled.as_text()`` is matched, its operand/result bytes computed from the
printed shapes, and its replica-group size parsed (both the explicit
``{{0,1},{2,3}}`` and the iota ``[8,64]<=[512]`` formats).

Ring-model traffic per device (bytes that actually cross links):
    all-reduce        2 * bytes * (n-1)/n
    all-gather        result_bytes * (n-1)/n
    reduce-scatter    input_bytes  * (n-1)/n   (= result_bytes * (n-1))
    all-to-all        bytes * (n-1)/n
    collective-permute bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

__all__ = ["CollectiveStats", "parse_collectives", "summarize"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_OP_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

# one shape token: f32[1,2,3]{...} — dims optional (scalars)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{\{")


@dataclasses.dataclass
class CollectiveStats:
    kind: str
    count: int = 0
    raw_bytes: float = 0.0  # sum of payload bytes (per device program)
    link_bytes: float = 0.0  # ring-model bytes crossing links per device


def _shape_bytes(text: str) -> float:
    """Sum bytes over every shape token in a result/operand string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[num_groups, group_size]<=[...]
        return int(m.group(2))
    return default


def parse_collectives(hlo_text: str, default_group: int = 1) -> Dict[str, CollectiveStats]:
    """Scan HLO; returns per-kind stats for the per-device program."""
    stats: Dict[str, CollectiveStats] = {
        k: CollectiveStats(kind=k) for k in _OP_KINDS
    }
    for line in hlo_text.splitlines():
        line = line.strip()
        # match "<result> = <shape...> <op>(" — the op name before '('
        m = re.search(r"=\s+(.+?)\s+([\w-]+)\(", line)
        if not m:
            continue
        op = m.group(2)
        # normalize fusions like all-gather-start / all-reduce-done
        base = None
        for kind in _OP_KINDS:
            if op == kind or op.startswith(kind + "-start"):
                base = kind
                break
        if base is None:
            continue
        result_text = m.group(1)
        payload = _shape_bytes(result_text)
        n = max(_group_size(line, default_group), 1)
        st = stats[base]
        st.count += 1
        st.raw_bytes += payload
        if base == "all-reduce":
            st.link_bytes += 2.0 * payload * (n - 1) / n
        elif base == "all-gather":
            st.link_bytes += payload * (n - 1) / n
        elif base == "reduce-scatter":
            st.link_bytes += payload * (n - 1)  # result is the scattered shard
        elif base in ("all-to-all", "ragged-all-to-all"):
            st.link_bytes += payload * (n - 1) / n
        else:  # collective-permute
            st.link_bytes += payload
    return {k: v for k, v in stats.items() if v.count}


def summarize(stats: Dict[str, CollectiveStats]) -> Dict:
    return {
        k: {"count": v.count, "raw_bytes": v.raw_bytes, "link_bytes": v.link_bytes}
        for k, v in stats.items()
    }
