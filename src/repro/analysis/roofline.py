"""Three-term roofline from the compiled dry-run artifact (EXPERIMENTS.md §9).

Hardware model (TPU v5e, per chip):
    peak bf16 compute   197 TFLOP/s
    HBM bandwidth       819 GB/s
    ICI                 ~50 GB/s per link (intra-pod collectives)
    DCN                 ~12.5 GB/s per host (inter-pod 'pod'-axis collectives)

Terms (seconds, per training/serving step):
    compute    = HLO_FLOPs_per_device / 197e12
    memory     = HLO_bytes_per_device / 819e9
    collective = ici_link_bytes / 50e9 + dcn_link_bytes / 12.5e9

cost_analysis() on the partitioned module reports PER-DEVICE flops/bytes.
Collective link-bytes come from analysis.hlo with the ring model; collectives
whose replica group spans pods (group size == 512 or touching the pod axis)
are charged at DCN rate — the parser cannot always tell, so the charge rule
is group_size > chips_per_pod -> DCN (conservative for multi-pod runs).

MODEL_FLOPS (the "useful" numerator): 6*N*D for a train step, 2*N*D for a
decode/prefill forward (N = active params for MoE, D = tokens in the step).
ratio = MODEL_FLOPS / (HLO_FLOPs_per_device * chips) exposes remat/dispatch
overhead.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

__all__ = ["HW", "RooflineTerms", "compute_roofline", "model_flops"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    ici_bw: float = 50e9
    dcn_bw: float = 12.5e9
    chips_per_pod: int = 256


V5E = HW()


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    ici_bytes: float
    dcn_bytes: float
    model_flops: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs * chips)
    dominant: str
    step_time_s: float  # max of the three (perfect-overlap lower bound)
    roofline_fraction: float  # compute_s / step_time_s ("how close to
    # compute-bound"; 1.0 = compute-limited = at roofline)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def model_flops(n_active_params: float, tokens: float, kind: str) -> float:
    """6ND for train (fwd+bwd), 2ND for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens


def compute_roofline(
    *,
    cost: Dict,
    collectives: Dict,
    chips: int,
    n_active_params: float,
    tokens: float,
    kind: str,
    hw: HW = V5E,
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    ici = dcn = 0.0
    for k, st in collectives.items():
        link = st["link_bytes"] if isinstance(st, dict) else st.link_bytes
        # crude pod detection: groups larger than a pod must cross DCN
        ici += link
    # dcn split is applied by the caller when it knows the mesh (multi-pod
    # runs re-bucket via `split_pod_traffic`)

    compute_s = flops / hw.peak_flops
    memory_s = bytes_accessed / hw.hbm_bw
    collective_s = ici / hw.ici_bw + dcn / hw.dcn_bw

    mf = model_flops(n_active_params, tokens, kind)
    total_hlo = flops * chips
    useful = mf / total_hlo if total_hlo else 0.0

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step = max(terms.values()) if terms else 0.0
    frac = compute_s / step if step else 0.0
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=bytes_accessed,
        ici_bytes=ici,
        dcn_bytes=dcn,
        model_flops=mf,
        useful_ratio=useful,
        dominant=dominant,
        step_time_s=step,
        roofline_fraction=frac,
    )
