"""Deterministic synthetic data pipeline (sharded, resumable).

Two generators:
* ``markov`` — a fixed random first-order Markov chain over the vocab.  This
  is *learnable* structure: a model trained on it shows the convergence curves
  the paper's Fig. 11/12 experiments need (loss decreases toward the chain's
  entropy), without any external dataset.
* ``uniform`` — i.i.d. tokens (loss floor = log V), for pure-throughput runs.

Determinism & fault tolerance: batch ``i`` is a pure function of (seed, i) —
``batch_at(step)`` — so a restart from a checkpoint at step N replays the
exact stream with no cursor files.  Sharding: each data-parallel host slices
its rows from the global batch by (host_index, num_hosts).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticConfig", "SyntheticStream", "ImageConfig", "ImageStream"]


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    kind: str = "markov"  # markov | uniform
    seed: int = 1234
    branching: int = 4  # markov: candidate successors per token
    frontend_dim: int = 0  # >0: also emit frontend embeddings (stub modality)
    frontend_len: int = 0


class SyntheticStream:
    """Stateless stream: batch_at(step) -> {tokens, targets[, frontend]}."""

    def __init__(self, config: SyntheticConfig):
        self.config = config
        rng = np.random.default_rng(config.seed)
        v, b = config.vocab_size, config.branching
        # fixed markov successor table: token t -> b candidates
        self._succ = rng.integers(0, v, size=(v, b), dtype=np.int32)
        self._succ_jnp = jnp.asarray(self._succ)

    def batch_at(self, step: int, host_index: int = 0, num_hosts: int = 1) -> Dict:
        cfg = self.config
        rows = cfg.global_batch // num_hosts
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        key = jax.random.fold_in(key, host_index)
        return self._generate(key, rows)

    def _generate(self, key, rows: int) -> Dict:
        cfg = self.config
        k_init, k_walk, k_front = jax.random.split(key, 3)
        if cfg.kind == "uniform":
            toks = jax.random.randint(
                k_init, (rows, cfg.seq_len + 1), 0, cfg.vocab_size, jnp.int32
            )
        else:
            start = jax.random.randint(k_init, (rows,), 0, cfg.vocab_size, jnp.int32)
            choices = jax.random.randint(
                k_walk, (rows, cfg.seq_len), 0, cfg.branching, jnp.int32
            )

            def walk(tok, choice):
                nxt = self._succ_jnp[tok, choice]
                return nxt, nxt

            _, seq = jax.lax.scan(walk, start, choices.T)
            toks = jnp.concatenate([start[:, None], seq.T], axis=1)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if cfg.frontend_dim:
            batch["frontend"] = (
                jax.random.normal(k_front, (rows, cfg.frontend_len, cfg.frontend_dim))
                * 0.02
            )
        return batch

    def entropy_floor(self) -> float:
        """Markov chain cross-entropy floor (nats) — uniform over branches."""
        if self.config.kind == "uniform":
            return float(np.log(self.config.vocab_size))
        # successors may collide; floor is <= log(branching)
        return float(np.log(self.config.branching))


# ---------------------------------------------------------------------------
# Image stream (convnet experiments: paper Fig. 11/12 trained CNNs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ImageConfig:
    """Class-conditional gaussian-blob images: learnable, dataset-free."""

    n_classes: int = 10
    img_size: int = 32
    global_batch: int = 16
    seed: int = 1234
    noise: float = 0.5  # per-sample noise scale around the class prototype


class ImageStream:
    """Stateless image stream with the same batch_at contract as
    :class:`SyntheticStream`: batch ``i`` is a pure function of (seed, i), so
    restarts replay the exact stream and every worker derives the same global
    batch (rows are then sharded over the data axis by the step's sharding).
    """

    def __init__(self, config: ImageConfig):
        self.config = config
        # fixed prototypes: the learnable structure (one blob per class).
        # Drawn at low resolution and upsampled so the class signal is
        # low-frequency, like natural images (white-noise prototypes would
        # give conv gradients a flat spectrum no spectral method compresses).
        proto_key = jax.random.PRNGKey(config.seed + 1)
        coarse = jax.random.normal(
            proto_key, (config.n_classes, 4, 4, 3)
        )
        self._protos = jax.image.resize(
            coarse,
            (config.n_classes, config.img_size, config.img_size, 3),
            method="linear",
        ) * 2.0

    def batch_at(self, step: int, host_index: int = 0, num_hosts: int = 1) -> Dict:
        cfg = self.config
        rows = cfg.global_batch // num_hosts
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        key = jax.random.fold_in(key, host_index)
        k_label, k_noise = jax.random.split(key)
        labels = jax.random.randint(k_label, (rows,), 0, cfg.n_classes, jnp.int32)
        images = self._protos[labels] + cfg.noise * jax.random.normal(
            k_noise, (rows, cfg.img_size, cfg.img_size, 3)
        )
        return {"images": images, "labels": labels}

    def entropy_floor(self) -> float:
        """Bayes loss is near 0 once prototypes separate; report 0."""
        return 0.0
