from repro.data.synthetic import (
    ImageConfig,
    ImageStream,
    SyntheticConfig,
    SyntheticStream,
)

__all__ = ["SyntheticConfig", "SyntheticStream", "ImageConfig", "ImageStream"]
