from repro.data.synthetic import SyntheticConfig, SyntheticStream

__all__ = ["SyntheticConfig", "SyntheticStream"]
