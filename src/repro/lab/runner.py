"""Experiment runner: drives ``train_loop`` on a simulated multi-worker mesh
while recording the per-step evidence the evaluator needs.

Recorded per step (via the ``TrainLoopConfig.metrics_hook`` seam):

* ``loss`` / ``acc`` — the step's averaged training metrics;
* ``grad_sq`` — measured gradient energy ``||g||^2`` (pre-clip global norm),
  the quantity Thm 3.4 bounds;
* ``theta`` — the quantized theta the step actually ran;
* ``payload_bits`` / ``compression_ratio`` — modeled wire payload at that
  theta over the run's bucket layout (feeds ``cost_model.run_wire_account``);
* Assumption 3.1 probe — every ``probe_every`` steps the LIVE full-batch
  gradient at the current params is compressed and reconstructed with the
  run's compressor at the step's theta, recording
  ``err_ratio = ||g - g_hat||/||g||`` and ``norm_ratio = ||g_hat||/||g||``
  (``core.theory.assumption31_stats``).

Multi-worker simulation: the caller (``repro.lab.run`` CLI or the tier-2
test) sets ``--xla_force_host_platform_device_count`` before jax's first
import; this module only checks the device count is sufficient.
"""

from __future__ import annotations

import dataclasses
import shutil
import tempfile
import time
from typing import Dict, List, Optional

import jax

from repro import jaxcompat as compat
from repro.comms import cost_model
from repro.comms import faults as faults_mod
from repro.comms.reducers import ReducerConfig, flatten_tree
from repro.configs.base import ArchConfig
from repro.core import schedules as theta_schedules
from repro.core.baselines import QSGD, TernGrad
from repro.core.compressor import (
    FFTCompressor,
    FFTCompressorConfig,
    TimeDomainCompressor,
)
from repro.core.theory import assumption31_stats
from repro.data import ImageConfig, ImageStream, SyntheticConfig, SyntheticStream
from repro.lab.spec import ExperimentSpec
from repro.launch.mesh import TWO_LEVEL_AXES, make_local_mesh
from repro.models.convnet import ConvConfig, ConvNet
from repro.models.transformer import LM
from repro.optim import OptConfig
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train.step import StepConfig

__all__ = ["RunResult", "run_experiment", "run_matrix"]

# CPU-sized model/data recipes — the matrix multiplies runs, so each run must
# stay tiny (2 cores in CI).  Scaling beyond smoke happens via spec overrides.
_LM_ARCH = ArchConfig(
    name="lab-lm", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64, remat="none",
)
_CONV_CFG = ConvConfig(n_classes=8, widths=(8, 16), blocks_per_stage=1, img_size=16)


@dataclasses.dataclass
class RunResult:
    """One completed experiment: the spec plus everything measured."""

    spec: ExperimentSpec
    records: List[Dict]  # one dict per step
    n_elems: int  # flat gradient length
    entropy_floor: float
    wire: Optional[Dict]  # cost_model.RunWireAccount.to_dict()
    walltime_s: float
    # resilience evidence (DESIGN.md §19): the loop's ReducerHealth record
    # (skipped steps, delays, degradation transitions) plus the number of
    # fatal-crash auto-resumes the harness performed
    health: Optional[Dict] = None

    @property
    def loss_curve(self) -> List[float]:
        return [r["loss"] for r in self.records]

    @property
    def grad_sq_curve(self) -> List[float]:
        return [r["grad_sq"] for r in self.records]

    def final_loss(self, tail: int = 5) -> float:
        tail = min(tail, len(self.records))
        return sum(self.loss_curve[-tail:]) / tail

    def to_dict(self) -> Dict:
        return {
            "spec": self.spec.to_dict(),
            "records": self.records,
            "n_elems": self.n_elems,
            "entropy_floor": self.entropy_floor,
            "final_loss": self.final_loss(),
            "wire": self.wire,
            "walltime_s": round(self.walltime_s, 2),
            "health": self.health,
        }


def _build_model_and_stream(spec: ExperimentSpec):
    if spec.model == "lm":
        model = LM(_LM_ARCH)
        stream = SyntheticStream(SyntheticConfig(
            vocab_size=_LM_ARCH.vocab_size, seq_len=32,
            global_batch=spec.global_batch, seed=1234 + spec.seed))
        return model, stream
    model = ConvNet(_CONV_CFG)
    stream = ImageStream(ImageConfig(
        n_classes=_CONV_CFG.n_classes, img_size=_CONV_CFG.img_size,
        global_batch=spec.global_batch, seed=1234 + spec.seed))
    return model, stream


def _data_axes(spec: ExperimentSpec):
    """The run's data-parallel axes: flat ("data",) or the two-level pair."""
    return TWO_LEVEL_AXES if spec.nodes is not None else ("data",)


def _reducer_config(spec: ExperimentSpec,
                    plan: Optional[faults_mod.FaultPlan]) -> Optional[ReducerConfig]:
    if spec.reducer is None:
        return None
    axis = TWO_LEVEL_AXES if spec.nodes is not None else "data"
    return ReducerConfig(
        kind=spec.reducer, axis=axis, theta=spec.theta,
        quantize=spec.quantize, bucket_bytes=spec.bucket_bytes,
        transport=spec.transport, error_feedback=spec.error_feedback,
        backend=spec.backend, stacked=spec.stacked,
        schedule=spec.exchange_schedule, selector=spec.selector,
        validate=spec.validate, faults=plan,
    )


def _compressor_at(spec: ExperimentSpec, theta: float):
    """The compressor a worker runs at this theta (for probe + wire model)."""
    cfg = FFTCompressorConfig(theta=theta, quantize=spec.quantize,
                              backend=spec.backend, selector=spec.selector)
    if spec.reducer == "fft":
        return FFTCompressor(cfg)
    if spec.reducer == "timedomain":
        return TimeDomainCompressor(cfg)
    if spec.reducer == "terngrad":
        return TernGrad()
    if spec.reducer == "qsgd":
        return QSGD()
    return None


def _payload_bits(spec: ExperimentSpec, theta: float, n_elems: int) -> Optional[float]:
    """Modeled wire payload of one exchange at this theta, over the run's
    bucket layout, priced at the TRANSPORT's payload granularity (monolithic
    for allgather, per-bucket quantizers for sequenced/psum — matches what
    the transport actually ships; ``cost_model.bucketed_payload_bits``).
    Stacked runs bill every bucket at the StackedPayload's padded row width
    (what the single collective actually moves on ragged layouts)."""
    comp = _compressor_at(spec, theta)
    if comp is None or not hasattr(comp, "wire_bits"):
        return None
    if spec.bucket_bytes is None:
        return float(comp.wire_bits(n_elems))
    from repro.comms.bucketing import build_layout

    # price per bucket with the SAME layout the reducer builds
    layout = build_layout(n_elems, spec.bucket_bytes)
    return cost_model.bucketed_payload_bits(
        comp.wire_bits, layout.sizes(), spec.transport,
        stacked=spec.stacked, chunk=layout.chunk)


def run_experiment(spec: ExperimentSpec, verbose: bool = True) -> RunResult:
    """Run one spec end-to-end; returns the recorded evidence."""
    n_devices = len(jax.devices())
    if n_devices < spec.workers:
        raise RuntimeError(
            f"spec {spec.name!r} needs {spec.workers} workers but only "
            f"{n_devices} devices exist; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={spec.workers} before "
            "importing jax (the repro.lab.run CLI does this automatically)")

    model, stream = _build_model_and_stream(spec)
    opt = (OptConfig(kind="sgd", lr=spec.lr, momentum=0.9)
           if spec.opt == "sgd" else OptConfig(kind="adamw", lr=spec.lr))
    plan = (faults_mod.FaultPlan.from_dicts(spec.faults)
            if spec.faults else None)
    reducer = _reducer_config(spec, plan)
    mode = "pjit" if reducer is None else "compressed_dp"
    if spec.nodes is not None:
        step_cfg = StepConfig(mode=mode, reducer=reducer,
                              data_axes=_data_axes(spec))
        mesh = make_local_mesh(
            (spec.nodes, spec.workers // spec.nodes), TWO_LEVEL_AXES)
    else:
        step_cfg = StepConfig(mode=mode, reducer=reducer)
        mesh = make_local_mesh((spec.workers,), ("data",))
    state = init_state(jax.random.PRNGKey(spec.seed), model, opt,
                       error_feedback=spec.error_feedback)
    n_elems = sum(int(l.size) for l in jax.tree_util.tree_leaves(state["params"]))

    schedule = (theta_schedules.make_schedule(**spec.schedule)
                if spec.schedule else None)

    # Assumption 3.1 probe: jitted per distinct quantized theta (bounded by
    # the schedule's value grid, same recompile contract as the train step)
    probe_cache: Dict[float, object] = {}

    def probe_fn(theta: float):
        if theta not in probe_cache:
            comp = _compressor_at(spec, theta)

            def probe(params, batch):
                grads = jax.grad(
                    lambda p: model.loss(p, batch, ctx=None)[0])(params)
                flat, _, _ = flatten_tree(grads)
                flat_hat = comp.decompress(comp.compress(flat))
                return assumption31_stats(flat, flat_hat)

            probe_cache[theta] = jax.jit(probe)
        return probe_cache[theta]

    records: List[Dict] = []
    # payload size depends only on the quantized theta (bounded grid):
    # memoize so the hot loop doesn't rebuild compressor + bucket layout
    payload_cache: Dict[float, Optional[float]] = {}

    def payload_at(theta: float) -> Optional[float]:
        if theta not in payload_cache:
            payload_cache[theta] = _payload_bits(spec, theta, n_elems)
        return payload_cache[theta]

    def hook(step: int, metrics: Dict, state) -> None:
        theta = metrics.get("theta")
        rec = {
            "step": step,
            "loss": metrics["loss"],
            "grad_sq": metrics["grad_norm"] ** 2,
            "theta": theta,
        }
        if "acc" in metrics:
            rec["acc"] = metrics["acc"]
        if "skipped" in metrics:
            rec["skipped"] = metrics["skipped"]
        payload = (payload_at(theta if theta is not None else spec.theta)
                   if spec.reducer is not None else None)
        rec["payload_bits"] = payload
        if payload:
            rec["compression_ratio"] = 32.0 * n_elems / payload
        probeable = (spec.reducer in ("fft", "timedomain")
                     and spec.probe_every
                     and step % spec.probe_every == 0
                     and theta is not None and theta > 0.0)
        if probeable:
            err, norm = probe_fn(theta)(state["params"], stream.batch_at(step))
            rec["err_ratio"] = float(err)
            rec["norm_ratio"] = float(norm)
        records.append(rec)
        if verbose and step % 10 == 0:
            print(f"[lab:{spec.name}] step {step} loss {metrics['loss']:.4f}")

    # crash/resume rows checkpoint into a throwaway dir; a fatal injected
    # crash simulates process death, so the harness restarts ``train_loop``
    # (auto-resume restores the newest checkpoint; the fired-crash set on
    # loop_cfg persists across restarts so each crash fires once)
    ckpt_dir = (tempfile.mkdtemp(prefix=f"lab-{spec.name}-ckpt-")
                if spec.ckpt_every else None)
    loop_cfg = TrainLoopConfig(
        total_steps=spec.steps, log_every=max(spec.steps, 1),
        theta_schedule=schedule, metrics_hook=hook,
        faults=plan, ckpt_dir=ckpt_dir,
        ckpt_every=spec.ckpt_every or 50,
    )
    t0 = time.perf_counter()
    resumes = 0
    try:
        with compat.set_mesh(mesh):
            while True:
                try:
                    out = train_loop(
                        model, opt, step_cfg, mesh, state, stream, loop_cfg)
                    break
                except faults_mod.FatalInjectedCrash as e:
                    resumes += 1
                    if resumes > 8:
                        raise
                    if verbose:
                        print(f"[lab:{spec.name}] {e}; restarting "
                              f"(auto-resume #{resumes})")
                    # simulated process death: the restarted process builds a
                    # fresh init state; restore overwrites it from the newest
                    # checkpoint (or the run restarts from scratch when the
                    # crash predates the first checkpoint)
                    state = init_state(
                        jax.random.PRNGKey(spec.seed), model, opt,
                        error_feedback=spec.error_feedback)
    finally:
        if ckpt_dir is not None:
            shutil.rmtree(ckpt_dir, ignore_errors=True)
    health = dict(out["health"], resumes=resumes)
    walltime = time.perf_counter() - t0

    if plan is not None:
        # rollback/resume re-runs steps, appending duplicate records; keep
        # the LAST record per step (what the committed trajectory saw)
        last = {r["step"]: r for r in records}
        records = [last[s] for s in sorted(last)]

    if schedule is not None:
        # the loop's realized thetas must equal the declarative curve —
        # guards schedule_curve and the loop's quantization from drifting
        expected = theta_schedules.schedule_curve(schedule, spec.steps)
        realized = tuple(r["theta"] for r in records)
        if realized != expected:
            raise RuntimeError(
                f"{spec.name}: realized theta curve diverged from "
                f"schedule_curve: {realized} != {expected}")

    wire = None
    if spec.reducer is not None:
        topology = ((spec.nodes, spec.workers // spec.nodes)
                    if spec.nodes is not None else None)
        wire = cost_model.run_wire_account(
            n_elems, [r["payload_bits"] for r in records],
            spec.transport, spec.workers, topology=topology,
        ).to_dict()

    return RunResult(
        spec=spec, records=records, n_elems=n_elems,
        entropy_floor=stream.entropy_floor(), wire=wire, walltime_s=walltime,
        health=health,
    )


def run_matrix(specs: List[ExperimentSpec], verbose: bool = True) -> Dict[str, RunResult]:
    """Run every spec; returns {spec.name: RunResult} in matrix order."""
    out: Dict[str, RunResult] = {}
    for i, spec in enumerate(specs):
        if verbose:
            print(f"[lab] ({i + 1}/{len(specs)}) {spec.name}")
        out[spec.name] = run_experiment(spec, verbose=verbose)
        if verbose:
            r = out[spec.name]
            print(f"[lab] {spec.name}: final {r.final_loss():.4f} "
                  f"({r.walltime_s:.1f}s)")
    return out
