"""Convergence lab: end-to-end multi-worker validation of the paper's claims.

The lab turns the paper's accuracy statements (Fig. 11/12, Thm 3.4/3.5,
Assumption 3.1) into executable, regression-gated checks:

* ``spec``     — declarative :class:`ExperimentSpec` (model x compressor x
  transport x theta-schedule x worker count) and the smoke/full matrices;
* ``runner``   — drives ``train_loop`` on simulated multi-worker meshes while
  recording per-step loss / grad-energy / compression ratio / modeled wire,
  plus an Assumption 3.1 probe on live gradients;
* ``evaluate`` — asserts the paper's claims against the recorded curves;
* ``report``   — writes ``BENCH_convergence.json`` and the Convergence
  results table in ``docs/EXPERIMENTS.md``;
* ``run``      — ``python -m repro.lab.run [--smoke]`` CLI.

This package must stay import-light: ``run.py`` sets
``--xla_force_host_platform_device_count`` BEFORE the first jax import, so
nothing at package import time may touch jax.  (``spec``/``report`` are
jax-free; import ``runner``/``evaluate`` lazily.)
"""

from repro.lab.spec import ExperimentSpec, smoke_matrix, full_matrix  # noqa: F401

__all__ = ["ExperimentSpec", "smoke_matrix", "full_matrix"]
