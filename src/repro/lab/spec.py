"""Declarative experiment specs for the convergence lab (jax-free module).

An :class:`ExperimentSpec` is the full recipe for one end-to-end training
run: model x compressor x transport x theta-schedule x worker count.  Specs
are plain data (JSON round-trippable) so the whole matrix lands verbatim in
``BENCH_convergence.json`` and a future session can re-run any row.

The *smoke* matrix is the tier-2 CI gate (8 simulated workers, two model
families, every transport); the *full* matrix adds the remaining compressor
baselines, schedules, and worker counts for the manual
``python -m repro.lab.run`` sweep.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

__all__ = ["ExperimentSpec", "smoke_matrix", "full_matrix", "chaos_matrix",
           "group_by_model"]

# mirrors comms/faults.py (VALIDATE_LEVELS / EVENT_KINDS) — this module must
# stay jax-free, so it cannot import the (jnp-using) faults module;
# tests/test_faults.py asserts the mirrors agree
_VALIDATE_LEVELS = ("off", "cheap", "full")
_EVENT_KINDS = ("nan_grad", "payload_corrupt", "step_crash", "slow_worker")


@dataclasses.dataclass
class ExperimentSpec:
    """One end-to-end training run, declaratively.

    ``reducer=None`` is the dense (pjit all-reduce) baseline; everything else
    runs ``compressed_dp`` over a (workers,)-shaped ``data`` mesh.
    ``schedule`` is a ``core.schedules.make_schedule`` description, e.g.
    ``{"kind": "constant", "theta": 0.7}``; ``None`` means no theta schedule
    (the reducer's static theta runs unscheduled — only sensible for dense).
    """

    name: str
    model: str = "lm"  # lm | convnet
    reducer: Optional[str] = "fft"  # None | fft | timedomain | terngrad | qsgd
    # allgather | sequenced | psum | hierarchical | reduce_scatter
    transport: str = "allgather"
    backend: str = "reference"  # reference | pallas | auto (kernels/engine.py)
    bucket_bytes: Optional[int] = None
    theta: float = 0.7
    schedule: Optional[Dict] = None  # make_schedule(**...) description
    workers: int = 8
    steps: int = 50
    global_batch: int = 16
    opt: str = "adamw"  # adamw | sgd (sgd runs momentum 0.9, paper-style)
    lr: float = 3e-3
    seed: int = 0
    quantize: bool = True
    error_feedback: bool = False
    # batched bucket executor (DESIGN.md §14): one collective per exchange;
    # False runs the per-bucket loop (bitwise-identical trajectories)
    stacked: bool = True
    # overlap engine (DESIGN.md §15): exchange dispatch schedule —
    # stacked | streamed | auto.  Named exchange_schedule because `schedule`
    # is this spec's THETA schedule; maps to ReducerConfig.schedule.
    exchange_schedule: str = "stacked"
    # selection engine (DESIGN.md §16): sort | sampled | bisect | auto top-k
    # selector; maps to ReducerConfig.selector
    selector: str = "sort"
    # Assumption 3.1 probe cadence: 1 = every step (smoke default); 0 = off
    probe_every: int = 1
    # two-level topology (DESIGN.md §18): split the workers into this many
    # NVLink-island nodes ((nodes, workers/nodes) x ("node", "local")); the
    # exchange then rides both axes and the hierarchical transports apply.
    # None keeps the flat (workers,) x ("data",) mesh.
    nodes: Optional[int] = None
    # chaos lane (DESIGN.md §19): a deterministic fault plan in its
    # JSON-dict form (``comms.faults.FaultPlan.to_dicts()``) — nan_grad /
    # payload_corrupt events ride the reducer into the jitted step,
    # step_crash / slow_worker fire host-side in the train loop
    faults: Optional[List[Dict]] = None
    # payload validation level on the exchange (ReducerConfig.validate):
    # off | cheap (index bounds + quantizer sanity) | full (+ checksums)
    validate: str = "off"
    # checkpoint cadence for crash/resume rows; 0 = no checkpointing
    ckpt_every: int = 0

    def __post_init__(self):
        if self.model not in ("lm", "convnet"):
            raise ValueError(f"unknown model {self.model!r}")
        # mirrors kernels/engine.BACKEND_NAMES — this module must stay
        # jax-free (importable before device-count env setup), so it cannot
        # import the engine; tests/test_engine.py asserts the lists agree
        if self.backend not in ("reference", "pallas", "auto"):
            raise ValueError(f"unknown backend {self.backend!r}")
        # mirrors comms/scheduler.SCHEDULE_NAMES (same jax-free constraint;
        # tests/test_scheduler.py asserts the lists agree)
        if self.exchange_schedule not in ("stacked", "streamed", "auto"):
            raise ValueError(
                f"unknown exchange_schedule {self.exchange_schedule!r}")
        # mirrors core/selection.SELECTOR_NAMES (same jax-free constraint;
        # tests/test_selection.py asserts the lists agree)
        if self.selector not in ("sort", "sampled", "bisect", "auto"):
            raise ValueError(f"unknown selector {self.selector!r}")
        if self.exchange_schedule == "streamed" and self.transport == "allgather":
            raise ValueError(
                "exchange_schedule='streamed' needs a bucketed transport "
                "(sequenced|psum)")
        if self.nodes is not None and (
                self.nodes < 1 or self.workers % self.nodes):
            raise ValueError(
                f"workers {self.workers} must split evenly into nodes "
                f"{self.nodes}")
        if self.transport == "hierarchical" and self.nodes is None:
            raise ValueError(
                "transport='hierarchical' needs a two-level mesh: set nodes")
        if self.reducer is None and self.schedule is not None:
            raise ValueError("dense baseline cannot take a theta schedule")
        if self.validate not in _VALIDATE_LEVELS:
            raise ValueError(f"unknown validate level {self.validate!r}")
        if self.faults is not None:
            for ev in self.faults:
                if not isinstance(ev, dict) or ev.get("kind") not in _EVENT_KINDS:
                    raise ValueError(f"unknown fault event {ev!r}")
        if self.ckpt_every < 0:
            raise ValueError(f"ckpt_every must be >= 0, got {self.ckpt_every}")
        if self.workers < 1 or self.global_batch % self.workers:
            raise ValueError(
                f"global_batch {self.global_batch} must divide by workers {self.workers}"
            )
        # theta and schedule encode the same knob: where the schedule's
        # initial value is derivable, the static theta must agree, so the
        # artifact's recipe can never contradict what actually ran
        if self.schedule is not None:
            kind = self.schedule.get("kind")
            initial = None
            if kind == "constant":
                initial = self.schedule["theta"]
            elif kind == "step_decay":
                initial = sorted(self.schedule["points"])[0][1]
            elif kind in ("polynomial_decay", "sigmoid_decay"):
                initial = self.schedule["theta0"]
            if initial is not None and abs(self.theta - initial) > 1e-9:
                raise ValueError(
                    f"theta={self.theta} disagrees with the schedule's "
                    f"initial value {initial}; set them equal")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "ExperimentSpec":
        return cls(**d)


def _matrix(model: str, *, workers: int, steps: int, seed: int = 0) -> List[ExperimentSpec]:
    """The per-model claim matrix: dense baseline, the paper's theta points,
    mixed comp, and the transport trio (same config, only transport varies).

    The transport trio runs monolithic payloads (``bucket_bytes=None``): with
    one bucket the per-bucket quantizer fit equals the global fit, so all
    three transports realize the SAME mean and the curves must be identical
    (the equivalence claim).  Bucketed quantized runs differ by design
    (per-bucket ranges) and are exercised by tests/test_transports.py instead.
    """
    base = dict(model=model, workers=workers, steps=steps, seed=seed)
    if model == "convnet":
        # paper-faithful CNN training: momentum SGD (adam's per-coordinate
        # normalization amplifies compression noise on the tiny convnet)
        base.update(opt="sgd", lr=0.1)
    # paper §IV-A1 "mixed comp": high theta early, fully dense late.  The
    # switch sits at one sixth of the run so the dense phase has room to
    # close the early-compression gap within a smoke-sized budget (momentum
    # SGD on the convnet needs most of the run to recover).
    mixed_points = [[0, 0.99], [max(steps // 6, 1), 0.0]]
    specs = [
        ExperimentSpec(name=f"{model}_dense", reducer=None, **base),
        ExperimentSpec(
            name=f"{model}_fft_theta0.7", theta=0.7,
            schedule={"kind": "constant", "theta": 0.7}, **base),
        ExperimentSpec(
            name=f"{model}_fft_theta0.9", theta=0.9,
            schedule={"kind": "constant", "theta": 0.9}, **base),
        ExperimentSpec(
            name=f"{model}_fft_mixed", theta=0.99,
            schedule={"kind": "step_decay", "points": mixed_points}, **base),
    ]
    for transport in ("sequenced", "psum"):
        specs.append(ExperimentSpec(
            name=f"{model}_fft_theta0.7_{transport}", theta=0.7, transport=transport,
            schedule={"kind": "constant", "theta": 0.7}, **base))
    # topology sweep axis (DESIGN.md §18): the theta0.7 config on a
    # (nodes, local) two-level mesh.  hierarchical re-compresses once per
    # island (a SECOND lossy step — island-shared, so still deterministic);
    # reduce_scatter shards the psum over the bucket axis.  The evaluator's
    # hierarchical_matches_flat claim requires both final losses within the
    # flat-psum row's 5% envelope.
    two_level_nodes = max(workers // 2, 1)
    for transport in ("hierarchical", "reduce_scatter"):
        suffix = "hier" if transport == "hierarchical" else "rs"
        specs.append(ExperimentSpec(
            name=f"{model}_fft_theta0.7_{suffix}", theta=0.7,
            transport=transport, nodes=two_level_nodes,
            schedule={"kind": "constant", "theta": 0.7}, **base))
    # backend sweep axis (engine backends, DESIGN.md §13): same config as the
    # theta0.7 row but stages executed by the fused Pallas kernels.  The
    # evaluator's backends_identical claim compares this curve against the
    # reference-backend row — compression must be a pure execution-engine
    # choice, never a numerics choice.
    specs.append(ExperimentSpec(
        name=f"{model}_fft_theta0.7_pallas", theta=0.7, backend="pallas",
        schedule={"kind": "constant", "theta": 0.7}, **base))
    # selection-engine sweep axis (DESIGN.md §16): the theta0.7 config with
    # the O(n) sampled-threshold selector replacing the exact sort.  The
    # evaluator's sampled_selector_matches_sort claim requires this curve to
    # track the sort row within the theta<=0.7 loss tolerance — the selector
    # trades exactness of the kept SET (never payload shape) for speed, so
    # convergence, not bitwise equality, is the contract.
    specs.append(ExperimentSpec(
        name=f"{model}_fft_theta0.7_sampled", theta=0.7, selector="sampled",
        schedule={"kind": "constant", "theta": 0.7}, **base))
    # exchange-schedule sweep axis (overlap engine, DESIGN.md §15): the same
    # bucketed config dispatched stacked (one collective after backprop) vs
    # streamed (readiness-ordered groups interleaved with backprop).  The
    # evaluator's streamed_identical claim requires the two curves BITWISE
    # equal — the schedule is a dispatch-shape choice, never a numerics one.
    for exchange_schedule in ("stacked", "streamed"):
        specs.append(ExperimentSpec(
            name=f"{model}_fft_theta0.7_bucketed_{exchange_schedule}",
            theta=0.7, transport="sequenced", bucket_bytes=4096 * 4,
            exchange_schedule=exchange_schedule,
            schedule={"kind": "constant", "theta": 0.7}, **base))
    return specs


def _chaos_rows(model: str, *, workers: int, steps: int, seed: int = 0) -> List[ExperimentSpec]:
    """The chaos lane (DESIGN.md §19): three fault rows per model, each
    proving one resilience claim against the model's clean theta0.7 row.

    * ``{model}_chaos_nan`` — two workers emit all-NaN gradients at two
      steps; the non-finite guard must skip EXACTLY those steps (bitwise
      clean before the first fault, 5% loss envelope at the end).
    * ``{model}_chaos_crash`` — a fatal crash mid-run with checkpointing;
      the harness restarts ``train_loop`` (auto-resume) and the deduped
      trajectory must be BITWISE identical to the uninterrupted clean row.
    * ``{model}_chaos_corrupt`` — persistent payload corruption on a
      bucketed exchange with ``validate=cheap``; the guard skips every
      corrupted step until the loop walks the degradation ladder, and the
      run still completes.
    """
    base = dict(model=model, workers=workers, steps=steps, seed=seed)
    if model == "convnet":
        base.update(opt="sgd", lr=0.1)
    sched = {"kind": "constant", "theta": 0.7}
    # probes record reconstruction stats, not trajectory — chaos rows skip
    # them (the bitwise claims compare losses, and the probe would fire on
    # skipped steps' params too)
    chaos = dict(theta=0.7, schedule=sched, probe_every=0)
    nan_steps = (steps // 4, steps // 2)
    # a run of corrupted steps long enough to exhaust the loop's skip
    # patience (max_retries=2 -> degrade after 3 consecutive skips)
    corrupt_lo = steps // 3
    corrupt_steps = range(corrupt_lo, corrupt_lo + 6)
    return [
        ExperimentSpec(
            name=f"{model}_chaos_nan",
            faults=[{"kind": "nan_grad", "step": nan_steps[0], "worker": 1},
                    {"kind": "nan_grad", "step": nan_steps[1],
                     "worker": workers - 1}],
            **chaos, **base),
        ExperimentSpec(
            name=f"{model}_chaos_crash", ckpt_every=10,
            faults=[{"kind": "step_crash", "step": (steps * 2) // 3,
                     "fatal": True}],
            **chaos, **base),
        ExperimentSpec(
            name=f"{model}_chaos_corrupt", transport="sequenced",
            bucket_bytes=4096 * 4, validate="cheap",
            faults=[{"kind": "payload_corrupt", "step": s, "worker": 1,
                     "plane": "idx"} for s in corrupt_steps],
            **chaos, **base),
    ]


def chaos_matrix(workers: int = 8) -> List[ExperimentSpec]:
    """The chaos lane plus the clean rows its claims compare against."""
    specs: List[ExperimentSpec] = []
    for model in ("lm", "convnet"):
        base = dict(model=model, workers=workers, steps=50)
        if model == "convnet":
            base.update(opt="sgd", lr=0.1)
        specs.append(ExperimentSpec(
            name=f"{model}_fft_theta0.7", theta=0.7,
            schedule={"kind": "constant", "theta": 0.7}, **base))
        specs += _chaos_rows(model, workers=workers, steps=50)
    return specs


def smoke_matrix(workers: int = 8) -> List[ExperimentSpec]:
    """CI smoke: convnet + tiny transformer, 8 simulated workers."""
    return (_matrix("lm", workers=workers, steps=50)
            + _matrix("convnet", workers=workers, steps=50))


def full_matrix(workers: int = 8) -> List[ExperimentSpec]:
    """The manual sweep: smoke + compressor baselines + extra schedules."""
    specs = smoke_matrix(workers)
    for model, steps in (("lm", 50), ("convnet", 50)):
        base = dict(model=model, workers=workers, steps=steps)
        if model == "convnet":
            base.update(opt="sgd", lr=0.1)
        specs += [
            ExperimentSpec(name=f"{model}_timedomain_theta0.7", reducer="timedomain",
                           theta=0.7, schedule={"kind": "constant", "theta": 0.7}, **base),
            ExperimentSpec(name=f"{model}_terngrad", reducer="terngrad", **base),
            ExperimentSpec(name=f"{model}_qsgd", reducer="qsgd", **base),
            ExperimentSpec(name=f"{model}_fft_thm35", theta=0.5,
                           schedule={"kind": "thm35", "lipschitz": 1.0, "eta": 0.3}, **base),
            ExperimentSpec(name=f"{model}_fft_theta0.7_bucketed_ef", theta=0.7,
                           bucket_bytes=4096 * 4, transport="sequenced",
                           error_feedback=True,
                           schedule={"kind": "constant", "theta": 0.7}, **base),
            # per-bucket loop vs batched executor: trajectories must be
            # bitwise-identical (the stacked executor is a pure launch-count
            # optimization, DESIGN.md §14)
            ExperimentSpec(name=f"{model}_fft_theta0.7_bucketed_looped",
                           theta=0.7, bucket_bytes=4096 * 4,
                           transport="sequenced", stacked=False,
                           schedule={"kind": "constant", "theta": 0.7}, **base),
            # auto policy row (DESIGN.md §15): the cost model picks the
            # dispatch schedule; whatever it picks, the trajectory equals the
            # smoke matrix's stacked/streamed bucketed rows
            ExperimentSpec(name=f"{model}_fft_theta0.7_bucketed_auto",
                           theta=0.7, bucket_bytes=4096 * 4,
                           transport="sequenced", exchange_schedule="auto",
                           schedule={"kind": "constant", "theta": 0.7}, **base),
        ]
    # chaos lane (DESIGN.md §19): the fault rows ride the full sweep too,
    # so BENCH_convergence.json carries the resilience evidence alongside
    # the accuracy claims (their clean comparators are the smoke rows above)
    for model in ("lm", "convnet"):
        specs += _chaos_rows(model, workers=workers, steps=50)
    # worker-count scaling point (claims are worker-count independent);
    # derived from the requested count so e.g. --workers 2 never demands
    # more devices than the CLI pinned
    alt = max(workers // 2, 1)
    if alt != workers:
        specs.append(ExperimentSpec(
            name=f"lm_fft_theta0.7_w{alt}", model="lm", workers=alt, steps=50,
            theta=0.7, schedule={"kind": "constant", "theta": 0.7}))
    return specs


def group_by_model(specs: List[ExperimentSpec]) -> Dict[str, List[ExperimentSpec]]:
    out: Dict[str, List[ExperimentSpec]] = {}
    for s in specs:
        out.setdefault(s.model, []).append(s)
    return out
