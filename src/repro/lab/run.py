"""CLI: run the convergence lab matrix and write the report artifacts.

    PYTHONPATH=src python -m repro.lab.run --smoke          # tier-2 CI matrix
    PYTHONPATH=src python -m repro.lab.run                  # full matrix
    PYTHONPATH=src python -m repro.lab.run --smoke --workers 4

Simulated multi-worker: the requested worker count is forced via
``--xla_force_host_platform_device_count`` which must be set BEFORE jax's
first import — so this module parses args and patches the environment before
importing the (jax-heavy) runner.  Exit status is nonzero when any paper
claim fails, which is what gates the CI ``lab-smoke`` job.
"""

from __future__ import annotations

import argparse
import os
import re
import sys

_COUNT_FLAG = re.compile(r"--xla_force_host_platform_device_count=(\d+)")


def _ensure_devices(workers: int) -> None:
    if "jax" in sys.modules:
        import jax

        if len(jax.devices()) < workers:
            raise RuntimeError(
                f"jax already imported with {len(jax.devices())} devices; "
                f"need {workers}. Run via `python -m repro.lab.run` in a "
                "fresh process.")
        return
    flags = os.environ.get("XLA_FLAGS", "")
    m = _COUNT_FLAG.search(flags)
    if m is None:
        flags = (flags + f" --xla_force_host_platform_device_count={workers}").strip()
    elif int(m.group(1)) < workers:
        # an inherited smaller pin would starve the mesh — raise it
        flags = _COUNT_FLAG.sub(
            f"--xla_force_host_platform_device_count={workers}", flags)
    os.environ["XLA_FLAGS"] = flags


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description="convergence lab matrix")
    p.add_argument("--smoke", action="store_true",
                   help="CI smoke matrix (convnet + tiny LM, all transports)")
    p.add_argument("--chaos", action="store_true",
                   help="chaos lane only (DESIGN.md §19): fault rows + their "
                        "clean comparators, judged by the resilience claims")
    p.add_argument("--workers", type=int, default=8,
                   help="simulated worker count (default 8)")
    p.add_argument("--out", default=None,
                   help="JSON artifact path (default BENCH_convergence.json; "
                        "BENCH_chaos.json with --chaos)")
    p.add_argument("--docs", default="docs/EXPERIMENTS.md",
                   help="EXPERIMENTS.md to splice the results table into "
                        "('skip' to disable)")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)

    _ensure_devices(args.workers)
    if args.out is None:
        args.out = "BENCH_chaos.json" if args.chaos else "BENCH_convergence.json"

    # jax-touching imports only AFTER the device count is pinned
    from repro.lab import report, spec
    from repro.lab.evaluate import chaos_claims, evaluate_results
    from repro.lab.runner import run_matrix

    if args.chaos:
        matrix = spec.chaos_matrix(args.workers)
    elif args.smoke:
        matrix = spec.smoke_matrix(args.workers)
    else:
        matrix = spec.full_matrix(args.workers)
    results = run_matrix(matrix, verbose=not args.quiet)
    runs = {name: r.to_dict() for name, r in results.items()}
    if args.chaos:
        # chaos lane: only the resilience claims apply (the accuracy claims
        # need the full accuracy rows, which this lane deliberately skips)
        claims = chaos_claims(runs)
        all_passed = bool(claims) and all(c.passed for c in claims)
    else:
        claims, all_passed = evaluate_results(runs)

    report.write_json(args.out, runs, [c.to_dict() for c in claims], all_passed)
    print(f"[lab] wrote {args.out}")
    if args.chaos and args.docs == "docs/EXPERIMENTS.md":
        args.docs = "skip"  # the chaos lane never rewrites the results table
    if args.docs != "skip":
        block = report.render_markdown(runs, [c.to_dict() for c in claims], all_passed)
        if report.splice_experiments_md(args.docs, block):
            print(f"[lab] updated {args.docs}")
        else:
            print(f"[lab] marker not found in {args.docs}; table not spliced")

    for c in claims:
        print(f"[lab] {'PASS' if c.passed else 'FAIL'} {c.name}: {c.detail}")
    print(f"[lab] {'ALL CLAIMS PASS' if all_passed else 'CLAIM FAILURES'}")
    return 0 if all_passed else 1


if __name__ == "__main__":
    sys.exit(main())
