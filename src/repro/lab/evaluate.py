"""Evaluator: the paper's accuracy claims as executable checks.

Consumes the JSON form of lab runs (``RunResult.to_dict()``) so the same code
evaluates a live matrix and a loaded ``BENCH_convergence.json``.  Claims per
model family (paper sections in brackets):

* ``theta0.7_matches_dense`` — static theta <= 0.7 reaches a final loss within
  ``loss_tol`` (5%) of the dense baseline [Fig. 11, Thm 3.4].
* ``theta0.9_degrades`` — static theta = 0.9 lands measurably above the
  theta = 0.7 run [Fig. 11's degradation, Thm 3.4's theta^2 noise ball].
* ``mixed_recovers`` — the "mixed comp" schedule (high theta early, 0 late)
  recovers to within ``loss_tol`` of dense [§IV-A1, Thm 3.5].
* ``transports_identical`` — runs differing ONLY in transport trace identical
  loss curves to ``transport_atol`` (they compute the same mean; DESIGN.md §9).
* ``backends_identical`` — runs differing ONLY in engine backend (reference
  jnp vs fused Pallas kernels) trace identical loss curves to
  ``backend_atol`` (codes are bitwise-equal across backends and the exchange
  path shares the spectral decompress, DESIGN.md §13 — backend choice is a
  pure execution-engine knob, never a numerics knob).
* ``streamed_identical`` — runs differing ONLY in exchange dispatch schedule
  (stacked single collective vs backprop-interleaved readiness streaming,
  DESIGN.md §15) trace BITWISE-identical loss curves (atol 0 on CPU: the
  schedule reorders dispatch, never arithmetic).
* ``hierarchical_matches_flat`` — the two-level-topology rows (DESIGN.md
  §18: hierarchical re-compresses once per island — a second, island-shared
  lossy step — and reduce_scatter shards the psum over the bucket axis)
  reach final losses within ``loss_tol`` of the flat psum row.  Convergence
  equivalence, not bitwise: the node-level re-compression is lossy by
  design.
* ``sampled_selector_matches_sort`` — runs differing ONLY in top-k selector
  (exact sort vs O(n) sampled threshold, DESIGN.md §16) reach final losses
  within ``loss_tol`` of each other: the selector perturbs the kept set by a
  few near-tau coefficients, so the claim is convergence-equivalence under
  the same tolerance the theta<=0.7 compression claim uses, not bitwise.
* ``assumption31`` — every probed step's live-gradient reconstruction obeys
  ``err <= 1.05*sqrt(theta) + quant_margin`` (the provable sqrt(theta) energy
  bound of DESIGN.md §6 plus the range-quantizer's relative-error envelope),
  checked through ``assumption31_holds_stats``.
* ``thm34_envelope`` — the measured min-so-far gradient energy stays under the
  Thm 3.4 bound evaluated with plug-in constants estimated from the same
  curve (``core.theory.estimate_curve_constants``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.theory import (
    assumption31_holds_stats,
    curves_close,
    estimate_curve_constants,
    thm34_envelope,
)

__all__ = ["Claim", "Tolerances", "evaluate_results", "chaos_claims"]


@dataclasses.dataclass
class Claim:
    name: str
    passed: bool
    detail: str

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Tolerances:
    loss_tol: float = 0.05  # "within 5% of dense"
    degrade_margin: float = 0.01  # theta=0.9 must sit >=1% above theta=0.7
    transport_atol: float = 1e-5  # pointwise curve divergence across transports
    backend_atol: float = 1e-4  # pointwise curve divergence across engine backends
    schedule_atol: float = 0.0  # streamed vs stacked dispatch: bitwise on CPU
    a31_sqrt_slack: float = 1.05  # on the provable sqrt(theta) energy bound
    a31_quant_margin: float = 0.15  # additive headroom for the 8-bit quantizer
    a31_norm_tol: float = 0.08  # ||v_hat||/||v|| headroom under quantization
    thm34_slack: float = 1.0
    final_tail: int = 5  # final loss = mean of the last N recorded steps


def _final(run: Dict, tail: int) -> float:
    curve = [r["loss"] for r in run["records"]]
    tail = min(tail, len(curve))
    return sum(curve[-tail:]) / tail


def _loss_curve(run: Dict) -> List[float]:
    return [r["loss"] for r in run["records"]]


def _models(runs: Dict[str, Dict]) -> List[str]:
    return sorted({r["spec"]["model"] for r in runs.values()})


def _named(runs: Dict[str, Dict], name: str) -> Optional[Dict]:
    return runs.get(name)


def _rel_gap(x: float, base: float) -> float:
    return (x - base) / max(abs(base), 1e-9)


def chaos_claims(
    runs: Dict[str, Dict], tol: Tolerances = Tolerances()
) -> List[Claim]:
    """The resilience claims (DESIGN.md §19), emitted ONLY for models whose
    chaos rows are present — a matrix without fault rows gets no chaos
    claims (so fabricated evaluator fixtures and pre-chaos artifacts keep
    evaluating cleanly)."""
    claims: List[Claim] = []

    def claim(name: str, passed: bool, detail: str) -> None:
        claims.append(Claim(name, bool(passed), detail))

    for m in _models(runs):
        has_chaos = any(f"{m}_chaos_{k}" in runs
                        for k in ("nan", "crash", "corrupt"))
        if not has_chaos:
            continue
        clean = _named(runs, f"{m}_fft_theta0.7")

        # -- nan_step_skipped_matches_clean --------------------------------
        nan_run = _named(runs, f"{m}_chaos_nan")
        if nan_run and clean:
            health = nan_run.get("health") or {}
            nan_steps = sorted({ev["step"]
                                for ev in (nan_run["spec"].get("faults") or [])
                                if ev.get("kind") == "nan_grad"})
            skip_steps = health.get("skip_steps", [])
            exact = skip_steps == nan_steps
            cl, ch = _loss_curve(clean), _loss_curve(nan_run)
            first = nan_steps[0] if nan_steps else len(ch)
            prefix_bitwise = cl[:first] == ch[:first] and first > 0
            fc, fn = _final(clean, tol.final_tail), _final(nan_run, tol.final_tail)
            gap = _rel_gap(fn, fc)
            claim(f"{m}:nan_step_skipped_matches_clean",
                  exact and prefix_bitwise and gap <= tol.loss_tol,
                  f"guard skipped steps {skip_steps} (planned {nan_steps}); "
                  f"pre-fault curve bitwise equal: {prefix_bitwise}; final "
                  f"clean {fc:.4f} vs chaos {fn:.4f} (gap {gap:+.2%}, "
                  f"tol {tol.loss_tol:.0%})")
        elif nan_run:
            claim(f"{m}:nan_step_skipped_matches_clean", False,
                  "missing clean theta0.7 comparator run")

        # -- crash_resume_bitwise ------------------------------------------
        crash_run = _named(runs, f"{m}_chaos_crash")
        if crash_run and clean:
            health = crash_run.get("health") or {}
            resumes = health.get("resumes", 0)
            cl, ch = _loss_curve(clean), _loss_curve(crash_run)
            bitwise = cl == ch and len(ch) > 0
            claim(f"{m}:crash_resume_bitwise",
                  resumes >= 1 and bitwise,
                  f"{resumes} auto-resume(s); kill+resume trajectory bitwise "
                  f"equal to the uninterrupted run: {bitwise} "
                  f"({len(ch)} vs {len(cl)} steps)")
        elif crash_run:
            claim(f"{m}:crash_resume_bitwise", False,
                  "missing clean theta0.7 comparator run")

        # -- corrupt_payload_detected_and_degraded -------------------------
        corrupt_run = _named(runs, f"{m}_chaos_corrupt")
        if corrupt_run:
            health = corrupt_run.get("health") or {}
            spec = corrupt_run["spec"]
            corrupt_steps = sorted({ev["step"]
                                    for ev in (spec.get("faults") or [])
                                    if ev.get("kind") == "payload_corrupt"})
            skip_steps = health.get("skip_steps", [])
            detected = (len(skip_steps) > 0
                        and set(skip_steps) <= set(corrupt_steps))
            transitions = health.get("transitions", [])
            completed = (len(corrupt_run["records"]) == spec["steps"]
                         and math.isfinite(_final(corrupt_run, tol.final_tail)))
            claim(f"{m}:corrupt_payload_detected_and_degraded",
                  detected and len(transitions) > 0 and completed,
                  f"validation caught {len(skip_steps)} corrupted step(s) "
                  f"{skip_steps} of planned {corrupt_steps}; ladder "
                  f"transitions {[t['rung'] for t in transitions]}; run "
                  f"completed: {completed}")
    return claims


def evaluate_results(
    runs: Dict[str, Dict], tol: Tolerances = Tolerances()
) -> Tuple[List[Claim], bool]:
    """Evaluate every claim against a {name: RunResult.to_dict()} matrix."""
    claims: List[Claim] = []

    def claim(name: str, passed: bool, detail: str) -> None:
        claims.append(Claim(name, bool(passed), detail))

    for m in _models(runs):
        dense = _named(runs, f"{m}_dense")
        t07 = _named(runs, f"{m}_fft_theta0.7")
        t09 = _named(runs, f"{m}_fft_theta0.9")
        mixed = _named(runs, f"{m}_fft_mixed")

        if dense and t07:
            fd, f7 = _final(dense, tol.final_tail), _final(t07, tol.final_tail)
            gap = _rel_gap(f7, fd)
            claim(f"{m}:theta0.7_matches_dense", gap <= tol.loss_tol,
                  f"final dense {fd:.4f} vs theta0.7 {f7:.4f} (gap {gap:+.2%}, "
                  f"tol {tol.loss_tol:.0%})")
        else:
            claim(f"{m}:theta0.7_matches_dense", False, "missing dense/theta0.7 run")

        if t07 and t09:
            f7, f9 = _final(t07, tol.final_tail), _final(t09, tol.final_tail)
            gap = _rel_gap(f9, f7)
            claim(f"{m}:theta0.9_degrades", gap >= tol.degrade_margin,
                  f"final theta0.9 {f9:.4f} vs theta0.7 {f7:.4f} (gap {gap:+.2%}, "
                  f"needs >= {tol.degrade_margin:+.0%})")
        else:
            claim(f"{m}:theta0.9_degrades", False, "missing theta0.9/theta0.7 run")

        if dense and mixed:
            fd, fm = _final(dense, tol.final_tail), _final(mixed, tol.final_tail)
            gap = _rel_gap(fm, fd)
            claim(f"{m}:mixed_recovers", gap <= tol.loss_tol,
                  f"final dense {fd:.4f} vs mixed {fm:.4f} (gap {gap:+.2%}, "
                  f"tol {tol.loss_tol:.0%})")
        else:
            claim(f"{m}:mixed_recovers", False, "missing dense/mixed run")

        trio = [t07] + [
            _named(runs, f"{m}_fft_theta0.7_{t}") for t in ("sequenced", "psum")
        ]
        if all(trio):
            worst = 0.0
            ok = True
            base_curve = _loss_curve(trio[0])
            for other in trio[1:]:
                close, div = curves_close(
                    base_curve, _loss_curve(other), tol.transport_atol)
                ok &= close
                worst = max(worst, div)
            claim(f"{m}:transports_identical", ok,
                  f"max pointwise loss divergence across "
                  f"allgather/sequenced/psum: {worst:.2e} (atol {tol.transport_atol})")
        else:
            claim(f"{m}:transports_identical", False, "missing transport trio")

        # topology axis (DESIGN.md §18): two-level transports vs flat psum.
        # One-sided like the dense claim — landing BELOW the flat row is fine.
        psum_run = _named(runs, f"{m}_fft_theta0.7_psum")
        hier = _named(runs, f"{m}_fft_theta0.7_hier")
        rs = _named(runs, f"{m}_fft_theta0.7_rs")
        if psum_run and hier and rs:
            fp = _final(psum_run, tol.final_tail)
            fh = _final(hier, tol.final_tail)
            fr = _final(rs, tol.final_tail)
            gap_h, gap_r = _rel_gap(fh, fp), _rel_gap(fr, fp)
            claim(f"{m}:hierarchical_matches_flat",
                  gap_h <= tol.loss_tol and gap_r <= tol.loss_tol,
                  f"final flat psum {fp:.4f} vs hierarchical {fh:.4f} "
                  f"(gap {gap_h:+.2%}) / reduce_scatter {fr:.4f} "
                  f"(gap {gap_r:+.2%}); tol {tol.loss_tol:.0%}")
        else:
            claim(f"{m}:hierarchical_matches_flat", False,
                  "missing psum/hier/rs topology rows")

        pallas = _named(runs, f"{m}_fft_theta0.7_pallas")
        if t07 and pallas:
            close, div = curves_close(
                _loss_curve(t07), _loss_curve(pallas), tol.backend_atol)
            claim(f"{m}:backends_identical", close,
                  f"max pointwise loss divergence reference vs pallas "
                  f"backend: {div:.2e} (atol {tol.backend_atol})")
        else:
            claim(f"{m}:backends_identical", False, "missing pallas-backend run")

        # selection engine (DESIGN.md §16): the sampled selector changes the
        # kept SET (a few near-tau coefficients), not the payload shape, so
        # the contract is convergence within the theta<=0.7 loss tolerance —
        # the same envelope the compression itself gets — not bitwise curves.
        sampled = _named(runs, f"{m}_fft_theta0.7_sampled")
        if t07 and sampled:
            f7 = _final(t07, tol.final_tail)
            fs = _final(sampled, tol.final_tail)
            gap = _rel_gap(fs, f7)
            claim(f"{m}:sampled_selector_matches_sort", gap <= tol.loss_tol,
                  f"final sort-selector {f7:.4f} vs sampled {fs:.4f} "
                  f"(gap {gap:+.2%}, tol {tol.loss_tol:.0%})")
        else:
            claim(f"{m}:sampled_selector_matches_sort", False,
                  "missing sampled-selector run")

        b_stacked = _named(runs, f"{m}_fft_theta0.7_bucketed_stacked")
        b_streamed = _named(runs, f"{m}_fft_theta0.7_bucketed_streamed")
        if b_stacked and b_streamed:
            close, div = curves_close(
                _loss_curve(b_stacked), _loss_curve(b_streamed),
                tol.schedule_atol)
            claim(f"{m}:streamed_identical", close,
                  f"max pointwise loss divergence stacked vs streamed "
                  f"dispatch: {div:.2e} (atol {tol.schedule_atol}, bitwise)")
        else:
            claim(f"{m}:streamed_identical", False,
                  "missing bucketed stacked/streamed run pair")

        # -- Assumption 3.1 on live gradients (all probed compressed runs) --
        probed = worst_a31 = 0
        a31_ok, a31_detail = True, []
        for name, run in runs.items():
            if run["spec"]["model"] != m or run["spec"].get("reducer") not in (
                    "fft", "timedomain"):
                continue
            quantized = run["spec"].get("quantize", True)
            margin = tol.a31_quant_margin if quantized else 0.0
            norm_tol = tol.a31_norm_tol if quantized else 1e-4
            for rec in run["records"]:
                if "err_ratio" not in rec:
                    continue
                probed += 1
                theta = rec["theta"]
                # the provable bound is sqrt(theta) (DESIGN.md §6); express it
                # through the paper's slack*theta form
                slack = (tol.a31_sqrt_slack * math.sqrt(theta) + margin) / theta
                if not assumption31_holds_stats(
                        rec["err_ratio"], rec["norm_ratio"], theta, slack, norm_tol):
                    a31_ok = False
                    worst_a31 += 1
                    if len(a31_detail) < 3:
                        a31_detail.append(
                            f"{name}@{rec['step']}: err {rec['err_ratio']:.3f} "
                            f"norm {rec['norm_ratio']:.3f} theta {theta}")
        claim(f"{m}:assumption31", a31_ok and probed > 0,
              f"{probed} probed steps, {worst_a31} violations"
              + (f" ({'; '.join(a31_detail)})" if a31_detail else ""))

        # -- Thm 3.4 envelope on every run of this model --
        env_ok, env_detail = True, []
        for name, run in runs.items():
            if run["spec"]["model"] != m:
                continue
            spec = run["spec"]
            # guard-skipped steps committed no update and their measured
            # gradient energy is the POISONED gradient's (NaN by design on
            # nan_grad rows) — the envelope bounds the committed trajectory
            recs = [r for r in run["records"] if not r.get("skipped")]
            loss = [r["loss"] for r in recs]
            gsq = [r["grad_sq"] for r in recs]
            thetas = [r["theta"] or 0.0 for r in recs]
            constants = estimate_curve_constants(
                loss, gsq, eta=spec["lr"], batch=spec["global_batch"],
                fstar=run.get("entropy_floor", 0.0))
            env = thm34_envelope(
                gsq, constants, eta=spec["lr"], theta=max(thetas),
                batch=spec["global_batch"], slack=tol.thm34_slack)
            if not env.holds:
                env_ok = False
                if len(env_detail) < 3:
                    worst = max(
                        ms - b for ms, b in zip(env.min_so_far, env.bounds))
                    env_detail.append(f"{name}: exceeds bound by {worst:.3g}")
        claim(f"{m}:thm34_envelope", env_ok,
              "measured min grad-energy under the plug-in Thm 3.4 bound"
              + (f" EXCEPT {'; '.join(env_detail)}" if env_detail else ""))

    claims += chaos_claims(runs, tol)
    return claims, all(c.passed for c in claims)
