"""Report writer: BENCH_convergence.json + the EXPERIMENTS.md table.

Jax-free on purpose (importable before device-count env setup).  The
markdown splice follows the same marker convention as
``benchmarks/make_report.py``: everything between ``<!-- CONVERGENCE_TABLE -->``
and the next ``## `` section header is regenerated in place.
"""

from __future__ import annotations

import json
from typing import Dict, List

__all__ = ["write_json", "render_markdown", "splice_experiments_md", "MARKER"]

MARKER = "<!-- CONVERGENCE_TABLE -->"


def write_json(path: str, runs: Dict[str, Dict], claims: List[Dict],
               all_passed: bool) -> None:
    """BENCH_convergence.json: full matrix evidence + claim verdicts."""
    payload = {
        "bench": "convergence_lab",
        "all_claims_passed": bool(all_passed),
        "claims": claims,
        "runs": runs,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")


def _fmt_schedule(spec: Dict) -> str:
    sched = spec.get("schedule")
    if spec.get("reducer") is None:
        return "—"
    if sched is None:
        return "static"
    if sched["kind"] == "constant":
        return f"θ={sched['theta']}"
    if sched["kind"] == "step_decay":
        pts = sched["points"]
        return "→".join(f"{v}" for _, v in pts)
    return sched["kind"]


def _fmt_ratio(run: Dict) -> str:
    recs = [r for r in run["records"] if r.get("compression_ratio")]
    if not recs:
        return "—"
    mean = sum(r["compression_ratio"] for r in recs) / len(recs)
    return f"{mean:.1f}×"


def _fmt_wire(run: Dict) -> str:
    wire = run.get("wire")
    if not wire or not wire.get("compressed_bits"):
        return "—"
    return f"{wire['savings']:.1f}×"


def render_markdown(runs: Dict[str, Dict], claims: List[Dict],
                    all_passed: bool) -> str:
    """The Convergence results block: run table + claim checklist."""
    lines = [
        "| experiment | reducer | transport | backend | θ-schedule | final loss | Δ vs dense | comp. | wire sav. | steps·workers |",
        "|---|---|---|---|---|---:|---:|---:|---:|---|",
    ]
    dense_final = {
        run["spec"]["model"]: run["final_loss"]
        for run in runs.values() if run["spec"]["reducer"] is None
    }
    for name in sorted(runs):
        run = runs[name]
        spec = run["spec"]
        base = dense_final.get(spec["model"])
        delta = ("—" if base is None or spec["reducer"] is None
                 else f"{run['final_loss'] - base:+.4f}")
        lines.append(
            f"| {name} | {spec['reducer'] or 'dense'} | "
            f"{spec['transport'] if spec['reducer'] else '—'} | "
            f"{spec.get('backend', 'reference') if spec['reducer'] else '—'} | "
            f"{_fmt_schedule(spec)} | {run['final_loss']:.4f} | {delta} | "
            f"{_fmt_ratio(run)} | {_fmt_wire(run)} | "
            f"{spec['steps']}·{spec['workers']} |")
    lines.append("")
    lines.append(f"**Claims ({'all pass' if all_passed else 'FAILURES'}):**")
    lines.append("")
    for c in claims:
        mark = "✅" if c["passed"] else "❌"
        lines.append(f"- {mark} `{c['name']}` — {c['detail']}")
    return "\n".join(lines) + "\n"


def splice_experiments_md(exp_path: str, block: str) -> bool:
    """Replace the marker..next-section region of EXPERIMENTS.md in place.

    Returns False (no write) when the marker is absent — callers running
    against a scratch docs tree shouldn't invent structure.
    """
    with open(exp_path) as f:
        text = f.read()
    if MARKER not in text:
        return False
    head, _, tail = text.partition(MARKER)
    nxt = tail.find("\n## ")
    tail2 = tail[nxt:] if nxt != -1 else "\n"
    with open(exp_path, "w") as f:
        f.write(head + MARKER + "\n\n" + block + tail2)
    return True
