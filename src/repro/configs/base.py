"""Architecture + shape configuration system.

One :class:`ArchConfig` describes any of the 10 assigned architectures (plus
the paper-era convnet); :class:`ShapeConfig` describes the 4 assigned input
shapes.  ``registry.build(config)`` assembles the model; ``launch/dryrun.py``
iterates the (arch x shape x mesh) grid.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    rope_theta: float = 1e4
    qkv_bias: bool = False
    attn_softcap: float = 0.0  # gemma2: 50.0
    final_softcap: float = 0.0  # gemma2: 30.0
    sliding_window: int = 0  # mixtral / gemma2 local layers
    local_global_period: int = 0  # gemma2: 2 -> [local, global] alternating
    mlp_activation: str = "swiglu"  # swiglu | geglu | relu

    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    moe_group_size: int = 512
    moe_capacity_factor: float = 1.25
    router_normalize_topk: bool = True

    # ssm / hybrid (hymba)
    ssm_state: int = 0
    ssm_conv_width: int = 4
    ssm_expand: int = 2

    # xlstm
    slstm_every: int = 0  # every k-th layer is sLSTM (0 = none)
    xlstm_proj_factor: float = 2.0

    # enc-dec / cross-attn
    n_encoder_layers: int = 0
    cross_attn_period: int = 0  # llama-vision: every 5th decoder layer

    # modality frontend STUB (per instructions: precomputed embeddings)
    frontend: str = "none"  # none | audio_frames | vision_patches
    n_frontend_tokens: int = 0

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    remat: str = "full"  # full | dots | none
    scan_layers: bool = True  # False: unrolled python loop (dry-run cost samples)
    ce_chunk: int = 512  # chunked cross-entropy: seq positions per unembed tile
    attn_q_chunk: int = 512  # flash tile sizes (working-set knob; §Perf)
    attn_kv_chunk: int = 1024

    # ----- derived ---------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_pattern(self) -> Tuple[str, ...]:
        """The repeating group of layer kinds the stack scans over."""
        if self.n_encoder_layers:  # enc-dec: every decoder layer has cross-attn
            return ("dec_cross_mlp",)
        if self.family == "ssm":  # xlstm
            period = self.slstm_every or self.n_layers + 1
            return tuple(
                "slstm" if (i + 1) % period == 0 else "mlstm" for i in range(period)
            )
        if self.family == "hybrid":
            return ("hybrid",)
        mlp = "moe" if self.n_experts else "mlp"
        if self.local_global_period:
            return tuple(
                f"attn_local_{mlp}" if i % self.local_global_period == 0 else f"attn_{mlp}"
                for i in range(self.local_global_period)
            )
        if self.cross_attn_period:
            group = [f"attn_{mlp}"] * (self.cross_attn_period - 1) + [f"cross_attn_{mlp}"]
            return tuple(group)
        if self.sliding_window:
            return (f"attn_local_{mlp}",)
        return (f"attn_{mlp}",)

    def n_groups(self) -> int:
        pattern = self.layer_pattern()
        assert self.n_layers % len(pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(pattern)}"
        )
        return self.n_layers // len(pattern)

    # ----- parameter accounting (roofline MODEL_FLOPS) ---------------------
    def _layer_params(self, kind: str) -> int:
        d, f = self.d_model, self.d_ff
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.qkv_bias:
            attn += self.q_dim + 2 * self.kv_dim
        glu_mult = 3 if self.mlp_activation in ("swiglu", "geglu") else 2
        mlp = glu_mult * d * f
        norms = 2 * d
        if kind.startswith("cross_attn"):
            attn *= 2  # self + cross
            norms += d
        if kind.endswith("moe"):
            mlp = self.n_experts * glu_mult * d * f + d * self.n_experts
        if kind == "hybrid":
            d_inner = self.ssm_expand * d
            ssm = (
                d * 2 * d_inner  # in_proj (x, z)
                + d_inner * self.ssm_conv_width  # conv
                + d_inner * (2 * self.ssm_state + 1)  # B, C, dt
                + d_inner * self.ssm_state  # A
                + d_inner * d  # out_proj
            )
            return attn + ssm + mlp + norms + d
        if kind == "mlstm":
            di = int(self.xlstm_proj_factor * d)
            return 2 * d * di + 3 * di * di + 3 * di + di * d + 2 * d
        if kind == "slstm":
            return 8 * d * d + 4 * d + 4 * d * d + 2 * d
        return attn + mlp + norms

    def param_count(self) -> int:
        n = self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        pattern = self.layer_pattern()
        for kind in pattern:
            n += self._layer_params(kind) * self.n_groups()
        n += self.d_model  # final norm
        # encoder stack (enc-dec): self-attn + mlp per layer, plus decoders'
        # cross-attn already counted via cross pattern when set
        if self.n_encoder_layers:
            enc_layer = self._layer_params("attn_mlp")
            n += self.n_encoder_layers * enc_layer
            # decoder cross-attn blocks (one per decoder layer for enc-dec)
            n += self.n_layers * (
                self.d_model * (self.q_dim + 2 * self.kv_dim)
                + self.q_dim * self.d_model
                + self.d_model
            )
        return n

    def active_param_count(self) -> int:
        """MoE: experts_per_token/n_experts of expert params are active."""
        if not self.n_experts:
            return self.param_count()
        glu_mult = 3 if self.mlp_activation in ("swiglu", "geglu") else 2
        expert_params = self.n_layers * self.n_experts * glu_mult * self.d_model * self.d_ff
        active_experts = self.n_layers * self.experts_per_token * glu_mult * self.d_model * self.d_ff
        return self.param_count() - expert_params + active_experts

    # ----- smoke-test reduction --------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        pattern_len = len(self.layer_pattern())
        return dataclasses.replace(
            self,
            n_layers=pattern_len * 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.n_experts else 0,
            moe_group_size=32,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            n_frontend_tokens=16 if self.frontend != "none" else 0,
            remat="none",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
