"""xlstm-1.3b [ssm]: 48L, d_model=2048, 4H, d_ff=0 (no separate FFN; blocks
carry internal up-projections), vocab=50304 — sLSTM + mLSTM blocks (7:1).
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm_1_3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    xlstm_proj_factor=2.0,
)
