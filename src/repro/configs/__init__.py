"""One config module per assigned architecture (+ the paper-era convnet)."""
