"""seamless-m4t-large-v2 [audio]: enc-dec, 24L, d_model=1024, 16H (kv=16),
d_ff=8192, vocab=256206.  [arXiv:2308.11596; hf]
Audio frontend is a STUB: input_specs provides precomputed frame embeddings.
RoPE replaces the original relative bias (DESIGN.md §7)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless_m4t_large_v2",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    mlp_activation="relu",
    frontend="audio_frames",
)
