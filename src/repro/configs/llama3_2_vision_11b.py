"""llama-3.2-vision-11b [vlm]: 40L, d_model=4096, 32H (GQA kv=8), d_ff=14336,
vocab=128256 — cross-attn image layers every 5th.  Vision frontend is a STUB
(precomputed patch embeddings).  [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3_2_vision_11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=5e5,
    cross_attn_period=5,
    frontend="vision_patches",
    n_frontend_tokens=1601,
)
