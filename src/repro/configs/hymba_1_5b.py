"""hymba-1.5b [hybrid]: 32L, d_model=1600, 25H (GQA kv=5), d_ff=5504,
vocab=32001, ssm_state=16 — parallel attention + mamba heads.
[arXiv:2411.13676; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba_1_5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
)
