"""qwen3-moe-235b-a22b [moe]: 94L, d_model=4096, 64H (GQA kv=4), d_ff=1536
(per-expert), vocab=151936 — 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_moe_235b_a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    rope_theta=1e6,
    n_experts=128,
    experts_per_token=8,
)
