"""gemma2-2b [dense]: 26L, d_model=2304, 8H (GQA kv=4), d_ff=9216,
vocab=256000 — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2_2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    mlp_activation="geglu",
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    local_global_period=2,
    tie_embeddings=True,
)
