"""Optimizers built from scratch (no optax): SGD+momentum, AdamW, clipping."""

from repro.optim.optimizers import OptConfig, init_opt_state, apply_updates
from repro.optim.clipping import clip_by_global_norm
from repro.optim import lr_schedules

__all__ = ["OptConfig", "init_opt_state", "apply_updates",
           "clip_by_global_norm", "lr_schedules"]
