"""SGD(+momentum) and AdamW, pytree-native.

Optimizer state mirrors the parameter pytree (and inherits its sharding under
pjit — momentum/Adam moments are sharded exactly like their parameters, which
is what makes the 235B config fit: 12 bytes/param spread over all 256 chips).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "apply_updates"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # adamw | sgd
    lr: float = 3e-4  # base lr; schedule multiplies
    momentum: float = 0.9  # sgd
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0


def init_opt_state(config: OptConfig, params) -> Dict[str, Any]:
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
    if config.kind == "sgd":
        return {"mu": zeros(), "count": jnp.zeros((), jnp.int32)}
    if config.kind == "adamw":
        return {"mu": zeros(), "nu": zeros(), "count": jnp.zeros((), jnp.int32)}
    raise ValueError(f"unknown optimizer {config.kind!r}")


def apply_updates(
    config: OptConfig, params, grads, state, lr_scale=1.0
) -> Tuple[Any, Dict[str, Any]]:
    """Returns (new_params, new_state). lr_scale: schedule multiplier."""
    count = state["count"] + 1
    lr = config.lr * lr_scale

    if config.kind == "sgd":
        mu = jax.tree_util.tree_map(
            lambda m, g: config.momentum * m + g, state["mu"], grads
        )
        new_params = jax.tree_util.tree_map(
            lambda p, m: p - lr * (m + config.weight_decay * p), params, mu
        )
        return new_params, {"mu": mu, "count": count}

    # adamw with bias correction
    c = count.astype(jnp.float32)
    b1c = 1.0 - config.b1**c
    b2c = 1.0 - config.b2**c
    mu = jax.tree_util.tree_map(
        lambda m, g: config.b1 * m + (1 - config.b1) * g, state["mu"], grads
    )
    nu = jax.tree_util.tree_map(
        lambda v, g: config.b2 * v + (1 - config.b2) * (g * g), state["nu"], grads
    )
    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        return p - lr * (mhat / (jnp.sqrt(vhat) + config.eps) + config.weight_decay * p)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}
