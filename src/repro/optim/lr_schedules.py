"""LR schedules as step -> multiplier callables (evaluated outside jit).

``rsqrt_decay`` provides the diminishing step size of Theorem 3.5
(sum eta = inf, sum eta^2 < inf); pairing it with
``core.schedules.thm35_schedule`` gives the provably convergent
(eta_t, theta_t) pair."""

from __future__ import annotations

import math

__all__ = ["constant", "cosine", "warmup_cosine", "rsqrt_decay", "step_decay"]


def constant():
    return lambda step: 1.0


def cosine(total_steps: int, final: float = 0.1):
    def f(step):
        frac = min(step / max(total_steps, 1), 1.0)
        return final + (1 - final) * 0.5 * (1 + math.cos(math.pi * frac))

    return f


def warmup_cosine(warmup: int, total_steps: int, final: float = 0.1):
    cos = cosine(total_steps - warmup, final)

    def f(step):
        if step < warmup:
            return (step + 1) / warmup
        return cos(step - warmup)

    return f


def rsqrt_decay(warmup: int = 100):
    def f(step):
        return min((step + 1) / warmup, math.sqrt(warmup / max(step + 1, 1)))

    return f


def step_decay(boundaries, factor=0.1):
    def f(step):
        mult = 1.0
        for b in boundaries:
            if step >= b:
                mult *= factor
        return mult

    return f
