"""Compatibility layer over the jax API renames this repo straddles.

The codebase targets current jax (``jax.shard_map`` with ``axis_names`` /
``check_vma``, ``jax.set_mesh``, ``jax.make_mesh(..., axis_types=...)``)
but must also run on older 0.4.x releases where the same features are spelled
``jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)``,
``with mesh:``, and ``jax.make_mesh`` without axis types.  Every call site
goes through these three wrappers; each dispatches on feature presence, not
version strings, so intermediate releases behave sensibly.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax

__all__ = ["make_auto_mesh", "set_mesh", "shard_map"]


def make_auto_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with every axis AUTO (explicit where supported)."""
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)  # pre-AxisType: axes default to auto


def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh.

    ``jax.set_mesh`` where it exists; on older releases ``Mesh`` itself is a
    context manager with the same scoping behavior.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, mesh, in_specs, out_specs, manual_axes: Optional[Sequence[str]] = None):
    """Partial-manual shard_map without replication checking, both spellings.

    ``manual_axes`` names the axes stripped inside ``f`` (the rest stay
    AUTO-partitioned).  ``None`` means fully manual — every mesh axis.
    Replication checking is disabled (``check_vma``/``check_rep``): the
    compressed reducers return unreplicated per-worker payloads mid-graph.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {"check_vma": False}
        if manual_axes is not None:
            kwargs["axis_names"] = frozenset(manual_axes)
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = {"check_rep": False}
    if manual_axes is not None:
        auto = frozenset(mesh.axis_names) - frozenset(manual_axes)
        if auto:
            kwargs["auto"] = auto
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )
