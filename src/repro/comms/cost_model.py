"""Paper §III-D: when is compression beneficial? (Fig. 9)

    cost_comp        = M * (4/T_m + 1/T_f + 1/T_p + 1/T_s)
    saved_cost_comm  = M/T_comm * (1 - 1/k)
    beneficial  <=>  2*cost_comp < saved_cost_comm
    k_min        =   1 / (1 - 2*T_comm*(4/T_m + 1/T_f + 1/T_p + 1/T_s))

(T_* are throughputs; the compress+decompress pair costs 2x, hence the 2.)
``k_min`` <= 0 or undefined means NO compression ratio can pay for itself on
that link — the compression pipeline is slower than just sending the bytes.

Default throughputs are TPU-v5e-adapted estimates derived from the roofline
terms of the Pallas kernels (bytes touched / 819 GB/s HBM for the
bandwidth-bound passes; MXU-limited for the 4-step FFT), replacing the paper's
V100 numbers.  The paper's measured GPU numbers are kept for reproducing
Fig. 9 exactly.

Calibration (DESIGN.md §17): every constant in this module —
``COLLECTIVE_ALPHA_S``, ``BACKPROP_FLOPS_PER_S``, the ``TPU_V5E`` throughput
table, and the ``NETWORKS`` byte-rates — is an UNCALIBRATED DEFAULT: a
documented napkin figure, not a measurement of the host this process runs
on.  ``comms/calibrate.py`` measures all of them on the live mesh (timed
collectives at a geometric size sweep, least-squares α–β fit, timed backward
pass) and packages the result as a frozen ``CostProfile``.  The pricing
functions below (``exchange_time_s``, ``streamed_exchange_time_s``) accept
``profile=`` and resolve any argument the caller leaves ``None`` from it;
with no profile they fall back to the static constants, which keeps every
pre-calibration call site bit-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["Throughputs", "PAPER_V100", "TPU_V5E", "compression_cost_s",
           "saved_comm_s", "k_min", "is_beneficial", "NETWORKS",
           "bucket_count", "transport_wire_bits", "overlap_fraction",
           "bucketed_payload_bits", "exchange_time_s", "ExchangePlan",
           "COLLECTIVE_ALPHA_S", "BACKPROP_FLOPS_PER_S",
           "WIRE_MODES", "dense_spectrum_bits", "dense_time_bits",
           "StreamedExchangePlan", "streamed_exchange_time_s",
           "TwoLevelWire", "two_level_wire_bits",
           "TwoLevelExchangePlan", "two_level_exchange_time_s",
           "dense_allreduce_bits", "RunWireAccount", "run_wire_account",
           "PublishWireAccount", "publish_wire_account"]


@dataclasses.dataclass(frozen=True)
class Throughputs:
    """All in bytes/second."""

    t_m: float  # precision change / thresholding (O(N), elementwise)
    t_f: float  # FFT
    t_p: float  # pack
    t_s: float  # top-k select

    def inv_sum(self) -> float:
        return 4.0 / self.t_m + 1.0 / self.t_f + 1.0 / self.t_p + 1.0 / self.t_s


# Paper's V100-era numbers (pack measured at 34 GB/s on V100; others scaled
# from cuFFT/Thrust throughput at ~10^2 GB/s class memory bandwidth).
PAPER_V100 = Throughputs(t_m=300e9, t_f=150e9, t_p=34e9, t_s=100e9)

# TPU v5e estimates from kernel napkin math (see fft4step.py docstring):
#   t_m: elementwise quant: 5 bytes/elem over 819 GB/s HBM -> ~650 GB/s eff.
#   t_f: 4-step FFT: 3.1 MFLOP / 16 KiB chunk; f32 MXU ~50 TFLOP/s
#        -> ~8 GFLOP/s per GB/s => ~260 GB/s input-byte throughput.
#   t_p: one-hot-matmul pack: k*F MACs per F elems; MXU-bound ~200 GB/s.
#   t_s: 26 compare+count VMEM sweeps -> HBM-bound read once ~600 GB/s.
TPU_V5E = Throughputs(t_m=650e9, t_f=260e9, t_p=200e9, t_s=600e9)

# network byte-throughputs (practical, not line-rate)
NETWORKS = {
    "10GbE": 1.1e9,
    "56Gb-FDR": 6.0e9,  # paper's practical 6 GB/s
    "100Gb-EDR": 11.0e9,
    "tpu-dcn-host": 12.5e9,  # inter-pod DCN per host
    "tpu-ici-link": 50.0e9,  # intra-pod per link
}


def compression_cost_s(message_bytes: float, thr: Throughputs) -> float:
    return message_bytes * thr.inv_sum()


def saved_comm_s(message_bytes: float, t_comm: float, k: float) -> float:
    return message_bytes / t_comm * (1.0 - 1.0 / k)


def k_min(t_comm: Optional[float] = None, thr: Optional[Throughputs] = None,
          *, profile=None) -> float:
    """Minimal beneficial compression ratio; inf if never beneficial."""
    t_comm, thr, _ = _resolve_pricing("allgather", t_comm, thr, 0.0, profile)
    denom = 1.0 - 2.0 * t_comm * thr.inv_sum()
    if denom <= 0.0:
        return float("inf")
    return 1.0 / denom


def is_beneficial(message_bytes: float, t_comm: Optional[float], k: float,
                  thr: Optional[Throughputs] = None, *, profile=None) -> bool:
    t_comm, thr, _ = _resolve_pricing("allgather", t_comm, thr, 0.0, profile)
    return 2.0 * compression_cost_s(message_bytes, thr) < saved_comm_s(
        message_bytes, t_comm, k
    )


# ---------------------------------------------------------------------------
# Bucketed, transport-aware exchange model (DESIGN.md §9, §11)
#
# The seed model above prices ONE monolithic exchange.  The bucketed reducer
# adds two degrees of freedom the model must reflect:
#
# * transport — which collective carries the payload and therefore how the
#   per-worker wire volume scales with the worker count P;
# * bucket count — independent per-bucket collectives let the compression of
#   bucket i+1 hide behind the wire time of bucket i (software pipelining),
#   so only the first bucket's compression is exposed.
# ---------------------------------------------------------------------------


def bucket_count(message_bytes: float, bucket_bytes, chunk: int = 4096,
                 dtype_bytes: int = 4) -> int:
    """Number of buckets the reducer splits a message into (≥ 1).

    Derived from the SAME layout the reducer builds, so chunk rounding and
    the sub-chunk tail merge are priced identically to how they execute.
    """
    from repro.comms.bucketing import build_layout

    total = max(1, int(-(-message_bytes // dtype_bytes)))
    return build_layout(total, bucket_bytes, chunk, dtype_bytes).n_buckets


WIRE_MODES = ("modeled", "runtime")


def dense_spectrum_bits(n_elems: int, chunk: int = 4096) -> float:
    """Wire bits of the DENSE dequantized spectrum of an n-element buffer.

    The runtime psum transport (``transport._psum_mean_payload``) moves two
    f32 planes (real + imag) of ``ceil(n/chunk) * (chunk//2 + 1)`` rfft bins
    — independent of theta.  This is what actually rides the collective
    today, as opposed to the O(k) sparse-allreduce endpoint the modeled
    pricing assumes.
    """
    if n_elems < 1:
        raise ValueError(f"n_elems must be >= 1, got {n_elems}")
    n_chunks = -(-int(n_elems) // int(chunk))
    bins = n_chunks * (int(chunk) // 2 + 1)
    return 2.0 * 32.0 * bins


def dense_time_bits(n_elems: int, chunk: int = 4096) -> float:
    """Wire bits of the chunk-padded DENSE time-domain buffer (f32 rows).

    The reduce_scatter transport's gather half moves the inverse-FFT'd
    time-domain rows (``chunk`` floats per chunk) instead of the spectrum
    (``2·(chunk/2+1)`` floats per chunk) — slightly fewer bytes.
    """
    if n_elems < 1:
        raise ValueError(f"n_elems must be >= 1, got {n_elems}")
    n_chunks = -(-int(n_elems) // int(chunk))
    return 32.0 * n_chunks * int(chunk)


@dataclasses.dataclass(frozen=True)
class TwoLevelWire:
    """Per-axis wire split of one hierarchical exchange (DESIGN.md §18)."""

    nodes: int
    local: int
    intra_bits_per_worker: float  # fast-link hop (spectra psum on the island)
    inter_bits_per_node: float  # fabric hop: nodes payloads land per island
    inter_bits_per_worker: float  # island share / local workers


def two_level_wire_bits(payload_bits: float, nodes: int, local: int,
                        *, mode: str = "runtime",
                        n_elems: Optional[int] = None,
                        chunk: int = 4096) -> TwoLevelWire:
    """Wire volumes of one hierarchical exchange, split by axis.

    * intra-node — the dequantized-spectra ``psum`` over the ``local`` axis.
      ``mode="runtime"`` (what the lowering moves, and what the ISSUE's
      pricing contract requires here) bills the ring all-reduce of the dense
      spectrum: ``2·(local-1)/local · dense_spectrum_bits``; ``"modeled"``
      bills the sparse-allreduce endpoint (one compressed payload).
    * inter-node — the all_gather of ONE re-compressed payload per island
      over the ``node`` axis: ``nodes · payload_bits`` land on each island
      (mode-independent — the fabric hop always moves compressed payloads).
      Per WORKER that is ``nodes · payload_bits / local``: growing the
      island shrinks every worker's share of the fabric, which is the whole
      point of the topology-aware transport (check_bench guards this).
    """
    if nodes < 1 or local < 1:
        raise ValueError(f"topology must be >= (1, 1), got ({nodes}, {local})")
    if mode not in WIRE_MODES:
        raise ValueError(f"unknown wire mode {mode!r}; expected {WIRE_MODES}")
    if mode == "runtime":
        if n_elems is None:
            raise ValueError(
                "runtime two-level pricing needs n_elems: the intra-node "
                "psum moves the dense spectrum")
        intra = 2.0 * dense_spectrum_bits(n_elems, chunk) * (local - 1) / local
    else:
        intra = float(payload_bits) if local > 1 else 0.0
    inter_node = float(nodes) * float(payload_bits) if nodes > 1 else 0.0
    return TwoLevelWire(
        nodes=int(nodes),
        local=int(local),
        intra_bits_per_worker=intra,
        inter_bits_per_node=inter_node,
        inter_bits_per_worker=inter_node / float(local),
    )


def transport_wire_bits(transport: str, payload_bits: float, workers: int,
                        *, mode: str = "modeled",
                        n_elems: Optional[int] = None,
                        chunk: int = 4096,
                        topology: "Optional[tuple]" = None) -> float:
    """Per-worker wire bits to exchange one compressed payload among P workers.

    * ``allgather``/``sequenced`` — every worker materializes all P payloads:
      P·B per worker (sequenced ships the SAME volume, just split into
      independent per-bucket collectives so it can be pipelined).
    * ``psum`` — in-network reduction of the dequantized spectra: each worker
      injects its kept coefficients once and the reduction happens inside the
      collective (reduce-scatter over the frequency bins), so the per-worker
      volume is B, independent of P — O(k) instead of O(P·k).  This is the
      bandwidth-optimal model; it is what makes the psum transport's wire
      volume ≤ 1/P of the all-gather transport's at equal theta.

    ``mode`` selects which endpoint is priced:

    * ``"modeled"`` (default) — the sparse-allreduce endpoint the transport
      abstraction is built for.  Use it for trajectory planning; it is NOT a
      prediction of today's XLA lowering for psum.
    * ``"runtime"`` — the bytes the CURRENT lowering actually moves.  The
      gather transports are priced identically (the all_gather really does
      land P payloads per worker), but the psum transport realizes its
      semantics with a dense-spectrum ``jax.lax.psum`` (see the NOTE in
      ``transport._psum_mean_payload``), so its runtime wire is a ring
      all-reduce of ``dense_spectrum_bits(n_elems, chunk)`` — 2·(P-1)/P of
      the dense spectrum per worker, theta-independent.  ``n_elems`` (the
      uncompressed element count) is required for psum in this mode.
      ``choose_schedule`` prices decisions in this mode so ``schedule=auto``
      reflects the collective that will actually run.

    The topology-aware transports (DESIGN.md §18):

    * ``reduce_scatter`` — modeled: the same O(k) sparse endpoint as psum.
      Runtime: the scatter half moves the dense spectra planes and the
      gather half the time-domain rows, each (P-1)/P per worker —
      ring-allreduce-shaped, so it stops growing with P.
    * ``hierarchical`` — needs ``topology=(nodes, local)``; returns the
      per-worker TOTAL (intra + inter share) so the single-link-rate
      pricing functions stay usable.  ``two_level_exchange_time_s`` prices
      the two hops at their own per-axis α–β instead.
    """
    if workers < 1:
        raise ValueError(f"workers must be >= 1, got {workers}")
    if mode not in WIRE_MODES:
        raise ValueError(f"unknown wire mode {mode!r}; expected {WIRE_MODES}")
    if transport in ("allgather", "sequenced"):
        return workers * payload_bits
    if transport == "psum":
        if mode == "runtime":
            if n_elems is None:
                raise ValueError(
                    "runtime psum pricing needs n_elems (the dense element "
                    "count): the lowering moves the dense spectrum")
            spectrum = dense_spectrum_bits(n_elems, chunk)
            return 2.0 * spectrum * (workers - 1) / workers
        return float(payload_bits)
    if transport == "reduce_scatter":
        if mode == "runtime":
            if n_elems is None:
                raise ValueError(
                    "runtime reduce_scatter pricing needs n_elems: the "
                    "scatter moves the dense spectra planes")
            dense = dense_spectrum_bits(n_elems, chunk) + dense_time_bits(
                n_elems, chunk)
            return dense * (workers - 1) / workers
        return float(payload_bits)
    if transport == "hierarchical":
        if topology is None:
            raise ValueError(
                "hierarchical pricing needs topology=(nodes, local)")
        nodes, local = int(topology[0]), int(topology[1])
        if nodes * local != workers:
            raise ValueError(
                f"topology ({nodes}, {local}) does not multiply out to "
                f"workers={workers}")
        wire = two_level_wire_bits(payload_bits, nodes, local, mode=mode,
                                   n_elems=n_elems, chunk=chunk)
        return wire.intra_bits_per_worker + wire.inter_bits_per_worker
    raise ValueError(f"unknown transport {transport!r}")


def bucketed_payload_bits(wire_bits_fn, sizes, transport: str = "sequenced",
                          *, stacked: bool = False, chunk: int = 4096) -> float:
    """Compressed payload bits of ONE exchange over a bucket layout.

    Quantizer-param overhead (4·32 bits: eps, P, vmin, vmax) is billed per
    PAYLOAD, and payload granularity is the transport's choice:

    * ``allgather`` concatenates the buckets and compresses monolithically —
      one quantizer fit, one overhead (`transport.AllGatherTransport`);
    * ``sequenced``/``psum`` compress per bucket
      (``FFTCompressor.compress_buckets`` fits one quantizer per bucket), so
      every bucket carries its own params.

    ``wire_bits_fn`` is the compressor's ``wire_bits`` (already includes one
    per-payload overhead); ``sizes`` are the layout's unpadded bucket lengths
    (``bucketing.BucketLayout.sizes()``).  Before this helper, models summed
    ONE monolithic ``wire_bits`` regardless of transport, under-billing the
    per-bucket params the bucketed transports actually exchange.

    ``stacked=True`` prices the batched executor's StackedPayload
    (DESIGN.md §14): its struct-of-arrays planes are UNIFORM at the widest
    bucket's chunk-rounded width, so every bucket is billed at that padded
    width — ragged layouts ship (inert, code-0) padding slots over the wire,
    and the model must bill the bytes that actually move.  Identical to the
    looped bill when no bucket is ragged (the common size-targeted case).
    """
    sizes = list(sizes)
    if not sizes:
        raise ValueError("empty bucket layout")
    if transport not in ("allgather", "sequenced", "psum", "hierarchical",
                         "reduce_scatter"):
        raise ValueError(f"unknown transport {transport!r}")
    if transport == "allgather" or len(sizes) == 1:
        return float(wire_bits_fn(sum(sizes)))
    if stacked:
        padded = max(-(-s // chunk) * chunk for s in sizes)
        return float(len(sizes) * wire_bits_fn(padded))
    return float(sum(wire_bits_fn(s) for s in sizes))


def overlap_fraction(n_buckets: int) -> float:
    """Fraction of compression cost hidden by per-bucket pipelining.

    With n independent bucket exchanges, buckets 2..n compress while earlier
    buckets are on the wire: (n-1)/n of the compression pipeline is hidden.
    One bucket means no overlap (the seed's monolithic behavior).
    """
    if n_buckets < 1:
        raise ValueError(f"n_buckets must be >= 1, got {n_buckets}")
    return (n_buckets - 1) / n_buckets


# Per-collective launch latency α (seconds): dispatch + rendezvous cost every
# collective pays before bytes move (the LogP latency term).  The looped
# bucketed exchange pays it PER BUCKET; the stacked executor (DESIGN.md §14)
# pays it once per exchange.  25 µs is a practical DCN collective-launch
# figure; ICI launches are cheaper but the ratio is what the model prices.
# UNCALIBRATED DEFAULT — comms/calibrate.py fits the real α per collective
# family from timed collectives on the live mesh (CostProfile.alpha_s).
COLLECTIVE_ALPHA_S = 25e-6

# Modeled backward-pass compute rate (FLOP/s) for the overlap policy.
# Matches the MXU-class figure the §III-D throughput model uses for the
# 4-step FFT (TPU_V5E derivation): ~50 TFLOP/s sustained f32.
# UNCALIBRATED DEFAULT — comms/calibrate.py measures the actual model's
# backward pass (CostProfile.backprop_flops_per_s).
BACKPROP_FLOPS_PER_S = 50e12


def _resolve_pricing(transport: str, t_comm, thr, alpha_s, profile):
    """(t_comm, thr, alpha_s) with explicit args > profile > static defaults.

    ``profile`` is a ``calibrate.CostProfile`` (duck-typed: anything with
    ``t_comm(transport)``, ``alpha_s(transport)``, ``throughputs``); ``None``
    keeps the documented uncalibrated constants.
    """
    if t_comm is None:
        t_comm = (profile.t_comm(transport) if profile is not None
                  else NETWORKS["tpu-dcn-host"])
    if thr is None:
        thr = profile.throughputs if profile is not None else TPU_V5E
    if alpha_s is None:
        alpha_s = (profile.alpha_s(transport) if profile is not None
                   else COLLECTIVE_ALPHA_S)
    return t_comm, thr, alpha_s


@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """A priced exchange configuration (used by benchmarks/throughput.py)."""

    transport: str
    n_buckets: int
    workers: int
    wire_bits_per_worker: float
    exchange_s: float
    overlap: float
    n_collectives: int = 1  # collective launches per exchange
    launch_s: float = 0.0  # alpha * n_collectives


def exchange_time_s(
    message_bytes: float,
    payload_bits: float,
    t_comm: Optional[float] = None,
    thr: Optional[Throughputs] = None,
    *,
    workers: int,
    transport: str = "allgather",
    n_buckets: int = 1,
    stacked: bool = False,
    alpha_s: Optional[float] = None,
    profile=None,
    wire_mode: str = "modeled",
    chunk: int = 4096,
    topology: "Optional[tuple]" = None,
) -> ExchangePlan:
    """Modeled wall time of one compressed gradient exchange.

    ``payload_bits`` is the compressed wire size of the WHOLE message (the
    compressor's ``wire_bits``); compression+decompression cost comes from the
    §III-D throughput model.  Per-bucket pipelining hides the overlap
    fraction of whichever of (compress, wire) is smaller behind the other; the
    monolithic transports serialize the two.

    Collective-launch latency (``alpha_s``) is billed per collective: the
    looped bucketed exchange issues ``n_buckets`` independent collectives
    (α·n), the stacked executor (``stacked=True``) ships every bucket in one
    ``StackedPayload`` collective (α·1, no per-bucket pipelining — the single
    fused program serializes compress and wire but pays one launch).

    ``t_comm``/``thr``/``alpha_s`` left ``None`` resolve from ``profile`` (a
    measured ``calibrate.CostProfile``) or, without one, from the static
    uncalibrated defaults; ``wire_mode="runtime"`` prices the bytes today's
    lowering actually moves (see ``transport_wire_bits``).
    """
    t_comm, thr, alpha_s = _resolve_pricing(
        transport, t_comm, thr, alpha_s, profile)
    comp_s = 2.0 * compression_cost_s(message_bytes, thr)  # compress + decompress
    wire_per_worker = transport_wire_bits(
        transport, payload_bits, workers, mode=wire_mode,
        n_elems=int(-(-message_bytes // 4)), chunk=chunk, topology=topology)
    wire_s = wire_per_worker / 8.0 / t_comm
    if stacked or transport == "allgather" or n_buckets <= 1:
        n_coll = 1
        total = comp_s + wire_s
        ov = 0.0
    else:
        # pipeline: first bucket's smaller stage fills, the rest overlaps
        n_coll = n_buckets
        ov = overlap_fraction(n_buckets)
        total = max(comp_s, wire_s) + min(comp_s, wire_s) * (1.0 - ov)
    launch_s = alpha_s * n_coll
    return ExchangePlan(
        transport=transport,
        n_buckets=n_buckets,
        workers=workers,
        wire_bits_per_worker=wire_per_worker,
        exchange_s=total + launch_s,
        overlap=ov,
        n_collectives=n_coll,
        launch_s=launch_s,
    )


# ---------------------------------------------------------------------------
# Streamed (backprop-interleaved) exchange model (DESIGN.md §15)
#
# The §11/§14 models price the exchange as a block that runs AFTER the
# gradient exists.  The overlap engine (comms/scheduler.py) instead streams
# readiness-ordered dispatch groups DURING the backward pass, so the model
# gains a timeline: group g's gradients become final at the point of the
# backward pass that has produced its share of the flat buffer, its
# exchange starts at max(ready_g, previous group finished), and whatever
# part of the total exchange work fits before the backward pass ends is
# HIDDEN.  ``overlap_efficiency`` — the fraction of exchange time hidden
# behind backprop — is the number the §1 overlap terms existed for; it is
# recorded per sweep row in BENCH_throughput.json and schema-guarded by
# tools/check_bench.py.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamedExchangePlan:
    """A priced streamed exchange: the readiness timeline's verdict."""

    transport: str
    n_groups: int
    workers: int
    wire_bits_per_worker: float
    exchange_s: float  # total exchange WORK (sum over groups, launches incl.)
    exposed_s: float  # exchange time sticking out past the backward pass
    hidden_s: float  # exchange_s - exposed_s
    overlap_efficiency: float  # hidden_s / exchange_s (0 with no backprop)
    step_s: float  # max(backprop_s, last group finish): modeled step comms wall
    n_collectives: int
    launch_s: float  # alpha * n_collectives


def streamed_exchange_time_s(
    message_bytes: float,
    payload_bits: float,
    t_comm: Optional[float] = None,
    thr: Optional[Throughputs] = None,
    *,
    workers: int,
    transport: str,
    group_fractions: "tuple[float, ...]",
    backprop_s: float,
    alpha_s: Optional[float] = None,
    profile=None,
    wire_mode: str = "modeled",
    chunk: int = 4096,
    topology: "Optional[tuple]" = None,
) -> StreamedExchangePlan:
    """Readiness-timeline model of one streamed exchange.

    ``group_fractions`` are the dispatch groups' element shares in READINESS
    order (``StreamPlan.group_fractions``): group g's compress+wire cost is
    its share of the whole message's, and its gradients become final once
    the backward pass has produced the first g groups' cumulative fraction
    (gradients stream out of backprop top-of-buffer first, uniformly in the
    element count — the same proxy the §III-D model uses for compute).

    Timeline: ``start_g = max(ready_g, finish_{g-1})``,
    ``finish_g = start_g + α + compress_g + wire_g`` (a group's collective
    serializes behind the previous group's on the same link).  Everything
    before ``backprop_s`` is hidden; only the tail past it is exposed.
    """
    if not group_fractions:
        raise ValueError("need at least one dispatch group")
    if abs(sum(group_fractions) - 1.0) > 1e-6:
        raise ValueError(f"group fractions must sum to 1: {group_fractions}")
    if backprop_s < 0.0:
        raise ValueError(f"backprop_s must be >= 0, got {backprop_s}")
    t_comm, thr, alpha_s = _resolve_pricing(
        transport, t_comm, thr, alpha_s, profile)
    wire_bits = transport_wire_bits(
        transport, payload_bits, workers, mode=wire_mode,
        n_elems=int(-(-message_bytes // 4)), chunk=chunk, topology=topology)
    comp_total = 2.0 * compression_cost_s(message_bytes, thr)
    wire_total = wire_bits / 8.0 / t_comm
    finish = 0.0
    total_work = 0.0
    ready = 0.0
    for frac in group_fractions:
        ready += frac * backprop_s
        e_g = alpha_s + frac * (comp_total + wire_total)
        start = max(ready, finish)
        finish = start + e_g
        total_work += e_g
    # Accounting identity (tests/test_calibrate.py property): the exchange
    # work splits EXACTLY into the exposed tail and the hidden remainder —
    # exposed_s + hidden_s == exchange_s always.  Hidden derives from
    # exposed, never clamped independently: ``finish >= total_work`` (work
    # only accumulates) and total readiness waiting is <= backprop_s, so
    # 0 <= hidden <= backprop_s follows structurally.
    exposed = min(max(0.0, finish - backprop_s), total_work)
    hidden = total_work - exposed
    n_groups = len(group_fractions)
    return StreamedExchangePlan(
        transport=transport,
        n_groups=n_groups,
        workers=workers,
        wire_bits_per_worker=wire_bits,
        exchange_s=total_work,
        exposed_s=exposed,
        hidden_s=hidden,
        overlap_efficiency=hidden / total_work if total_work > 0 else 0.0,
        step_s=max(backprop_s, finish),
        n_collectives=n_groups,
        launch_s=alpha_s * n_groups,
    )


# ---------------------------------------------------------------------------
# Two-level (hierarchical) exchange pricing (DESIGN.md §18)
#
# The flat pricing functions above bill every wire bit at ONE link rate.
# The hierarchical transport's two hops ride different links — the
# intra-node spectra psum on the fast island link, the re-compressed
# payload gather on the slow fabric — so its plan prices each hop at its
# own per-axis α–β (calibrate.py fits them per mesh axis when given a 2-D
# mesh; the static defaults use the ICI vs DCN byte-rates).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TwoLevelExchangePlan:
    """A priced hierarchical exchange: per-hop wire, per-hop time."""

    transport: str
    nodes: int
    local: int
    wire: TwoLevelWire
    intra_s: float  # island hop at the intra-axis link rate
    inter_s: float  # fabric hop at the inter-axis link rate
    comp_s: float  # leaf dense-FFT pass + node compress + gather decompress
    launch_s: float  # one collective launch per hop
    exchange_s: float  # total


def _axis_link_pricing(transport: str, t_comm, alpha_s, profile,
                       axis: Optional[str], default_network: str):
    """(t_comm, alpha_s) for ONE hop: explicit > per-axis profile fit >
    profile base fit > static default for that link class."""
    if t_comm is None:
        if profile is not None:
            try:
                t_comm = profile.t_comm(transport, axis=axis)
            except TypeError:  # profile predating per-axis fits
                t_comm = profile.t_comm(transport)
        else:
            t_comm = NETWORKS[default_network]
    if alpha_s is None:
        if profile is not None:
            try:
                alpha_s = profile.alpha_s(transport, axis=axis)
            except TypeError:
                alpha_s = profile.alpha_s(transport)
        else:
            alpha_s = COLLECTIVE_ALPHA_S
    return t_comm, alpha_s


def two_level_exchange_time_s(
    message_bytes: float,
    payload_bits: float,
    *,
    nodes: int,
    local: int,
    thr: Optional[Throughputs] = None,
    t_comm_intra: Optional[float] = None,
    t_comm_inter: Optional[float] = None,
    alpha_intra_s: Optional[float] = None,
    alpha_inter_s: Optional[float] = None,
    profile=None,
    wire_mode: str = "runtime",
    chunk: int = 4096,
    intra_axis: str = "local",
    inter_axis: str = "node",
) -> TwoLevelExchangePlan:
    """Modeled wall time of one hierarchical exchange (DESIGN.md §18).

    Wire: ``two_level_wire_bits`` — the default ``wire_mode="runtime"``
    bills the intra-node hop as the dense-spectrum psum the lowering
    actually runs.  The island hop is priced per worker at the intra-axis
    link rate; the fabric hop per NODE at the inter-axis rate (the island's
    workers share one fabric endpoint — that collective's wall time is the
    island's, not divided among its workers).

    Compression: three passes of the §III-D pipeline — the leaf dense-FFT
    pass feeding the intra psum (no leaf top-k: the dense psum makes it
    free loss, transport.py), the per-node compress of the island mean,
    and the gather-side decompress folded into the final mean.

    Link rates/launch latencies left ``None`` resolve per hop: the intra
    hop from the profile's ``psum`` fit on ``intra_axis``, the inter hop
    from the ``gather`` fit on ``inter_axis`` (per-axis fits when the
    profile was calibrated on a 2-D mesh, its base fits otherwise); with no
    profile, the static ICI vs DCN byte-rates.
    """
    if thr is None:
        thr = profile.throughputs if profile is not None else TPU_V5E
    t_comm_intra, alpha_intra_s = _axis_link_pricing(
        "psum", t_comm_intra, alpha_intra_s, profile, intra_axis,
        "tpu-ici-link")
    t_comm_inter, alpha_inter_s = _axis_link_pricing(
        "allgather", t_comm_inter, alpha_inter_s, profile, inter_axis,
        "tpu-dcn-host")
    wire = two_level_wire_bits(
        payload_bits, nodes, local, mode=wire_mode,
        n_elems=int(-(-message_bytes // 4)), chunk=chunk)
    comp_s = 3.0 * compression_cost_s(message_bytes, thr)
    intra_s = wire.intra_bits_per_worker / 8.0 / t_comm_intra
    inter_s = wire.inter_bits_per_node / 8.0 / t_comm_inter
    launch_s = (alpha_intra_s if local > 1 else 0.0) + (
        alpha_inter_s if nodes > 1 else 0.0)
    return TwoLevelExchangePlan(
        transport="hierarchical",
        nodes=int(nodes),
        local=int(local),
        wire=wire,
        intra_s=intra_s,
        inter_s=inter_s,
        comp_s=comp_s,
        launch_s=launch_s,
        exchange_s=comp_s + intra_s + inter_s + launch_s,
    )


# ---------------------------------------------------------------------------
# Per-run wire accounting (convergence lab)
#
# A training RUN is a sequence of exchanges whose payload size changes with
# the theta schedule (each step's quantized theta fixes the kept-k and hence
# wire_bits).  The lab prices the whole run so the report can state "this
# curve cost X GiB on the wire vs the dense baseline's Y" — the paper's
# accuracy-vs-traffic trade made concrete per experiment.
# ---------------------------------------------------------------------------


def dense_allreduce_bits(n_elems: int, workers: int, dtype_bits: int = 32) -> float:
    """Per-worker wire bits of one dense ring all-reduce (the 'orig' baseline).

    Ring all-reduce moves 2*(P-1)/P of the buffer past every worker
    (reduce-scatter + all-gather phases) — the same model analysis/hlo.py
    applies to measured HLO.
    """
    if workers <= 1:
        return 0.0
    return 2.0 * dtype_bits * n_elems * (workers - 1) / workers


@dataclasses.dataclass(frozen=True)
class RunWireAccount:
    """Total modeled wire traffic of one training run, per worker."""

    transport: str
    workers: int
    steps: int
    dense_bits: float  # dense baseline: one ring all-reduce per step
    compressed_bits: float  # sum of per-step transport_wire_bits
    savings: float  # dense_bits / compressed_bits (inf when compressed is 0)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def run_wire_account(
    n_elems: int,
    per_step_payload_bits: "list[float]",
    transport: str,
    workers: int,
    dtype_bits: int = 32,
    topology: "Optional[tuple]" = None,
) -> RunWireAccount:
    """Price a whole run: per-step compressed payloads vs the dense baseline.

    ``per_step_payload_bits[t]`` is the compressor's ``wire_bits`` at step t's
    (quantized) theta; a dense step is priced as the ring all-reduce instead
    of a payload exchange (pass the step's entry as ``None``).
    ``topology=(nodes, local)`` is required for the hierarchical transport.
    """
    steps = len(per_step_payload_bits)
    dense_step = dense_allreduce_bits(n_elems, workers, dtype_bits)
    dense_total = dense_step * steps
    compressed_total = 0.0
    for payload in per_step_payload_bits:
        if payload is None:
            compressed_total += dense_step
        else:
            compressed_total += transport_wire_bits(
                transport, payload, workers, topology=topology)
    savings = dense_total / compressed_total if compressed_total > 0 else float("inf")
    return RunWireAccount(
        transport=transport,
        workers=workers,
        steps=steps,
        dense_bits=dense_total,
        compressed_bits=compressed_total,
        savings=savings,
    )


# ---------------------------------------------------------------------------
# publish-path pricing (DESIGN.md §20): the asymmetric train->serve traffic.
# A training job publishing weight deltas to a replica fleet moves ONE
# compressed StackedPayload per publish plus a dense snapshot per rebase
# point; the baseline it must beat is shipping a dense snapshot at the same
# cadence.  Unlike the exchange paths there is no collective here — the
# bytes land on the ring (disk or fabric) once, whatever the fleet size —
# so the account is pure payload bits, no α–β term.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PublishWireAccount:
    """Modeled publish traffic of one training run (serve/publish.py)."""

    steps: int
    publish_every: int
    n_publishes: int
    snapshot_every: int
    n_snapshots: int  # rebase snapshots (the version-0 seed included)
    delta_bits: float  # compressed delta payloads, total
    snapshot_bits: float  # dense rebase snapshots, total
    total_bits: float  # delta_bits + snapshot_bits
    dense_bits: float  # baseline: one dense snapshot per publish
    savings: float  # dense_bits / delta_bits (inf when delta_bits is 0)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def publish_wire_account(
    n_elems: int,
    wire_bits_fn,
    sizes,
    *,
    steps: int,
    publish_every: int = 1,
    snapshot_every: int = 16,
    chunk: int = 4096,
    dtype_bits: int = 32,
) -> PublishWireAccount:
    """Price the publish path at one (cadence, theta) point.

    ``wire_bits_fn``/``sizes`` follow :func:`bucketed_payload_bits` (the
    publisher ships one stacked payload over the delta's bucket layout per
    publish).  ``steps`` are trainer steps; publishes land on every
    ``publish_every``-th step (step 0 included — the loop's 0-based
    convention), and every ``snapshot_every``-th publish also writes a
    dense rebase snapshot, plus the version-0 snapshot at ring creation.

    The acceptance comparison (tools/check_bench.py ``check_serve``) is
    ``delta_bits`` vs ``dense_bits``: compressed deltas must be strictly
    cheaper than shipping dense snapshots at the SAME cadence.
    """
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    if publish_every < 1:
        raise ValueError(f"publish_every must be >= 1, got {publish_every}")
    if snapshot_every < 1:
        raise ValueError(f"snapshot_every must be >= 1, got {snapshot_every}")
    n_publishes = -(-steps // publish_every)  # steps 0..steps-1, step 0 pubs
    delta_per_publish = bucketed_payload_bits(
        wire_bits_fn, sizes, "sequenced", stacked=True, chunk=chunk)
    delta_bits = n_publishes * delta_per_publish
    snapshot_each = float(dtype_bits) * n_elems
    n_snapshots = 1 + n_publishes // snapshot_every
    snapshot_bits = n_snapshots * snapshot_each
    dense_bits = n_publishes * snapshot_each
    savings = dense_bits / delta_bits if delta_bits > 0 else float("inf")
    return PublishWireAccount(
        steps=int(steps),
        publish_every=int(publish_every),
        n_publishes=int(n_publishes),
        snapshot_every=int(snapshot_every),
        n_snapshots=int(n_snapshots),
        delta_bits=delta_bits,
        snapshot_bits=snapshot_bits,
        total_bits=delta_bits + snapshot_bits,
        dense_bits=dense_bits,
        savings=savings,
    )
