"""Paper §III-D: when is compression beneficial? (Fig. 9)

    cost_comp        = M * (4/T_m + 1/T_f + 1/T_p + 1/T_s)
    saved_cost_comm  = M/T_comm * (1 - 1/k)
    beneficial  <=>  2*cost_comp < saved_cost_comm
    k_min        =   1 / (1 - 2*T_comm*(4/T_m + 1/T_f + 1/T_p + 1/T_s))

(T_* are throughputs; the compress+decompress pair costs 2x, hence the 2.)
``k_min`` <= 0 or undefined means NO compression ratio can pay for itself on
that link — the compression pipeline is slower than just sending the bytes.

Default throughputs are TPU-v5e-adapted estimates derived from the roofline
terms of the Pallas kernels (bytes touched / 819 GB/s HBM for the
bandwidth-bound passes; MXU-limited for the 4-step FFT), replacing the paper's
V100 numbers.  The paper's measured GPU numbers are kept for reproducing
Fig. 9 exactly.
"""

from __future__ import annotations

import dataclasses

__all__ = ["Throughputs", "PAPER_V100", "TPU_V5E", "compression_cost_s",
           "saved_comm_s", "k_min", "is_beneficial", "NETWORKS"]


@dataclasses.dataclass(frozen=True)
class Throughputs:
    """All in bytes/second."""

    t_m: float  # precision change / thresholding (O(N), elementwise)
    t_f: float  # FFT
    t_p: float  # pack
    t_s: float  # top-k select

    def inv_sum(self) -> float:
        return 4.0 / self.t_m + 1.0 / self.t_f + 1.0 / self.t_p + 1.0 / self.t_s


# Paper's V100-era numbers (pack measured at 34 GB/s on V100; others scaled
# from cuFFT/Thrust throughput at ~10^2 GB/s class memory bandwidth).
PAPER_V100 = Throughputs(t_m=300e9, t_f=150e9, t_p=34e9, t_s=100e9)

# TPU v5e estimates from kernel napkin math (see fft4step.py docstring):
#   t_m: elementwise quant: 5 bytes/elem over 819 GB/s HBM -> ~650 GB/s eff.
#   t_f: 4-step FFT: 3.1 MFLOP / 16 KiB chunk; f32 MXU ~50 TFLOP/s
#        -> ~8 GFLOP/s per GB/s => ~260 GB/s input-byte throughput.
#   t_p: one-hot-matmul pack: k*F MACs per F elems; MXU-bound ~200 GB/s.
#   t_s: 26 compare+count VMEM sweeps -> HBM-bound read once ~600 GB/s.
TPU_V5E = Throughputs(t_m=650e9, t_f=260e9, t_p=200e9, t_s=600e9)

# network byte-throughputs (practical, not line-rate)
NETWORKS = {
    "10GbE": 1.1e9,
    "56Gb-FDR": 6.0e9,  # paper's practical 6 GB/s
    "100Gb-EDR": 11.0e9,
    "tpu-dcn-host": 12.5e9,  # inter-pod DCN per host
    "tpu-ici-link": 50.0e9,  # intra-pod per link
}


def compression_cost_s(message_bytes: float, thr: Throughputs) -> float:
    return message_bytes * thr.inv_sum()


def saved_comm_s(message_bytes: float, t_comm: float, k: float) -> float:
    return message_bytes / t_comm * (1.0 - 1.0 / k)


def k_min(t_comm: float, thr: Throughputs) -> float:
    """Minimal beneficial compression ratio; inf if never beneficial."""
    denom = 1.0 - 2.0 * t_comm * thr.inv_sum()
    if denom <= 0.0:
        return float("inf")
    return 1.0 / denom


def is_beneficial(message_bytes: float, t_comm: float, k: float, thr: Throughputs) -> bool:
    return 2.0 * compression_cost_s(message_bytes, thr) < saved_comm_s(
        message_bytes, t_comm, k
    )
