"""Overlap engine: backprop-interleaved bucket streaming (DESIGN.md §15).

The stacked executor (§14) minimizes LAUNCHES: one collective per exchange,
issued after the whole gradient exists.  This module implements the other
half of the paper's communication strategy — HIDING the exchange behind the
backward pass.  Buckets are assigned reverse-topological readiness ranks
from the model's parameter order (``bucketing.readiness_ranks``: the flat
buffer is parameter order, backprop finalizes gradients from the top down),
grouped into dispatch groups, and each group's compress+exchange is issued
as soon as its gradients are final — first-ready group first.  Inside a
jitted train step each group's subgraph depends ONLY on its own slice of
the flat gradient, which is exactly the dependence structure XLA's
latency-hiding scheduler needs to start group g's collective while earlier
(lower-offset) gradients are still being computed.

Three schedules, selected by ``ReducerConfig.schedule``:

* ``stacked``  — §14 behavior: one collective after backprop (latency-
  optimal: pays collective-launch α once; nothing overlaps).
* ``streamed`` — this module: one collective per readiness group, issued in
  readiness order (bandwidth-optimal: exchange time hides behind backprop;
  pays α per group).
* ``auto``     — the policy layer: picks per model between the two by the
  cost model (``choose_schedule``) — stacked for latency-bound exchanges
  (small/shallow models, tiny payloads where α·n dominates), streamed for
  bandwidth-bound ones (deep models whose backprop is long enough to hide
  the wire time).

Bitwise contract: a streamed exchange produces EXACTLY the stacked
exchange's bytes and means.  Groups are contiguous bucket ranges, so every
bucket keeps its own boundaries, its own quantizer fit, and its own payload
slots; the worker mean folds in the same left-to-right order per group
(``transport._ordered_worker_mean`` is elementwise, so grouping cannot
reorder it); and error-feedback residuals are sliced per readiness group
with the same boundaries that split the gradient.  ``streamed`` vs
``stacked`` may not move one bit of the training trajectory
(tests/test_scheduler.py) — the schedule is a dispatch-shape choice, never
a numerics choice.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.comms import bucketing, cost_model
from repro.comms.bucketing import BucketLayout
# reassembly helper moved to transport.py with the run() redesign
# (DESIGN.md §20); the alias keeps this module's historical import path
# (executor.streamed_roundtrip_fn) working
from repro.comms.transport import _concat_index_order  # noqa: F401

__all__ = [
    "SCHEDULE_NAMES",
    "StreamPlan",
    "build_plan",
    "exchange_streamed",
    "local_roundtrip_streamed",
    "ScheduleDecision",
    "TransportDecision",
    "choose_schedule",
    "choose_transport",
    "modeled_backprop_s",
    "resolve_schedule",
    "resolve_transport",
    "BACKPROP_FLOPS_PER_S",
    "DEFAULT_BATCH_TOKENS",
    "DEFAULT_WORKERS",
]

SCHEDULE_NAMES = ("stacked", "streamed", "auto")

# Modeled backward-pass compute rate for the policy layer — re-exported
# from the cost model, where it is documented as an UNCALIBRATED DEFAULT
# (comms/calibrate.py measures the real rate into CostProfile).
BACKPROP_FLOPS_PER_S = cost_model.BACKPROP_FLOPS_PER_S

# Worker-count assumption when the caller cannot supply the mesh's gradient
# axis size (a reducer built outside a train step).  Two is the smallest
# mesh that exchanges at all; gather-transport wire only grows with P, so
# this is the conservative case for stacked.  build_train_step always
# passes the REAL axis size (the workers=2 mispricing was a bug).
DEFAULT_WORKERS = 2

# Batch-token assumption when the caller cannot supply one (a reducer built
# outside a train step).  The decision rule is a pure function of its
# inputs, so a documented default keeps `auto` deterministic everywhere.
DEFAULT_BATCH_TOKENS = 4096


# ---------------------------------------------------------------------------
# stream plan: readiness-ordered dispatch groups
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Dispatch schedule of one streamed exchange.

    ``groups`` are contiguous bucket ranges ``[lo, hi)`` listed in READINESS
    order — ``groups[0]`` covers the highest flat offsets (first gradients
    out of backprop) and is dispatched first.  A frozen/hashable pure value
    (like ``BucketLayout``): equal layouts yield equal plans, so the
    executor's jit cache can key on it and every worker derives the same
    schedule from the same pytree.
    """

    layout: BucketLayout
    groups: Tuple[Tuple[int, int], ...]

    def __post_init__(self):
        n = self.layout.n_buckets
        flat = [b for lo, hi in sorted(self.groups) for b in range(lo, hi)]
        if flat != list(range(n)):
            raise ValueError(
                f"groups {self.groups} do not partition {n} buckets")
        for (lo_a, _), (lo_b, _) in zip(self.groups, self.groups[1:]):
            if lo_b >= lo_a:
                raise ValueError(
                    f"groups must be readiness-ordered (descending offsets): "
                    f"{self.groups}")

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    def group_slices(self):
        """Per group, readiness-ordered: (flat_lo, flat_hi, sub_layout)."""
        out = []
        for lo_b, hi_b in self.groups:
            out.append((self.layout.boundaries[lo_b],
                        self.layout.boundaries[hi_b],
                        bucketing.sub_layout(self.layout, lo_b, hi_b)))
        return out

    def group_fractions(self) -> Tuple[float, ...]:
        """Element fraction of each group (readiness order) — the cost
        model's proxy for both its share of the payload and the point in
        the backward pass at which it becomes final."""
        total = float(self.layout.total)
        return tuple(
            (self.layout.boundaries[hi] - self.layout.boundaries[lo]) / total
            for lo, hi in self.groups)


def build_plan(layout: BucketLayout, n_groups: Optional[int] = None) -> StreamPlan:
    """Readiness-ordered dispatch groups over a bucket layout.

    ``n_groups=None`` streams one group per bucket (finest dispatch grain —
    maximum overlap surface, α per bucket).  Smaller counts merge ADJACENT
    buckets (groups must stay contiguous in the flat space) as evenly as
    possible, assigned from the top of the flat buffer down so every group
    is a readiness run.  Pure function of ``(layout, n_groups)``.
    """
    n = layout.n_buckets
    g = n if n_groups is None else max(1, min(int(n_groups), n))
    # split [0, n) into g contiguous ranges, sizes as even as possible, then
    # list them top-down (readiness order)
    base, extra = divmod(n, g)
    ranges = []
    lo = 0
    for i in range(g):
        hi = lo + base + (1 if i < extra else 0)
        ranges.append((lo, hi))
        lo = hi
    return StreamPlan(layout, tuple(reversed(ranges)))


# ---------------------------------------------------------------------------
# streamed execution: one collective per readiness group, issued in order
# ---------------------------------------------------------------------------


def _warn_streamed_deprecated(old: str) -> None:
    warnings.warn(
        f"scheduler.{old}() is deprecated; call Transport.run(flat, "
        f"comp=..., plan=..., axis=...) instead (DESIGN.md §20)",
        DeprecationWarning, stacklevel=3)


def exchange_streamed(transport, flat: jnp.ndarray, plan: StreamPlan, comp,
                      axis: str, stacked: bool = True,
                      monitor=None) -> jnp.ndarray:
    """Deprecated shim over ``Transport.run(plan=...)`` (DESIGN.md §20).

    The streamed dispatch semantics — one collective per readiness group,
    traced first-ready first, reassembled in index order, bitwise the
    stacked exchange — now live on the transport's single entry point.
    """
    _warn_streamed_deprecated("exchange_streamed")
    return transport.run(flat, comp=comp, plan=plan, axis=axis,
                         stacked=stacked, monitor=monitor)


def local_roundtrip_streamed(transport, flat: jnp.ndarray, plan: StreamPlan,
                             comp, stacked: bool = True) -> jnp.ndarray:
    """Deprecated shim over ``Transport.run(plan=..., axis=None)``: the
    compress->decompress reconstruction at the streamed dispatch
    granularity (what streamed error feedback accumulates against)."""
    _warn_streamed_deprecated("local_roundtrip_streamed")
    return transport.run(flat, comp=comp, plan=plan, stacked=stacked)


# ---------------------------------------------------------------------------
# policy layer: stacked vs streamed, decided by the cost model
# ---------------------------------------------------------------------------


def modeled_backprop_s(n_params: int, batch_tokens: int,
                       flops_per_s: float = BACKPROP_FLOPS_PER_S) -> float:
    """Modeled backward-pass wall time: ~4 FLOPs per parameter per token
    (forward is 2·N·T, backward twice that — the standard 6·N·T split)."""
    return 4.0 * float(n_params) * float(batch_tokens) / flops_per_s


@dataclasses.dataclass(frozen=True)
class ScheduleDecision:
    """The auto policy's verdict plus the numbers behind it."""

    schedule: str  # "stacked" | "streamed"
    stacked_step_s: float  # backprop + serialized stacked exchange
    streamed_step_s: float  # max(backprop, streamed finish)
    overlap_efficiency: float  # streamed: fraction of exchange time hidden
    n_groups: int
    backprop_s: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def choose_schedule(
    plan: StreamPlan,
    message_bytes: float,
    payload_bits: float,
    *,
    workers: int,
    transport: str,
    backprop_s: float,
    t_comm: Optional[float] = None,
    thr: Optional[cost_model.Throughputs] = None,
    alpha_s: Optional[float] = None,
    profile=None,
    wire_mode: str = "runtime",
    topology: Optional[Tuple[int, int]] = None,
) -> ScheduleDecision:
    """The auto decision rule (DESIGN.md §15/§17).

    stacked step time  = backprop + (α·1 + compress + wire), serialized;
    streamed step time = the readiness-timeline finish
    (``cost_model.streamed_exchange_time_s``).  Streamed wins when the
    backward pass is long enough to hide the per-group exchanges despite
    paying α per group — deep, bandwidth-bound models; stacked wins when
    α·n_groups dominates — small, latency-bound models.

    Pricing inputs left ``None`` resolve from ``profile`` (a measured
    ``calibrate.CostProfile``) or the documented uncalibrated defaults.  A
    DECISION must price the bytes today's lowering actually moves, so the
    default ``wire_mode`` is ``"runtime"`` — for the psum transport that is
    the dense dequantized spectrum, not the sparse-allreduce endpoint the
    trajectory-planning model (``wire_mode="modeled"``) prices.
    """
    stacked_plan = cost_model.exchange_time_s(
        message_bytes, payload_bits, t_comm, thr, workers=workers,
        transport=transport, n_buckets=plan.layout.n_buckets, stacked=True,
        alpha_s=alpha_s, profile=profile, wire_mode=wire_mode,
        chunk=plan.layout.chunk, topology=topology)
    streamed_plan = cost_model.streamed_exchange_time_s(
        message_bytes, payload_bits, t_comm, thr, workers=workers,
        transport=transport, group_fractions=plan.group_fractions(),
        backprop_s=backprop_s, alpha_s=alpha_s, profile=profile,
        wire_mode=wire_mode, chunk=plan.layout.chunk, topology=topology)
    stacked_step = backprop_s + stacked_plan.exchange_s
    streamed_step = streamed_plan.step_s
    return ScheduleDecision(
        schedule="streamed" if streamed_step < stacked_step else "stacked",
        stacked_step_s=stacked_step,
        streamed_step_s=streamed_step,
        overlap_efficiency=streamed_plan.overlap_efficiency,
        n_groups=plan.n_groups,
        backprop_s=backprop_s,
    )


def resolve_schedule(
    config,
    n_elems: int,
    batch_tokens: Optional[int] = None,
    *,
    workers: Optional[int] = None,
    profile=None,
    topology: Optional[Tuple[int, int]] = None,
) -> Tuple[str, Optional[ScheduleDecision]]:
    """Resolve a ``ReducerConfig.schedule`` to a concrete name.

    Pure function of ``(config, n_elems, batch_tokens, workers, profile)`` —
    the same inputs always yield the same schedule (tests/test_scheduler.py).
    Non-auto schedules pass through; ``auto`` runs :func:`choose_schedule`
    with the config's own layout/payload model.  The monolithic cases —
    allgather transport or a single-bucket layout — have nothing to stream
    and resolve to ``stacked``.

    ``workers`` is the gradient-axis size of the live mesh
    (``build_train_step`` passes it); ``None`` falls back to the documented
    :data:`DEFAULT_WORKERS` assumption.  ``profile`` is a measured
    ``calibrate.CostProfile``: with one, α–β, the stage throughputs AND the
    backprop length come from measurements (``profile.backprop_s``) instead
    of the static constants.
    """
    if config.schedule != "auto":
        return config.schedule, None
    layout = config.layout_for(n_elems)
    if config.transport == "allgather" or layout.n_buckets == 1:
        return "stacked", None
    comp = _wire_model_compressor(config)
    if comp is None:  # no wire model (dense): nothing to decide
        return "stacked", None
    payload_bits = cost_model.bucketed_payload_bits(
        comp.wire_bits, layout.sizes(), config.transport,
        stacked=True, chunk=layout.chunk)
    plan = build_plan(layout, config.stream_groups)
    tokens = DEFAULT_BATCH_TOKENS if batch_tokens is None else batch_tokens
    p = DEFAULT_WORKERS if workers is None else int(workers)
    if profile is not None:
        backprop_s = profile.backprop_s(n_elems, tokens)
    else:
        backprop_s = modeled_backprop_s(n_elems, tokens)
    decision = choose_schedule(
        plan, 4.0 * n_elems, payload_bits,
        workers=p, transport=config.transport,
        backprop_s=backprop_s, profile=profile, topology=topology)
    return decision.schedule, decision


# ---------------------------------------------------------------------------
# policy layer: flat vs hierarchical transport, decided by the cost model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransportDecision:
    """The transport auto policy's verdict plus the numbers behind it."""

    transport: str  # "psum" | "hierarchical"
    flat_exchange_s: float  # flat psum over the combined axes
    hier_exchange_s: float  # two-level island reduce + fabric gather
    nodes: int
    local: int
    inter_bits_per_worker: float  # hierarchical's fabric share per worker
    flat_wire_bits: float  # flat psum's per-worker runtime wire

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def choose_transport(
    n_elems: int,
    payload_bits: float,
    *,
    nodes: int,
    local: int,
    n_buckets: int = 1,
    chunk: int = 4096,
    profile=None,
) -> TransportDecision:
    """Flat ``psum`` vs ``hierarchical`` on a (nodes, local) topology.

    Both candidates are priced in ``wire_mode="runtime"`` (decisions price
    today's lowering, DESIGN.md §17): flat psum ring-reduces the dense
    spectrum over all ``nodes·local`` workers at one link rate;
    hierarchical pays the same dense psum only inside the island plus
    ``nodes`` compressed payloads per island on the fabric, each hop at its
    own (per-axis, when calibrated) α–β.  Hierarchical wins exactly when
    the fabric is slow enough that shrinking its traffic to one payload per
    island beats the second compression pass it costs.
    """
    workers = int(nodes) * int(local)
    flat = cost_model.exchange_time_s(
        4.0 * n_elems, payload_bits, workers=workers, transport="psum",
        n_buckets=n_buckets, stacked=True, profile=profile,
        wire_mode="runtime", chunk=chunk)
    hier = cost_model.two_level_exchange_time_s(
        4.0 * n_elems, payload_bits, nodes=nodes, local=local,
        profile=profile, wire_mode="runtime", chunk=chunk)
    return TransportDecision(
        transport=("hierarchical"
                   if hier.exchange_s < flat.exchange_s else "psum"),
        flat_exchange_s=flat.exchange_s,
        hier_exchange_s=hier.exchange_s,
        nodes=int(nodes),
        local=int(local),
        inter_bits_per_worker=hier.wire.inter_bits_per_worker,
        flat_wire_bits=flat.wire_bits_per_worker,
    )


def resolve_transport(
    config,
    n_elems: int,
    *,
    topology: Optional[Tuple[int, int]] = None,
    profile=None,
) -> Tuple[str, Optional[TransportDecision]]:
    """Resolve ``ReducerConfig.transport`` to a concrete name.

    Non-``auto`` transports pass through untouched.  ``auto`` needs a
    ``topology`` (the live mesh's (nodes, local) over the reducer's
    exchange axes — ``build_train_step`` derives it); a degenerate topology
    (one node, or one worker per node — no island to exploit) resolves to
    flat ``psum`` without pricing, as does a config with no wire model
    (dense) whose payload the candidates cannot price.  Pure function of
    its inputs, like :func:`resolve_schedule`.
    """
    if config.transport != "auto":
        return config.transport, None
    if topology is None or topology[0] <= 1 or topology[1] <= 1:
        return "psum", None
    comp = _wire_model_compressor(config)
    if comp is None:
        return "psum", None
    layout = config.layout_for(n_elems)
    payload_bits = cost_model.bucketed_payload_bits(
        comp.wire_bits, layout.sizes(), "psum",
        stacked=True, chunk=layout.chunk)
    decision = choose_transport(
        n_elems, payload_bits, nodes=topology[0], local=topology[1],
        n_buckets=layout.n_buckets, chunk=layout.chunk, profile=profile)
    return decision.transport, decision


def _wire_model_compressor(config):
    """A compressor instance for wire_bits pricing (None when kind has no
    static wire model, e.g. dense)."""
    from repro.comms.reducers import _make_compressor

    try:
        comp = _make_compressor(config)
    except ValueError:
        return None
    return comp if hasattr(comp, "wire_bits") else None
