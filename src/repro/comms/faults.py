"""Deterministic fault injection + resilience primitives (DESIGN.md §19).

The resilience layer has three cooperating pieces:

* **FaultPlan** — a frozen, hashable plan of typed fault events, threaded
  through ``ReducerConfig.faults`` (in-step events: poisoned gradients,
  corrupted payloads) and ``TrainLoopConfig.faults`` (host-side events:
  step crashes, straggler delays).  Every event is pinned to a (step,
  worker) coordinate, so a chaos run is exactly reproducible on fake
  devices — the harness replaces the old untyped ``failure_injector``
  callable.

* **ExchangeMonitor** — rides along one compressed exchange inside the
  jitted step.  At each payload-creation site the transport hands the
  payload over; the monitor (a) injects any planned wire corruption for
  this (step, worker) and (b) folds the payload's validation verdict into
  one boolean.  Validation levels (``ReducerConfig.validate``):

  - ``off``   — no checks, no overhead (the default; payload creation is
                untouched and the reducer keeps its historical signature);
  - ``cheap`` — structural sanity per payload: index bounds vs the chunk
                width, quantizer-param sanity (finite, eps > 0,
                ``vmin <= vmax``, P in range), finiteness of any float
                plane.  O(payload) elementwise work, no extra collectives;
  - ``full``  — ``cheap`` plus per-plane checksums: planes are checksummed
                at compress time (before the simulated wire) and re-summed
                after, so silent bit corruption in the value planes — which
                decodes to plausible floats — is still caught.

* **ReducerHealth** — the host-side health record the train loop keeps:
  skipped-step counts, straggler delays, and every degradation-ladder
  transition (``reducers.degrade_config``), serialized into run results
  and BENCH artifacts.

The guard decision itself (skip the optimizer update, quarantine the EF
residual) lives in ``train/step.py``; this module only provides the
deterministic ingredients.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "NanGrad",
    "PayloadCorrupt",
    "StepCrash",
    "SlowWorker",
    "FaultPlan",
    "InjectedCrash",
    "FatalInjectedCrash",
    "VALIDATE_LEVELS",
    "ExchangeMonitor",
    "payload_checksums",
    "tree_finite",
    "validate_payload",
    "corrupt_payload",
    "match_events",
    "ReducerHealth",
]

VALIDATE_LEVELS = ("off", "cheap", "full")

CORRUPT_PLANES = ("values", "idx", "quant")


class InjectedCrash(RuntimeError):
    """A planned, recoverable step failure (exercises rollback/retry)."""


class FatalInjectedCrash(Exception):
    """A planned process death.  Deliberately NOT a RuntimeError: the train
    loop's recovery path must never catch it — it propagates out of
    ``train_loop`` like a SIGKILL would, and the harness simulates the
    restart by calling ``train_loop`` again (auto-resume picks up the last
    checkpoint)."""


# ---------------------------------------------------------------------------
# typed events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NanGrad:
    """Worker ``worker``'s local gradient becomes all-NaN at ``step``."""

    step: int
    worker: int
    kind: ClassVar[str] = "nan_grad"


@dataclasses.dataclass(frozen=True)
class PayloadCorrupt:
    """Worker ``worker``'s outgoing payload is corrupted at ``step``.

    ``plane`` picks the corruption site: ``idx`` (out-of-bounds index,
    caught at validate>=cheap), ``quant`` (NaN quantizer eps, caught at
    cheap), or ``values`` (silent mantissa bit-flips in the value plane —
    decodes to finite floats, only the ``full`` checksums catch it).
    """

    step: int
    worker: int
    plane: str = "idx"
    kind: ClassVar[str] = "payload_corrupt"

    def __post_init__(self):
        if self.plane not in CORRUPT_PLANES:
            raise ValueError(
                f"unknown corrupt plane {self.plane!r}; expected one of "
                f"{CORRUPT_PLANES}")


@dataclasses.dataclass(frozen=True)
class StepCrash:
    """The host step raises at ``step`` (before the step function runs).

    ``fatal=False`` raises :class:`InjectedCrash` (a recoverable
    RuntimeError — exercises rollback and the degradation ladder);
    ``fatal=True`` raises :class:`FatalInjectedCrash` (simulated process
    death — exercises checkpoint auto-resume).  Each event fires at most
    once per :class:`TrainLoopConfig` (a restarted process does not re-hit
    a transient crash), so resume-after-crash runs to completion.
    """

    step: int
    fatal: bool = False
    kind: ClassVar[str] = "step_crash"


@dataclasses.dataclass(frozen=True)
class SlowWorker:
    """Worker ``worker`` stalls ``delay_s`` seconds at ``step`` (host-side
    sleep; in the single-process harness every worker shares the host, so
    the whole step is delayed — the observable is the ``dt`` metric)."""

    step: int
    worker: int
    delay_s: float = 0.05
    kind: ClassVar[str] = "slow_worker"


_EVENT_TYPES = (NanGrad, PayloadCorrupt, StepCrash, SlowWorker)
EVENT_KINDS = {cls.kind: cls for cls in _EVENT_TYPES}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A frozen, hashable schedule of fault events.

    Hashable and comparable so it can live on the frozen ``ReducerConfig``
    (jit caches keyed on the config keep working); JSON round-trippable
    (``to_dicts``/``from_dicts``) so the lab's jax-free ``ExperimentSpec``
    can carry fault rows as plain dicts.
    """

    events: Tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        for e in self.events:
            if not isinstance(e, _EVENT_TYPES):
                raise TypeError(f"not a fault event: {e!r}")

    # -- selectors ----------------------------------------------------------

    @property
    def nan_events(self) -> Tuple[NanGrad, ...]:
        return tuple(e for e in self.events if isinstance(e, NanGrad))

    @property
    def corrupt_events(self) -> Tuple[PayloadCorrupt, ...]:
        return tuple(e for e in self.events if isinstance(e, PayloadCorrupt))

    @property
    def has_exchange_faults(self) -> bool:
        """True when any event must be threaded into the jitted exchange."""
        return bool(self.nan_events or self.corrupt_events)

    def crashes_at(self, step: int) -> List[Tuple[int, StepCrash]]:
        """(event_index, event) of every crash planned at ``step`` — the
        loop tracks fired indices so each crash fires once."""
        return [(i, e) for i, e in enumerate(self.events)
                if isinstance(e, StepCrash) and e.step == step]

    def delay_at(self, step: int) -> float:
        return sum(e.delay_s for e in self.events
                   if isinstance(e, SlowWorker) and e.step == step)

    # -- JSON ----------------------------------------------------------------

    def to_dicts(self) -> List[Dict]:
        return [dict(kind=e.kind, **dataclasses.asdict(e)) for e in self.events]

    @classmethod
    def from_dicts(cls, dicts: Optional[List[Dict]]) -> Optional["FaultPlan"]:
        if not dicts:
            return None
        events = []
        for d in dicts:
            d = dict(d)
            kind = d.pop("kind")
            if kind not in EVENT_KINDS:
                raise ValueError(
                    f"unknown fault kind {kind!r}; expected one of "
                    f"{sorted(EVENT_KINDS)}")
            events.append(EVENT_KINDS[kind](**d))
        return cls(tuple(events))


def match_events(events, step, worker=None):
    """Traced OR over events: does any event hit this (step, worker)?

    ``step``/``worker`` are traced i32 scalars; event coordinates are
    static Python ints, so the match lowers to a handful of fused
    compares — identical on every worker for the step part, per-worker
    for the worker part (bitwise-replicated decisions).
    """
    hit = jnp.bool_(False)
    for e in events:
        h = jnp.asarray(step) == e.step
        if worker is not None and hasattr(e, "worker"):
            h = h & (jnp.asarray(worker) == e.worker)
        hit = hit | h
    return hit


# ---------------------------------------------------------------------------
# payload validation / corruption
# ---------------------------------------------------------------------------


def _leaf_checksum(x) -> jnp.ndarray:
    """uint32 wrap-around sum of a plane's raw bits (order-independent)."""
    x = jnp.asarray(x)
    if x.size == 0:
        return jnp.uint32(0)
    if jnp.issubdtype(x.dtype, jnp.floating):
        bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    else:
        bits = x.astype(jnp.uint32)
    return bits.sum(dtype=jnp.uint32)


def payload_checksums(payload) -> Tuple[jnp.ndarray, ...]:
    """Per-plane uint32 checksums over any payload pytree."""
    return tuple(_leaf_checksum(l) for l in jax.tree_util.tree_leaves(payload))


def tree_finite(tree) -> jnp.ndarray:
    """Traced AND of ``isfinite`` over every float leaf of a pytree."""
    ok = jnp.bool_(True)
    for leaf in jax.tree_util.tree_leaves(tree):
        leaf = jnp.asarray(leaf)
        if jnp.issubdtype(leaf.dtype, jnp.floating) and leaf.size:
            ok = ok & jnp.isfinite(leaf).all()
    return ok


def validate_payload(payload, level: str, *, reference_checksums=None):
    """jnp bool scalar: is this payload structurally sound at ``level``?

    Payload classes that define ``.validate(level)`` (``FFTPayload`` /
    ``StackedPayload``) get their structural checks; anything else
    (terngrad/qsgd tuples) gets generic float-finiteness.  At ``full``,
    ``reference_checksums`` (from :func:`payload_checksums` at compress
    time) are compared against the payload's current checksums.
    """
    if level not in VALIDATE_LEVELS:
        raise ValueError(
            f"unknown validate level {level!r}; expected one of {VALIDATE_LEVELS}")
    if level == "off":
        return jnp.bool_(True)
    if hasattr(payload, "validate"):
        ok = payload.validate(level)
    else:
        ok = tree_finite(payload)
    if level == "full" and reference_checksums is not None:
        for got, want in zip(payload_checksums(payload), reference_checksums):
            ok = ok & (got == want)
    return ok


def _flip_bits(plane, hit):
    """Silent corruption: flip low mantissa/code bits where ``hit``.

    Mantissa-only flips keep floats finite — the point is corruption that
    ``cheap`` validation CANNOT see (caught only by ``full`` checksums).
    """
    plane = jnp.asarray(plane)
    if plane.size == 0:
        return plane
    if jnp.issubdtype(plane.dtype, jnp.floating):
        bits = jax.lax.bitcast_convert_type(plane.astype(jnp.float32), jnp.uint32)
        flipped = jax.lax.bitcast_convert_type(
            bits ^ jnp.uint32(0x000FFF00), plane.dtype)
    else:
        flipped = (plane.astype(jnp.uint32) ^ jnp.uint32(0x55)).astype(plane.dtype)
    return jnp.where(hit, flipped, plane)


def corrupt_payload(payload, plane_hits: Dict[str, jnp.ndarray]):
    """Apply per-plane corruption masks to an FFT/Stacked payload.

    ``plane_hits`` maps plane name -> traced bool scalar.  Non-FFT payloads
    (baseline compressors) pass through untouched — the chaos lane targets
    the paper's codec.
    """
    if not (hasattr(payload, "idx") and hasattr(payload, "re")):
        return payload
    out = payload
    hit = plane_hits.get("values")
    if hit is not None:
        out = dataclasses.replace(out, re=_flip_bits(out.re, hit))
    hit = plane_hits.get("idx")
    if hit is not None:
        # one past the last valid bin: unambiguously out of [0, chunk)
        bad = jnp.asarray(out.chunk, out.idx.dtype)
        out = dataclasses.replace(
            out, idx=jnp.where(hit, bad, out.idx))
    hit = plane_hits.get("quant")
    if hit is not None and out.quant is not None:
        q = out.quant
        bad_eps = jnp.where(hit, jnp.float32(jnp.nan), q.eps)
        out = dataclasses.replace(
            out, quant=type(q)(q.config, bad_eps, q.p_codes, q.vmax, q.vmin))
    return out


class ExchangeMonitor:
    """Per-exchange corruption injector + validation accumulator.

    One monitor is created per traced reduce call (so its state is local
    to the trace); transports hand every locally created payload through
    :meth:`on_payload` before it reaches a collective.  ``ok()`` is the
    worker-local AND of every payload verdict — the step guard combines it
    across workers with a pmin so the skip decision is replicated.
    """

    def __init__(self, level: str = "off", *, step=None, worker=None,
                 corrupt: Tuple[PayloadCorrupt, ...] = ()):
        if level not in VALIDATE_LEVELS:
            raise ValueError(
                f"unknown validate level {level!r}; expected one of "
                f"{VALIDATE_LEVELS}")
        self.level = level
        self.step = step
        self.worker = worker
        self.corrupt = tuple(corrupt)
        self._ok = jnp.bool_(True)

    def on_payload(self, payload):
        reference = (payload_checksums(payload)
                     if self.level == "full" else None)
        if self.corrupt and self.step is not None and self.worker is not None:
            hits = {
                plane: match_events(
                    tuple(e for e in self.corrupt if e.plane == plane),
                    self.step, self.worker)
                for plane in CORRUPT_PLANES
                if any(e.plane == plane for e in self.corrupt)
            }
            payload = corrupt_payload(payload, hits)
        if self.level != "off":
            self._ok = self._ok & validate_payload(
                payload, self.level, reference_checksums=reference)
        return payload

    def ok(self) -> jnp.ndarray:
        return self._ok


# ---------------------------------------------------------------------------
# health record
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ReducerHealth:
    """Host-side record of guard skips and degradation-ladder transitions."""

    skipped_steps: int = 0
    skip_steps: List[int] = dataclasses.field(default_factory=list)
    delays: int = 0
    transitions: List[Dict] = dataclasses.field(default_factory=list)

    def record_skip(self, step: int):
        self.skipped_steps += 1
        self.skip_steps.append(int(step))

    def record_delay(self, step: int):
        self.delays += 1

    def record_transition(self, step: int, rung: str, reason: str):
        self.transitions.append(
            {"step": int(step), "rung": rung, "reason": str(reason)})

    def to_dict(self) -> Dict:
        return {
            "skipped_steps": int(self.skipped_steps),
            "skip_steps": list(self.skip_steps),
            "delays": int(self.delays),
            "transitions": list(self.transitions),
        }
