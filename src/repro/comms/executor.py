"""Cached, end-to-end jitted entry points for the batched bucket executor
(DESIGN.md §14).

Inside a jitted train step the stacked exchange is just traced code — but the
hot paths that drive compression from Python (benchmarks, the perf smoke,
error-feedback probes, any eager caller) used to pay one dispatch per bucket
per call, and re-trace whenever they rebuilt their jit wrapper.  This module
owns ONE jit cache for those callers, keyed on everything that shapes the
executable:

    (entry point, compressor class, compressor config, bucket layout)

``FFTCompressorConfig`` and ``BucketLayout`` are frozen/hashable dataclasses,
so the key is a pure value — two compressors with equal configs share one
executable, and a config or layout change is a new cache line, never a
silent retrace of an old one.

Buffer donation: the flat gradient is donated to the compiled call where the
platform supports it (TPU/GPU), so the compress consumes its input buffer in
place — the steady-state cost of a call is one executable launch, no defensive
copy.  On CPU donation is not implemented by the runtime and is skipped to
avoid per-call warnings.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax

from repro.comms import bucketing

__all__ = ["compress_fn", "roundtrip_fn", "looped_compress_fn",
           "streamed_compress_fn", "streamed_roundtrip_fn", "cache_size",
           "clear_cache"]

_CACHE: Dict[Tuple, Callable] = {}


def _donate_argnums() -> tuple:
    # donation is a no-op (with a warning) on the CPU runtime
    return (0,) if jax.default_backend() in ("tpu", "gpu") else ()


def _key(tag: str, comp, layout: bucketing.BucketLayout, donate: bool):
    return (tag, type(comp).__name__, comp.config, layout, donate)


def compress_fn(comp, layout: bucketing.BucketLayout, *, donate: bool = True):
    """flat -> ``StackedPayload``: one cached jitted launch for ALL buckets."""
    key = _key("compress", comp, layout, donate)
    if key not in _CACHE:
        def run(flat):
            return comp.compress_stacked(
                bucketing.stack_buckets(flat, layout), layout.sizes())

        _CACHE[key] = jax.jit(
            run, donate_argnums=_donate_argnums() if donate else ())
    return _CACHE[key]


def roundtrip_fn(comp, layout: bucketing.BucketLayout, *, donate: bool = False):
    """flat -> flat reconstruction through the full stacked
    compress -> decompress path (what error feedback accumulates against),
    as one cached jitted executable.

    Donation is OFF by default here: the canonical use computes a residual
    against the input afterwards (``residual = corrected - roundtrip``), so
    donating the input would invalidate it on TPU/GPU.  Opt in only when the
    caller truly discards the input."""
    key = _key("roundtrip", comp, layout, donate)
    if key not in _CACHE:
        def run(flat):
            payload = comp.compress_stacked(
                bucketing.stack_buckets(flat, layout), layout.sizes())
            return bucketing.unstack_buckets(
                comp.decompress_stacked(payload), layout)

        _CACHE[key] = jax.jit(
            run, donate_argnums=_donate_argnums() if donate else ())
    return _CACHE[key]


def looped_compress_fn(comp, layout: bucketing.BucketLayout):
    """flat -> list of per-bucket payloads via the PER-BUCKET loop, jitted as
    one program — the pre-stacked execution shape, kept as the parity/bench
    baseline (its compile time grows with the bucket count; the stacked
    executable's does not)."""
    key = _key("looped", comp, layout, False)
    if key not in _CACHE:
        def run(flat):
            return comp.compress_buckets(bucketing.split_buckets(flat, layout))

        _CACHE[key] = jax.jit(run)
    return _CACHE[key]


def streamed_compress_fn(comp, plan):
    """flat -> list of per-GROUP ``StackedPayload``s, readiness-ordered
    (overlap engine, DESIGN.md §15).

    One cached jitted executable per dispatch group, launched in readiness
    order: this is the eager-driver analog of the streamed train-step path —
    group g's executable consumes only its flat slice, so its (async) device
    work overlaps the host's dispatch of the remaining groups.  Each group's
    cache key carries its absolute flat range plus its sub-layout (both pure
    values), so equal plans share executables group for group.

    Donation is structurally OFF here: every group reads a slice of the SAME
    flat buffer, so donating it to the first group's executable would
    invalidate the input for the rest — the one entry point where the §14
    donation rule cannot apply (documented, not silently skipped).
    """
    fns = []
    for lo, hi, sub in plan.group_slices():
        # the key must carry the group's ABSOLUTE flat range: two parent
        # layouts can share an identical sub-layout at different offsets,
        # and the compiled closure bakes the slice in
        key = _key(f"streamed_compress[{lo}:{hi}]", comp, sub, False)
        if key not in _CACHE:
            def run(flat, lo=lo, hi=hi, sub=sub):
                return comp.compress_stacked(
                    bucketing.stack_buckets(flat[lo:hi], sub), sub.sizes())

            _CACHE[key] = jax.jit(run)
        fns.append(_CACHE[key])

    def dispatch(flat):
        return [fn(flat) for fn in fns]  # readiness order, async launches

    return dispatch


def streamed_roundtrip_fn(comp, plan):
    """flat -> flat reconstruction through the streamed dispatch shape: one
    cached jitted roundtrip per readiness group, reassembled in index order
    (what streamed error feedback accumulates against)."""
    fns = []
    for lo, hi, sub in plan.group_slices():
        key = _key(f"streamed_roundtrip[{lo}:{hi}]", comp, sub, False)
        if key not in _CACHE:
            def run(flat, lo=lo, hi=hi, sub=sub):
                payload = comp.compress_stacked(
                    bucketing.stack_buckets(flat[lo:hi], sub), sub.sizes())
                return bucketing.unstack_buckets(
                    comp.decompress_stacked(payload), sub)

            _CACHE[key] = jax.jit(run)
        fns.append(_CACHE[key])

    def dispatch(flat):
        # readiness-order launches; reassembly helper shared with the traced
        # streamed paths (lazy import: scheduler depends on cost_model, and
        # this module must stay importable first from comms/__init__)
        from repro.comms.scheduler import _concat_index_order

        parts = [fn(flat) for fn in fns]
        return _concat_index_order(parts)

    return dispatch


def cache_size() -> int:
    return len(_CACHE)


def clear_cache() -> None:
    _CACHE.clear()
