"""Explicit collective schedules on jax.lax primitives (shard_map context).

XLA's built-in all_reduce/all_gather are the production path; the explicit
ring implementations here exist because the paper's contribution lives in the
collective schedule: a ring step is a ``ppermute``, and interleaving
compression work between permute steps is how compute/comm overlap is
expressed on TPU (paper §IV-C).  They are also the reference for the
collective-bytes accounting in the roofline (analysis/hlo.py counts these ops
in lowered HLO).
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

__all__ = ["AxisSpec", "normalize_axes", "axis_size", "axis_sizes",
           "axis_linear_index", "ring_all_gather", "ring_reduce_scatter",
           "ring_all_reduce"]

# A gradient-sync axis spec: one mesh axis name, or a tuple of names for the
# multi-axis collectives the two-level transports ride (DESIGN.md §18).
AxisSpec = Union[str, Sequence[str]]


def normalize_axes(axis: AxisSpec) -> Union[str, Tuple[str, ...]]:
    """Canonicalize an axis spec: str passes through, any other sequence
    becomes a tuple of names (lists from JSON-ish config land here).  A
    single-name tuple stays a tuple — collectives treat both spellings
    identically, so no silent unwrapping."""
    if isinstance(axis, str):
        return axis
    axes = tuple(axis)
    if not axes or not all(isinstance(a, str) for a in axes):
        raise ValueError(
            f"axis spec must be a name or a non-empty sequence of names, "
            f"got {axis!r}")
    return axes


def axis_size(axis_name: AxisSpec) -> int:
    """Worker count over one mesh axis OR a tuple of axes (their product).

    ``jax.lax.psum`` accepts a tuple of axis names natively; this wrapper
    only normalizes the spelling (lists become tuples) so callers holding a
    config-provided axis spec never trip the silent single-axis assumption
    the pre-topology code had.
    """
    return jax.lax.psum(1, normalize_axes(axis_name))


def axis_sizes(axes: AxisSpec) -> Tuple[int, ...]:
    """Per-axis worker counts, in spec order (shard_map context)."""
    norm = normalize_axes(axes)
    if isinstance(norm, str):
        norm = (norm,)
    return tuple(jax.lax.psum(1, a) for a in norm)


def axis_linear_index(axes: AxisSpec):
    """Row-major linear worker index over one axis or a tuple of axes.

    Equivalent to ``jax.lax.axis_index(tuple)`` but spelled out so it works
    on every jax generation the repo straddles (0.4.x included).
    """
    norm = normalize_axes(axes)
    if isinstance(norm, str):
        return jax.lax.axis_index(norm)
    idx = jax.lax.axis_index(norm[0])
    for a in norm[1:]:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def ring_all_gather(x: jnp.ndarray, axis_name: str, *, reverse: bool = False):
    """All-gather via n-1 ppermute steps; returns (n, *x.shape).

    Equivalent to jax.lax.all_gather(x, axis_name) but with an explicit ring
    schedule a caller can interleave work into (see ``on_step``-style usage in
    reducers).
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = out.at[idx].set(x)
    buf = x
    step = -1 if reverse else 1
    for i in range(1, n):
        perm = [(j, (j + step) % n) for j in range(n)]
        buf = jax.lax.ppermute(buf, axis_name, perm)
        src = (idx - step * i) % n
        out = out.at[src].set(buf)
    return out


def ring_reduce_scatter(x: jnp.ndarray, axis_name: str):
    """Reduce-scatter via n-1 ppermute+add steps.

    ``x`` (n*s, ...) is viewed as n shards of s rows; returns this device's
    reduced shard (s, ...).
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    assert x.shape[0] % n == 0, "leading dim must divide the axis size"
    shards = x.reshape((n, x.shape[0] // n) + x.shape[1:])
    # The accumulator passed around at step i carries chunk (d + n-1-i) mod n
    # on device d; each device adds its local copy of that chunk.  After n-1
    # steps device d holds the fully reduced chunk d.
    acc = shards[(idx + n - 1) % n]
    for i in range(1, n):
        perm = [(j, (j + 1) % n) for j in range(n)]
        acc = jax.lax.ppermute(acc, axis_name, perm)
        acc = acc + shards[(idx + n - 1 - i) % n]
    return acc


def ring_all_reduce(
    x: jnp.ndarray,
    axis_name: str,
    *,
    shard_hook: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
):
    """Ring all-reduce = reduce-scatter + all-gather (the classic 2(n-1)/n).

    ``shard_hook`` runs on the reduced shard between the two phases — this is
    where per-shard compression slots in so only compressed bytes ride the
    all-gather half of the ring.
    """
    n = axis_size(axis_name)
    pad = (-x.shape[0]) % n
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    shard = ring_reduce_scatter(xp, axis_name)
    if shard_hook is not None:
        shard = shard_hook(shard)
    full = ring_all_gather(shard, axis_name)
    full = full.reshape((-1,) + x.shape[1:])
    return full[: x.shape[0]]
