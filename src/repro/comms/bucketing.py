"""Deterministic, chunk-aligned bucketing of the flat gradient space.

The seed reducer concatenated every gradient leaf into ONE flat buffer and
exchanged it with a single collective — nothing could be pipelined against
backprop and wire traffic grew O(workers).  This module is layer (1) of the
bucketed exchange (DESIGN.md §8): it partitions the *flat index space*
``[0, total)`` into size-targeted buckets whose interior boundaries are
multiples of the FFT chunk, so that

* every bucket except possibly the last is an exact number of chunks (no
  padding waste, and per-chunk top-k selection is IDENTICAL to the monolithic
  path — bucketing never changes which coefficients are kept);
* unpadding is exact: each bucket remembers its own unpadded length and the
  compressor slices its zero-padding tail off on inverse;
* the error-feedback residual (one flat f32 vector, same length as the
  gradient) is sliced per bucket with the same boundaries, so each bucket
  owns an independent residual slice (DESIGN.md §8).

The layout is a pure function of ``(total, bucket_bytes, chunk)`` — every
worker derives the same layout from the same pytree, no negotiation needed.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import fft as cfft

__all__ = [
    "BucketLayout",
    "build_layout",
    "split_buckets",
    "concat_buckets",
    "stack_buckets",
    "unstack_buckets",
    "residual_size",
    "readiness_ranks",
    "readiness_order",
    "sub_layout",
]


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Partition of the flat index space ``[0, total)`` into buckets.

    ``boundaries`` has ``n_buckets + 1`` entries, starts at 0, ends at
    ``total``, is strictly increasing, and every interior boundary is a
    multiple of ``chunk``.
    """

    total: int
    boundaries: Tuple[int, ...]
    chunk: int

    def __post_init__(self):
        b = self.boundaries
        if len(b) < 2 or b[0] != 0 or b[-1] != self.total:
            raise ValueError(f"bad boundaries {b} for total={self.total}")
        if any(lo >= hi for lo, hi in zip(b, b[1:])):
            raise ValueError(f"boundaries must be strictly increasing: {b}")
        if any(x % self.chunk for x in b[1:-1]):
            raise ValueError(f"interior boundaries must be chunk-aligned: {b}")

    @property
    def n_buckets(self) -> int:
        return len(self.boundaries) - 1

    def sizes(self) -> Tuple[int, ...]:
        return tuple(
            hi - lo for lo, hi in zip(self.boundaries, self.boundaries[1:])
        )

    def bounds(self, b: int) -> Tuple[int, int]:
        return self.boundaries[b], self.boundaries[b + 1]

    # -- stacked (batched-executor) geometry, DESIGN.md §14 -----------------

    def chunk_counts(self) -> Tuple[int, ...]:
        """Per-bucket chunk count BEFORE stacking pads rows to a common width
        (the compressor pads each bucket to whole chunks either way)."""
        return tuple(-(-s // self.chunk) for s in self.sizes())

    @property
    def max_chunks(self) -> int:
        """Row width of the stacked matrix, in chunks."""
        return max(self.chunk_counts())

    @property
    def padded_size(self) -> int:
        """Row width of the stacked matrix, in elements (chunk multiple)."""
        return self.max_chunks * self.chunk

    @property
    def uniform(self) -> bool:
        """True when every bucket already fills a full row (no ragged tail);
        stack/unstack are then pure reshapes."""
        return all(s == self.padded_size for s in self.sizes())


def build_layout(
    total: int,
    bucket_bytes: Optional[int],
    chunk: int = cfft.DEFAULT_CHUNK,
    dtype_bytes: int = 4,
) -> BucketLayout:
    """Size-targeted partition: ~``bucket_bytes`` per bucket, chunk-aligned.

    ``bucket_bytes=None`` (or a target at least as large as the buffer) yields
    a single bucket — the seed's monolithic behavior.  The per-bucket element
    target is rounded UP to a chunk multiple so no bucket is smaller than one
    chunk; the final bucket absorbs the ragged tail.
    """
    if total <= 0:
        raise ValueError(f"total must be positive, got {total}")
    if bucket_bytes is None or bucket_bytes >= total * dtype_bytes:
        return BucketLayout(total, (0, total), chunk)
    target = max(1, bucket_bytes // dtype_bytes)
    # round up to a whole number of chunks (alignment floor: one chunk)
    target = max(chunk, -(-target // chunk) * chunk)
    boundaries = list(range(0, total, target))
    # a tail shorter than one chunk rides the previous bucket instead of
    # becoming a degenerate sub-chunk bucket
    if total - boundaries[-1] < chunk and len(boundaries) > 1:
        boundaries.pop()
    boundaries.append(total)
    return BucketLayout(total, tuple(boundaries), chunk)


def split_buckets(flat: jnp.ndarray, layout: BucketLayout) -> List[jnp.ndarray]:
    """Static-shape views of the flat buffer, one per bucket."""
    if flat.shape[0] != layout.total:
        raise ValueError(f"flat has {flat.shape[0]} elems, layout {layout.total}")
    return [flat[lo:hi] for lo, hi in zip(layout.boundaries, layout.boundaries[1:])]


def concat_buckets(parts: Sequence[jnp.ndarray], layout: BucketLayout) -> jnp.ndarray:
    """Inverse of :func:`split_buckets`; checks sizes match the layout."""
    sizes = tuple(int(p.shape[0]) for p in parts)
    if sizes != layout.sizes():
        raise ValueError(f"part sizes {sizes} != layout sizes {layout.sizes()}")
    return parts[0] if len(parts) == 1 else jnp.concatenate(list(parts))


def stack_buckets(flat: jnp.ndarray, layout: BucketLayout) -> jnp.ndarray:
    """Flat buffer -> uniform ``(n_buckets, padded_size)`` matrix.

    The batched executor's input layout (DESIGN.md §14): every bucket becomes
    one row, zero-padded on the right to the widest bucket's chunk-rounded
    width.  Zero padding is exact for the compressor — whole padding chunks
    produce all-zero spectra whose payload slots quantize to code 0, and the
    per-bucket quantizer fit masks padding chunks out — so stacked payloads
    stay bitwise-equal to the per-bucket loop (tests/test_stacked.py).  When
    no bucket is ragged this is a pure reshape (no copy beyond XLA's).
    """
    if flat.shape[0] != layout.total:
        raise ValueError(f"flat has {flat.shape[0]} elems, layout {layout.total}")
    padded = layout.padded_size
    if layout.uniform:
        return flat.reshape(layout.n_buckets, padded)
    rows = []
    for lo, hi in zip(layout.boundaries, layout.boundaries[1:]):
        if hi - lo == padded:
            rows.append(flat[lo:hi])
        else:
            # same padding op as cfft.pad_to_chunks: zeros + prefix set
            rows.append(
                jnp.zeros((padded,), flat.dtype).at[: hi - lo].set(flat[lo:hi]))
    return jnp.stack(rows)


def unstack_buckets(stacked: jnp.ndarray, layout: BucketLayout) -> jnp.ndarray:
    """Inverse of :func:`stack_buckets`: slice each row's padding tail off and
    concatenate back to the flat buffer."""
    if stacked.shape != (layout.n_buckets, layout.padded_size):
        raise ValueError(
            f"stacked is {stacked.shape}, layout wants "
            f"{(layout.n_buckets, layout.padded_size)}")
    if layout.uniform:
        return stacked.reshape(-1)
    return jnp.concatenate(
        [stacked[b, :s] for b, s in enumerate(layout.sizes())])


# ---------------------------------------------------------------------------
# readiness metadata (overlap engine, DESIGN.md §15)
#
# The flat index space is PARAMETER order: leaf 0 (the embedding / first
# layer) occupies the lowest offsets, the head the highest.  Backprop visits
# the model in reverse, so gradients become FINAL from the top of the flat
# buffer downward — the bucket covering the highest offsets is ready first.
# Readiness is therefore a pure function of the layout (itself a pure
# function of the model's parameter order): no per-step bookkeeping, every
# worker derives the identical schedule.
# ---------------------------------------------------------------------------


def readiness_ranks(layout: BucketLayout) -> Tuple[int, ...]:
    """Per-bucket readiness rank: rank 0 becomes final FIRST under backprop.

    Reverse-topological in the flat parameter order: bucket ``n_buckets-1``
    (highest offsets == parameters used last in the forward pass, whose
    gradients backprop emits first) gets rank 0.
    """
    n = layout.n_buckets
    return tuple(n - 1 - b for b in range(n))


def readiness_order(layout: BucketLayout) -> Tuple[int, ...]:
    """Bucket indices sorted first-ready first — derived from the rank map
    (for the pure-reversal ranks the permutation is its own inverse, so the
    two views coincide; deriving keeps them coupled if ranks ever change)."""
    ranks = readiness_ranks(layout)
    return tuple(sorted(range(layout.n_buckets), key=ranks.__getitem__))


def sub_layout(layout: BucketLayout, lo_bucket: int, hi_bucket: int) -> BucketLayout:
    """The layout of buckets ``[lo_bucket, hi_bucket)`` over their own flat
    slice ``[boundaries[lo_bucket], boundaries[hi_bucket])`` re-based to 0.

    A contiguous bucket range is a contiguous flat range (buckets partition
    the index space in order), so a streamed dispatch group can reuse every
    flat entry point — stack/unstack, transports, the batched executor — on
    its slice with an ordinary layout.  Bucket boundaries (and hence payload
    codes and per-bucket quantizer fits) are EXACTLY the parent layout's.
    """
    if not (0 <= lo_bucket < hi_bucket <= layout.n_buckets):
        raise ValueError(
            f"bad bucket range [{lo_bucket}, {hi_bucket}) for "
            f"{layout.n_buckets} buckets")
    base = layout.boundaries[lo_bucket]
    bounds = tuple(x - base for x in layout.boundaries[lo_bucket : hi_bucket + 1])
    return BucketLayout(bounds[-1], bounds, layout.chunk)


def residual_size(params) -> int:
    """Flat residual length for error-feedback state allocation.

    The residual is one flat vector over the whole gradient; per-bucket
    residual slices are views through the same :class:`BucketLayout` that
    splits the gradient, so state allocation needs no layout knowledge.
    """
    leaves = jax.tree_util.tree_leaves(params)
    return sum(int(l.size) for l in leaves)
