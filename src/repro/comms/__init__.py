"""Distributed gradient exchange: dense / compressed / hierarchical reducers
built on jax.lax collectives under shard_map (no NCCL/MPI emulation).

Four layers (DESIGN.md §8-§9, §15): ``bucketing`` partitions the flat
gradient into chunk-aligned buckets (with backprop-readiness metadata),
``transport`` exchanges each bucket through a pluggable collective strategy,
``scheduler`` decides the dispatch shape (stacked single collective vs
backprop-interleaved streaming), and ``reducers`` composes it all under the
mesh axes (plus error feedback).  ``cost_model`` prices the choices."""

from repro.comms import (
    bucketing,
    collectives,
    cost_model,
    executor,
    scheduler,
    transport,
)
from repro.comms.reducers import ReducerConfig, make_reducer
from repro.comms.scheduler import SCHEDULE_NAMES
from repro.comms.transport import TRANSPORT_NAMES, get_transport

__all__ = [
    "ReducerConfig",
    "make_reducer",
    "bucketing",
    "collectives",
    "cost_model",
    "executor",
    "scheduler",
    "transport",
    "get_transport",
    "TRANSPORT_NAMES",
    "SCHEDULE_NAMES",
]
