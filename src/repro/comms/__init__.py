"""Distributed gradient exchange: dense / compressed / hierarchical reducers
built on jax.lax collectives under shard_map (no NCCL/MPI emulation).

Three layers (DESIGN.md §8-§9): ``bucketing`` partitions the flat gradient
into chunk-aligned buckets, ``transport`` exchanges each bucket through a
pluggable collective strategy, and ``reducers`` composes both under the mesh
axes (plus error feedback).  ``cost_model`` prices the choices."""

from repro.comms import bucketing, collectives, cost_model, executor, transport
from repro.comms.reducers import ReducerConfig, make_reducer
from repro.comms.transport import get_transport, TRANSPORT_NAMES

__all__ = [
    "ReducerConfig",
    "make_reducer",
    "bucketing",
    "collectives",
    "cost_model",
    "executor",
    "transport",
    "get_transport",
    "TRANSPORT_NAMES",
]
