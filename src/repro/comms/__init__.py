"""Distributed gradient exchange: dense / compressed / hierarchical reducers
built on jax.lax collectives under shard_map (no NCCL/MPI emulation)."""

from repro.comms.reducers import ReducerConfig, make_reducer
from repro.comms import collectives, cost_model

__all__ = ["ReducerConfig", "make_reducer", "collectives", "cost_model"]
