"""Pluggable exchange strategies for compressed gradient buckets.

Layer (2) of the bucketed exchange (DESIGN.md §9).  A transport turns a list
of per-bucket flat gradients into the list of their cross-worker means, using
one compressor.  All transports compute the SAME mean — mean over the axis of
the per-worker dequantized reconstructions — they differ in which collective
carries the bytes and at what granularity:

============== =========================== ============================== =========
name           collective                  per-worker wire (cost model)   overlap
============== =========================== ============================== =========
allgather      one all_gather of the       P · B  (P payloads land on     none
               monolithic payload          every worker)
sequenced      one all_gather PER BUCKET   P · B  total, issued as        buckets
               (independent collectives)   n_buckets independent ops      pipeline
psum           per-bucket psum of the      B      (in-network reduction:  buckets
               locally dequantized         each worker injects its kept
               spectrum                    coefficients once; P-free)
hierarchical   intra-node spectra psum     inter-node: nodes·B per NODE   buckets
               ('local' axis) -> ONE       (one compressed payload per
               re-compressed payload per   island crosses the fabric);
               island -> inter-node        intra-node: dense-spectrum
               all_gather ('node' axis)    psum on the fast link
reduce_scatter psum_scatter of spectra     2·(P-1)/P of the dense         buckets
               over the BUCKET axis; each  planes (ring-allreduce-
               worker iFFTs its own        shaped: gather-path wire
               contiguous sub_layout       stops growing with P)
               range, then all_gather
============== =========================== ============================== =========

``B = comp.wire_bits(n)`` at equal theta; see ``cost_model.transport_wire_bits``
for the model the acceptance tests assert against (the psum column prices the
sparse-allreduce endpoint; today's lowering is a dense-spectrum psum — see
``_psum_mean_payload``).

The psum transport exploits FFT linearity (DESIGN.md §10): sum of spectra ==
spectrum of the sum, so workers dequantize locally, sum spectra with a single
``psum``, and run ONE inverse FFT on the mean spectrum.  For non-spectral
compressors (timedomain/terngrad/qsgd) it degrades gracefully to a psum of the
dense local reconstruction — still numerically identical to the all-gather
mean, still O(1) payloads per worker in the cost model.

Quantizer granularity: the monolithic ``allgather`` transport fits ONE
quantizer over the whole buffer (seed behavior); ``sequenced`` and ``psum``
compress per bucket, so each bucket fits its own range (small buckets stop
inheriting a global range — see ``FFTCompressor.compress_buckets``).

Two-level topology (DESIGN.md §18): the ``hierarchical`` transport takes a
TUPLE axis spec ``(node_axis, local_axis)`` over a 2-D mesh
(``launch.mesh.make_two_level_mesh``).  FFT linearity makes the intra-node
hop a plain ``psum`` of dequantized spectra over the fast link; the node
mean is re-compressed ONCE so the slow fabric moves exactly one compressed
``StackedPayload`` per island; the inter-node all_gather's result is
replicated over the local axis by construction (the psum already
broadcast), so the intra-node broadcast costs nothing extra.  The
``reduce_scatter`` transport is flat (one axis or a tuple treated as one
flattened axis) but partitions the BUCKET axis: ``psum_scatter`` hands each
worker the reduced spectra of its own contiguous ``sub_layout`` range, the
worker runs the inverse FFT only on its shard, and a tiled all_gather
rebuilds the flat buffer — per-worker wire is ring-allreduce-shaped
(2·(P-1)/P of the dense planes) instead of growing with P like the gather
transports.

One entry point (DESIGN.md §20): every consumer — the stacked executor
(§14), the streamed overlap engine (§15), error feedback, and the serving
publisher — calls ``Transport.run(flat, comp=..., ...)``:

* ``layout=``            one stacked dispatch over the whole layout;
* ``plan=``              a ``StreamPlan``: one dispatch per readiness group,
                         issued first-ready first, reassembled in index
                         order (bitwise the stacked result);
* ``axis=None``          no collective: the local compress->decompress
                         roundtrip at the exchange's own granularity (what
                         error feedback accumulates against);
* ``axis="data"``/tuple  the cross-worker mean over that mesh axis.

The legacy names (``exchange``, ``exchange_flat``, ``local_roundtrip``,
``local_roundtrip_flat``, and ``scheduler.exchange_streamed`` /
``local_roundtrip_streamed``) remain as thin deprecated shims over ``run``
and emit ``DeprecationWarning``.

With ``stacked=True`` (the default) and a stacked-capable compressor, each
dispatch compresses EVERY bucket with one batched kernel pass
(``compress_stacked``) and moves ONE ``StackedPayload`` per collective —
while staying bitwise-equal to the per-bucket loop (per-bucket quantizers
included).  ``stacked=False`` or a loop-only compressor (terngrad/qsgd)
falls back to the per-bucket path.
"""

from __future__ import annotations

import warnings
from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.comms import bucketing
from repro.comms.collectives import axis_size
from repro.core import fft as cfft

__all__ = ["Transport", "get_transport", "TRANSPORT_NAMES", "two_level_axes"]

TRANSPORT_NAMES = ("allgather", "sequenced", "psum", "hierarchical",
                   "reduce_scatter")


def two_level_axes(axis) -> tuple:
    """Validate a hierarchical transport's axis spec -> (node_axis, local_axis).

    The hierarchical transport is the only one whose two hops ride DIFFERENT
    links, so it refuses a flat axis instead of silently degenerating: the
    caller must say which axis is the slow fabric and which the fast
    intra-node link.
    """
    if (isinstance(axis, (tuple, list)) and len(axis) == 2
            and all(isinstance(a, str) for a in axis)):
        return tuple(axis)
    raise ValueError(
        f"hierarchical transport needs axis=(node_axis, local_axis) over a "
        f"2-D mesh (launch.mesh.make_two_level_mesh), got {axis!r}")


def _warn_deprecated(old: str) -> None:
    warnings.warn(
        f"Transport.{old}() is deprecated; call Transport.run(flat, "
        f"comp=..., layout=/plan=..., axis=...) instead (DESIGN.md §20)",
        DeprecationWarning, stacklevel=3)


def _concat_index_order(parts):
    """Readiness-ordered group results -> flat buffer in index order.

    ``StreamPlan`` groups are strictly descending in the flat space
    (validated in ``StreamPlan.__post_init__``), so index order is exactly
    the reverse of dispatch order."""
    ordered = list(reversed(parts))
    return ordered[0] if len(ordered) == 1 else jnp.concatenate(ordered)


def _compress_all(buckets: Sequence[jnp.ndarray], comp, monitor=None) -> List:
    """Per-bucket payloads; FFTCompressor fits one quantizer per bucket.

    ``monitor`` (comms.faults.ExchangeMonitor, DESIGN.md §19) intercepts
    every locally created payload before it reaches a collective: planned
    wire corruption is injected and the validation verdict accumulated.
    ``None`` (the default) is the zero-overhead path.
    """
    if hasattr(comp, "compress_buckets"):
        payloads = comp.compress_buckets(buckets)
    else:
        payloads = [comp.compress(b) for b in buckets]
    if monitor is not None:
        payloads = [monitor.on_payload(p) for p in payloads]
    return payloads


def _can_stack(comp) -> bool:
    return hasattr(comp, "compress_stacked")


def _compress_stacked(flat: jnp.ndarray, layout, comp, monitor=None):
    """ONE batched compress of every bucket (same quantizer granularity as
    the per-bucket loop: one fit per bucket row)."""
    payload = comp.compress_stacked(
        bucketing.stack_buckets(flat, layout), layout.sizes())
    return payload if monitor is None else monitor.on_payload(payload)


def _irfft_rows(mean_spectrum: jnp.ndarray, chunk: int) -> jnp.ndarray:
    """(B, max_chunks, f) mean spectrum -> (B, padded_size) time domain."""
    x = jnp.fft.irfft(mean_spectrum, n=chunk, axis=-1)
    return x.reshape(mean_spectrum.shape[0], -1).astype(jnp.float32)


def _ordered_worker_mean(stacked: jnp.ndarray) -> jnp.ndarray:
    """Mean over the leading (worker) axis as a left-to-right fold.

    The fold order matters for bitwise reproducibility, not correctness: the
    CPU backend's all-reduce sums contributions in worker order, so folding the
    gathered reconstructions the same way makes the gather transports produce
    bit-identical means to the psum transport (seeded-determinism contract,
    tests/test_transports.py).  ``jnp.mean``'s pairwise reduction would differ
    by ~1 ulp and the divergence compounds over training steps.
    """
    p = stacked.shape[0]
    acc = stacked[0]
    for w in range(1, p):
        acc = acc + stacked[w]
    return acc * (1.0 / p)


def _gather_mean_payload(payload, comp, axis: str) -> jnp.ndarray:
    """Seed exchange: all_gather one payload -> mean reconstruction.

    For spectral compressors the mean is taken in the frequency domain and a
    single inverse FFT recovers the time-domain mean (FFT linearity).
    """
    gathered = jax.lax.all_gather(payload, axis)  # leading axis: workers
    if hasattr(comp, "decompress_spectrum"):
        spectra = jax.vmap(comp.decompress_spectrum)(gathered)
        mean_spectrum = _ordered_worker_mean(spectra)
        return cfft.chunked_irfft(mean_spectrum, payload.orig_len, payload.chunk)
    decompressed = jax.vmap(comp.decompress)(gathered)
    return _ordered_worker_mean(decompressed)


def _psum_mean_payload(payload, comp, axis: str) -> jnp.ndarray:
    """Dequantize locally -> psum -> /P (-> one iFFT if spectral).

    NOTE: ``jax.lax.psum`` here moves the DENSE dequantized spectrum — this
    is the reference implementation of the psum semantics, not the O(k)
    wire-optimal sparse allreduce the cost model prices (see
    ``cost_model.transport_wire_bits``).  Even dense it beats the payload
    all-gather once P > 2F/k, and XLA may further optimize the reduction.
    """
    inv_p = 1.0 / axis_size(axis)
    if hasattr(comp, "decompress_spectrum"):
        spec = comp.decompress_spectrum(payload)
        # psum real/imag planes separately: complex psum support varies by
        # backend, and two f32 reductions lower to one fused collective anyway
        summed = jax.lax.psum(jnp.stack([spec.real, spec.imag]), axis)
        mean_spectrum = (summed[0] + 1j * summed[1]) * inv_p
        return cfft.chunked_irfft(mean_spectrum, payload.orig_len, payload.chunk)
    return jax.lax.psum(comp.decompress(payload), axis) * inv_p


class Transport:
    """Exchange interface.

    The single public entry point is :meth:`run`; subclasses implement the
    private dispatch hooks:

    * ``_exchange_flat`` / ``_roundtrip_flat`` — the batched-executor paths
      (whole flat buffer + bucket layout), overridden with stacked
      single-collective implementations;
    * ``_exchange_buckets`` / ``_roundtrip_buckets`` — the per-bucket loop
      fallback (and the path for compressors with no stacked support).

    ``run(axis=None)`` exposes the compress->decompress reconstruction at
    the SAME granularity the transport ships at, so error feedback
    accumulates exactly what this transport drops (per-bucket quantizers
    and all).
    """

    name: str = "base"

    # -- the single public entry point (DESIGN.md §20) ----------------------

    def run(self, flat: jnp.ndarray, *, comp, layout=None, axis=None,
            plan=None, stacked: bool = True, monitor=None) -> jnp.ndarray:
        """One dispatch surface for every exchange shape.

        Args:
          flat: the whole flat f32 buffer (gradient, delta, ...).
          comp: the compressor carrying the wire codec.
          layout: ``BucketLayout`` for one stacked dispatch over the whole
            buffer.  Mutually exclusive with ``plan``.
          axis: mesh axis name (or tuple for two-level transports) to mean
            over; ``None`` runs the LOCAL compress->decompress roundtrip —
            no collective — at the transport's own granularity.
          plan: a ``scheduler.StreamPlan``: dispatch one collective per
            readiness group, first-ready first, and reassemble in index
            order (bitwise the ``layout=`` result; DESIGN.md §15).
          stacked: batched single-collective path (default) vs the
            per-bucket loop.
          monitor: ``comms.faults.ExchangeMonitor`` threading the resilience
            layer through every payload-creation site; the roundtrip
            (error-feedback) path is deliberately NOT monitored — the
            residual never crosses the wire (DESIGN.md §19).

        Returns the flat mean (``axis`` given) or the flat reconstruction
        (``axis=None``), same shape as ``flat``.
        """
        if plan is not None:
            if layout is not None:
                raise ValueError("run() takes layout= or plan=, not both")
            parts = [
                self._run_one(flat[lo:hi], sub, comp, axis, stacked, monitor)
                for lo, hi, sub in plan.group_slices()  # readiness order
            ]
            return _concat_index_order(parts)
        if layout is None:
            raise ValueError("run() needs a layout= or a plan=")
        return self._run_one(flat, layout, comp, axis, stacked, monitor)

    def _run_one(self, flat, layout, comp, axis, stacked, monitor):
        if axis is None:
            return self._roundtrip_flat(flat, layout, comp, stacked)
        return self._exchange_flat(flat, layout, comp, axis, stacked, monitor)

    # -- deprecated shims (kept for one release; DESIGN.md §20) -------------

    def exchange(self, buckets: Sequence[jnp.ndarray], comp, axis: str,
                 monitor=None) -> List[jnp.ndarray]:
        _warn_deprecated("exchange")
        return self._exchange_buckets(buckets, comp, axis, monitor=monitor)

    def local_roundtrip(self, buckets: Sequence[jnp.ndarray],
                        comp) -> List[jnp.ndarray]:
        _warn_deprecated("local_roundtrip")
        return self._roundtrip_buckets(buckets, comp)

    def exchange_flat(self, flat: jnp.ndarray, layout, comp, axis: str,
                      stacked: bool = True, monitor=None) -> jnp.ndarray:
        _warn_deprecated("exchange_flat")
        return self.run(flat, comp=comp, layout=layout, axis=axis,
                        stacked=stacked, monitor=monitor)

    def local_roundtrip_flat(self, flat: jnp.ndarray, layout, comp,
                             stacked: bool = True) -> jnp.ndarray:
        _warn_deprecated("local_roundtrip_flat")
        return self.run(flat, comp=comp, layout=layout, stacked=stacked)

    # -- per-bucket loop hooks ----------------------------------------------

    def _exchange_buckets(self, buckets: Sequence[jnp.ndarray], comp,
                          axis: str, monitor=None) -> List[jnp.ndarray]:
        raise NotImplementedError

    def _roundtrip_buckets(self, buckets: Sequence[jnp.ndarray],
                           comp) -> List[jnp.ndarray]:
        return [comp.decompress(p) for p in _compress_all(buckets, comp)]

    # -- flat (batched-executor) hooks, DESIGN.md §14 ------------------------

    def _exchange_flat(self, flat: jnp.ndarray, layout, comp, axis: str,
                       stacked: bool = True, monitor=None) -> jnp.ndarray:
        """Whole-gradient exchange over a bucket layout -> flat mean.

        Default: the per-bucket loop (split -> exchange -> concat).  Stacked
        transports override this with the single-collective path.
        """
        del stacked  # loop fallback ignores the flag
        buckets = bucketing.split_buckets(flat, layout)
        return bucketing.concat_buckets(
            self._exchange_buckets(buckets, comp, axis, monitor=monitor),
            layout)

    def _roundtrip_flat(self, flat: jnp.ndarray, layout, comp,
                        stacked: bool = True) -> jnp.ndarray:
        del stacked
        buckets = bucketing.split_buckets(flat, layout)
        return bucketing.concat_buckets(
            self._roundtrip_buckets(buckets, comp), layout)


class AllGatherTransport(Transport):
    """Seed behavior: ONE monolithic payload all_gather, global quantizer."""

    name = "allgather"

    def _exchange_buckets(self, buckets, comp, axis, monitor=None):
        sizes = [int(b.shape[0]) for b in buckets]
        flat = buckets[0] if len(buckets) == 1 else jnp.concatenate(list(buckets))
        payload = comp.compress(flat)
        if monitor is not None:
            payload = monitor.on_payload(payload)
        mean = _gather_mean_payload(payload, comp, axis)
        return _resplit(mean, sizes)

    def _roundtrip_buckets(self, buckets, comp):
        sizes = [int(b.shape[0]) for b in buckets]
        flat = buckets[0] if len(buckets) == 1 else jnp.concatenate(list(buckets))
        return _resplit(comp.decompress(comp.compress(flat)), sizes)

    # monolithic by definition: already one payload, one collective — the
    # flat entry points skip the bucket split/concat entirely
    def _exchange_flat(self, flat, layout, comp, axis, stacked=True,
                       monitor=None):
        del layout, stacked
        payload = comp.compress(flat)
        if monitor is not None:
            payload = monitor.on_payload(payload)
        return _gather_mean_payload(payload, comp, axis)

    def _roundtrip_flat(self, flat, layout, comp, stacked=True):
        del layout, stacked
        return comp.decompress(comp.compress(flat))


class SequencedTransport(Transport):
    """Bucketed all_gather with per-bucket quantizer ranges.

    Stacked (default): ONE all_gather of the whole exchange's
    ``StackedPayload`` — a single collective launch carrying every bucket's
    codes, indices, and quantizer params as struct-of-arrays planes.  Looped
    fallback: one independent all_gather PER BUCKET (XLA's latency-hiding
    scheduler may pipeline them, at n_buckets collective launches).  Both
    paths realize the same mean bitwise.
    """

    name = "sequenced"

    def _exchange_buckets(self, buckets, comp, axis, monitor=None):
        payloads = _compress_all(buckets, comp, monitor)
        return [_gather_mean_payload(p, comp, axis) for p in payloads]

    def _exchange_flat(self, flat, layout, comp, axis, stacked=True,
                       monitor=None):
        if not (stacked and _can_stack(comp)):
            return super()._exchange_flat(flat, layout, comp, axis, stacked,
                                          monitor=monitor)
        payload = _compress_stacked(flat, layout, comp, monitor)
        gathered = jax.lax.all_gather(payload, axis)  # ONE collective
        if hasattr(comp, "decompress_spectrum"):
            spectra = jax.vmap(comp.decompress_spectrum)(gathered)
            mean = _ordered_worker_mean(spectra)  # (B, max_chunks, f)
            return bucketing.unstack_buckets(
                _irfft_rows(mean, layout.chunk), layout)
        recon = jax.vmap(comp.decompress_stacked)(gathered)  # (W, B, padded)
        return bucketing.unstack_buckets(_ordered_worker_mean(recon), layout)

    def _roundtrip_flat(self, flat, layout, comp, stacked=True):
        if not (stacked and _can_stack(comp)):
            return super()._roundtrip_flat(flat, layout, comp, stacked)
        payload = _compress_stacked(flat, layout, comp)
        return bucketing.unstack_buckets(
            comp.decompress_stacked(payload), layout)


class SpectrumPsumTransport(Transport):
    """Psum of dequantized spectra: O(k) wire, P-independent.

    Stacked (default): every bucket's dequantized spectrum rides ONE psum of
    the ``(2, n_buckets, max_chunks, f)`` plane stack — a single collective
    launch — followed by one batched inverse FFT.  Looped fallback: one psum
    per bucket.
    """

    name = "psum"

    def _exchange_buckets(self, buckets, comp, axis, monitor=None):
        payloads = _compress_all(buckets, comp, monitor)
        return [_psum_mean_payload(p, comp, axis) for p in payloads]

    def _exchange_flat(self, flat, layout, comp, axis, stacked=True,
                       monitor=None):
        if not (stacked and _can_stack(comp)):
            return super()._exchange_flat(flat, layout, comp, axis, stacked,
                                          monitor=monitor)
        payload = _compress_stacked(flat, layout, comp, monitor)
        inv_p = 1.0 / axis_size(axis)
        if hasattr(comp, "decompress_spectrum"):
            spec = comp.decompress_spectrum(payload)  # (B, max_chunks, f)
            summed = jax.lax.psum(jnp.stack([spec.real, spec.imag]), axis)
            mean = (summed[0] + 1j * summed[1]) * inv_p
            return bucketing.unstack_buckets(
                _irfft_rows(mean, layout.chunk), layout)
        summed = jax.lax.psum(comp.decompress_stacked(payload), axis)
        return bucketing.unstack_buckets(summed * inv_p, layout)

    def _roundtrip_flat(self, flat, layout, comp, stacked=True):
        if not (stacked and _can_stack(comp)):
            return super()._roundtrip_flat(flat, layout, comp, stacked)
        payload = _compress_stacked(flat, layout, comp)
        return bucketing.unstack_buckets(
            comp.decompress_stacked(payload), layout)


class HierarchicalTransport(Transport):
    """Two-level exchange over a (node, local) mesh (DESIGN.md §18).

    Dataflow per exchange (stacked path, spectral compressor):

    1. every worker runs the chunked rfft of its buckets — the DENSE
       spectrum, no thresholding: the intra-node psum moves dense spectra
       planes either way (the psum semantics, ``_psum_mean_payload``), so a
       leaf-level top-k would add loss without saving a single intra byte;
    2. intra-node: ONE ``psum`` of the dense spectra planes over the fast
       ``local`` axis — FFT linearity accumulates the deltas in the
       spectrum, and the psum's result is already replicated across the
       island (the "broadcast" of step 4 is free);
    3. compress the node-mean signal ONCE per island — the ONLY lossy step
       — so the slow inter-node fabric moves exactly one compressed
       ``StackedPayload`` per node instead of one per worker;
    4. inter-node: all_gather of the per-node payloads over ``node``, folded
       left-to-right (``_ordered_worker_mean``) so every worker — and every
       run — produces bit-identical means.

    The node-level compression keeps top-k of the ISLAND MEAN's spectrum
    rather than per-worker top-k of each leaf spectrum, so the hierarchical
    mean tracks the flat psum mean within the lab's tolerance envelope
    rather than bitwise — the accuracy claim ``hierarchical_matches_flat``
    (lab/evaluate.py) guards the gap.  Determinism is still exact: fixed
    psum order on an island, fixed fold order across islands.

    Degrades gracefully for non-spectral compressors: the intra-node psum
    runs on the raw time-domain bucket rows (equal by linearity, same wire).
    """

    name = "hierarchical"

    def _exchange_buckets(self, buckets, comp, axis, monitor=None):
        node_ax, local_ax = two_level_axes(axis)
        inv_l = 1.0 / axis_size(local_ax)
        # loop fallback psums the raw time-domain buckets (== the spectra
        # psum by FFT linearity, same dense wire), then compresses the node
        # mean once per island
        node_means = [jax.lax.psum(b, local_ax) * inv_l for b in buckets]
        node_payloads = _compress_all(node_means, comp, monitor)
        return [_gather_mean_payload(p, comp, node_ax) for p in node_payloads]

    def _exchange_flat(self, flat, layout, comp, axis, stacked=True,
                       monitor=None):
        node_ax, local_ax = two_level_axes(axis)
        if not (stacked and _can_stack(comp)):
            return super()._exchange_flat(flat, layout, comp, axis, stacked,
                                          monitor=monitor)
        inv_l = 1.0 / axis_size(local_ax)
        rows = bucketing.stack_buckets(flat, layout)  # (B, padded)
        if hasattr(comp, "decompress_spectrum"):
            x3 = rows.reshape(layout.n_buckets, -1, layout.chunk)
            spec = jnp.fft.rfft(x3, axis=-1)  # DENSE spectra — no top-k
            summed = jax.lax.psum(jnp.stack([spec.real, spec.imag]), local_ax)
            node_mean = bucketing.unstack_buckets(
                _irfft_rows((summed[0] + 1j * summed[1]) * inv_l, layout.chunk),
                layout)
        else:
            node_mean = bucketing.unstack_buckets(
                jax.lax.psum(rows, local_ax) * inv_l, layout)
        # compress ONCE per island: this payload is the only thing the
        # inter-node fabric carries (every island worker holds the same
        # node_mean after the psum, so the fabric sees one copy per node)
        node_payload = _compress_stacked(node_mean, layout, comp, monitor)
        gathered = jax.lax.all_gather(node_payload, node_ax)
        if hasattr(comp, "decompress_spectrum"):
            spectra = jax.vmap(comp.decompress_spectrum)(gathered)
            mean = _ordered_worker_mean(spectra)
            return bucketing.unstack_buckets(
                _irfft_rows(mean, layout.chunk), layout)
        recon = jax.vmap(comp.decompress_stacked)(gathered)
        return bucketing.unstack_buckets(_ordered_worker_mean(recon), layout)

    def _roundtrip_flat(self, flat, layout, comp, stacked=True):
        # EF residual: the exchange's only loss is the island-level compress
        # of the node MEAN — per-worker state can't hold island-shared loss,
        # so the residual accumulates this worker's own compress roundtrip
        # as the local estimate of what the island compress drops (same
        # compressor, same theta, same bucket granularity as the flat
        # transports); see DESIGN.md §18
        if not (stacked and _can_stack(comp)):
            return super()._roundtrip_flat(flat, layout, comp, stacked)
        payload = _compress_stacked(flat, layout, comp)
        return bucketing.unstack_buckets(
            comp.decompress_stacked(payload), layout)


class ReduceScatterTransport(Transport):
    """Bucket-partitioned reduce: psum_scatter over the bucket axis.

    Stacked path: the dequantized spectra planes (leading axis = buckets,
    padded to a multiple of P with zero rows) ride ONE ``psum_scatter``;
    worker i receives the reduced planes of the contiguous bucket range
    ``[i·B/P, (i+1)·B/P)`` — exactly a ``bucketing.sub_layout`` ownership
    range — runs the inverse FFT only on its own rows, and a tiled
    ``all_gather`` of the TIME-DOMAIN rows rebuilds the flat buffer.
    Per-worker wire is ring-allreduce-shaped (2·(P-1)/P of the dense
    planes): unlike the gather transports it stops growing with P.

    ``axis`` may be one name or a tuple (the tuple is treated as one
    flattened worker axis — ``psum_scatter``/``all_gather`` accept both).
    Per-bucket loop fallback degrades to the psum transport's per-bucket
    exchange (same mean; a single bucket has nothing to scatter).
    """

    name = "reduce_scatter"

    def _exchange_buckets(self, buckets, comp, axis, monitor=None):
        payloads = _compress_all(buckets, comp, monitor)
        return [_psum_mean_payload(p, comp, axis) for p in payloads]

    def _exchange_flat(self, flat, layout, comp, axis, stacked=True,
                       monitor=None):
        if not (stacked and _can_stack(comp)):
            return super()._exchange_flat(flat, layout, comp, axis, stacked,
                                          monitor=monitor)
        p = axis_size(axis)
        inv_p = 1.0 / p
        payload = _compress_stacked(flat, layout, comp, monitor)
        if hasattr(comp, "decompress_spectrum"):
            spec = comp.decompress_spectrum(payload)  # (B, max_chunks, f)
            planes = jnp.stack([spec.real, spec.imag], axis=1)  # (B, 2, c, f)
        else:
            planes = comp.decompress_stacked(payload)[:, None, :]  # (B, 1, n)
        b = planes.shape[0]
        pad_rows = (-b) % p
        if pad_rows:
            planes = jnp.concatenate(
                [planes, jnp.zeros((pad_rows,) + planes.shape[1:],
                                   planes.dtype)])
        shard = jax.lax.psum_scatter(
            planes, axis, scatter_dimension=0, tiled=True)  # (B'/P, 2, c, f)
        if hasattr(comp, "decompress_spectrum"):
            mean_spec = (shard[:, 0] + 1j * shard[:, 1]) * inv_p
            rows = _irfft_rows(mean_spec, layout.chunk)  # (B'/P, padded)
        else:
            rows = shard[:, 0] * inv_p
        full = jax.lax.all_gather(rows, axis, tiled=True)  # (B', padded)
        return bucketing.unstack_buckets(full[:b], layout)

    def _roundtrip_flat(self, flat, layout, comp, stacked=True):
        if not (stacked and _can_stack(comp)):
            return super()._roundtrip_flat(flat, layout, comp, stacked)
        payload = _compress_stacked(flat, layout, comp)
        return bucketing.unstack_buckets(
            comp.decompress_stacked(payload), layout)


def _resplit(flat: jnp.ndarray, sizes: List[int]) -> List[jnp.ndarray]:
    out, off = [], 0
    for s in sizes:
        out.append(flat[off : off + s])
        off += s
    return out


_TRANSPORTS = {
    t.name: t for t in (AllGatherTransport(), SequencedTransport(),
                        SpectrumPsumTransport(), HierarchicalTransport(),
                        ReduceScatterTransport())
}


def get_transport(name: str) -> Transport:
    try:
        return _TRANSPORTS[name]
    except KeyError:
        raise ValueError(
            f"unknown transport {name!r}; expected one of {TRANSPORT_NAMES}"
        ) from None
