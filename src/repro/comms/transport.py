"""Pluggable exchange strategies for compressed gradient buckets.

Layer (2) of the bucketed exchange (DESIGN.md §9).  A transport turns a list
of per-bucket flat gradients into the list of their cross-worker means, using
one compressor.  All transports compute the SAME mean — mean over the axis of
the per-worker dequantized reconstructions — they differ in which collective
carries the bytes and at what granularity:

========== =========================== ============================== =========
name       collective                  per-worker wire (cost model)   overlap
========== =========================== ============================== =========
allgather  one all_gather of the       P · B  (P payloads land on     none
           monolithic payload          every worker)
sequenced  one all_gather PER BUCKET   P · B  total, issued as        buckets
           (independent collectives)   n_buckets independent ops      pipeline
psum       per-bucket psum of the      B      (in-network reduction:  buckets
           locally dequantized         each worker injects its kept
           spectrum                    coefficients once; P-free)
========== =========================== ============================== =========

``B = comp.wire_bits(n)`` at equal theta; see ``cost_model.transport_wire_bits``
for the model the acceptance tests assert against (the psum column prices the
sparse-allreduce endpoint; today's lowering is a dense-spectrum psum — see
``_psum_mean_payload``).

The psum transport exploits FFT linearity (DESIGN.md §10): sum of spectra ==
spectrum of the sum, so workers dequantize locally, sum spectra with a single
``psum``, and run ONE inverse FFT on the mean spectrum.  For non-spectral
compressors (timedomain/terngrad/qsgd) it degrades gracefully to a psum of the
dense local reconstruction — still numerically identical to the all-gather
mean, still O(1) payloads per worker in the cost model.

Quantizer granularity: the monolithic ``allgather`` transport fits ONE
quantizer over the whole buffer (seed behavior); ``sequenced`` and ``psum``
compress per bucket, so each bucket fits its own range (small buckets stop
inheriting a global range — see ``FFTCompressor.compress_buckets``).

Batched bucket executor (DESIGN.md §14): the hot entry point is now
``exchange_flat`` — the whole flat gradient goes in, the whole mean comes
out.  With ``stacked=True`` (the default) and a stacked-capable compressor,
the bucketed transports compress EVERY bucket with one batched kernel pass
(``compress_stacked``) and move ONE ``StackedPayload`` per exchange — one
collective launch instead of one per bucket — while staying bitwise-equal to
the per-bucket loop (per-bucket quantizers included).  ``stacked=False`` or a
loop-only compressor (terngrad/qsgd) falls back to the per-bucket path.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.comms import bucketing
from repro.comms.collectives import axis_size
from repro.core import fft as cfft

__all__ = ["Transport", "get_transport", "TRANSPORT_NAMES"]

TRANSPORT_NAMES = ("allgather", "sequenced", "psum")


def _compress_all(buckets: Sequence[jnp.ndarray], comp) -> List:
    """Per-bucket payloads; FFTCompressor fits one quantizer per bucket."""
    if hasattr(comp, "compress_buckets"):
        return comp.compress_buckets(buckets)
    return [comp.compress(b) for b in buckets]


def _can_stack(comp) -> bool:
    return hasattr(comp, "compress_stacked")


def _compress_stacked(flat: jnp.ndarray, layout, comp):
    """ONE batched compress of every bucket (same quantizer granularity as
    the per-bucket loop: one fit per bucket row)."""
    return comp.compress_stacked(
        bucketing.stack_buckets(flat, layout), layout.sizes())


def _irfft_rows(mean_spectrum: jnp.ndarray, chunk: int) -> jnp.ndarray:
    """(B, max_chunks, f) mean spectrum -> (B, padded_size) time domain."""
    x = jnp.fft.irfft(mean_spectrum, n=chunk, axis=-1)
    return x.reshape(mean_spectrum.shape[0], -1).astype(jnp.float32)


def _ordered_worker_mean(stacked: jnp.ndarray) -> jnp.ndarray:
    """Mean over the leading (worker) axis as a left-to-right fold.

    The fold order matters for bitwise reproducibility, not correctness: the
    CPU backend's all-reduce sums contributions in worker order, so folding the
    gathered reconstructions the same way makes the gather transports produce
    bit-identical means to the psum transport (seeded-determinism contract,
    tests/test_transports.py).  ``jnp.mean``'s pairwise reduction would differ
    by ~1 ulp and the divergence compounds over training steps.
    """
    p = stacked.shape[0]
    acc = stacked[0]
    for w in range(1, p):
        acc = acc + stacked[w]
    return acc * (1.0 / p)


def _gather_mean_payload(payload, comp, axis: str) -> jnp.ndarray:
    """Seed exchange: all_gather one payload -> mean reconstruction.

    For spectral compressors the mean is taken in the frequency domain and a
    single inverse FFT recovers the time-domain mean (FFT linearity).
    """
    gathered = jax.lax.all_gather(payload, axis)  # leading axis: workers
    if hasattr(comp, "decompress_spectrum"):
        spectra = jax.vmap(comp.decompress_spectrum)(gathered)
        mean_spectrum = _ordered_worker_mean(spectra)
        return cfft.chunked_irfft(mean_spectrum, payload.orig_len, payload.chunk)
    decompressed = jax.vmap(comp.decompress)(gathered)
    return _ordered_worker_mean(decompressed)


def _psum_mean_payload(payload, comp, axis: str) -> jnp.ndarray:
    """Dequantize locally -> psum -> /P (-> one iFFT if spectral).

    NOTE: ``jax.lax.psum`` here moves the DENSE dequantized spectrum — this
    is the reference implementation of the psum semantics, not the O(k)
    wire-optimal sparse allreduce the cost model prices (see
    ``cost_model.transport_wire_bits``).  Even dense it beats the payload
    all-gather once P > 2F/k, and XLA may further optimize the reduction.
    """
    inv_p = 1.0 / axis_size(axis)
    if hasattr(comp, "decompress_spectrum"):
        spec = comp.decompress_spectrum(payload)
        # psum real/imag planes separately: complex psum support varies by
        # backend, and two f32 reductions lower to one fused collective anyway
        summed = jax.lax.psum(jnp.stack([spec.real, spec.imag]), axis)
        mean_spectrum = (summed[0] + 1j * summed[1]) * inv_p
        return cfft.chunked_irfft(mean_spectrum, payload.orig_len, payload.chunk)
    return jax.lax.psum(comp.decompress(payload), axis) * inv_p


class Transport:
    """Exchange interface.

    The hot entry points take the WHOLE flat gradient plus its bucket layout
    (``exchange_flat`` / ``local_roundtrip_flat``) so the batched executor
    can run end-to-end without per-bucket list plumbing; the per-bucket
    ``exchange``/``local_roundtrip`` remain as the loop fallback (and for
    compressors with no stacked path).

    ``local_roundtrip_flat`` exposes the compress->decompress reconstruction
    at the SAME granularity the transport ships at, so error feedback
    accumulates exactly what this transport drops (per-bucket quantizers and
    all).
    """

    name: str = "base"

    def exchange(self, buckets: Sequence[jnp.ndarray], comp, axis: str) -> List[jnp.ndarray]:
        raise NotImplementedError

    def local_roundtrip(self, buckets: Sequence[jnp.ndarray], comp) -> List[jnp.ndarray]:
        return [comp.decompress(p) for p in _compress_all(buckets, comp)]

    # -- flat (batched-executor) entry points, DESIGN.md §14 ----------------

    def exchange_flat(self, flat: jnp.ndarray, layout, comp, axis: str,
                      stacked: bool = True) -> jnp.ndarray:
        """Whole-gradient exchange over a bucket layout -> flat mean.

        Default: the per-bucket loop (split -> exchange -> concat).  Stacked
        transports override this with the single-collective path.
        """
        del stacked  # loop fallback ignores the flag
        buckets = bucketing.split_buckets(flat, layout)
        return bucketing.concat_buckets(
            self.exchange(buckets, comp, axis), layout)

    def local_roundtrip_flat(self, flat: jnp.ndarray, layout, comp,
                             stacked: bool = True) -> jnp.ndarray:
        del stacked
        buckets = bucketing.split_buckets(flat, layout)
        return bucketing.concat_buckets(
            self.local_roundtrip(buckets, comp), layout)


class AllGatherTransport(Transport):
    """Seed behavior: ONE monolithic payload all_gather, global quantizer."""

    name = "allgather"

    def exchange(self, buckets, comp, axis):
        sizes = [int(b.shape[0]) for b in buckets]
        flat = buckets[0] if len(buckets) == 1 else jnp.concatenate(list(buckets))
        mean = _gather_mean_payload(comp.compress(flat), comp, axis)
        return _resplit(mean, sizes)

    def local_roundtrip(self, buckets, comp):
        sizes = [int(b.shape[0]) for b in buckets]
        flat = buckets[0] if len(buckets) == 1 else jnp.concatenate(list(buckets))
        return _resplit(comp.decompress(comp.compress(flat)), sizes)

    # monolithic by definition: already one payload, one collective — the
    # flat entry points skip the bucket split/concat entirely
    def exchange_flat(self, flat, layout, comp, axis, stacked=True):
        del layout, stacked
        return _gather_mean_payload(comp.compress(flat), comp, axis)

    def local_roundtrip_flat(self, flat, layout, comp, stacked=True):
        del layout, stacked
        return comp.decompress(comp.compress(flat))


class SequencedTransport(Transport):
    """Bucketed all_gather with per-bucket quantizer ranges.

    Stacked (default): ONE all_gather of the whole exchange's
    ``StackedPayload`` — a single collective launch carrying every bucket's
    codes, indices, and quantizer params as struct-of-arrays planes.  Looped
    fallback: one independent all_gather PER BUCKET (XLA's latency-hiding
    scheduler may pipeline them, at n_buckets collective launches).  Both
    paths realize the same mean bitwise.
    """

    name = "sequenced"

    def exchange(self, buckets, comp, axis):
        payloads = _compress_all(buckets, comp)
        return [_gather_mean_payload(p, comp, axis) for p in payloads]

    def exchange_flat(self, flat, layout, comp, axis, stacked=True):
        if not (stacked and _can_stack(comp)):
            return super().exchange_flat(flat, layout, comp, axis, stacked)
        payload = _compress_stacked(flat, layout, comp)
        gathered = jax.lax.all_gather(payload, axis)  # ONE collective
        if hasattr(comp, "decompress_spectrum"):
            spectra = jax.vmap(comp.decompress_spectrum)(gathered)
            mean = _ordered_worker_mean(spectra)  # (B, max_chunks, f)
            return bucketing.unstack_buckets(
                _irfft_rows(mean, layout.chunk), layout)
        recon = jax.vmap(comp.decompress_stacked)(gathered)  # (W, B, padded)
        return bucketing.unstack_buckets(_ordered_worker_mean(recon), layout)

    def local_roundtrip_flat(self, flat, layout, comp, stacked=True):
        if not (stacked and _can_stack(comp)):
            return super().local_roundtrip_flat(flat, layout, comp, stacked)
        payload = _compress_stacked(flat, layout, comp)
        return bucketing.unstack_buckets(
            comp.decompress_stacked(payload), layout)


class SpectrumPsumTransport(Transport):
    """Psum of dequantized spectra: O(k) wire, P-independent.

    Stacked (default): every bucket's dequantized spectrum rides ONE psum of
    the ``(2, n_buckets, max_chunks, f)`` plane stack — a single collective
    launch — followed by one batched inverse FFT.  Looped fallback: one psum
    per bucket.
    """

    name = "psum"

    def exchange(self, buckets, comp, axis):
        payloads = _compress_all(buckets, comp)
        return [_psum_mean_payload(p, comp, axis) for p in payloads]

    def exchange_flat(self, flat, layout, comp, axis, stacked=True):
        if not (stacked and _can_stack(comp)):
            return super().exchange_flat(flat, layout, comp, axis, stacked)
        payload = _compress_stacked(flat, layout, comp)
        inv_p = 1.0 / axis_size(axis)
        if hasattr(comp, "decompress_spectrum"):
            spec = comp.decompress_spectrum(payload)  # (B, max_chunks, f)
            summed = jax.lax.psum(jnp.stack([spec.real, spec.imag]), axis)
            mean = (summed[0] + 1j * summed[1]) * inv_p
            return bucketing.unstack_buckets(
                _irfft_rows(mean, layout.chunk), layout)
        summed = jax.lax.psum(comp.decompress_stacked(payload), axis)
        return bucketing.unstack_buckets(summed * inv_p, layout)

    def local_roundtrip_flat(self, flat, layout, comp, stacked=True):
        if not (stacked and _can_stack(comp)):
            return super().local_roundtrip_flat(flat, layout, comp, stacked)
        payload = _compress_stacked(flat, layout, comp)
        return bucketing.unstack_buckets(
            comp.decompress_stacked(payload), layout)


def _resplit(flat: jnp.ndarray, sizes: List[int]) -> List[jnp.ndarray]:
    out, off = [], 0
    for s in sizes:
        out.append(flat[off : off + s])
        off += s
    return out


_TRANSPORTS = {
    t.name: t for t in (AllGatherTransport(), SequencedTransport(), SpectrumPsumTransport())
}


def get_transport(name: str) -> Transport:
    try:
        return _TRANSPORTS[name]
    except KeyError:
        raise ValueError(
            f"unknown transport {name!r}; expected one of {TRANSPORT_NAMES}"
        ) from None
