"""Cost-model calibration: measure α–β and backprop on the live mesh.

The §III-D/§11/§15 pricing functions (``cost_model.py``) and the auto
schedule policy (``scheduler.choose_schedule``) stand on four numbers:
collective launch latency α, link byte-rate (β⁻¹), the compression-stage
``Throughputs`` table, and the backward-pass FLOP rate.  Until this module,
all four were hardcoded napkin figures (``COLLECTIVE_ALPHA_S``, ``TPU_V5E``,
``BACKPROP_FLOPS_PER_S``) — fiction on any particular host.  This module
makes them measurements (DESIGN.md §17):

* ``benchmark_collectives`` times REAL collectives (``all_gather`` for the
  gather transports, ``psum`` for the spectrum transport) inside a jitted
  ``shard_map`` over the live mesh, at a geometric sweep of message sizes —
  the SSFusion-style ``_benchmark_communication`` startup pass;
* ``fit_alpha_beta`` least-squares-fits the linear α–β (latency–bandwidth)
  model ``t(wire_bytes) = α + β·wire_bytes`` per collective family, the
  standard measured basis for scheduling decisions (arXiv 2003.03009);
* ``measure_throughputs`` times the jitted compression stages (quantize,
  FFT, pack, select) on this host and rebuilds the §III-D table from the
  measured byte-rates;
* ``measure_backprop_rate`` times the backward pass of the ACTUAL model and
  converts it to a FLOP rate via the 4·N·T backward-FLOP model, so
  ``modeled_backprop_s`` stops assuming an MXU that may not exist.

The result is a frozen :class:`CostProfile`.  It persists as a JSON artifact
keyed on (platform, mesh shape, model, jax version) — production jobs load
it (``CostProfile.load``) instead of re-profiling; a key mismatch (different
mesh, different jax, different model) raises :class:`ProfileKeyMismatch` so
a stale calibration can never silently price a new topology.

Threading: ``scheduler.choose_schedule``/``resolve_schedule``,
``cost_model.exchange_time_s``/``streamed_exchange_time_s`` all accept
``profile=``; ``train/step.py`` loads the artifact named by
``StepConfig.calibration_path``; ``launch/train.py --calibrate`` runs this
pass at startup on the live mesh.  Without a profile every call site keeps
the documented uncalibrated defaults bit-for-bit.

jax is imported inside the measurement functions only (the priceable values
— ``CostProfile``, the α–β fit — are host-side pure Python like
``cost_model``), so the CLI (``python -m repro.comms.calibrate --devices N``)
can pin a fake host-device count before the jax BACKEND initializes: the
import chain loads jax but nothing in it touches devices, and XLA reads
``XLA_FLAGS`` at first backend use, not at import.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.comms import cost_model

__all__ = [
    "ARTIFACT_VERSION",
    "COLLECTIVE_FAMILIES",
    "CostProfile",
    "LinkFit",
    "ProfileKey",
    "ProfileKeyMismatch",
    "UNCALIBRATED",
    "benchmark_collectives",
    "calibrate",
    "collective_family",
    "fit_alpha_beta",
    "load_or_calibrate",
    "load_profile_for",
    "measure_backprop_rate",
    "measure_throughputs",
    "profile_key",
]

# v2: ProfileKey records the calibration axes and LinkFit carries an
# optional per-axis tag (DESIGN.md §18) — v1 artifacts, which treated the
# mesh as one flat shape with no record of WHICH axis the collectives were
# timed over, are rejected rather than silently mispricing a new topology.
ARTIFACT_VERSION = 2

# Collective families the transports lower to: the gather transports
# (allgather/sequenced) ride ``jax.lax.all_gather``; the spectrum transport
# rides ``jax.lax.psum``.  One α–β fit per family.
COLLECTIVE_FAMILIES = ("gather", "psum")

# The two-level transports (DESIGN.md §18) price per HOP: hierarchical's
# bottleneck hop is the inter-node payload gather; reduce_scatter rides the
# reduce-scatter/all-gather pair the psum family's ring model covers.
_FAMILY_FOR_TRANSPORT = {
    "allgather": "gather",
    "sequenced": "gather",
    "psum": "psum",
    "hierarchical": "gather",
    "reduce_scatter": "psum",
}

# Fit floors: CPU-host timings are noisy enough that an unconstrained
# least-squares intercept/slope can come out non-positive; a profile must
# stay usable as a divisor (and check_bench requires α > 0, β > 0).
ALPHA_FLOOR_S = 1e-9
BETA_FLOOR_S_PER_BYTE = 1e-15  # 1 PB/s bandwidth cap

# Default geometric size sweep (per-worker payload bytes): 64 KiB .. 16 MiB,
# 4x steps — small enough to finish in seconds on a CPU host, wide enough
# that the bandwidth term dominates the top and the latency term the bottom.
DEFAULT_SIZES_BYTES = tuple(1 << p for p in range(16, 25, 2))
SMOKE_SIZES_BYTES = (1 << 14, 1 << 16, 1 << 18)


def collective_family(transport: str) -> str:
    """The α–β fit family a transport's collective belongs to."""
    try:
        return _FAMILY_FOR_TRANSPORT[transport]
    except KeyError:
        raise ValueError(
            f"unknown transport {transport!r}; expected one of "
            f"{tuple(_FAMILY_FOR_TRANSPORT)}") from None


class ProfileKeyMismatch(ValueError):
    """A persisted calibration artifact does not match the live system."""


@dataclasses.dataclass(frozen=True)
class ProfileKey:
    """What a calibration is valid FOR.  All fields must match for a
    persisted artifact to be loadable: α–β depend on platform + mesh AND on
    which axes the collectives were timed over, the backprop rate on the
    model, and kernel/collective lowering on the jax version.

    ``mesh`` records ((axis, size), ...) in mesh order — axis NAMES included,
    so a profile measured on a (node=2, local=4) mesh is rejected on
    (node=4, local=2) even though both flatten to 8 workers.  ``axes``
    records the exchange axes the collective sweep ran over; a sweep over
    the fast ``local`` link must never price the slow ``node`` fabric.
    """

    platform: str  # jax.default_backend()
    mesh: Tuple[Tuple[str, int], ...]  # ((axis, size), ...) in mesh order
    model: str  # "<ClassName>/<param_count>" or "none"
    jax_version: str
    axes: Tuple[str, ...] = ()  # exchange axes the collectives were timed over

    def to_dict(self) -> dict:
        return {
            "platform": self.platform,
            "mesh": [list(ax) for ax in self.mesh],
            "model": self.model,
            "jax_version": self.jax_version,
            "axes": list(self.axes),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ProfileKey":
        return cls(
            platform=d["platform"],
            mesh=tuple((str(a), int(s)) for a, s in d["mesh"]),
            model=d["model"],
            jax_version=d["jax_version"],
            axes=tuple(str(a) for a in d.get("axes", ())),
        )


@dataclasses.dataclass(frozen=True)
class LinkFit:
    """Fitted α–β model of one collective family: t(wire_bytes) = α + β·b.

    ``wire_bytes`` is the cost model's per-worker wire volume for that
    collective (P·payload for gather, 2·(P-1)/P·buffer for psum), so
    ``1/β`` plugs directly into the pricing functions as ``t_comm``.

    ``axis=None`` is the base fit over the profile's full exchange-axis
    spec; a named ``axis`` is a per-axis fit (one mesh axis of a two-level
    topology — the intra-node link and the inter-node fabric have different
    α–β, which is the whole point of DESIGN.md §18 pricing).
    """

    family: str  # "gather" | "psum"
    alpha_s: float
    beta_s_per_byte: float
    n_points: int = 0
    axis: Optional[str] = None  # None: base fit over the full axis spec

    def __post_init__(self):
        if self.family not in COLLECTIVE_FAMILIES:
            raise ValueError(
                f"unknown collective family {self.family!r}; expected one of "
                f"{COLLECTIVE_FAMILIES}")
        if self.alpha_s <= 0.0 or self.beta_s_per_byte <= 0.0:
            raise ValueError(
                f"alpha/beta must be positive, got α={self.alpha_s} "
                f"β={self.beta_s_per_byte}")

    @property
    def t_comm(self) -> float:
        """Fitted link byte-rate (bytes/second)."""
        return 1.0 / self.beta_s_per_byte

    def time_s(self, wire_bytes: float) -> float:
        return self.alpha_s + self.beta_s_per_byte * wire_bytes

    def to_dict(self) -> dict:
        return dict(dataclasses.asdict(self), t_comm_bytes_per_s=self.t_comm)


@dataclasses.dataclass(frozen=True)
class CostProfile:
    """A complete, frozen calibration of the cost model for one system.

    Every pricing input the §11/§15 models consume, measured (or, for
    :data:`UNCALIBRATED`, the documented static defaults).  Hashable pure
    value: equal profiles price identically, so decision functions stay pure
    functions of (config, profile).
    """

    key: ProfileKey
    fits: Tuple[LinkFit, ...]  # one base (axis=None) fit per family,
    # plus optional per-axis fits for two-level meshes
    throughputs: cost_model.Throughputs
    backprop_flops_per_s: float
    calibrated: bool = True  # False: the static-defaults profile

    def __post_init__(self):
        base = tuple(f.family for f in self.fits if f.axis is None)
        if sorted(base) != sorted(COLLECTIVE_FAMILIES):
            raise ValueError(
                f"profile needs exactly one base (axis=None) fit per family "
                f"{COLLECTIVE_FAMILIES}, got {base}")
        tagged = [(f.family, f.axis) for f in self.fits]
        if len(tagged) != len(set(tagged)):
            raise ValueError(
                f"duplicate (family, axis) fits in profile: {tagged}")
        if self.backprop_flops_per_s <= 0.0:
            raise ValueError(
                f"backprop_flops_per_s must be positive, got "
                f"{self.backprop_flops_per_s}")

    # -- pricing accessors (what cost_model/scheduler consume) --------------

    def fit_for(self, transport: str,
                axis: Optional[str] = None) -> LinkFit:
        """The fit pricing ``transport``.  With ``axis``, prefer the
        per-axis fit for that mesh axis (two-level pricing charges each hop
        at its own link's α–β) and fall back to the base fit when the
        profile predates per-axis calibration."""
        family = collective_family(transport)
        if axis is not None:
            for f in self.fits:
                if f.family == family and f.axis == axis:
                    return f
        return next(f for f in self.fits
                    if f.family == family and f.axis is None)

    def alpha_s(self, transport: str, axis: Optional[str] = None) -> float:
        return self.fit_for(transport, axis=axis).alpha_s

    def t_comm(self, transport: str, axis: Optional[str] = None) -> float:
        return self.fit_for(transport, axis=axis).t_comm

    def backprop_s(self, n_params: int, batch_tokens: int) -> float:
        """Backward-pass wall time at the measured rate (4 FLOPs/param/token
        — the standard 6·N·T split's backward share)."""
        return 4.0 * float(n_params) * float(batch_tokens) / self.backprop_flops_per_s

    # -- persistence --------------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "version": ARTIFACT_VERSION,
            "key": self.key.to_dict(),
            "fits": [f.to_dict() for f in self.fits],
            "throughputs": dataclasses.asdict(self.throughputs),
            "backprop_flops_per_s": self.backprop_flops_per_s,
            "calibrated": self.calibrated,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CostProfile":
        if d.get("version") != ARTIFACT_VERSION:
            raise ProfileKeyMismatch(
                f"calibration artifact version {d.get('version')!r} != "
                f"supported {ARTIFACT_VERSION}")
        return cls(
            key=ProfileKey.from_dict(d["key"]),
            fits=tuple(
                LinkFit(family=f["family"], alpha_s=f["alpha_s"],
                        beta_s_per_byte=f["beta_s_per_byte"],
                        n_points=int(f.get("n_points", 0)),
                        axis=f.get("axis"))
                for f in d["fits"]),
            throughputs=cost_model.Throughputs(
                **{k: float(v) for k, v in d["throughputs"].items()}),
            backprop_flops_per_s=float(d["backprop_flops_per_s"]),
            calibrated=bool(d.get("calibrated", True)),
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2)

    @classmethod
    def load(cls, path: str, expect: Optional[ProfileKey] = None,
             strict: bool = True) -> "CostProfile":
        """Load a persisted artifact.  With ``expect`` and ``strict`` (the
        default), a key mismatch raises :class:`ProfileKeyMismatch` — a
        calibration measured on another platform/mesh/model/jax must never
        silently price this one.  ``strict=False`` downgrades the mismatch
        to acceptance (for offline analysis of foreign artifacts)."""
        with open(path) as f:
            profile = cls.from_dict(json.load(f))
        if expect is not None and profile.key != expect:
            msg = (f"calibration artifact at {path} was measured for "
                   f"{profile.key}, but this system is {expect}")
            if strict:
                raise ProfileKeyMismatch(msg)
        return profile


# The documented static defaults as a profile: what every pricing call used
# before calibration existed, and what profile=None still means.  Kept as a
# value so code can treat "calibrated or not" uniformly.
UNCALIBRATED = CostProfile(
    key=ProfileKey(platform="static", mesh=(), model="none",
                   jax_version="any"),
    fits=(
        LinkFit("gather", cost_model.COLLECTIVE_ALPHA_S,
                1.0 / cost_model.NETWORKS["tpu-dcn-host"]),
        LinkFit("psum", cost_model.COLLECTIVE_ALPHA_S,
                1.0 / cost_model.NETWORKS["tpu-dcn-host"]),
    ),
    throughputs=cost_model.TPU_V5E,
    backprop_flops_per_s=cost_model.BACKPROP_FLOPS_PER_S,
    calibrated=False,
)


# ---------------------------------------------------------------------------
# α–β fit
# ---------------------------------------------------------------------------


def fit_alpha_beta(wire_bytes: Sequence[float],
                   times_s: Sequence[float]) -> Tuple[float, float]:
    """Least-squares fit of ``t = α + β·bytes`` -> (alpha_s, beta_s_per_byte).

    Closed-form simple linear regression; degenerate sweeps (fewer than two
    distinct sizes — e.g. a 1-worker psum whose wire volume is 0 at every
    size) fall back to α = mean(t) at the β floor.  Both coefficients are
    clamped to positive floors so the fit always yields a usable profile
    (noisy host timings can produce a negative intercept).
    """
    xs = [float(x) for x in wire_bytes]
    ts = [float(t) for t in times_s]
    if len(xs) != len(ts) or not xs:
        raise ValueError(
            f"need matching non-empty sweeps, got {len(xs)} sizes / "
            f"{len(ts)} times")
    n = len(xs)
    mean_x = sum(xs) / n
    mean_t = sum(ts) / n
    var_x = sum((x - mean_x) ** 2 for x in xs)
    if var_x <= 0.0:
        alpha, beta = mean_t, BETA_FLOOR_S_PER_BYTE
    else:
        beta = sum((x - mean_x) * (t - mean_t)
                   for x, t in zip(xs, ts)) / var_x
        alpha = mean_t - beta * mean_x
    return (max(alpha, ALPHA_FLOOR_S), max(beta, BETA_FLOOR_S_PER_BYTE))


# ---------------------------------------------------------------------------
# measurement passes (jax imported lazily: see module docstring)
# ---------------------------------------------------------------------------


def _median_time_s(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _modeled_wire_bytes(family: str, per_worker_bytes: int, workers: int) -> float:
    """The cost model's per-worker wire volume for one timed collective —
    the fit's x variable, so the fitted 1/β is directly the model's t_comm."""
    if family == "gather":
        return float(workers * per_worker_bytes)
    return 2.0 * per_worker_bytes * (workers - 1) / workers  # ring allreduce


def _axes_tuple(axis) -> Tuple[str, ...]:
    """An axis spec (name or sequence of names) as a tuple of names."""
    if isinstance(axis, str):
        return (axis,)
    axes = tuple(str(a) for a in axis)
    if not axes:
        raise ValueError("axis spec must name at least one mesh axis")
    return axes


def benchmark_collectives(
    mesh,
    axis="data",
    sizes_bytes: Sequence[int] = DEFAULT_SIZES_BYTES,
    *,
    iters: int = 3,
) -> Dict[str, List[Tuple[float, float]]]:
    """Time real collectives on the live mesh at a geometric size sweep.

    ``axis`` is one mesh axis name or a tuple of names — a tuple times the
    collectives over the combined axes (workers = product of the named
    sizes), which is what the two-level transports' flat baseline rides.
    Returns ``{family: [(modeled_wire_bytes, seconds), ...]}`` for each
    collective family — the direct input to :func:`fit_alpha_beta`.  Each
    point times a jitted ``shard_map`` whose body is ONLY the collective
    (all_gather / psum of a per-worker f32 buffer), median-of-``iters`` after
    a compile+warmup call.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import jaxcompat as compat

    axes = _axes_tuple(axis)
    shape = dict(mesh.shape)
    workers = 1
    for a in axes:
        workers *= shape[a]
    spec = axes[0] if len(axes) == 1 else axes
    key = jax.random.PRNGKey(0)
    out: Dict[str, List[Tuple[float, float]]] = {f: [] for f in COLLECTIVE_FAMILIES}
    for size in sizes_bytes:
        n = max(1, int(size) // 4)
        x = jax.random.normal(key, (workers, n), jnp.float32)
        gather = compat.shard_map(
            lambda v: jax.lax.all_gather(v[0], spec),
            mesh, in_specs=P(spec), out_specs=P())
        psum = compat.shard_map(
            lambda v: jax.lax.psum(v[0], spec),
            mesh, in_specs=P(spec), out_specs=P())
        with compat.set_mesh(mesh):
            t_gather = _median_time_s(jax.jit(gather), x, iters=iters)
            t_psum = _median_time_s(jax.jit(psum), x, iters=iters)
        out["gather"].append(
            (_modeled_wire_bytes("gather", 4 * n, workers), t_gather))
        out["psum"].append(
            (_modeled_wire_bytes("psum", 4 * n, workers), t_psum))
    return out


def measure_throughputs(n_elems: int = 1 << 20, *,
                        theta: float = 0.7) -> cost_model.Throughputs:
    """Measured §III-D stage throughputs (bytes/s) on this host.

    Times the SAME jitted stages the Fig. 15 benchmark times (quantize ->
    t_m, chunked rfft -> t_f, index pack -> t_p, top-k select -> t_s), at a
    calibration-sized buffer, and rebuilds the throughput table from the
    measured byte-rates.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import fft as cfft
    from repro.core import packing, sparsify
    from repro.core.quantizer import RangeQuantConfig, encode, fit_quantizer

    g = jax.random.normal(jax.random.PRNGKey(1), (n_elems,)) * 0.05
    fft_fn = jax.jit(lambda x: cfft.chunked_rfft(x)[0])
    freqs = fft_fn(g)
    k = sparsify.keep_count(freqs.shape[-1], theta)
    mag = jnp.abs(freqs)
    select_fn = jax.jit(lambda m: sparsify.topk_select(m, k))
    idx = select_fn(mag)
    pack_fn = jax.jit(lambda f, i: packing.pack_by_indices(f, i))
    q = fit_quantizer(-1.0, 1.0, RangeQuantConfig(8, 3))
    vals = jnp.real(pack_fn(freqs, idx))
    quant_fn = jax.jit(lambda v: encode(v, q))

    def rate(fn, args, bytes_in):
        return bytes_in / _median_time_s(fn, *args)

    return cost_model.Throughputs(
        t_m=rate(quant_fn, (vals,), 4 * vals.size),
        t_f=rate(fft_fn, (g,), 4 * n_elems),
        t_p=rate(pack_fn, (freqs, idx), 8 * freqs.size),
        t_s=rate(select_fn, (mag,), 4 * mag.size),
    )


def measure_backprop_rate(model, params, batch, *,
                          batch_tokens: Optional[int] = None,
                          iters: int = 3) -> float:
    """Measured backward-pass FLOP rate of the ACTUAL model (FLOP/s).

    Times jitted ``grad(loss)`` on a real batch and converts the wall time
    via the 4·N·T backward-FLOP model — the same model
    ``modeled_backprop_s`` prices with, so rate-in/time-out round-trips.
    """
    import jax

    from repro.models.sharding import count_params

    n_params = count_params(model.spec())
    tokens = _batch_tokens(batch) if batch_tokens is None else batch_tokens
    grad_fn = jax.jit(jax.grad(lambda p, b: model.loss(p, b, ctx=None)[0]))
    t = _median_time_s(grad_fn, params, batch, iters=iters)
    return 4.0 * float(n_params) * float(tokens) / t


def _batch_tokens(batch_tree) -> int:
    """Per-step token count (mirrors train/step._batch_tokens, which cannot
    be imported here without a cycle: train.step imports this module)."""
    import jax

    if isinstance(batch_tree, dict) and "tokens" in batch_tree:
        n = 1
        for s in batch_tree["tokens"].shape:
            n *= int(s)
        return n
    leaves = jax.tree_util.tree_leaves(batch_tree)
    if not leaves or not leaves[0].shape:
        return 1
    return int(leaves[0].shape[0])


# ---------------------------------------------------------------------------
# the startup profiling pass
# ---------------------------------------------------------------------------


def profile_key(mesh, model=None, model_name: Optional[str] = None,
                axes=()) -> ProfileKey:
    """The key a calibration of THIS system persists under.  ``axes`` is
    the exchange-axis spec the collective sweep ran over (DESIGN.md §18)."""
    import jax

    if model_name is None:
        if model is None:
            model_name = "none"
        else:
            from repro.models.sharding import count_params

            model_name = f"{type(model).__name__}/{count_params(model.spec())}"
    return ProfileKey(
        platform=jax.default_backend(),
        mesh=tuple((str(a), int(s)) for a, s in dict(mesh.shape).items()),
        model=model_name,
        jax_version=jax.__version__,
        axes=tuple(str(a) for a in _axes_tuple(axes)) if axes else (),
    )


def _fit_sweeps(sweeps, axis: Optional[str] = None) -> List[LinkFit]:
    fits = []
    for family in COLLECTIVE_FAMILIES:
        points = sweeps[family]
        alpha, beta = fit_alpha_beta([b for b, _ in points],
                                     [t for _, t in points])
        fits.append(LinkFit(family, alpha, beta, n_points=len(points),
                            axis=axis))
    return fits


def calibrate(
    mesh,
    axis="data",
    *,
    model=None,
    params=None,
    batch=None,
    sizes_bytes: Sequence[int] = DEFAULT_SIZES_BYTES,
    iters: int = 3,
    throughput_elems: int = 1 << 20,
    measure_stages: bool = True,
) -> CostProfile:
    """The startup profiling pass: one measured :class:`CostProfile`.

    Times collectives over ``axis`` of the live ``mesh`` (a name or a tuple
    of names), fits α–β per collective family, measures the compression-stage
    throughputs, and — when ``(model, params, batch)`` are given — the
    model's real backward pass.  A multi-axis spec additionally sweeps each
    axis SEPARATELY and records per-axis :class:`LinkFit`\\ s, so two-level
    pricing charges the intra-node hop at the measured ``local`` link rate
    and the inter-node hop at the measured ``node`` fabric rate.  Without a
    model the backprop rate keeps the static default (the profile is still
    calibrated on the comms side; its key records ``model="none"`` so it
    will not be accepted for a model-keyed load).
    """
    axes = _axes_tuple(axis)
    sweeps = benchmark_collectives(mesh, axes, sizes_bytes, iters=iters)
    fits = _fit_sweeps(sweeps)
    if len(axes) > 1:
        for a in axes:
            per_axis = benchmark_collectives(mesh, a, sizes_bytes,
                                             iters=iters)
            fits.extend(_fit_sweeps(per_axis, axis=a))
    thr = (measure_throughputs(throughput_elems) if measure_stages
           else cost_model.TPU_V5E)
    if model is not None and params is not None and batch is not None:
        backprop = measure_backprop_rate(model, params, batch, iters=iters)
    else:
        backprop = cost_model.BACKPROP_FLOPS_PER_S
    return CostProfile(
        key=profile_key(mesh, model=model, axes=axes),
        fits=tuple(fits),
        throughputs=thr,
        backprop_flops_per_s=backprop,
    )


def load_profile_for(path: str, mesh, model=None, axes=None) -> CostProfile:
    """Load an artifact for THIS mesh/model (what ``build_train_step`` uses).

    Platform, mesh shape (axis names AND sizes — a (node=2, local=4)
    calibration must not price a (node=4, local=2) mesh) and jax version
    must match the live system exactly; the model key must match the live
    model OR be ``"none"`` — a comms-only calibration prices any model's
    collectives (its backprop rate is the static default, so nothing
    model-specific is being trusted).  With ``axes``, the artifact must
    additionally have been calibrated over that exchange-axis spec.  Any
    mismatch raises :class:`ProfileKeyMismatch`.
    """
    profile = CostProfile.load(path)
    live = profile_key(mesh, model=model,
                       axes=axes if axes is not None else profile.key.axes)
    ok = (profile.key.platform == live.platform
          and profile.key.mesh == live.mesh
          and profile.key.jax_version == live.jax_version
          and profile.key.axes == live.axes
          and profile.key.model in (live.model, "none"))
    if not ok:
        raise ProfileKeyMismatch(
            f"calibration artifact at {path} was measured for {profile.key}, "
            f"but this system is {live}")
    return profile


def load_or_calibrate(
    path: Optional[str],
    mesh,
    axis: str = "data",
    *,
    expect: Optional[ProfileKey] = None,
    **calibrate_kwargs,
) -> CostProfile:
    """Artifact-first entry point: load ``path`` when it exists and matches
    ``expect``; otherwise run the profiling pass and persist it to ``path``
    (when given) so the NEXT job skips the warm-up."""
    import os

    if path is not None and os.path.exists(path):
        return CostProfile.load(path, expect=expect)
    profile = calibrate(mesh, axis, **calibrate_kwargs)
    if path is not None:
        profile.save(path)
    return profile


# ---------------------------------------------------------------------------
# CLI: smoke/offline profiling without a training job
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    """``python -m repro.comms.calibrate``: run the profiling pass (or check
    an existing artifact) on this host.  ``--devices N`` pins N fake host
    devices BEFORE jax's first import (this module is jax-free at import
    time precisely so this works), which is how the CI calibration-smoke leg
    exercises real multi-worker collectives on a CPU host."""
    import argparse

    ap = argparse.ArgumentParser(description="cost-model calibration pass")
    ap.add_argument("--devices", type=int, default=None,
                    help="fake host device count (must be set before jax "
                         "initializes; ignored if jax is already imported "
                         "with enough devices)")
    ap.add_argument("--smoke", action="store_true",
                    help="small size sweep + tiny throughput buffer (CI)")
    ap.add_argument("--out", default=None, help="persist the artifact here")
    ap.add_argument("--check", default=None,
                    help="load an artifact, verify it against this host's "
                         "key, print it, and exit")
    args = ap.parse_args(argv)

    if args.devices is not None:
        _pin_host_devices(args.devices)

    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh()
    if args.check is not None:
        profile = CostProfile.load(args.check, expect=None)
        live = profile_key(mesh, model_name=profile.key.model,
                           axes=profile.key.axes)
        if profile.key != live:
            print(f"[calibrate] STALE artifact: measured for {profile.key}, "
                  f"live system is {live}")
            return 1
        print(json.dumps(profile.to_dict(), indent=2))
        print("[calibrate] artifact matches the live system")
        return 0

    sizes = SMOKE_SIZES_BYTES if args.smoke else DEFAULT_SIZES_BYTES
    profile = calibrate(
        mesh, "data", sizes_bytes=sizes,
        throughput_elems=(1 << 16) if args.smoke else (1 << 20))
    print(json.dumps(profile.to_dict(), indent=2))
    for fit in profile.fits:
        print(f"[calibrate] {fit.family}: α={fit.alpha_s * 1e6:.1f} µs  "
              f"1/β={fit.t_comm / 1e9:.2f} GB/s  ({fit.n_points} points)")
    if args.out:
        profile.save(args.out)
        print(f"[calibrate] wrote {args.out}")
    return 0


def _pin_host_devices(n: int) -> None:
    """Request ``n`` fake host devices via
    ``--xla_force_host_platform_device_count``.

    jax is already imported by the time the CLI runs (this module's import
    chain pulls it), but XLA reads ``XLA_FLAGS`` at first BACKEND use, not
    at import — so setting the flag here still works as long as nothing has
    touched devices yet.  The flag is written first and the device count
    checked second, so the checking call itself initializes the backend with
    the flag in place; an insufficient count afterwards means the backend
    was already up, which only a fresh process can fix."""
    import os
    import re

    pat = re.compile(r"--xla_force_host_platform_device_count=(\d+)")
    flags = os.environ.get("XLA_FLAGS", "")
    m = pat.search(flags)
    if m is None:
        flags = (flags + f" --xla_force_host_platform_device_count={n}").strip()
    elif int(m.group(1)) < n:
        flags = pat.sub(f"--xla_force_host_platform_device_count={n}", flags)
    os.environ["XLA_FLAGS"] = flags

    import jax

    if len(jax.devices()) < n:
        raise RuntimeError(
            f"jax backend already initialized with {len(jax.devices())} "
            f"devices; need {n}. Run `python -m repro.comms.calibrate` in a "
            "fresh process.")


if __name__ == "__main__":
    import sys

    sys.exit(main())
