"""Gradient reducers: the paper's compressed exchange as a pluggable stage.

All reducers run inside ``shard_map`` and average a *gradient pytree* over one
or two named mesh axes.  Variants:

* ``dense``        — jax.lax.pmean (the paper's "orig" baseline).
* ``fft``          — the paper: per-shard FFT -> theta-drop -> range-quant ->
                     pack -> compressed exchange -> frequency-domain sum ->
                     single inverse FFT per bucket.  FFT linearity (sum of
                     spectra = spectrum of sum) means one iFFT regardless of
                     the worker count (beyond-paper; DESIGN.md §10).
* ``timedomain``   — DGC/Aji-style top-k exchange (paper Fig. 12 baseline).
* ``terngrad`` / ``qsgd`` — quantization baselines (paper Table I).
* ``hierarchical`` — multi-pod: dense psum_scatter intra-pod (fast ICI),
                     compressed exchange over the ``pod`` axis (slow DCN),
                     all-gather intra-pod.  This is the faithful adaptation of
                     "compress the bandwidth-limited exchange" to a TPU fleet.

The compressed exchange is a three-layer subsystem (DESIGN.md §8-§9):

1. **bucketing** — the gradient pytree is flattened, concatenated, and split
   into size-targeted, chunk-aligned buckets (``comms.bucketing``).  With
   ``bucket_bytes=None`` the whole buffer is one bucket (seed behavior).
2. **transport** — the exchange rides a pluggable collective strategy
   (``comms.transport``): ``allgather`` (one monolithic payload all_gather),
   ``sequenced`` (bucketed all_gather), or ``psum`` (spectrum-psum:
   dequantize locally, psum spectra, one iFFT — O(k) wire instead of
   O(P·k)).  With ``stacked=True`` (default, DESIGN.md §14) the bucketed
   transports compress every bucket in one batched kernel pass and issue ONE
   collective per exchange (a ``StackedPayload``); ``stacked=False`` runs
   the per-bucket loop (one collective per bucket), bitwise-identically.
3. **schedule** — the overlap engine (``comms.scheduler``, DESIGN.md §15):
   ``ReducerConfig.schedule`` picks the dispatch shape — ``stacked`` (one
   collective after backprop), ``streamed`` (readiness-ordered dispatch
   groups interleaved with the backward pass; bitwise-identical
   trajectories), or ``auto`` (the cost-model policy, resolved per model).
4. **this module** — flatten/split, hierarchical axis composition, and the
   per-bucket (and, streamed, per-readiness-group) error-feedback residual
   slices.

Leaves smaller than a chunk still ride their bucket — correctness is
unaffected because unpadding is exact, and because interior bucket boundaries
are chunk multiples the per-chunk top-k selection is identical at every
bucket granularity.

Error feedback (optional, default off — the paper's method is memoryless):
``make_reducer`` returns a (reduce_fn, init_residual_fn) pair when
``config.error_feedback`` is set; the train step threads the residual as one
flat vector, and this module slices it per bucket with the same layout that
splits the gradient, so each bucket accumulates exactly what ITS transport
granularity dropped (per-bucket quantizers included).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comms import bucketing, collectives, scheduler
from repro.comms import faults as faults_mod
from repro.comms.transport import TRANSPORT_NAMES, get_transport
from repro.core import baselines as B
from repro.core.compressor import (
    FFTCompressor,
    FFTCompressorConfig,
    TimeDomainCompressor,
)
from repro.kernels.engine import BACKEND_NAMES

__all__ = [
    "ReducerConfig",
    "make_reducer",
    "degrade_config",
    "flatten_tree",
    "unflatten_tree",
    "residual_size",
]


# ---------------------------------------------------------------------------
# pytree <-> flat buffer
# ---------------------------------------------------------------------------


def flatten_tree(tree) -> Tuple[jnp.ndarray, list, list]:
    """Concatenate all leaves into one f32 vector; returns (flat, shapes, treedef)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [(l.shape, l.dtype) for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, shapes, treedef


def unflatten_tree(flat: jnp.ndarray, shapes, treedef):
    leaves = []
    offset = 0
    for shape, dtype in shapes:
        size = 1
        for s in shape:
            size *= s
        leaves.append(flat[offset : offset + size].reshape(shape).astype(dtype))
        offset += size
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# reducer construction
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReducerConfig:
    kind: str = "dense"  # dense|fft|timedomain|terngrad|qsgd|hierarchical
    # gradient-sync mesh axis: one name, or a tuple of names for two-level
    # topologies (("node", "local") — required by transport="hierarchical",
    # accepted by every flat transport; None: auto-handled)
    axis: Optional[object] = "data"
    pod_axis: Optional[str] = None  # set for hierarchical (compressed) axis
    theta: float = 0.7
    n_bits: int = 8
    m_bits: int = 3
    chunk: int = 4096
    quantize: bool = True
    range_mode: str = "auto"
    fixed_range: Tuple[float, float] = (-1.0, 1.0)
    error_feedback: bool = False
    # bucketed exchange (DESIGN.md §8-§9): target bucket size in bytes of the
    # f32 gradient (None = one monolithic bucket) and the collective strategy
    bucket_bytes: Optional[int] = None
    # allgather|sequenced|psum|hierarchical|reduce_scatter, or "auto" (the
    # cost-model transport policy: flat psum vs hierarchical, resolved per
    # topology by scheduler.resolve_transport)
    transport: str = "allgather"
    # compressor stage-execution engine (DESIGN.md §13): reference|pallas|auto
    backend: str = "reference"
    # batched bucket executor (DESIGN.md §14): compress every bucket in one
    # batched kernel pass and move one StackedPayload per exchange (bitwise-
    # equal to the loop); False forces the per-bucket loop
    stacked: bool = True
    # overlap engine (DESIGN.md §15): exchange dispatch schedule.
    #   stacked  — one collective after backprop (§14)
    #   streamed — one collective per readiness group, issued while backprop
    #              still runs (comms/scheduler.py); bitwise-equal trajectories
    #   auto     — cost-model policy picks per model (scheduler.choose_schedule)
    schedule: str = "stacked"
    # streamed dispatch groups (None: one group per bucket — finest grain)
    stream_groups: Optional[int] = None
    # selection engine (DESIGN.md §16): sort|sampled|bisect|auto top-k
    # selector on the compression hot path, plus the sampled estimator's
    # subsample rate and bracket-refinement sweep count
    selector: str = "sort"
    sample_rate: float = 1.0 / 64.0
    tau_refine_iters: int = 16
    # resilience layer (DESIGN.md §19): payload validation level
    # (off | cheap | full) and a deterministic FaultPlan of injected
    # events.  With validate="off" and faults=None (the defaults) the
    # reducer keeps its historical signature and adds zero work; otherwise
    # the reduce functions take a ``step=`` kwarg and return an extra
    # worker-local ``ok`` flag the step guard folds across workers.
    validate: str = "off"
    faults: Optional[faults_mod.FaultPlan] = None

    @property
    def resilient(self) -> bool:
        """True when the reduce functions carry the (step, ok) contract.

        Dense reduction has no payloads to corrupt or validate, so a dense
        config (including one reached down the degradation ladder, which
        keeps the FaultPlan for gradient-level events) is never resilient.
        """
        if self.kind == "dense":
            return False
        return (self.validate != "off"
                or (self.faults is not None
                    and bool(self.faults.corrupt_events)))

    def __post_init__(self):
        from repro.core.selection import SELECTOR_NAMES

        if self.selector not in SELECTOR_NAMES:
            raise ValueError(
                f"unknown selector {self.selector!r}; expected one of "
                f"{SELECTOR_NAMES}")
        if self.transport not in TRANSPORT_NAMES + ("auto",):
            raise ValueError(
                f"unknown transport {self.transport!r}; expected one of "
                f"{TRANSPORT_NAMES + ('auto',)}"
            )
        if self.axis is not None and not isinstance(self.axis, str):
            # normalize sequence specs to tuples so the config stays hashable
            object.__setattr__(self, "axis", tuple(self.axis))
        if self.bucket_bytes is not None and self.bucket_bytes <= 0:
            raise ValueError(f"bucket_bytes must be positive, got {self.bucket_bytes}")
        if self.backend not in BACKEND_NAMES:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of {BACKEND_NAMES}")
        if self.schedule not in scheduler.SCHEDULE_NAMES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; expected one of "
                f"{scheduler.SCHEDULE_NAMES}")
        # the monolithic all-gather fits ONE quantizer over the whole buffer;
        # streaming it per group would change the fit (different numerics),
        # so the streamed schedule requires a bucketed transport
        if self.schedule == "streamed" and self.transport == "allgather":
            raise ValueError(
                "schedule='streamed' needs a bucketed transport "
                "(sequenced|psum); allgather is monolithic by definition")
        if self.stream_groups is not None and self.stream_groups < 1:
            raise ValueError(
                f"stream_groups must be >= 1, got {self.stream_groups}")
        if self.validate not in faults_mod.VALIDATE_LEVELS:
            raise ValueError(
                f"unknown validate level {self.validate!r}; expected one of "
                f"{faults_mod.VALIDATE_LEVELS}")
        if self.faults is not None and not isinstance(
                self.faults, faults_mod.FaultPlan):
            raise TypeError(
                f"faults must be a comms.faults.FaultPlan, got "
                f"{type(self.faults).__name__}")

    def compressor_config(self) -> FFTCompressorConfig:
        return FFTCompressorConfig(
            theta=self.theta,
            n_bits=self.n_bits,
            m_bits=self.m_bits,
            chunk=self.chunk,
            quantize=self.quantize,
            range_mode=self.range_mode,
            fixed_range=self.fixed_range,
            backend=self.backend,
            selector=self.selector,
            sample_rate=self.sample_rate,
            tau_refine_iters=self.tau_refine_iters,
        )

    def layout_for(self, total: int) -> bucketing.BucketLayout:
        return bucketing.build_layout(total, self.bucket_bytes, self.chunk)


def _mean_over(x, axis):
    return jax.lax.pmean(x, axis)


def _make_compressor(config: ReducerConfig):
    if config.kind in ("fft", "hierarchical"):
        return FFTCompressor(config.compressor_config())
    if config.kind == "timedomain":
        return TimeDomainCompressor(config.compressor_config())
    if config.kind == "terngrad":
        return B.TernGrad()
    if config.kind == "qsgd":
        return B.QSGD()
    raise ValueError(f"unknown compressed reducer kind {config.kind!r}")


def make_reducer(config: ReducerConfig, *, batch_tokens: Optional[int] = None,
                 workers: Optional[int] = None, profile=None,
                 topology: Optional[Tuple[int, int]] = None):
    """Returns reduce_fn(grads[, residual]) for use INSIDE shard_map.

    Without error feedback: reduce_fn(grads) -> mean_grads.
    With error feedback:    reduce_fn(grads, residual) -> (mean_grads, residual').

    Resilient contract (``config.resilient`` — validate != "off" or a
    FaultPlan with payload-corruption events, DESIGN.md §19): the reduce
    functions accept an extra ``step=`` kwarg (traced i32 scalar; drives
    deterministic fault matching) and return one extra WORKER-LOCAL ``ok``
    bool — the AND of every payload validation this worker saw.  The step
    guard combines it across workers (pmin) so skip decisions replicate.

    ``batch_tokens``, ``workers``, ``profile`` and ``topology`` are the
    policy layers' pricing inputs (DESIGN.md §15/§17/§18): the train-step
    builder passes the real per-step token count, the gradient axes' mesh
    size, (when ``StepConfig.calibration_path`` names one) the measured
    ``calibrate.CostProfile``, and — on a two-level mesh — the (nodes,
    local) shape of the exchange axes, so ``schedule='auto'`` prices the
    actual backward pass on the actual topology and ``transport='auto'``
    can pick flat psum vs hierarchical.  Direct callers may omit all four
    (documented defaults keep the decisions deterministic).
    """
    if config.kind == "dense":
        if config.error_feedback:
            raise ValueError("error feedback is meaningless for dense reduction")

        def dense_reduce(grads):
            axes = (config.axis,) if config.pod_axis is None else (
                config.axis,
                config.pod_axis,
            )
            out = grads
            for ax in axes:
                out = _mean_over(out, ax)
            return out

        return dense_reduce

    comp = _make_compressor(config)
    resilient = config.resilient

    def _monitor(step):
        """One ExchangeMonitor per traced reduce call (None when inert)."""
        if not resilient:
            return None
        axes = []
        for a in (config.axis, config.pod_axis):
            if a is None:
                continue
            axes.extend(a if isinstance(a, tuple) else (a,))
        worker = collectives.axis_linear_index(tuple(axes))
        step_t = (jnp.asarray(-1, jnp.int32) if step is None
                  else jnp.asarray(step, jnp.int32))
        corrupt = (config.faults.corrupt_events
                   if config.faults is not None else ())
        return faults_mod.ExchangeMonitor(
            config.validate, step=step_t, worker=worker, corrupt=corrupt)

    def _concrete(total: int) -> ReducerConfig:
        """The config with ``transport='auto'`` resolved for a flat buffer
        of this size — a pure host-side computation per trace (the flat
        length is static inside jit), like the schedule resolution below."""
        name, _ = scheduler.resolve_transport(
            config, total, topology=topology, profile=profile)
        if name == config.transport:
            return config
        return dataclasses.replace(config, transport=name)

    def _schedule_for(cfg: ReducerConfig, total: int) -> str:
        """Concrete dispatch schedule for a flat buffer of this size —
        resolved at trace time (the flat length is static inside jit), so
        an auto decision is one pure host-side computation per trace."""
        resolved, _ = scheduler.resolve_schedule(
            cfg, total, batch_tokens, workers=workers, profile=profile,
            topology=topology)
        return resolved

    def _dispatch_spec(cfg: ReducerConfig, total: int) -> dict:
        """layout= or plan= kwargs for ``Transport.run`` — plan when the
        resolved schedule streams over a multi-bucket layout, one stacked
        layout dispatch otherwise (DESIGN.md §20)."""
        layout = cfg.layout_for(total)
        if _schedule_for(cfg, total) == "streamed" and layout.n_buckets > 1:
            return {"plan": scheduler.build_plan(layout, cfg.stream_groups)}
        return {"layout": layout}

    def _exchange_flat(flat: jnp.ndarray, axis, monitor=None) -> jnp.ndarray:
        cfg = _concrete(flat.shape[0])
        transport = get_transport(cfg.transport)
        return transport.run(flat, comp=comp, axis=axis, stacked=cfg.stacked,
                             monitor=monitor,
                             **_dispatch_spec(cfg, flat.shape[0]))

    def _local_roundtrip_flat(flat: jnp.ndarray) -> jnp.ndarray:
        cfg = _concrete(flat.shape[0])
        transport = get_transport(cfg.transport)
        return transport.run(flat, comp=comp, stacked=cfg.stacked,
                             **_dispatch_spec(cfg, flat.shape[0]))

    def compressed_reduce(grads, step=None):
        monitor = _monitor(step)
        flat, shapes, treedef = flatten_tree(grads)
        if config.kind == "hierarchical":
            # 1) dense mean over the fast intra-pod axis (ICI).  axis=None
            # means the intra-pod reduction is handled by the AUTO partitioner
            # (partial-manual shard_map where only 'pod' is manual).
            if config.axis:
                flat = _mean_over(flat, config.axis)
            # 2) compressed exchange over the slow pod axis (DCN)
            if config.pod_axis is not None:
                flat = _exchange_flat(flat, config.pod_axis, monitor)
        else:
            flat = _exchange_flat(flat, config.axis, monitor)
            if config.pod_axis is not None:
                flat = _mean_over(flat, config.pod_axis)
        mean = unflatten_tree(flat, shapes, treedef)
        if resilient:
            return mean, monitor.ok()
        return mean

    if not config.error_feedback:
        return compressed_reduce

    def ef_reduce(grads, residual_flat, step=None):
        monitor = _monitor(step)
        flat, shapes, treedef = flatten_tree(grads)
        if config.kind == "hierarchical" and config.axis:
            flat = _mean_over(flat, config.axis)
        corrected = flat + residual_flat
        # residual at the exchange's own compression AND dispatch granularity:
        # what THIS schedule's transport dropped on this worker (per-bucket
        # quantizer fits, per-readiness-group slices and all).  The local
        # roundtrip is NOT monitored: the residual never crosses the wire,
        # and a skipped step quarantines it regardless (DESIGN.md §19).
        local_hat = _local_roundtrip_flat(corrected)
        new_residual = corrected - local_hat
        axis = config.pod_axis if config.kind == "hierarchical" else config.axis
        mean_flat = _exchange_flat(corrected, axis, monitor)
        if config.kind != "hierarchical" and config.pod_axis is not None:
            mean_flat = _mean_over(mean_flat, config.pod_axis)
        mean = unflatten_tree(mean_flat, shapes, treedef)
        if resilient:
            return mean, new_residual, monitor.ok()
        return mean, new_residual

    return ef_reduce


# ---------------------------------------------------------------------------
# degradation ladder (DESIGN.md §19)
# ---------------------------------------------------------------------------


def degrade_config(config: ReducerConfig) -> Optional[Tuple[ReducerConfig, str]]:
    """One rung down the degradation ladder: (simpler config, rung label).

    Returns ``None`` when the ladder is exhausted (already dense).  Rung
    order drops the most sophisticated machinery first, preserving as much
    compression as possible at each step:

    1. fused pallas kernels (or auto)      -> reference backend
    2. streamed/auto dispatch              -> stacked (one collective)
    3. hierarchical/reduce_scatter fabric  -> flat spectrum psum
    4. any compressed kind                 -> dense pmean (error feedback
       off — dense drops nothing, so there is nothing to accumulate; the
       train loop pops the residual from the state when it takes this rung)

    The FaultPlan is kept (gradient-level events must keep replaying under
    a degraded exchange) but validation is retired with the payloads on
    the dense rung.
    """
    if config.kind == "dense":
        return None
    if config.backend != "reference":
        return (dataclasses.replace(config, backend="reference"),
                f"backend:{config.backend}->reference")
    if config.schedule != "stacked":
        return (dataclasses.replace(config, schedule="stacked"),
                f"schedule:{config.schedule}->stacked")
    if config.transport in ("hierarchical", "reduce_scatter", "auto"):
        return (dataclasses.replace(config, transport="psum"),
                f"transport:{config.transport}->psum")
    return (dataclasses.replace(config, kind="dense", error_feedback=False,
                                validate="off"),
            f"kind:{config.kind}->dense")


def residual_size(params) -> int:
    """Flat residual length for error-feedback state allocation."""
    return bucketing.residual_size(params)
