"""Gradient reducers: the paper's compressed exchange as a pluggable stage.

All reducers run inside ``shard_map`` and average a *gradient pytree* over one
or two named mesh axes.  Variants:

* ``dense``        — jax.lax.pmean (the paper's "orig" baseline).
* ``fft``          — the paper: per-shard FFT -> theta-drop -> range-quant ->
                     pack -> **all-gather of payloads** -> frequency-domain sum
                     -> single inverse FFT.  FFT linearity (sum of spectra =
                     spectrum of sum) means one iFFT per step regardless of
                     the worker count (beyond-paper; DESIGN.md §10).
* ``timedomain``   — DGC/Aji-style top-k exchange (paper Fig. 12 baseline).
* ``terngrad`` / ``qsgd`` — quantization baselines (paper Table I).
* ``hierarchical`` — multi-pod: dense psum_scatter intra-pod (fast ICI),
                     compressed exchange over the ``pod`` axis (slow DCN),
                     all-gather intra-pod.  This is the faithful adaptation of
                     "compress the bandwidth-limited exchange" to a TPU fleet.

Leaf bucketing: gradients are flattened and concatenated into one buffer
before compression (better chunk utilization + one FFT dispatch), then split
back.  Leaves smaller than ``min_leaf_size`` in aggregate still ride the
bucket — correctness is unaffected because unpadding is exact.

Error feedback (optional, default off — the paper's method is memoryless):
``make_reducer`` returns a (reduce_fn, init_residual_fn) pair when
``config.error_feedback`` is set; the train step threads the residual.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import baselines as B
from repro.core.compressor import (
    FFTCompressor,
    FFTCompressorConfig,
    TimeDomainCompressor,
)

__all__ = ["ReducerConfig", "make_reducer", "flatten_tree", "unflatten_tree"]


# ---------------------------------------------------------------------------
# pytree <-> flat buffer
# ---------------------------------------------------------------------------


def flatten_tree(tree) -> Tuple[jnp.ndarray, list, list]:
    """Concatenate all leaves into one f32 vector; returns (flat, shapes, treedef)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [(l.shape, l.dtype) for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, shapes, treedef


def unflatten_tree(flat: jnp.ndarray, shapes, treedef):
    leaves = []
    offset = 0
    for shape, dtype in shapes:
        size = 1
        for s in shape:
            size *= s
        leaves.append(flat[offset : offset + size].reshape(shape).astype(dtype))
        offset += size
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# reducer construction
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReducerConfig:
    kind: str = "dense"  # dense|fft|timedomain|terngrad|qsgd|hierarchical
    axis: Optional[str] = "data"  # gradient-sync mesh axis (None: auto-handled)
    pod_axis: Optional[str] = None  # set for hierarchical (compressed) axis
    theta: float = 0.7
    n_bits: int = 8
    m_bits: int = 3
    chunk: int = 4096
    quantize: bool = True
    range_mode: str = "auto"
    fixed_range: Tuple[float, float] = (-1.0, 1.0)
    error_feedback: bool = False

    def compressor_config(self) -> FFTCompressorConfig:
        return FFTCompressorConfig(
            theta=self.theta,
            n_bits=self.n_bits,
            m_bits=self.m_bits,
            chunk=self.chunk,
            quantize=self.quantize,
            range_mode=self.range_mode,
            fixed_range=self.fixed_range,
        )


def _mean_over(x, axis):
    return jax.lax.pmean(x, axis)


def _fft_exchange(flat: jnp.ndarray, comp: FFTCompressor, axis: str) -> jnp.ndarray:
    """Compressed allreduce of a flat buffer: payload all-gather + spectrum sum."""
    payload = comp.compress(flat)
    gathered = jax.lax.all_gather(payload, axis)  # leading axis: workers
    spectra = jax.vmap(comp.decompress_spectrum)(gathered)
    mean_spectrum = jnp.mean(spectra, axis=0)
    from repro.core import fft as cfft

    return cfft.chunked_irfft(mean_spectrum, payload.orig_len, payload.chunk)


def _payload_exchange(flat: jnp.ndarray, comp, axis: str) -> jnp.ndarray:
    """Generic compressed allreduce: all-gather payloads, decompress, average."""
    payload = comp.compress(flat)
    gathered = jax.lax.all_gather(payload, axis)
    decompressed = jax.vmap(comp.decompress)(gathered)
    return jnp.mean(decompressed, axis=0)


def _make_flat_exchange(config: ReducerConfig) -> Callable[[jnp.ndarray, str], jnp.ndarray]:
    if config.kind in ("fft", "hierarchical"):
        comp = FFTCompressor(config.compressor_config())
        return lambda flat, axis: _fft_exchange(flat, comp, axis)
    if config.kind == "timedomain":
        comp = TimeDomainCompressor(config.compressor_config())
        return lambda flat, axis: _payload_exchange(flat, comp, axis)
    if config.kind == "terngrad":
        comp = B.TernGrad()
        return lambda flat, axis: _payload_exchange(flat, comp, axis)
    if config.kind == "qsgd":
        comp = B.QSGD()
        return lambda flat, axis: _payload_exchange(flat, comp, axis)
    raise ValueError(f"unknown compressed reducer kind {config.kind!r}")


def make_reducer(config: ReducerConfig):
    """Returns reduce_fn(grads[, residual]) for use INSIDE shard_map.

    Without error feedback: reduce_fn(grads) -> mean_grads.
    With error feedback:    reduce_fn(grads, residual) -> (mean_grads, residual').
    """
    if config.kind == "dense":
        if config.error_feedback:
            raise ValueError("error feedback is meaningless for dense reduction")

        def dense_reduce(grads):
            axes = (config.axis,) if config.pod_axis is None else (
                config.axis,
                config.pod_axis,
            )
            out = grads
            for ax in axes:
                out = _mean_over(out, ax)
            return out

        return dense_reduce

    exchange = _make_flat_exchange(config)

    def compressed_reduce(grads):
        flat, shapes, treedef = flatten_tree(grads)
        if config.kind == "hierarchical":
            # 1) dense mean over the fast intra-pod axis (ICI).  axis=None
            # means the intra-pod reduction is handled by the AUTO partitioner
            # (partial-manual shard_map where only 'pod' is manual).
            if config.axis:
                flat = _mean_over(flat, config.axis)
            # 2) compressed exchange over the slow pod axis (DCN)
            if config.pod_axis is not None:
                flat = exchange(flat, config.pod_axis)
        else:
            flat = exchange(flat, config.axis)
            if config.pod_axis is not None:
                flat = _mean_over(flat, config.pod_axis)
        return unflatten_tree(flat, shapes, treedef)

    if not config.error_feedback:
        return compressed_reduce

    comp_cfg = config.compressor_config()
    comp = (
        FFTCompressor(comp_cfg)
        if config.kind in ("fft", "hierarchical")
        else TimeDomainCompressor(comp_cfg)
    )

    def ef_reduce(grads, residual_flat):
        flat, shapes, treedef = flatten_tree(grads)
        if config.kind == "hierarchical" and config.axis:
            flat = _mean_over(flat, config.axis)
        corrected = flat + residual_flat
        # local residual: what compression dropped on THIS worker
        local_payload = comp.compress(corrected)
        local_hat = comp.decompress(local_payload)
        new_residual = corrected - local_hat
        axis = config.pod_axis if config.kind == "hierarchical" else config.axis
        mean_flat = exchange(corrected, axis)
        if config.kind != "hierarchical" and config.pod_axis is not None:
            mean_flat = _mean_over(mean_flat, config.pod_axis)
        return unflatten_tree(mean_flat, shapes, treedef), new_residual

    return ef_reduce


def residual_size(params) -> int:
    """Flat residual length for error-feedback state allocation."""
    leaves = jax.tree_util.tree_leaves(params)
    return sum(int(l.size) for l in leaves)
