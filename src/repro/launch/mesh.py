"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before its first jax
import, and nothing here may run earlier.
"""

from __future__ import annotations

import math

import jax

from repro.jaxcompat import make_auto_mesh

__all__ = ["make_production_mesh", "make_local_mesh", "make_two_level_mesh",
           "TWO_LEVEL_AXES"]

# Canonical axis names of the two-level (NVLink-islands-over-fabric) data
# topology: ``node`` is the slow inter-node fabric, ``local`` the fast
# intra-node link (DESIGN.md §18).
TWO_LEVEL_AXES = ("node", "local")


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) x ("data", "model").  Multi-pod: (2, 16, 16) x
    ("pod", "data", "model") — 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_local_mesh(shape=None, axes=None):
    """Mesh over whatever devices exist (tests / CPU examples).

    The 2-D spelling ``make_local_mesh(shape=(nodes, local),
    axes=("node", "local"))`` builds the two-level data topology the
    hierarchical transports exchange over (DESIGN.md §18).  Validation names
    the device-count mismatch instead of surfacing as a bare reshape failure
    deep inside mesh construction.
    """
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    shape = tuple(int(s) for s in shape)
    if axes is None:
        if len(shape) == 1:
            axes = ("data",)
        elif len(shape) == 2:
            axes = TWO_LEVEL_AXES
        else:
            raise ValueError(
                f"shape {shape} needs explicit axes= (only 1-D and 2-D "
                f"shapes have default axis names)")
    axes = tuple(axes)
    if len(axes) != len(shape):
        raise ValueError(
            f"mesh shape {shape} has {len(shape)} dims but axes {axes} "
            f"names {len(axes)}")
    if any(s < 1 for s in shape):
        raise ValueError(f"mesh shape {shape} has a non-positive axis size")
    need = math.prod(shape)
    if need > n:
        raise ValueError(
            f"mesh shape {shape} over axes {axes} needs {need} devices, "
            f"but only {n} host device{'s' if n != 1 else ''} exist "
            f"(set --xla_force_host_platform_device_count)")
    return make_auto_mesh(shape, axes)


def make_two_level_mesh(nodes: int, local=None, axes=TWO_LEVEL_AXES):
    """(nodes, local) x ("node", "local") mesh over the host devices.

    ``local=None`` divides whatever devices exist evenly across the nodes;
    an uneven split is a named error, not a bare reshape failure.
    """
    n = len(jax.devices())
    nodes = int(nodes)
    if nodes < 1:
        raise ValueError(f"nodes must be >= 1, got {nodes}")
    if local is None:
        if n % nodes:
            raise ValueError(
                f"{n} devices do not split evenly across {nodes} nodes; "
                f"pass local= explicitly or pick a divisor of {n}")
        local = n // nodes
    return make_local_mesh((nodes, int(local)), axes)
