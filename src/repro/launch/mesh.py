"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before its first jax
import, and nothing here may run earlier.
"""

from __future__ import annotations

import jax

from repro.jaxcompat import make_auto_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) x ("data", "model").  Multi-pod: (2, 16, 16) x
    ("pod", "data", "model") — 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_local_mesh(shape=None, axes=None):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return make_auto_mesh(shape, axes)
