"""Training CLI.

Examples (CPU-scale):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2_2b --reduced \\
      --steps 50 --batch 8 --seq 128 --mode compressed_dp --theta 0.7
  PYTHONPATH=src python -m repro.launch.train --arch xlstm_1_3b --reduced \\
      --steps 20 --ckpt-dir /tmp/ckpt

On a real fleet the same entrypoint runs under the production mesh
(--mesh production[:multi_pod]); on CPU it builds a mesh over however many
host devices exist.
"""

from __future__ import annotations

import argparse

import jax

from repro import jaxcompat as compat

from repro.comms.reducers import ReducerConfig
from repro.core import schedules as theta_schedules
from repro.data import SyntheticConfig, SyntheticStream
from repro.launch.mesh import (
    TWO_LEVEL_AXES,
    make_local_mesh,
    make_production_mesh,
    make_two_level_mesh,
)
from repro.models import registry
from repro.optim import OptConfig, lr_schedules
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train.step import StepConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b", choices=registry.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mode", default="pjit",
                    choices=["pjit", "compressed_dp", "hierarchical"])
    ap.add_argument("--reducer", default="fft",
                    choices=["fft", "timedomain", "terngrad", "qsgd", "dense"])
    ap.add_argument("--theta", type=float, default=0.7)
    ap.add_argument("--theta-schedule", default="constant",
                    choices=["constant", "step", "thm35"])
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--bucket-mb", type=float, default=None,
                    help="bucketed exchange: target bucket size in MB "
                         "(default: one monolithic bucket)")
    ap.add_argument("--transport", default="allgather",
                    choices=["allgather", "sequenced", "psum",
                             "hierarchical", "reduce_scatter", "auto"],
                    help="collective strategy for the compressed exchange; "
                         "hierarchical/reduce_scatter need a two-level mesh "
                         "(--nodes), auto picks flat psum vs hierarchical "
                         "from the (calibrated) cost model")
    ap.add_argument("--backend", default="auto",
                    choices=["reference", "pallas", "auto"],
                    help="compressor stage-execution engine: fused Pallas "
                         "kernels, the jnp reference path, or auto "
                         "(pallas when the platform compiles Mosaic)")
    ap.add_argument("--no-stacked", action="store_true",
                    help="disable the batched bucket executor and run the "
                         "per-bucket compress/collective loop instead "
                         "(bitwise-identical; one collective per bucket)")
    ap.add_argument("--schedule", default="stacked",
                    choices=["stacked", "streamed", "auto"],
                    help="exchange dispatch schedule (DESIGN.md §15): one "
                         "collective after backprop (stacked), readiness-"
                         "ordered bucket streaming interleaved with backprop "
                         "(streamed; bitwise-identical trajectory), or the "
                         "cost-model policy (auto)")
    ap.add_argument("--stream-groups", type=int, default=None,
                    help="streamed dispatch groups (default: one per bucket)")
    ap.add_argument("--selector", default="auto",
                    choices=["sort", "sampled", "bisect", "auto"],
                    help="top-k selection engine (DESIGN.md §16): exact "
                         "lax.top_k sort, O(n) DGC-style sampled threshold, "
                         "full value-axis bisection, or auto (sampled on "
                         "wide rows)")
    ap.add_argument("--sample-rate", type=float, default=1.0 / 64.0,
                    help="sampled selector: fraction of magnitudes in the "
                         "tau-estimation subsample")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the cost-model calibration pass on the live "
                         "mesh before training (DESIGN.md §17): time real "
                         "collectives, fit α–β, measure the compression "
                         "stages and this model's backward pass; the auto "
                         "schedule then prices with measurements")
    ap.add_argument("--calibration-path", default=None,
                    help="calibration artifact path: loaded when it exists "
                         "(key-checked against this platform/mesh/model/jax), "
                         "written after --calibrate so later jobs skip the "
                         "profiling pass")
    ap.add_argument("--publish-dir", default=None,
                    help="serving publish path (DESIGN.md §20): append "
                         "compressed weight deltas to this ring-buffer "
                         "directory every --publish-every steps; replicas "
                         "tail it with `launch.serve --follow <dir>`")
    ap.add_argument("--publish-every", type=int, default=1,
                    help="trainer steps between published deltas")
    ap.add_argument("--publish-theta", type=float, default=0.0,
                    help="spectrum drop-out of the delta codec (0.0: "
                         "lossless spectrum, quantization only)")
    ap.add_argument("--publish-capacity", type=int, default=64,
                    help="ring depth: deltas buffered for lagging replicas")
    ap.add_argument("--publish-snapshot-every", type=int, default=16,
                    help="deltas between dense snapshots (rebase points)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default="local", choices=["local", "production", "multi_pod"])
    ap.add_argument("--nodes", type=int, default=None,
                    help="two-level local mesh (DESIGN.md §18): split the "
                         "host devices into this many NVLink-island nodes "
                         "((nodes, local) x ('node', 'local')); the reducer "
                         "exchanges over both axes and the hierarchical "
                         "transports become available")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = registry.build(cfg)

    if args.nodes is not None:
        if args.mesh != "local":
            ap.error("--nodes builds a two-level LOCAL mesh; drop --mesh")
        mesh = make_two_level_mesh(args.nodes)
    elif args.mesh == "local":
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi_pod")

    # the gradient-sync axes: both two-level axes on a --nodes mesh
    data_axes = TWO_LEVEL_AXES if args.nodes is not None else None
    exchange_axis = TWO_LEVEL_AXES if args.nodes is not None else "data"
    reducer = None
    if args.mode != "pjit":
        reducer = ReducerConfig(
            kind=args.reducer if args.mode == "compressed_dp" else "hierarchical",
            axis=exchange_axis,
            pod_axis="pod" if "pod" in mesh.axis_names else None,
            theta=args.theta,
            error_feedback=args.error_feedback,
            bucket_bytes=int(args.bucket_mb * (1 << 20)) if args.bucket_mb else None,
            transport=args.transport,
            backend=args.backend,
            stacked=not args.no_stacked,
            schedule=args.schedule,
            stream_groups=args.stream_groups,
            selector=args.selector,
            sample_rate=args.sample_rate,
        )
    step_cfg = StepConfig(
        mode=args.mode,
        multi_pod="pod" in mesh.axis_names,
        reducer=reducer,
        calibration_path=args.calibration_path,
        data_axes=data_axes,
    )
    opt_cfg = OptConfig(kind="adamw", lr=args.lr)

    stream = SyntheticStream(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        frontend_dim=cfg.d_model if cfg.frontend != "none" else 0,
        frontend_len=(args.seq if cfg.frontend == "audio_frames"
                      else cfg.n_frontend_tokens),
        seed=args.seed,
    ))

    theta_sched = None
    if args.mode != "pjit":
        if args.theta_schedule == "constant":
            theta_sched = theta_schedules.constant(args.theta)
        elif args.theta_schedule == "step":
            theta_sched = theta_schedules.step_decay(
                [(0, args.theta), (args.steps // 2, 0.0)])
        else:
            theta_sched = theta_schedules.thm35_schedule(
                1.0, lambda s: args.lr * lr_schedules.rsqrt_decay()(s))

    state = init_state(jax.random.PRNGKey(args.seed), model, opt_cfg,
                       error_feedback=args.error_feedback)
    if args.error_feedback:
        # per-worker residual rows over the manual axes
        import jax.numpy as jnp
        w = 1
        for ax in step_cfg.manual_axes:
            w *= dict(mesh.shape)[ax]
        n = state["residual"].shape[0]
        state["residual"] = jnp.zeros((w, n), jnp.float32)

    if args.calibrate and args.mode != "pjit":
        import dataclasses
        import tempfile

        from repro.comms import calibrate as cal

        with compat.set_mesh(mesh):
            # calibrate over the axes the exchange actually rides: on a
            # two-level mesh that also records per-axis (node/local) fits
            profile = cal.calibrate(
                mesh, exchange_axis, model=model, params=state["params"],
                batch=stream.batch_at(0))
        path = args.calibration_path
        if path is None:  # the step loads the profile by path
            fd, path = tempfile.mkstemp(suffix=".calibration.json")
            import os

            os.close(fd)
        profile.save(path)
        step_cfg = dataclasses.replace(step_cfg, calibration_path=path)
        for fit in profile.fits:
            print(f"[calibrate] {fit.family}: α={fit.alpha_s * 1e6:.1f} µs  "
                  f"1/β={fit.t_comm / 1e9:.2f} GB/s")
        print(f"[calibrate] backprop {profile.backprop_flops_per_s / 1e12:.2f} "
              f"TFLOP/s; artifact at {path}")

    publisher = None
    if args.publish_dir is not None:
        from repro.serve import PublishConfig, WeightDeltaPublisher

        publisher = WeightDeltaPublisher(
            args.publish_dir, state["params"],
            PublishConfig(
                publish_every=args.publish_every,
                capacity=args.publish_capacity,
                snapshot_every=args.publish_snapshot_every,
                theta=args.publish_theta,
            ),
            extra_meta={"arch": args.arch, "reduced": bool(args.reduced)})
        print(f"[publish] ring at {args.publish_dir} "
              f"(every {args.publish_every} steps, "
              f"theta={args.publish_theta})")

    loop_cfg = TrainLoopConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        log_every=max(1, args.steps // 20),
        theta_schedule=theta_sched,
        lr_schedule=lr_schedules.warmup_cosine(max(2, args.steps // 10), args.steps),
        publish_hook=publisher.hook() if publisher is not None else None,
    )
    try:
        with compat.set_mesh(mesh):
            result = train_loop(model, opt_cfg, step_cfg, mesh, state, stream,
                                loop_cfg)
    finally:
        if publisher is not None:
            publisher.close()
            print(f"[publish] closed ring at v{publisher.version} "
                  f"({publisher.delta_bytes_total} delta bytes)")
    for row in result["history"]:
        print({k: (round(v, 4) if isinstance(v, float) else v) for k, v in row.items()})
    return result


if __name__ == "__main__":
    main()
