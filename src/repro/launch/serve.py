"""Serving CLI: batched generation on a local or production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --reduced
"""

from __future__ import annotations

import argparse

import jax

from repro import jaxcompat as compat
import jax.numpy as jnp

from repro.launch.mesh import make_local_mesh
from repro.models import registry
from repro.serve import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b", choices=registry.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = registry.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_local_mesh()
    with compat.set_mesh(mesh):
        engine = Engine(model, params, ServeConfig(
            max_seq=args.prompt_len + args.new_tokens + 8,
            batch=args.batch, temperature=args.temperature))
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size, jnp.int32)
        out = engine.generate(prompts, args.new_tokens)
    print(out)
    return out


if __name__ == "__main__":
    main()
