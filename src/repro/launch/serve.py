"""Serving CLI: batched generation on a local or production mesh.

Standalone (random init):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_2b --reduced

Replica mode (DESIGN.md §20) — tail a training job's delta ring, fold every
compressed weight delta into the replica state, and generate with the final
weights once the publisher closes the stream:

    PYTHONPATH=src python -m repro.launch.serve --follow /path/to/ring
"""

from __future__ import annotations

import argparse

import jax

from repro import jaxcompat as compat
import jax.numpy as jnp

from repro.launch.mesh import make_local_mesh
from repro.models import registry
from repro.serve import Engine, ReplicaSubscriber, ServeConfig


def _follow_ring(args):
    """-> (arch config, model, params) from a delta ring's final state."""
    sub = ReplicaSubscriber(args.follow)
    meta = sub.meta
    arch = meta.get("arch", args.arch)
    reduced = bool(meta.get("reduced", args.reduced))
    cfg = registry.get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = registry.build(cfg)
    template = model.init(jax.random.PRNGKey(0))

    def on_sync(stats):
        print(f"[serve] v{stats.version}: +{stats.applied} deltas, "
              f"{stats.bytes_read} bytes, "
              f"{stats.decompress_count} decompress"
              + (", snapshot fallback" if stats.gap_detected else ""))

    final_version = sub.follow(timeout_s=args.follow_timeout,
                               on_sync=on_sync)
    print(f"[serve] ring closed at v{final_version}; weights loaded")
    return cfg, model, sub.params_like(template)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b", choices=registry.ARCH_NAMES)
    # NOTE: this was `default=True` until PR 10, which made the flag inert —
    # the full-size config was unreachable from the CLI
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--follow", default=None, metavar="RING_DIR",
                    help="replica mode: tail this delta ring "
                         "(serve/ring.py) until the publisher closes it, "
                         "then serve the final weights; arch/reduced come "
                         "from the ring manifest")
    ap.add_argument("--follow-timeout", type=float, default=300.0,
                    help="give up if the ring is not closed after this many "
                         "seconds")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    if args.follow is not None:
        cfg, model, params = _follow_ring(args)
    else:
        cfg = registry.get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        model = registry.build(cfg)
        params = model.init(jax.random.PRNGKey(0))
    mesh = make_local_mesh()
    with compat.set_mesh(mesh):
        engine = Engine(model, params, ServeConfig(
            max_seq=args.prompt_len + args.new_tokens + 8,
            batch=args.batch, temperature=args.temperature))
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size, jnp.int32)
        out = engine.generate(prompts, args.new_tokens)
    print(out)
    return out


if __name__ == "__main__":
    main()
