import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh ((16,16) or (2,16,16) = 512 chips),
  2. builds ShapeDtypeStruct stand-ins for state/batch/caches (no allocation),
  3. jax.jit(step, in_shardings, out_shardings).lower(...).compile(),
  4. prints compiled.memory_analysis() (proves HBM fit) and cost_analysis(),
  5. parses collective bytes out of the optimized HLO,
  6. writes a JSON artifact consumed by EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma2_2b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out benchmarks/artifacts]
  python -m repro.launch.dryrun --arch gemma2_2b --shape train_4k \
      --mode hierarchical --theta 0.7     # compressed-exchange variants
"""

import argparse
import json
import time
import traceback

import jax

from repro import jaxcompat as compat
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo as hlo_mod
from repro.analysis.roofline import compute_roofline
from repro.comms.reducers import ReducerConfig
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.models.sharding import abstract_params, count_params, spec_tree_to_pspecs
from repro.models.transformer import MeshCtx
from repro.optim import OptConfig
from repro.serve.engine import build_decode_step, build_prefill_step
from repro.train.state import abstract_state
from repro.train.step import StepConfig, build_train_step

# FSDP (params 2D-sharded over data x model) is the uniform TRAIN default:
# replicated fp32 params+opt (12 bytes/param) blow 16GB/chip even at 2.6B
# when attention heads can't divide the model axis, and the per-layer
# allgather it costs is overlappable (the production default in MaxText too).
# Serving weights (bf16, no opt state) only need 2D sharding above ~40B.
FSDP_TRAIN_THRESHOLD = 0
FSDP_SERVE_THRESHOLD = 40e9


def _shardify(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _cache_pspecs(caches_abs, cfg, shape, mesh_axes):
    """PartitionSpec per cache leaf (leaves carry a leading n_groups axis)."""
    data_ok = shape.global_batch % mesh_axes.get("data", 1) == 0 and shape.global_batch > 1
    kv_ok = cfg.n_kv_heads % mesh_axes.get("model", 1) == 0
    model_n = mesh_axes.get("model", 1)

    def leaf_spec(leaf):
        shp = leaf.shape
        nd = len(shp)
        if nd >= 2 and shp[1] == shape.global_batch and nd >= 4:
            # (G, B, ...) state/cache tensors: 2-D sharding — batch over
            # 'data' AND the first large divisible trailing axis (cache seq)
            # over 'model'.  A 32k x 128 dense KV cache at 80 layers is
            # 86 GiB/device unsharded; batch/16 + seq/16 leaves 0.34 GiB
            # (§Perf decode iteration D1).
            batch_ax = "data" if data_ok else None
            rest = [None] * (nd - 2)
            if not data_ok:
                # long-context batch=1: seq takes 'data' instead
                for i in range(nd - 2):
                    if shp[2 + i] % mesh_axes.get("data", 1) == 0 and shp[2 + i] > 1:
                        rest[i] = "data"
                        break
            for i in range(nd - 2):
                if rest[i] is None and shp[2 + i] % model_n == 0 and shp[2 + i] >= model_n:
                    rest[i] = "model"
                    break
            return P(None, batch_ax, *rest)
        if nd == 2:  # (G, S) position arrays
            return P(None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map(leaf_spec, caches_abs)


def _lower_cell(cfg, shape, mesh, mesh_axes, *, multi_pod, mode, theta):
    """Lower + compile one cell for the given (possibly depth-reduced) cfg.

    Returns (compiled, kind, tokens)."""
    model = registry.build(cfg)
    n_params = count_params(model.spec())
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    specs = registry.input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = OptConfig(kind="adamw")
        reducer = None
        if mode != "pjit":
            reducer = ReducerConfig(
                kind="fft" if mode == "compressed_dp" else "hierarchical",
                # hierarchical: only 'pod' is manual; 'data' reduction is
                # auto-partitioned, so the reducer must not psum over it
                axis="data" if mode == "compressed_dp" else None,
                pod_axis="pod" if multi_pod else None,
                theta=theta,
            )
        step_cfg = StepConfig(
            mode=mode,
            fsdp=n_params > FSDP_TRAIN_THRESHOLD,
            multi_pod=multi_pod,
            reducer=reducer,
        )
        state = abstract_state(model, opt_cfg)
        step = build_train_step(model, opt_cfg, step_cfg, mesh, specs, donate=True)
        lowered = step.lower(state, specs)
        return lowered.compile(), "train", shape.tokens

    fsdp = n_params > FSDP_SERVE_THRESHOLD
    pspecs = spec_tree_to_pspecs(model.spec(), mesh_axes, fsdp=fsdp)
    params_abs = abstract_params(model.spec(), jnp.bfloat16)
    params_sh = _shardify(mesh, pspecs)
    ctx = MeshCtx(batch=batch_axes if shape.global_batch > 1 else (),
                  model_size=mesh_axes.get("model", 1),
                  seq="data" if shape.global_batch == 1 else None)
    if shape.kind == "prefill":
        fn = build_prefill_step(model, ctx, max_seq=shape.seq_len)
        batch_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P(batch_axes)), specs)
        caches_abs = jax.eval_shape(lambda: model.init_caches(
            shape.global_batch, shape.seq_len))
        cache_sh = _shardify(mesh, _cache_pspecs(caches_abs, cfg, shape, mesh_axes))
        jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                         out_shardings=(None, cache_sh))
        lowered = jitted.lower(params_abs, specs)
        return lowered.compile(), "prefill", shape.tokens
    # decode
    fn = build_decode_step(model, ctx)
    caches_abs = specs["caches"]
    cache_sh = _shardify(mesh, _cache_pspecs(caches_abs, cfg, shape, mesh_axes))
    tok_sh = NamedSharding(mesh, P(batch_axes) if shape.global_batch > 1 else P())
    jitted = jax.jit(
        fn,
        in_shardings=(params_sh, cache_sh, tok_sh, NamedSharding(mesh, P())),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )
    lowered = jitted.lower(params_abs, caches_abs, specs["token"], specs["pos"])
    return lowered.compile(), "decode", shape.global_batch


def _cost_and_collectives(compiled):
    cost = compiled.cost_analysis()
    coll = hlo_mod.summarize(hlo_mod.parse_collectives(compiled.as_text()))
    return cost, coll


def _recurrent_correction(cfg, shape, mesh_axes, kind: str) -> float:
    """Analytic per-device FLOPs for the per-timestep mLSTM/sLSTM scans.

    The sLSTM time loop stays lax.scan even in the unrolled cost samples
    (4096 iterations cannot be unrolled), so HLO counts one step per layer;
    this adds the remaining (S-1) steps:
        sLSTM step ~ 8*d^2 flops/token (h @ R recurrent matmul)
    (mLSTM uses the chunkwise-parallel form whose chunk loop IS unrolled in
    the samples, so it needs no correction.)  Batch is sharded over 'data'.
    """
    if cfg.family != "ssm":
        return 0.0
    steps = 1 if kind == "decode" else shape.seq_len
    if steps <= 1:
        return 0.0
    b_local = max(1, shape.global_batch // mesh_axes.get("data", 1))
    pattern = cfg.layer_pattern()
    n_slstm = sum(k == "slstm" for k in pattern) * cfg.n_groups()
    per_tok = n_slstm * 8.0 * cfg.d_model**2
    return float((steps - 1) * b_local * per_tok)


def _affine_extrapolate(c1, c2, g1: int, g2: int, g_full: int):
    """f(G) = a + b*G from two samples; evaluated at g_full (>= exact for
    affine-in-depth costs; XLA counts while bodies once, so sampling at true
    depths 1 and 2 groups gives the exact per-group increment)."""
    b = (c2 - c1) / (g2 - g1)
    a = c1 - b * g1
    return a + b * g_full


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, mode: str = "pjit",
             theta: float = 0.7, out_dir: str = "benchmarks/artifacts/dryrun",
             verbose: bool = True, skip_cost: bool = False):
    shape = SHAPES[shape_name]
    skip = registry.cell_is_supported(arch, shape)
    if skip:
        result = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                  "mode": mode, "status": "skipped", "reason": skip}
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            tag = (f"{arch}__{shape_name}__"
                   f"{'multi' if multi_pod else 'single'}__{mode}")
            with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                json.dump(result, f, indent=1)
        return result

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_axes = dict(mesh.shape)
    chips = mesh.devices.size
    cfg = registry.get_config(arch)
    model = registry.build(cfg)
    n_params = count_params(model.spec())
    n_active = cfg.active_param_count() if cfg.n_experts else n_params
    plen = len(cfg.layer_pattern())
    g_full = cfg.n_groups()

    with compat.set_mesh(mesh):
        # 1) FULL-depth compile: proves lowering + sharding + memory fit.
        compiled, kind, tokens = _lower_cell(
            cfg, shape, mesh, mesh_axes, multi_pod=multi_pod, mode=mode, theta=theta)
        t_full = time.time() - t0

        # 2) depth-1 / depth-2 UNROLLED compiles for cost extrapolation: XLA's
        # cost_analysis visits while(scan) bodies ONCE regardless of trip
        # count, so the shallow samples lower with straight-line HLO
        # (scan_layers=False + flags.UNROLL_INNER) and the affine-in-depth
        # extrapolation recovers exact totals.  The per-timestep xLSTM
        # recurrences stay scans; their analytic correction is added below.
        import dataclasses as _dc
        from repro.models import flags as _flags

        cost1 = cost2 = coll1 = coll2 = None
        if skip_cost:
            g_full = 1  # reuse the full compile's (undercounted) cost; the
            # single-pod table is the roofline source, multi-pod proves
            # lowering + HBM fit
        if g_full > 1:
            _flags.UNROLL_INNER = True
            try:
                cfg1 = _dc.replace(cfg, n_layers=plen * 1, scan_layers=False)
                cfg2 = _dc.replace(cfg, n_layers=plen * 2, scan_layers=False)
                comp1, _, _ = _lower_cell(cfg1, shape, mesh, mesh_axes,
                                          multi_pod=multi_pod, mode=mode, theta=theta)
                cost1, coll1 = _cost_and_collectives(comp1)
                comp2, _, _ = _lower_cell(cfg2, shape, mesh, mesh_axes,
                                          multi_pod=multi_pod, mode=mode, theta=theta)
                cost2, coll2 = _cost_and_collectives(comp2)
                del comp1, comp2
            finally:
                _flags.UNROLL_INNER = False

    t_all = time.time() - t0

    mem = compiled.memory_analysis()
    if g_full > 1:
        cost = {
            k: _affine_extrapolate(cost1.get(k, 0.0), cost2.get(k, 0.0), 1, 2, g_full)
            for k in ("flops", "bytes accessed")
        }
        kinds = set(coll1) | set(coll2)
        collectives = {}
        for k in kinds:
            z = {"count": 0, "raw_bytes": 0.0, "link_bytes": 0.0}
            s1, s2 = coll1.get(k, z), coll2.get(k, z)
            collectives[k] = {
                f: _affine_extrapolate(s1[f], s2[f], 1, 2, g_full) for f in z
            }
    else:
        cost, collectives = _cost_and_collectives(compiled)
    cost["flops"] = cost.get("flops", 0.0) + _recurrent_correction(
        cfg, shape, mesh_axes, kind)
    terms = compute_roofline(
        cost=cost, collectives=collectives, chips=chips,
        n_active_params=n_active, tokens=tokens, kind=kind,
    )

    mem_dict = {
        "argument_size_gib": getattr(mem, "argument_size_in_bytes", 0) / 2**30,
        "output_size_gib": getattr(mem, "output_size_in_bytes", 0) / 2**30,
        "temp_size_gib": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
        "generated_code_size_gib": getattr(mem, "generated_code_size_in_bytes", 0) / 2**30,
    }
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod, "mode": mode,
        "status": "ok", "chips": chips, "kind": kind,
        "n_params": n_params, "n_active_params": n_active, "tokens": tokens,
        "memory": mem_dict,
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed") if k in cost},
        "collectives": collectives,
        "roofline": terms.as_dict(),
        "full_compile_s": round(t_full, 1), "total_s": round(t_all, 1),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh={'multi' if multi_pod else 'single'} "
              f"mode={mode}: OK (full compile {t_full:.0f}s, total {t_all:.0f}s)")
        print(f"  memory/device: {mem_dict}")
        print(f"  roofline: compute={terms.compute_s*1e3:.2f}ms "
              f"memory={terms.memory_s*1e3:.2f}ms collective={terms.collective_s*1e3:.2f}ms "
              f"dominant={terms.dominant} useful={terms.useful_ratio:.2f}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}__{mode}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="pjit",
                    choices=["pjit", "compressed_dp", "hierarchical"])
    ap.add_argument("--theta", type=float, default=0.7)
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--skip-cost", action="store_true",
                    help="full compile only (multi-pod fit/lowering proof)")
    args = ap.parse_args()

    cells = []
    if args.all:
        # enc-dec (seamless) compiles slowest on CPU-XLA; schedule it last so
        # the rest of the table lands early
        order = [a for a in registry.ARCH_NAMES if a != "seamless_m4t_large_v2"]
        order.append("seamless_m4t_large_v2")
        for arch in order:
            for shape in SHAPES:
                if os.path.exists(os.path.join(
                        args.out, f"{arch}__{shape}__"
                        f"{'multi' if args.multi_pod else 'single'}__{args.mode}.json")):
                    continue  # resumable batch
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        try:
            run_cell(arch, shape, multi_pod=args.multi_pod, mode=args.mode,
                     theta=args.theta, out_dir=args.out,
                     skip_cost=args.skip_cost)
        except Exception:
            failures += 1
            print(f"[dryrun] {arch} x {shape} FAILED:")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
