from repro.train.state import TrainState, init_state, abstract_state
from repro.train.step import StepConfig, build_train_step
from repro.train.loop import TrainLoopConfig, train_loop

__all__ = ["TrainState", "init_state", "abstract_state", "StepConfig",
           "build_train_step", "TrainLoopConfig", "train_loop"]
