"""Train state: params + optimizer state + step counter (+ EF residual).

The error-feedback residual is ONE flat f32 vector over the whole gradient,
regardless of how the reducer buckets the exchange: the bucket layout is a
pure function of the flat length (comms/bucketing.py), so per-bucket residual
slices are views the reducer takes at trace time — state allocation and
checkpoints stay layout-independent (rebucketing a restored run is free)."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.comms.bucketing import residual_size
from repro.optim import OptConfig, init_opt_state

__all__ = ["TrainState", "init_state", "abstract_state"]

TrainState = Dict[str, Any]  # {"params", "opt", "step"[, "residual"]}


def init_state(key, model, opt_cfg: OptConfig, *, error_feedback: bool = False,
               dtype=jnp.float32) -> TrainState:
    params = model.init(key, dtype)
    state: TrainState = {
        "params": params,
        "opt": init_opt_state(opt_cfg, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if error_feedback:
        state["residual"] = jnp.zeros((residual_size(params),), jnp.float32)
    return state


def abstract_state(model, opt_cfg: OptConfig, *, error_feedback: bool = False,
                   dtype=jnp.float32) -> TrainState:
    """ShapeDtypeStruct tree — dry-run path, no allocation."""
    from repro.models.sharding import abstract_params

    params = abstract_params(model.spec(), dtype)
    state = jax.eval_shape(
        lambda p: {
            "opt": init_opt_state(opt_cfg, p),
            "step": jnp.zeros((), jnp.int32),
        },
        params,
    )
    state["params"] = params
    if error_feedback:
        n = residual_size(params)
        state["residual"] = jax.ShapeDtypeStruct((n,), jnp.float32)
    return state
