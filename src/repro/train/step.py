"""Train-step builders: pjit baseline + the paper's compressed variants.

Three modes (StepConfig.mode):

* ``pjit`` — everything auto-sharded; XLA inserts all collectives.  This is
  the dense baseline every dry-run cell lowers, and what the roofline table
  measures.  FSDP (params additionally sharded over ``data``) turns on per
  config for the >20B models.

* ``compressed_dp`` — the paper's setting: pure data parallelism over the
  (``pod``, ``data``) axes (manual via shard_map), tensor parallelism over
  ``model`` stays AUTO (partial-manual shard_map).  Per-shard gradients are
  exchanged with the configured reducer (FFT compression etc.).  Parameters
  are replicated over the manual axes, so this mode fits <= ~7B models — which
  covers the paper-faithful experiments (the paper ran AlexNet/VGG/ResNet).

* ``hierarchical`` — the multi-pod adaptation for big FSDP models: only the
  ``pod`` axis is manual; within a pod, XLA runs the usual FSDP collectives
  over (``data``, ``model``); ACROSS pods the gradient sync is the compressed
  exchange over DCN.  "Compress the bandwidth-limited hop" (DESIGN.md §2).

All modes share: grad -> [reduce] -> global-norm clip -> optimizer -> new
state, with theta threaded statically (a theta-schedule change rebuilds the
step — bounded recompiles, see core/schedules.py).

The compressed exchange is bucketed and transport-pluggable (DESIGN.md
§8-§9): ``ReducerConfig.bucket_bytes`` splits the flat gradient into
chunk-aligned buckets and ``ReducerConfig.transport`` picks the collective
(``allgather`` | ``sequenced`` | ``psum``).  The EF residual stays ONE flat
vector in the state; per-bucket slices are taken inside the reducer.

Overlap engine (DESIGN.md §15): ``ReducerConfig.schedule`` picks the
exchange's dispatch shape.  With ``streamed`` the step is STAGED — the
reducer splits the exchange into readiness-ordered dispatch groups
(``comms/scheduler.py``), and because each group's compress+collective
subgraph consumes only its own slice of the flat gradient (the slice
backprop finalizes first), XLA's latency-hiding scheduler is free to issue
group g's collective while lower-offset gradients are still being computed
— communication hides behind the backward pass instead of serializing after
it.  With ``auto`` this builder resolves the schedule ONCE per step build
via the cost-model policy (`scheduler.resolve_schedule`), using the model's
true parameter count, the batch's token count, the exchange axis's REAL
mesh size, and — when ``StepConfig.calibration_path`` names a persisted
calibration artifact (DESIGN.md §17) — the measured ``CostProfile`` in
place of the static pricing constants; the resolved decision is
exposed on the returned step object (``.schedule_decision``).  Either way
the trajectory is bitwise-identical to the stacked path, and jit-level
buffer donation of the state is preserved (the streamed groups read gradient
slices, not donated state buffers).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import jaxcompat as compat
from repro.comms import collectives, scheduler
from repro.comms import faults as faults_mod
from repro.comms.reducers import ReducerConfig, make_reducer
from repro.models.sharding import count_params, spec_tree_to_pspecs
from repro.models.transformer import MeshCtx
from repro.optim import OptConfig, apply_updates, clip_by_global_norm

__all__ = ["StepConfig", "build_train_step", "state_pspecs", "batch_pspecs"]


@dataclasses.dataclass(frozen=True)
class StepConfig:
    mode: str = "pjit"  # pjit | compressed_dp | hierarchical
    fsdp: bool = False
    multi_pod: bool = False
    clip_norm: float = 1.0
    reducer: Optional[ReducerConfig] = None  # compressed modes
    # batch/data axes override (DESIGN.md §18): on a two-level mesh the
    # batch shards over ("node", "local") instead of ("data",) — set this to
    # the mesh's data axes and give the reducer the same tuple as its
    # exchange axis.  None keeps the 1-D default (("data",), or
    # ("pod", "data") with multi_pod).
    data_axes: Optional[Tuple[str, ...]] = None
    # calibration artifact (DESIGN.md §17): path to a persisted CostProfile
    # measured on this (platform, mesh, model, jax) — the auto-schedule
    # policy then prices with fitted α–β, measured stage throughputs and the
    # measured backprop rate instead of the static defaults.  A key mismatch
    # raises calibrate.ProfileKeyMismatch at step-build time.
    calibration_path: Optional[str] = None
    # non-finite guard (DESIGN.md §19, compressed modes): every step, all
    # workers agree (one pmin over the manual axes) that the local gradient,
    # the reduced mean, the EF residual update, and every payload validation
    # are finite/sound; a failed step commits NOTHING — params, optimizer
    # moments, and the EF residual carry over unchanged (only the step
    # counter advances), so one poisoned worker cannot sneak a NaN into the
    # DGC recurrence.  The decision is bitwise-replicated; on a clean step
    # the select is the identity, so guarded and unguarded trajectories are
    # bitwise-identical.
    guard: bool = True

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        if self.data_axes is not None:
            return tuple(self.data_axes)
        return ("pod", "data") if self.multi_pod else ("data",)

    @property
    def manual_axes(self):
        if self.mode == "compressed_dp":
            return tuple(self.batch_axes)
        if self.mode == "hierarchical":
            return ("pod",)
        return ()


def state_pspecs(model, opt_cfg: OptConfig, step_cfg: StepConfig, mesh) -> Dict:
    """PartitionSpec tree for the TrainState under this mesh/mode."""
    axis_sizes = dict(mesh.shape)
    # params sharded over 'model' (+FSDP over 'data'); NEVER over 'pod'
    fsdp = step_cfg.fsdp and step_cfg.mode != "compressed_dp"
    param_specs = spec_tree_to_pspecs(model.spec(), axis_sizes, fsdp=fsdp)
    out = {
        "params": param_specs,
        "opt": {"mu": param_specs, "count": P()},
        "step": P(),
    }
    if opt_cfg.kind == "adamw":
        out["opt"]["nu"] = param_specs
    if step_cfg.reducer is not None and step_cfg.reducer.error_feedback:
        out["residual"] = P(step_cfg.batch_axes)  # per-worker rows
    return out


def batch_pspecs(step_cfg: StepConfig, batch_tree) -> Dict:
    """Batch rows over the batch axes (leading dim of every input)."""
    return jax.tree_util.tree_map(lambda _: P(step_cfg.batch_axes), batch_tree)


def _loss_and_grad(model, mesh_ctx):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, ctx=mesh_ctx)
        return loss, metrics

    return jax.value_and_grad(loss_fn, has_aux=True)


def _optimizer_update(opt_cfg, step_cfg, state, grads, lr_scale):
    grads, gnorm = clip_by_global_norm(grads, step_cfg.clip_norm)
    new_params, new_opt = apply_updates(
        opt_cfg, state["params"], grads, state["opt"], lr_scale
    )
    new_state = dict(state)
    new_state.update(params=new_params, opt=new_opt, step=state["step"] + 1)
    return new_state, gnorm


def build_train_step(
    model,
    opt_cfg: OptConfig,
    step_cfg: StepConfig,
    mesh,
    batch_tree,
    *,
    lr_scale: float = 1.0,
    donate: bool = True,
) -> Callable:
    """Returns jitted step(state, batch) -> (state, metrics).

    ``batch_tree`` is any pytree with the batch's structure (abstract ok) —
    used to build input shardings.
    """
    axes = dict(mesh.shape)
    mesh_ctx = MeshCtx(
        batch=step_cfg.batch_axes,
        model="model" if "model" in axes else None,
        model_size=axes.get("model", 1),
    )
    sharding = lambda spec_tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    batch_sh = sharding(batch_pspecs(step_cfg, batch_tree))

    if step_cfg.mode == "pjit":
        vg = _loss_and_grad(model, mesh_ctx)

        def step(state, batch):
            (loss, metrics), grads = vg(state["params"], batch)
            new_state, gnorm = _optimizer_update(opt_cfg, step_cfg, state, grads, lr_scale)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm)
            return new_state, metrics

        state_sh = sharding(state_pspecs(model, opt_cfg, step_cfg, mesh))
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,) if donate else (),
        )

        class _PjitStep:
            # device_put against these before calling (freshly generated
            # batches may be mesh-committed as replicated, which conflicts
            # with explicit in_shardings)
            batch_sharding = batch_sh
            state_sharding = state_sh

            def __call__(self, st, batch):
                return jitted(st, jax.device_put(batch, batch_sh))

            def lower(self, st, batch):
                return jitted.lower(st, batch)

        return _PjitStep()

    # ---- compressed modes: partial-manual shard_map ------------------------
    assert step_cfg.reducer is not None, "compressed modes need a ReducerConfig"
    # overlap-engine auto policy (DESIGN.md §15): resolve the dispatch
    # schedule HERE, where the model's parameter count and the batch's token
    # count are known — the reducer then traces a concrete schedule
    reducer_cfg = step_cfg.reducer
    batch_tokens = _batch_tokens(batch_tree)
    # the compressed exchange's collective runs over one axis (pod for
    # hierarchical, the data axis otherwise) OR a tuple of axes (the
    # two-level ("node", "local") topology); its mesh size is the worker
    # count the wire model must price — NOT a hardcoded 2
    exchange_axis = (reducer_cfg.pod_axis if reducer_cfg.kind == "hierarchical"
                     else reducer_cfg.axis)
    if exchange_axis is None:
        exchange_axes: Tuple[str, ...] = ()
    elif isinstance(exchange_axis, str):
        exchange_axes = (exchange_axis,)
    else:
        exchange_axes = tuple(exchange_axis)
    exchange_workers = 1
    for a in exchange_axes:
        exchange_workers *= axes.get(a, 1)
    # the (nodes, local) shape the transport policy prices — only a 2-axis
    # exchange spec has a two-level topology to exploit
    topology = (tuple(axes.get(a, 1) for a in exchange_axes)
                if len(exchange_axes) == 2 else None)
    profile = None
    if step_cfg.calibration_path is not None:
        from repro.comms import calibrate

        profile = calibrate.load_profile_for(
            step_cfg.calibration_path, mesh, model=model)
    transport_decision = None
    if reducer_cfg.transport == "auto":
        resolved_t, transport_decision = scheduler.resolve_transport(
            reducer_cfg, count_params(model.spec()),
            topology=topology, profile=profile)
        reducer_cfg = dataclasses.replace(reducer_cfg, transport=resolved_t)
    schedule_decision = None
    if reducer_cfg.schedule == "auto":
        resolved, schedule_decision = scheduler.resolve_schedule(
            reducer_cfg, count_params(model.spec()), batch_tokens,
            workers=exchange_workers, profile=profile, topology=topology)
        reducer_cfg = dataclasses.replace(reducer_cfg, schedule=resolved)
    reducer = make_reducer(reducer_cfg, batch_tokens=batch_tokens,
                           workers=exchange_workers, profile=profile,
                           topology=topology)
    manual = step_cfg.manual_axes
    ef = step_cfg.reducer.error_feedback

    # Inside the shard_map the manual axes are stripped; model-axis
    # constraints still apply through the auto axes.  In hierarchical mode
    # 'data' remains auto so batch constraints over it stay valid.
    inner_ctx = None if step_cfg.mode == "compressed_dp" else MeshCtx(
        batch=("data",),
        model="model" if "model" in axes else None,
        model_size=axes.get("model", 1),
    )
    vg_inner = _loss_and_grad(model, inner_ctx)

    plan = reducer_cfg.faults
    resilient = reducer_cfg.resilient
    guard = step_cfg.guard

    def inner(state, batch):
        step_no = state["step"]
        if ef:
            state = dict(state, residual=state["residual"][0])
        (loss, metrics), grads = vg_inner(state["params"], batch)
        if plan is not None and plan.nan_events:
            # deterministic gradient poisoning (FaultPlan.nan_grad): the
            # worker coordinate is the row-major linear index over the
            # manual axes, the step coordinate the replicated counter —
            # both traced, so the chaos run shares the clean run's jaxpr
            widx = collectives.axis_linear_index(manual)
            poison = faults_mod.match_events(plan.nan_events, step_no, widx)
            grads = jax.tree_util.tree_map(
                lambda g: jnp.where(poison, jnp.asarray(jnp.nan, g.dtype), g),
                grads)
        pay_ok = jnp.bool_(True)
        if ef:
            if resilient:
                reduced, new_residual, pay_ok = reducer(
                    grads, state["residual"], step=step_no)
            else:
                reduced, new_residual = reducer(grads, state["residual"])
        else:
            if resilient:
                reduced, pay_ok = reducer(grads, step=step_no)
            else:
                reduced = reducer(grads)
        loss = jax.lax.pmean(loss, manual)
        metrics = jax.lax.pmean(metrics, manual)
        new_state, gnorm = _optimizer_update(
            opt_cfg, step_cfg, state, reduced, lr_scale)
        if ef:
            new_state["residual"] = new_residual
        skipped = jnp.float32(0.0)
        if guard:
            # all-workers-agree finiteness flag: local gradient, reduced
            # mean, residual update, and payload validation must all be
            # sound EVERYWHERE — one pmin makes the verdict bitwise-
            # replicated, so workers can never diverge on whether the
            # update committed
            ok_local = (pay_ok
                        & faults_mod.tree_finite(grads)
                        & faults_mod.tree_finite(reduced))
            if ef:
                ok_local = ok_local & jnp.isfinite(new_residual).all()
            keep = jax.lax.pmin(ok_local.astype(jnp.int32), manual) > 0
            # a skipped step commits nothing but the step counter: params
            # and moments stay put, and the EF residual is QUARANTINED —
            # carrying e_{t-1} over unchanged keeps the DGC recurrence on
            # clean inputs instead of folding a poisoned error in
            old_state = dict(state, step=state["step"] + 1)
            new_state = jax.tree_util.tree_map(
                lambda new, old: jnp.where(keep, new, old),
                new_state, old_state)
            skipped = 1.0 - keep.astype(jnp.float32)
        if ef:
            new_state["residual"] = new_state["residual"][None]
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, skipped=skipped)
        return new_state, metrics

    def state_in_specs(state_like):
        specs = jax.tree_util.tree_map(lambda _: P(), state_like)
        if ef:
            specs["residual"] = P(manual)
        return specs

    def step(state, batch):
        # partial-manual shard_map: in_specs may reference MANUAL axes only;
        # the auto ('data'/'model') sharding of the batch comes from the
        # model's internal constraints
        batch_specs = jax.tree_util.tree_map(lambda _: P(manual), batch)
        step_sm = compat.shard_map(
            inner,
            mesh,
            in_specs=(state_in_specs(state), batch_specs),
            out_specs=(state_in_specs(state), P()),
            manual_axes=manual,
        )
        return step_sm(state, batch)

    # NOTE: composing jit-level in_shardings (FSDP over the auto axes) with
    # the partial-manual shard_map check-fails inside XLA's SPMD partitioner
    # (spmd_partitioner_util.cc:504; same family as b/433785288 pending the
    # Shardy partitioner).  Until then the compressed modes run with params
    # replicated over the manual axes — fine for the paper-scale models the
    # compressed_dp mode targets; the hierarchical mode's FSDP composition is
    # documented as blocked-on-upstream in EXPERIMENTS.md §Perf.
    jitted = jax.jit(step, donate_argnums=(0,) if donate else ())
    batch_sh_manual = NamedSharding(mesh, P(manual))

    _resolved_cfg, _decision, _t_decision = (
        reducer_cfg, schedule_decision, transport_decision)

    class _Step:
        batch_sharding = batch_sh_manual
        # the concrete config the step traced (auto resolved) and, when the
        # auto policies ran, the cost-model numbers behind their verdicts
        reducer_config = _resolved_cfg
        schedule_decision = _decision
        transport_decision = _t_decision

        def __call__(self, state, batch):
            with compat.set_mesh(mesh):
                return jitted(state, jax.device_put(batch, batch_sh_manual))

        def lower(self, state, batch):
            with compat.set_mesh(mesh):
                return jitted.lower(state, batch)

    return _Step()


def _batch_tokens(batch_tree) -> Optional[int]:
    """Per-step token count for the auto-schedule policy's backprop model.

    Sequence batches ('tokens' of shape (B, S)) yield B·S; otherwise the
    leading (batch) dimension of the first leaf.  A policy hint, not an
    accounting quantity."""
    if isinstance(batch_tree, dict) and "tokens" in batch_tree:
        shape = batch_tree["tokens"].shape
        n = 1
        for s in shape:
            n *= int(s)
        return n
    leaves = jax.tree_util.tree_leaves(batch_tree)
    if not leaves:
        return None
    return int(leaves[0].shape[0]) if leaves[0].shape else None
