"""Checkpointing: atomic, resumable, mesh-elastic.

Layout per checkpoint:  <dir>/step_<N>/
    manifest.json   — leaf paths, shapes, dtypes, PartitionSpecs (logical)
    arrays.npz      — all leaves, host-gathered

Design points for fleet-scale operation (DESIGN.md §5):
* **atomicity** — written to ``step_<N>.tmp`` then ``os.rename``d; a crash
  mid-write never corrupts the latest checkpoint;
* **elastic remesh** — arrays are saved *unsharded* (host view) with their
  logical PartitionSpec recorded; ``restore`` re-device_puts onto whatever
  mesh is alive, so a 512-chip run restores onto 256 chips (or 8 CPU devices
  in tests) without conversion;
* **determinism** — the data stream is stateless (batch_at(step)), so
  (state, step) is the complete resume point;
* on a real multi-host fleet the np.savez writer shards by host; the
  single-process container exercises the same code path with one host.

Async: ``save`` can run on a background thread (``block=False``) so the train
loop overlaps checkpoint I/O with compute.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(directory: str, step: int, state, *, block: bool = True) -> str:
    """Write state atomically; returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"

    leaves = _flatten_with_paths(state)
    arrays = {k: np.asarray(v) for k, v in leaves.items()}
    manifest = {
        "step": step,
        "leaves": {
            k: {"shape": list(a.shape), "dtype": str(a.dtype)} for k, a in arrays.items()
        },
    }

    def write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if block:
        write()
    else:
        t = threading.Thread(target=write, daemon=True)
        t.start()
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(directory: str, state_like, *, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``state_like``; reshard if given.

    ``state_like`` may be concrete or ShapeDtypeStructs; ``shardings`` is an
    optional matching tree of NamedShardings for the TARGET mesh (elastic
    remesh: the saved mesh is irrelevant).
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = {k: data[k] for k in data.files}

    flat_like = _flatten_with_paths(state_like)
    missing = set(flat_like) - set(arrays)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")

    flat_sh = _flatten_with_paths(shardings) if shardings is not None else {}
    restored = {}
    for k, like in flat_like.items():
        arr = arrays[k].astype(like.dtype)
        if k in flat_sh:
            restored[k] = jax.device_put(arr, flat_sh[k])
        else:
            restored[k] = jax.numpy.asarray(arr)

    # rebuild the tree in state_like's structure
    paths, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    leaves = [restored[jax.tree_util.keystr(p)] for p, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints, saves every ``every`` steps."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3,
                 async_save: bool = False):
        self.directory = directory
        self.every = every
        self.keep = keep
        self.async_save = async_save

    def maybe_save(self, step: int, state) -> Optional[str]:
        if step % self.every != 0:
            return None
        path = save(self.directory, step, state, block=not self.async_save)
        self._gc()
        return path

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
