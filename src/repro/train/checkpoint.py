"""Checkpointing: atomic, resumable, mesh-elastic, corruption-detecting.

Layout per checkpoint:  <dir>/step_<N>/
    manifest.json   — leaf paths, shapes, dtypes, per-array sha256 digests
    arrays.npz      — all leaves, host-gathered

Design points for fleet-scale operation (DESIGN.md §5, §19):
* **atomicity** — written to ``step_<N>.tmp`` then ``os.rename``d; a crash
  mid-write never corrupts the latest checkpoint (the stray ``.tmp`` dir is
  invisible to ``latest_step``/``restore``);
* **end-to-end verification** — ``manifest.json`` records a sha256 digest
  per array; ``restore`` re-hashes what it loaded and, when the newest
  checkpoint fails verification (bit rot, torn write below the rename),
  falls back to the previous step with a warning instead of resuming from
  garbage;
* **elastic remesh** — arrays are saved *unsharded* (host view) with their
  logical PartitionSpec recorded; ``restore`` re-device_puts onto whatever
  mesh is alive, so a 512-chip run restores onto 256 chips (or 8 CPU devices
  in tests) without conversion;
* **determinism** — the data stream is stateless (batch_at(step)), so
  (state, step) is the complete resume point;
* on a real multi-host fleet the np.savez writer shards by host; the
  single-process container exercises the same code path with one host.

Async: ``save`` can run on a background thread (``block=False``) so the
train loop overlaps checkpoint I/O with compute.  The in-flight writer is
TRACKED: the next ``save`` (either mode), ``restore``, and the manager's
``_gc`` join it first, and ``wait()`` drains it at loop shutdown — so the
final-path return value can never race a later reader and GC can never
unlink a directory mid-rename.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import warnings
from typing import Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "wait", "CheckpointError",
           "CheckpointManager"]


class CheckpointError(RuntimeError):
    """A checkpoint exists but cannot be trusted (digest mismatch, torn
    archive).  A RuntimeError so the train loop's recovery path may absorb
    it like any other step-time failure."""


_STEP_RE = re.compile(r"^step_(\d+)$")

# in-flight async writer (module-level: `save` is a free function); guarded
# by a lock so concurrent callers hand off cleanly
_INFLIGHT_LOCK = threading.Lock()
_INFLIGHT: Optional[threading.Thread] = None


def wait() -> None:
    """Join the in-flight async save, if any (loop shutdown, pre-restore)."""
    with _INFLIGHT_LOCK:
        t = _INFLIGHT
    if t is not None:
        t.join()


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(directory: str, step: int, state, *, block: bool = True) -> str:
    """Write state atomically; returns the final checkpoint path.

    With ``block=False`` the write runs on a background thread; the
    returned path is only guaranteed to exist after the NEXT ``save`` /
    ``restore`` / ``wait()`` joins the writer.
    """
    global _INFLIGHT
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"

    # snapshot to host BEFORE returning: the caller may mutate/donate the
    # state the moment save() returns, async or not
    leaves = _flatten_with_paths(state)
    arrays = {k: np.asarray(v) for k, v in leaves.items()}
    manifest = {
        "step": step,
        "leaves": {
            k: {"shape": list(a.shape), "dtype": str(a.dtype)} for k, a in arrays.items()
        },
        "digests": {
            k: hashlib.sha256(a.tobytes()).hexdigest() for k, a in arrays.items()
        },
    }

    def write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    wait()  # never two writers in flight; serializes with the previous save
    if block:
        write()
    else:
        t = threading.Thread(target=write, daemon=True)
        with _INFLIGHT_LOCK:
            _INFLIGHT = t
        t.start()
    return final


def _step_numbers(directory: str):
    """Sorted step numbers of COMPLETE checkpoints; stray files, ``.tmp``
    leftovers of dead writers, and non-conforming names are ignored."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m and os.path.isdir(os.path.join(directory, d)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = _step_numbers(directory)
    return steps[-1] if steps else None


def _load_verified(directory: str, step: int):
    """Load + digest-check one checkpoint; raises CheckpointError when the
    archive is torn or any array's sha256 disagrees with the manifest."""
    path = os.path.join(directory, f"step_{step:08d}")
    try:
        with np.load(os.path.join(path, "arrays.npz")) as data:
            arrays = {k: data[k] for k in data.files}
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except Exception as e:  # torn zip, truncated json, interrupted GC, ...
        raise CheckpointError(f"unreadable checkpoint {path}: {e}") from e
    digests = manifest.get("digests")
    if digests:  # pre-digest checkpoints restore unverified
        for k, want in digests.items():
            if k not in arrays:
                raise CheckpointError(f"{path}: manifest names missing leaf {k}")
            got = hashlib.sha256(arrays[k].tobytes()).hexdigest()
            if got != want:
                raise CheckpointError(
                    f"{path}: digest mismatch on {k} (corrupt array)")
    return arrays


def restore(directory: str, state_like, *, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``state_like``; reshard if given.

    ``state_like`` may be concrete or ShapeDtypeStructs; ``shardings`` is an
    optional matching tree of NamedShardings for the TARGET mesh (elastic
    remesh: the saved mesh is irrelevant).

    Every array is digest-verified against the manifest.  When no explicit
    ``step`` is requested and the newest checkpoint fails verification, the
    restore WARNS and falls back to the next-older step — resuming slightly
    earlier beats resuming from corruption.
    """
    wait()  # never read under an in-flight writer
    if step is not None:
        candidates = [step]
    else:
        candidates = list(reversed(_step_numbers(directory)))
        if not candidates:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    last_err: Optional[Exception] = None
    for s in candidates:
        try:
            arrays = _load_verified(directory, s)
        except CheckpointError as e:
            last_err = e
            if step is not None:
                raise
            warnings.warn(
                f"checkpoint step {s} failed verification ({e}); "
                f"falling back to the previous step")
            continue
        return _rebuild(arrays, state_like, shardings), s
    raise CheckpointError(
        f"no verifiable checkpoint under {directory}") from last_err


def _rebuild(arrays, state_like, shardings):
    flat_like = _flatten_with_paths(state_like)
    missing = set(flat_like) - set(arrays)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")

    flat_sh = _flatten_with_paths(shardings) if shardings is not None else {}
    restored = {}
    for k, like in flat_like.items():
        arr = arrays[k].astype(like.dtype)
        if k in flat_sh:
            restored[k] = jax.device_put(arr, flat_sh[k])
        else:
            restored[k] = jax.numpy.asarray(arr)

    # rebuild the tree in state_like's structure
    paths, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    leaves = [restored[jax.tree_util.keystr(p)] for p, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints, saves every ``every`` steps."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3,
                 async_save: bool = False):
        self.directory = directory
        self.every = every
        self.keep = keep
        self.async_save = async_save

    def maybe_save(self, step: int, state) -> Optional[str]:
        if step % self.every != 0:
            return None
        path = save(self.directory, step, state, block=not self.async_save)
        self._gc()
        return path

    def wait(self) -> None:
        """Drain the in-flight async writer (call at loop shutdown)."""
        wait()

    def _gc(self):
        # join the in-flight writer first: GC must never race a rename,
        # and the newest checkpoint must be visible before pruning
        wait()
        steps = _step_numbers(self.directory)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
