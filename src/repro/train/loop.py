"""The training loop: schedules, checkpoint/restart, failure recovery.

Fault-tolerance contract (DESIGN.md §5):
* auto-resume — on start, restore the newest checkpoint if one exists;
* step-level recovery — a failing step rolls back to the last checkpoint
  and continues (``max_retries`` guards livelock); a failure-injection hook
  exercises this in tests;
* theta/lr schedules — evaluated host-side per step; a *theta* change swaps
  the compiled step function (static kept-k), which is the recompile-bounded
  behaviour discussed in core/schedules.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


from repro.core.schedules import quantize_theta
from repro.train import checkpoint as ckpt
from repro.train.step import StepConfig, build_train_step

__all__ = ["TrainLoopConfig", "train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    max_retries: int = 2
    theta_schedule: Optional[Callable[[int], float]] = None  # -> theta
    lr_schedule: Optional[Callable[[int], float]] = None  # -> multiplier
    failure_injector: Optional[Callable[[int], None]] = None  # tests raise here
    # Called EVERY step (not just log_every) with (step, metrics, state) after
    # the step commits; metrics values are host floats.  The convergence lab
    # hangs its per-step recorder (loss / grad-energy / Assumption 3.1 probe)
    # here without changing the history contract below.
    metrics_hook: Optional[Callable[[int, Dict, Dict], None]] = None


def train_loop(
    model,
    opt_cfg,
    step_cfg: StepConfig,
    mesh,
    state,
    stream,
    loop_cfg: TrainLoopConfig,
) -> Dict:
    """Runs the loop; returns {"state": final_state, "history": [...]}."""
    manager = (
        ckpt.CheckpointManager(loop_cfg.ckpt_dir, loop_cfg.ckpt_every, loop_cfg.ckpt_keep)
        if loop_cfg.ckpt_dir
        else None
    )

    start_step = 0
    if manager is not None and ckpt.latest_step(loop_cfg.ckpt_dir) is not None:
        state, start_step = ckpt.restore(loop_cfg.ckpt_dir, state)
        print(f"[loop] resumed from step {start_step}")

    # compiled step cache keyed by (theta_bucket,) — schedule-driven rebuilds
    step_fns: Dict[float, Callable] = {}

    def get_step_fn(theta: Optional[float]):
        key = -1.0 if theta is None else theta
        if key not in step_fns:
            cfg = step_cfg
            if theta is not None and step_cfg.reducer is not None:
                cfg = dataclasses.replace(
                    step_cfg, reducer=dataclasses.replace(step_cfg.reducer, theta=theta)
                )
            example = stream.batch_at(0)
            step_fns[key] = build_train_step(model, opt_cfg, cfg, mesh, example)
        return step_fns[key]

    history: List[Dict] = []
    step = start_step
    retries = 0
    while step < loop_cfg.total_steps:
        theta = None
        if loop_cfg.theta_schedule is not None:
            theta = quantize_theta(loop_cfg.theta_schedule(step))
        lr_scale = loop_cfg.lr_schedule(step) if loop_cfg.lr_schedule else 1.0
        try:
            if loop_cfg.failure_injector is not None:
                loop_cfg.failure_injector(step)
            batch = stream.batch_at(step)
            step_fn = get_step_fn(theta)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            if loop_cfg.metrics_hook is not None:
                hook_metrics = {k: float(v) for k, v in metrics.items()}
                hook_metrics.update(step=step, theta=theta, dt=time.perf_counter() - t0)
                loop_cfg.metrics_hook(step, hook_metrics, state)
            if step % loop_cfg.log_every == 0:
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics.update(step=step, theta=theta, dt=time.perf_counter() - t0)
                history.append(metrics)
            step += 1
            retries = 0
            if manager is not None:
                manager.maybe_save(step, state)
        except RuntimeError as e:
            retries += 1
            if manager is None or retries > loop_cfg.max_retries:
                raise
            print(f"[loop] step {step} failed ({e}); rolling back to last checkpoint")
            state, step = ckpt.restore(loop_cfg.ckpt_dir, state)
    return {"state": state, "history": history}
