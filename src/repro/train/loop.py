"""The training loop: schedules, checkpoint/restart, failure recovery.

Fault-tolerance contract (DESIGN.md §19):
* auto-resume — on start, restore the newest checkpoint if one exists;
* typed fault injection — ``TrainLoopConfig.faults`` takes a deterministic
  ``comms.faults.FaultPlan``; host-side events (``step_crash``,
  ``slow_worker``) fire here, in-step events (``nan_grad``,
  ``payload_corrupt``) ride the reducer config into the jitted step;
* step-level recovery — a failing step (any ``_RECOVERABLE`` error) rolls
  back to the last checkpoint and retries; with no checkpoint yet it
  retries in place (nothing was committed), and the original error — not a
  ``FileNotFoundError`` from a hopeless restore — surfaces if recovery
  fails;
* degradation ladder — when retries are exhausted, or the non-finite guard
  keeps skipping steps, the loop walks ``reducers.degrade_config`` one
  rung at a time (pallas→reference, streamed→stacked, exotic transports→
  flat psum, compressed→dense) instead of raising; each transition lands
  in the run's ``ReducerHealth`` record.  Only a fully-degraded config
  that still fails propagates the error;
* theta/lr schedules — evaluated host-side per step; a *theta* change swaps
  the compiled step function (static kept-k), which is the recompile-bounded
  behaviour discussed in core/schedules.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Set

from repro.comms import faults as faults_mod
from repro.comms import reducers
from repro.core.schedules import quantize_theta
from repro.train import checkpoint as ckpt
from repro.train.step import StepConfig, build_train_step

__all__ = ["TrainLoopConfig", "train_loop", "_RECOVERABLE"]


def _recoverable_types():
    """Errors the rollback/ladder path may absorb: host-side RuntimeErrors,
    float traps, and whatever runtime-error types this jax generation
    raises from a failing executable (modern jax subclasses RuntimeError,
    older jaxlib spellings are added defensively)."""
    types = [RuntimeError, FloatingPointError]
    try:
        from jax.errors import JaxRuntimeError

        types.append(JaxRuntimeError)
    except ImportError:
        pass
    try:
        from jax._src.lib import xla_client

        types.append(xla_client.XlaRuntimeError)
    except (ImportError, AttributeError):
        pass
    return tuple(types)


_RECOVERABLE = _recoverable_types()


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    max_retries: int = 2
    theta_schedule: Optional[Callable[[int], float]] = None  # -> theta
    lr_schedule: Optional[Callable[[int], float]] = None  # -> multiplier
    # deterministic fault plan (comms/faults.py): step_crash / slow_worker
    # events fire host-side here; nan_grad / payload_corrupt events should
    # ALSO be set on the reducer config (ReducerConfig.faults) — they run
    # inside the jitted step
    faults: Optional[faults_mod.FaultPlan] = None
    # Called EVERY step (not just log_every) with (step, metrics, state) after
    # the step commits; metrics values are host floats.  The convergence lab
    # hangs its per-step recorder (loss / grad-energy / Assumption 3.1 probe)
    # here without changing the history contract below.
    metrics_hook: Optional[Callable[[int, Dict, Dict], None]] = None
    # Called EVERY committed step with (step, state) AFTER metrics_hook —
    # the serving publish path (serve/publish.py, DESIGN.md §20) hangs
    # WeightDeltaPublisher.hook() here; the publisher applies its own
    # publish_every cadence.  Kept separate from metrics_hook: it consumes
    # the state (not the metrics), and skipped steps still publish — the
    # replica fleet tracks committed weights, whatever the step did.
    publish_hook: Optional[Callable[[int, Dict], None]] = None
    # crash events that already fired, persisted ACROSS train_loop calls on
    # the same config: a restarted process does not re-hit a transient
    # crash, so fatal-crash + auto-resume runs complete (comms/faults.py)
    fired_faults: Set[int] = dataclasses.field(
        default_factory=set, repr=False, compare=False)


def train_loop(
    model,
    opt_cfg,
    step_cfg: StepConfig,
    mesh,
    state,
    stream,
    loop_cfg: TrainLoopConfig,
) -> Dict:
    """Runs the loop; returns {"state": ..., "history": [...], "health": {...}}."""
    manager = (
        ckpt.CheckpointManager(loop_cfg.ckpt_dir, loop_cfg.ckpt_every, loop_cfg.ckpt_keep)
        if loop_cfg.ckpt_dir
        else None
    )
    health = faults_mod.ReducerHealth()

    start_step = 0
    if manager is not None and ckpt.latest_step(loop_cfg.ckpt_dir) is not None:
        state, start_step = ckpt.restore(loop_cfg.ckpt_dir, state)
        print(f"[loop] resumed from step {start_step}")

    # the live step config: the degradation ladder replaces the reducer in
    # here and invalidates the compiled-step cache below
    live_cfg = step_cfg

    # compiled step cache keyed by (theta_bucket,) — schedule-driven rebuilds
    step_fns: Dict[float, Callable] = {}

    def get_step_fn(theta: Optional[float]):
        key = -1.0 if theta is None else theta
        if key not in step_fns:
            cfg = live_cfg
            if theta is not None and live_cfg.reducer is not None:
                cfg = dataclasses.replace(
                    live_cfg, reducer=dataclasses.replace(live_cfg.reducer, theta=theta)
                )
            example = stream.batch_at(0)
            step_fns[key] = build_train_step(model, opt_cfg, cfg, mesh, example)
        return step_fns[key]

    def degrade(at_step: int, reason: str) -> bool:
        """One rung down the ladder; False when there is nowhere to go."""
        nonlocal live_cfg, state
        if live_cfg.reducer is None:
            return False
        rung = reducers.degrade_config(live_cfg.reducer)
        if rung is None:
            return False
        new_reducer, label = rung
        if live_cfg.reducer.error_feedback and not new_reducer.error_feedback:
            # the dense rung has no compression loss to accumulate — drop
            # the residual from the state (and from future checkpoints)
            state = {k: v for k, v in state.items() if k != "residual"}
        live_cfg = dataclasses.replace(live_cfg, reducer=new_reducer)
        step_fns.clear()
        health.record_transition(at_step, label, reason)
        print(f"[loop] step {at_step}: degrading exchange — {label} ({reason})")
        return True

    history: List[Dict] = []
    step = start_step
    retries = 0
    consecutive_skips = 0
    while step < loop_cfg.total_steps:
        theta = None
        if loop_cfg.theta_schedule is not None:
            theta = quantize_theta(loop_cfg.theta_schedule(step))
        lr_scale = loop_cfg.lr_schedule(step) if loop_cfg.lr_schedule else 1.0
        try:
            if loop_cfg.faults is not None:
                for idx, ev in loop_cfg.faults.crashes_at(step):
                    if idx in loop_cfg.fired_faults:
                        continue
                    loop_cfg.fired_faults.add(idx)
                    if ev.fatal:
                        raise faults_mod.FatalInjectedCrash(
                            f"planned fatal crash at step {step}")
                    raise faults_mod.InjectedCrash(
                        f"planned crash at step {step}")
                delay = loop_cfg.faults.delay_at(step)
                if delay > 0:
                    health.record_delay(step)
                    time.sleep(delay)
            batch = stream.batch_at(step)
            step_fn = get_step_fn(theta)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            skipped = bool(float(metrics.get("skipped", 0.0)))
            if skipped:
                health.record_skip(step)
                consecutive_skips += 1
            else:
                consecutive_skips = 0
            if loop_cfg.metrics_hook is not None:
                hook_metrics = {k: float(v) for k, v in metrics.items()}
                hook_metrics.update(step=step, theta=theta,
                                    dt=time.perf_counter() - t0,
                                    degradations=len(health.transitions))
                loop_cfg.metrics_hook(step, hook_metrics, state)
            if loop_cfg.publish_hook is not None:
                loop_cfg.publish_hook(step, state)
            if step % loop_cfg.log_every == 0:
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics.update(step=step, theta=theta, dt=time.perf_counter() - t0)
                history.append(metrics)
            step += 1
            retries = 0
            if manager is not None:
                manager.maybe_save(step, state)
            # the guard skipping step after step means the exchange itself is
            # producing garbage (poisoned payloads, broken kernels): walk the
            # ladder — skipped steps committed nothing, so no rollback needed
            if consecutive_skips > loop_cfg.max_retries:
                if degrade(step, f"{consecutive_skips} consecutive skipped steps"):
                    consecutive_skips = 0
        except _RECOVERABLE as e:
            retries += 1
            if retries > loop_cfg.max_retries:
                if not degrade(step, f"step failure: {e}"):
                    raise
                retries = 0
            if (manager is not None
                    and ckpt.latest_step(loop_cfg.ckpt_dir) is not None):
                print(f"[loop] step {step} failed ({e}); "
                      f"rolling back to last checkpoint")
                state, step = ckpt.restore(loop_cfg.ckpt_dir, state)
            else:
                # nothing committed and nothing to restore: retry in place,
                # keeping the ORIGINAL error as what surfaces on exhaustion
                print(f"[loop] step {step} failed ({e}); "
                      f"no checkpoint yet — retrying in place")
    if manager is not None:
        ckpt.wait()
    return {"state": state, "history": history, "health": health.to_dict()}
