"""Core: the paper's gradient-compression framework (SuperNeurons, 2018).

Public API:
    FFTCompressor / FFTCompressorConfig  — the paper's pipeline (Fig. 5)
    TimeDomainCompressor / QuantOnlyCompressor / NoCompression — ablations
    baselines: TernGrad, QSGD, DGCTopK, AjiThreshold, OneBitSGD
    quantizer: range-based N-bit float (Alg. 1)
    schedules: theta schedules incl. Theorem 3.5
"""

from repro.core.compressor import (
    FFTCompressor,
    FFTCompressorConfig,
    FFTPayload,
    NoCompression,
    QuantOnlyCompressor,
    TimeDomainCompressor,
)
from repro.core.quantizer import (
    FittedQuantizer,
    RangeQuantConfig,
    fit_quantizer,
)
from repro.core import baselines, error_feedback, fft, packing, schedules, sparsify, theory

__all__ = [
    "FFTCompressor",
    "FFTCompressorConfig",
    "FFTPayload",
    "NoCompression",
    "QuantOnlyCompressor",
    "TimeDomainCompressor",
    "FittedQuantizer",
    "RangeQuantConfig",
    "fit_quantizer",
    "baselines",
    "error_feedback",
    "fft",
    "packing",
    "schedules",
    "sparsify",
    "theory",
]
