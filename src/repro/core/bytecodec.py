"""Versioned byte codec for compressed payloads (DESIGN.md §20).

The registered-pytree payloads (``FFTPayload`` / ``StackedPayload``) are the
IN-PROCESS wire format: they flow through collectives as device arrays.  The
serving ring buffer — and any future cross-process transport — needs the same
payload as BYTES a separate process can read back without sharing a Python
session.  This module is that boundary:

    blob    = to_bytes(payload)
    payload = from_bytes(blob)

Format (all integers little-endian):

    [0:4]    magic  b"RPAY"
    [4:8]    u32    header length H
    [8:8+H]  JSON   self-describing header (utf-8)
    [8+H:]   raw plane bytes, concatenated in header order, C-order LE

The header carries everything needed to reconstruct the payload with no
out-of-band knowledge — format version, payload kind, static aux fields
(``sizes``/``orig_len``, ``chunk``, ``has_im``), and one descriptor
``{name, dtype, shape}`` per array plane (``re``/``im``/``idx`` plus the four
quantizer leaves and its ``n_bits``/``m_bits`` when quantization is on).
Dtypes are spelled as numpy names ("uint8", "float32", ...), so the blob is
backend-agnostic: a payload compressed by any engine backend round-trips
through host memory and reconstructs on any other (the planes are identical
across backends by the parity contract, tests/test_engine.py).

Version policy: ``FORMAT_VERSION`` bumps on any layout change; ``from_bytes``
rejects unknown versions loudly instead of misparsing silently.  Readers MUST
tolerate unknown *header keys* (forward-compatible additions); writers MUST
NOT change the meaning of existing keys within a version.
"""

from __future__ import annotations

import json
import struct
from typing import List, Tuple, Union

import numpy as np
import jax.numpy as jnp

from repro.core.compressor import FFTPayload, StackedPayload
from repro.core.quantizer import FittedQuantizer, RangeQuantConfig

__all__ = ["FORMAT_VERSION", "MAGIC", "to_bytes", "from_bytes"]

MAGIC = b"RPAY"
FORMAT_VERSION = 1

# quantizer leaves in serialization order (matches FittedQuantizer fields)
_QUANT_LEAVES = ("eps", "p_codes", "vmax", "vmin")


def _plane_desc(name: str, arr: np.ndarray) -> dict:
    return {"name": name, "dtype": arr.dtype.name,
            "shape": list(arr.shape)}


def _host(arr) -> np.ndarray:
    """Device array -> contiguous little-endian host array."""
    a = np.asarray(arr)
    le = a.dtype.newbyteorder("<")
    return np.ascontiguousarray(a.astype(le, copy=False))


def to_bytes(payload: Union[FFTPayload, StackedPayload]) -> bytes:
    """Serialize a payload to a self-describing binary blob."""
    if isinstance(payload, StackedPayload):
        kind = "stacked"
        aux = {"sizes": [int(s) for s in payload.sizes]}
    elif isinstance(payload, FFTPayload):
        kind = "fft"
        aux = {"orig_len": int(payload.orig_len)}
    else:
        raise TypeError(f"cannot serialize {type(payload).__name__}")

    planes: List[Tuple[str, np.ndarray]] = [
        ("re", _host(payload.re)),
        ("im", _host(payload.im)),
        ("idx", _host(payload.idx)),
    ]
    quant_hdr = None
    if payload.quant is not None:
        q = payload.quant
        quant_hdr = {"n_bits": q.config.n_bits, "m_bits": q.config.m_bits,
                     "planes": []}
        for leaf in _QUANT_LEAVES:
            arr = _host(getattr(q, leaf))
            quant_hdr["planes"].append(_plane_desc(leaf, arr))
            planes.append((f"quant.{leaf}", arr))

    header = {
        "magic": "RPAY",
        "format_version": FORMAT_VERSION,
        "kind": kind,
        "chunk": int(payload.chunk),
        "has_im": bool(payload.has_im),
        "planes": [_plane_desc(n, a) for n, a in planes[:3]],
        "quant": quant_hdr,
        **aux,
    }
    hdr_bytes = json.dumps(header, sort_keys=True).encode("utf-8")
    out = bytearray()
    out += MAGIC
    out += struct.pack("<I", len(hdr_bytes))
    out += hdr_bytes
    for _, arr in planes:
        out += arr.tobytes(order="C")
    return bytes(out)


def _read_plane(buf: memoryview, off: int, desc: dict) -> Tuple[np.ndarray, int]:
    dtype = np.dtype(desc["dtype"]).newbyteorder("<")
    shape = tuple(int(d) for d in desc["shape"])
    nbytes = dtype.itemsize * int(np.prod(shape, dtype=np.int64)) if shape \
        else dtype.itemsize
    if off + nbytes > len(buf):
        raise ValueError(
            f"payload blob truncated: plane {desc['name']!r} needs "
            f"{nbytes} bytes at offset {off}, blob has {len(buf)}")
    arr = np.frombuffer(buf[off:off + nbytes], dtype=dtype).reshape(shape)
    # native byte order for jnp; copy releases the memoryview
    return np.ascontiguousarray(arr.astype(arr.dtype.newbyteorder("="))), \
        off + nbytes


def from_bytes(blob: bytes) -> Union[FFTPayload, StackedPayload]:
    """Reconstruct a payload from :func:`to_bytes` output.

    Validates the magic and format version; raises ``ValueError`` on
    anything that is not a well-formed v1 blob (truncation included) so a
    torn ring-buffer read can never yield a silently-wrong payload.
    """
    if len(blob) < 8 or blob[:4] != MAGIC:
        raise ValueError("not a payload blob (bad magic)")
    (hdr_len,) = struct.unpack("<I", blob[4:8])
    if len(blob) < 8 + hdr_len:
        raise ValueError("payload blob truncated: incomplete header")
    try:
        header = json.loads(blob[8:8 + hdr_len].decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ValueError(f"payload header is not valid JSON: {e}") from None
    version = header.get("format_version")
    if version != FORMAT_VERSION:
        raise ValueError(
            f"unsupported payload format version {version!r} "
            f"(this reader supports {FORMAT_VERSION})")
    kind = header.get("kind")
    if kind not in ("fft", "stacked"):
        raise ValueError(f"unknown payload kind {kind!r}")

    buf = memoryview(blob)
    off = 8 + hdr_len
    arrays = {}
    for desc in header["planes"]:
        arrays[desc["name"]], off = _read_plane(buf, off, desc)

    quant = None
    if header.get("quant") is not None:
        qh = header["quant"]
        leaves = {}
        for desc in qh["planes"]:
            leaves[desc["name"]], off = _read_plane(buf, off, desc)
        missing = set(_QUANT_LEAVES) - set(leaves)
        if missing:
            raise ValueError(f"quantizer block missing leaves {sorted(missing)}")
        quant = FittedQuantizer(
            RangeQuantConfig(int(qh["n_bits"]), int(qh["m_bits"])),
            *(jnp.asarray(leaves[name]) for name in _QUANT_LEAVES))

    re = jnp.asarray(arrays["re"])
    im = jnp.asarray(arrays["im"])
    idx = jnp.asarray(arrays["idx"])
    chunk = int(header["chunk"])
    has_im = bool(header["has_im"])
    if kind == "stacked":
        return StackedPayload(re, im, idx, quant,
                              tuple(int(s) for s in header["sizes"]),
                              chunk, has_im=has_im)
    return FFTPayload(re, im, idx, quant, int(header["orig_len"]),
                      chunk, has_im=has_im)
