"""The composed gradient-compression pipeline (paper Fig. 5).

    gradient --rFFT--> spectrum --theta-drop--> sparse --range-quant--> codes
             --pack--> (values, indices) payload --> wire

and the exact reverse on the receiver.  All stages are jit-compatible with
static shapes; the payload is a registered pytree so it flows through
``shard_map`` collectives unchanged.

Key property used by the distributed reducer (beyond-paper, DESIGN.md §10):
the FFT is linear, so workers can sum *spectra* after dequantize/unpack and run
a single inverse FFT — ``decompress_spectrum`` exposes that path.

Compressor protocol (duck-typed; baselines implement the same):

    payload = comp.compress(x_flat, key=None)
    x_hat   = comp.decompress(payload)
    bits    = comp.wire_bits(n)         # static wire size estimate
    ratio   = comp.ratio(n)             # 32*n / wire_bits

Stage execution is delegated to a pluggable ENGINE BACKEND
(``kernels/engine.py``): ``reference`` (pure jnp, seed behavior), ``pallas``
(the fused device kernels), or ``auto`` (pallas when the platform compiles
Mosaic and the config is kernel-eligible).  Every backend emits the same
payload layout, so transports and reducers are backend-oblivious.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fft as cfft
from repro.core import packing, selection, sparsify
from repro.core.quantizer import (
    FittedQuantizer,
    RangeQuantConfig,
    decode as q_decode,
    encode as q_encode,
    fit_quantizer,
)

__all__ = [
    "FFTCompressorConfig",
    "FFTPayload",
    "StackedPayload",
    "stack_bucket_quant",
    "valid_chunk_mask",
    "FFTCompressor",
    "TimeDomainCompressor",
    "QuantOnlyCompressor",
    "NoCompression",
]


def valid_chunk_mask(sizes, max_chunks: int, chunk: int) -> jnp.ndarray:
    """(n_buckets, max_chunks, 1) mask of REAL chunk rows in a stacked bucket
    matrix — False on the zero-padding rows the uniform width added.  The
    canonical padding-mask rule of the batched executor (DESIGN.md §14):
    every stacked quantizer fit masks with this, so the fit sees exactly the
    values the per-bucket loop saw."""
    counts = jnp.asarray([-(-int(s) // chunk) for s in sizes])
    return (jnp.arange(max_chunks)[None, :] < counts[:, None])[:, :, None]


def stack_bucket_quant(q: FittedQuantizer) -> FittedQuantizer:
    """Reshape a vector quantizer fit (leaves ``(n_buckets,)``) to the
    StackedPayload leaf layout ``(n_buckets, 1, 1)`` so its params broadcast
    against ``(n_buckets, max_chunks, k)`` payload planes."""
    return FittedQuantizer(
        q.config, q.eps.reshape(-1, 1, 1), q.p_codes.reshape(-1, 1, 1),
        q.vmax.reshape(-1, 1, 1), q.vmin.reshape(-1, 1, 1))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FFTPayload:
    """Wire payload: quantized kept spectrum + indices + quantizer params.

    ``has_im`` (static) marks whether the imaginary plane carries data.
    Time-domain payloads are purely real: they ship an EMPTY ``im`` array
    (shape (c, 0)) with ``has_im=False`` so the collectives move half the
    value bytes — matching ``TimeDomainCompressor.wire_bits``, which has
    always billed a single value plane.
    """

    re: jnp.ndarray  # (c, k) codes (uintN) or f32 when quantization is off
    im: jnp.ndarray  # (c, k), or (c, 0) when has_im=False (time domain)
    idx: jnp.ndarray  # (c, k) int16 bin indices (chunk <= 4096 fits; 16 wire bits)
    quant: Optional[FittedQuantizer]  # None when quantization is off
    orig_len: int = dataclasses.field(metadata={"static": True})
    chunk: int = dataclasses.field(metadata={"static": True})
    has_im: bool = dataclasses.field(default=True, metadata={"static": True})

    def tree_flatten(self):
        return (self.re, self.im, self.idx, self.quant), (
            self.orig_len, self.chunk, self.has_im)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    def validate(self, level: str = "cheap") -> jnp.ndarray:
        """Traced structural sanity check -> bool scalar (DESIGN.md §19).

        ``cheap`` (and ``full``, whose extra checksum comparison lives in
        ``comms.faults`` where the compress-time reference is known):
        index bounds vs the chunk width, finiteness of float value planes,
        and quantizer-param sanity.  O(payload) elementwise work; no
        collectives.
        """
        return _validate_planes(self, level)

    def to_bytes(self) -> bytes:
        """Self-describing binary blob (core.bytecodec, DESIGN.md §20)."""
        from repro.core import bytecodec

        return bytecodec.to_bytes(self)

    @staticmethod
    def from_bytes(blob: bytes) -> "FFTPayload":
        from repro.core import bytecodec

        payload = bytecodec.from_bytes(blob)
        if not isinstance(payload, FFTPayload):
            raise ValueError("blob holds a StackedPayload, not an FFTPayload")
        return payload


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StackedPayload:
    """Struct-of-arrays payload of one WHOLE bucketed exchange (DESIGN.md §14).

    Where the per-bucket loop emits ``n_buckets`` :class:`FFTPayload` objects,
    the batched executor emits ONE of these: every plane carries a leading
    bucket axis (``(n_buckets, max_chunks, k)``), so a transport moves the
    entire exchange with a single collective per plane instead of one per
    bucket.  Per-bucket quantizer params are stacked the same way —
    ``quant`` leaves have shape ``(n_buckets, 1, 1)`` and broadcast against
    the code planes in encode/decode.

    Rows beyond a bucket's true chunk count (``chunk_counts``) are padding:
    their slots hold code 0 at index 0..k-1 and decode to nothing.  Slicing
    row ``b`` down to its true chunk count recovers the exact payload the
    per-bucket loop would have produced (:meth:`bucket_payloads` — the
    bitwise-parity contract, tests/test_stacked.py).
    """

    re: jnp.ndarray  # (n_buckets, max_chunks, k) codes or f32
    im: jnp.ndarray  # same, or (n_buckets, max_chunks, 0) when has_im=False
    idx: jnp.ndarray  # (n_buckets, max_chunks, k) int16 bin indices
    quant: Optional[FittedQuantizer]  # leaves (n_buckets, 1, 1); None when off
    sizes: Tuple[int, ...] = dataclasses.field(metadata={"static": True})
    chunk: int = dataclasses.field(metadata={"static": True})
    has_im: bool = dataclasses.field(default=True, metadata={"static": True})

    def tree_flatten(self):
        return (self.re, self.im, self.idx, self.quant), (
            self.sizes, self.chunk, self.has_im)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @property
    def n_buckets(self) -> int:
        return len(self.sizes)

    @property
    def padded_size(self) -> int:
        return self.re.shape[-2] * self.chunk

    def chunk_counts(self) -> Tuple[int, ...]:
        return tuple(-(-s // self.chunk) for s in self.sizes)

    def bucket_quant(self, b: int) -> Optional[FittedQuantizer]:
        if self.quant is None:
            return None
        q = self.quant
        return FittedQuantizer(q.config, q.eps[b, 0, 0], q.p_codes[b, 0, 0],
                               q.vmax[b, 0, 0], q.vmin[b, 0, 0])

    def bucket_payloads(self) -> list:
        """Slice back to the per-bucket payloads the looped path emits."""
        out = []
        for b, (size, c_b) in enumerate(zip(self.sizes, self.chunk_counts())):
            out.append(FFTPayload(
                self.re[b, :c_b], self.im[b, :c_b], self.idx[b, :c_b],
                self.bucket_quant(b), size, self.chunk, has_im=self.has_im))
        return out

    def validate(self, level: str = "cheap") -> jnp.ndarray:
        """Traced structural sanity check -> bool scalar; see
        :meth:`FFTPayload.validate`."""
        return _validate_planes(self, level)

    def to_bytes(self) -> bytes:
        """Self-describing binary blob (core.bytecodec, DESIGN.md §20)."""
        from repro.core import bytecodec

        return bytecodec.to_bytes(self)

    @staticmethod
    def from_bytes(blob: bytes) -> "StackedPayload":
        from repro.core import bytecodec

        payload = bytecodec.from_bytes(blob)
        if not isinstance(payload, StackedPayload):
            raise ValueError("blob holds an FFTPayload, not a StackedPayload")
        return payload


def _validate_planes(payload, level: str) -> jnp.ndarray:
    """Shared structural checks for FFT/Stacked payloads (DESIGN.md §19)."""
    if level == "off":
        return jnp.bool_(True)
    ok = (payload.idx >= 0).all() & (payload.idx < payload.chunk).all()
    for plane in (payload.re, payload.im):
        if jnp.issubdtype(plane.dtype, jnp.floating) and plane.size:
            ok = ok & jnp.isfinite(plane).all()
    q = payload.quant
    if q is not None:
        ok = ok & jnp.isfinite(q.eps).all() & (q.eps > 0).all()
        ok = ok & jnp.isfinite(q.vmax).all() & jnp.isfinite(q.vmin).all()
        ok = ok & (q.vmin <= q.vmax).all()
        n_codes = q.config.n_codes
        ok = ok & ((q.p_codes >= 1) & (q.p_codes <= n_codes - 2)).all()
    return ok


@dataclasses.dataclass(frozen=True)
class FFTCompressorConfig:
    """Static knobs of the paper's pipeline."""

    theta: float = 0.7  # frequency drop-out ratio (paper's main knob)
    n_bits: int = 8  # range-based float width (paper uses 8)
    m_bits: int = 3
    chunk: int = cfft.DEFAULT_CHUNK
    quantize: bool = True
    range_mode: str = "auto"  # "auto": per-call min/max; "fixed": use fixed_range
    fixed_range: Tuple[float, float] = (-1.0, 1.0)  # paper: [-1,1] AlexNet, [-6,6] ResNet
    index_bits: int = 16
    # stage-execution engine: reference | pallas | auto (kernels/engine.py)
    backend: str = "reference"
    # selection engine (core/selection.py, DESIGN.md §16): how the top-k kept
    # set is found.  "sort" is the seed behavior (exact lax.top_k); "bisect"
    # and "sampled" are the O(n) threshold selectors; "auto" resolves per row
    # width.  sample_rate / tau_refine_iters / selector_seed parameterize the
    # sampled estimator and are inert under other selectors.
    selector: str = "sort"
    sample_rate: float = 1.0 / 64.0
    tau_refine_iters: int = 16
    selector_seed: int = 0

    def __post_init__(self):
        # payloads carry int16 indices (and bill index_bits=16 on the wire);
        # a chunk beyond int16 range would silently wrap top-k indices
        if self.chunk > 32767:
            raise ValueError(f"chunk must be <= 32767 (int16 indices), got {self.chunk}")
        if self.chunk < 1:
            raise ValueError(f"chunk must be positive, got {self.chunk}")
        from repro.core.selection import SELECTOR_NAMES

        if self.selector not in SELECTOR_NAMES:
            raise ValueError(
                f"unknown selector {self.selector!r}; expected one of {SELECTOR_NAMES}")
        if not 0.0 < self.sample_rate <= 1.0:
            raise ValueError(
                f"sample_rate must be in (0, 1], got {self.sample_rate}")
        if self.tau_refine_iters < 1:
            raise ValueError(
                f"tau_refine_iters must be >= 1, got {self.tau_refine_iters}")
        from repro.kernels.engine import BACKEND_NAMES

        if self.backend not in BACKEND_NAMES:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of {BACKEND_NAMES}")

    def with_theta(self, theta: float) -> "FFTCompressorConfig":
        return dataclasses.replace(self, theta=theta)


class FFTCompressor:
    """Paper's full pipeline: FFT -> theta-drop -> range-quant -> pack.

    Owns the protocol and the config; STAGE EXECUTION is delegated to the
    engine backend named by ``config.backend`` (kernels/engine.py).  All
    backends emit the same payload layout, so a payload compressed by one
    backend decompresses under any other.
    """

    def __init__(self, config: FFTCompressorConfig = FFTCompressorConfig()):
        self.config = config
        from repro.kernels import engine as _engine

        self._engine_mod = _engine
        self._backend = _engine.get_backend(config.backend)

    @property
    def backend(self):
        """The engine backend executing this compressor's stages."""
        return self._backend

    # -- protocol ----------------------------------------------------------
    def compress(self, x_flat: jnp.ndarray, key=None) -> FFTPayload:
        return self._backend.compress(self.config, x_flat)

    def decompress_spectrum(self, payload: FFTPayload) -> jnp.ndarray:
        """Payload -> dense complex spectrum (c, chunk//2+1)."""
        return self._backend.decompress_spectrum(payload)

    def decompress(self, payload: FFTPayload) -> jnp.ndarray:
        return self._backend.decompress(payload)

    def compress_buckets(self, bucket_flats) -> list:
        """Per-bucket compression: each bucket fits its OWN quantizer range
        (DESIGN.md §8); the bucketed transports rely on this."""
        return self._backend.compress_buckets(self.config, bucket_flats)

    def compress_stacked(self, stacked: jnp.ndarray, sizes) -> StackedPayload:
        """Batched bucket executor (DESIGN.md §14): compress EVERY bucket of a
        ``(n_buckets, padded_size)`` matrix (``bucketing.stack_buckets``) with
        one batched kernel pass, fitting one quantizer per bucket row.
        Bitwise-equal to :meth:`compress_buckets` on the same layout."""
        return self._backend.compress_stacked(self.config, stacked, sizes)

    def decompress_stacked(self, payload: StackedPayload) -> jnp.ndarray:
        """Inverse of :meth:`compress_stacked` -> ``(n_buckets, padded_size)``
        (``bucketing.unstack_buckets`` recovers the flat buffer)."""
        return self._backend.decompress_stacked(payload)

    # -- size accounting ----------------------------------------------------
    def wire_bits(self, n: int) -> int:
        return self._engine_mod.wire_bits(self.config, n)

    def ratio(self, n: int) -> float:
        return 32.0 * n / self.wire_bits(n)


class TimeDomainCompressor:
    """DGC/Aji-style top-k in the time domain + the same range quantizer.

    Used for the paper's Fig. 12 comparison (frequency vs time domain at the
    same theta).
    """

    def __init__(self, config: FFTCompressorConfig = FFTCompressorConfig()):
        self.config = config
        self._qcfg = RangeQuantConfig(config.n_bits, config.m_bits)

    def compress(self, x_flat: jnp.ndarray, key=None):
        cfg = self.config
        x2d, n = cfft.pad_to_chunks(x_flat, cfg.chunk)
        k = sparsify.keep_count(cfg.chunk, cfg.theta)
        idx, _ = selection.select_indices(
            jnp.abs(x2d), k, cfg.selector, sample_rate=cfg.sample_rate,
            refine_iters=cfg.tau_refine_iters, seed=cfg.selector_seed)
        vals = packing.pack_by_indices(x2d, idx)
        if cfg.quantize:
            quant = fit_quantizer(vals.min(), vals.max(), self._qcfg)
            vals = q_encode(vals, quant)
        else:
            quant = None
        # int16 indices, same as FFTPayload's frequency path: chunk <= 4096
        # fits and the wire accounting (index_bits=16) matches the payload.
        # The payload is purely real: ship an EMPTY im plane (has_im=False)
        # so collectives move exactly the bytes wire_bits bills — the old
        # zeros_like(vals) plane doubled the value bytes on every exchange.
        empty_im = jnp.zeros(vals.shape[:-1] + (0,), vals.dtype)
        return FFTPayload(vals, empty_im, idx.astype(jnp.int16), quant, n,
                          cfg.chunk, has_im=False)

    def decompress(self, payload: FFTPayload) -> jnp.ndarray:
        vals = payload.re
        if payload.quant is not None:
            vals = q_decode(vals, payload.quant)
        dense = packing.unpack_by_indices(
            vals.astype(jnp.float32), payload.idx, payload.chunk
        )
        return dense.reshape(-1)[: payload.orig_len]

    def compress_stacked(self, stacked: jnp.ndarray, sizes) -> StackedPayload:
        """Batched per-bucket top-k (DESIGN.md §14): one batched selection over
        the ``(n_buckets, padded_size)`` matrix, one quantizer fit per bucket
        row (padding chunks masked out of the range), bitwise-equal to the
        per-bucket loop."""
        cfg = self.config
        sizes = tuple(int(s) for s in sizes)
        n_buckets, padded = stacked.shape
        c_max = padded // cfg.chunk
        x3 = stacked.reshape(n_buckets, c_max, cfg.chunk).astype(jnp.float32)
        k = sparsify.keep_count(cfg.chunk, cfg.theta)
        idx, _ = selection.select_indices(
            jnp.abs(x3), k, cfg.selector, sample_rate=cfg.sample_rate,
            refine_iters=cfg.tau_refine_iters, seed=cfg.selector_seed)
        vals = packing.pack_by_indices(x3, idx)
        if cfg.quantize:
            valid = valid_chunk_mask(sizes, c_max, cfg.chunk)
            lo = jnp.where(valid, vals, jnp.inf).min(axis=(1, 2))
            hi = jnp.where(valid, vals, -jnp.inf).max(axis=(1, 2))
            quant = stack_bucket_quant(fit_quantizer(lo, hi, self._qcfg))
            vals = q_encode(vals, quant)
        else:
            quant = None
        empty_im = jnp.zeros(vals.shape[:-1] + (0,), vals.dtype)
        return StackedPayload(vals, empty_im, idx.astype(jnp.int16), quant,
                              sizes, cfg.chunk, has_im=False)

    def decompress_stacked(self, payload: StackedPayload) -> jnp.ndarray:
        vals = payload.re
        if payload.quant is not None:
            vals = q_decode(vals, payload.quant)
        n_buckets, c_max, k = vals.shape
        dense = packing.unpack_by_indices(
            vals.astype(jnp.float32).reshape(n_buckets * c_max, k),
            payload.idx.reshape(n_buckets * c_max, k), payload.chunk)
        return dense.reshape(n_buckets, c_max * payload.chunk)

    def wire_bits(self, n: int) -> int:
        cfg = self.config
        n_chunks = max(1, -(-n // cfg.chunk))
        k = sparsify.keep_count(cfg.chunk, cfg.theta)
        value_bits = cfg.n_bits if cfg.quantize else 32
        return n_chunks * k * (value_bits + cfg.index_bits) + 4 * 32

    def ratio(self, n: int) -> float:
        return 32.0 * n / self.wire_bits(n)


class QuantOnlyCompressor:
    """Range-based N-bit quantization without sparsification (ablation)."""

    def __init__(self, n_bits: int = 8, m_bits: int = 3):
        self._qcfg = RangeQuantConfig(n_bits, m_bits)
        self.n_bits = n_bits

    def compress(self, x_flat: jnp.ndarray, key=None):
        quant = fit_quantizer(x_flat.min(), x_flat.max(), self._qcfg)
        return (q_encode(x_flat, quant), quant)

    def decompress(self, payload):
        codes, quant = payload
        return q_decode(codes, quant)

    def wire_bits(self, n: int) -> int:
        return n * self.n_bits + 4 * 32

    def ratio(self, n: int) -> float:
        return 32.0 * n / self.wire_bits(n)


class NoCompression:
    """Identity compressor (the paper's 'orig' baseline)."""

    def compress(self, x_flat: jnp.ndarray, key=None):
        return x_flat

    def decompress(self, payload):
        return payload

    def wire_bits(self, n: int) -> int:
        return 32 * n

    def ratio(self, n: int) -> float:
        return 1.0
