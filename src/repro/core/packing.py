"""Sparse-to-dense packing (paper §III-B.1's status-bitmap + prefix-sum pack).

Two payload layouts, both static-shape (XLA requirement):

* **index payload** (default): per-chunk top-k gives (values[(c,k)],
  indices[(c,k)] int16).  Cost per kept coefficient: payload_bits + 16.
  Smaller than the bitmap whenever (1-theta)*16 < 1 bit/elem, i.e. theta<0.9375
  relative to a 1-bit map over a 4096 chunk — and it removes the prefix-sum
  from the decompress critical path.
* **bitmap payload** (paper-faithful): a status bitmap (1 bit/elem packed into
  uint32 words) plus the dense value vector in chunk order.  The prefix-sum
  pack of the paper maps to ``jnp.nonzero(..., size=k)`` under a static kept
  budget; the Pallas ``pack`` kernel implements the same with a VMEM-local
  cumulative sum.

Both round-trip exactly (tests/test_packing.py, hypothesis sweeps).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "pack_by_indices",
    "unpack_by_indices",
    "make_bitmap",
    "bitmap_to_mask",
    "pack_bitmap",
    "unpack_bitmap",
    "payload_bits_index",
    "payload_bits_bitmap",
]


def pack_by_indices(x2d: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather per-row kept values: (c, n), (c, k) -> (c, k)."""
    return jnp.take_along_axis(x2d, idx, axis=-1)


def unpack_by_indices(values: jnp.ndarray, idx: jnp.ndarray, n: int) -> jnp.ndarray:
    """Scatter per-row values back to dense (c, n) with zeros elsewhere."""
    zeros = jnp.zeros(values.shape[:-1] + (n,), values.dtype)
    return jax.vmap(lambda row, i, v: row.at[i].set(v))(zeros, idx, values)


# ---------------------------------------------------------------------------
# Bitmap layout (paper-faithful status vector)
# ---------------------------------------------------------------------------


def make_bitmap(mask: jnp.ndarray) -> jnp.ndarray:
    """Bool (c, n) -> packed uint32 words (c, ceil(n/32)). n must be mult of 32."""
    c, n = mask.shape
    assert n % 32 == 0, "bitmap requires chunk % 32 == 0"
    bits = mask.reshape(c, n // 32, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def bitmap_to_mask(words: jnp.ndarray, n: int) -> jnp.ndarray:
    """Packed uint32 words (c, n//32) -> bool mask (c, n)."""
    c = words.shape[0]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(c, n).astype(bool)


class BitmapPayload(NamedTuple):
    """Paper layout: status bitmap + compacted dense values (chunk order)."""

    words: jnp.ndarray  # (c, n//32) uint32
    values: jnp.ndarray  # (c, k) compacted, chunk order, zero-filled tail
    count: jnp.ndarray  # (c,) int32 actual nonzeros (<= k)


def pack_bitmap(x2d: jnp.ndarray, mask: jnp.ndarray, k: int) -> BitmapPayload:
    """Prefix-sum compaction under a static budget k (paper's parallel pack).

    Elements beyond the k-th nonzero of a row are dropped (the thresholding
    guarantees <= k nonzeros per row when used with top-k masks).
    """
    words = make_bitmap(mask)

    def row_pack(row, m):
        idx = jnp.nonzero(m, size=k, fill_value=row.shape[0] - 1)[0]
        vals = row[idx] * (jnp.arange(k) < jnp.sum(m)).astype(row.dtype)
        return vals

    values = jax.vmap(row_pack)(x2d, mask)
    return BitmapPayload(words, values, jnp.sum(mask, axis=-1).astype(jnp.int32))


def unpack_bitmap(payload: BitmapPayload, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bitmap` -> dense (c, n)."""
    mask = bitmap_to_mask(payload.words, n)

    def row_unpack(m, vals):
        # position of each element among the nonzeros of its row
        pos = jnp.cumsum(m) - 1
        gathered = vals[jnp.clip(pos, 0, vals.shape[0] - 1)]
        return jnp.where(m, gathered, 0.0).astype(vals.dtype)

    return jax.vmap(row_unpack)(mask, payload.values)


# ---------------------------------------------------------------------------
# Size accounting (feeds the §III-D break-even model and EXPERIMENTS.md)
# ---------------------------------------------------------------------------


def payload_bits_index(n: int, k: int, value_bits: int, index_bits: int = 16) -> int:
    """Bits per chunk for the index layout (index_bits/coeff overhead)."""
    return k * (value_bits + index_bits)


def payload_bits_bitmap(n: int, k: int, value_bits: int) -> int:
    """Bits per chunk for the paper's bitmap layout (n/k bits/coeff overhead).

    Bitmap wins whenever 1/(1-theta) < index_bits, i.e. theta < 15/16 for
    16-bit indices — the paper's theta<=0.9 regime ships the bitmap; DGC's
    theta=0.999 regime ships indices.
    """
    return n + k * value_bits
