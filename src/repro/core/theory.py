"""Convergence-theory helpers (paper §III-C, Lemma 3.3 / Thm 3.4 / Thm 3.5).

These make the paper's guarantees *executable*: tests and benchmarks call
:func:`assumption31_holds` on every sparsifier and evaluate the Thm 3.4 bound
against measured training curves.

Facts used by the tests (DESIGN.md §6): dropping the theta-fraction of
*smallest-magnitude* coefficients of any orthonormal transform discards at
most a theta fraction of the energy, so ||v - v_hat|| <= sqrt(theta) * ||v||
always holds; on near-normal gradients the empirical constant is far below
theta itself, which is what Assumption 3.1 asks for.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax.numpy as jnp

__all__ = [
    "assumption31_stats",
    "assumption31_holds",
    "assumption31_holds_stats",
    "thm34_bound",
    "Thm34Terms",
    "CurveConstants",
    "estimate_curve_constants",
    "Thm34Envelope",
    "thm34_envelope",
    "curves_close",
]


def assumption31_stats(v: jnp.ndarray, v_hat: jnp.ndarray):
    """Returns (||v - v_hat|| / ||v||, ||v_hat|| / ||v||)."""
    nv = jnp.maximum(jnp.linalg.norm(v), 1e-30)
    return jnp.linalg.norm(v - v_hat) / nv, jnp.linalg.norm(v_hat) / nv


def assumption31_holds_stats(
    err_ratio: float,
    norm_ratio: float,
    theta: float,
    slack: float = 1.0,
    norm_tol: float = 1e-4,
) -> bool:
    """Assumption 3.1 on precomputed ratios (the lab records these per step).

    ``norm_tol`` loosens the ``||v_hat|| <= ||v||`` side for quantized
    pipelines: round-to-nearest encoding can push individual coefficients (and
    hence the reconstruction norm) up to one mantissa step above the input,
    so quantized runs pass ``norm_tol ~ quantization_rtol``.
    """
    return bool(
        (float(err_ratio) <= slack * theta + 1e-6)
        & (float(norm_ratio) <= 1.0 + norm_tol)
    )


def assumption31_holds(
    v: jnp.ndarray, v_hat: jnp.ndarray, theta: float, slack: float = 1.0,
    norm_tol: float = 1e-4,
) -> bool:
    """Check ||v-v_hat|| <= slack*theta*||v|| and ||v_hat|| <= (1+tol)*||v||.

    ``slack=1`` is the paper's literal assumption; quantization adds a small
    multiplicative wiggle so callers may pass ``slack`` slightly above 1 for
    the provable sqrt(theta) regime (see module docstring).
    """
    err_ratio, norm_ratio = assumption31_stats(v, v_hat)
    return assumption31_holds_stats(err_ratio, norm_ratio, theta, slack, norm_tol)


@dataclasses.dataclass
class Thm34Terms:
    """min_t E||grad f(x_t)||^2 <= opt_term + noise_term (Thm 3.4)."""

    opt_term: float  # 4 (f(x0) - f*) / (eta K)
    noise_term: float  # (L eta + theta^2) 2 sigma^2 / b
    bound: float


def thm34_bound(
    f0_minus_fstar: float,
    lipschitz: float,
    eta: float,
    theta: float,
    sigma_sq: float,
    batch: int,
    steps: int,
) -> Thm34Terms:
    """Evaluate the Theorem 3.4 bound for fixed eta/theta/b over K steps."""
    opt = 4.0 * f0_minus_fstar / (eta * max(steps, 1))
    noise = (lipschitz * eta + theta**2) * 2.0 * sigma_sq / max(batch, 1)
    return Thm34Terms(opt, noise, opt + noise)


# ---------------------------------------------------------------------------
# Measured-curve evaluation (convergence lab)
#
# Thm 3.4 bounds min_t E||grad f(x_t)||^2 in terms of constants (L, sigma^2,
# f0 - f*) a real run never knows a priori.  The lab therefore evaluates the
# bound with PLUG-IN estimates derived from the same measured curve, which
# keeps the check executable and honest about where each constant comes from:
#
# * L-hat — the smallest smoothness constant consistent with the descent
#   lemma  f(x_{t+1}) <= f(x_t) - eta(1 - L*eta/2)||g_t||^2  along the
#   recorded trajectory (rearranged per step, maximized over steps);
# * sigma^2-hat — near stationarity the minibatch gradient satisfies
#   E||g_b||^2 ~= sigma^2 / b, so sigma^2-hat = b * mean(tail of ||g||^2).
#
# The envelope check then asserts min-so-far measured grad-energy stays under
# the bound at every recorded prefix length K.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CurveConstants:
    """Plug-in constants estimated from one measured training curve."""

    f0_minus_fstar: float
    lipschitz: float
    sigma_sq: float


def estimate_curve_constants(
    loss_curve: Sequence[float],
    grad_sq_curve: Sequence[float],
    eta: float,
    batch: int,
    fstar: float = 0.0,
    tail_fraction: float = 0.25,
) -> CurveConstants:
    """Estimate (f0 - f*, L, sigma^2) from per-step loss and ||grad||^2."""
    if len(loss_curve) < 2 or len(loss_curve) != len(grad_sq_curve):
        raise ValueError("need >= 2 aligned (loss, grad_sq) samples")
    f0 = float(loss_curve[0])
    # descent lemma per step: L >= 2*(delta_f + eta*gsq) / (eta^2 * gsq)
    l_hat = 0.0
    for f_t, f_next, gsq in zip(loss_curve, loss_curve[1:], grad_sq_curve):
        if gsq <= 0.0:
            continue
        l_step = 2.0 * ((f_next - f_t) + eta * gsq) / (eta * eta * gsq)
        l_hat = max(l_hat, l_step)
    l_hat = max(l_hat, 1e-6)
    tail = max(1, int(len(grad_sq_curve) * tail_fraction))
    tail_mean = sum(grad_sq_curve[-tail:]) / tail
    return CurveConstants(
        f0_minus_fstar=max(f0 - fstar, 0.0),
        lipschitz=l_hat,
        sigma_sq=max(batch, 1) * tail_mean,
    )


@dataclasses.dataclass
class Thm34Envelope:
    """Per-prefix Thm 3.4 bound vs the measured min-so-far grad energy."""

    bounds: Tuple[float, ...]  # bound evaluated at K = 1..len(curve)
    min_so_far: Tuple[float, ...]  # running min of measured ||grad||^2
    holds: bool  # min_so_far[K] <= slack * bounds[K] at every K


def thm34_envelope(
    grad_sq_curve: Sequence[float],
    constants: CurveConstants,
    eta: float,
    theta: float,
    batch: int,
    slack: float = 1.0,
) -> Thm34Envelope:
    """Check a measured grad-energy curve against the Thm 3.4 envelope.

    ``theta`` should be the LARGEST theta the run used (the bound is monotone
    in theta, so the max is the valid envelope for a scheduled run).
    """
    bounds, mins = [], []
    running = float("inf")
    for k, gsq in enumerate(grad_sq_curve, start=1):
        running = min(running, float(gsq))
        terms = thm34_bound(
            constants.f0_minus_fstar, constants.lipschitz, eta, theta,
            constants.sigma_sq, batch, k,
        )
        bounds.append(terms.bound)
        mins.append(running)
    holds = all(m <= slack * b + 1e-9 for m, b in zip(mins, bounds))
    return Thm34Envelope(tuple(bounds), tuple(mins), holds)


def curves_close(
    a: Sequence[float], b: Sequence[float], atol: float = 1e-5
) -> Tuple[bool, float]:
    """Pointwise curve comparison -> (within_atol, max_abs_divergence).

    Used for the transport-equivalence claim: two runs that differ only in
    transport must trace identical loss curves (bitwise on the CPU backend —
    see transport.py's ordered worker fold — so atol=1e-5 has huge margin).
    """
    if len(a) != len(b):
        raise ValueError(f"curve lengths differ: {len(a)} vs {len(b)}")
    worst = max((abs(float(x) - float(y)) for x, y in zip(a, b)), default=0.0)
    return worst <= atol, worst
