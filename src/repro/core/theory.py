"""Convergence-theory helpers (paper §III-C, Lemma 3.3 / Thm 3.4 / Thm 3.5).

These make the paper's guarantees *executable*: tests and benchmarks call
:func:`assumption31_holds` on every sparsifier and evaluate the Thm 3.4 bound
against measured training curves.

Facts used by the tests (DESIGN.md §6): dropping the theta-fraction of
*smallest-magnitude* coefficients of any orthonormal transform discards at
most a theta fraction of the energy, so ||v - v_hat|| <= sqrt(theta) * ||v||
always holds; on near-normal gradients the empirical constant is far below
theta itself, which is what Assumption 3.1 asks for.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

__all__ = ["assumption31_stats", "assumption31_holds", "thm34_bound", "Thm34Terms"]


def assumption31_stats(v: jnp.ndarray, v_hat: jnp.ndarray):
    """Returns (||v - v_hat|| / ||v||, ||v_hat|| / ||v||)."""
    nv = jnp.maximum(jnp.linalg.norm(v), 1e-30)
    return jnp.linalg.norm(v - v_hat) / nv, jnp.linalg.norm(v_hat) / nv


def assumption31_holds(
    v: jnp.ndarray, v_hat: jnp.ndarray, theta: float, slack: float = 1.0
) -> bool:
    """Check ||v-v_hat|| <= slack*theta*||v|| and ||v_hat|| <= (1+tol)*||v||.

    ``slack=1`` is the paper's literal assumption; quantization adds a small
    multiplicative wiggle so callers may pass ``slack`` slightly above 1 for
    the provable sqrt(theta) regime (see module docstring).
    """
    err_ratio, norm_ratio = assumption31_stats(v, v_hat)
    return bool((err_ratio <= slack * theta + 1e-6) & (norm_ratio <= 1.0 + 1e-4))


@dataclasses.dataclass
class Thm34Terms:
    """min_t E||grad f(x_t)||^2 <= opt_term + noise_term (Thm 3.4)."""

    opt_term: float  # 4 (f(x0) - f*) / (eta K)
    noise_term: float  # (L eta + theta^2) 2 sigma^2 / b
    bound: float


def thm34_bound(
    f0_minus_fstar: float,
    lipschitz: float,
    eta: float,
    theta: float,
    sigma_sq: float,
    batch: int,
    steps: int,
) -> Thm34Terms:
    """Evaluate the Theorem 3.4 bound for fixed eta/theta/b over K steps."""
    opt = 4.0 * f0_minus_fstar / (eta * max(steps, 1))
    noise = (lipschitz * eta + theta**2) * 2.0 * sigma_sq / max(batch, 1)
    return Thm34Terms(opt, noise, opt + noise)
