"""Sparsification in the frequency and time domains (paper §III-B.1).

``theta`` is the paper's drop-out ratio: keep the top ``(1 - theta)`` fraction
of coefficients by magnitude, zero the rest.  On TPU the selection is per-chunk
``jax.lax.top_k`` with a *static* k — XLA needs static shapes, so a theta
schedule (Thm 3.5) implies one recompile per distinct theta value (DESIGN.md
§2).  The Pallas ``topk_threshold`` kernel provides the fused TPU hot path;
this module is the reference/composable implementation.

Frequency-domain selection ranks rfft bins by Hermitian-weighted magnitude so
the dropped energy equals the time-domain energy loss exactly (Parseval).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import fft as cfft

__all__ = [
    "keep_count",
    "topk_select",
    "topk_mask",
    "frequency_sparsify",
    "time_sparsify",
    "threshold_sparsify",
]


def keep_count(n: int, theta: float) -> int:
    """Static number of kept coefficients for drop ratio theta in [0, 1)."""
    if not 0.0 <= theta < 1.0:
        raise ValueError(f"theta must be in [0,1), got {theta}")
    return max(1, int(round((1.0 - theta) * n)))


def topk_select(mag: jnp.ndarray, k: int) -> jnp.ndarray:
    """Indices (…, k) of the k largest magnitudes along the last axis."""
    _, idx = jax.lax.top_k(mag, k)
    return idx


def topk_mask(mag: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask keeping the per-row top-k by magnitude.

    Tau-comparison form (selection engine, DESIGN.md §16): the k-th order
    statistic from ``top_k`` IS the threshold, and ``mag >= tau`` is one
    vectorized compare — no O(n·k) index scatter.  Under bitwise ties at tau
    the mask may keep MORE than k entries (every tied coefficient), which is
    the honest semantics for a mask: thresholding cannot distinguish tied
    values, and downstream static-budget packing truncates, as always.
    """
    vals = jax.lax.top_k(mag, k)[0]
    tau = vals[..., -1:]
    return mag >= tau


def frequency_sparsify(
    x_flat: jnp.ndarray, theta: float, chunk: int = cfft.DEFAULT_CHUNK
) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """FFT -> drop theta fraction of bins -> return (freqs, kept_idx, orig_len).

    ``freqs`` is the sparsified (zero-filled) complex spectrum; ``kept_idx`` is
    the (n_chunks, k) static-shape index payload that pack/unpack uses.
    """
    freqs, n = cfft.chunked_rfft(x_flat, chunk)
    f_bins = freqs.shape[-1]
    k = keep_count(f_bins, theta)
    w = cfft.hermitian_weights(chunk)
    mag = jnp.abs(freqs) * w  # weighted magnitude = energy-faithful ranking
    idx = topk_select(mag, k)
    kept = jnp.take_along_axis(freqs, idx, axis=-1)
    sparse = jnp.zeros_like(freqs)
    sparse = jax.vmap(lambda row, i, v: row.at[i].set(v))(sparse, idx, kept)
    return sparse, idx, n


def time_sparsify(x_flat: jnp.ndarray, theta: float, chunk: int = cfft.DEFAULT_CHUNK):
    """Time-domain per-chunk top-k (DGC / Aji-Heafield baseline path)."""
    x2d, n = cfft.pad_to_chunks(x_flat, chunk)
    k = keep_count(chunk, theta)
    idx = topk_select(jnp.abs(x2d), k)
    kept = jnp.take_along_axis(x2d, idx, axis=-1)
    sparse = jnp.zeros_like(x2d)
    sparse = jax.vmap(lambda row, i, v: row.at[i].set(v))(sparse, idx, kept)
    return sparse, idx, n


def threshold_sparsify(x: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """Magnitude thresholding (irregular sparsity; kept for the bitmap path)."""
    return jnp.where(jnp.abs(x) >= tau, x, jnp.zeros_like(x))
