"""Range-based N-bit floating point quantizer (paper §III-B.2, Algorithm 1).

The paper's offset-based representation: code "0...0" is 0, code "0...01" is
the smallest positive representable number ``eps`` (pbase), and successive
codes walk upward with an IEEE-like exponent/mantissa pattern — ``m`` mantissa
bits mean the spacing doubles every ``2**m`` codes.  Positive codes occupy
``1..P``; negative codes occupy ``P+1 .. 2**N - 1`` with the same pattern
mirrored.  Given the observed gradient range ``[min, max]`` the quantizer
allocates precision *where the gradients live* — exponentially denser around
zero (paper Fig. 8) — instead of uniformly (QSGD) or ternary (TernGrad).

Value of positive code ``c`` (1-indexed):

    idx = c - 1;  q = idx >> m;  r = idx & (2**m - 1)
    value(c) = eps * 2**q * (1 + r / 2**m)

so segment ``q`` covers ``[eps*2**q, eps*2**(q+1))`` with ``2**m`` evenly
spaced values — relative error ≤ 2**-(m+1) once above ``eps``.

Two ways to fit ``eps``:

* :func:`tune_eps_heuristic` — the paper's Algorithm 1: start from a guess,
  decode the most-negative code, and multiply/divide ``eps`` by 2 until the
  representable range straddles ``min``.  Converges to within a factor of 2.
* :func:`solve_eps` — closed form (beyond paper; see DESIGN.md §10).  Requiring
  value(P) = max and value_neg(2**N - 1 - P) = |min| gives

      P   = (2**N - 1 + 2**m * log2(max / |min|)) / 2
      eps = max / 2**(P / 2**m)

  which balances the positive/negative code budget exactly instead of to
  within ×2.  Both are exposed; the hot path uses the closed form.

Everything here is pure ``jnp`` and jit-compatible with dynamic ``min``/``max``
(the fit is branch-free math / a bounded ``while_loop``).  The Pallas kernel in
``repro.kernels.range_quant`` implements the same encode/decode for the TPU hot
path and is checked against this module.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "RangeQuantConfig",
    "FittedQuantizer",
    "solve_eps",
    "tune_eps_heuristic",
    "fit_quantizer",
    "encode",
    "decode",
    "representable_values",
]


@dataclasses.dataclass(frozen=True)
class RangeQuantConfig:
    """Static configuration of the N-bit range-based float."""

    n_bits: int = 8
    m_bits: int = 3  # mantissa bits; paper: "pick m based on experience"

    def __post_init__(self):
        if not (1 < self.m_bits < self.n_bits):
            raise ValueError(f"need 1 < m_bits < n_bits, got {self}")
        if self.n_bits > 16:
            raise ValueError("n_bits > 16 not supported (codes stored u16)")

    @property
    def n_codes(self) -> int:
        return 1 << self.n_bits

    @property
    def mantissa_scale(self) -> int:
        return 1 << self.m_bits

    @property
    def code_dtype(self):
        return jnp.uint8 if self.n_bits <= 8 else jnp.uint16


# Dynamic (traced) parameters of a fitted quantizer: (eps, P) plus the clip
# range actually representable.  Kept as a small pytree-friendly tuple.
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FittedQuantizer:
    """A fitted range quantizer: static config + dynamic (eps, P, vmin, vmax)."""

    config: RangeQuantConfig
    eps: jnp.ndarray  # scalar f32
    p_codes: jnp.ndarray  # scalar i32: number of positive codes
    vmax: jnp.ndarray  # largest positive representable
    vmin: jnp.ndarray  # most negative representable (≤ 0)

    def tree_flatten(self):
        return (self.eps, self.p_codes, self.vmax, self.vmin), self.config

    @classmethod
    def tree_unflatten(cls, config, leaves):
        return cls(config, *leaves)

    # -- convenience ------------------------------------------------------
    def encode(self, x: jnp.ndarray) -> jnp.ndarray:
        return encode(x, self)

    def decode(self, codes: jnp.ndarray) -> jnp.ndarray:
        return decode(codes, self)


def _value_of_index(idx, eps, m_bits):
    """value for 0-based positive index: eps * 2**q * (1 + r/2**m)."""
    m_scale = 1 << m_bits
    q = idx // m_scale
    r = idx % m_scale
    return eps * jnp.exp2(q.astype(jnp.float32)) * (1.0 + r.astype(jnp.float32) / m_scale)


def solve_eps(vmin, vmax, config: RangeQuantConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Closed-form (eps, P) balancing positive/negative code budgets.

    ``vmax`` must be > 0 and ``vmin`` < 0 (symmetric or asymmetric).  Degenerate
    one-sided ranges are handled by the caller (:func:`fit_quantizer`).
    """
    m_scale = config.mantissa_scale
    n_codes = config.n_codes
    vmax = jnp.maximum(vmax, 1e-30)
    vmag = jnp.maximum(-vmin, 1e-30)
    # P = (2^N - 1 + 2^m log2(max/|min|)) / 2, clipped to leave ≥1 code per side
    p_f = (n_codes - 1 + m_scale * (jnp.log2(vmax) - jnp.log2(vmag))) / 2.0
    p = jnp.clip(jnp.round(p_f), 1, n_codes - 2).astype(jnp.int32)
    # In the log-linear approximation value(idx) ≈ eps * 2**(idx / 2**m); pin
    # the TOP code (idx = P-1) to vmax so the clip gap at the range boundary is
    # at most one mantissa step (not a whole half-segment).  The exponent is
    # clamped so eps never underflows f32 (12-bit quantizers of wide ranges
    # would otherwise drive vmax / 2**(P/2**m) to zero).
    exponent = jnp.minimum((p.astype(jnp.float32) - 1.0) / m_scale, 96.0)
    eps = jnp.maximum(vmax / jnp.exp2(exponent), 1e-30)
    return eps, p


def tune_eps_heuristic(
    vmin,
    vmax,
    config: RangeQuantConfig,
    eps_init: float = 0.002,
    max_iters: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Paper Algorithm 1: ×2/÷2 search on eps until the decoded "1...1" code
    (most negative representable) straddles ``vmin``.

    Faithful to the paper's loop: if ``actual_min < min`` there are too many
    negative codes → decrease ``eps`` (÷2) to spend more codes on the positive
    side; else increase (×2).  Stops when the sign of the error flips or after
    ``max_iters``.  Returns (eps, P).
    """
    m_scale = config.mantissa_scale
    n_codes = config.n_codes
    vmax = jnp.maximum(vmax, 1e-30)
    vmag = jnp.maximum(-vmin, 1e-30)

    def p_of_eps(eps):
        # codes needed to reach vmax from eps (ceil), ≥ 1
        steps = jnp.ceil(m_scale * (jnp.log2(vmax) - jnp.log2(eps)))
        return jnp.clip(steps, 1, n_codes - 2).astype(jnp.int32)

    def actual_min_of_eps(eps):
        p = p_of_eps(eps)
        n_neg = n_codes - 1 - p
        return -_value_of_index(jnp.maximum(n_neg - 1, 0), eps, config.m_bits)

    def body(state):
        eps, it, prev_sign, done = state
        actual_min = actual_min_of_eps(eps)
        # actual_min < vmin: negative range overshoots → too many negative
        # codes → decrease eps (paper: divide by 2); else multiply by 2.
        sign = jnp.where(actual_min < vmin, -1, 1)
        flipped = (prev_sign != 0) & (sign != prev_sign)
        new_eps = jnp.where(sign < 0, eps * 0.5, eps * 2.0)
        new_eps = jnp.clip(new_eps, 1e-30, vmax)
        done = done | flipped
        eps = jnp.where(done, eps, new_eps)
        return eps, it + 1, sign, done

    def cond(state):
        _, it, _, done = state
        return (~done) & (it < max_iters)

    eps0 = jnp.asarray(eps_init, jnp.float32)
    eps, _, _, _ = jax.lax.while_loop(
        cond, body, (eps0, jnp.asarray(0), jnp.asarray(0), jnp.asarray(False))
    )
    return eps, p_of_eps(eps)


def fit_quantizer(
    vmin,
    vmax,
    config: RangeQuantConfig = RangeQuantConfig(),
    method: str = "solve",
) -> FittedQuantizer:
    """Fit the quantizer to an observed range.

    Handles degenerate ranges: if the data is one-sided we still reserve one
    code on the empty side (the math needs vmin<0<vmax); callers see correct
    clipping behaviour either way.
    """
    vmin = jnp.asarray(vmin, jnp.float32)
    vmax = jnp.asarray(vmax, jnp.float32)
    # Guard: ensure a strictly two-sided, non-empty range.
    span = jnp.maximum(vmax - vmin, 1e-30)
    vmax_eff = jnp.maximum(vmax, span * 1e-6)
    vmin_eff = jnp.minimum(vmin, -span * 1e-6)
    if method == "solve":
        eps, p = solve_eps(vmin_eff, vmax_eff, config)
    elif method == "heuristic":
        eps, p = tune_eps_heuristic(vmin_eff, vmax_eff, config)
    else:
        raise ValueError(f"unknown fit method {method!r}")
    n_neg = config.n_codes - 1 - p
    vmax_rep = _value_of_index(p - 1, eps, config.m_bits)
    vmin_rep = -_value_of_index(jnp.maximum(n_neg - 1, 0), eps, config.m_bits)
    return FittedQuantizer(config, eps, p, vmax_rep, vmin_rep)


def _encode_magnitude(a, eps, m_bits, max_idx):
    """0-based index for magnitude ``a`` (≥0); round-to-nearest; clipped."""
    m_scale = 1 << m_bits
    safe_a = jnp.maximum(a, eps)
    # exponent segment: floor(log2(a/eps)); nudge avoids 2.0 -> q=0.9999…
    q = jnp.floor(jnp.log2(safe_a) - jnp.log2(eps) + 1e-6)
    seg_base = eps * jnp.exp2(q)
    r = jnp.round((safe_a / seg_base - 1.0) * m_scale)
    # r may round up to 2**m: carry into the next exponent segment.
    carry = r >= m_scale
    q = jnp.where(carry, q + 1, q)
    r = jnp.where(carry, 0.0, r)
    idx = (q * m_scale + r).astype(jnp.int32)
    # below-eps values: nearest of {0, eps} in linear space
    idx = jnp.where(a < eps, jnp.where(a * 2.0 >= eps, 0, -1), idx)
    return jnp.clip(idx, -1, max_idx - 1)  # -1 encodes "zero"


def encode(x: jnp.ndarray, quant: FittedQuantizer) -> jnp.ndarray:
    """float32 -> N-bit codes (stored in the smallest unsigned dtype)."""
    cfg = quant.config
    x = x.astype(jnp.float32)
    pos = x >= 0
    a = jnp.abs(x)
    n_neg = cfg.n_codes - 1 - quant.p_codes
    idx_pos = _encode_magnitude(a, quant.eps, cfg.m_bits, quant.p_codes)
    idx_neg = _encode_magnitude(a, quant.eps, cfg.m_bits, jnp.maximum(n_neg, 1))
    code = jnp.where(
        pos,
        jnp.where(idx_pos < 0, 0, idx_pos + 1),
        jnp.where(idx_neg < 0, 0, quant.p_codes + idx_neg + 1),
    )
    return code.astype(cfg.code_dtype)


def decode(codes: jnp.ndarray, quant: FittedQuantizer) -> jnp.ndarray:
    """N-bit codes -> float32."""
    cfg = quant.config
    c = codes.astype(jnp.int32)
    is_zero = c == 0
    is_pos = (c >= 1) & (c <= quant.p_codes)
    idx = jnp.where(is_pos, c - 1, c - quant.p_codes - 1)
    idx = jnp.maximum(idx, 0)
    mag = _value_of_index(idx, quant.eps, cfg.m_bits)
    val = jnp.where(is_pos, mag, -mag)
    return jnp.where(is_zero, 0.0, val).astype(jnp.float32)


def representable_values(quant: FittedQuantizer) -> jnp.ndarray:
    """All 2**N representable values (paper Fig. 8); for tests/benchmarks."""
    cfg = quant.config
    codes = jnp.arange(cfg.n_codes, dtype=jnp.int32).astype(cfg.code_dtype)
    return decode(codes, quant)


def quantization_rtol(config: RangeQuantConfig) -> float:
    """Worst-case relative error for magnitudes in [eps, vmax]."""
    return 0.5 / config.mantissa_scale
