"""Selection engine: pluggable top-k selectors for the compression hot path.

PR 4's honest benchmark showed steady-state compress on a 64 MB gradient is
dominated by ``jax.lax.top_k`` — a full per-chunk sort.  The paper itself uses
count-based bucketSelect rather than a global sort (§III-B.1), and Deep
Gradient Compression (arXiv 1712.01887) estimates the threshold ``tau`` from a
small magnitude subsample in O(n).  This module is the shared math of every
selector; ``FFTCompressorConfig.selector`` picks one:

* ``sort``    — the seed behavior: ``jax.lax.top_k`` (exact, magnitude-
                descending slot order).  Bitwise-identical to every pre-engine
                payload; the library default.
* ``bisect``  — the threshold kernel's value-axis bisection
                (``kernels/topk_threshold.py``) as a pure-jnp path: 48
                compare+count sweeps over the full [0, max] range, then one
                count-and-compact pass.  No sort primitive anywhere.
* ``sampled`` — DGC-style: bracket tau from a strided magnitude subsample
                (two cheap bisections on ~1/64 of the data), clamp the bracket
                so the bisection invariant provably holds on the FULL rows
                (mis-bracketing costs accuracy, never correctness), refine
                with ``tau_refine_iters`` sweeps, then count-and-compact.
                O(n) with a small constant; the steady-state winner.
* ``auto``    — ``sampled`` when rows are wide enough for the subsample to
                carry signal (``AUTO_SAMPLED_MIN_COLS``), else ``sort``.

Exact-k repair: thresholding keeps ``count >= k`` coefficients (ties, or a
sampled tau that converged a few ulps below the k-th order statistic).
``count_compact`` packs the kept set index-ascending into ``k+1`` slots and
drops the overflow slot — the highest-index surplus entries truncate under the
static budget, identical to bucketSelect semantics and to what
``kernels/fused_compress.py`` already does.  Payload SHAPES therefore never
depend on the selector, and error-feedback residuals stay exact (the residual
is ``corrected - roundtrip``, exact for any kept set).

The bisection invariant everything rests on::

    count(mag >= lo) >= k  >  count(mag >= hi)

``upper_bracket`` widens ``hi`` one representable float above the row max
(bitcast+1, clamped to FLT_MAX) so the invariant holds exactly even for rows
whose max is denormal or near f32 overflow — the old ``max*1.0000002 + 1e-30``
expression rounds back to ``max`` for both.

The Pallas kernels (``kernels/topk_threshold.py``,
``kernels/sampled_threshold.py``) call these same functions inside their
kernel bodies, so the pure-jnp reference path and the fused path run
literally the same arithmetic — that is what makes cross-backend payloads
bitwise-comparable (DESIGN.md §16).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "SELECTOR_NAMES",
    "BISECT_ITERS",
    "DEFAULT_SAMPLE_RATE",
    "DEFAULT_REFINE_ITERS",
    "AUTO_SAMPLED_MIN_COLS",
    "FLT_MAX",
    "resolve_selector",
    "upper_bracket",
    "bisect_bracket",
    "refine_bracket",
    "bisect_tau",
    "strided_sample",
    "sample_bracket",
    "sampled_tau",
    "selector_tau",
    "count_compact",
    "select_indices",
]

SELECTOR_NAMES = ("sort", "sampled", "bisect", "auto")

# enough sweeps that lo/hi reach ADJACENT f32 values even when tau sits far
# below the row max (the interval halves from ~max each sweep; 48 covers
# tau >= max * 2^-24, the f32 mantissa range).  Canonical home of the constant
# the threshold kernels share (kernels/topk_threshold re-exports it) so the
# reference and fused bisections can never desynchronize.
BISECT_ITERS = 48

# sampled-selector defaults (DGC samples 0.1-1%; 1/64 ~ 1.6% keeps the
# sample order statistics tight enough that the clamped bracket rarely
# falls back to the full range)
DEFAULT_SAMPLE_RATE = 1.0 / 64.0
DEFAULT_REFINE_ITERS = 16

# auto policy: below this row width the subsample is too small for its order
# statistics to bracket anything — fall back to the exact sort
AUTO_SAMPLED_MIN_COLS = 512

FLT_MAX = float(jnp.finfo(jnp.float32).max)


def resolve_selector(selector: str, cols: int) -> str:
    """Concrete selector for rows of this width (static, trace-time)."""
    if selector not in SELECTOR_NAMES:
        raise ValueError(
            f"unknown selector {selector!r}; expected one of {SELECTOR_NAMES}")
    if selector == "auto":
        return "sampled" if cols >= AUTO_SAMPLED_MIN_COLS else "sort"
    return selector


# ---------------------------------------------------------------------------
# bracket arithmetic
# ---------------------------------------------------------------------------


def upper_bracket(x: jnp.ndarray) -> jnp.ndarray:
    """Smallest representable f32 strictly above ``x`` (nextafter-to-+inf),
    clamped to FLT_MAX.

    For non-negative finite f32, adding 1 to the bit pattern IS nextafter:
    ``upper_bracket(0) = 2^-149`` (the smallest denormal, so all-zero rows
    still satisfy ``count(>= hi) < k`` ... trivially 0), and a denormal max
    steps to the exactly-next denormal.  At FLT_MAX the clamp keeps ``hi``
    finite — bisection on an all-FLT_MAX row then converges to FLT_MAX and
    the count-and-compact repair truncates, instead of ``mid = inf`` stalling
    the loop forever.

    On flush-to-zero hosts (XLA CPU) the denormal step itself flushes to 0,
    collapsing the bracket to ``[0, 0]`` on all-zero/denormal rows; bisection
    then converges to ``tau = 0`` whose kept count is the whole row ``>= k``,
    so the invariant the callers rely on survives FTZ unharmed
    (``tests/test_selection.py`` pins both behaviors).
    """
    x = x.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    nxt = jax.lax.bitcast_convert_type(bits + 1, jnp.float32)
    return jnp.minimum(nxt, jnp.float32(FLT_MAX))


def bisect_bracket(
    mag: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, k: int, iters: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``iters`` value-axis bisection sweeps on rows ``mag`` (rows, cols).

    Preserves the invariant ``count(>= lo) >= k > count(>= hi)`` the caller
    establishes; returns the narrowed ``(lo, hi)``.  This one loop body is
    shared by the pure-jnp selectors AND the Pallas kernel bodies
    (``topk_threshold``, ``sampled_threshold``) so both paths run identical
    arithmetic.
    """

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        count = jnp.sum(mag >= mid[:, None], axis=-1)
        feasible = count >= k  # mid keeps at least the budget
        new_lo = jnp.where(feasible, mid, lo)
        new_hi = jnp.where(feasible, hi, mid)
        return new_lo, new_hi

    return jax.lax.fori_loop(0, iters, body, (lo, hi))


def refine_bracket(
    mag: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, k: int, iters: int
) -> jnp.ndarray:
    """Clamp an ESTIMATED bracket so the invariant provably holds, then
    bisect; returns tau (rows,) with ``count(mag >= tau) >= k`` guaranteed.

    The two clamp passes are what makes a sampled bracket safe: if the
    subsample under- or over-shot, the offending edge falls back to the full
    range (0 below, one-past-max above) — a bad sample costs refinement
    accuracy, never the ``>= k`` guarantee the static payload budget needs.
    """
    lo = jnp.where(jnp.sum(mag >= lo[:, None], axis=-1) >= k,
                   lo, jnp.zeros_like(lo))
    hi_fallback = upper_bracket(jnp.max(mag, axis=-1))
    hi = jnp.where(jnp.sum(mag >= hi[:, None], axis=-1) < k, hi, hi_fallback)
    lo, _ = bisect_bracket(mag, lo, hi, k, iters)
    return lo


def bisect_tau(mag: jnp.ndarray, k: int, iters: int = BISECT_ITERS) -> jnp.ndarray:
    """Full-range bisection threshold: tau (rows,) with ``count(>= tau) >= k``.

    The ``bisect`` selector, and the exact math of the ``topk_threshold``
    kernel body (which calls this)."""
    hi = upper_bracket(jnp.max(mag, axis=-1))
    lo = jnp.zeros_like(hi)
    lo, _ = bisect_bracket(mag, lo, hi, k, iters)
    return lo


# ---------------------------------------------------------------------------
# sampled threshold (DGC-style)
# ---------------------------------------------------------------------------


def _sample_layout(cols: int, sample_rate: float, seed: int) -> Tuple[int, int, int]:
    """Static (n_sample, stride, offset) of the strided subsample."""
    if not 0.0 < sample_rate <= 1.0:
        raise ValueError(f"sample_rate must be in (0, 1], got {sample_rate}")
    s = max(1, min(cols, int(round(cols * sample_rate))))
    stride = max(1, cols // s)
    offset = seed % stride
    return s, stride, offset


def strided_sample(
    mag: jnp.ndarray, sample_rate: float = DEFAULT_SAMPLE_RATE, seed: int = 0
) -> jnp.ndarray:
    """(rows, s) strided subsample of the magnitude rows.

    A strided (not contiguous) pick because rfft magnitudes are strongly
    ordered in frequency — a contiguous window would sample one band.  The
    seed rotates the phase so repeated calls need not resample identical
    bins; everything is static so the jaxpr carries a plain strided slice
    (no gather, no sort).
    """
    cols = mag.shape[-1]
    s, stride, offset = _sample_layout(cols, sample_rate, seed)
    return jax.lax.slice_in_dim(
        mag, offset, offset + (s - 1) * stride + 1, stride, axis=-1)


def sample_bracket(
    sample: jnp.ndarray, k: int, cols: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bracket the full-row tau from sample order statistics: (lo, hi) rows.

    The k-th largest of the row maps to rank ``k_s = k*s/cols`` in the
    sample; a ``4*sqrt(k_s)+2`` rank margin on each side covers the sampling
    noise of a binomial count (4 sigma) plus integer slop.  Each rank's value
    is found by bisection ON THE SAMPLE — never ``jnp.sort`` — so the sampled
    selector's jaxpr is sort-free end to end (the property
    ``benchmarks/perf_smoke.py`` asserts deterministically).
    """
    s = sample.shape[-1]
    k_s = k * s / cols
    margin = 4.0 * (max(k_s, 1.0) ** 0.5) + 2.0
    hi_rank = max(1, int(k_s - margin))
    lo_rank = min(s, int(k_s + margin) + 1)
    hi0 = upper_bracket(jnp.max(sample, axis=-1))
    zero = jnp.zeros_like(hi0)
    # value at sample-rank hi_rank (a HIGH magnitude: few sample entries
    # above it) bounds tau from above; rank lo_rank bounds it from below
    hi, _ = bisect_bracket(sample, zero, hi0, hi_rank, BISECT_ITERS)
    lo, _ = bisect_bracket(sample, zero, hi0, lo_rank, BISECT_ITERS)
    return lo, hi


def sampled_tau(
    mag: jnp.ndarray,
    k: int,
    *,
    sample_rate: float = DEFAULT_SAMPLE_RATE,
    refine_iters: int = DEFAULT_REFINE_ITERS,
    seed: int = 0,
) -> jnp.ndarray:
    """DGC-style sampled threshold: tau (rows,), ``count(>= tau) >= k``.

    sample -> rank-bracket -> clamp -> ``refine_iters`` full-row sweeps.
    Total full-row passes: 2 clamp + refine_iters (vs BISECT_ITERS=48 for
    the full bisection; the sample bisections touch ~sample_rate of the
    data)."""
    sample = strided_sample(mag, sample_rate, seed)
    lo, hi = sample_bracket(sample, k, mag.shape[-1])
    return refine_bracket(mag, lo, hi, k, refine_iters)


# ---------------------------------------------------------------------------
# dispatch + exact-k compaction
# ---------------------------------------------------------------------------


def _as_rows(mag: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    lead = mag.shape[:-1]
    return mag.reshape(-1, mag.shape[-1]), lead


def selector_tau(
    mag: jnp.ndarray,
    k: int,
    selector: str,
    *,
    sample_rate: float = DEFAULT_SAMPLE_RATE,
    refine_iters: int = DEFAULT_REFINE_ITERS,
    seed: int = 0,
) -> jnp.ndarray:
    """Threshold (…, 1) for a RESOLVED threshold selector (bisect|sampled).

    Shape-polymorphic over leading axes (chunk, bucket — any stack);
    ``count(mag >= tau) >= k`` holds per row by the bisection invariant.
    """
    rows, lead = _as_rows(mag.astype(jnp.float32))
    if selector == "bisect":
        tau = bisect_tau(rows, k)
    elif selector == "sampled":
        tau = sampled_tau(rows, k, sample_rate=sample_rate,
                          refine_iters=refine_iters, seed=seed)
    else:
        raise ValueError(
            f"selector_tau takes a resolved threshold selector "
            f"(bisect|sampled), got {selector!r}")
    return tau.reshape(lead + (1,))


def count_compact(mag: jnp.ndarray, tau: jnp.ndarray, k: int) -> jnp.ndarray:
    """Exact-k index compaction of the tau mask: (…, k) int32, index-ascending.

    Slot ``j`` holds the index of the ``(j+1)``-th kept coefficient, found by
    a vectorized lower-bound binary search on the mask's running count: the
    search target ``j+1`` first appears in ``cumsum(mask)`` exactly at that
    coefficient.  Surplus kept entries (ties, or a tau a few ulps under the
    k-th order statistic) simply never get a slot — the highest-INDEX surplus
    truncates under the static budget, exactly bucketSelect's semantics and
    exactly what the fused kernel's compaction does, so reference and pallas
    payloads stay slot-for-slot comparable.  Requires ``count(>= tau) >= k``
    (every selector in this module guarantees it).

    Cost: one O(n) cumsum + ``k·ceil(log2(n))`` gathers — no sort primitive
    and no dense scatter (an ``.at[pos].set`` compaction benches ~3x slower
    on CPU hosts, and the one-hot matmul form the fused kernel uses is
    VPU-shaped, not host-shaped).
    """
    rows, lead = _as_rows(mag)
    trows = tau.reshape(-1, 1).astype(rows.dtype)
    n_rows, cols = rows.shape
    cum = jnp.cumsum((rows >= trows).astype(jnp.int32), axis=-1)
    targets = jnp.arange(1, k + 1, dtype=jnp.int32)
    lo = jnp.zeros((n_rows, k), jnp.int32)
    hi = jnp.full((n_rows, k), cols - 1, jnp.int32)

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) >> 1
        found = jnp.take_along_axis(cum, mid, axis=-1) >= targets[None, :]
        return jnp.where(found, lo, mid + 1), jnp.where(found, mid, hi)

    steps = max(1, (cols - 1).bit_length())
    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo.reshape(lead + (k,))


def select_indices(
    mag: jnp.ndarray,
    k: int,
    selector: str,
    *,
    sample_rate: float = DEFAULT_SAMPLE_RATE,
    refine_iters: int = DEFAULT_REFINE_ITERS,
    seed: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-call selection: resolved-selector indices (…, k) plus tau (…, 1).

    ``sort`` returns magnitude-descending ``top_k`` indices and ``tau=None``;
    the threshold selectors return index-ascending compacted indices and the
    tau their kept set (pre-truncation) is defined by — callers that fit a
    quantizer range use ``mag >= tau`` so the fit matches the fused kernel's
    mask (DESIGN.md §16).
    """
    resolved = resolve_selector(selector, mag.shape[-1])
    if resolved == "sort":
        _, idx = jax.lax.top_k(mag, k)
        return idx, None
    tau = selector_tau(mag, k, resolved, sample_rate=sample_rate,
                       refine_iters=refine_iters, seed=seed)
    return count_compact(mag, tau, k), tau
