"""Error-feedback residual accumulation (beyond paper; default OFF).

DGC-style memory: the compression error of step t is added back to the
gradient of step t+1, turning a biased compressor into an asymptotically
unbiased one.  The paper's own scheme does NOT use error feedback (its
convergence proof covers the memoryless compressor), so the paper-faithful
reducer keeps this disabled; it is exposed for the aggressive theta -> 0.99
regimes where it empirically recovers accuracy.

    e_0 = 0
    c_t = compress(g_t + e_{t-1})
    e_t = (g_t + e_{t-1}) - decompress(c_t)
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_residual", "compress_with_feedback"]


def init_residual(grads) -> Any:
    """Zero residual pytree matching the gradient pytree."""
    return jax.tree_util.tree_map(jnp.zeros_like, grads)


def compress_with_feedback(
    compress_fn: Callable[[jnp.ndarray], Any],
    decompress_fn: Callable[[Any], jnp.ndarray],
    grad_flat: jnp.ndarray,
    residual_flat: jnp.ndarray,
) -> Tuple[Any, jnp.ndarray]:
    """One EF step on a flat leaf; returns (payload, new_residual)."""
    corrected = grad_flat + residual_flat
    payload = compress_fn(corrected)
    new_residual = corrected - decompress_fn(payload)
    return payload, new_residual
