"""Theta (drop-out ratio) schedules (paper §IV-A1 and Theorem 3.5).

The paper trains with a *static* theta <= 0.7 without accuracy loss, shows
theta = 0.9+ degrades accuracy (Thm 3.4's noise-ball term), and fixes it by
*shrinking* theta during training ("mixed comp": theta=0.99 early, 0 late).
Thm 3.5 proves convergence when theta_t^2 = L * eta_t with a diminishing step
size.  The paper also suggests polynomial / sigmoid decays, mirroring LR
schedules.

Schedules are plain step -> float callables evaluated OUTSIDE jit: a theta
change alters the static kept-k, so the training loop re-instantiates the
compiled step per distinct theta (a handful per run; see DESIGN.md §2).
"""

from __future__ import annotations

import math
from typing import Callable, Optional, Sequence, Tuple

__all__ = [
    "constant",
    "step_decay",
    "polynomial_decay",
    "sigmoid_decay",
    "thm35_schedule",
    "quantize_theta",
    "make_schedule",
    "schedule_curve",
]

ThetaSchedule = Callable[[int], float]


def constant(theta: float) -> ThetaSchedule:
    return lambda step: theta


def step_decay(boundaries_and_values: Sequence[Tuple[int, float]]) -> ThetaSchedule:
    """Piecewise-constant: [(step_boundary, theta_after), ...], sorted.

    The paper's "mixed comp" is ``step_decay([(0, 0.99), (T, 0.0)])``.
    """
    table = sorted(boundaries_and_values)

    def schedule(step: int) -> float:
        theta = table[0][1]
        for boundary, value in table:
            if step >= boundary:
                theta = value
        return theta

    return schedule


def polynomial_decay(
    theta0: float, total_steps: int, power: float = 1.0, theta_end: float = 0.0
) -> ThetaSchedule:
    def schedule(step: int) -> float:
        frac = min(max(step / max(total_steps, 1), 0.0), 1.0)
        return theta_end + (theta0 - theta_end) * (1.0 - frac) ** power

    return schedule


def sigmoid_decay(theta0: float, midpoint: int, steepness: float = 0.01) -> ThetaSchedule:
    def schedule(step: int) -> float:
        return theta0 / (1.0 + math.exp(steepness * (step - midpoint)))

    return schedule


def thm35_schedule(lipschitz: float, eta_schedule: Callable[[int], float]) -> ThetaSchedule:
    """Theorem 3.5: theta_t = sqrt(L * eta_t), clipped to the lemma's
    admissible region theta^2 <= 1/4 (i.e. theta <= 0.5)."""

    def schedule(step: int) -> float:
        return min(0.5, math.sqrt(max(lipschitz * eta_schedule(step), 0.0)))

    return schedule


def quantize_theta(theta: float, granularity: float = 0.05) -> float:
    """Snap theta to a grid so a smooth schedule yields a bounded number of
    recompilations (static kept-k changes only at grid boundaries)."""
    return min(0.95, max(0.0, round(theta / granularity) * granularity))


# ---------------------------------------------------------------------------
# Declarative construction + curve evaluation (convergence lab)
# ---------------------------------------------------------------------------


def make_schedule(kind: Optional[str], **params) -> Optional[ThetaSchedule]:
    """Build a schedule from a JSON-serializable (kind, params) description.

    The experiment lab declares schedules as data (``ExperimentSpec`` must
    round-trip through JSON for the report artifact), so the callable is
    constructed here from names::

        make_schedule("constant", theta=0.7)
        make_schedule("step_decay", points=[[0, 0.99], [30, 0.0]])
        make_schedule("polynomial_decay", theta0=0.9, total_steps=50)
        make_schedule("sigmoid_decay", theta0=0.9, midpoint=25)
        make_schedule("thm35", lipschitz=1.0, eta=0.3)   # fixed-eta variant
        make_schedule(None)                              # dense: no schedule
    """
    if kind is None:
        return None
    if kind == "constant":
        return constant(params["theta"])
    if kind == "step_decay":
        return step_decay([(int(s), float(v)) for s, v in params["points"]])
    if kind == "polynomial_decay":
        return polynomial_decay(
            params["theta0"], params["total_steps"],
            params.get("power", 1.0), params.get("theta_end", 0.0))
    if kind == "sigmoid_decay":
        return sigmoid_decay(
            params["theta0"], params["midpoint"], params.get("steepness", 0.01))
    if kind == "thm35":
        eta = params["eta"]
        return thm35_schedule(params["lipschitz"], lambda s: eta)
    raise ValueError(f"unknown schedule kind {kind!r}")


def schedule_curve(
    schedule: Optional[ThetaSchedule], steps: int, granularity: float = 0.05
) -> Tuple[float, ...]:
    """The quantized theta the training loop will realize at each step.

    Mirrors the loop's contract (it snaps through :func:`quantize_theta`
    before rebuilding the step), so a planned run can be priced before it
    executes — and the lab runner asserts its recorded per-step thetas match
    this curve exactly, so the two implementations cannot silently drift.
    ``schedule=None`` (dense) yields all zeros.
    """
    if schedule is None:
        return tuple(0.0 for _ in range(steps))
    return tuple(quantize_theta(schedule(s), granularity) for s in range(steps))
