"""Chunked real FFT used by the frequency-domain sparsifier (paper §III-B.1).

The paper runs cuFFT over the flattened per-layer gradient.  On TPU we chunk
the signal into fixed-size pieces (default 4096) and transform each chunk
independently:

* static shapes (XLA requirement) regardless of layer size;
* each chunk's working set fits VMEM, and the Pallas ``fft4step`` kernel
  implements the transform as two 64x64 DFT matmuls on the MXU;
* chunks are embarrassingly parallel => trivially shardable.

Because the input is real we use rFFT: a chunk of C reals produces F = C/2+1
complex coefficients.  Parseval with Hermitian symmetry means bin energies are

    E = (|X_0|^2 + 2*sum_{1..F-2} |X_k|^2 + |X_{F-1}|^2) / C

so DC and Nyquist carry weight 1 and interior bins weight 2
(:func:`hermitian_weights`).  Sparsification ranks bins by *weighted* magnitude
so the dropped-energy accounting behind Assumption 3.1 is exact (DESIGN.md §6).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = [
    "DEFAULT_CHUNK",
    "pad_to_chunks",
    "chunked_rfft",
    "chunked_irfft",
    "hermitian_weights",
    "chunk_energy",
]

DEFAULT_CHUNK = 4096


def pad_to_chunks(x_flat: jnp.ndarray, chunk: int = DEFAULT_CHUNK) -> Tuple[jnp.ndarray, int]:
    """Zero-pad a flat vector to a multiple of ``chunk`` and reshape.

    Returns (chunks_2d, original_length).  Padding with zeros is exact for the
    transform (adds no energy) and the tail is sliced off on inverse.
    """
    n = x_flat.shape[0]
    n_chunks = max(1, -(-n // chunk))
    padded = jnp.zeros((n_chunks * chunk,), x_flat.dtype).at[:n].set(x_flat)
    return padded.reshape(n_chunks, chunk), n


def chunked_rfft(x_flat: jnp.ndarray, chunk: int = DEFAULT_CHUNK) -> Tuple[jnp.ndarray, int]:
    """Flat f32 -> (n_chunks, chunk//2+1) complex64, plus the original length."""
    x2d, n = pad_to_chunks(x_flat.astype(jnp.float32), chunk)
    return jnp.fft.rfft(x2d, axis=-1).astype(jnp.complex64), n


def chunked_irfft(freqs: jnp.ndarray, orig_len: int, chunk: int = DEFAULT_CHUNK) -> jnp.ndarray:
    """(n_chunks, chunk//2+1) complex64 -> flat f32 of ``orig_len``."""
    x2d = jnp.fft.irfft(freqs, n=chunk, axis=-1)
    return x2d.reshape(-1)[:orig_len].astype(jnp.float32)


def hermitian_weights(chunk: int = DEFAULT_CHUNK) -> jnp.ndarray:
    """Energy weights per rfft bin: [1, 2, 2, ..., 2, 1] (len chunk//2+1)."""
    f = chunk // 2 + 1
    w = jnp.full((f,), 2.0, jnp.float32)
    w = w.at[0].set(1.0)
    if chunk % 2 == 0:
        w = w.at[-1].set(1.0)
    return w


def chunk_energy(freqs: jnp.ndarray, chunk: int = DEFAULT_CHUNK) -> jnp.ndarray:
    """Per-chunk signal energy from rfft coefficients (Parseval)."""
    w = hermitian_weights(chunk)
    return jnp.sum(w * jnp.abs(freqs) ** 2, axis=-1) / chunk
