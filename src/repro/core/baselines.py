"""Baseline gradient compressors the paper compares against (Table I, Fig. 12).

* :class:`TernGrad`       — Wen et al. 2017: stochastic ternary {-1,0,1}*s.
* :class:`QSGD`           — Alistarh et al. 2017: stochastic uniform levels.
* :class:`DGCTopK`        — Lin et al. 2017 / Aji-Heafield 2017: time-domain
                            top-k keeping raw fp32 values (+16-bit indices).
* :class:`AjiThreshold`   — absolute-value thresholding variant.
* :class:`OneBitSGD`      — Seide et al. 2014: sign + column mean, with the
                            original's error feedback folded in by the caller.

All follow the same duck-typed protocol as :class:`repro.core.compressor
.FFTCompressor` so reducers/benchmarks treat them interchangeably.  Stochastic
methods take an optional PRNG key (deterministic rounding if omitted).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import fft as cfft
from repro.core import packing, sparsify

__all__ = ["TernGrad", "QSGD", "DGCTopK", "AjiThreshold", "OneBitSGD"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ScaledCodes:
    """codes + scale payload; orig_len is STATIC aux so the payload survives
    all_gather + vmap in the reducers (a traced length cannot slice)."""

    codes: jnp.ndarray
    scale: jnp.ndarray
    orig_len: int

    def tree_flatten(self):
        return (self.codes, self.scale), (self.orig_len,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)


class TernGrad:
    """g -> s * ternary, s = max|g|; E[compress(g)] = g (unbiased)."""

    bits_per_value = 2

    def compress(self, x_flat: jnp.ndarray, key=None) -> ScaledCodes:
        s = jnp.maximum(jnp.max(jnp.abs(x_flat)), 1e-30)
        p = jnp.abs(x_flat) / s
        if key is None:
            b = (p >= 0.5).astype(jnp.int8)
        else:
            b = jax.random.bernoulli(key, p).astype(jnp.int8)
        codes = jnp.sign(x_flat).astype(jnp.int8) * b
        return ScaledCodes(codes, s, x_flat.shape[0])

    def decompress(self, payload: ScaledCodes) -> jnp.ndarray:
        return payload.codes.astype(jnp.float32) * payload.scale

    def wire_bits(self, n: int) -> int:
        return self.bits_per_value * n + 32

    def ratio(self, n: int) -> float:
        return 32.0 * n / self.wire_bits(n)


class QSGD:
    """Stochastic uniform quantization onto s levels of |g|/||g||_2.

    Per-bucket norms (as in the QSGD paper's practical variant) — a single
    global L2 norm over 1e8 elements would collapse every value to the lowest
    level.
    """

    def __init__(self, levels: int = 16, bucket: int = 4096):  # 4-bit default
        self.levels = levels
        self.bucket = bucket

    @property
    def bits_per_value(self) -> int:
        return max(1, (self.levels - 1).bit_length()) + 1  # + sign bit

    def compress(self, x_flat: jnp.ndarray, key=None) -> ScaledCodes:
        x2d, n = cfft.pad_to_chunks(x_flat, self.bucket)
        norm = jnp.maximum(jnp.linalg.norm(x2d, axis=-1, keepdims=True), 1e-30)
        y = jnp.abs(x2d) / norm * self.levels
        lo = jnp.floor(y)
        frac = y - lo
        if key is None:
            up = frac >= 0.5
        else:
            up = jax.random.bernoulli(key, frac)
        q = jnp.clip(lo + up.astype(jnp.float32), 0, self.levels)
        codes = (jnp.sign(x2d) * q).astype(jnp.int8)
        return ScaledCodes(codes, norm, n)

    def decompress(self, payload: ScaledCodes) -> jnp.ndarray:
        dense = payload.codes.astype(jnp.float32) / self.levels * payload.scale
        return dense.reshape(-1)[: payload.orig_len]

    def wire_bits(self, n: int) -> int:
        n_buckets = max(1, -(-n // self.bucket))
        return self.bits_per_value * n + 32 * n_buckets

    def ratio(self, n: int) -> float:
        return 32.0 * n / self.wire_bits(n)


@dataclasses.dataclass
class DGCTopK:
    """Time-domain top-k with raw fp32 values (DGC's wire format)."""

    theta: float = 0.99
    chunk: int = cfft.DEFAULT_CHUNK
    index_bits: int = 16

    def compress(self, x_flat: jnp.ndarray, key=None):
        x2d, n = cfft.pad_to_chunks(x_flat, self.chunk)
        k = sparsify.keep_count(self.chunk, self.theta)
        idx = sparsify.topk_select(jnp.abs(x2d), k)
        vals = packing.pack_by_indices(x2d, idx)
        return (vals, idx.astype(jnp.int32), n)

    def decompress(self, payload) -> jnp.ndarray:
        vals, idx, n = payload
        dense = packing.unpack_by_indices(vals, idx, self.chunk)
        return dense.reshape(-1)[:n]

    def wire_bits(self, n: int) -> int:
        n_chunks = max(1, -(-n // self.chunk))
        k = sparsify.keep_count(self.chunk, self.theta)
        return n_chunks * k * (32 + self.index_bits)

    def ratio(self, n: int) -> float:
        return 32.0 * n / self.wire_bits(n)


@dataclasses.dataclass
class AjiThreshold:
    """|g| >= tau thresholding; tau chosen per-call as the theta-quantile."""

    theta: float = 0.99
    chunk: int = cfft.DEFAULT_CHUNK

    def compress(self, x_flat: jnp.ndarray, key=None):
        # Static-shape version: theta-quantile == per-chunk top-k boundary.
        return DGCTopK(self.theta, self.chunk).compress(x_flat)

    def decompress(self, payload):
        return DGCTopK(self.theta, self.chunk).decompress(payload)

    def wire_bits(self, n: int) -> int:
        return DGCTopK(self.theta, self.chunk).wire_bits(n)

    def ratio(self, n: int) -> float:
        return 32.0 * n / self.wire_bits(n)


class OneBitSGD:
    """sign(g) * mean(|g|); caller maintains the error-feedback residual."""

    def compress(self, x_flat: jnp.ndarray, key=None) -> ScaledCodes:
        s = jnp.mean(jnp.abs(x_flat))
        codes = (x_flat >= 0).astype(jnp.int8) * 2 - 1
        return ScaledCodes(codes, s, x_flat.shape[0])

    def decompress(self, payload: ScaledCodes) -> jnp.ndarray:
        return payload.codes.astype(jnp.float32) * payload.scale

    def wire_bits(self, n: int) -> int:
        return n + 32

    def ratio(self, n: int) -> float:
        return 32.0 * n / self.wire_bits(n)
