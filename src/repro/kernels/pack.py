"""Pallas TPU kernel: sparse->dense pack / dense->sparse unpack.

The paper's GPU pack is: status bitmap -> parallel prefix sum -> scattered
write (689x speedup over 1 thread on V100).  TPUs have no efficient in-VMEM
scatter, so the adaptation (DESIGN.md §2) reformulates compaction as
**cumsum + one-hot contraction**, both native TPU operations:

    pos[i]   = cumsum(mask)[i] - 1                (position among kept)
    vals[j]  = sum_i x[i]   * mask[i] * [pos[i] == j]
    idx[j]   = sum_i i      * mask[i] * [pos[i] == j]

The contraction is tiled over the k output slots (tile 128 = lane width) so
the one-hot never materializes beyond a ``(rows, cols, 128)`` VMEM slab.
Unpack is the transpose: ``dense[i] = sum_j vals[j] * [idx[j] == i]`` tiled
over the dense axis.  Round-trips exactly against the jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.runtime import resolve_interpret

__all__ = ["pack_pallas", "unpack_pallas"]

_K_TILE = 128
_F_TILE = 512


def _pack_body(x_ref, tau_ref, vals_ref, idx_ref, *, k: int):
    x = x_ref[...]  # (r, cols)
    tau = tau_ref[...]  # (r, 1)
    r, cols = x.shape
    mask = (jnp.abs(x) >= tau).astype(jnp.float32)
    pos = jnp.cumsum(mask, axis=-1) - 1.0  # (r, cols) position among kept
    pos = jnp.where(mask > 0, pos, -1.0)  # dropped -> sentinel
    col_iota = jax.lax.broadcasted_iota(jnp.float32, (r, cols), 1)

    n_tiles = pl.cdiv(k, _K_TILE)
    for t in range(n_tiles):  # static unroll: k is static
        slot = jax.lax.broadcasted_iota(jnp.float32, (1, 1, _K_TILE), 2) + t * _K_TILE
        onehot = (pos[:, :, None] == slot).astype(jnp.float32)  # (r, cols, K_TILE)
        vals_t = jnp.sum(x[:, :, None] * onehot, axis=1)  # (r, K_TILE)
        idx_t = jnp.sum(col_iota[:, :, None] * onehot, axis=1)
        vals_ref[:, t * _K_TILE : (t + 1) * _K_TILE] = vals_t
        idx_ref[:, t * _K_TILE : (t + 1) * _K_TILE] = idx_t.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def pack_pallas(
    x2d: jnp.ndarray,
    tau: jnp.ndarray,
    *,
    k: int,
    block_rows: int = 4,
    interpret: bool = None,
):
    """Compact per-row elements with |x| >= tau into (vals, idx) of width k.

    ``k`` must be padded to a multiple of 128 by the caller (ops.py does).
    Slots beyond the actual kept count hold (0.0, 0) — dequant-neutral.
    """
    interpret = resolve_interpret(interpret)
    rows, cols = x2d.shape
    assert k % _K_TILE == 0, "pad k to a multiple of 128 (see ops.pad_k)"
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_pack_body, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, k), jnp.float32),
            jax.ShapeDtypeStruct((rows, k), jnp.int32),
        ],
        interpret=interpret,
    )(x2d.astype(jnp.float32), tau.astype(jnp.float32))


def _unpack_body(vals_ref, idx_ref, dense_ref, *, cols: int):
    vals = vals_ref[...]  # (r, k)
    idx = idx_ref[...].astype(jnp.float32)  # (r, k)
    r, k = vals.shape
    # slots with vals == 0 are padding; idx 0 collisions are harmless (add 0)
    n_tiles = pl.cdiv(cols, _F_TILE)
    for t in range(n_tiles):
        col = jax.lax.broadcasted_iota(jnp.float32, (1, 1, _F_TILE), 2) + t * _F_TILE
        onehot = (idx[:, :, None] == col).astype(jnp.float32)  # (r, k, F_TILE)
        dense_t = jnp.sum(vals[:, :, None] * onehot, axis=1)  # (r, F_TILE)
        dense_ref[:, t * _F_TILE : (t + 1) * _F_TILE] = dense_t


@functools.partial(jax.jit, static_argnames=("cols", "block_rows", "interpret"))
def unpack_pallas(
    vals: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    cols: int,
    block_rows: int = 4,
    interpret: bool = None,
):
    """Scatter (vals, idx) of width k back to a dense (rows, cols) array."""
    interpret = resolve_interpret(interpret)
    rows, k = vals.shape
    assert cols % _F_TILE == 0, "pad cols to a multiple of 512 (see ops.pad_cols)"
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_unpack_body, cols=cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0), memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(vals.astype(jnp.float32), idx.astype(jnp.int32))
