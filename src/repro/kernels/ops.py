"""Public jit'd wrappers over the Pallas kernels.

``interpret`` defaults to the shared platform policy in
``kernels/runtime.py``: interpret mode off-TPU (kernel bodies execute as jax
ops on the host for correctness validation), Mosaic compilation on TPU.
Every kernel entry point — wrapper or raw ``*_pallas`` function — resolves
``interpret=None`` through that one policy, so the fused and unfused paths
can never disagree.

The wrappers also own the static-shape hygiene the kernels demand:
* ``pad_k``   — round the kept budget up to the 128-lane tile;
* rfft slicing — the fft kernel produces the full 4096-bin spectrum; rfft
  semantics (2049 bins) are applied here.
"""

from __future__ import annotations


import jax.numpy as jnp

from repro.kernels import fft4step, pack, range_quant, topk_threshold
from repro.kernels.runtime import default_interpret  # noqa: F401 (re-export)

__all__ = [
    "default_interpret",
    "pad_k",
    "quant_encode",
    "quant_decode",
    "threshold_select",
    "pack_threshold",
    "unpack_dense",
    "rfft4096",
    "irfft4096",
    "compress_chunks",
    "decompress_chunks",
]

RFFT_BINS = fft4step.CHUNK // 2 + 1


def pad_k(k: int, tile: int = 128) -> int:
    return max(tile, ((k + tile - 1) // tile) * tile)


def quant_encode(x2d, quantizer, interpret=None):
    cfg = quantizer.config
    return range_quant.encode_pallas(
        x2d, quantizer.eps, quantizer.p_codes,
        n_bits=cfg.n_bits, m_bits=cfg.m_bits, interpret=interpret,
    )


def quant_decode(codes2d, quantizer, interpret=None):
    cfg = quantizer.config
    return range_quant.decode_pallas(
        codes2d, quantizer.eps, quantizer.p_codes,
        n_bits=cfg.n_bits, m_bits=cfg.m_bits, interpret=interpret,
    )


def threshold_select(mag2d, k: int, interpret=None):
    return topk_threshold.threshold_pallas(mag2d, k=k, interpret=interpret)


def pack_threshold(x2d, tau, k: int, interpret=None):
    return pack.pack_pallas(x2d, tau, k=pad_k(k), interpret=interpret)


def unpack_dense(vals, idx, cols: int, interpret=None):
    pad = (-cols) % pack._F_TILE
    dense = pack.unpack_pallas(vals, idx, cols=cols + pad, interpret=interpret)
    return dense[:, :cols]


def rfft4096(x2d, interpret=None):
    """(rows, 4096) real -> (re, im) each (rows, 2049)."""
    re, im = fft4step.fft4096_pallas(
        x2d, jnp.zeros_like(x2d), inverse=False, interpret=interpret
    )
    return re[:, :RFFT_BINS], im[:, :RFFT_BINS]


def irfft4096(re, im, interpret=None):
    """(rows, 2049) rfft spectrum -> (rows, 4096) real (hermitian inverse)."""
    # hermitian completion: X[N-k] = conj(X[k]) for k = 1..N/2-1
    tail_re = re[:, 1:-1][:, ::-1]
    tail_im = -im[:, 1:-1][:, ::-1]
    full_re = jnp.concatenate([re, tail_re], axis=-1)
    full_im = jnp.concatenate([im, tail_im], axis=-1)
    out_re, _ = fft4step.fft4096_pallas(
        full_re, full_im, inverse=True, interpret=interpret)
    return out_re


def compress_chunks(x2d, k: int, quantizer, interpret=None):
    """Kernel-composed paper pipeline on (rows, 4096) chunks.

    rfft -> weighted-magnitude threshold -> pack -> quantize re/im.
    Returns (re_codes, im_codes, idx, tau) with static width pad_k(k).
    """
    re, im = rfft4096(x2d, interpret)
    w = jnp.concatenate(
        [jnp.ones((1,)), 2 * jnp.ones((RFFT_BINS - 2,)), jnp.ones((1,))]
    ).astype(jnp.float32)
    mag = jnp.sqrt(re * re + im * im) * w
    tau, _ = threshold_select(mag, k, interpret)
    # pack the complex pair by thresholding the magnitude plane: pack indices
    # from mag, then gather re/im at those indices via the same kernel trick
    # (two packs share the tau so their index sets agree).
    mvals, idx = pack_threshold(mag, tau, k, interpret)
    # gather re/im at idx using unpack-transpose: cheaper path — use
    # take_along_axis outside the kernel (XLA gather on (rows, 2049)).
    re_k = jnp.take_along_axis(re, idx, axis=-1) * (mvals != 0)
    im_k = jnp.take_along_axis(im, idx, axis=-1) * (mvals != 0)
    re_c = quant_encode(re_k, quantizer, interpret)
    im_c = quant_encode(im_k, quantizer, interpret)
    return re_c, im_c, idx, tau


def decompress_chunks(re_c, im_c, idx, quantizer, orig_len: int, interpret=None):
    """Inverse of :func:`compress_chunks` -> flat f32 of orig_len."""
    re_k = quant_decode(re_c, quantizer, interpret)
    im_k = quant_decode(im_c, quantizer, interpret)
    pad = (-RFFT_BINS) % pack._F_TILE
    re = unpack_dense(re_k, idx, RFFT_BINS + pad, interpret)[:, :RFFT_BINS]
    im = unpack_dense(im_k, idx, RFFT_BINS + pad, interpret)[:, :RFFT_BINS]
    x2d = irfft4096(re, im, interpret)
    return x2d.reshape(-1)[:orig_len]
