"""Pallas TPU kernels for the paper's four compression hot spots
(FFT, top-k select, precision conversion, pack) + the fused pipeline.

Each kernel: <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
jit'd wrappers in ops.py, pure-jnp oracles in ref.py.
Validated in interpret mode on CPU; compiled via Mosaic on TPU.
"""

from repro.kernels import ops, ref  # noqa: F401
