"""Pallas TPU kernels for the paper's four compression hot spots
(FFT, top-k select, precision conversion, pack) + the fused pipeline
(``fused_compress``, ``fused_decompress``) and the ENGINE that dispatches
the compressor's stage execution across backends (``engine``: reference jnp
| fused pallas | auto).

Each kernel: <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
jit'd wrappers in ops.py, pure-jnp oracles in ref.py, shared interpret-mode
policy in runtime.py.
Validated in interpret mode on CPU; compiled via Mosaic on TPU.
"""

from repro.kernels import ops, ref, runtime  # noqa: F401
