"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors one kernel with straightforward jnp code; kernel tests
sweep shapes/dtypes and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantizer import (
    FittedQuantizer,
    RangeQuantConfig,
    decode as _q_decode,
    encode as _q_encode,
)

__all__ = [
    "quant_encode_ref",
    "quant_decode_ref",
    "threshold_ref",
    "pack_ref",
    "unpack_ref",
    "fft4096_ref",
]


def _quantizer_from(eps, p_codes, n_bits: int, m_bits: int) -> FittedQuantizer:
    cfg = RangeQuantConfig(n_bits, m_bits)
    # vmax/vmin unused by encode/decode math; fill for completeness
    return FittedQuantizer(cfg, jnp.asarray(eps, jnp.float32), jnp.asarray(p_codes, jnp.int32),
                           jnp.asarray(0.0), jnp.asarray(0.0))


def quant_encode_ref(x2d, eps, p_codes, n_bits=8, m_bits=3):
    return _q_encode(x2d, _quantizer_from(eps, p_codes, n_bits, m_bits))


def quant_decode_ref(codes2d, eps, p_codes, n_bits=8, m_bits=3):
    return _q_decode(codes2d, _quantizer_from(eps, p_codes, n_bits, m_bits))


def threshold_ref(mag2d: jnp.ndarray, k: int):
    """Exact k-th largest per row as the threshold (tau), plus count >= k."""
    top, _ = jax.lax.top_k(mag2d, k)
    tau = top[:, -1:]
    count = jnp.sum(mag2d >= tau, axis=-1, keepdims=True).astype(jnp.int32)
    return tau, count


def pack_ref(x2d: jnp.ndarray, tau: jnp.ndarray, k: int):
    """Compact |x| >= tau into (vals, idx) of static width k, in index order."""

    def row(xr, tr):
        mask = jnp.abs(xr) >= tr[0]
        idx = jnp.nonzero(mask, size=k, fill_value=-1)[0]
        valid = idx >= 0
        vals = jnp.where(valid, xr[jnp.maximum(idx, 0)], 0.0)
        return vals, jnp.where(valid, idx, 0).astype(jnp.int32)

    return jax.vmap(row)(x2d, tau)


def unpack_ref(vals: jnp.ndarray, idx: jnp.ndarray, cols: int):
    """Scatter (vals, idx) to dense (rows, cols)."""

    def row(v, i):
        return jnp.zeros((cols,), v.dtype).at[i].add(v)

    return jax.vmap(row)(vals, idx)


def fft4096_ref(x_re: jnp.ndarray, x_im: jnp.ndarray, inverse: bool = False):
    """Full 4096-bin complex FFT per row via jnp.fft."""
    z = x_re.astype(jnp.complex64) + 1j * x_im.astype(jnp.complex64)
    out = jnp.fft.ifft(z, axis=-1) if inverse else jnp.fft.fft(z, axis=-1)
    return jnp.real(out).astype(jnp.float32), jnp.imag(out).astype(jnp.float32)
