"""Pallas TPU kernel: FUSED threshold + pack + quantize (beyond paper).

The paper runs four separate GPU passes (§III-D's own cost model weights the
elementwise pass 4x: cost = M*(4/T_m + 1/T_f + 1/T_p + 1/T_s)).  On TPU the
spectrum tile can stay resident in VMEM through magnitude -> bisection
threshold -> one-hot compaction -> range quantization, cutting the HBM
round-trips of the compress stage from

    read re,im (8B/bin) + write mag (4) + read mag (4) + write tau
  + read re,im,mag (12) + write packed (..)    ~ 28 B/bin
to
    read re,im (8B/bin) + write codes+idx (~0.9 B/bin @ theta=0.7)

a ~3.1x reduction of the compression stage's memory term (EXPERIMENTS.md
§Perf, hypothesis H-K1).  Numerics identical to the unfused kernels
(tests/test_kernels.py::test_fused_matches_unfused).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core import selection
from repro.kernels.range_quant import encode_math
from repro.kernels.runtime import resolve_interpret

__all__ = ["fused_compress_pallas"]

_K_TILE = 128


def _fused_body(params_ref, re_ref, im_ref, w_ref, tau_in_ref,
                rec_ref, imc_ref, idx_ref, tau_ref, *, k_keep: int, k_pad: int,
                m_bits: int, per_row: bool = False):
    if per_row:
        # batched-bucket mode (DESIGN.md §14): each row carries its own
        # quantizer fit — params ride a VMEM plane, one lane-tile wide
        eps = params_ref[:, 0:1]       # (r, 1), broadcasts against (r, cols)
        p_codes = params_ref[:, 1:2]
        n_neg = params_ref[:, 2:3]
    else:
        eps = params_ref[0]
        p_codes = params_ref[1]
        n_neg = params_ref[2]
    m_scale = float(1 << m_bits)

    re = re_ref[...]
    im = im_ref[...]
    w = w_ref[...]  # (1, cols) hermitian weights
    r, cols = re.shape

    # 1. weighted magnitude (stays in VMEM)
    mag = jnp.sqrt(re * re + im * im) * w

    # 2. threshold: caller-provided (the engine shares ONE bisection between
    # the quantizer range fit and this kernel), or bisected in-kernel
    # (invariant: count(>=lo) >= k > count(>=hi))
    if tau_in_ref is not None:
        tau = tau_in_ref[...][:, 0]
    else:
        # shared selection-engine math (DESIGN.md §16): identical arithmetic
        # to threshold_pallas and the pure-jnp bisect selector, including the
        # nextafter-widened upper bracket
        tau = selection.bisect_tau(mag, k_keep)
    tau_ref[...] = tau[:, None]

    # 3. compaction positions
    mask = (mag >= tau[:, None]).astype(jnp.float32)
    pos = jnp.cumsum(mask, axis=-1) - 1.0
    pos = jnp.where(mask > 0, pos, -1.0)
    col_iota = jax.lax.broadcasted_iota(jnp.float32, (r, cols), 1)

    # 4. quantize-then-pack per 128-slot tile (values quantized in registers;
    # shared quantizer math keeps codes bitwise-equal to the staged kernel)
    def q_encode(a_signed):
        return encode_math(a_signed, eps, p_codes, n_neg, m_scale)

    n_tiles = pl.cdiv(k_pad, _K_TILE)
    for t in range(n_tiles):
        slot = jax.lax.broadcasted_iota(jnp.float32, (1, 1, _K_TILE), 2) + t * _K_TILE
        onehot = (pos[:, :, None] == slot).astype(jnp.float32)  # (r, cols, 128)
        re_t = jnp.sum(re[:, :, None] * onehot, axis=1)
        im_t = jnp.sum(im[:, :, None] * onehot, axis=1)
        ix_t = jnp.sum(col_iota[:, :, None] * onehot, axis=1)
        filled = jnp.sum(onehot, axis=1) > 0  # padding slots stay code 0
        rec_ref[:, t * _K_TILE:(t + 1) * _K_TILE] = jnp.where(
            filled, q_encode(re_t), 0.0).astype(rec_ref.dtype)
        imc_ref[:, t * _K_TILE:(t + 1) * _K_TILE] = jnp.where(
            filled, q_encode(im_t), 0.0).astype(imc_ref.dtype)
        idx_ref[:, t * _K_TILE:(t + 1) * _K_TILE] = ix_t.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k_keep", "m_bits", "n_bits",
                                             "block_rows", "interpret"))
def fused_compress_pallas(
    re2d: jnp.ndarray,
    im2d: jnp.ndarray,
    weights: jnp.ndarray,  # (cols,) hermitian weights
    eps: jnp.ndarray,
    p_codes: jnp.ndarray,
    tau: jnp.ndarray = None,  # optional (rows,) or (rows, 1) threshold
    *,
    k_keep: int,
    n_bits: int = 8,
    m_bits: int = 3,
    block_rows: int = 4,
    interpret: bool = None,
):
    """(rows, cols) spectrum planes -> (re_codes u8, im_codes u8, idx i32, tau).

    With ``tau=None`` the kernel bisects for the keep count ``k_keep``
    itself; a caller that already ran the threshold kernel (the engine does,
    to fit the quantizer range over the kept set) passes its tau in and the
    in-kernel search is skipped — one bisection per compress, and the mask
    provably matches the fit.  The payload width is padded to the 128-lane
    tile.

    Quantizer params may be scalars (one fit for every row — the monolithic
    path) or vectors of shape ``(rows,)`` (one fit PER ROW — the batched
    bucket executor maps each bucket's fit onto its chunk rows, so ALL
    buckets compress in this one launch; DESIGN.md §14).  Vector params ride
    a VMEM plane instead of SMEM scalars; the in-register math is identical.
    """
    interpret = resolve_interpret(interpret)
    rows, cols = re2d.shape
    k = ((k_keep + _K_TILE - 1) // _K_TILE) * _K_TILE
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    n_neg = (1 << n_bits) - 1 - p_codes
    per_row = jnp.ndim(eps) == 1
    if per_row:
        # (rows, lane-tile) plane: col 0 = eps, 1 = P, 2 = n_neg, rest pad
        params = jnp.zeros((rows, _K_TILE), jnp.float32)
        params = (params.at[:, 0].set(jnp.asarray(eps, jnp.float32))
                  .at[:, 1].set(p_codes.astype(jnp.float32))
                  .at[:, 2].set(n_neg.astype(jnp.float32)))
    else:
        params = jnp.stack([
            jnp.asarray(eps, jnp.float32),
            p_codes.astype(jnp.float32),
            n_neg.astype(jnp.float32),
        ])
    data = lambda c: pl.BlockSpec((block_rows, c), lambda i: (i, 0),
                                  memory_space=pltpu.VMEM)
    out_dtype = jnp.uint8 if n_bits <= 8 else jnp.uint16
    in_specs = [
        data(_K_TILE) if per_row else pl.BlockSpec(memory_space=pltpu.SMEM),
        data(cols), data(cols),
        pl.BlockSpec((1, cols), lambda i: (0, 0), memory_space=pltpu.VMEM),
    ]
    args = [params, re2d.astype(jnp.float32), im2d.astype(jnp.float32),
            weights.reshape(1, -1).astype(jnp.float32)]
    if tau is None:
        def body(p_ref, re_ref, im_ref, w_ref, *out_refs):
            _fused_body(p_ref, re_ref, im_ref, w_ref, None, *out_refs,
                        k_keep=k_keep, k_pad=k, m_bits=m_bits,
                        per_row=per_row)
    else:
        body = functools.partial(_fused_body, k_keep=k_keep, k_pad=k,
                                 m_bits=m_bits, per_row=per_row)
        in_specs.append(data(1))
        args.append(tau.reshape(rows, 1).astype(jnp.float32))
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=[data(k), data(k), data(k), data(1)],
        out_shape=[
            jax.ShapeDtypeStruct((rows, k), out_dtype),
            jax.ShapeDtypeStruct((rows, k), out_dtype),
            jax.ShapeDtypeStruct((rows, k), jnp.int32),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
