"""Pallas TPU kernel: range-based N-bit float encode/decode (paper Alg. 1).

The precision conversion is one of the four compression primitives the paper
optimizes on GPU ("embarrassingly data parallel ... take the benefit of GPU").
On TPU it is a pure VPU elementwise pass: grid over row-blocks, each block a
``(block_rows, cols)`` VMEM tile; quantizer parameters (eps, P, n_neg) ride in
SMEM as scalars.

Codes are emitted as uint8 (n_bits <= 8) — the memory-bandwidth win (4 bytes ->
1 byte) is the entire point of the pass; see EXPERIMENTS.md §Perf for the
fused variant that removes this pass's HBM round-trip altogether.

Matches :mod:`repro.core.quantizer` bit-for-bit (tests/test_kernels.py sweeps
shapes x dtypes against the oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.runtime import resolve_interpret

__all__ = ["encode_pallas", "decode_pallas", "encode_math", "decode_math"]

_LANE = 128  # TPU lane tile; width of the per-row params plane


def encode_math(x, eps, p_codes, n_neg, m_scale):
    """Range-quant ENCODE on an f32 plane (paper Alg. 1) — pure jnp math.

    Shared by this kernel's body and the fused compress kernel
    (``fused_compress.py``); one definition keeps the in-register and staged
    quantizers bitwise-identical by construction.  Parameters ride as traced
    f32 scalars (SMEM in the kernels).
    """
    a = jnp.abs(x)
    pos = x >= 0

    safe_a = jnp.maximum(a, eps)
    q = jnp.floor(jnp.log2(safe_a) - jnp.log2(eps) + 1e-6)
    seg_base = eps * jnp.exp2(q)
    r = jnp.round((safe_a / seg_base - 1.0) * m_scale)
    carry = r >= m_scale
    q = jnp.where(carry, q + 1.0, q)
    r = jnp.where(carry, 0.0, r)
    idx = q * m_scale + r
    # below-eps: nearest of {0, eps}
    idx = jnp.where(a < eps, jnp.where(a * 2.0 >= eps, 0.0, -1.0), idx)
    idx_pos = jnp.clip(idx, -1.0, p_codes - 1.0)
    idx_neg = jnp.clip(idx, -1.0, jnp.maximum(n_neg, 1.0) - 1.0)

    return jnp.where(
        pos,
        jnp.where(idx_pos < 0, 0.0, idx_pos + 1.0),
        jnp.where(idx_neg < 0, 0.0, p_codes + idx_neg + 1.0),
    )


def decode_math(c, eps, p_codes, m_scale):
    """Range-quant DECODE on an f32-carried code plane — pure jnp math.

    Shared by this kernel's body and the fused decompress kernel
    (``fused_decompress.py``)."""
    is_zero = c == 0.0
    is_pos = (c >= 1.0) & (c <= p_codes)
    idx = jnp.where(is_pos, c - 1.0, c - p_codes - 1.0)
    idx = jnp.maximum(idx, 0.0)
    q = jnp.floor(idx / m_scale)
    r = idx - q * m_scale
    mag = eps * jnp.exp2(q) * (1.0 + r / m_scale)
    val = jnp.where(is_pos, mag, -mag)
    return jnp.where(is_zero, 0.0, val)


def _unpack_params(params_ref, per_row: bool):
    """(eps, P, n_neg) from SMEM scalars or a per-row VMEM plane.

    Per-row mode carries one quantizer fit PER ROW (col 0/1/2 of a lane-tile
    plane) — the batched bucket executor's layout, where each bucket's fit is
    repeated onto its chunk rows (DESIGN.md §14).  The (r, 1) slices
    broadcast against the (r, cols) data tile, so the math below is shared.
    """
    if per_row:
        return params_ref[:, 0:1], params_ref[:, 1:2], params_ref[:, 2:3]
    return params_ref[0], params_ref[1], params_ref[2]


def _encode_body(params_ref, x_ref, codes_ref, *, m_bits: int,
                 per_row: bool = False):
    eps, p_codes, n_neg = _unpack_params(params_ref, per_row)
    code = encode_math(x_ref[...], eps, p_codes, n_neg, float(1 << m_bits))
    codes_ref[...] = code.astype(codes_ref.dtype)


def _decode_body(params_ref, codes_ref, x_ref, *, m_bits: int,
                 per_row: bool = False):
    eps, p_codes, _ = _unpack_params(params_ref, per_row)
    val = decode_math(codes_ref[...].astype(jnp.float32), eps, p_codes,
                      float(1 << m_bits))
    x_ref[...] = val.astype(x_ref.dtype)


def _params_vec(eps, p_codes, n_codes: int):
    """Quantizer params for the kernels: SMEM scalars, or — when ``eps`` /
    ``p_codes`` are ``(rows,)`` vectors — a per-row VMEM plane."""
    n_neg = n_codes - 1 - p_codes
    if jnp.ndim(eps) == 1:
        rows = eps.shape[0]
        plane = jnp.zeros((rows, _LANE), jnp.float32)
        return (plane.at[:, 0].set(jnp.asarray(eps, jnp.float32))
                .at[:, 1].set(p_codes.astype(jnp.float32))
                .at[:, 2].set(n_neg.astype(jnp.float32)))
    return jnp.stack(
        [
            jnp.asarray(eps, jnp.float32),
            p_codes.astype(jnp.float32),
            n_neg.astype(jnp.float32),
        ]
    )


@functools.partial(jax.jit, static_argnames=("n_bits", "m_bits", "block_rows", "interpret"))
def encode_pallas(
    x2d: jnp.ndarray,
    eps: jnp.ndarray,
    p_codes: jnp.ndarray,
    *,
    n_bits: int = 8,
    m_bits: int = 3,
    block_rows: int = 8,
    interpret: bool = None,
) -> jnp.ndarray:
    """f32 (rows, cols) -> uint8/uint16 codes, tiled over rows.

    ``eps``/``p_codes`` may be scalars (one fit for the whole plane) or
    ``(rows,)`` vectors (one fit per row — the batched bucket executor)."""
    interpret = resolve_interpret(interpret)
    rows, cols = x2d.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    out_dtype = jnp.uint8 if n_bits <= 8 else jnp.uint16
    per_row = jnp.ndim(eps) == 1
    params = _params_vec(eps, p_codes, 1 << n_bits)
    data = lambda c: pl.BlockSpec((block_rows, c), lambda i: (i, 0),
                                  memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_encode_body, m_bits=m_bits, per_row=per_row),
        grid=grid,
        in_specs=[
            data(_LANE) if per_row else pl.BlockSpec(memory_space=pltpu.SMEM),
            data(cols),
        ],
        out_specs=data(cols),
        out_shape=jax.ShapeDtypeStruct((rows, cols), out_dtype),
        interpret=interpret,
    )(params, x2d.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("n_bits", "m_bits", "block_rows", "interpret"))
def decode_pallas(
    codes2d: jnp.ndarray,
    eps: jnp.ndarray,
    p_codes: jnp.ndarray,
    *,
    n_bits: int = 8,
    m_bits: int = 3,
    block_rows: int = 8,
    interpret: bool = None,
) -> jnp.ndarray:
    """codes (rows, cols) -> f32, tiled over rows.

    ``eps``/``p_codes`` may be scalars or per-row ``(rows,)`` vectors, as in
    :func:`encode_pallas`."""
    interpret = resolve_interpret(interpret)
    rows, cols = codes2d.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    per_row = jnp.ndim(eps) == 1
    params = _params_vec(jnp.float32(0) + eps, p_codes, 1 << n_bits)
    data = lambda c: pl.BlockSpec((block_rows, c), lambda i: (i, 0),
                                  memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_decode_body, m_bits=m_bits, per_row=per_row),
        grid=grid,
        in_specs=[
            data(_LANE) if per_row else pl.BlockSpec(memory_space=pltpu.SMEM),
            data(cols),
        ],
        out_specs=data(cols),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(params, codes2d)
