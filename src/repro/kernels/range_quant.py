"""Pallas TPU kernel: range-based N-bit float encode/decode (paper Alg. 1).

The precision conversion is one of the four compression primitives the paper
optimizes on GPU ("embarrassingly data parallel ... take the benefit of GPU").
On TPU it is a pure VPU elementwise pass: grid over row-blocks, each block a
``(block_rows, cols)`` VMEM tile; quantizer parameters (eps, P, n_neg) ride in
SMEM as scalars.

Codes are emitted as uint8 (n_bits <= 8) — the memory-bandwidth win (4 bytes ->
1 byte) is the entire point of the pass; see EXPERIMENTS.md §Perf for the
fused variant that removes this pass's HBM round-trip altogether.

Matches :mod:`repro.core.quantizer` bit-for-bit (tests/test_kernels.py sweeps
shapes x dtypes against the oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.runtime import resolve_interpret

__all__ = ["encode_pallas", "decode_pallas", "encode_math", "decode_math"]


def encode_math(x, eps, p_codes, n_neg, m_scale):
    """Range-quant ENCODE on an f32 plane (paper Alg. 1) — pure jnp math.

    Shared by this kernel's body and the fused compress kernel
    (``fused_compress.py``); one definition keeps the in-register and staged
    quantizers bitwise-identical by construction.  Parameters ride as traced
    f32 scalars (SMEM in the kernels).
    """
    a = jnp.abs(x)
    pos = x >= 0

    safe_a = jnp.maximum(a, eps)
    q = jnp.floor(jnp.log2(safe_a) - jnp.log2(eps) + 1e-6)
    seg_base = eps * jnp.exp2(q)
    r = jnp.round((safe_a / seg_base - 1.0) * m_scale)
    carry = r >= m_scale
    q = jnp.where(carry, q + 1.0, q)
    r = jnp.where(carry, 0.0, r)
    idx = q * m_scale + r
    # below-eps: nearest of {0, eps}
    idx = jnp.where(a < eps, jnp.where(a * 2.0 >= eps, 0.0, -1.0), idx)
    idx_pos = jnp.clip(idx, -1.0, p_codes - 1.0)
    idx_neg = jnp.clip(idx, -1.0, jnp.maximum(n_neg, 1.0) - 1.0)

    return jnp.where(
        pos,
        jnp.where(idx_pos < 0, 0.0, idx_pos + 1.0),
        jnp.where(idx_neg < 0, 0.0, p_codes + idx_neg + 1.0),
    )


def decode_math(c, eps, p_codes, m_scale):
    """Range-quant DECODE on an f32-carried code plane — pure jnp math.

    Shared by this kernel's body and the fused decompress kernel
    (``fused_decompress.py``)."""
    is_zero = c == 0.0
    is_pos = (c >= 1.0) & (c <= p_codes)
    idx = jnp.where(is_pos, c - 1.0, c - p_codes - 1.0)
    idx = jnp.maximum(idx, 0.0)
    q = jnp.floor(idx / m_scale)
    r = idx - q * m_scale
    mag = eps * jnp.exp2(q) * (1.0 + r / m_scale)
    val = jnp.where(is_pos, mag, -mag)
    return jnp.where(is_zero, 0.0, val)


def _encode_body(params_ref, x_ref, codes_ref, *, m_bits: int):
    eps = params_ref[0]
    p_codes = params_ref[1]  # f32-carried int
    n_neg = params_ref[2]
    code = encode_math(x_ref[...], eps, p_codes, n_neg, float(1 << m_bits))
    codes_ref[...] = code.astype(codes_ref.dtype)


def _decode_body(params_ref, codes_ref, x_ref, *, m_bits: int):
    eps = params_ref[0]
    p_codes = params_ref[1]
    val = decode_math(codes_ref[...].astype(jnp.float32), eps, p_codes,
                      float(1 << m_bits))
    x_ref[...] = val.astype(x_ref.dtype)


def _params_vec(eps, p_codes, n_codes: int):
    n_neg = n_codes - 1 - p_codes
    return jnp.stack(
        [
            jnp.asarray(eps, jnp.float32),
            p_codes.astype(jnp.float32),
            n_neg.astype(jnp.float32),
        ]
    )


@functools.partial(jax.jit, static_argnames=("n_bits", "m_bits", "block_rows", "interpret"))
def encode_pallas(
    x2d: jnp.ndarray,
    eps: jnp.ndarray,
    p_codes: jnp.ndarray,
    *,
    n_bits: int = 8,
    m_bits: int = 3,
    block_rows: int = 8,
    interpret: bool = None,
) -> jnp.ndarray:
    """f32 (rows, cols) -> uint8/uint16 codes, tiled over rows."""
    interpret = resolve_interpret(interpret)
    rows, cols = x2d.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    out_dtype = jnp.uint8 if n_bits <= 8 else jnp.uint16
    params = _params_vec(eps, p_codes, 1 << n_bits)
    return pl.pallas_call(
        functools.partial(_encode_body, m_bits=m_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0), memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((rows, cols), out_dtype),
        interpret=interpret,
    )(params, x2d.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("n_bits", "m_bits", "block_rows", "interpret"))
def decode_pallas(
    codes2d: jnp.ndarray,
    eps: jnp.ndarray,
    p_codes: jnp.ndarray,
    *,
    n_bits: int = 8,
    m_bits: int = 3,
    block_rows: int = 8,
    interpret: bool = None,
) -> jnp.ndarray:
    """codes (rows, cols) -> f32, tiled over rows."""
    interpret = resolve_interpret(interpret)
    rows, cols = codes2d.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    params = _params_vec(jnp.float32(0) + eps, p_codes, 1 << n_bits)
    return pl.pallas_call(
        functools.partial(_decode_body, m_bits=m_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0), memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(params, codes2d)
