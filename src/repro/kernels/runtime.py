"""Shared runtime policy for every Pallas kernel entry point.

One place answers "should this kernel run in interpret mode?" so the fused
and unfused kernels can never disagree (they used to: ``fused_compress``
hardcoded ``interpret=True`` while ``ops.py`` detected the platform).

* ``default_interpret()`` — True off-TPU (interpret mode executes the kernel
  bodies as jax ops on the host for correctness validation), False on TPU
  where the kernels compile to Mosaic.
* ``resolve_interpret(flag)`` — the contract every kernel entry point
  follows: ``interpret=None`` (the default everywhere) means "use the shared
  platform default"; an explicit bool always wins (tests pin True).
* ``mosaic_available()`` — can this process compile Pallas to Mosaic?  The
  ``auto`` engine backend (``kernels/engine.py``) keys off this.
"""

from __future__ import annotations

from typing import Optional

import jax

__all__ = ["default_interpret", "resolve_interpret", "mosaic_available"]


def mosaic_available() -> bool:
    """True when Pallas kernels compile to Mosaic on this platform (TPU)."""
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Platform default for Pallas ``interpret``: True everywhere but TPU."""
    return not mosaic_available()


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> shared platform default; explicit bool -> honored verbatim."""
    return default_interpret() if interpret is None else bool(interpret)
