"""Pallas TPU kernel: per-chunk magnitude threshold selection.

The paper uses Thrust sort / bucketSelect on GPU to find the top-(1-theta)
coefficients.  A global sort is hostile to the TPU (no efficient gather/
shuffle); instead each chunk's threshold ``tau`` is found by **bisection on the
value axis** — ~26 VPU-vectorized compare+count sweeps over a VMEM-resident
row, no data movement.  This mirrors bucketSelect's spirit (count-based
selection) and is exact for distinct magnitudes (f32 bisection converges to
the k-th order statistic).

Outputs per row: ``tau`` (smallest kept magnitude) and ``count`` (#elements
>= tau, == k for continuous data; may exceed k under ties — the pack stage
truncates under its static budget, identical to bucketSelect semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.selection import BISECT_ITERS
from repro.kernels.runtime import resolve_interpret
from repro.core import selection

__all__ = ["threshold_pallas", "BISECT_ITERS"]

# BISECT_ITERS now lives in core/selection.py (the selection engine's shared
# math, DESIGN.md §16) and is re-exported here for back-compat; the kernel
# body below calls selection.bisect_tau so the pure-jnp bisect selector and
# this kernel can never desynchronize.
_BISECT_ITERS = BISECT_ITERS


def _threshold_body(mag_ref, tau_ref, count_ref, *, k: int):
    mag = mag_ref[...]  # (block_rows, cols)
    # upper bracket = one representable f32 above the row max (nextafter via
    # bitcast+1, clamped to FLT_MAX) so the count(>= hi) < k invariant holds
    # exactly for denormal and near-overflow rows; lower edge tau guarantees
    # count >= k (never drops below budget)
    tau = selection.bisect_tau(mag, k)
    count = jnp.sum(mag >= tau[:, None], axis=-1)
    tau_ref[...] = tau[:, None]
    count_ref[...] = count[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def threshold_pallas(
    mag2d: jnp.ndarray,
    *,
    k: int,
    block_rows: int = 8,
    interpret: bool = None,
):
    """(rows, cols) magnitudes -> (tau (rows,1) f32, count (rows,1) i32)."""
    interpret = resolve_interpret(interpret)
    rows, cols = mag2d.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_threshold_body, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((rows, 1), jnp.int32),
        ],
        interpret=interpret,
    )(mag2d.astype(jnp.float32))
