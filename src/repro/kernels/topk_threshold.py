"""Pallas TPU kernel: per-chunk magnitude threshold selection.

The paper uses Thrust sort / bucketSelect on GPU to find the top-(1-theta)
coefficients.  A global sort is hostile to the TPU (no efficient gather/
shuffle); instead each chunk's threshold ``tau`` is found by **bisection on the
value axis** — ~26 VPU-vectorized compare+count sweeps over a VMEM-resident
row, no data movement.  This mirrors bucketSelect's spirit (count-based
selection) and is exact for distinct magnitudes (f32 bisection converges to
the k-th order statistic).

Outputs per row: ``tau`` (smallest kept magnitude) and ``count`` (#elements
>= tau, == k for continuous data; may exceed k under ties — the pack stage
truncates under its static budget, identical to bucketSelect semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.runtime import resolve_interpret

__all__ = ["threshold_pallas", "BISECT_ITERS"]

# enough sweeps that lo/hi reach ADJACENT f32 values even when tau sits far
# below the row max (the interval halves from ~max each sweep; 48 covers
# tau >= max * 2^-24, the f32 mantissa range).  Short of adjacency the kept
# count can exceed k without a genuine bitwise tie — at 30 iterations a tau
# near max*1e-3 leaves a ~2^-30·max window spanning several representable
# values, and backend code parity (DESIGN.md §13) would break data-dependently.
# Shared with fused_compress's in-kernel (tau=None) search so the two
# bisections can never desynchronize.
BISECT_ITERS = 48
_BISECT_ITERS = BISECT_ITERS


def _threshold_body(mag_ref, tau_ref, count_ref, *, k: int):
    mag = mag_ref[...]  # (block_rows, cols)
    # invariant: count(>= lo) >= k, count(>= hi) < k
    hi = jnp.max(mag, axis=-1) * 1.0000002 + 1e-30  # strictly above max
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        count = jnp.sum(mag >= mid[:, None], axis=-1)
        feasible = count >= k  # mid keeps at least the budget
        new_lo = jnp.where(feasible, mid, lo)
        new_hi = jnp.where(feasible, hi, mid)
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, body, (lo, hi))
    # lower edge: guarantees count >= k (never drops below budget)
    tau = lo
    count = jnp.sum(mag >= tau[:, None], axis=-1)
    tau_ref[...] = tau[:, None]
    count_ref[...] = count[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def threshold_pallas(
    mag2d: jnp.ndarray,
    *,
    k: int,
    block_rows: int = 8,
    interpret: bool = None,
):
    """(rows, cols) magnitudes -> (tau (rows,1) f32, count (rows,1) i32)."""
    interpret = resolve_interpret(interpret)
    rows, cols = mag2d.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_threshold_body, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((rows, 1), jnp.int32),
        ],
        interpret=interpret,
    )(mag2d.astype(jnp.float32))
