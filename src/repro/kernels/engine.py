"""Compressor engine: pluggable stage-execution backends for the paper's
pipeline (the "swappable fusion schedule" move — SSFusion's schedule registry
applied to our compress/decompress hot path).

``FFTCompressor`` (core/compressor.py) owns the *protocol* — payload format,
wire accounting, config — and delegates stage execution here.  A backend
implements the entry points the compressor exposes:

    compress(cfg, x_flat)            -> FFTPayload
    compress_buckets(cfg, buckets)   -> [FFTPayload]        (per-bucket loop)
    compress_stacked(cfg, mat, sizes)-> StackedPayload      (batched executor,
                                        DESIGN.md §14: every bucket in ONE
                                        launch, bitwise-equal to the loop)
    decompress(payload)              -> flat f32
    decompress_stacked(payload)      -> (n_buckets, padded) f32
    decompress_spectrum(payload)     -> dense complex spectrum (batch-aware)
    wire_bits(cfg, n)                -> static wire estimate (shared accounting)

Backends (``FFTCompressorConfig.backend``):

* ``reference`` — the pure-``jnp`` path (the seed's staged pipeline; its
  ranking magnitude is now the canonical kernel-native form, see
  ``_weighted_magnitude`` — kept sets can differ from pre-engine output at
  1-ulp boundaries).
* ``pallas``    — the fused device kernels: compress runs the bisection
  threshold + ``fused_compress`` (threshold -> pack -> quantize in one VMEM
  pass); decompress runs ``fused_decompress`` (dequantize -> Hermitian
  scatter -> 4-step iFFT in one VMEM pass).  Stages with no kernel-eligible
  shape fall back per-stage with a logged reason.
* ``auto``      — ``pallas`` when the platform compiles Mosaic
  (``runtime.mosaic_available``) and the config is kernel-eligible
  (``kernel_eligibility``), else ``reference``; the choice is logged once.

Payload compatibility contract: every backend emits the SAME ``FFTPayload``
layout — ``(c, k)`` planes, int16 indices, one fitted quantizer — so the
transports (comms/transport.py) accept engine-produced payloads unchanged
and backends can be mixed across workers.  The only licensed difference is
slot ORDER: reference packs kept coefficients magnitude-descending
(``top_k`` order) while pallas packs index-ascending (compaction order);
both decompress identically because unpacking is a scatter.

Forward FFT note: the fused win the paper measures is in the *post*-FFT
stages (its own §III-D model weights the elementwise pass 4x), so the pallas
compress backend keeps XLA's exact native rfft for the forward transform —
this is also what makes reference/pallas CODES bitwise-identical (the
matmul-based 4-step FFT is ~1e-5-approximate and would perturb codes near
quantization bin edges).  The inverse transform sits inside the fused
decompress kernel, where reconstructions are compared by tolerance, not
bitwise (tests/test_engine.py).
"""

from __future__ import annotations

import logging
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import fft as cfft
from repro.core import packing, selection, sparsify
from repro.core.quantizer import (
    RangeQuantConfig,
    decode as q_decode,
    encode as q_encode,
    fit_quantizer,
)
from repro.kernels import fused_compress, fused_decompress, ops, sampled_threshold
from repro.kernels.fft4step import CHUNK as KERNEL_CHUNK
from repro.kernels.runtime import mosaic_available

__all__ = [
    "BACKEND_NAMES",
    "CompressorBackend",
    "ReferenceBackend",
    "PallasBackend",
    "AutoBackend",
    "get_backend",
    "kernel_eligibility",
    "wire_bits",
]

BACKEND_NAMES = ("reference", "pallas", "auto")

_LOG = logging.getLogger(__name__)
_logged_reasons: set = set()


def _log_once(reason: str) -> None:
    if reason not in _logged_reasons:
        _logged_reasons.add(reason)
        _LOG.info("engine backend fallback: %s", reason)


def _payload_cls():
    # deferred: core.compressor imports this module's consumers; the class is
    # only needed at trace time, long after both modules finished importing
    from repro.core.compressor import FFTPayload

    return FFTPayload


def _stacked_cls():
    from repro.core.compressor import StackedPayload

    return StackedPayload


# ---------------------------------------------------------------------------
# shared helpers (config math used by every backend)
# ---------------------------------------------------------------------------


def _keep_k(cfg) -> int:
    return sparsify.keep_count(cfg.chunk // 2 + 1, cfg.theta)


def _weighted_magnitude(re, im, w):
    """Canonical Hermitian-weighted ranking magnitude: sqrt(re²+im²)·w.

    This is the KERNEL-NATIVE form (Pallas carries complex data as separate
    real planes, so the fused kernel computes exactly this in-register).
    ``jnp.abs(complex)`` disagrees with it by 1 ulp on ~a third of bins
    (XLA's complex abs is hypot-style), which is enough to flip kept-set
    boundaries — so EVERY backend ranks with this one definition, keeping
    the kept set, the threshold tau, and the quantizer-range fit
    bitwise-identical across backends (DESIGN.md §13).
    """
    return jnp.sqrt(re * re + im * im) * w


def _qcfg(cfg) -> RangeQuantConfig:
    return RangeQuantConfig(cfg.n_bits, cfg.m_bits)


def _selector_tau(cfg, mag, k: int, sel: str):
    """Pure-jnp threshold for a resolved threshold selector (…, 1)."""
    return selection.selector_tau(
        mag, k, sel, sample_rate=cfg.sample_rate,
        refine_iters=cfg.tau_refine_iters, seed=cfg.selector_seed)


def _pallas_tau(cfg, mag2d, k: int, sel: str):
    """Threshold-kernel dispatch for the pallas backend: (tau (r,1), count).

    ``sort`` and ``bisect`` both map to the full bisection kernel — on this
    backend the "sort" selector has always BEEN count-based selection
    (``threshold_pallas``); ``bisect`` just names it explicitly.  ``sampled``
    runs the sampled-bracket kernel, whose body calls the same
    ``core/selection`` math the reference selector runs (DESIGN.md §16).
    """
    if sel == "sampled":
        return sampled_threshold.sampled_select(
            mag2d, k=k, sample_rate=cfg.sample_rate,
            refine_iters=cfg.tau_refine_iters, seed=cfg.selector_seed)
    return ops.threshold_select(mag2d, k)


def _scatter_spectrum(idx, kept, f_bins: int) -> jnp.ndarray:
    """Additive scatter of kept coefficients into dense ``(..., f_bins)`` rows.

    Shape-polymorphic over LEADING axes (chunk, bucket, worker — any stack of
    them): the row scatter is defined once over a flattened row axis, so the
    transports' worker-axis ``vmap`` composes with the executor's bucket axis
    without re-tracing per composition (the old per-call ``jnp.zeros`` target
    was rebuilt for every distinct leading shape).  ``.add`` tolerates the
    code-0/index-0 padding slots of tile- and bucket-padded payloads.
    """
    lead = kept.shape[:-1]
    k = kept.shape[-1]
    rows_i = idx.reshape(-1, k)
    rows_v = kept.reshape(-1, k)
    zeros = jnp.zeros((rows_v.shape[0], f_bins), rows_v.dtype)
    out = jax.vmap(lambda row, i, v: row.at[i].add(v))(zeros, rows_i, rows_v)
    return out.reshape(lead + (f_bins,))


def _valid_chunk_mask(sizes, max_chunks: int, chunk: int) -> jnp.ndarray:
    # canonical padding-mask rule lives next to StackedPayload (deferred
    # import, same reason as _payload_cls)
    from repro.core.compressor import valid_chunk_mask

    return valid_chunk_mask(sizes, max_chunks, chunk)


def _stack_quant(q):
    from repro.core.compressor import stack_bucket_quant

    return stack_bucket_quant(q)


def wire_bits(cfg, n: int) -> int:
    """Static wire estimate of one monolithic payload (backend-independent:
    every backend ships the same layout).  Bucketed exchanges fit one
    quantizer PER bucket — price those with
    ``comms.cost_model.bucketed_payload_bits``, not one call of this."""
    n_chunks = max(1, -(-n // cfg.chunk))
    k = _keep_k(cfg)
    value_bits = 2 * (cfg.n_bits if cfg.quantize else 32)  # re + im
    per_chunk = k * (value_bits + cfg.index_bits)
    overhead = 4 * 32  # quantizer params (eps, P, vmin, vmax)
    return n_chunks * per_chunk + overhead


def kernel_eligibility(cfg) -> Tuple[bool, str]:
    """Is the FULLY fused kernel pipeline available for this config?

    Returns (eligible, reason).  Ineligible configs still run under the
    ``pallas`` backend — each stage falls back individually (see
    ``PallasBackend``) — but ``auto`` only prefers pallas when the whole
    pipeline fuses.
    """
    reasons = []
    if cfg.chunk != KERNEL_CHUNK:
        reasons.append(
            f"chunk={cfg.chunk} != {KERNEL_CHUNK} (fft4step/fused_decompress "
            "are specialized to 4096-pt chunks)")
    if not cfg.quantize:
        reasons.append("quantize=False (the fused kernels quantize in-register)")
    return (not reasons, "; ".join(reasons))


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


class CompressorBackend:
    """Stage-execution strategy behind the compressor protocol."""

    name: str = "base"

    # -- compress ----------------------------------------------------------
    def compress(self, cfg, x_flat: jnp.ndarray):
        raise NotImplementedError

    def compress_buckets(self, cfg, bucket_flats: Sequence[jnp.ndarray]) -> List:
        """Per-bucket compression: each bucket fits its OWN quantizer range.

        The monolithic path fits one (min, max) over the whole gradient, so a
        small bucket whose spectrum lives in a narrow band inherits a global
        range and wastes most of its codes.  Compressing per bucket keeps the
        range local (DESIGN.md §8); the bucketed transports rely on this.
        """
        return [self.compress(cfg, b) for b in bucket_flats]

    def compress_stacked(self, cfg, stacked: jnp.ndarray, sizes):
        """Batched bucket executor (DESIGN.md §14): compress a uniform
        ``(n_buckets, padded_size)`` matrix (``bucketing.stack_buckets``) in
        one batched pass, one quantizer fit per bucket row, producing a
        ``StackedPayload`` bitwise-equal to :meth:`compress_buckets` on the
        same layout."""
        raise NotImplementedError

    # -- decompress --------------------------------------------------------
    def decompress_spectrum(self, payload) -> jnp.ndarray:
        """Payload -> dense complex spectrum (..., chunk//2+1).

        Shared by every backend: the dequantize+scatter is O(k) work that the
        collectives vmap over the worker axis (comms/transport.py), so it
        stays plain jnp — the kernel-fused win lives in compress/decompress.
        Batch-aware over leading axes: accepts the monolithic (c, k) payload,
        the stacked (n_buckets, max_chunks, k) payload, and any worker-vmap
        of either (see ``_scatter_spectrum``).
        """
        re, im = payload.re, payload.im
        if payload.quant is not None:
            re, im = q_decode(re, payload.quant), q_decode(im, payload.quant)
        kept = re.astype(jnp.float32) + 1j * im.astype(jnp.float32)
        return _scatter_spectrum(payload.idx, kept, payload.chunk // 2 + 1)

    def decompress(self, payload) -> jnp.ndarray:
        spectrum = self.decompress_spectrum(payload)
        return cfft.chunked_irfft(spectrum, payload.orig_len, payload.chunk)

    def decompress_stacked(self, payload) -> jnp.ndarray:
        """StackedPayload -> ``(n_buckets, padded_size)`` time-domain matrix
        (``bucketing.unstack_buckets`` recovers the flat buffer).  Padding
        rows decode to exact zeros, so each row's prefix is bitwise-equal to
        the per-bucket ``decompress``."""
        spectrum = self.decompress_spectrum(payload)  # (B, max_chunks, f)
        x = jnp.fft.irfft(spectrum, n=payload.chunk, axis=-1)
        return x.reshape(spectrum.shape[0], -1).astype(jnp.float32)


class ReferenceBackend(CompressorBackend):
    """The pure-jnp path: XLA rfft -> top_k -> gather -> range-quant encode.
    Packs kept coefficients in top_k (magnitude descending) order.  Ranks by
    the canonical ``_weighted_magnitude`` so its kept set is bitwise-equal to
    the fused kernel's."""

    name = "reference"

    def compress(self, cfg, x_flat: jnp.ndarray):
        freqs, n = cfft.chunked_rfft(x_flat, cfg.chunk)
        k = _keep_k(cfg)
        w = cfft.hermitian_weights(cfg.chunk)
        re_p = jnp.real(freqs).astype(jnp.float32)
        im_p = jnp.imag(freqs).astype(jnp.float32)
        mag = _weighted_magnitude(re_p, im_p, w)
        sel = selection.resolve_selector(cfg.selector, mag.shape[-1])
        if sel == "sort":
            idx = sparsify.topk_select(mag, k)
            tau = None
        else:
            # threshold selector (DESIGN.md §16): O(n) tau + one count-and-
            # compact pass; slots come out index-ascending (pallas order)
            tau = _selector_tau(cfg, mag, k, sel)
            idx = selection.count_compact(mag, tau, k)
        kept = packing.pack_by_indices(freqs, idx)
        re, im = jnp.real(kept), jnp.imag(kept)
        if cfg.quantize:
            if tau is None:
                quant = self._fit(cfg, re, im)
            else:
                # fit over the PRE-truncation tau mask — the same set the
                # pallas backend fits over, so cross-backend codes stay
                # bitwise-equal under every selector (tie caveat as in
                # PallasBackend.compress)
                quant = self._fit_masked(cfg, re_p, im_p, mag >= tau)
            re, im = q_encode(re, quant), q_encode(im, quant)
        else:
            quant = None
        # int16 indices: 2049 rfft bins fit; halves the index wire bytes
        return _payload_cls()(re, im, idx.astype(jnp.int16), quant, n, cfg.chunk)

    def _fit(self, cfg, re: jnp.ndarray, im: jnp.ndarray):
        if cfg.range_mode == "fixed":
            lo, hi = cfg.fixed_range
            return fit_quantizer(lo, hi, _qcfg(cfg))
        lo = jnp.minimum(re.min(), im.min())
        hi = jnp.maximum(re.max(), im.max())
        return fit_quantizer(lo, hi, _qcfg(cfg))

    def _fit_masked(self, cfg, re_p, im_p, mask):
        """Range fit over masked spectrum PLANES — expression-for-expression
        the fit the pallas backend runs, so the two backends' quantizer
        params are bitwise-identical whenever their tau is."""
        if cfg.range_mode == "fixed":
            lo, hi = cfg.fixed_range
            return fit_quantizer(lo, hi, _qcfg(cfg))
        lo = jnp.minimum(jnp.where(mask, re_p, jnp.inf).min(),
                         jnp.where(mask, im_p, jnp.inf).min())
        hi = jnp.maximum(jnp.where(mask, re_p, -jnp.inf).max(),
                         jnp.where(mask, im_p, -jnp.inf).max())
        return fit_quantizer(lo, hi, _qcfg(cfg))

    def compress_stacked(self, cfg, stacked: jnp.ndarray, sizes):
        """ONE executable for every bucket: the per-bucket loop's exact math
        as a ``lax.map`` over the bucket axis of the (n_buckets, max_chunks,
        chunk) tensor.  The rolled grid keeps the program size (and compile
        time) independent of the bucket count — the unrolled loop compiles
        one subgraph PER BUCKET — while each iteration's working set stays
        one bucket wide (cache-resident on hosts; the pallas backend flattens
        the same math into one kernel grid instead).  Per-bucket quantizer
        ranges are per-bucket reductions with the zero-padding chunks masked
        out (min/max over a subset is order-free, so each bucket's fit — and
        hence its codes — is bitwise-equal to the loop's)."""
        sizes = tuple(int(s) for s in sizes)
        n_buckets, padded = stacked.shape
        c_max = padded // cfg.chunk
        k = _keep_k(cfg)
        w = cfft.hermitian_weights(cfg.chunk)
        counts = jnp.asarray([-(-s // cfg.chunk) for s in sizes])
        sel = selection.resolve_selector(cfg.selector, cfg.chunk // 2 + 1)

        def one_bucket(args):
            x2d, c_b = args  # (max_chunks, chunk) rows, true chunk count
            # row-for-row the same transform the looped path runs via
            # cfft.chunked_rfft
            freqs = jnp.fft.rfft(x2d.astype(jnp.float32),
                                 axis=-1).astype(jnp.complex64)
            re_p = jnp.real(freqs).astype(jnp.float32)
            im_p = jnp.imag(freqs).astype(jnp.float32)
            mag = _weighted_magnitude(re_p, im_p, w)
            if sel == "sort":
                idx = sparsify.topk_select(mag, k)
                tau = None
            else:
                # per-row threshold selection is bucket-independent, so the
                # stacked result matches the looped compress row-for-row
                tau = _selector_tau(cfg, mag, k, sel)
                idx = selection.count_compact(mag, tau, k)
            kept = packing.pack_by_indices(freqs, idx)
            re, im = jnp.real(kept), jnp.imag(kept)
            if not cfg.quantize:
                return re, im, idx
            if cfg.range_mode == "fixed":
                lo, hi = cfg.fixed_range
                quant = fit_quantizer(lo, hi, _qcfg(cfg))
            elif tau is None:
                valid = (jnp.arange(c_max) < c_b)[:, None]
                lo = jnp.minimum(jnp.where(valid, re, jnp.inf).min(),
                                 jnp.where(valid, im, jnp.inf).min())
                hi = jnp.maximum(jnp.where(valid, re, -jnp.inf).max(),
                                 jnp.where(valid, im, -jnp.inf).max())
                quant = fit_quantizer(lo, hi, _qcfg(cfg))
            else:
                # pre-truncation tau mask, with the all-zero PADDING rows
                # (tau 0 -> mask all-true) excluded so the fit sees exactly
                # what the looped per-bucket fit saw
                m = (mag >= tau) & (jnp.arange(c_max) < c_b)[:, None]
                lo = jnp.minimum(jnp.where(m, re_p, jnp.inf).min(),
                                 jnp.where(m, im_p, jnp.inf).min())
                hi = jnp.maximum(jnp.where(m, re_p, -jnp.inf).max(),
                                 jnp.where(m, im_p, -jnp.inf).max())
                quant = fit_quantizer(lo, hi, _qcfg(cfg))
            return q_encode(re, quant), q_encode(im, quant), idx, quant

        x3 = stacked.reshape(n_buckets, c_max, cfg.chunk)
        if cfg.quantize:
            re, im, idx, quant = jax.lax.map(one_bucket, (x3, counts))
            quant = _stack_quant(quant)
        else:
            re, im, idx = jax.lax.map(one_bucket, (x3, counts))
            quant = None
        return _stacked_cls()(re, im, idx.astype(jnp.int16), quant, sizes,
                              cfg.chunk)


class PallasBackend(CompressorBackend):
    """Fused Pallas kernels on the hot stages, per-stage fallback elsewhere.

    compress:   exact XLA rfft (see module docstring) -> bisection-threshold
                kernel (quantizer range fit over the kept set) ->
                ``fused_compress_pallas`` (threshold+pack+quantize, one VMEM
                pass) -> slice the 128-lane padding down to the true keep
                count so the payload layout matches ``reference`` exactly.
    decompress: ``fused_decompress_pallas`` (dequantize + Hermitian scatter +
                4-step iFFT, one VMEM pass) when the payload is quantized and
                chunked at 4096; otherwise per-stage (quant_decode kernel +
                jnp scatter + XLA irfft) with a logged reason.

    Packs kept coefficients in index-ascending (compaction) order.
    """

    name = "pallas"

    def compress(self, cfg, x_flat: jnp.ndarray):
        freqs, n = cfft.chunked_rfft(x_flat, cfg.chunk)
        re = jnp.real(freqs).astype(jnp.float32)
        im = jnp.imag(freqs).astype(jnp.float32)
        k = _keep_k(cfg)
        w = cfft.hermitian_weights(cfg.chunk)
        mag = _weighted_magnitude(re, im, w)
        sel = selection.resolve_selector(cfg.selector, mag.shape[-1])

        if not cfg.quantize:
            _log_once("pallas compress: quantize=False -> per-stage "
                      "threshold+pack kernels (no fused quantization)")
            tau, _ = _pallas_tau(cfg, mag, k, sel)
            mvals, idx = ops.pack_threshold(mag, tau, k)  # width pad_k(k)
            valid = mvals != 0
            re_k = jnp.take_along_axis(re, idx, axis=-1) * valid
            im_k = jnp.take_along_axis(im, idx, axis=-1) * valid
            return _payload_cls()(
                re_k[:, :k], im_k[:, :k], idx[:, :k].astype(jnp.int16),
                None, n, cfg.chunk)

        # ONE bisection-threshold pass defines the kept set; its tau is shared
        # with the fused kernel (no second in-kernel search) so the mask the
        # kernel packs provably equals the set the quantizer range was fitted
        # over.  The kernel recomputes the magnitudes IN-REGISTER (that is
        # the fusion), and a recompute in a different compilation context may
        # differ by 1 ulp — so the shared tau is placed in the MIDDLE of the
        # gap between the k-th and (k+1)-th magnitudes, where an ulp of noise
        # on either side cannot flip the comparison.  (Bitwise ties at the
        # boundary still truncate under the static budget, as documented on
        # the slice below.)  Under selector=sampled the same contract holds
        # with the sampled-bracket tau: count(>= tau) >= k is guaranteed by
        # the in-kernel clamp, the surplus (a few near-tau values the short
        # refinement didn't split) truncates index-ascending, and the fit
        # below covers the full pre-truncation mask — exactly what the
        # reference selector path fits (DESIGN.md §16).
        tau_k, _ = _pallas_tau(cfg, mag, k, sel)
        below = jnp.max(jnp.where(mag < tau_k, mag, 0.0), axis=-1,
                        keepdims=True)  # largest dropped magnitude (or 0)
        tau = 0.5 * (tau_k + below)
        if cfg.range_mode == "fixed":
            lo, hi = cfg.fixed_range
            quant = fit_quantizer(lo, hi, _qcfg(cfg))
        else:
            mask = mag >= tau  # same set as mag >= tau_k on this plane
            lo = jnp.minimum(jnp.where(mask, re, jnp.inf).min(),
                             jnp.where(mask, im, jnp.inf).min())
            hi = jnp.maximum(jnp.where(mask, re, -jnp.inf).max(),
                             jnp.where(mask, im, -jnp.inf).max())
            quant = fit_quantizer(lo, hi, _qcfg(cfg))

        rec, imc, idx, _tau = fused_compress.fused_compress_pallas(
            re, im, w, quant.eps, quant.p_codes, tau,
            k_keep=k, n_bits=cfg.n_bits, m_bits=cfg.m_bits)
        # slice the tile padding off: payload layout == reference layout.
        # Residual caveat, bitwise ties ONLY: if j > 0 extra magnitudes equal
        # the k-th exactly, the mask keeps k+j coefficients, so (a) the range
        # fit sees j extra values and may differ from reference's k-value
        # fit, and (b) this slice truncates the highest-INDEX kept slots
        # (bucketSelect's static-budget semantics, kernels/topk_threshold)
        # while reference top_k drops by magnitude — code parity is exact
        # only for tie-free planes (continuous gradient data in practice).
        return _payload_cls()(
            rec[:, :k], imc[:, :k], idx[:, :k].astype(jnp.int16),
            quant, n, cfg.chunk)

    def compress_stacked(self, cfg, stacked: jnp.ndarray, sizes):
        """ONE kernel launch for every bucket: all bucket rows ride a single
        grid, and the per-bucket quantizer params become per-ROW planes inside
        the fused kernel (``fused_compress_pallas`` with vector eps/p_codes).
        The shared mid-gap tau and masked range fit keep codes bitwise-equal
        to the per-bucket loop (and to the reference backend, slot order
        aside)."""
        sizes = tuple(int(s) for s in sizes)
        n_buckets, padded = stacked.shape
        c_max = padded // cfg.chunk
        rows = n_buckets * c_max
        x2d = stacked.reshape(rows, cfg.chunk).astype(jnp.float32)
        freqs = jnp.fft.rfft(x2d, axis=-1).astype(jnp.complex64)
        re = jnp.real(freqs).astype(jnp.float32)
        im = jnp.imag(freqs).astype(jnp.float32)
        k = _keep_k(cfg)
        w = cfft.hermitian_weights(cfg.chunk)
        mag = _weighted_magnitude(re, im, w)
        sel = selection.resolve_selector(cfg.selector, mag.shape[-1])

        if not cfg.quantize:
            _log_once("pallas compress_stacked: quantize=False -> per-stage "
                      "threshold+pack kernels (no fused quantization)")
            tau, _ = _pallas_tau(cfg, mag, k, sel)
            mvals, idx = ops.pack_threshold(mag, tau, k)
            valid = mvals != 0
            re_k = jnp.take_along_axis(re, idx, axis=-1) * valid
            im_k = jnp.take_along_axis(im, idx, axis=-1) * valid
            return _stacked_cls()(
                re_k[:, :k].reshape(n_buckets, c_max, k),
                im_k[:, :k].reshape(n_buckets, c_max, k),
                idx[:, :k].astype(jnp.int16).reshape(n_buckets, c_max, k),
                None, sizes, cfg.chunk)

        # same one-threshold/mid-gap-tau contract as the looped compress,
        # batched over every bucket's chunks in one threshold-kernel launch
        tau_k, _ = _pallas_tau(cfg, mag, k, sel)
        below = jnp.max(jnp.where(mag < tau_k, mag, 0.0), axis=-1,
                        keepdims=True)
        tau = 0.5 * (tau_k + below)
        if cfg.range_mode == "fixed":
            lo = jnp.full((n_buckets,), cfg.fixed_range[0], jnp.float32)
            hi = jnp.full((n_buckets,), cfg.fixed_range[1], jnp.float32)
        else:
            # per-bucket fit over the kept set; padding rows (all-zero chunks,
            # tau 0, mask all-true) are excluded so the fit sees exactly the
            # values the looped per-bucket fit saw
            mask = ((mag >= tau)
                    & _valid_chunk_mask(sizes, c_max, cfg.chunk).reshape(
                        rows, 1))
            m3 = mask.reshape(n_buckets, c_max, -1)
            re3 = re.reshape(n_buckets, c_max, -1)
            im3 = im.reshape(n_buckets, c_max, -1)
            lo = jnp.minimum(
                jnp.where(m3, re3, jnp.inf).min(axis=(1, 2)),
                jnp.where(m3, im3, jnp.inf).min(axis=(1, 2)))
            hi = jnp.maximum(
                jnp.where(m3, re3, -jnp.inf).max(axis=(1, 2)),
                jnp.where(m3, im3, -jnp.inf).max(axis=(1, 2)))
        quant = _stack_quant(fit_quantizer(lo, hi, _qcfg(cfg)))
        # per-bucket params -> per-row planes for the single fused launch
        eps_rows = jnp.repeat(quant.eps.reshape(n_buckets), c_max)
        p_rows = jnp.repeat(quant.p_codes.reshape(n_buckets), c_max)
        rec, imc, idx, _tau = fused_compress.fused_compress_pallas(
            re, im, w, eps_rows, p_rows, tau,
            k_keep=k, n_bits=cfg.n_bits, m_bits=cfg.m_bits)
        return _stacked_cls()(
            rec[:, :k].reshape(n_buckets, c_max, k),
            imc[:, :k].reshape(n_buckets, c_max, k),
            idx[:, :k].astype(jnp.int16).reshape(n_buckets, c_max, k),
            quant, sizes, cfg.chunk)

    def decompress_stacked(self, payload) -> jnp.ndarray:
        if payload.quant is not None and payload.chunk == KERNEL_CHUNK:
            n_buckets, c_max, k = payload.re.shape
            rows = n_buckets * c_max
            eps_rows = jnp.repeat(payload.quant.eps.reshape(n_buckets), c_max)
            p_rows = jnp.repeat(
                payload.quant.p_codes.reshape(n_buckets), c_max)
            x2d = fused_decompress.fused_decompress_pallas(
                payload.re.reshape(rows, k), payload.im.reshape(rows, k),
                payload.idx.reshape(rows, k), eps_rows, p_rows,
                m_bits=payload.quant.config.m_bits)
            return x2d.reshape(n_buckets, c_max * KERNEL_CHUNK)
        if payload.quant is not None:
            _log_once(
                f"pallas decompress_stacked: chunked at {payload.chunk} != "
                f"{KERNEL_CHUNK} -> per-stage (per-row quant_decode kernel + "
                "shared scatter + XLA irfft)")
            from repro.kernels import range_quant

            n_buckets, c_max, k = payload.re.shape
            rows = n_buckets * c_max
            eps_rows = jnp.repeat(payload.quant.eps.reshape(n_buckets), c_max)
            p_rows = jnp.repeat(
                payload.quant.p_codes.reshape(n_buckets), c_max)
            qcfg = payload.quant.config
            re = range_quant.decode_pallas(
                payload.re.reshape(rows, k), eps_rows, p_rows,
                n_bits=qcfg.n_bits, m_bits=qcfg.m_bits).reshape(
                    n_buckets, c_max, k)
            im = range_quant.decode_pallas(
                payload.im.reshape(rows, k), eps_rows, p_rows,
                n_bits=qcfg.n_bits, m_bits=qcfg.m_bits).reshape(
                    n_buckets, c_max, k)
            payload = _stacked_cls()(re, im, payload.idx, None, payload.sizes,
                                     payload.chunk, payload.has_im)
        return super().decompress_stacked(payload)

    def decompress(self, payload) -> jnp.ndarray:
        if payload.quant is not None and payload.chunk == KERNEL_CHUNK:
            x2d = fused_decompress.fused_decompress_pallas(
                payload.re, payload.im, payload.idx,
                payload.quant.eps, payload.quant.p_codes,
                m_bits=payload.quant.config.m_bits)
            return x2d.reshape(-1)[: payload.orig_len].astype(jnp.float32)
        _log_once(
            "pallas decompress: payload is "
            + ("unquantized" if payload.quant is None
               else f"chunked at {payload.chunk} != {KERNEL_CHUNK}")
            + " -> per-stage (quant_decode kernel + scatter + XLA irfft)")
        if payload.quant is not None:
            re = ops.quant_decode(payload.re, payload.quant)
            im = ops.quant_decode(payload.im, payload.quant)
            payload = _payload_cls()(
                re, im, payload.idx, None, payload.orig_len, payload.chunk)
        return super().decompress(payload)


class AutoBackend(CompressorBackend):
    """Per-call choice: pallas when Mosaic compiles AND the config fuses
    end-to-end, reference otherwise (with the reason logged once)."""

    name = "auto"

    def __init__(self):
        self._reference = ReferenceBackend()
        self._pallas = PallasBackend()

    def _pick(self, cfg) -> CompressorBackend:
        if not mosaic_available():
            _log_once("auto backend -> reference: platform does not compile "
                      "Mosaic (pallas would run in interpret mode)")
            return self._reference
        eligible, reason = kernel_eligibility(cfg)
        if not eligible:
            _log_once(f"auto backend -> reference: {reason}")
            return self._reference
        return self._pallas

    def compress(self, cfg, x_flat: jnp.ndarray):
        return self._pick(cfg).compress(cfg, x_flat)

    def compress_buckets(self, cfg, bucket_flats):
        return self._pick(cfg).compress_buckets(cfg, bucket_flats)

    def compress_stacked(self, cfg, stacked, sizes):
        return self._pick(cfg).compress_stacked(cfg, stacked, sizes)

    def decompress(self, payload) -> jnp.ndarray:
        # payloads carry no backend tag (they are backend-portable); route by
        # the same platform gate — the pallas backend degrades per-stage on
        # shapes its fused kernel cannot take
        if mosaic_available():
            return self._pallas.decompress(payload)
        return self._reference.decompress(payload)

    def decompress_stacked(self, payload) -> jnp.ndarray:
        if mosaic_available():
            return self._pallas.decompress_stacked(payload)
        return self._reference.decompress_stacked(payload)


_BACKENDS = {
    "reference": ReferenceBackend(),
    "pallas": PallasBackend(),
    "auto": AutoBackend(),
}


def get_backend(name: str) -> CompressorBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown compressor backend {name!r}; expected one of {BACKEND_NAMES}"
        ) from None
