"""Pallas TPU kernel: sampled-bracket threshold refinement (selection engine).

The ``sampled`` selector (DESIGN.md §16) splits threshold selection into a
cheap host-side estimate and a short on-chip refinement:

1. host (pure jnp, O(n·sample_rate)): strided magnitude subsample -> bracket
   ``(lo, hi)`` around tau from the sample's order statistics
   (``core/selection.sample_bracket`` — count-based bisection on the sample,
   never a sort, so the whole pipeline's jaxpr is sort-free);
2. kernel (this file): each VMEM-resident row clamps the bracket so the
   bisection invariant ``count(>= lo) >= k > count(>= hi)`` provably holds on
   the FULL row, then runs ``refine_iters`` compare+count sweeps —
   ``refine_iters`` (default 16) instead of the full-range ``BISECT_ITERS``
   (48) because the sampled bracket already spans a narrow value interval.

The kernel body calls ``core/selection.refine_bracket`` directly: the
pure-jnp reference selector and this fused path run literally the same
arithmetic, so cross-backend payloads stay bitwise-comparable in interpret
mode (tests/test_selection.py).

Outputs per row match ``threshold_pallas``: ``tau`` (smallest kept
magnitude, count(>= tau) >= k guaranteed by the clamp) and ``count``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core import selection
from repro.kernels.runtime import resolve_interpret

__all__ = ["sampled_threshold_pallas", "sampled_select"]


def _sampled_body(mag_ref, lo_ref, hi_ref, tau_ref, count_ref,
                  *, k: int, iters: int):
    mag = mag_ref[...]  # (block_rows, cols)
    lo = lo_ref[...][:, 0]
    hi = hi_ref[...][:, 0]
    tau = selection.refine_bracket(mag, lo, hi, k, iters)
    count = jnp.sum(mag >= tau[:, None], axis=-1)
    tau_ref[...] = tau[:, None]
    count_ref[...] = count[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "refine_iters",
                                             "block_rows", "interpret"))
def sampled_threshold_pallas(
    mag2d: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    *,
    k: int,
    refine_iters: int = selection.DEFAULT_REFINE_ITERS,
    block_rows: int = 8,
    interpret: bool = None,
):
    """(rows, cols) magnitudes + estimated bracket -> (tau (rows,1), count).

    ``lo``/``hi`` are per-row bracket estimates (any shape reshapeable to
    (rows, 1)); rows where the estimate violates the bisection invariant
    fall back to the full [0, nextafter(max)] range in-kernel.
    """
    interpret = resolve_interpret(interpret)
    rows, cols = mag2d.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    edge = lambda: pl.BlockSpec((block_rows, 1), lambda i: (i, 0),
                                memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_sampled_body, k=k, iters=refine_iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            edge(), edge(),
        ],
        out_specs=[edge(), edge()],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((rows, 1), jnp.int32),
        ],
        interpret=interpret,
    )(mag2d.astype(jnp.float32),
      lo.reshape(rows, 1).astype(jnp.float32),
      hi.reshape(rows, 1).astype(jnp.float32))


def sampled_select(
    mag2d: jnp.ndarray,
    *,
    k: int,
    sample_rate: float = selection.DEFAULT_SAMPLE_RATE,
    refine_iters: int = selection.DEFAULT_REFINE_ITERS,
    seed: int = 0,
    interpret: bool = None,
):
    """Full sampled selection: (tau (rows,1) f32, count (rows,1) i32).

    Drop-in for ``ops.threshold_select`` under ``selector=sampled`` — the
    sample/bracket stage runs as plain jnp (it touches ~sample_rate of the
    data), the full-row clamp+refine runs in the Pallas kernel.
    """
    sample = selection.strided_sample(mag2d, sample_rate, seed)
    lo, hi = selection.sample_bracket(sample, k, mag2d.shape[-1])
    return sampled_threshold_pallas(
        mag2d, lo, hi, k=k, refine_iters=refine_iters, interpret=interpret)
