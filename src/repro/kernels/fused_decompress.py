"""Pallas TPU kernel: FUSED decompress — dequantize -> scatter-unpack ->
inverse FFT in one VMEM-resident pass.

Closes the asymmetry left by ``fused_compress``: the compress side had a
single fused kernel while decompress was three staged passes
(``range_quant.decode`` -> ``pack.unpack`` -> ``fft4step`` inverse), each
round-tripping the dense spectrum through HBM:

    read codes (~0.9 B/bin) + write re,im (8) + read re,im (8)
  + write full spectrum (8) + read full spectrum (8) + write signal (4)
    ~ 37 B/bin
vs
    read codes+idx (~0.9 B/bin) + write signal (4 B/bin)

Everything between — decode, the Hermitian scatter, and the 4-step iFFT
matmuls — stays in VMEM.  The Hermitian completion is folded into the
scatter itself: each kept rfft coefficient (value v at bin i) contributes

    spectrum[i]        += v          (direct)
    spectrum[4096 - i] += conj(v)    (mirror, interior bins 1..2047 only)

as a one-hot contraction over frequency tiles — no lane-axis flips, which
Mosaic lowers poorly; DC (0) and Nyquist (2048) are their own mirrors and
contribute once.  Padding slots (code 0 at index 0) decode to 0.0 and add
nothing, so payload widths padded to the 128-lane tile are harmless.

Numerics match the unfused three-stage path to f32 matmul-FFT tolerance
(tests/test_engine.py::test_fused_decompress_matches_unfused).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import fft4step
from repro.kernels.range_quant import decode_math
from repro.kernels.runtime import resolve_interpret

__all__ = ["fused_decompress_pallas"]

_K_TILE = 128
_F_TILE = 512
_CHUNK = fft4step.CHUNK
_NYQUIST = _CHUNK // 2


def _fused_decompress_body(params_ref, rec_ref, imc_ref, idx_ref,
                           fre_ref, fim_ref, wre_ref, wim_ref,
                           out_ref, *, m_bits: int, per_row: bool = False):
    if per_row:
        # batched-bucket mode: one quantizer fit per row (DESIGN.md §14)
        eps = params_ref[:, 0:1]  # (r, 1), broadcasts against (r, k) codes
        p_codes = params_ref[:, 1:2]
    else:
        eps = params_ref[0]
        p_codes = params_ref[1]
    m_scale = float(1 << m_bits)

    # 1. dequantize both code planes (stays in VMEM; shared quantizer math)
    re_k = decode_math(rec_ref[...].astype(jnp.float32), eps, p_codes, m_scale)
    im_k = decode_math(imc_ref[...].astype(jnp.float32), eps, p_codes, m_scale)
    idx = idx_ref[...].astype(jnp.float32)  # bins <= 2048: exact in f32
    r, k = re_k.shape

    # 2. Hermitian scatter: direct bin + conjugate mirror, tiled one-hot
    # contraction over the 4096 output bins.  Interior bins (1..2047) mirror
    # to 4096-i; DC/Nyquist map to themselves and must not double-count.
    interior = (idx >= 1.0) & (idx <= float(_NYQUIST - 1))
    mirror_idx = jnp.where(interior, float(_CHUNK) - idx, -1.0)  # -1: no slot

    full_re_tiles = []
    full_im_tiles = []
    n_tiles = pl.cdiv(_CHUNK, _F_TILE)
    for t in range(n_tiles):  # static unroll
        col = jax.lax.broadcasted_iota(jnp.float32, (1, 1, _F_TILE), 2) + t * _F_TILE
        direct = (idx[:, :, None] == col).astype(jnp.float32)  # (r, k, F_TILE)
        mirror = (mirror_idx[:, :, None] == col).astype(jnp.float32)
        full_re_tiles.append(jnp.sum(re_k[:, :, None] * (direct + mirror), axis=1))
        full_im_tiles.append(jnp.sum(im_k[:, :, None] * (direct - mirror), axis=1))
    full_re = jnp.concatenate(full_re_tiles, axis=-1)  # (r, 4096)
    full_im = jnp.concatenate(full_im_tiles, axis=-1)

    # 3. inverse 4-step FFT on the MXU; hermitian input -> real output
    out_re, _ = fft4step.apply_4step(
        full_re, full_im, fre_ref[...], fim_ref[...], wre_ref[...], wim_ref[...],
        inverse=True,
    )
    out_ref[...] = out_re


@functools.partial(jax.jit, static_argnames=("m_bits", "block_rows", "interpret"))
def fused_decompress_pallas(
    re_codes: jnp.ndarray,  # (rows, k) uint8/uint16 codes
    im_codes: jnp.ndarray,  # (rows, k)
    idx: jnp.ndarray,  # (rows, k) int16/int32 bin indices in [0, 2048]
    eps: jnp.ndarray,
    p_codes: jnp.ndarray,
    *,
    m_bits: int = 3,
    block_rows: int = 4,
    interpret: bool = None,
) -> jnp.ndarray:
    """Quantized payload planes -> (rows, 4096) f32 time-domain chunks.

    Accepts any payload width; pads to the 128-lane tile internally with
    code-0/index-0 slots (decode-neutral, see module docstring).

    ``eps``/``p_codes`` may be scalars (one fit for every row) or ``(rows,)``
    vectors (one fit per row — the batched bucket executor decompresses every
    bucket of a stacked payload in this one launch; DESIGN.md §14).
    """
    interpret = resolve_interpret(interpret)
    rows, k = re_codes.shape
    k_pad = max(_K_TILE, ((k + _K_TILE - 1) // _K_TILE) * _K_TILE)
    if k_pad != k:
        pad = [(0, 0), (0, k_pad - k)]
        re_codes = jnp.pad(re_codes, pad)
        im_codes = jnp.pad(im_codes, pad)
        idx = jnp.pad(idx, pad)
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    per_row = jnp.ndim(eps) == 1
    if per_row:
        params = jnp.zeros((rows, _K_TILE), jnp.float32)
        params = (params.at[:, 0].set(jnp.asarray(eps, jnp.float32))
                  .at[:, 1].set(p_codes.astype(jnp.float32)))
    else:
        params = jnp.stack([
            jnp.asarray(eps, jnp.float32),
            p_codes.astype(jnp.float32),
        ])
    fre, fim, wre, wim = (jnp.asarray(c)
                          for c in fft4step._dft_constants(inverse=True))
    const_spec = pl.BlockSpec((fft4step.N1, fft4step.N2), lambda i: (0, 0),
                              memory_space=pltpu.VMEM)
    data = lambda c: pl.BlockSpec((block_rows, c), lambda i: (i, 0),
                                  memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_fused_decompress_body, m_bits=m_bits,
                          per_row=per_row),
        grid=grid,
        in_specs=[data(_K_TILE) if per_row
                  else pl.BlockSpec(memory_space=pltpu.SMEM)]
        + [data(k_pad)] * 3 + [const_spec] * 4,
        out_specs=data(_CHUNK),
        out_shape=jax.ShapeDtypeStruct((rows, _CHUNK), jnp.float32),
        interpret=interpret,
    )(params, re_codes, im_codes, idx.astype(jnp.int32), fre, fim, wre, wim)
