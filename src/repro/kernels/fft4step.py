"""Pallas TPU kernel: 4096-point FFT via Bailey's 4-step algorithm on the MXU.

The paper leans on cuFFT.  TPUs have no FFT unit — but the MXU is a 128x128
systolic matmul array, and Bailey's 4-step factorization turns an N-point DFT
into sqrt(N) x sqrt(N) DFT *matmuls*:

    view x as a (64, 64) matrix  xm[n1, n2] = x[n1*64 + n2]
    A  = F64 @ xm                     (DFT along columns)        [stage 1]
    B  = A * W,  W[k1,n2] = w^(k1*n2) (twiddle, elementwise)     [stage 2]
    Xm = B @ F64^T                    (DFT along rows)           [stage 3]
    X[k2*64 + k1] = Xm[k1, k2]        (transpose read-out)       [stage 4]

Complex arithmetic is carried as separate real/imag planes (the MXU is real):
stage 1 on a real input costs 2 real 64x64 matmuls, stage 3 costs 4 — six
64x64x(64*B) matmuls per block of B chunks, batched along columns/rows so the
MXU sees well-shaped (64, 64*B) operands.

Napkin math (why this beats a "ported" radix-2 FFT on TPU): 4-step does
~6*2*64^3*B = 3.1 MFLOP per 4096-chunk vs ~0.25 MFLOP for radix-2 — 12x more
FLOPs — but runs on the MXU at 197 TFLOP/s(bf16)/~50(f32) with zero
shuffle/bit-reverse ops, vs the VPU's ~4 TFLOP/s with heavy lane crossings.
Net ≳ 4x, and the chunk never leaves VMEM.

The inverse uses conj twiddles + 1/N.  ``rfft`` semantics (first 2049 bins)
are applied by the ops.py wrapper; the kernel produces/consumes the full
4096-bin spectrum.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.runtime import resolve_interpret

__all__ = ["fft4096_pallas", "apply_4step", "CHUNK", "N1", "N2"]

CHUNK = 4096
N1 = 64
N2 = 64


@functools.lru_cache(maxsize=4)
def _dft_constants(inverse: bool):
    """(F64_re, F64_im, W_re, W_im) as float32 numpy arrays."""
    sign = 2.0 if inverse else -2.0
    k = np.arange(N1)[:, None]
    n = np.arange(N1)[None, :]
    f = np.exp(sign * 1j * np.pi * k * n / N1)
    k1 = np.arange(N1)[:, None]
    n2 = np.arange(N2)[None, :]
    w = np.exp(sign * 1j * np.pi * k1 * n2 / CHUNK)  # w^(k1*n2), w = e^(-+2*pi*i/N)
    return (
        f.real.astype(np.float32),
        f.imag.astype(np.float32),
        w.real.astype(np.float32),
        w.imag.astype(np.float32),
    )


def apply_4step(xre, xim, fre, fim, wre, wim, *, inverse: bool):
    """The 4-step DFT math on (b, 4096) re/im planes, VMEM-composable.

    Shared by the standalone FFT kernel body and the fused decompress kernel
    (``kernels/fused_decompress.py``), which runs it as the last stage of one
    VMEM-resident pass.  Returns (out_re, out_im), each (b, 4096).
    """
    b = xre.shape[0]  # chunks in this block

    # stage 0: matrix view — (b, 4096) -> (b, 64, 64) -> (64, b*64)
    xre = xre.reshape(b, N1, N2).transpose(1, 0, 2).reshape(N1, b * N2)
    xim = xim.reshape(b, N1, N2).transpose(1, 0, 2).reshape(N1, b * N2)

    # stage 1: A = F64 @ xm (complex x complex as 4 real matmuls)
    dot = functools.partial(jax.lax.dot, precision=jax.lax.Precision.HIGHEST)
    are = dot(fre, xre) - dot(fim, xim)
    aim = dot(fre, xim) + dot(fim, xre)

    # stage 2: twiddle — W broadcast over the b chunks along columns
    a_re = are.reshape(N1, b, N2)
    a_im = aim.reshape(N1, b, N2)
    w_re = wre[:, None, :]
    w_im = wim[:, None, :]
    bre = a_re * w_re - a_im * w_im
    bim = a_re * w_im + a_im * w_re

    # stage 3: Xm = B @ F64^T, batched along rows -> (b*64, 64)
    bre2 = bre.transpose(1, 0, 2).reshape(b * N1, N2)
    bim2 = bim.transpose(1, 0, 2).reshape(b * N1, N2)
    ft_re, ft_im = fre.T, fim.T
    xmre = dot(bre2, ft_re) - dot(bim2, ft_im)
    xmim = dot(bre2, ft_im) + dot(bim2, ft_re)

    # stage 4: transpose read-out X[k2*64 + k1] = Xm[k1, k2]
    xmre = xmre.reshape(b, N1, N2).transpose(0, 2, 1).reshape(b, CHUNK)
    xmim = xmim.reshape(b, N1, N2).transpose(0, 2, 1).reshape(b, CHUNK)
    scale = (1.0 / CHUNK) if inverse else 1.0
    return xmre * scale, xmim * scale


def _fft_body(fre_ref, fim_ref, wre_ref, wim_ref, xre_ref, xim_ref, ore_ref, oim_ref, *, inverse: bool):
    out_re, out_im = apply_4step(
        xre_ref[...], xim_ref[...], fre_ref[...], fim_ref[...],
        wre_ref[...], wim_ref[...], inverse=inverse,
    )
    ore_ref[...] = out_re
    oim_ref[...] = out_im


@functools.partial(jax.jit, static_argnames=("inverse", "block_chunks", "interpret"))
def fft4096_pallas(
    x_re: jnp.ndarray,
    x_im: jnp.ndarray,
    *,
    inverse: bool = False,
    block_chunks: int = 8,
    interpret: bool = None,
):
    """Batched 4096-pt complex FFT: (rows, 4096) re/im -> (rows, 4096) re/im.

    VMEM per block at block_chunks=8: 8*4096*4B*2(re,im)*3(live stages) ≈ 1.5MB
    — comfortably under the ~16MB/core budget, leaving room for double
    buffering.
    """
    interpret = resolve_interpret(interpret)
    rows, n = x_re.shape
    assert n == CHUNK, f"kernel is specialized to {CHUNK}-pt chunks"
    block_chunks = min(block_chunks, rows)
    grid = (pl.cdiv(rows, block_chunks),)
    fre, fim, wre, wim = (jnp.asarray(c) for c in _dft_constants(inverse))
    const_spec = pl.BlockSpec((N1, N2), lambda i: (0, 0), memory_space=pltpu.VMEM)
    data_spec = pl.BlockSpec((block_chunks, CHUNK), lambda i: (i, 0), memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_fft_body, inverse=inverse),
        grid=grid,
        in_specs=[const_spec] * 4 + [data_spec] * 2,
        out_specs=[data_spec] * 2,
        out_shape=[
            jax.ShapeDtypeStruct((rows, CHUNK), jnp.float32),
            jax.ShapeDtypeStruct((rows, CHUNK), jnp.float32),
        ],
        interpret=interpret,
    )(fre, fim, wre, wim, x_re.astype(jnp.float32), x_im.astype(jnp.float32))
