"""Reproduce the paper's Fig. 11 experiment shape: loss curves for dense vs
FFT-compressed training at several theta, including the paper's "mixed"
schedule (aggressive early, zero late) and the Theorem 3.5 schedule.

    PYTHONPATH=src python examples/convergence_paper.py --steps 80
"""

import argparse

import jax

from repro import jaxcompat as compat
from repro.comms.reducers import ReducerConfig
from repro.configs.base import ArchConfig
from repro.core import schedules
from repro.data import SyntheticConfig, SyntheticStream
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import LM
from repro.optim import OptConfig
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train.step import StepConfig

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=64, remat="none")


def run_variant(name, reducer_cfg, theta_schedule, steps):
    model = LM(TINY)
    opt = OptConfig(kind="adamw", lr=3e-3)
    mesh = make_local_mesh()
    stream = SyntheticStream(SyntheticConfig(vocab_size=64, seq_len=32,
                                             global_batch=8))
    mode = "pjit" if reducer_cfg is None else "compressed_dp"
    state = init_state(jax.random.PRNGKey(0), model, opt)
    with compat.set_mesh(mesh):
        out = train_loop(
            model, opt, StepConfig(mode=mode, reducer=reducer_cfg), mesh,
            state, stream,
            TrainLoopConfig(total_steps=steps, log_every=max(1, steps // 10),
                            theta_schedule=theta_schedule))
    curve = [(h["step"], round(h["loss"], 3)) for h in out["history"]]
    print(f"{name:24s} {curve}")
    return curve[-1][1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()

    r = lambda theta: ReducerConfig(kind="fft", axis="data", theta=theta)
    dense = run_variant("dense", None, None, args.steps)
    for theta in (0.3, 0.7, 0.9):
        run_variant(f"fft theta={theta}", r(theta), None, args.steps)
    run_variant("fft mixed 0.9->0", r(0.9),
                schedules.step_decay([(0, 0.9), (args.steps // 2, 0.0)]),
                args.steps)
    run_variant("fft thm3.5", r(0.5),
                schedules.thm35_schedule(1.0, lambda s: 0.3 / (1 + s) ** 0.5),
                args.steps)
    print(f"\ndense final loss: {dense} — per the paper, theta<=0.7 should "
          "match it and theta=0.9 should lag unless scheduled down.")


if __name__ == "__main__":
    main()
