"""Serve a small model with batched requests: prefill + token-by-token decode
with KV/SSM caches, greedy or sampled.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2_2b --requests 4
    PYTHONPATH=src python examples/serve_lm.py --arch xlstm_1_3b   # O(1)-state
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b", choices=registry.ARCH_NAMES)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch).reduced()
    model = registry.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(
        max_seq=args.prompt_len + args.new_tokens + 8,
        batch=args.requests, temperature=args.temperature))

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.requests, args.prompt_len), 0,
        cfg.vocab_size, jnp.int32)

    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new_tokens, key=jax.random.PRNGKey(2))
    dt = time.perf_counter() - t0
    total_new = args.requests * args.new_tokens
    print(f"arch={args.arch} (reduced): {args.requests} requests x "
          f"{args.new_tokens} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s incl. compile)")
    for i in range(min(2, args.requests)):
        print(f"request {i}: prompt={out[i, :args.prompt_len].tolist()[:8]}... "
              f"generated={out[i, args.prompt_len:].tolist()}")


if __name__ == "__main__":
    main()
