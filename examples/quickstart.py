"""Quickstart: compress a gradient with the paper's pipeline in 20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import FFTCompressor, FFTCompressorConfig, theory

# a gradient-like signal (paper Fig. 3: gradients are ~N(0, sigma), bounded)
grad = jax.random.normal(jax.random.PRNGKey(0), (1_000_000,)) * 0.05

# the paper's pipeline: rFFT -> drop theta of the spectrum -> range-based
# 8-bit quantization -> packed payload
comp = FFTCompressor(FFTCompressorConfig(theta=0.7, n_bits=8))
payload = jax.jit(comp.compress)(grad)
grad_hat = jax.jit(comp.decompress)(payload)

err, norm_ratio = theory.assumption31_stats(grad, grad_hat)
print(f"compression ratio : {comp.ratio(grad.size):.1f}x")
print(f"relative L2 error : {float(err):.3f}  (Assumption 3.1 needs <= theta)")
print(f"norm ratio        : {float(norm_ratio):.3f}  (needs <= 1)")
print(f"sign agreement    : {float(jnp.mean(jnp.sign(grad_hat) == jnp.sign(grad))):.3f}")
assert theory.assumption31_holds(grad, grad_hat, theta=0.7)
print("Assumption 3.1 holds — Theorem 3.4/3.5 convergence guarantees apply.")
