"""End-to-end driver: train a language model with compressed gradient
exchange for a few hundred steps on synthetic markov data.

CPU-sized default (a ~1M-param gemma2-family model); the SAME driver scales
to the production mesh — pass --arch/--mesh to launch/train.py directly:

    # a few hundred steps on CPU with the paper's reducer
    PYTHONPATH=src python examples/train_lm.py --steps 200

    # ~100M-param variant (slower on CPU; intended shape for a single host)
    PYTHONPATH=src python examples/train_lm.py --steps 200 --size 100m
"""

import argparse
import dataclasses

import jax

from repro.comms.reducers import ReducerConfig
from repro.core import schedules
from repro.data import SyntheticConfig, SyntheticStream
from repro.launch.mesh import make_local_mesh
from repro.models import registry
from repro.optim import OptConfig, lr_schedules
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train.step import StepConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--size", default="1m", choices=["1m", "10m", "100m"])
    ap.add_argument("--theta", type=float, default=0.7)
    ap.add_argument("--dense", action="store_true", help="no compression")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    base = registry.get_config("gemma2_2b").reduced()
    dims = {"1m": (64, 4, 128), "10m": (256, 4, 1024), "100m": (768, 12, 3072)}
    d, layers_mult, ff = dims[args.size]
    cfg = dataclasses.replace(
        base, d_model=d, d_ff=ff, n_layers=2 * layers_mult, vocab_size=2048,
        head_dim=max(16, d // 8), sliding_window=64)
    model = registry.build(cfg)
    from repro.models.sharding import count_params
    print(f"model: {count_params(model.spec())/1e6:.1f}M params")

    mesh = make_local_mesh()
    reducer = None if args.dense else ReducerConfig(
        kind="fft", axis="data", theta=args.theta)
    step_cfg = StepConfig(mode="pjit" if args.dense else "compressed_dp",
                          reducer=reducer)
    opt = OptConfig(kind="adamw", lr=1e-3)
    stream = SyntheticStream(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=128, global_batch=8))
    state = init_state(jax.random.PRNGKey(0), model, opt)
    loop_cfg = TrainLoopConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(25, args.steps // 4),
        log_every=max(1, args.steps // 25),
        lr_schedule=lr_schedules.warmup_cosine(10, args.steps),
        theta_schedule=None if args.dense else schedules.constant(args.theta),
    )
    with jax.set_mesh(mesh):
        out = train_loop(model, opt, step_cfg, mesh, state, stream, loop_cfg)
    hist = out["history"]
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"(markov entropy floor ~{stream.entropy_floor():.3f})")


if __name__ == "__main__":
    main()
