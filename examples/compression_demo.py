"""Walk the paper's Fig. 5 pipeline stage by stage and print what each does,
including the Pallas-kernel path (interpret mode on CPU).

    PYTHONPATH=src python examples/compression_demo.py
"""

import jax
import jax.numpy as jnp

from repro.core import fft as cfft
from repro.core import packing, sparsify
from repro.core.quantizer import RangeQuantConfig, fit_quantizer
from repro.kernels import ops

THETA = 0.7
grad = jax.random.normal(jax.random.PRNGKey(0), (8 * 4096,)) * 0.05
print(f"gradient: {grad.size} floats = {grad.size * 4 / 1e3:.0f} KB")

# 1. chunked rFFT (TPU: fft4step Pallas kernel — two 64x64 MXU matmuls)
freqs, n = cfft.chunked_rfft(grad)
print(f"1. rFFT -> {freqs.shape} complex bins per chunk")

# 2. theta-drop: keep top 30% of bins by weighted magnitude
k = sparsify.keep_count(freqs.shape[-1], THETA)
mag = jnp.abs(freqs) * cfft.hermitian_weights()
idx = sparsify.topk_select(mag, k)
kept = packing.pack_by_indices(freqs, idx)
dropped_energy = 1 - float((jnp.abs(kept) ** 2 * 2).sum() / (mag**2 / cfft.hermitian_weights()).sum())
print(f"2. sparsify theta={THETA}: keep {k}/{freqs.shape[-1]} bins")

# 3. range-based 8-bit quantization (paper Alg. 1)
q = fit_quantizer(jnp.real(kept).min(), jnp.real(kept).max(), RangeQuantConfig(8, 3))
re_codes = q.encode(jnp.real(kept))
im_codes = q.encode(jnp.imag(kept))
print(f"3. quantize: eps={float(q.eps):.2e}, P={int(q.p_codes)} positive codes")

# 4. wire size
wire = re_codes.size + im_codes.size + idx.size * 2
print(f"4. payload: {wire / 1e3:.0f} KB -> ratio {grad.size * 4 / wire:.1f}x")

# 5. reconstruct (receiver side, reverse order)
re = q.decode(re_codes).astype(jnp.float32)
im = q.decode(im_codes).astype(jnp.float32)
spectrum = packing.unpack_by_indices(re + 1j * im, idx, freqs.shape[-1])
grad_hat = cfft.chunked_irfft(spectrum, n)
rel = float(jnp.linalg.norm(grad - grad_hat) / jnp.linalg.norm(grad))
sign = float(jnp.mean(jnp.sign(grad_hat) == jnp.sign(grad)))
print(f"5. reconstruct: rel err {rel:.3f}, sign agreement {sign:.3f}")

# 6. the same pipeline through the Pallas TPU kernels (interpret mode here)
payload = ops.compress_chunks(grad.reshape(8, 4096), k, q)
grad_hat_k = ops.decompress_chunks(payload[0], payload[1], payload[2], q, n)
print(f"6. Pallas kernel path matches: "
      f"{float(jnp.max(jnp.abs(grad_hat_k - grad_hat))):.2e} max diff")
