"""Property tests for the versioned payload byte codec (core/bytecodec.py).

Round-trip law: ``from_bytes(to_bytes(p))`` reproduces every plane and
quantizer leaf BIT-FOR-BIT (the ring stores these blobs; a lossy codec here
would silently break the serve path's bitwise-replica guarantee), across
theta, bit widths, quantization on/off, monolithic and stacked payloads,
ragged bucket tails, and the backend spellings.  Malformed input never
crashes into numpy — every corruption fails as ``ValueError``.

``given``/``st`` come from tests/helpers.py: real hypothesis when installed,
a deterministic boundary-example runner otherwise.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from helpers import given, settings, st  # hypothesis or deterministic fallback

from repro.comms import bucketing
from repro.core import bytecodec
from repro.core.compressor import (
    FFTCompressor,
    FFTCompressorConfig,
    FFTPayload,
    StackedPayload,
)

CHUNK = 64


def _flat(n: int, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n,)).astype(np.float32))


def _comp(**kw):
    kw.setdefault("chunk", CHUNK)
    kw.setdefault("backend", "reference")
    return FFTCompressor(FFTCompressorConfig(**kw))


def _assert_payload_equal(a, b):
    assert type(a) is type(b)
    np.testing.assert_array_equal(np.asarray(a.re), np.asarray(b.re))
    np.testing.assert_array_equal(np.asarray(a.im), np.asarray(b.im))
    np.testing.assert_array_equal(np.asarray(a.idx), np.asarray(b.idx))
    assert np.asarray(a.re).dtype == np.asarray(b.re).dtype
    assert np.asarray(a.idx).dtype == np.asarray(b.idx).dtype
    assert a.chunk == b.chunk and a.has_im == b.has_im
    if a.quant is None:
        assert b.quant is None
    else:
        assert a.quant.config.n_bits == b.quant.config.n_bits
        assert a.quant.config.m_bits == b.quant.config.m_bits
        for leaf in ("eps", "p_codes", "vmax", "vmin"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a.quant, leaf)),
                np.asarray(getattr(b.quant, leaf)))


@settings(max_examples=20, deadline=None)
@given(theta=st.sampled_from([0.0, 0.5, 0.9]),
       n_bits=st.sampled_from([8, 12]),
       quantize=st.sampled_from([True, False]),
       tail=st.integers(1, 2 * CHUNK - 1))
def test_stacked_roundtrip_bitwise(theta, n_bits, quantize, tail):
    comp = _comp(theta=theta, n_bits=n_bits, quantize=quantize)
    total = 3 * 512 + tail  # last bucket ragged
    layout = bucketing.build_layout(total, 4 * 512, CHUNK)
    p = comp.compress_stacked(
        bucketing.stack_buckets(_flat(total, seed=tail), layout),
        layout.sizes())
    q = StackedPayload.from_bytes(p.to_bytes())
    _assert_payload_equal(p, q)
    assert q.sizes == p.sizes
    np.testing.assert_array_equal(np.asarray(comp.decompress_stacked(q)),
                                  np.asarray(comp.decompress_stacked(p)))


@settings(max_examples=20, deadline=None)
@given(theta=st.sampled_from([0.0, 0.7]),
       quantize=st.sampled_from([True, False]),
       n=st.integers(CHUNK, 5 * CHUNK + 17))
def test_monolithic_roundtrip_bitwise(theta, quantize, n):
    comp = _comp(theta=theta, quantize=quantize)
    p = comp.compress(_flat(n, seed=n))
    q = FFTPayload.from_bytes(p.to_bytes())
    _assert_payload_equal(p, q)
    assert q.orig_len == p.orig_len
    np.testing.assert_array_equal(np.asarray(comp.decompress(q)),
                                  np.asarray(comp.decompress(p)))


def test_backend_spellings_share_the_wire_format():
    """auto resolves per platform, but the blob layout is backend-free:
    whatever backend compressed it, any subscriber can decode it."""
    flat = _flat(4 * CHUNK)
    blobs = {}
    for backend in ("reference", "auto"):
        comp = _comp(theta=0.5, backend=backend)
        blobs[backend] = comp.compress(flat).to_bytes()
    decoded = {k: FFTPayload.from_bytes(v) for k, v in blobs.items()}
    ref = _comp(theta=0.5)
    np.testing.assert_array_equal(
        np.asarray(ref.decompress(decoded["reference"])),
        np.asarray(ref.decompress(decoded["auto"])))


def test_header_is_self_describing():
    p = _comp(theta=0.5).compress(_flat(3 * CHUNK))
    blob = p.to_bytes()
    assert blob[:4] == bytecodec.MAGIC
    hlen = int.from_bytes(blob[4:8], "little")
    import json

    header = json.loads(blob[8:8 + hlen])
    assert header["format_version"] == bytecodec.FORMAT_VERSION
    assert header["kind"] == "fft"
    assert {pl["name"] for pl in header["planes"]} >= {"re", "im", "idx"}


def test_malformed_blobs_raise_value_error():
    p = _comp(theta=0.5).compress(_flat(3 * CHUNK))
    blob = p.to_bytes()
    with pytest.raises(ValueError):
        bytecodec.from_bytes(b"XXXX" + blob[4:])  # wrong magic
    with pytest.raises(ValueError):
        bytecodec.from_bytes(blob[:len(blob) // 2])  # truncated planes
    with pytest.raises(ValueError):
        bytecodec.from_bytes(blob[:6])  # truncated header
    with pytest.raises(ValueError):
        StackedPayload.from_bytes(blob)  # kind mismatch (fft blob)
