"""MoE dispatch invariants + data-pipeline determinism properties."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from helpers import given, settings, st  # hypothesis or deterministic fallback

from repro.configs.base import ArchConfig
from repro.data import SyntheticConfig, SyntheticStream
from repro.models import moe as M
from repro.models.sharding import init_params

MOE_CFG = ArchConfig(
    name="moe_test", family="moe", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
    n_experts=4, experts_per_token=2, moe_group_size=16,
    moe_capacity_factor=2.0, remat="none",
)


def test_moe_identity_when_experts_equal():
    """With all experts identical and capacity ample, MoE == a single MLP
    (routing weights sum to 1 after top-k renormalization)."""
    spec = M.moe_spec(MOE_CFG)
    params = init_params(jax.random.PRNGKey(0), spec)
    # make every expert identical
    params = dict(params)
    for k in ("up", "down", "gate"):
        params[k] = jnp.broadcast_to(params[k][:1], params[k].shape)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    out, aux = M.moe_apply(params, x, MOE_CFG)

    from repro.models.layers import mlp
    dense = mlp({"up": params["up"][0], "down": params["down"][0],
                 "gate": params["gate"][0]}, x, "swiglu")
    np.testing.assert_allclose(np.array(out), np.array(dense), atol=2e-3)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= n_experts/top_k the dispatch cannot drop."""
    cfg = dataclasses.replace(MOE_CFG, moe_capacity_factor=2.0)
    spec = M.moe_spec(cfg)
    params = init_params(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 32), jnp.float32)
    out, _ = M.moe_apply(params, x, cfg)
    # every token must receive a nonzero combination (no fully dropped rows)
    norms = jnp.linalg.norm(out.reshape(-1, 32), axis=-1)
    assert bool(jnp.all(norms > 0))


def test_moe_aux_loss_balanced_at_uniform_routing():
    """Switch aux loss is minimized (=1) under perfectly uniform routing."""
    spec = M.moe_spec(MOE_CFG)
    params = init_params(jax.random.PRNGKey(0), spec)
    params = dict(params, router=jnp.zeros_like(params["router"]))  # uniform
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 32), jnp.float32)
    _, aux = M.moe_apply(params, x, MOE_CFG)
    assert abs(float(aux) - 1.0) < 0.05


# ---------------------------------------------------------------------------
# data pipeline determinism (fault-tolerance contract)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 10_000), seed=st.integers(0, 2**20))
def test_stream_is_pure_function_of_step(step, seed):
    cfg = SyntheticConfig(vocab_size=64, seq_len=16, global_batch=4, seed=seed)
    a = SyntheticStream(cfg).batch_at(step)
    b = SyntheticStream(cfg).batch_at(step)  # fresh instance, same result
    np.testing.assert_array_equal(np.array(a["tokens"]), np.array(b["tokens"]))
    c = SyntheticStream(cfg).batch_at(step + 1)
    assert not np.array_equal(np.array(a["tokens"]), np.array(c["tokens"]))


def test_markov_stream_is_learnable_structure():
    """Targets must be deterministic successors (up to branching choices)."""
    cfg = SyntheticConfig(vocab_size=64, seq_len=64, global_batch=4, branching=4)
    stream = SyntheticStream(cfg)
    batch = stream.batch_at(0)
    tok = np.array(batch["tokens"])
    tgt = np.array(batch["targets"])
    succ = stream._succ
    # every target is one of the 4 allowed successors of its token
    ok = np.isin(tgt, succ[tok]).mean()
    assert ok == 1.0
