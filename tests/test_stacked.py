"""Batched bucket executor (DESIGN.md §14): the stacked path must be a pure
EXECUTION-SHAPE change — payloads bitwise-equal to the per-bucket loop on
both engine backends, ragged tails exact through the padded matrix, one
collective per exchange instead of one per bucket, and a jit cache keyed on
layout + config so steady state is one executable launch."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import given, st, run_with_devices

from repro.comms import bucketing, cost_model as cm, executor
from repro.comms.transport import get_transport
from repro.core.compressor import (
    FFTCompressor,
    FFTCompressorConfig,
    StackedPayload,
    TimeDomainCompressor,
)

# 5 full chunks + a ragged tail: with 2-chunk buckets the layout is
# (2, 2, 1+tail) chunks — the last bucket is ragged AND wider than none,
# while a 3-chunk bucket target gives (3, 2+tail) — tail bucket NARROWER
# than the widest.  Both padding regimes are exercised below.
G = jax.random.normal(jax.random.PRNGKey(42), (5 * 4096 + 517,)) * 0.05


def _layout(bucket_chunks):
    return bucketing.build_layout(
        G.shape[0], None if bucket_chunks is None else bucket_chunks * 4096 * 4)


def _assert_payloads_bitwise(stacked: StackedPayload, looped):
    assert stacked.n_buckets == len(looped)
    for b, (sliced, ref) in enumerate(zip(stacked.bucket_payloads(), looped)):
        for plane in ("re", "im", "idx"):
            np.testing.assert_array_equal(
                np.asarray(getattr(sliced, plane)),
                np.asarray(getattr(ref, plane)),
                err_msg=f"bucket {b} plane {plane}")
        assert (sliced.orig_len, sliced.chunk, sliced.has_im) == (
            ref.orig_len, ref.chunk, ref.has_im)
        if ref.quant is None:
            assert sliced.quant is None
        else:
            # per-bucket fit: identical eps AND code split, not just close
            assert float(sliced.quant.eps) == float(ref.quant.eps), b
            assert int(sliced.quant.p_codes) == int(ref.quant.p_codes), b


@given(theta=st.sampled_from([0.5, 0.7, 0.9]),
       n_bits=st.sampled_from([4, 8]),
       bucket_chunks=st.sampled_from([1, 2, 3]))
def test_stacked_payloads_bitwise_equal_looped(theta, n_bits, bucket_chunks):
    """The tentpole contract: ONE batched compress of the stacked matrix
    emits, bucket for bucket, the exact payload bytes of the per-bucket loop
    — same codes, same indices, same per-bucket quantizer fits — on BOTH
    engine backends, across theta x n_bits x bucket granularity.

    Both sides run COMPILED (the executor's cached jit vs the loop jitted as
    one program): that is the only way either path executes in the system —
    transports and train steps are always jitted — and compiled-vs-eager
    comparisons of the SAME math already differ by 1 ulp in the quantizer
    fit's transcendentals, stacked or not."""
    layout = _layout(bucket_chunks)
    for backend in ("reference", "pallas"):
        for quantize in (True, False):
            comp = FFTCompressor(FFTCompressorConfig(
                theta=theta, n_bits=n_bits, quantize=quantize, backend=backend))
            _assert_payloads_bitwise(
                executor.compress_fn(comp, layout, donate=False)(G),
                executor.looped_compress_fn(comp, layout)(G))


def test_stacked_timedomain_payloads_bitwise_equal_looped():
    layout = _layout(2)
    comp = TimeDomainCompressor(FFTCompressorConfig(theta=0.7))
    sp = executor.compress_fn(comp, layout, donate=False)(G)
    assert sp.has_im is False and sp.im.shape[-1] == 0
    looped = jax.jit(lambda flat: [
        comp.compress(b) for b in bucketing.split_buckets(flat, layout)])(G)
    _assert_payloads_bitwise(sp, looped)


def test_ragged_tail_roundtrips_exactly_through_padded_matrix():
    """stack -> unstack is the identity, and the padded rows stay inert end
    to end: a ragged tail bucket decompresses bitwise-identically to its
    per-bucket decompress, and the padding region of the stacked
    reconstruction is exactly zero (padding slots decode to code 0)."""
    for bucket_chunks in (2, 3):
        layout = _layout(bucket_chunks)
        assert not layout.uniform  # the property under test needs a ragged tail
        stacked = bucketing.stack_buckets(G, layout)
        np.testing.assert_array_equal(
            np.asarray(bucketing.unstack_buckets(stacked, layout)),
            np.asarray(G))
        for backend in ("reference", "pallas"):
            comp = FFTCompressor(FFTCompressorConfig(theta=0.7, backend=backend))
            sp = executor.compress_fn(comp, layout, donate=False)(G)
            recon = np.asarray(jax.jit(comp.decompress_stacked)(sp))
            looped = jax.jit(lambda flat: [
                comp.decompress(p) for p in comp.compress_buckets(
                    bucketing.split_buckets(flat, layout))])(G)
            for b, (size, ref) in enumerate(zip(layout.sizes(), looped)):
                np.testing.assert_array_equal(
                    recon[b, :size], np.asarray(ref),
                    err_msg=f"{backend} bucket {b}")
                c_b = layout.chunk_counts()[b]
                # padding CHUNKS (all-zero rows) reconstruct to exact zeros
                np.testing.assert_array_equal(
                    recon[b, c_b * layout.chunk:], 0.0,
                    err_msg=f"{backend} bucket {b} padding")


def test_stacked_exchange_issues_one_collective_per_exchange():
    """The launch-count claim, asserted structurally: the traced stacked
    exchange contains a bucket-count-INDEPENDENT number of collectives (one
    per payload leaf), while the looped exchange scales with n_buckets."""
    from repro.jaxcompat import make_auto_mesh, shard_map as smap
    from jax.sharding import PartitionSpec as P

    mesh = make_auto_mesh((1,), ("data",))
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))

    def count(prim, transport_name, layout, stacked):
        transport = get_transport(transport_name)
        fn = smap(
            lambda flat: transport.exchange_flat(flat[0], layout, comp,
                                                 "data", stacked=stacked),
            mesh=mesh, in_specs=P("data"), out_specs=P())
        return str(jax.make_jaxpr(fn)(G[None])).count(prim)

    few, many = _layout(3), _layout(1)  # 2 vs 6 buckets
    for prim, transport_name in (("all_gather", "sequenced"), ("psum", "psum")):
        n_few_looped = count(prim, transport_name, few, stacked=False)
        n_many_looped = count(prim, transport_name, many, stacked=False)
        n_few = count(prim, transport_name, few, stacked=True)
        n_many = count(prim, transport_name, many, stacked=True)
        # looped: one collective per bucket (per payload leaf)
        assert n_many_looped > n_few_looped, (transport_name, n_few_looped,
                                              n_many_looped)
        # stacked: bucket-count independent, strictly fewer launches
        assert n_few == n_many, (transport_name, n_few, n_many)
        assert n_many < n_many_looped, (transport_name, n_many, n_many_looped)


def test_executor_jit_cache_keyed_on_config_and_layout():
    executor.clear_cache()
    layout = _layout(2)
    comp_a = FFTCompressor(FFTCompressorConfig(theta=0.7))
    comp_b = FFTCompressor(FFTCompressorConfig(theta=0.7))  # equal config
    # donate=False throughout: the shared module-level G is reused below (and
    # by other tests) — a donating executable would consume its buffer on
    # GPU/TPU backends
    fn = executor.compress_fn(comp_a, layout, donate=False)
    assert executor.compress_fn(comp_b, layout, donate=False) is fn  # value-keyed
    assert executor.cache_size() == 1
    assert executor.compress_fn(comp_a, _layout(1), donate=False) is not fn
    assert executor.compress_fn(
        FFTCompressor(FFTCompressorConfig(theta=0.9)), layout,
        donate=False) is not fn
    assert executor.cache_size() == 3
    # the cached executable produces the contract payloads (compared against
    # the compiled loop: jit-vs-eager runs of the SAME math differ by 1 ulp
    # in the quantizer fit's transcendentals, so the parity contract — like
    # the hot path itself — lives among compiled programs)
    _assert_payloads_bitwise(fn(G), executor.looped_compress_fn(comp_a, layout)(G))
    # end-to-end roundtrip matches the looped reconstruction bitwise
    rt = executor.roundtrip_fn(comp_a, layout, donate=False)(G)
    looped = jax.jit(lambda flat: jnp.concatenate([
        comp_a.decompress(p)
        for p in comp_a.compress_buckets(
            bucketing.split_buckets(flat, layout))]))(G)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(looped))
    executor.clear_cache()


def test_cost_model_prices_stacked_launch_once():
    kw = dict(workers=8, transport="psum", n_buckets=16)
    looped = cm.exchange_time_s(64 << 20, 8e7, cm.NETWORKS["tpu-dcn-host"],
                                cm.TPU_V5E, **kw)
    stacked = cm.exchange_time_s(64 << 20, 8e7, cm.NETWORKS["tpu-dcn-host"],
                                 cm.TPU_V5E, stacked=True, **kw)
    assert looped.n_collectives == 16 and stacked.n_collectives == 1
    assert looped.launch_s == pytest.approx(16 * cm.COLLECTIVE_ALPHA_S)
    assert stacked.launch_s == pytest.approx(cm.COLLECTIVE_ALPHA_S)
    # same wire volume either way; only launch count and overlap change
    assert stacked.wire_bits_per_worker == looped.wire_bits_per_worker
    # when alpha dominates (tiny payloads), stacked must win
    tiny_l = cm.exchange_time_s(4096, 1e4, cm.NETWORKS["tpu-dcn-host"],
                                cm.TPU_V5E, workers=8, transport="psum",
                                n_buckets=64)
    tiny_s = cm.exchange_time_s(4096, 1e4, cm.NETWORKS["tpu-dcn-host"],
                                cm.TPU_V5E, workers=8, transport="psum",
                                n_buckets=64, stacked=True)
    assert tiny_s.exchange_s < tiny_l.exchange_s


def test_cost_model_bills_stacked_padding_rows():
    """A ragged StackedPayload ships padding rows (uniform planes at the
    widest bucket's width); the model must bill those bytes.  Uniform
    layouts bill identically stacked or looped."""
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    ragged = [4096 * 3, 4096 * 3, 4096 * 2]  # padded rows: 3 chunks each
    looped = cm.bucketed_payload_bits(comp.wire_bits, ragged, "sequenced")
    stacked = cm.bucketed_payload_bits(comp.wire_bits, ragged, "sequenced",
                                       stacked=True)
    assert stacked == 3 * comp.wire_bits(4096 * 3)
    assert stacked > looped  # the tail bucket's padding chunk is on the wire
    uniform = [4096 * 2] * 4
    assert (cm.bucketed_payload_bits(comp.wire_bits, uniform, "psum",
                                     stacked=True)
            == cm.bucketed_payload_bits(comp.wire_bits, uniform, "psum"))
    # monolithic pricing is unaffected by the flag
    assert (cm.bucketed_payload_bits(comp.wire_bits, ragged, "allgather",
                                     stacked=True)
            == comp.wire_bits(sum(ragged)))


def test_reducer_stacked_equals_looped_bitwise_multidevice():
    """End to end on 4 fake workers: flipping ReducerConfig.stacked may not
    move a single bit of the reduced gradient or the EF residual, for every
    transport — the executor is a launch-count optimization, never a
    numerics choice."""
    out = run_with_devices("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.jaxcompat import make_auto_mesh, shard_map as smap
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((4,), ("data",))
n = 2 * 4096 + 173
grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, n)) * 0.1}

def run(cfg):
    r = make_reducer(cfg)
    f = smap(lambda g: r(jax.tree.map(lambda x: x[0], g)),
             mesh=mesh, in_specs=P("data"), out_specs=P())
    return np.asarray(jax.jit(f)(grads)["w"])

def run_ef(cfg):
    r = make_reducer(cfg)
    def step(g, res):
        out, new_res = r(jax.tree.map(lambda x: x[0], g), res[0])
        return out["w"], new_res[None]
    f = smap(step, mesh=mesh, in_specs=(P("data"), P("data")),
             out_specs=(P(), P("data")))
    res = jnp.zeros((4, n))
    outs = []
    for _ in range(2):
        got, res = jax.jit(f)(grads, res)
        outs.append(np.asarray(got))
    return outs, np.asarray(res)

for kind in ("fft", "timedomain"):
    for transport in ("allgather", "sequenced", "psum"):
        base = ReducerConfig(kind=kind, axis="data", theta=0.7, quantize=True,
                             transport=transport, bucket_bytes=4096 * 4)
        d = np.abs(run(base) - run(dataclasses.replace(base, stacked=False)))
        assert d.max() == 0.0, (kind, transport, d.max())

for transport in ("sequenced", "psum"):
    ef = ReducerConfig(kind="fft", axis="data", theta=0.7, quantize=True,
                       transport=transport, bucket_bytes=4096 * 4,
                       error_feedback=True)
    o_s, r_s = run_ef(ef)
    o_l, r_l = run_ef(dataclasses.replace(ef, stacked=False))
    for a, b in zip(o_s, o_l):
        assert np.array_equal(a, b), transport
    assert np.array_equal(r_s, r_l), transport
    assert np.linalg.norm(r_s) > 0.0  # EF is live through the stacked path
print("STACKED_REDUCER_OK")
""", devices=4)
    assert "STACKED_REDUCER_OK" in out
