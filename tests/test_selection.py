"""Selection-engine property tests (DESIGN.md §16).

The selection engine's contract, exercised on the adversarial inputs a
threshold selector can actually get wrong:

* the bisection invariant — every threshold selector returns a tau with
  ``count(mag >= tau) >= k``, on all-zero rows, single-element chunks,
  bitwise-tied rows, denormal rows, and heavy-tailed rows where the strided
  subsample is guaranteed to miss the mass;
* exact-k repair — ``count_compact`` always emits exactly ``k`` valid,
  strictly ascending, kept indices (payload shapes never depend on the
  selector), matching a naive numpy compaction bit for bit;
* accuracy — the sampled selector's end-to-end reconstruction error is
  never worse than the exact sort's beyond a small near-tau tolerance, on
  BOTH engine backends;
* parity — reference and pallas payloads stay bitwise-comparable for every
  selector (the kernels call the same ``core.selection`` math);
* structure — the sampled selector's traced compress contains no
  sort-family primitive (the O(n) property perf_smoke gates);
* config mirrors — every selector-validating surface (compressor config,
  reducer config, lab spec, launch CLI) accepts the same name set.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection, sparsify
from repro.core.compressor import FFTCompressor, FFTCompressorConfig

THRESHOLD_SELECTORS = ("bisect", "sampled")
DENORM = 2.0 ** -149  # smallest positive f32 denormal


def _rows(name):
    """Adversarial magnitude rows, (rows, cols) f32, by family name."""
    if name == "zero":
        return jnp.zeros((3, 640), jnp.float32)
    if name == "single":  # single-element chunks
        return jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (5, 1)))
    if name == "ties":  # every value bitwise-identical
        return jnp.full((2, 640), 0.25, jnp.float32)
    if name == "denormal":  # whole row below the normal range
        r = jax.random.randint(jax.random.PRNGKey(1), (2, 640), 1, 64)
        return (r.astype(jnp.float32) * DENORM).astype(jnp.float32)
    if name == "heavy_tail":  # one huge spike the subsample likely misses
        base = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (2, 640))) * 1e-6
        return base.at[:, 123].set(1e30)
    raise AssertionError(name)


def _k_for(mag):
    return max(1, mag.shape[-1] // 10)


@pytest.mark.parametrize("family", ["zero", "single", "ties", "denormal",
                                    "heavy_tail"])
@pytest.mark.parametrize("sel", THRESHOLD_SELECTORS)
def test_tau_invariant_and_exact_k(family, sel):
    mag = _rows(family)
    k = _k_for(mag)
    tau = selection.selector_tau(mag, k, sel)
    assert tau.shape == mag.shape[:-1] + (1,)
    # the invariant every selector must guarantee regardless of input
    count = np.asarray(jnp.sum(mag >= tau, axis=-1))
    assert (count >= k).all(), (family, sel, count)
    idx = selection.count_compact(mag, tau, k)
    assert idx.shape == mag.shape[:-1] + (k,)
    idx = np.asarray(idx)
    assert (0 <= idx).all() and (idx < mag.shape[-1]).all()
    # exactly k slots, strictly ascending (unique), all above threshold
    assert (np.diff(idx, axis=-1) > 0).all() or k == 1
    kept = np.take_along_axis(np.asarray(mag), idx, axis=-1)
    assert (kept >= np.asarray(tau)).all()


@pytest.mark.parametrize("family", ["zero", "ties", "denormal", "heavy_tail"])
def test_count_compact_matches_naive(family):
    mag = _rows(family)
    k = _k_for(mag)
    tau = selection.selector_tau(mag, k, "bisect")
    got = np.asarray(selection.count_compact(mag, tau, k))
    mask = np.asarray(mag >= tau)
    for r in range(mag.shape[0]):
        naive = np.nonzero(mask[r])[0][:k]  # index-ascending truncation
        np.testing.assert_array_equal(got[r], naive, err_msg=family)


def test_count_compact_shape_polymorphic():
    mag = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (2, 3, 640)))
    k = 64
    tau = selection.selector_tau(mag, k, "sampled")
    idx = selection.count_compact(mag, tau, k)
    assert idx.shape == (2, 3, 64)
    flat = selection.count_compact(
        mag.reshape(-1, 640), tau.reshape(-1, 1), k)
    np.testing.assert_array_equal(np.asarray(idx).reshape(-1, 64),
                                  np.asarray(flat))


def test_upper_bracket_properties():
    ub = jax.jit(selection.upper_bracket)
    # at/below the denormal range the step is nextafter on IEEE-strict
    # hardware but may FLUSH TO ZERO on FTZ hosts (XLA CPU does) — either
    # way the selector invariant survives, which the adversarial-family
    # tests above assert directly; here only pin the two allowed outcomes
    assert float(ub(jnp.float32(0.0))) in (0.0, DENORM)
    assert float(ub(jnp.float32(DENORM))) in (0.0, DENORM, 2 * DENORM)
    # FLT_MAX clamps (never inf: bisection must terminate)
    assert float(ub(jnp.float32(selection.FLT_MAX))) == selection.FLT_MAX
    # in the normal range it IS nextafter-to-+inf
    xs = np.float32([1.2e-38, 0.1, 1.0, 3.5e4, 1e30])
    np.testing.assert_array_equal(
        np.asarray(ub(jnp.asarray(xs))),
        np.nextafter(xs, np.float32(np.inf), dtype=np.float32))


def test_strided_sample_is_static_slice():
    mag = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (2, 2049)))
    s = selection.strided_sample(mag, 1.0 / 64.0, seed=0)
    assert s.shape == (2, 32)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(mag)[:, 0:-1:64])
    # the seed rotates the phase, never the sample size
    s1 = selection.strided_sample(mag, 1.0 / 64.0, seed=1)
    assert s1.shape == s.shape
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(mag)[:, 1::64])


def test_resolve_selector_auto_policy():
    assert selection.resolve_selector("auto", 2049) == "sampled"
    assert selection.resolve_selector(
        "auto", selection.AUTO_SAMPLED_MIN_COLS - 1) == "sort"
    for name in selection.SELECTOR_NAMES:
        assert selection.resolve_selector(name, 2049) in (
            "sort", "sampled", "bisect")
    with pytest.raises(ValueError):
        selection.resolve_selector("bucket", 2049)


def test_topk_mask_tie_semantics():
    # tie-free: exactly k kept (the seed contract, still guarded by
    # test_sparsify_packing); under bitwise ties the tau mask honestly keeps
    # every tied coefficient rather than an arbitrary subset
    tied = jnp.float32([[5.0, 1.0, 1.0, 1.0, 0.5]])
    mask = sparsify.topk_mask(tied, 2)
    assert int(mask.sum()) == 4  # 5.0 plus all three tied 1.0s
    assert bool(mask[0, 0]) and not bool(mask[0, 4])


G = jax.random.normal(jax.random.PRNGKey(42), (3 * 4096 + 517,)) * 0.05


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_sampled_error_bounded_by_sort(backend):
    err = {}
    for sel in ("sort", "sampled", "bisect"):
        comp = FFTCompressor(FFTCompressorConfig(
            theta=0.7, backend=backend, selector=sel))
        ghat = np.asarray(comp.decompress(jax.jit(comp.compress)(G)))
        err[sel] = float(np.linalg.norm(np.asarray(G) - ghat)
                         / np.linalg.norm(np.asarray(G)))
    # bisect picks the same set as sort (exact threshold); sampled may trade
    # a few near-tau coefficients — bounded, never catastrophic
    assert err["bisect"] <= err["sort"] + 1e-3, err
    assert err["sampled"] <= err["sort"] + 0.05, err


@pytest.mark.parametrize("sel", ["sort", "sampled", "bisect", "auto"])
def test_cross_backend_payload_parity(sel):
    ref = FFTCompressor(FFTCompressorConfig(
        theta=0.7, backend="reference", selector=sel))
    pal = FFTCompressor(FFTCompressorConfig(
        theta=0.7, backend="pallas", selector=sel))
    p_ref = jax.jit(ref.compress)(G)
    p_pal = jax.jit(pal.compress)(G)
    order_r = np.argsort(np.asarray(p_ref.idx), axis=-1, kind="stable")
    order_p = np.argsort(np.asarray(p_pal.idx), axis=-1, kind="stable")
    for plane_r, plane_p, what in (
            (p_ref.idx, p_pal.idx, "idx"),
            (p_ref.re, p_pal.re, "re"),
            (p_ref.im, p_pal.im, "im")):
        np.testing.assert_array_equal(
            np.take_along_axis(np.asarray(plane_r), order_r, axis=-1),
            np.take_along_axis(np.asarray(plane_p), order_p, axis=-1),
            err_msg=f"{sel}: {what} codes diverge across backends")
    assert float(p_ref.quant.eps) == float(p_pal.quant.eps)


def test_sampled_compress_is_sort_free():
    """The tentpole's structural claim: no sort-family primitive anywhere in
    the sampled selector's traced compress (mirrors perf_smoke's
    deterministic fallback, kept here so plain pytest catches it too)."""
    sort_family = {"sort", "top_k", "approx_top_k"}

    def prims(jaxpr, acc):
        for eqn in jaxpr.eqns:
            acc.add(eqn.primitive.name)
            for v in eqn.params.values():
                for w in (v if isinstance(v, (list, tuple)) else (v,)):
                    if hasattr(w, "eqns"):
                        prims(w, acc)
                    elif hasattr(w, "jaxpr"):
                        prims(w.jaxpr, acc)
        return acc

    g = jax.ShapeDtypeStruct((1 << 16,), jnp.float32)
    sampled = FFTCompressor(FFTCompressorConfig(theta=0.7, selector="sampled"))
    found = prims(jax.make_jaxpr(sampled.compress)(g).jaxpr, set())
    assert not (found & sort_family), sorted(found & sort_family)
    sort = FFTCompressor(FFTCompressorConfig(theta=0.7, selector="sort"))
    found = prims(jax.make_jaxpr(sort.compress)(g).jaxpr, set())
    assert found & sort_family  # else the comparison above proves nothing


def test_selector_name_mirrors():
    """Every selector-validating surface accepts the same name set; a new
    selector added to core.selection must be threaded everywhere."""
    from repro.comms.reducers import ReducerConfig
    from repro.lab.spec import ExperimentSpec

    for name in selection.SELECTOR_NAMES:
        FFTCompressorConfig(selector=name)
        ReducerConfig(kind="fft", axis="data", selector=name)
        ExperimentSpec(name="t", model="lm", reducer="fft", selector=name)
    for bad in ("bucket", "topk", ""):
        with pytest.raises(ValueError):
            FFTCompressorConfig(selector=bad)
        with pytest.raises(ValueError):
            ReducerConfig(kind="fft", axis="data", selector=bad)
        with pytest.raises(ValueError):
            ExperimentSpec(name="t", model="lm", reducer="fft", selector=bad)
    # the launch CLI exposes the same choices (argparse is built inline, so
    # guard the source: cheap, and drift fails loudly here)
    import inspect

    from repro.launch import train

    src = inspect.getsource(train)
    for name in selection.SELECTOR_NAMES:
        assert f'"{name}"' in src, f"launch CLI lost selector {name!r}"


def test_lab_matrix_has_sampled_row():
    from repro.lab.spec import smoke_matrix

    names = {s.name for s in smoke_matrix()}
    assert any(n.endswith("_fft_theta0.7_sampled") for n in names), names
