"""Resilience layer (DESIGN.md §19): typed fault plans, payload validation,
the non-finite step guard, the degradation ladder, and corruption-detecting
checkpoints.  Multi-worker behaviour (guard agreement, crash auto-resume)
runs on fake CPU devices in a subprocess."""

import dataclasses
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_with_devices

from repro.comms import faults
from repro.comms.reducers import ReducerConfig, degrade_config
from repro.core.compressor import FFTCompressor, FFTCompressorConfig
from repro.train import checkpoint as ckpt

# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------


def test_fault_plan_roundtrip_and_hashable():
    plan = faults.FaultPlan(events=(
        faults.NanGrad(step=3, worker=2),
        faults.PayloadCorrupt(step=5, worker=0, plane="values"),
        faults.StepCrash(step=7, fatal=True),
        faults.SlowWorker(step=9, worker=1, delay_s=0.01),
    ))
    dicts = plan.to_dicts()
    json.loads(json.dumps(dicts))  # JSON-serializable
    assert faults.FaultPlan.from_dicts(dicts) == plan
    hash(plan)  # frozen ReducerConfigs carry the plan into jit cache keys
    assert faults.FaultPlan.from_dicts(None) is None
    assert faults.FaultPlan.from_dicts([]) is None


def test_fault_plan_selectors():
    plan = faults.FaultPlan(events=(
        faults.NanGrad(step=3, worker=2),
        faults.StepCrash(step=7),
        faults.StepCrash(step=7, fatal=True),
        faults.SlowWorker(step=9, worker=1, delay_s=0.25),
    ))
    assert len(plan.nan_events) == 1
    assert plan.has_exchange_faults
    assert [i for i, _ in plan.crashes_at(7)] == [1, 2]
    assert plan.crashes_at(3) == []
    assert plan.delay_at(9) == pytest.approx(0.25)
    assert plan.delay_at(0) == 0.0


def test_fault_events_reject_bad_input():
    with pytest.raises(ValueError):
        faults.PayloadCorrupt(step=1, worker=0, plane="imaginary")
    with pytest.raises(TypeError):
        faults.FaultPlan(events=("not-an-event",))
    with pytest.raises(ValueError):
        faults.FaultPlan.from_dicts([{"kind": "meteor_strike", "step": 1}])


def test_spec_mirrors_agree_with_faults_module():
    """lab/spec.py stays jax-free, so it mirrors the validate levels and
    event kinds; the mirrors must never drift from the real registry."""
    from repro.lab import spec as lab_spec

    assert lab_spec._VALIDATE_LEVELS == faults.VALIDATE_LEVELS
    assert tuple(sorted(lab_spec._EVENT_KINDS)) == tuple(
        sorted(faults.EVENT_KINDS))


def test_match_events_is_traced_and_exact():
    events = (faults.NanGrad(step=3, worker=2),)

    def f(step, worker):
        return faults.match_events(events, step, worker)

    hit = jax.jit(f)(jnp.int32(3), jnp.int32(2))
    miss_step = jax.jit(f)(jnp.int32(4), jnp.int32(2))
    miss_worker = jax.jit(f)(jnp.int32(3), jnp.int32(1))
    assert bool(hit) and not bool(miss_step) and not bool(miss_worker)


# ---------------------------------------------------------------------------
# payload validation + corruption
# ---------------------------------------------------------------------------


def _payload(quantize=True):
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7, quantize=quantize))
    g = jnp.sin(jnp.arange(4096) / 30.0) * 0.1
    return comp.compress(g)


def test_validation_levels_on_clean_payload():
    p = _payload()
    assert bool(faults.validate_payload(p, "off"))
    assert bool(faults.validate_payload(p, "cheap"))
    ref = faults.payload_checksums(p)
    assert bool(faults.validate_payload(p, "full", reference_checksums=ref))
    with pytest.raises(ValueError):
        faults.validate_payload(p, "paranoid")


def test_cheap_validation_catches_index_and_quant_corruption():
    p = _payload()
    hit = jnp.bool_(True)
    bad_idx = faults.corrupt_payload(p, {"idx": hit})
    assert not bool(faults.validate_payload(bad_idx, "cheap"))
    bad_quant = faults.corrupt_payload(p, {"quant": hit})
    assert not bool(faults.validate_payload(bad_quant, "cheap"))


def test_value_corruption_is_silent_until_full_checksums():
    """Mantissa bit-flips decode to finite floats: cheap validation cannot
    see them, the full checksums must."""
    p = _payload(quantize=False)
    ref = faults.payload_checksums(p)
    bad = faults.corrupt_payload(p, {"values": jnp.bool_(True)})
    assert not np.array_equal(np.asarray(bad.re), np.asarray(p.re))
    assert bool(faults.validate_payload(bad, "cheap"))  # silent at cheap
    assert not bool(
        faults.validate_payload(bad, "full", reference_checksums=ref))


def test_corruption_miss_is_identity():
    p = _payload()
    out = faults.corrupt_payload(p, {"idx": jnp.bool_(False),
                                     "values": jnp.bool_(False)})
    np.testing.assert_array_equal(np.asarray(out.idx), np.asarray(p.idx))
    np.testing.assert_array_equal(np.asarray(out.re), np.asarray(p.re))


def test_exchange_monitor_injects_and_accumulates():
    p = _payload()
    corrupt = (faults.PayloadCorrupt(step=3, worker=1, plane="idx"),)
    # event hits this (step, worker): verdict goes false
    mon = faults.ExchangeMonitor("cheap", step=jnp.int32(3),
                                 worker=jnp.int32(1), corrupt=corrupt)
    mon.on_payload(p)
    assert not bool(mon.ok())
    # different worker: payload untouched, verdict stays true
    mon2 = faults.ExchangeMonitor("cheap", step=jnp.int32(3),
                                  worker=jnp.int32(0), corrupt=corrupt)
    out = mon2.on_payload(p)
    assert bool(mon2.ok())
    np.testing.assert_array_equal(np.asarray(out.idx), np.asarray(p.idx))


def test_tree_finite():
    assert bool(faults.tree_finite({"a": jnp.ones(3), "b": jnp.arange(3)}))
    assert not bool(faults.tree_finite({"a": jnp.array([1.0, jnp.nan])}))
    assert not bool(faults.tree_finite({"a": jnp.array([jnp.inf])}))


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------


def test_degrade_config_walks_every_rung():
    cfg = ReducerConfig(kind="fft", axis="data", theta=0.7, backend="pallas",
                        transport="sequenced", bucket_bytes=4096 * 4,
                        schedule="streamed", error_feedback=True,
                        validate="cheap")
    labels = []
    while True:
        rung = degrade_config(cfg)
        if rung is None:
            break
        cfg, label = rung
        labels.append(label)
    assert labels == ["backend:pallas->reference",
                      "schedule:streamed->stacked",
                      "kind:fft->dense"]
    # terminal rung: dense, no EF, validation off — and nowhere further
    assert cfg.kind == "dense" and not cfg.error_feedback
    assert cfg.validate == "off"
    assert degrade_config(cfg) is None


def test_degrade_config_retires_exotic_transports():
    cfg = ReducerConfig(kind="fft", axis=("node", "local"),
                        transport="hierarchical", theta=0.7)
    cfg2, label = degrade_config(cfg)
    assert label == "transport:hierarchical->psum"
    assert cfg2.transport == "psum"


def test_degraded_dense_config_is_not_resilient():
    """The dense rung keeps the FaultPlan (for the record) but must opt out
    of the resilient reduce contract — dense exchanges ship no payloads."""
    plan = faults.FaultPlan(events=(
        faults.PayloadCorrupt(step=1, worker=0),))
    cfg = ReducerConfig(kind="fft", axis="data", theta=0.7, validate="cheap",
                        faults=plan)
    assert cfg.resilient
    dense, _ = degrade_config(cfg)
    assert dense.faults == plan and not dense.resilient


# ---------------------------------------------------------------------------
# checkpoint verification + async writer
# ---------------------------------------------------------------------------


def _state(v=1.0):
    return {"params": {"w": jnp.full((32,), v)}, "step": jnp.int32(7)}


def test_checkpoint_digest_mismatch_falls_back(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 5, _state(1.0))
    ckpt.save(d, 10, _state(2.0))
    # corrupt the newest checkpoint's arrays behind the manifest's back
    path = os.path.join(d, "step_00000010", "arrays.npz")
    with np.load(path) as data:
        arrays = {k: data[k].copy() for k in data.files}
    k = next(k for k in arrays if arrays[k].dtype.kind == "f")
    arrays[k].flat[0] += 1.0  # bit rot
    np.savez(path, **arrays)
    with pytest.warns(UserWarning, match="failed verification"):
        state, step = ckpt.restore(d, _state())
    assert step == 5
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.full((32,), 1.0))
    # explicitly requesting the corrupt step must raise, not fall back
    with pytest.raises(ckpt.CheckpointError):
        ckpt.restore(d, _state(), step=10)


def test_writer_death_mid_write_leaves_prior_step(tmp_path):
    """A .tmp directory from a dead writer is invisible: latest_step and
    restore resume from the last COMPLETE checkpoint."""
    d = str(tmp_path / "ck")
    ckpt.save(d, 5, _state(1.0))
    # simulate a writer killed between makedirs and rename
    torn = os.path.join(d, "step_00000010.tmp")
    os.makedirs(torn)
    with open(os.path.join(torn, "arrays.npz"), "wb") as f:
        f.write(b"partial garbage")
    assert ckpt.latest_step(d) == 5
    state, step = ckpt.restore(d, _state())
    assert step == 5


def test_latest_step_ignores_stray_names(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, _state())
    os.makedirs(os.path.join(d, "step_"), exist_ok=True)
    os.makedirs(os.path.join(d, "lost+found"), exist_ok=True)
    with open(os.path.join(d, "step_00000099"), "w") as f:
        f.write("a FILE named like a checkpoint")
    assert ckpt.latest_step(d) == 3


def test_async_save_is_joined_before_reads(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _state(1.0), block=False)
    # restore joins the in-flight writer — no race, fresh data
    state, step = ckpt.restore(d, _state())
    assert step == 1
    ckpt.save(d, 2, _state(2.0), block=False)
    ckpt.wait()
    assert ckpt.latest_step(d) == 2


def test_async_save_serializes_with_next_save(tmp_path):
    """Back-to-back async saves must not interleave their write/rename."""
    d = str(tmp_path / "ck")
    for i in range(1, 6):
        ckpt.save(d, i, _state(float(i)), block=False)
    ckpt.wait()
    state, step = ckpt.restore(d, _state())
    assert step == 5
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.full((32,), 5.0))


def test_restore_raises_when_nothing_verifiable(tmp_path):
    d = str(tmp_path / "ck")
    with pytest.raises(FileNotFoundError):
        ckpt.restore(d, _state())
    ckpt.save(d, 5, _state())
    shutil.rmtree(os.path.join(d, "step_00000005"))
    os.makedirs(os.path.join(d, "step_00000005"))  # complete-looking, empty
    with pytest.raises(ckpt.CheckpointError):
        ckpt.restore(d, _state())


# ---------------------------------------------------------------------------
# train-loop recovery semantics (host-side, single device)
# ---------------------------------------------------------------------------


def test_loop_without_checkpoint_surfaces_original_error():
    """When every retry fails before any checkpoint exists, the ORIGINAL
    step error must surface — not a FileNotFoundError from a hopeless
    restore."""
    from repro.configs.base import ArchConfig
    from repro.data import SyntheticConfig, SyntheticStream
    from repro.launch.mesh import make_local_mesh
    from repro.models.transformer import LM
    from repro.optim import OptConfig
    from repro.train import TrainLoopConfig, init_state, train_loop
    from repro.train.step import StepConfig
    from repro import jaxcompat as compat

    tiny = ArchConfig(name="tiny", family="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=64, remat="none")
    model = LM(tiny)
    opt = OptConfig(kind="sgd")
    stream = SyntheticStream(SyntheticConfig(
        vocab_size=64, seq_len=16, global_batch=4))
    mesh = make_local_mesh()
    # three planned crashes at step 0, one per attempt: retries exhaust
    # before any checkpoint exists and pjit mode has no ladder to walk
    plan = faults.FaultPlan(events=tuple(
        faults.StepCrash(step=0) for _ in range(3)))
    with compat.set_mesh(mesh):
        with pytest.raises(faults.InjectedCrash):
            train_loop(model, opt, StepConfig(mode="pjit"), mesh,
                       init_state(jax.random.PRNGKey(0), model, opt), stream,
                       TrainLoopConfig(total_steps=4, max_retries=1,
                                       faults=plan))


# ---------------------------------------------------------------------------
# multi-worker guard + crash auto-resume (fake devices, subprocess)
# ---------------------------------------------------------------------------


def test_guard_skips_and_crash_resumes_on_fake_devices():
    """4 fake devices, compressed exchange with error feedback:

    * a NaN gradient on ONE worker skips exactly that step everywhere
      (params, moments, EF residual quarantined), bitwise-clean before it;
    * a fatal injected crash + harness restart resumes from the last
      checkpoint and lands bitwise-identical to the uninterrupted run.
    """
    out = run_with_devices("""
import tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig
from repro.comms.faults import FatalInjectedCrash, FaultPlan, NanGrad, StepCrash
from repro.comms.reducers import ReducerConfig
from repro.data import SyntheticConfig, SyntheticStream
from repro.models.transformer import LM
from repro.optim import OptConfig
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train.step import StepConfig
from repro.jaxcompat import make_auto_mesh, set_mesh

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=64, remat="none")
mesh = make_auto_mesh((4,), ("data",))
model = LM(TINY)
opt = OptConfig(kind="adamw", lr=3e-3)
stream = SyntheticStream(SyntheticConfig(vocab_size=64, seq_len=32, global_batch=8))

def run(plan, steps=10, ckpt_dir=None, ckpt_every=50):
    rc = ReducerConfig(kind="fft", axis="data", theta=0.5,
                       error_feedback=True, faults=plan)
    recs = []
    loop_cfg = TrainLoopConfig(total_steps=steps, log_every=100,
                               ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                               faults=plan,
                               metrics_hook=lambda s, m, st: recs.append(dict(m)))
    state = init_state(jax.random.PRNGKey(0), model, opt, error_feedback=True)
    with set_mesh(mesh):
        while True:
            try:
                out = train_loop(model, opt,
                                 StepConfig(mode="compressed_dp", reducer=rc),
                                 mesh, state, stream, loop_cfg)
                break
            except FatalInjectedCrash:
                state = init_state(jax.random.PRNGKey(0), model, opt,
                                   error_feedback=True)
    last = {r["step"]: r for r in recs}
    return out, [last[s] for s in sorted(last)]

clean, crecs = run(None)

# --- non-finite guard: nan on worker 2 at step 3 ---
nan_plan = FaultPlan(events=(NanGrad(step=3, worker=2),))
faulty, frecs = run(nan_plan)
skips = [r["step"] for r in frecs if r["skipped"] > 0]
assert skips == [3], skips
assert faulty["health"]["skip_steps"] == [3], faulty["health"]
for s in range(3):
    assert crecs[s]["loss"] == frecs[s]["loss"], (s, crecs[s], frecs[s])
cl, fl = crecs[-1]["loss"], frecs[-1]["loss"]
assert abs(fl - cl) <= 0.05 * abs(cl) + 0.05, (cl, fl)

# --- fatal crash at step 6, checkpoint every 2, auto-resume: bitwise ---
crash_plan = FaultPlan(events=(StepCrash(step=6, fatal=True),))
with tempfile.TemporaryDirectory() as d:
    crashed, krecs = run(crash_plan, ckpt_dir=d, ckpt_every=2)
assert crashed["health"]["skipped_steps"] == 0
assert len(krecs) == len(crecs)
for a, b in zip(crecs, krecs):
    assert a["loss"] == b["loss"], (a, b)
print("RESILIENCE_OK", skips, cl, fl)
""", devices=4, timeout=560)
    assert "RESILIENCE_OK" in out


# ---------------------------------------------------------------------------
# resilient reducer contract (single device)
# ---------------------------------------------------------------------------


def test_reducer_signature_unchanged_when_not_resilient():
    """validate='off' with no exchange faults keeps the historical reducer
    signatures — resilience must cost nothing when off."""
    cfg = ReducerConfig(kind="fft", axis="data", theta=0.7)
    assert not cfg.resilient
    plan = faults.FaultPlan(events=(faults.StepCrash(step=1),))
    host_only = dataclasses.replace(cfg, faults=plan)
    assert not host_only.resilient  # crash events are host-side
    assert dataclasses.replace(cfg, validate="cheap").resilient
    nan_plan = faults.FaultPlan(events=(faults.NanGrad(step=1, worker=0),))
    assert not dataclasses.replace(cfg, faults=nan_plan).resilient
    corrupt = faults.FaultPlan(events=(
        faults.PayloadCorrupt(step=1, worker=0),))
    assert dataclasses.replace(cfg, faults=corrupt).resilient


def test_reducer_config_rejects_bad_resilience_args():
    with pytest.raises(ValueError):
        ReducerConfig(kind="fft", axis="data", validate="sometimes")
    with pytest.raises(TypeError):
        ReducerConfig(kind="fft", axis="data",
                      faults=[{"kind": "nan_grad", "step": 1, "worker": 0}])
