"""Transport equivalence across 8 fake devices: every transport must produce
the same mean as the seed all_gather path (ISSUE 1 acceptance), including the
error-feedback and hierarchical modes.

With quantization OFF the bucketed paths are bit-identical to the monolithic
seed path (chunk-aligned bucket boundaries keep per-chunk top-k selection
unchanged; FFT linearity keeps the means equal), so the comparison is exact
up to f32 reduction order.  With quantization ON, per-bucket quantizer fits
differ from the global fit, so agreement is within quantization tolerance.
"""

from helpers import run_with_devices

SMAP_COMPAT = """
import jax
from repro.jaxcompat import make_auto_mesh, shard_map as smap
"""


def test_all_transports_match_seed_allgather_mean():
    out = run_with_devices(SMAP_COMPAT + """
import dataclasses
import jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((8,), ("data",))
grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 3 * 4096 + 173)) * 0.1,
         "b": jax.random.normal(jax.random.PRNGKey(1), (8, 64)) * 0.1}
dense = jax.tree.map(lambda x: np.asarray(x.mean(0)), grads)

def run(cfg):
    r = make_reducer(cfg)
    f = smap(lambda g: r(jax.tree.map(lambda x: x[0], g)),
             mesh=mesh, in_specs=P("data"), out_specs=P())
    return jax.tree.map(np.asarray, jax.jit(f)(grads))

def flat(t):
    return np.concatenate([np.ravel(t[k]) for k in sorted(t)])

for kind in ("fft", "timedomain"):
    # seed path: monolithic all_gather, no bucketing
    seed_cfg = ReducerConfig(kind=kind, axis="data", theta=0.5, quantize=False)
    seed = run(seed_cfg)
    for transport in ("allgather", "sequenced", "psum"):
        got = run(dataclasses.replace(seed_cfg, transport=transport,
                                      bucket_bytes=4096 * 4))
        err = np.abs(flat(got) - flat(seed)).max()
        assert err < 1e-5, (kind, transport, err)
    # quantized: per-bucket fits agree with the global fit within quant tol
    seed_q = run(dataclasses.replace(seed_cfg, quantize=True))
    for transport in ("sequenced", "psum"):
        got = run(dataclasses.replace(seed_cfg, quantize=True,
                                      transport=transport, bucket_bytes=4096 * 4))
        rel = (np.linalg.norm(flat(got) - flat(seed_q))
               / np.linalg.norm(flat(seed_q)))
        assert rel < 0.1, (kind, transport, rel)
    # and every transport still approximates the dense mean (Assumption 3.1)
    rel_dense = (np.linalg.norm(flat(seed) - flat(dense))
                 / np.linalg.norm(flat(dense)))
    assert rel_dense < 0.5 ** 0.5 + 1e-3, (kind, rel_dense)
print("TRANSPORTS_OK")
""")
    assert "TRANSPORTS_OK" in out


def test_error_feedback_identical_across_transports():
    out = run_with_devices(SMAP_COMPAT + """
import dataclasses
import jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((4,), ("data",))
n = 2 * 4096 + 301
g = {"w": jnp.tile(jnp.sin(jnp.arange(n) / 50.0)[None] * 0.1, (4, 1))}

def run_ef(cfg):
    r = make_reducer(cfg)
    def step(grads, res):
        out, new_res = r(jax.tree.map(lambda x: x[0], grads), res[0])
        return out["w"], new_res[None]
    f = smap(step, mesh=mesh, in_specs=(P("data"), P("data")),
             out_specs=(P(), P("data")))
    res = jnp.zeros((4, n))
    outs = []
    for _ in range(3):
        got, res = jax.jit(f)(g, res)
        outs.append(np.asarray(got))
    return outs, np.asarray(res)

seed_cfg = ReducerConfig(kind="fft", axis="data", theta=0.9,
                         error_feedback=True, quantize=False)
seed_outs, seed_res = run_ef(seed_cfg)
for transport in ("allgather", "sequenced", "psum"):
    outs, res = run_ef(dataclasses.replace(seed_cfg, transport=transport,
                                           bucket_bytes=4096 * 4))
    for a, b in zip(outs, seed_outs):
        assert np.abs(a - b).max() < 1e-5, transport
    assert np.abs(res - seed_res).max() < 1e-5, transport
# EF still does its job: residual is exactly what compression dropped
assert np.linalg.norm(seed_res) > 0.0
print("EF_TRANSPORTS_OK")
""", devices=4)
    assert "EF_TRANSPORTS_OK" in out


def test_seeded_determinism_bitwise_across_transports(tmp_path):
    """Identical seed + config must produce bitwise-identical checkpoints
    regardless of transport: the gather transports fold worker contributions
    in the same order the CPU backend's all-reduce sums them (see
    transport._ordered_worker_mean), so allgather/sequenced/psum realize the
    SAME f32 mean bit-for-bit, and a rerun of any transport is bitwise
    reproducible.  This is what makes transport choice a pure performance
    knob: switching transports mid-experiment can never change the training
    trajectory."""
    out = run_with_devices(SMAP_COMPAT + f"""
import dataclasses, os
import numpy as np
from repro.comms.reducers import ReducerConfig
from repro.configs.base import ArchConfig
from repro.data import SyntheticConfig, SyntheticStream
from repro.jaxcompat import set_mesh
from repro.models.transformer import LM
from repro.optim import OptConfig
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train import checkpoint as ckpt
from repro.train.step import StepConfig

TINY = ArchConfig(name="tiny", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=32, remat="none")
mesh = make_auto_mesh((4,), ("data",))
model = LM(TINY)
opt = OptConfig(kind="adamw", lr=3e-3)
stream = SyntheticStream(SyntheticConfig(vocab_size=32, seq_len=16, global_batch=8))

def run(transport, tag):
    cfg = StepConfig(mode="compressed_dp", reducer=ReducerConfig(
        kind="fft", axis="data", theta=0.7, quantize=True, transport=transport))
    state = init_state(jax.random.PRNGKey(7), model, opt)
    ckdir = os.path.join({str(tmp_path)!r}, tag)
    with set_mesh(mesh):
        train_loop(model, opt, cfg, mesh, state, stream,
                   TrainLoopConfig(total_steps=8, ckpt_dir=ckdir,
                                   ckpt_every=8, log_every=100))
    return ckdir

def arrays(ckdir):
    d = np.load(os.path.join(ckdir, "step_00000008", "arrays.npz"))
    return {{k: d[k] for k in d.files}}

base = arrays(run("allgather", "ag"))
rerun = arrays(run("allgather", "ag2"))
for k in base:
    assert np.array_equal(base[k], rerun[k]), ("rerun nondeterminism", k)
for transport in ("sequenced", "psum"):
    got = arrays(run(transport, transport))
    assert set(got) == set(base)
    for k in base:
        assert base[k].dtype == got[k].dtype and np.array_equal(base[k], got[k]), (
            transport, k, np.abs(base[k].astype(np.float64)
                                 - got[k].astype(np.float64)).max())
print("DETERMINISM_OK")
""", devices=4, timeout=560)
    assert "DETERMINISM_OK" in out


def test_hierarchical_mode_across_transports():
    out = run_with_devices(SMAP_COMPAT + """
import dataclasses
import jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((2, 4), ("pod", "data"))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 2 * 4096 + 87)) * 0.1
expect = np.asarray(g.mean(0))

def run(cfg):
    r = make_reducer(cfg)
    f = smap(lambda v: r({"g": v[0]})["g"],
             mesh=mesh, in_specs=P(("pod", "data")), out_specs=P())
    return np.asarray(jax.jit(f)(g))

seed_cfg = ReducerConfig(kind="hierarchical", axis="data", pod_axis="pod",
                         theta=0.3, quantize=False)
seed = run(seed_cfg)
for transport in ("allgather", "sequenced", "psum"):
    got = run(dataclasses.replace(seed_cfg, transport=transport,
                                  bucket_bytes=4096 * 4))
    assert np.abs(got - seed).max() < 1e-5, transport
    # intra-pod mean is exact; only the pod-axis exchange is lossy
    rel = np.linalg.norm(got - expect) / np.linalg.norm(expect)
    assert rel < 0.35, (transport, rel)
print("HIER_TRANSPORTS_OK")
""")
    assert "HIER_TRANSPORTS_OK" in out
