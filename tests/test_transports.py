"""Transport equivalence across 8 fake devices: every transport must produce
the same mean as the seed all_gather path (ISSUE 1 acceptance), including the
error-feedback and hierarchical modes.

With quantization OFF the bucketed paths are bit-identical to the monolithic
seed path (chunk-aligned bucket boundaries keep per-chunk top-k selection
unchanged; FFT linearity keeps the means equal), so the comparison is exact
up to f32 reduction order.  With quantization ON, per-bucket quantizer fits
differ from the global fit, so agreement is within quantization tolerance.
"""

from helpers import run_with_devices

SMAP_COMPAT = """
import jax
from repro.jaxcompat import make_auto_mesh, shard_map as smap
"""


def test_all_transports_match_seed_allgather_mean():
    out = run_with_devices(SMAP_COMPAT + """
import dataclasses
import jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((8,), ("data",))
grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 3 * 4096 + 173)) * 0.1,
         "b": jax.random.normal(jax.random.PRNGKey(1), (8, 64)) * 0.1}
dense = jax.tree.map(lambda x: np.asarray(x.mean(0)), grads)

def run(cfg):
    r = make_reducer(cfg)
    f = smap(lambda g: r(jax.tree.map(lambda x: x[0], g)),
             mesh=mesh, in_specs=P("data"), out_specs=P())
    return jax.tree.map(np.asarray, jax.jit(f)(grads))

def flat(t):
    return np.concatenate([np.ravel(t[k]) for k in sorted(t)])

for kind in ("fft", "timedomain"):
    # seed path: monolithic all_gather, no bucketing
    seed_cfg = ReducerConfig(kind=kind, axis="data", theta=0.5, quantize=False)
    seed = run(seed_cfg)
    for transport in ("allgather", "sequenced", "psum"):
        got = run(dataclasses.replace(seed_cfg, transport=transport,
                                      bucket_bytes=4096 * 4))
        err = np.abs(flat(got) - flat(seed)).max()
        assert err < 1e-5, (kind, transport, err)
    # quantized: per-bucket fits agree with the global fit within quant tol
    seed_q = run(dataclasses.replace(seed_cfg, quantize=True))
    for transport in ("sequenced", "psum"):
        got = run(dataclasses.replace(seed_cfg, quantize=True,
                                      transport=transport, bucket_bytes=4096 * 4))
        rel = (np.linalg.norm(flat(got) - flat(seed_q))
               / np.linalg.norm(flat(seed_q)))
        assert rel < 0.1, (kind, transport, rel)
    # and every transport still approximates the dense mean (Assumption 3.1)
    rel_dense = (np.linalg.norm(flat(seed) - flat(dense))
                 / np.linalg.norm(flat(dense)))
    assert rel_dense < 0.5 ** 0.5 + 1e-3, (kind, rel_dense)
print("TRANSPORTS_OK")
""")
    assert "TRANSPORTS_OK" in out


def test_error_feedback_identical_across_transports():
    out = run_with_devices(SMAP_COMPAT + """
import dataclasses
import jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((4,), ("data",))
n = 2 * 4096 + 301
g = {"w": jnp.tile(jnp.sin(jnp.arange(n) / 50.0)[None] * 0.1, (4, 1))}

def run_ef(cfg):
    r = make_reducer(cfg)
    def step(grads, res):
        out, new_res = r(jax.tree.map(lambda x: x[0], grads), res[0])
        return out["w"], new_res[None]
    f = smap(step, mesh=mesh, in_specs=(P("data"), P("data")),
             out_specs=(P(), P("data")))
    res = jnp.zeros((4, n))
    outs = []
    for _ in range(3):
        got, res = jax.jit(f)(g, res)
        outs.append(np.asarray(got))
    return outs, np.asarray(res)

seed_cfg = ReducerConfig(kind="fft", axis="data", theta=0.9,
                         error_feedback=True, quantize=False)
seed_outs, seed_res = run_ef(seed_cfg)
for transport in ("allgather", "sequenced", "psum"):
    outs, res = run_ef(dataclasses.replace(seed_cfg, transport=transport,
                                           bucket_bytes=4096 * 4))
    for a, b in zip(outs, seed_outs):
        assert np.abs(a - b).max() < 1e-5, transport
    assert np.abs(res - seed_res).max() < 1e-5, transport
# EF still does its job: residual is exactly what compression dropped
assert np.linalg.norm(seed_res) > 0.0
print("EF_TRANSPORTS_OK")
""", devices=4)
    assert "EF_TRANSPORTS_OK" in out


def test_seeded_determinism_bitwise_across_transports(tmp_path):
    """Identical seed + config must produce bitwise-identical checkpoints
    regardless of transport: the gather transports fold worker contributions
    in the same order the CPU backend's all-reduce sums them (see
    transport._ordered_worker_mean), so allgather/sequenced/psum realize the
    SAME f32 mean bit-for-bit, and a rerun of any transport is bitwise
    reproducible.  This is what makes transport choice a pure performance
    knob: switching transports mid-experiment can never change the training
    trajectory."""
    out = run_with_devices(SMAP_COMPAT + f"""
import dataclasses, os
import numpy as np
from repro.comms.reducers import ReducerConfig
from repro.configs.base import ArchConfig
from repro.data import SyntheticConfig, SyntheticStream
from repro.jaxcompat import set_mesh
from repro.models.transformer import LM
from repro.optim import OptConfig
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train import checkpoint as ckpt
from repro.train.step import StepConfig

TINY = ArchConfig(name="tiny", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=32, remat="none")
mesh = make_auto_mesh((4,), ("data",))
model = LM(TINY)
opt = OptConfig(kind="adamw", lr=3e-3)
stream = SyntheticStream(SyntheticConfig(vocab_size=32, seq_len=16, global_batch=8))

def run(transport, tag):
    cfg = StepConfig(mode="compressed_dp", reducer=ReducerConfig(
        kind="fft", axis="data", theta=0.7, quantize=True, transport=transport))
    state = init_state(jax.random.PRNGKey(7), model, opt)
    ckdir = os.path.join({str(tmp_path)!r}, tag)
    with set_mesh(mesh):
        train_loop(model, opt, cfg, mesh, state, stream,
                   TrainLoopConfig(total_steps=8, ckpt_dir=ckdir,
                                   ckpt_every=8, log_every=100))
    return ckdir

def arrays(ckdir):
    d = np.load(os.path.join(ckdir, "step_00000008", "arrays.npz"))
    return {{k: d[k] for k in d.files}}

base = arrays(run("allgather", "ag"))
rerun = arrays(run("allgather", "ag2"))
for k in base:
    assert np.array_equal(base[k], rerun[k]), ("rerun nondeterminism", k)
for transport in ("sequenced", "psum"):
    got = arrays(run(transport, transport))
    assert set(got) == set(base)
    for k in base:
        assert base[k].dtype == got[k].dtype and np.array_equal(base[k], got[k]), (
            transport, k, np.abs(base[k].astype(np.float64)
                                 - got[k].astype(np.float64)).max())
print("DETERMINISM_OK")
""", devices=4, timeout=560)
    assert "DETERMINISM_OK" in out


def test_hierarchical_mode_across_transports():
    out = run_with_devices(SMAP_COMPAT + """
import dataclasses
import jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((2, 4), ("pod", "data"))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 2 * 4096 + 87)) * 0.1
expect = np.asarray(g.mean(0))

def run(cfg):
    r = make_reducer(cfg)
    f = smap(lambda v: r({"g": v[0]})["g"],
             mesh=mesh, in_specs=P(("pod", "data")), out_specs=P())
    return np.asarray(jax.jit(f)(g))

seed_cfg = ReducerConfig(kind="hierarchical", axis="data", pod_axis="pod",
                         theta=0.3, quantize=False)
seed = run(seed_cfg)
for transport in ("allgather", "sequenced", "psum"):
    got = run(dataclasses.replace(seed_cfg, transport=transport,
                                  bucket_bytes=4096 * 4))
    assert np.abs(got - seed).max() < 1e-5, transport
    # intra-pod mean is exact; only the pod-axis exchange is lossy
    rel = np.linalg.norm(got - expect) / np.linalg.norm(expect)
    assert rel < 0.35, (transport, rel)
print("HIER_TRANSPORTS_OK")
""")
    assert "HIER_TRANSPORTS_OK" in out


# ---------------------------------------------------------------------------
# Two-level (node x local) topology suite — DESIGN.md §18
# ---------------------------------------------------------------------------

def test_two_level_transports_match_flat_psum_mean():
    """hierarchical and reduce_scatter on a (2, 4) mesh track the flat psum
    transport over the same 8 workers: reduce_scatter realizes the identical
    mean (same dequantize -> reduce -> iFFT numerics, just bucket-partitioned),
    and hierarchical — whose only loss is the single island-level compress of
    the node mean — stays inside the lab's 5% envelope on CORRELATED worker
    gradients with energy-concentrated spectra (what real data-parallel
    gradients look like; on WHITE iid noise every coefficient sits at the
    top-k threshold, kept sets churn, and the envelope is meaningless by
    design — the lab rows measure the realistic case end-to-end)."""
    out = run_with_devices(SMAP_COMPAT + """
import dataclasses
import jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((2, 4), ("node", "local"))
n = 3 * 4096 + 173

def lowpass(key, shape):
    # moving-average filter concentrates spectral energy like real gradients
    raw = jax.random.normal(key, shape[:-1] + (n + 64,))
    k = jnp.ones(64) / 64.0
    f = lambda r: jnp.convolve(r, k, mode="valid")[:n]
    return f(raw) if raw.ndim == 1 else jax.vmap(f)(raw)

base = lowpass(jax.random.PRNGKey(0), (n,))
noise = lowpass(jax.random.PRNGKey(1), (8, n)) * 0.1
g = {"w": base[None] + noise}  # correlated workers: shared signal, small jitter
dense = np.asarray(g["w"].mean(0))

def run(cfg):
    r = make_reducer(cfg)
    f = smap(lambda v: r({"w": v[0]})["w"],
             mesh=mesh, in_specs=P(("node", "local")), out_specs=P())
    return np.asarray(jax.jit(f)(g["w"]))

base_cfg = ReducerConfig(kind="fft", axis=("node", "local"), theta=0.7,
                         quantize=False, bucket_bytes=4096 * 4)
flat = run(dataclasses.replace(base_cfg, transport="psum"))
rs = run(dataclasses.replace(base_cfg, transport="reduce_scatter"))
hier = run(dataclasses.replace(base_cfg, transport="hierarchical"))

# reduce_scatter: identical mean, only the dispatch differs
assert np.abs(rs - flat).max() < 1e-5, np.abs(rs - flat).max()
# hierarchical: one island-level compress of the node mean; 5% envelope
rel = np.linalg.norm(hier - flat) / np.linalg.norm(flat)
assert rel < 0.05, rel
# and all three track the dense mean closely on energy-concentrated data
for name, got in (("psum", flat), ("hier", hier), ("rs", rs)):
    rel_d = np.linalg.norm(got - dense) / np.linalg.norm(dense)
    assert rel_d < 0.2, (name, rel_d)
# quantized run: per-bucket quantizer fits stay within the same envelope
flat_q = run(dataclasses.replace(base_cfg, transport="psum", quantize=True))
hier_q = run(dataclasses.replace(base_cfg, transport="hierarchical",
                                 quantize=True))
rel_q = np.linalg.norm(hier_q - flat_q) / np.linalg.norm(flat_q)
assert rel_q < 0.05, rel_q
print("TWO_LEVEL_MEANS_OK")
""")
    assert "TWO_LEVEL_MEANS_OK" in out


def test_two_level_error_feedback_residual_parity():
    """EF residual parity through reducers.py: the residual accumulates each
    worker's OWN compress roundtrip at the exchange's bucket granularity on
    every transport — psum, hierarchical, and reduce_scatter must produce the
    same residual state given the same inputs (the hierarchical mean differs;
    the residual contract does not), and the residual must be nonzero (EF is
    actually accumulating dropped signal)."""
    out = run_with_devices(SMAP_COMPAT + """
import dataclasses
import jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((2, 4), ("node", "local"))
n = 2 * 4096 + 301
g = jnp.tile(jnp.sin(jnp.arange(n) / 50.0)[None] * 0.1, (8, 1))

def run_ef(cfg):
    r = make_reducer(cfg)
    def step(grads, res):
        out, new_res = r({"w": grads[0]}, res[0])
        return out["w"], new_res[None]
    f = smap(step, mesh=mesh, in_specs=(P(("node", "local")),) * 2,
             out_specs=(P(), P(("node", "local"))))
    res = jnp.zeros((8, n))
    for _ in range(3):
        got, res = jax.jit(f)(g, res)
    return np.asarray(got), np.asarray(res)

base_cfg = ReducerConfig(kind="fft", axis=("node", "local"), theta=0.8,
                         error_feedback=True, quantize=False,
                         bucket_bytes=4096 * 4)
out_p, res_p = run_ef(dataclasses.replace(base_cfg, transport="psum"))
out_h, res_h = run_ef(dataclasses.replace(base_cfg, transport="hierarchical"))
out_r, res_r = run_ef(dataclasses.replace(base_cfg, transport="reduce_scatter"))
assert np.linalg.norm(res_p) > 0.0
assert np.abs(res_h - res_p).max() < 1e-6, np.abs(res_h - res_p).max()
assert np.abs(res_r - res_p).max() < 1e-6, np.abs(res_r - res_p).max()
# reduce_scatter's EF-corrected mean equals psum's (same exchange numerics)
assert np.abs(out_r - out_p).max() < 1e-5
print("TWO_LEVEL_EF_OK")
""")
    assert "TWO_LEVEL_EF_OK" in out


def test_two_level_backend_parity_bitwise():
    """Payloads stay bitwise-comparable across engine backends on the 2-D
    mesh: the pallas and reference backends produce identical codes/spectra
    (test_engine.py), so the hierarchical and reduce_scatter means — which
    compress/decompress through the SAME engine seam — must be bit-identical
    across backends too."""
    out = run_with_devices(SMAP_COMPAT + """
import dataclasses
import jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((2, 4), ("node", "local"))
n = 2 * 4096 + 87
g = jax.random.normal(jax.random.PRNGKey(3), (8, n)) * 0.1

def run(cfg):
    r = make_reducer(cfg)
    f = smap(lambda v: r({"w": v[0]})["w"],
             mesh=mesh, in_specs=P(("node", "local")), out_specs=P())
    return np.asarray(jax.jit(f)(g))

for transport in ("hierarchical", "reduce_scatter"):
    cfg = ReducerConfig(kind="fft", axis=("node", "local"), theta=0.6,
                        quantize=True, bucket_bytes=4096 * 4,
                        transport=transport)
    ref = run(dataclasses.replace(cfg, backend="reference"))
    pal = run(dataclasses.replace(cfg, backend="pallas"))
    dev = np.abs(ref - pal).max()
    assert dev == 0.0, (transport, dev)
print("TWO_LEVEL_BACKENDS_OK")
""")
    assert "TWO_LEVEL_BACKENDS_OK" in out


def test_two_level_inter_node_wire_beats_flat_psum():
    """Cost-model acceptance assertion (ISSUE 8): on every swept (nodes,
    local) shape with >= 4 nodes, the modeled per-worker inter-node wire of
    the hierarchical transport is STRICTLY below the flat psum transport's
    runtime per-worker wire at the same worker count, and for fixed nodes it
    strictly shrinks as the island grows (each worker's share of the fabric
    hop is nodes*B/local)."""
    from repro.comms import cost_model
    from repro.core.compressor import FFTCompressor, FFTCompressorConfig

    n = 6 * 4096 + 321
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    payload = float(comp.wire_bits(n))
    for nodes in (4, 8):
        prev = None
        for local in (2, 4, 8):
            wire = cost_model.two_level_wire_bits(
                payload, nodes, local, mode="runtime", n_elems=n)
            flat = cost_model.transport_wire_bits(
                "psum", payload, nodes * local, mode="runtime", n_elems=n)
            assert wire.inter_bits_per_worker < flat, (
                nodes, local, wire.inter_bits_per_worker, flat)
            assert wire.inter_bits_per_node == nodes * payload
            if prev is not None:
                assert wire.inter_bits_per_worker < prev, (nodes, local)
            prev = wire.inter_bits_per_worker


def test_collectives_tuple_axes_on_2d_mesh():
    """comms/collectives.py multi-axis helpers: axis_size/axis_sizes accept a
    tuple of names (product semantics), axis_linear_index enumerates workers
    row-major over the tuple, and normalize_axes rejects junk specs."""
    out = run_with_devices(SMAP_COMPAT + """
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms.collectives import (
    axis_linear_index, axis_size, axis_sizes, normalize_axes)

assert normalize_axes("data") == "data"
assert normalize_axes(["node", "local"]) == ("node", "local")
assert normalize_axes(("local",)) == ("local",)
for bad in ((), ["node", 3]):
    try:
        normalize_axes(bad)
    except ValueError:
        pass
    else:
        raise AssertionError(f"normalize_axes({bad!r}) should raise")

mesh = make_auto_mesh((2, 4), ("node", "local"))

def probe(_):
    sizes = (axis_size("node"), axis_size("local"),
             axis_size(("node", "local")), axis_sizes(("node", "local")))
    assert sizes[:3] == (2, 4, 8), sizes
    assert sizes[3] == (2, 4), sizes
    return axis_linear_index(("node", "local"))[None]

import jax.numpy as jnp
f = smap(probe, mesh=mesh, in_specs=P(("node", "local")),
         out_specs=P(("node", "local")))
idx = np.asarray(jax.jit(f)(jnp.zeros((8,))))
assert list(idx) == list(range(8)), idx  # row-major worker enumeration
print("TUPLE_AXES_OK")
""")
    assert "TUPLE_AXES_OK" in out


def test_two_level_mesh_validation_names_device_count():
    """launch/mesh.py validation: an impossible 2-D shape fails with an error
    naming the device count (not a bare reshape failure), and an uneven
    make_two_level_mesh split names the divisor problem."""
    out = run_with_devices("""
from repro.launch.mesh import make_local_mesh, make_two_level_mesh

mesh = make_local_mesh((2, 4))  # default axes = ("node", "local")
assert mesh.axis_names == ("node", "local"), mesh.axis_names
assert dict(mesh.shape) == {"node": 2, "local": 4}
assert make_two_level_mesh(4).shape["local"] == 2

try:
    make_local_mesh((4, 4), ("node", "local"))
except ValueError as e:
    msg = str(e)
    assert "16 devices" in msg and "8 host devices" in msg, msg
else:
    raise AssertionError("oversized mesh should raise")

try:
    make_two_level_mesh(3)
except ValueError as e:
    assert "do not split evenly" in str(e), e
else:
    raise AssertionError("uneven node split should raise")

try:
    make_local_mesh((2, 2, 2))
except ValueError as e:
    assert "explicit axes" in str(e), e
else:
    raise AssertionError("3-D shape without axes should raise")
print("MESH_VALIDATION_OK")
""")
    assert "MESH_VALIDATION_OK" in out
