"""End-to-end compressor pipeline (paper Fig. 5) + baselines protocol."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, theory
from repro.core.compressor import (
    FFTCompressor,
    FFTCompressorConfig,
    NoCompression,
    QuantOnlyCompressor,
)

G = jax.random.normal(jax.random.PRNGKey(0), (100_000,)) * 0.05


@pytest.mark.parametrize("theta", [0.3, 0.7])
def test_fft_pipeline_roundtrip_under_jit(theta):
    comp = FFTCompressor(FFTCompressorConfig(theta=theta))
    payload = jax.jit(comp.compress)(G)
    g_hat = jax.jit(comp.decompress)(payload)
    err, norm_ratio = theory.assumption31_stats(G, g_hat)
    assert float(err) <= theta**0.5 + 0.05  # quantization slack
    assert float(norm_ratio) <= 1.01


def test_payload_is_pytree():
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    payload = comp.compress(G)
    leaves = jax.tree_util.tree_leaves(payload)
    assert len(leaves) >= 3
    rebuilt = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(payload), leaves
    )
    np.testing.assert_allclose(
        np.array(comp.decompress(rebuilt)), np.array(comp.decompress(payload))
    )


def test_compression_ratio_matches_paper_formula():
    """Paper: overall k = 4 / (1 - freq_drop%) for 8-bit quantization; our
    index payload adds the 16-bit index per kept coefficient."""
    n = 1 << 20
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7, n_bits=8))
    ratio = comp.ratio(n)
    # values-only ratio (bitmap-free): 32 bits -> 2*8 bits on 30% of bins
    # plus indices: (2*8+16)*0.3 bits/coeff vs 32*2 bits/coeff... sanity bounds
    assert 5.5 <= ratio <= 8.5
    # quantization contributes ~2x on top of sparsification alone
    raw = FFTCompressor(FFTCompressorConfig(theta=0.7, quantize=False)).ratio(n)
    assert ratio / raw == pytest.approx(2.0, rel=0.35)


def test_wire_bits_monotone_in_theta():
    n = 1 << 18
    ratios = [FFTCompressor(FFTCompressorConfig(theta=t)).ratio(n)
              for t in (0.0, 0.5, 0.9)]
    assert ratios[0] < ratios[1] < ratios[2]


def test_quant_only_and_nocompression():
    qc = QuantOnlyCompressor()
    gr = qc.decompress(qc.compress(G))
    assert float(jnp.mean((G - gr) ** 2)) < 1e-4
    assert qc.ratio(1 << 20) == pytest.approx(4.0, rel=0.01)
    nc = NoCompression()
    assert nc.ratio(100) == 1.0
    np.testing.assert_array_equal(np.array(nc.decompress(nc.compress(G))), np.array(G))


@pytest.mark.parametrize("comp,max_err,ratio_range", [
    (baselines.TernGrad(), 2.5, (15.9, 16.1)),
    (baselines.QSGD(), 2.5, (6.0, 6.6)),
    (baselines.DGCTopK(0.99), 1.01, (60, 70)),
    (baselines.OneBitSGD(), 0.8, (31, 33)),
])
def test_baseline_protocol(comp, max_err, ratio_range):
    payload = comp.compress(G, jax.random.PRNGKey(1))
    g_hat = comp.decompress(payload)
    assert g_hat.shape == G.shape
    err, _ = theory.assumption31_stats(G, g_hat)
    assert float(err) <= max_err
    assert ratio_range[0] <= comp.ratio(G.shape[0]) <= ratio_range[1]


def test_terngrad_unbiased():
    """E[decompress(compress(g))] = g for stochastic ternarization."""
    tern = baselines.TernGrad()
    g = jnp.array([0.3, -0.7, 0.05] * 100)
    acc = jnp.zeros_like(g)
    for i in range(400):
        acc = acc + tern.decompress(tern.compress(g, jax.random.PRNGKey(i)))
    np.testing.assert_allclose(np.array(acc / 400), np.array(g), atol=0.12)
