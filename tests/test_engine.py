"""Backend parity: the pallas engine backend must be payload-compatible and
numerically interchangeable with the reference backend (DESIGN.md §13).

Contract under test:

* CODES are bitwise-identical across backends (the pallas compress keeps the
  exact XLA rfft and the in-register quantizer matches the jnp oracle
  bit-for-bit); only the slot ORDER differs (reference packs top_k
  magnitude-descending, pallas packs index-ascending), so comparisons sort
  by index first.
* RECONSTRUCTIONS agree within the matmul-FFT tolerance of the fused
  decompress kernel (the 4-step iFFT is ~1e-5-approximate; codes are exact).
* Payloads are backend-PORTABLE: either backend decompresses the other's
  payload, and the transports exchange pallas payloads unchanged.
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_with_devices
from repro.core.compressor import FFTCompressor, FFTCompressorConfig, TimeDomainCompressor
from repro.kernels import engine, ops

G = jax.random.normal(jax.random.PRNGKey(42), (3 * 4096 + 517,)) * 0.05


def _cfg(backend, **kw):
    return FFTCompressorConfig(backend=backend, **kw)


def _sorted_planes(payload):
    """Canonical (index-ascending) view of the payload planes."""
    order = np.argsort(np.array(payload.idx), axis=-1, kind="stable")
    return tuple(
        np.take_along_axis(np.array(plane), order, axis=-1)
        for plane in (payload.re, payload.im, payload.idx)
    )


@pytest.mark.parametrize("theta", [0.5, 0.7, 0.9])
@pytest.mark.parametrize("n_bits,quantize", [(4, True), (8, True), (8, False)])
def test_backend_parity_codes_bitwise(theta, n_bits, quantize):
    ref = FFTCompressor(_cfg("reference", theta=theta, n_bits=n_bits, quantize=quantize))
    pal = FFTCompressor(_cfg("pallas", theta=theta, n_bits=n_bits, quantize=quantize))
    p_ref = jax.jit(ref.compress)(G)
    p_pal = jax.jit(pal.compress)(G)

    # identical layout: shapes, dtypes, statics
    assert p_ref.re.shape == p_pal.re.shape
    assert p_ref.re.dtype == p_pal.re.dtype
    assert p_ref.idx.dtype == p_pal.idx.dtype == jnp.int16
    assert (p_ref.orig_len, p_ref.chunk) == (p_pal.orig_len, p_pal.chunk)

    # identical quantizer fit (masked min/max == packed min/max, order-free)
    if quantize:
        assert float(p_ref.quant.eps) == float(p_pal.quant.eps)
        assert int(p_ref.quant.p_codes) == int(p_pal.quant.p_codes)
    else:
        assert p_ref.quant is None and p_pal.quant is None

    # identical codes once both payloads are in canonical index order
    for a, b, what in zip(_sorted_planes(p_ref), _sorted_planes(p_pal),
                          ("re", "im", "idx")):
        np.testing.assert_array_equal(a, b, err_msg=f"{what} codes diverge")

    # reconstructions within the fused-iFFT tolerance; same sparsify bound
    x_ref = np.array(ref.decompress(p_ref))
    x_pal = np.array(pal.decompress(p_pal))
    np.testing.assert_allclose(x_pal, x_ref, atol=5e-5)

    # payloads are backend-portable: cross-decompression works unchanged
    np.testing.assert_allclose(
        np.array(ref.decompress(p_pal)), x_ref, atol=5e-5)
    np.testing.assert_allclose(
        np.array(pal.decompress(p_ref)), x_ref, atol=5e-5)


def test_backend_spectra_bitwise_identical():
    """The exchange path (decompress_spectrum) is shared: payloads from
    either backend produce the SAME dense spectrum bit-for-bit — this is why
    transports and reducers are backend-oblivious."""
    ref = FFTCompressor(_cfg("reference"))
    pal = FFTCompressor(_cfg("pallas"))
    s_ref = np.array(ref.decompress_spectrum(ref.compress(G)))
    s_pal = np.array(pal.decompress_spectrum(pal.compress(G)))
    np.testing.assert_array_equal(s_ref, s_pal)


def test_fused_decompress_matches_unfused():
    """Golden check: the fused decompress kernel (dequant -> Hermitian
    scatter -> 4-step iFFT, one VMEM pass) equals the unfused three-stage
    path (quant_decode kernel -> scatter -> XLA irfft) on the same payload."""
    from repro.core import fft as cfft
    from repro.kernels import fused_decompress

    comp = FFTCompressor(_cfg("pallas", theta=0.7))
    payload = comp.compress(G)
    fused = fused_decompress.fused_decompress_pallas(
        payload.re, payload.im, payload.idx,
        payload.quant.eps, payload.quant.p_codes,
        m_bits=payload.quant.config.m_bits,
    ).reshape(-1)[: payload.orig_len]

    re = ops.quant_decode(payload.re, payload.quant)
    im = ops.quant_decode(payload.im, payload.quant)
    spectrum = jax.vmap(
        lambda i, v: jnp.zeros((2049,), jnp.complex64).at[i].add(v)
    )((payload.idx).astype(jnp.int32), (re + 1j * im).astype(jnp.complex64))
    unfused = cfft.chunked_irfft(spectrum, payload.orig_len, payload.chunk)

    np.testing.assert_allclose(np.array(fused), np.array(unfused), atol=2e-6)


def test_fused_decompress_tolerates_tile_padding():
    """Payload widths are padded to the 128-lane tile inside the kernel with
    code-0/index-0 slots; those must contribute NOTHING (the scatter is
    additive, so a padding slot may not clobber a genuinely-kept DC bin)."""
    from repro.kernels import fused_decompress

    comp = FFTCompressor(_cfg("pallas", theta=0.7))
    payload = comp.compress(G)  # width 615: kernel pads to 640 internally
    k = payload.re.shape[-1]
    pad = ops.pad_k(k) - k
    padded = [jnp.pad(p, [(0, 0), (0, pad)]) for p in
              (payload.re, payload.im, payload.idx)]
    out_sliced = fused_decompress.fused_decompress_pallas(
        payload.re, payload.im, payload.idx,
        payload.quant.eps, payload.quant.p_codes)
    out_padded = fused_decompress.fused_decompress_pallas(
        *padded, payload.quant.eps, payload.quant.p_codes)
    np.testing.assert_array_equal(np.array(out_sliced), np.array(out_padded))


def test_auto_backend_selects_reference_off_tpu():
    """On this host Mosaic is unavailable, so auto must resolve to the
    reference path (same payloads bit-for-bit, including slot order)."""
    auto = FFTCompressor(_cfg("auto"))
    ref = FFTCompressor(_cfg("reference"))
    p_auto, p_ref = auto.compress(G), ref.compress(G)
    for a, b in ((p_auto.re, p_ref.re), (p_auto.im, p_ref.im),
                 (p_auto.idx, p_ref.idx)):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_spec_backend_names_mirror_engine_registry():
    """lab/spec.py is jax-free by design so it cannot import the engine; its
    hardcoded backend list must track engine.BACKEND_NAMES (adding a backend
    to the registry must also open it to the convergence-lab sweep)."""
    import inspect

    from repro.lab import spec as lab_spec

    src = inspect.getsource(lab_spec.ExperimentSpec.__post_init__)
    for name in engine.BACKEND_NAMES:
        assert f'"{name}"' in src, (
            f"engine backend {name!r} missing from ExperimentSpec validation")


def test_engine_eligibility_rules():
    ok, why = engine.kernel_eligibility(_cfg("pallas"))
    assert ok and not why
    ok, why = engine.kernel_eligibility(_cfg("pallas", chunk=1024))
    assert not ok and "chunk" in why
    ok, why = engine.kernel_eligibility(_cfg("pallas", quantize=False))
    assert not ok and "quantize" in why
    with pytest.raises(ValueError, match="backend"):
        FFTCompressorConfig(backend="cuda")


def test_pallas_per_stage_fallback_on_non_kernel_chunk():
    """chunk != 4096 has no fused iFFT: the pallas backend must fall back
    per-stage and still round-trip correctly."""
    ref = FFTCompressor(_cfg("reference", theta=0.7, chunk=1024))
    pal = FFTCompressor(_cfg("pallas", theta=0.7, chunk=1024))
    p_ref, p_pal = ref.compress(G), pal.compress(G)
    for a, b in zip(_sorted_planes(p_ref), _sorted_planes(p_pal)):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_allclose(
        np.array(pal.decompress(p_pal)), np.array(ref.decompress(p_ref)),
        atol=1e-6)


def test_timedomain_payload_ships_no_imaginary_plane():
    """The time-domain payload is purely real: the im plane must be EMPTY
    (not a zeros plane silently doubling wire traffic) and the wire
    accounting must describe the payload actually shipped."""
    comp = TimeDomainCompressor(FFTCompressorConfig(theta=0.7))
    payload = comp.compress(G)
    assert payload.has_im is False
    assert payload.im.shape == (payload.re.shape[0], 0)
    # round-trip unaffected
    x_hat = comp.decompress(payload)
    assert x_hat.shape == G.shape
    err = float(jnp.linalg.norm(G - x_hat) / jnp.linalg.norm(G))
    assert err <= 0.7 ** 0.5 + 0.05
    # shipped value bits == billed value bits (single plane + indices)
    k = payload.re.shape[-1]
    c = payload.re.shape[0]
    shipped = c * k * (8 + 16)  # uint8 codes + int16 indices
    billed = comp.wire_bits(G.shape[0]) - 4 * 32  # minus quantizer params
    assert shipped == billed
    # FFT payloads still carry both planes
    fp = FFTCompressor(FFTCompressorConfig(theta=0.7)).compress(G)
    assert fp.has_im is True and fp.im.shape == fp.re.shape


def test_bucketed_wire_accounting_matches_transport_granularity():
    from repro.comms import cost_model as cm

    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    sizes = [4096 * 2, 4096 * 2, 4096 + 173]
    total = sum(sizes)
    mono = cm.bucketed_payload_bits(comp.wire_bits, sizes, "allgather")
    per_bucket = cm.bucketed_payload_bits(comp.wire_bits, sizes, "sequenced")
    assert mono == comp.wire_bits(total)
    assert per_bucket == sum(comp.wire_bits(s) for s in sizes)
    # one quantizer-param overhead (4*32 bits) per PAYLOAD: the bucketed
    # exchange carries exactly one extra per additional bucket
    assert per_bucket - mono == (len(sizes) - 1) * 4 * 32
    assert (cm.bucketed_payload_bits(comp.wire_bits, sizes, "psum")
            == per_bucket)
    with pytest.raises(ValueError):
        cm.bucketed_payload_bits(comp.wire_bits, sizes, "carrier-pigeon")


def test_interpret_default_unified():
    """Every kernel entry point resolves interpret=None through the shared
    runtime policy (True on this CPU-only host)."""
    from repro.kernels import runtime

    assert runtime.default_interpret() is True
    assert runtime.resolve_interpret(None) is True
    assert runtime.resolve_interpret(False) is False
    assert ops.default_interpret is runtime.default_interpret
    # the fused kernels accept the shared default (no hardcoded True):
    # running them with interpret=None must succeed on CPU
    comp = FFTCompressor(_cfg("pallas"))
    comp.decompress(comp.compress(G))


def test_backend_parity_through_transports_with_error_feedback():
    """Bucketed + error-feedback reduction through every transport, pallas vs
    reference backends, on 4 fake devices: non-EF means must be bitwise
    equal (codes identical, shared spectral exchange); EF means/residuals
    agree within the fused-iFFT tolerance."""
    out = run_with_devices("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.jaxcompat import make_auto_mesh, shard_map as smap
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((4,), ("data",))
grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 2 * 4096 + 173)) * 0.1}
n = 2 * 4096 + 173

def run(cfg):
    r = make_reducer(cfg)
    f = smap(lambda g: r(jax.tree.map(lambda x: x[0], g)),
             mesh=mesh, in_specs=P("data"), out_specs=P())
    return np.asarray(jax.jit(f)(grads)["w"])

def run_ef(cfg):
    r = make_reducer(cfg)
    def step(g, res):
        out, new_res = r(jax.tree.map(lambda x: x[0], g), res[0])
        return out["w"], new_res[None]
    f = smap(step, mesh=mesh, in_specs=(P("data"), P("data")),
             out_specs=(P(), P("data")))
    res = jnp.zeros((4, n))
    outs = []
    for _ in range(2):
        got, res = jax.jit(f)(grads, res)
        outs.append(np.asarray(got))
    return outs, np.asarray(res)

for transport in ("allgather", "sequenced", "psum"):
    base = ReducerConfig(kind="fft", axis="data", theta=0.7, quantize=True,
                         transport=transport, bucket_bytes=4096 * 4)
    dev = np.abs(run(base) - run(dataclasses.replace(base, backend="pallas"))).max()
    assert dev == 0.0, (transport, dev)  # bitwise: shared exchange numerics

    ef = dataclasses.replace(base, error_feedback=True)
    o_ref, r_ref = run_ef(ef)
    o_pal, r_pal = run_ef(dataclasses.replace(ef, backend="pallas"))
    for a, b in zip(o_ref, o_pal):
        assert np.abs(a - b).max() < 1e-3, transport
    assert np.abs(r_ref - r_pal).max() < 1e-2, transport
    assert np.linalg.norm(r_pal) > 0.0  # EF is live under pallas too
print("BACKEND_TRANSPORTS_OK")
""", devices=4)
    assert "BACKEND_TRANSPORTS_OK" in out
