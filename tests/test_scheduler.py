"""Overlap engine (DESIGN.md §15): readiness schedules must be pure
functions of the model's parameter order (same spec -> same bucket
schedule), streamed dispatch must be bitwise-equal to the stacked path —
payloads, exchanged means, EF residuals, and whole training trajectories,
across theta x n_bits x ragged bucket tails on fake devices — and the auto
policy must pick streamed exactly when the cost model says the backward
pass can hide the exchange."""

import dataclasses

import jax
import numpy as np
import pytest

from helpers import given, st, run_with_devices

from repro.comms import bucketing, cost_model as cm, executor, scheduler
from repro.comms.reducers import ReducerConfig
from repro.comms.transport import get_transport
from repro.core.compressor import FFTCompressor, FFTCompressorConfig

# 5 full chunks + ragged tail (same fixture family as test_stacked.py):
# 2-chunk buckets -> ragged tail NOT the widest; 3-chunk -> tail narrower.
G = jax.random.normal(jax.random.PRNGKey(7), (5 * 4096 + 517,)) * 0.05


def _layout(bucket_chunks):
    return bucketing.build_layout(
        G.shape[0], None if bucket_chunks is None else bucket_chunks * 4096 * 4)


# ---------------------------------------------------------------------------
# readiness metadata
# ---------------------------------------------------------------------------


def test_readiness_is_reverse_topological():
    layout = _layout(2)  # 3 buckets
    assert bucketing.readiness_ranks(layout) == (2, 1, 0)
    assert bucketing.readiness_order(layout) == (2, 1, 0)
    mono = _layout(None)
    assert bucketing.readiness_ranks(mono) == (0,)


def test_sub_layout_preserves_boundaries():
    layout = _layout(2)
    sub = bucketing.sub_layout(layout, 1, 3)
    assert sub.total == layout.total - layout.boundaries[1]
    assert sub.sizes() == layout.sizes()[1:3]
    assert sub.chunk == layout.chunk
    # single-bucket slice
    one = bucketing.sub_layout(layout, 0, 1)
    assert one.sizes() == (layout.sizes()[0],)
    with pytest.raises(ValueError):
        bucketing.sub_layout(layout, 2, 2)
    with pytest.raises(ValueError):
        bucketing.sub_layout(layout, 0, 99)


def test_plan_is_pure_function_of_registry_entry():
    """Same model registry entry -> same parameter count -> same layout ->
    same readiness schedule, across independent derivations (the
    every-worker-derives-the-same-schedule contract)."""
    from repro.models import registry
    from repro.models.sharding import count_params

    def derive():
        cfg = registry.get_config("gemma2_2b").reduced()
        n = count_params(registry.build(cfg).spec())
        layout = bucketing.build_layout(n, 64 << 10)
        return scheduler.build_plan(layout), bucketing.readiness_ranks(layout)

    (plan_a, ranks_a), (plan_b, ranks_b) = derive(), derive()
    assert plan_a == plan_b  # frozen dataclass value equality
    assert ranks_a == ranks_b
    assert hash(plan_a) == hash(plan_b)  # executor cache key stability


def test_build_plan_groups_partition_in_readiness_order():
    layout = _layout(1)  # 6 buckets
    plan = scheduler.build_plan(layout)
    assert plan.n_groups == layout.n_buckets  # default: one group per bucket
    assert plan.groups[0] == (layout.n_buckets - 1, layout.n_buckets)
    for g in (1, 2, 3, 4, 6, 99):
        p = scheduler.build_plan(layout, g)
        assert p.n_groups == min(g, layout.n_buckets)
        covered = sorted(b for lo, hi in p.groups for b in range(lo, hi))
        assert covered == list(range(layout.n_buckets))
        # readiness order: strictly descending bucket ranges
        los = [lo for lo, _ in p.groups]
        assert los == sorted(los, reverse=True)
        assert abs(sum(p.group_fractions()) - 1.0) < 1e-12
    with pytest.raises(ValueError):
        scheduler.StreamPlan(layout, ((0, 2), (2, layout.n_buckets)))  # wrong order
    with pytest.raises(ValueError):
        scheduler.StreamPlan(layout, ((3, layout.n_buckets),))  # not a partition


def test_schedule_names_mirror_lab_spec():
    """lab/spec.py must stay jax-free so it mirrors SCHEDULE_NAMES as a
    literal — this is the drift guard (same pattern as the backend list)."""
    from repro.lab.spec import ExperimentSpec

    for name in scheduler.SCHEDULE_NAMES:
        if name == "streamed":
            ExperimentSpec(name="x", exchange_schedule=name,
                           transport="sequenced")
        else:
            ExperimentSpec(name="x", exchange_schedule=name)
    with pytest.raises(ValueError):
        ExperimentSpec(name="x", exchange_schedule="nope")
    with pytest.raises(ValueError):  # streamed needs a bucketed transport
        ExperimentSpec(name="x", exchange_schedule="streamed",
                       transport="allgather")


# ---------------------------------------------------------------------------
# bitwise parity: streamed dispatch == stacked execution
# ---------------------------------------------------------------------------


@given(theta=st.sampled_from([0.5, 0.7, 0.9]),
       n_bits=st.sampled_from([4, 8]),
       bucket_chunks=st.sampled_from([1, 2, 3]))
def test_streamed_payloads_bitwise_equal_stacked(theta, n_bits, bucket_chunks):
    """Group-wise compression emits, bucket for bucket, the exact payloads
    of the one-shot stacked compress — same codes, indices, per-bucket
    quantizer fits — across theta x n_bits x ragged bucket tails."""
    layout = _layout(bucket_chunks)
    comp = FFTCompressor(FFTCompressorConfig(theta=theta, n_bits=n_bits))
    plan = scheduler.build_plan(layout)
    stacked = executor.compress_fn(comp, layout, donate=False)(G)
    group_payloads = executor.streamed_compress_fn(comp, plan)(G)
    # groups are readiness-ordered; reassemble per-bucket payloads in index
    # order and compare against the stacked slicer
    per_bucket = {}
    for (lo_b, hi_b), sp in zip(plan.groups, group_payloads):
        for i, p in enumerate(sp.bucket_payloads()):
            per_bucket[lo_b + i] = p
    ref = stacked.bucket_payloads()
    assert sorted(per_bucket) == list(range(len(ref)))
    for b, expect in enumerate(ref):
        got = per_bucket[b]
        for plane in ("re", "im", "idx"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, plane)),
                np.asarray(getattr(expect, plane)),
                err_msg=f"bucket {b} plane {plane}")
        if expect.quant is not None:
            assert float(got.quant.eps) == float(expect.quant.eps), b
            assert int(got.quant.p_codes) == int(expect.quant.p_codes), b
    # the streamed roundtrip reconstruction is bitwise the stacked one's
    np.testing.assert_array_equal(
        np.asarray(executor.streamed_roundtrip_fn(comp, plan)(G)),
        np.asarray(executor.roundtrip_fn(comp, layout, donate=False)(G)))


def test_streamed_exchange_collective_count_scales_with_groups():
    """Structural claim on the traced jaxpr: the streamed exchange issues
    one collective set PER READINESS GROUP (the dispatch boundaries the
    overlap engine exists for), vs the stacked path's single set."""
    from repro.jaxcompat import make_auto_mesh, shard_map as smap
    from jax.sharding import PartitionSpec as P

    mesh = make_auto_mesh((1,), ("data",))
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    layout = _layout(1)  # 6 buckets

    def count(prim, transport_name, plan):
        transport = get_transport(transport_name)
        if plan is None:
            fn = lambda flat: transport.exchange_flat(
                flat[0], layout, comp, "data")
        else:
            fn = lambda flat: scheduler.exchange_streamed(
                transport, flat[0], plan, comp, "data")
        wrapped = smap(fn, mesh=mesh, in_specs=P("data"), out_specs=P())
        return str(jax.make_jaxpr(wrapped)(G[None])).count(prim)

    for prim, tname in (("all_gather", "sequenced"), ("psum", "psum")):
        base = count(prim, tname, None)
        per_bucket = count(prim, tname, scheduler.build_plan(layout))
        two_groups = count(prim, tname, scheduler.build_plan(layout, 2))
        assert base >= 1
        assert per_bucket == layout.n_buckets * base, (tname, per_bucket, base)
        assert two_groups == 2 * base, (tname, two_groups, base)


def test_streamed_trajectories_bitwise_equal_multidevice():
    """End to end on 4 fake workers: flipping ReducerConfig.schedule between
    stacked and streamed may not move one bit of the reduced gradient, the
    EF residual, or a short training trajectory — for both bucketed
    transports, theta x n_bits, ragged tails, and coarse/fine group counts."""
    out = run_with_devices("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.jaxcompat import make_auto_mesh, shard_map as smap
from repro.comms import ReducerConfig, make_reducer

mesh = make_auto_mesh((4,), ("data",))
n = 3 * 4096 + 517  # ragged tail
grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, n)) * 0.1}

def run(cfg):
    r = make_reducer(cfg)
    f = smap(lambda g: r(jax.tree.map(lambda x: x[0], g)),
             mesh=mesh, in_specs=P("data"), out_specs=P())
    return np.asarray(jax.jit(f)(grads)["w"])

for transport in ("sequenced", "psum"):
    for theta in (0.7, 0.9):
        for n_bits in (4, 8):
            base = ReducerConfig(kind="fft", axis="data", theta=theta,
                                 n_bits=n_bits, transport=transport,
                                 bucket_bytes=4096 * 4)
            a = run(base)
            for groups in (None, 2):
                b = run(dataclasses.replace(base, schedule="streamed",
                                            stream_groups=groups))
                assert np.array_equal(a, b), (transport, theta, n_bits, groups)

# EF trajectory: two chained reductions, residual threaded
def run_ef(cfg):
    r = make_reducer(cfg)
    def stepfn(g, res):
        out, new_res = r(jax.tree.map(lambda x: x[0], g), res[0])
        return out["w"], new_res[None]
    f = smap(stepfn, mesh=mesh, in_specs=(P("data"), P("data")),
             out_specs=(P(), P("data")))
    res = jnp.zeros((4, n))
    outs = []
    for _ in range(2):
        got, res = jax.jit(f)(grads, res)
        outs.append(np.asarray(got))
    return outs, np.asarray(res)

ef = ReducerConfig(kind="fft", axis="data", theta=0.7, transport="sequenced",
                   bucket_bytes=4096 * 4, error_feedback=True)
o_s, r_s = run_ef(dataclasses.replace(ef, schedule="streamed"))
o_k, r_k = run_ef(ef)
for a, b in zip(o_s, o_k):
    assert np.array_equal(a, b)
assert np.array_equal(r_s, r_k)
assert np.linalg.norm(r_s) > 0.0  # EF live through the streamed path

# whole TRAIN trajectory through build_train_step on 2 workers: 3 steps of
# the lab LM, stacked vs streamed states bitwise-identical
from repro.data import SyntheticConfig, SyntheticStream
from repro.lab.runner import _LM_ARCH
from repro.models.transformer import LM
from repro.optim import OptConfig
from repro.train import init_state
from repro.train.step import StepConfig, build_train_step
from repro import jaxcompat as compat

mesh2 = make_auto_mesh((2,), ("data",))
model = LM(_LM_ARCH)
stream = SyntheticStream(SyntheticConfig(
    vocab_size=_LM_ARCH.vocab_size, seq_len=16, global_batch=4, seed=3))
opt = OptConfig(kind="adamw", lr=3e-3)

def train(schedule):
    rc = ReducerConfig(kind="fft", axis="data", theta=0.7,
                       transport="sequenced", bucket_bytes=4096 * 4,
                       schedule=schedule)
    step_cfg = StepConfig(mode="compressed_dp", reducer=rc)
    state = init_state(jax.random.PRNGKey(0), model, opt)
    step = build_train_step(model, opt, step_cfg, mesh2, stream.batch_at(0),
                            donate=False)
    with compat.set_mesh(mesh2):
        for i in range(3):
            state, metrics = step(state, stream.batch_at(i))
    return state

s_stacked = train("stacked")
s_streamed = train("streamed")
for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_leaves_with_path(s_stacked),
        jax.tree_util.tree_leaves_with_path(s_streamed)):
    assert np.array_equal(np.asarray(a), np.asarray(b)), pa
print("STREAMED_TRAJECTORY_OK")
""", devices=4)
    assert "STREAMED_TRAJECTORY_OK" in out


# ---------------------------------------------------------------------------
# policy layer + cost model
# ---------------------------------------------------------------------------


def test_reducer_config_schedule_validation():
    ReducerConfig(kind="fft", schedule="streamed", transport="sequenced")
    with pytest.raises(ValueError):
        ReducerConfig(kind="fft", schedule="nope")
    with pytest.raises(ValueError):
        ReducerConfig(kind="fft", schedule="streamed", transport="allgather")
    with pytest.raises(ValueError):
        ReducerConfig(kind="fft", schedule="streamed", transport="sequenced",
                      stream_groups=0)


def test_choose_schedule_deep_streams_shallow_stacks():
    layout = bucketing.build_layout(1 << 24, 1 << 20)  # 16 buckets
    plan = scheduler.build_plan(layout)
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    bits = cm.bucketed_payload_bits(comp.wire_bits, layout.sizes(),
                                    "sequenced", stacked=True,
                                    chunk=layout.chunk)
    deep = scheduler.choose_schedule(
        plan, 4.0 * (1 << 24), bits, workers=8, transport="sequenced",
        backprop_s=scheduler.modeled_backprop_s(1 << 24, 1 << 20))
    assert deep.schedule == "streamed"
    assert 0.0 < deep.overlap_efficiency < 1.0
    assert deep.streamed_step_s < deep.stacked_step_s
    # no backward pass to hide behind -> alpha-per-group only hurts
    shallow = scheduler.choose_schedule(
        plan, 4.0 * (1 << 24), bits, workers=8, transport="sequenced",
        backprop_s=0.0)
    assert shallow.schedule == "stacked"
    assert shallow.overlap_efficiency == 0.0


def test_resolve_schedule_pure_and_monolithic_falls_back():
    cfg = ReducerConfig(kind="fft", transport="sequenced",
                        bucket_bytes=1 << 20, schedule="auto")
    a = scheduler.resolve_schedule(cfg, 1 << 24, 1 << 20)
    b = scheduler.resolve_schedule(cfg, 1 << 24, 1 << 20)
    assert a[0] == b[0] == "streamed"
    assert a[1].to_dict() == b[1].to_dict()  # same spec -> same decision
    # tiny model: latency-bound -> stacked
    assert scheduler.resolve_schedule(cfg, 3 * 4096, 64)[0] == "stacked"
    # monolithic layout: nothing to stream
    mono = dataclasses.replace(cfg, bucket_bytes=None)
    assert scheduler.resolve_schedule(mono, 1 << 24, 1 << 20)[0] == "stacked"
    # allgather: monolithic by definition
    ag = dataclasses.replace(cfg, transport="allgather")
    assert scheduler.resolve_schedule(ag, 1 << 24, 1 << 20)[0] == "stacked"
    # non-auto passes through untouched
    for fixed in ("stacked", "streamed"):
        f = dataclasses.replace(cfg, schedule=fixed)
        assert scheduler.resolve_schedule(f, 1 << 24, 1 << 20) == (fixed, None)


def test_train_step_resolves_auto_schedule():
    """The step builder resolves `auto` with the model's real parameter
    count and exposes the decision (train/step.py)."""
    from repro.data import SyntheticConfig, SyntheticStream
    from repro.lab.runner import _LM_ARCH
    from repro.launch.mesh import make_local_mesh
    from repro.models.transformer import LM
    from repro.optim import OptConfig
    from repro.train.step import StepConfig, build_train_step

    model = LM(_LM_ARCH)
    stream = SyntheticStream(SyntheticConfig(
        vocab_size=_LM_ARCH.vocab_size, seq_len=16, global_batch=2, seed=0))
    rc = ReducerConfig(kind="fft", axis="data", transport="sequenced",
                       bucket_bytes=4096 * 4, schedule="auto")
    step = build_train_step(
        model, OptConfig(kind="adamw", lr=1e-3),
        StepConfig(mode="compressed_dp", reducer=rc),
        make_local_mesh((1,), ("data",)), stream.batch_at(0), donate=False)
    assert step.reducer_config.schedule in ("stacked", "streamed")
    assert step.schedule_decision is not None
    assert step.schedule_decision.schedule == step.reducer_config.schedule


def test_streamed_cost_model_invariants():
    kw = dict(workers=8, transport="sequenced")
    fr = (0.25, 0.25, 0.25, 0.25)
    net, thr = cm.NETWORKS["tpu-dcn-host"], cm.TPU_V5E
    no_cover = cm.streamed_exchange_time_s(
        64 << 20, 8e7, net, thr, group_fractions=fr, backprop_s=0.0, **kw)
    assert no_cover.overlap_efficiency == 0.0
    assert no_cover.exposed_s == pytest.approx(no_cover.exchange_s)
    assert no_cover.n_collectives == 4
    assert no_cover.launch_s == pytest.approx(4 * cm.COLLECTIVE_ALPHA_S)
    covered = cm.streamed_exchange_time_s(
        64 << 20, 8e7, net, thr, group_fractions=fr, backprop_s=10.0, **kw)
    assert covered.hidden_s > no_cover.hidden_s
    assert 0.0 < covered.overlap_efficiency < 1.0
    assert covered.step_s >= 10.0
    # the last group only becomes ready at the end of backprop, so its own
    # exchange can never hide: efficiency is bounded away from 1
    assert covered.exposed_s > 0.0
    # work conservation
    assert covered.hidden_s + covered.exposed_s == pytest.approx(
        covered.exchange_s)
    with pytest.raises(ValueError):
        cm.streamed_exchange_time_s(1, 1, net, thr, group_fractions=(),
                                    backprop_s=1.0, **kw)
    with pytest.raises(ValueError):
        cm.streamed_exchange_time_s(1, 1, net, thr, group_fractions=(0.5, 0.4),
                                    backprop_s=1.0, **kw)
    with pytest.raises(ValueError):
        cm.streamed_exchange_time_s(1, 1, net, thr, group_fractions=(1.0,),
                                    backprop_s=-1.0, **kw)


def test_executor_streamed_cache_reuse():
    executor.clear_cache()
    layout = _layout(2)
    plan = scheduler.build_plan(layout)
    comp_a = FFTCompressor(FFTCompressorConfig(theta=0.7))
    comp_b = FFTCompressor(FFTCompressorConfig(theta=0.7))
    executor.streamed_compress_fn(comp_a, plan)
    n = executor.cache_size()
    assert n == plan.n_groups  # one cached executable per dispatch group
    executor.streamed_compress_fn(comp_b, plan)  # equal config: no new entries
    assert executor.cache_size() == n
    executor.streamed_compress_fn(
        FFTCompressor(FFTCompressorConfig(theta=0.9)), plan)
    assert executor.cache_size() == 2 * n
    executor.clear_cache()


def test_executor_streamed_cache_keys_on_absolute_offsets():
    """Regression: two parent layouts can contain an IDENTICAL group
    sub-layout at different flat offsets (the compiled closure bakes the
    slice in), so the cache key must carry the absolute range — a collision
    silently compresses the wrong gradient slice."""
    executor.clear_cache()
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    n = 4 * 4096
    flat = jax.random.normal(jax.random.PRNGKey(3), (n,)) * 0.05
    # same sub-layout (one 4096-elem bucket) at offset 4096 vs offset 8192
    lay_a = bucketing.BucketLayout(3 * 4096, (0, 4096, 3 * 4096), 4096)
    lay_b = bucketing.BucketLayout(n, (0, 8192, n), 4096)
    plan_a = scheduler.build_plan(lay_a, 2)
    plan_b = scheduler.build_plan(lay_b, 2)
    got_a = executor.streamed_compress_fn(comp, plan_a)(flat[: lay_a.total])
    got_b = executor.streamed_compress_fn(comp, plan_b)(flat)
    # every cached executable is offset-distinct: 2 groups x 2 plans
    assert executor.cache_size() == 4
    # plan_b's SECOND (index-order first) group covers flat[0:8192] — compare
    # against a direct stacked compress of that slice
    direct = executor.compress_fn(
        comp, bucketing.sub_layout(lay_b, 0, 1), donate=False)(flat[:8192])
    np.testing.assert_array_equal(
        np.asarray(got_b[-1].re), np.asarray(direct.re))
    # and plan_a's tail group (flat[4096:12288]) differs from plan_b's
    # (flat[8192:16384]) — the collision would have made them equal
    assert not np.array_equal(np.asarray(got_a[0].re),
                              np.asarray(got_b[0].re))
    executor.clear_cache()
