"""Convergence lab: tier-1 unit tests for the spec/evaluator/report layers
(pure logic, fabricated curves) plus the tier-2 ``-m lab`` smoke matrix that
actually trains on 8 simulated workers via the CLI."""

import json
import os
import subprocess
import sys

import pytest

from helpers import REPO

from repro.comms import cost_model
from repro.lab import report
from repro.lab.evaluate import Tolerances, chaos_claims, evaluate_results
from repro.lab.spec import (ExperimentSpec, chaos_matrix, full_matrix,
                            smoke_matrix)

# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------


def test_spec_json_roundtrip():
    for spec in full_matrix():
        d = spec.to_dict()
        json.loads(json.dumps(d))  # JSON-serializable
        assert ExperimentSpec.from_dict(d) == spec


def test_smoke_matrix_covers_the_claims():
    names = {s.name for s in smoke_matrix()}
    for model in ("lm", "convnet"):
        assert f"{model}_dense" in names
        assert f"{model}_fft_theta0.7" in names
        assert f"{model}_fft_theta0.9" in names
        assert f"{model}_fft_mixed" in names
        for transport in ("sequenced", "psum"):
            assert f"{model}_fft_theta0.7_{transport}" in names
        assert f"{model}_fft_theta0.7_pallas" in names  # backend sweep axis
        # exchange-schedule sweep axis (DESIGN.md §15)
        assert f"{model}_fft_theta0.7_bucketed_stacked" in names
        assert f"{model}_fft_theta0.7_bucketed_streamed" in names
        # selection-engine sweep axis (DESIGN.md §16)
        assert f"{model}_fft_theta0.7_sampled" in names
        # two-level topology sweep axis (DESIGN.md §18)
        assert f"{model}_fft_theta0.7_hier" in names
        assert f"{model}_fft_theta0.7_rs" in names


def test_spec_rejects_bad_configs():
    with pytest.raises(ValueError):
        ExperimentSpec(name="x", model="mlp")
    with pytest.raises(ValueError):
        ExperimentSpec(name="x", reducer=None,
                       schedule={"kind": "constant", "theta": 0.5})
    with pytest.raises(ValueError):
        ExperimentSpec(name="x", workers=8, global_batch=12)
    with pytest.raises(ValueError):
        ExperimentSpec(name="x", validate="sometimes")
    with pytest.raises(ValueError):
        ExperimentSpec(name="x", faults=[{"kind": "meteor", "step": 1}])
    with pytest.raises(ValueError):
        ExperimentSpec(name="x", ckpt_every=-1)


def test_chaos_matrix_covers_the_chaos_claims():
    """Each model gets its clean comparator plus one row per resilience
    claim (DESIGN.md §19); the full matrix carries the same rows."""
    names = {s.name for s in chaos_matrix()}
    for model in ("lm", "convnet"):
        assert f"{model}_fft_theta0.7" in names  # the comparator rides along
        assert f"{model}_chaos_nan" in names
        assert f"{model}_chaos_crash" in names
        assert f"{model}_chaos_corrupt" in names
    assert len(names) == 8
    assert names <= {s.name for s in full_matrix()}
    by_name = {s.name: s for s in chaos_matrix()}
    # the crash row checkpoints (else resume is impossible) and its crash
    # is fatal (else the in-loop rollback absorbs it and nothing resumes)
    crash = by_name["lm_chaos_crash"]
    assert crash.ckpt_every > 0
    assert all(ev["fatal"] for ev in crash.faults)
    # the corrupt row validates a bucketed exchange — payloads must exist
    corrupt = by_name["lm_chaos_corrupt"]
    assert corrupt.validate != "off" and corrupt.bucket_bytes


# ---------------------------------------------------------------------------
# evaluator on fabricated curves
# ---------------------------------------------------------------------------


def _fake_run(name, reducer, losses, theta=0.7, schedule=None, model="lm",
              err_ratio=0.5, lr=3e-3, backend="reference",
              transport="allgather", bucket_bytes=None,
              exchange_schedule="stacked", selector="sort", nodes=None):
    records = []
    for i, loss in enumerate(losses):
        rec = {"step": i, "loss": loss, "grad_sq": max(loss - 1.0, 0.05),
               "theta": None if reducer is None else theta}
        if reducer in ("fft", "timedomain"):
            rec["err_ratio"] = err_ratio
            rec["norm_ratio"] = 0.95
            rec["payload_bits"] = 1e5
            rec["compression_ratio"] = 10.0
        records.append(rec)
    return {
        "spec": ExperimentSpec(
            name=name, model=model, reducer=reducer, theta=theta,
            schedule=schedule, lr=lr, backend=backend, transport=transport,
            bucket_bytes=bucket_bytes, nodes=nodes,
            exchange_schedule=exchange_schedule, selector=selector).to_dict(),
        "records": records,
        "n_elems": 10000,
        "entropy_floor": 1.0,
        "final_loss": losses[-1],
        "wire": None,
    }


def _matrix_runs(t09_final=2.6, mixed_final=2.05, trio_losses=None,
                 pallas_losses=None, streamed_losses=None,
                 sampled_losses=None, hier_losses=None, rs_losses=None):
    dense = [4.0, 3.0, 2.5, 2.2, 2.0, 2.0]
    t07 = [4.0, 3.1, 2.6, 2.25, 2.05, 2.02]
    trio = trio_losses if trio_losses is not None else t07
    pallas = pallas_losses if pallas_losses is not None else t07
    streamed = streamed_losses if streamed_losses is not None else t07
    sampled = sampled_losses if sampled_losses is not None else t07
    hier = hier_losses if hier_losses is not None else t07
    rs = rs_losses if rs_losses is not None else t07
    sched = {"kind": "constant", "theta": 0.7}
    return {
        "lm_dense": _fake_run("lm_dense", None, dense),
        "lm_fft_theta0.7": _fake_run("lm_fft_theta0.7", "fft", t07, schedule=sched),
        "lm_fft_theta0.9": _fake_run(
            "lm_fft_theta0.9", "fft", dense[:-1] + [t09_final], theta=0.9,
            schedule={"kind": "constant", "theta": 0.9}),
        "lm_fft_mixed": _fake_run(
            "lm_fft_mixed", "fft", dense[:-1] + [mixed_final], theta=0.99,
            schedule={"kind": "step_decay", "points": [[0, 0.99], [2, 0.0]]}),
        "lm_fft_theta0.7_sequenced": _fake_run(
            "lm_fft_theta0.7_sequenced", "fft", trio, schedule=sched),
        "lm_fft_theta0.7_psum": _fake_run(
            "lm_fft_theta0.7_psum", "fft", trio, schedule=sched),
        "lm_fft_theta0.7_hier": _fake_run(
            "lm_fft_theta0.7_hier", "fft", hier, schedule=sched,
            transport="hierarchical", nodes=4),
        "lm_fft_theta0.7_rs": _fake_run(
            "lm_fft_theta0.7_rs", "fft", rs, schedule=sched,
            transport="reduce_scatter", nodes=4),
        "lm_fft_theta0.7_pallas": _fake_run(
            "lm_fft_theta0.7_pallas", "fft", pallas, schedule=sched,
            backend="pallas"),
        "lm_fft_theta0.7_bucketed_stacked": _fake_run(
            "lm_fft_theta0.7_bucketed_stacked", "fft", t07, schedule=sched,
            transport="sequenced", bucket_bytes=4096 * 4),
        "lm_fft_theta0.7_bucketed_streamed": _fake_run(
            "lm_fft_theta0.7_bucketed_streamed", "fft", streamed,
            schedule=sched, transport="sequenced", bucket_bytes=4096 * 4,
            exchange_schedule="streamed"),
        "lm_fft_theta0.7_sampled": _fake_run(
            "lm_fft_theta0.7_sampled", "fft", sampled, schedule=sched,
            selector="sampled"),
    }


def test_evaluator_passes_a_good_matrix():
    claims, ok = evaluate_results(_matrix_runs(), Tolerances(final_tail=2))
    assert ok, [c.to_dict() for c in claims if not c.passed]
    assert len(claims) == 10  # one model family x ten claims


def test_evaluator_catches_theta09_not_degrading():
    runs = _matrix_runs(t09_final=1.9)  # BETTER than theta0.7: claim must fail
    claims, ok = evaluate_results(runs, Tolerances(final_tail=1))
    assert not ok
    failed = {c.name for c in claims if not c.passed}
    assert "lm:theta0.9_degrades" in failed


def test_evaluator_catches_mixed_not_recovering():
    claims, ok = evaluate_results(
        _matrix_runs(mixed_final=3.5), Tolerances(final_tail=1))
    assert {c.name for c in claims if not c.passed} == {"lm:mixed_recovers"}


def test_evaluator_catches_transport_divergence():
    trio = [4.0, 3.1, 2.6, 2.25, 2.05, 2.02 + 1e-3]
    claims, ok = evaluate_results(
        _matrix_runs(trio_losses=trio), Tolerances(final_tail=2))
    assert "lm:transports_identical" in {c.name for c in claims if not c.passed}


def test_evaluator_catches_hierarchical_divergence():
    """hierarchical_matches_flat is a loss-TOLERANCE claim (the island
    re-compression is lossy by design): only a final-loss gap beyond
    loss_tol vs the flat psum row fails it, and a missing topology row is a
    failure, not a silent skip."""
    hier = [4.0, 3.1, 2.6, 2.25, 2.05, 2.02 * 1.2]  # 20% >> 5% tol
    claims, ok = evaluate_results(
        _matrix_runs(hier_losses=hier), Tolerances(final_tail=1))
    assert "lm:hierarchical_matches_flat" in {
        c.name for c in claims if not c.passed}
    # inside the tolerance: small drift must PASS (convergence, not bitwise)
    hier = [4.0, 3.1, 2.6, 2.25, 2.05, 2.02 * 1.01]
    claims, ok = evaluate_results(
        _matrix_runs(hier_losses=hier), Tolerances(final_tail=1))
    assert "lm:hierarchical_matches_flat" not in {
        c.name for c in claims if not c.passed}
    runs = _matrix_runs()
    del runs["lm_fft_theta0.7_rs"]
    claims, ok = evaluate_results(runs, Tolerances(final_tail=2))
    assert "lm:hierarchical_matches_flat" in {
        c.name for c in claims if not c.passed}


def test_evaluator_catches_backend_divergence():
    pallas = [4.0, 3.1, 2.6, 2.25, 2.05, 2.02 + 1e-2]
    claims, ok = evaluate_results(
        _matrix_runs(pallas_losses=pallas), Tolerances(final_tail=2))
    assert "lm:backends_identical" in {c.name for c in claims if not c.passed}
    # and a missing pallas-backend run is a failure, not a silent skip
    runs = _matrix_runs()
    del runs["lm_fft_theta0.7_pallas"]
    claims, ok = evaluate_results(runs, Tolerances(final_tail=2))
    assert "lm:backends_identical" in {c.name for c in claims if not c.passed}


def test_evaluator_catches_streamed_divergence():
    """The streamed_identical claim is BITWISE (atol 0): any divergence —
    even one well inside float noise — must fail it, and a missing row pair
    is a failure, not a silent skip."""
    streamed = [4.0, 3.1, 2.6, 2.25, 2.05, 2.02 + 1e-7]
    claims, ok = evaluate_results(
        _matrix_runs(streamed_losses=streamed), Tolerances(final_tail=2))
    assert "lm:streamed_identical" in {c.name for c in claims if not c.passed}
    runs = _matrix_runs()
    del runs["lm_fft_theta0.7_bucketed_streamed"]
    claims, ok = evaluate_results(runs, Tolerances(final_tail=2))
    assert "lm:streamed_identical" in {c.name for c in claims if not c.passed}


def test_evaluator_catches_sampled_selector_divergence():
    """sampled_selector_matches_sort is a loss-TOLERANCE claim (the selector
    may trade a few near-tau coefficients), so only a gap beyond loss_tol
    fails it; a missing sampled row is a failure, not a silent skip."""
    sampled = [4.0, 3.1, 2.6, 2.25, 2.05, 2.02 * 1.2]  # 20% >> 5% tol
    claims, ok = evaluate_results(
        _matrix_runs(sampled_losses=sampled), Tolerances(final_tail=1))
    assert "lm:sampled_selector_matches_sort" in {
        c.name for c in claims if not c.passed}
    # inside the tolerance: small drift must PASS (not a bitwise claim)
    sampled = [4.0, 3.1, 2.6, 2.25, 2.05, 2.02 * 1.01]
    claims, ok = evaluate_results(
        _matrix_runs(sampled_losses=sampled), Tolerances(final_tail=1))
    assert "lm:sampled_selector_matches_sort" not in {
        c.name for c in claims if not c.passed}
    runs = _matrix_runs()
    del runs["lm_fft_theta0.7_sampled"]
    claims, ok = evaluate_results(runs, Tolerances(final_tail=2))
    assert "lm:sampled_selector_matches_sort" in {
        c.name for c in claims if not c.passed}


def test_evaluator_catches_assumption31_violation():
    runs = _matrix_runs()
    # theta=0.7 with err_ratio 0.99 > 1.05*sqrt(0.7)+0.15 must trip the claim
    runs["lm_fft_theta0.7"] = _fake_run(
        "lm_fft_theta0.7", "fft", [4.0, 3.1, 2.6, 2.25, 2.05, 2.02],
        schedule={"kind": "constant", "theta": 0.7}, err_ratio=1.2)
    claims, ok = evaluate_results(runs, Tolerances(final_tail=2))
    assert "lm:assumption31" in {c.name for c in claims if not c.passed}


def test_evaluator_flags_missing_runs():
    runs = _matrix_runs()
    del runs["lm_dense"]
    claims, ok = evaluate_results(runs)
    assert not ok
    failed = {c.name for c in claims if not c.passed}
    assert "lm:theta0.7_matches_dense" in failed
    assert "lm:mixed_recovers" in failed


# ---------------------------------------------------------------------------
# chaos claims on fabricated runs (DESIGN.md §19)
# ---------------------------------------------------------------------------


T07 = [4.0, 3.1, 2.6, 2.25, 2.05, 2.02]
SCHED = {"kind": "constant", "theta": 0.7}


def _chaos_runs():
    """A healthy chaos lane: skip exactly the planned nan step, one
    auto-resume with a bitwise curve, corruption caught then degraded."""
    runs = _matrix_runs()

    nan = _fake_run("lm_chaos_nan", "fft",
                    [4.0, 3.1, 2.7, 2.31, 2.08, 2.04], schedule=SCHED)
    nan["spec"]["faults"] = [{"kind": "nan_grad", "step": 2, "worker": 1}]
    nan["health"] = {"skipped_steps": 1, "skip_steps": [2], "resumes": 0,
                     "transitions": [], "delays": 0}

    crash = _fake_run("lm_chaos_crash", "fft", list(T07), schedule=SCHED)
    crash["spec"]["faults"] = [{"kind": "step_crash", "step": 4,
                                "fatal": True}]
    crash["spec"]["ckpt_every"] = 2
    crash["health"] = {"skipped_steps": 0, "skip_steps": [], "resumes": 1,
                       "transitions": [], "delays": 0}

    corrupt = _fake_run("lm_chaos_corrupt", "fft",
                        [4.0, 3.1, 2.6, 2.6, 2.2, 2.1], schedule=SCHED,
                        transport="sequenced", bucket_bytes=4096 * 4)
    corrupt["spec"]["faults"] = [
        {"kind": "payload_corrupt", "step": 3, "worker": 1, "plane": "idx"}]
    corrupt["spec"]["validate"] = "cheap"
    corrupt["spec"]["steps"] = 6  # fabricated curves are 6 steps long
    corrupt["health"] = {"skipped_steps": 1, "skip_steps": [3], "resumes": 0,
                         "transitions": [{"step": 4, "rung": "kind:fft->dense"}],
                         "delays": 0}

    runs.update({r["spec"]["name"]: r for r in (nan, crash, corrupt)})
    return runs


def test_chaos_claims_pass_on_a_healthy_lane():
    claims = chaos_claims(_chaos_runs(), Tolerances(final_tail=1))
    names = {c.name: c for c in claims}
    assert set(names) == {"lm:nan_step_skipped_matches_clean",
                          "lm:crash_resume_bitwise",
                          "lm:corrupt_payload_detected_and_degraded"}
    assert all(c.passed for c in claims), [c.to_dict() for c in claims]
    # and evaluate_results folds them in next to the convergence claims
    all_claims, ok = evaluate_results(_chaos_runs(), Tolerances(final_tail=1))
    assert ok and set(names) <= {c.name for c in all_claims}


def test_chaos_claims_absent_without_chaos_rows():
    """Pre-chaos artifacts and plain fixtures get no chaos claims."""
    assert chaos_claims(_matrix_runs()) == []


def test_chaos_claims_catch_wrong_or_extra_skips():
    runs = _chaos_runs()
    runs["lm_chaos_nan"]["health"]["skip_steps"] = [2, 4]  # spurious skip
    claims = {c.name: c for c in chaos_claims(runs, Tolerances(final_tail=1))}
    assert not claims["lm:nan_step_skipped_matches_clean"].passed
    runs = _chaos_runs()
    runs["lm_chaos_nan"]["health"]["skip_steps"] = []  # nan slipped through
    claims = {c.name: c for c in chaos_claims(runs, Tolerances(final_tail=1))}
    assert not claims["lm:nan_step_skipped_matches_clean"].passed


def test_chaos_claims_catch_prefix_divergence():
    """Before the first fault the guarded run must be bitwise clean — the
    guard may not perturb healthy steps even inside float noise."""
    runs = _chaos_runs()
    recs = runs["lm_chaos_nan"]["records"]
    recs[1]["loss"] = recs[1]["loss"] + 1e-7
    claims = {c.name: c for c in chaos_claims(runs, Tolerances(final_tail=1))}
    assert not claims["lm:nan_step_skipped_matches_clean"].passed


def test_chaos_claims_catch_missing_resume_or_divergent_resume():
    runs = _chaos_runs()
    runs["lm_chaos_crash"]["health"]["resumes"] = 0  # crash never fired
    claims = {c.name: c for c in chaos_claims(runs, Tolerances(final_tail=1))}
    assert not claims["lm:crash_resume_bitwise"].passed
    runs = _chaos_runs()
    runs["lm_chaos_crash"]["records"][5]["loss"] += 1e-7  # not bitwise
    claims = {c.name: c for c in chaos_claims(runs, Tolerances(final_tail=1))}
    assert not claims["lm:crash_resume_bitwise"].passed


def test_chaos_claims_catch_undetected_or_undegraded_corruption():
    runs = _chaos_runs()
    runs["lm_chaos_corrupt"]["health"]["skip_steps"] = []  # nothing caught
    claims = {c.name: c for c in chaos_claims(runs, Tolerances(final_tail=1))}
    assert not claims["lm:corrupt_payload_detected_and_degraded"].passed
    runs = _chaos_runs()
    runs["lm_chaos_corrupt"]["health"]["transitions"] = []  # ladder never walked
    claims = {c.name: c for c in chaos_claims(runs, Tolerances(final_tail=1))}
    assert not claims["lm:corrupt_payload_detected_and_degraded"].passed
    runs = _chaos_runs()
    runs["lm_chaos_corrupt"]["records"] = (
        runs["lm_chaos_corrupt"]["records"][:4])  # run did not complete
    claims = {c.name: c for c in chaos_claims(runs, Tolerances(final_tail=1))}
    assert not claims["lm:corrupt_payload_detected_and_degraded"].passed


def test_chaos_claims_require_the_clean_comparator():
    runs = _chaos_runs()
    del runs["lm_fft_theta0.7"]
    claims = {c.name: c for c in chaos_claims(runs, Tolerances(final_tail=1))}
    assert not claims["lm:nan_step_skipped_matches_clean"].passed
    assert not claims["lm:crash_resume_bitwise"].passed


# ---------------------------------------------------------------------------
# per-run wire accounting (cost model)
# ---------------------------------------------------------------------------


def test_run_wire_account_prices_dense_and_compressed_steps():
    n = 1 << 16
    payload = 1e5
    acct = cost_model.run_wire_account(n, [payload, payload, None], "allgather",
                                       workers=8)
    dense_step = cost_model.dense_allreduce_bits(n, 8)
    assert acct.steps == 3
    assert acct.dense_bits == pytest.approx(3 * dense_step)
    # two compressed steps (P*B each) + one dense fallback step
    assert acct.compressed_bits == pytest.approx(2 * 8 * payload + dense_step)
    assert acct.savings > 1.0


def test_run_wire_account_psum_is_worker_count_free():
    acct_ag = cost_model.run_wire_account(4096, [1e4] * 5, "allgather", workers=8)
    acct_ps = cost_model.run_wire_account(4096, [1e4] * 5, "psum", workers=8)
    assert acct_ps.compressed_bits == pytest.approx(acct_ag.compressed_bits / 8)
    assert acct_ps.savings == pytest.approx(acct_ag.savings * 8)


def test_dense_allreduce_bits_single_worker_is_free():
    assert cost_model.dense_allreduce_bits(4096, 1) == 0.0


# ---------------------------------------------------------------------------
# report writer
# ---------------------------------------------------------------------------


def test_report_json_and_markdown(tmp_path):
    runs = _matrix_runs()
    claims, ok = evaluate_results(runs, Tolerances(final_tail=2))
    claim_dicts = [c.to_dict() for c in claims]

    out = tmp_path / "BENCH_convergence.json"
    report.write_json(str(out), runs, claim_dicts, ok)
    data = json.loads(out.read_text())
    assert data["bench"] == "convergence_lab"
    assert data["all_claims_passed"] is True
    assert set(data["runs"]) == set(runs)

    block = report.render_markdown(runs, claim_dicts, ok)
    assert "| experiment |" in block
    assert "lm_fft_theta0.9" in block
    assert "`lm:transports_identical`" in block

    docs = tmp_path / "EXPERIMENTS.md"
    docs.write_text("# EXPERIMENTS\n\n## Convergence results\n\n"
                    f"{report.MARKER}\n\n*(pending)*\n\n## Next section\n\nkeep me\n")
    assert report.splice_experiments_md(str(docs), block)
    text = docs.read_text()
    assert "| experiment |" in text
    assert "*(pending)*" not in text  # old block replaced
    assert "## Next section\n\nkeep me" in text  # later sections intact
    # idempotent: splicing again keeps exactly one table
    assert report.splice_experiments_md(str(docs), block)
    assert docs.read_text().count("| experiment |") == 1

    nomark = tmp_path / "OTHER.md"
    nomark.write_text("# no marker here\n")
    assert not report.splice_experiments_md(str(nomark), block)
    assert nomark.read_text() == "# no marker here\n"


# ---------------------------------------------------------------------------
# tier-2: the real smoke matrix (8 simulated workers, ~10 min on 2 cores)
# ---------------------------------------------------------------------------


@pytest.mark.lab
def test_lab_smoke_matrix_end_to_end(tmp_path):
    """Acceptance gate: `python -m repro.lab.run --smoke` completes on an
    8-simulated-worker CPU host, writes BENCH_convergence.json, splices the
    EXPERIMENTS.md table, and every paper claim passes."""
    out_json = tmp_path / "BENCH_convergence.json"
    docs = tmp_path / "EXPERIMENTS.md"
    docs.write_text("# EXPERIMENTS\n\n## Convergence results\n\n"
                    f"{report.MARKER}\n\n*(pending)*\n\n## Tail\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)  # the CLI pins the device count itself
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lab.run", "--smoke",
         "--out", str(out_json), "--docs", str(docs), "--quiet"],
        capture_output=True, text=True, timeout=2400, env=env)
    assert proc.returncode == 0, (
        f"lab smoke failed (rc={proc.returncode})\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr}")

    data = json.loads(out_json.read_text())
    assert data["all_claims_passed"] is True
    claim_names = {c["name"] for c in data["claims"]}
    for model in ("lm", "convnet"):
        for claim in ("theta0.7_matches_dense", "theta0.9_degrades",
                      "mixed_recovers", "transports_identical",
                      "backends_identical", "streamed_identical",
                      "sampled_selector_matches_sort",
                      "assumption31", "thm34_envelope"):
            assert f"{model}:{claim}" in claim_names, claim_names
    # per-step evidence is in the artifact (curves + probes + wire model)
    run = data["runs"]["lm_fft_theta0.7"]
    assert len(run["records"]) == run["spec"]["steps"]
    assert all("err_ratio" in r for r in run["records"])
    assert run["wire"]["compressed_bits"] > 0
    # the docs table was spliced in place
    text = docs.read_text()
    assert "| experiment |" in text and "## Tail" in text
