"""Range-based N-bit float (paper Alg. 1) — unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import given, settings, st  # hypothesis or deterministic fallback

from repro.core import quantizer as Q

CFG = Q.RangeQuantConfig(n_bits=8, m_bits=3)


def test_roundtrip_relative_error():
    q = Q.fit_quantizer(-1.0, 1.0, CFG)
    x = jnp.linspace(-1, 1, 4001)
    xr = Q.decode(Q.encode(x, q), q)
    rel = jnp.abs(x - xr) / jnp.maximum(jnp.abs(x), q.eps)
    # one mantissa step of slack on top of 2^-(m+1)
    assert float(rel.max()) <= 2.0 ** (-(CFG.m_bits + 1)) * 1.05


def test_zero_maps_to_zero():
    q = Q.fit_quantizer(-1.0, 1.0, CFG)
    assert float(Q.decode(Q.encode(jnp.zeros(4), q), q).max()) == 0.0


def test_monotonicity():
    q = Q.fit_quantizer(-2.0, 2.0, CFG)
    x = jnp.linspace(-2, 2, 1000)
    xr = Q.decode(Q.encode(x, q), q)
    assert bool(jnp.all(jnp.diff(xr) >= 0))


def test_density_concentrated_near_zero():
    """Paper Fig. 8: representable values are denser around 0."""
    q = Q.fit_quantizer(-1.0, 1.0, CFG)
    vals = np.sort(np.array(Q.representable_values(q)))
    gaps = np.diff(vals)
    mid = len(vals) // 2
    inner = gaps[mid - 8: mid + 8].mean()
    outer = np.concatenate([gaps[:8], gaps[-8:]]).mean()
    assert inner < outer / 8  # exponential spacing: inner gaps tiny


def test_code_budget_balanced():
    """solve_eps balances positive/negative codes for a symmetric range."""
    eps, p = Q.solve_eps(jnp.float32(-1), jnp.float32(1), CFG)
    assert abs(int(p) - 128) <= 1


def test_heuristic_agrees_with_closed_form():
    """Paper's x2 search lands within a factor of 2 of the closed form."""
    for lo, hi in [(-1, 1), (-6, 6), (-0.1, 0.5)]:
        e_h, _ = Q.tune_eps_heuristic(jnp.float32(lo), jnp.float32(hi), CFG)
        e_s, _ = Q.solve_eps(jnp.float32(lo), jnp.float32(hi), CFG)
        ratio = float(e_h / e_s)
        assert 0.4 <= ratio <= 2.6, (lo, hi, ratio)


def test_out_of_range_clips_to_boundary():
    """Paper: 'numbers beyond the range are represented by the closest
    boundary' — e.g. -2 -> -1 when the range is [-1, 1]."""
    q = Q.fit_quantizer(-1.0, 1.0, CFG)
    xr = Q.decode(Q.encode(jnp.array([-2.0, 2.0]), q), q)
    assert float(xr[0]) == pytest.approx(float(q.vmin), rel=1e-6)
    assert float(xr[1]) == pytest.approx(float(q.vmax), rel=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    hi=st.floats(1e-3, 1e3),
    asym=st.floats(0.1, 10.0),
    n_bits=st.sampled_from([6, 8, 12]),
    m_bits=st.sampled_from([2, 3, 4]),
)
def test_property_roundtrip_any_range(hi, asym, n_bits, m_bits):
    """Quantizer contract: |x - Q(x)| <= max(eps, rel_bound * |x|) for all
    in-range x.  The absolute arm covers the denormal gap below eps (any
    quantizer with a smallest-representable eps has it); the relative arm is
    one mantissa step, 2^-(m+1), with log-approximation slack."""
    cfg = Q.RangeQuantConfig(n_bits=n_bits, m_bits=m_bits)
    lo = -hi * asym
    q = Q.fit_quantizer(lo, hi, cfg)
    x = jnp.clip(jnp.linspace(lo, hi, 513), q.vmin, q.vmax)
    xr = Q.decode(Q.encode(x, q), q)
    err = jnp.abs(x - xr)
    bound = jnp.maximum(q.eps, 2.0 ** (-(m_bits + 1)) * 1.2 * jnp.abs(x)) + 1e-30
    assert bool(jnp.all(err <= bound)), float((err / bound).max())


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_gaussian_snr(seed):
    """8-bit range quantization keeps >20 dB SNR on gaussian gradients."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (20000,)) * 0.1
    q = Q.fit_quantizer(g.min(), g.max(), CFG)
    gr = Q.decode(Q.encode(g, q), q)
    mse = float(jnp.mean((g - gr) ** 2))
    snr = 10 * np.log10(float(jnp.var(g)) / max(mse, 1e-20))
    assert snr > 20.0
