"""Publish/subscribe weight-delta path (DESIGN.md §20).

The contract under test, stated in serve/publish.py's terms:

* **exactness** — at theta=0 with quantization off the codec keeps the full
  spectrum, so one published delta reconstructs the trainer's weights to
  float-roundoff;
* **bounded staleness** — at lossy settings the publisher diffs against its
  replica MIRROR (error feedback), so the replica's error vs the trainer is
  bounded by ONE delta's codec error and does not accumulate across deltas;
* **summed-spectrum catch-up** — a replica K versions behind folds K
  spectra and runs ONE irfft, landing BITWISE on the weights of a replica
  that replayed the deltas one at a time;
* **snapshot fallback** — when the ring wrapped past a laggard, it reloads
  the snapshot (gap detected) and still lands bitwise on the replay
  replica;
* plus the config invariants and the end-to-end lab-LM smoke: train with
  the publish hook, rebuild weights from the ring directory alone, and
  generate greedy tokens through the serving engine.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.comms.reducers import flatten_tree
from repro.serve import (
    PublishConfig,
    ReplicaSubscriber,
    WeightDeltaPublisher,
)

N = 3000


def _params(seed: int):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(50, 40)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(N - 2000,)).astype(np.float32)),
    }


def _walk(params, seed: int, scale: float = 1e-2):
    """One optimizer-ish step: params + small random update."""
    rng = np.random.default_rng(seed)
    return jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(
            scale * rng.normal(size=x.shape).astype(np.float32)), params)


def _cfg(**kw):
    kw.setdefault("chunk", 64)
    kw.setdefault("bucket_bytes", 4 * 1024)  # 1024 floats -> 3 buckets
    kw.setdefault("snapshot_every", 4)
    kw.setdefault("capacity", 4)
    return PublishConfig(**kw)


def _flat(params) -> np.ndarray:
    return np.asarray(flatten_tree(params)[0])


def test_theta0_unquantized_delta_is_exact(tmp_path):
    pub = WeightDeltaPublisher(
        str(tmp_path), _params(0), _cfg(theta=0.0, quantize=False))
    stepped = _walk(_params(0), seed=1)
    pub.publish(0, stepped)
    sub = ReplicaSubscriber(str(tmp_path))
    stats = sub.sync()
    assert stats.applied == 1 and stats.decompress_count == 1
    np.testing.assert_allclose(sub.weights(), _flat(stepped),
                               rtol=1e-5, atol=1e-6)


def test_lossy_staleness_bounded_by_one_delta(tmp_path):
    """Error feedback: replica error vs the trainer stays at single-delta
    codec scale over many publishes instead of accumulating."""
    cfg = _cfg(theta=0.7, quantize=True)
    params = _params(0)
    pub = WeightDeltaPublisher(str(tmp_path), params, cfg)
    sub = ReplicaSubscriber(str(tmp_path))
    errs = []
    for step in range(12):
        params = _walk(params, seed=100 + step)
        pub.publish(step, params)
        sub.sync()
        true = _flat(params)
        errs.append(np.linalg.norm(sub.weights() - true)
                    / np.linalg.norm(true))
    # lossy but bounded: no blow-up, and the tail is no worse than the
    # early error (the accumulation failure mode this guards against)
    assert max(errs) < 0.1
    assert errs[-1] < 3.0 * max(errs[0], 1e-6)
    # the publisher's mirror IS a replica: bitwise equal to the subscriber
    np.testing.assert_array_equal(
        np.asarray(pub.state.materialize()), sub.weights())


def test_catchup_sums_spectra_one_decompress_bitwise(tmp_path):
    cfg = _cfg(theta=0.5, quantize=True, snapshot_every=8, capacity=8)
    params = _params(1)
    pub = WeightDeltaPublisher(str(tmp_path), params, cfg)
    replay = ReplicaSubscriber(str(tmp_path))  # one delta at a time
    laggard = ReplicaSubscriber(str(tmp_path))  # catches up in one sync
    for step in range(3):
        params = _walk(params, seed=200 + step)
        pub.publish(step, params)
        replay.sync()
    stats = laggard.sync()
    assert stats.applied == 3
    assert stats.decompress_count == 1  # K spectra summed, ONE irfft
    assert not stats.gap_detected
    np.testing.assert_array_equal(laggard.weights(), replay.weights())


def test_catchup_across_rebase_boundary_stays_bitwise(tmp_path):
    """A catch-up window crossing a snapshot version rebases locally at the
    same version the publisher did — equality survives the boundary."""
    cfg = _cfg(theta=0.5, quantize=True, snapshot_every=4, capacity=8)
    params = _params(2)
    pub = WeightDeltaPublisher(str(tmp_path), params, cfg)
    replay = ReplicaSubscriber(str(tmp_path))
    laggard = ReplicaSubscriber(str(tmp_path))
    for step in range(6):  # crosses the v4 rebase
        params = _walk(params, seed=300 + step)
        pub.publish(step, params)
        replay.sync()
    stats = laggard.sync()
    assert stats.applied == 6
    assert stats.rebases == 1
    np.testing.assert_array_equal(laggard.weights(), replay.weights())


def test_ring_wrap_falls_back_to_snapshot(tmp_path):
    cfg = _cfg(theta=0.5, quantize=True, snapshot_every=4, capacity=4)
    params = _params(3)
    pub = WeightDeltaPublisher(str(tmp_path), params, cfg)
    replay = ReplicaSubscriber(str(tmp_path))
    laggard = ReplicaSubscriber(str(tmp_path))  # will be wrapped past
    for step in range(10):
        params = _walk(params, seed=400 + step)
        pub.publish(step, params)
        replay.sync()
    stats = laggard.sync()
    assert stats.gap_detected
    assert stats.snapshot_loads == 1
    assert stats.version == 10
    np.testing.assert_array_equal(laggard.weights(), replay.weights())


def test_publish_cadence_and_close(tmp_path):
    cfg = _cfg(publish_every=3)
    pub = WeightDeltaPublisher(str(tmp_path), _params(4), cfg)
    hook = pub.hook()
    params = _params(4)
    for step in range(7):
        params = _walk(params, seed=500 + step)
        hook(step, {"params": params})
    assert pub.version == 3  # steps 0, 3, 6
    pub.close()
    sub = ReplicaSubscriber(str(tmp_path))
    assert sub.follow(timeout_s=5.0) == 3


def test_config_invariants():
    with pytest.raises(ValueError, match="capacity"):
        PublishConfig(capacity=2, snapshot_every=8)
    with pytest.raises(ValueError, match="publish_every"):
        PublishConfig(publish_every=0)
    with pytest.raises(ValueError, match="snapshot_every"):
        PublishConfig(snapshot_every=0, capacity=4)


def test_publisher_rejects_mismatched_tree(tmp_path):
    pub = WeightDeltaPublisher(str(tmp_path), _params(5), _cfg())
    with pytest.raises(ValueError, match="elements"):
        pub.publish(0, {"w": jnp.zeros((3, 3), jnp.float32)})


def test_lab_lm_train_publish_serve_smoke(tmp_path):
    """End to end on the tiny LM: train with the publish hook, rebuild the
    weights from the ring directory alone, generate greedy tokens."""
    from repro import jaxcompat as compat
    from repro.configs.base import ArchConfig
    from repro.data import SyntheticConfig, SyntheticStream
    from repro.launch.mesh import make_local_mesh
    from repro.models.transformer import LM
    from repro.optim import OptConfig
    from repro.serve import Engine, ServeConfig
    from repro.train import TrainLoopConfig, init_state, train_loop
    from repro.train.step import StepConfig

    arch = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=64, remat="none")
    model = LM(arch)
    opt = OptConfig(kind="adamw", lr=3e-3)
    mesh = make_local_mesh()
    stream = SyntheticStream(SyntheticConfig(vocab_size=64, seq_len=16,
                                             global_batch=4))
    state = init_state(jax.random.PRNGKey(0), model, opt)
    pub = WeightDeltaPublisher(
        str(tmp_path), state["params"],
        PublishConfig(publish_every=1, snapshot_every=2, capacity=4,
                      theta=0.0, quantize=False))
    with compat.set_mesh(mesh):
        out = train_loop(model, opt, StepConfig(mode="pjit"), mesh, state,
                         stream, TrainLoopConfig(total_steps=4, log_every=4,
                                                 publish_hook=pub.hook()))
    pub.close()

    sub = ReplicaSubscriber(str(tmp_path))
    assert sub.follow(timeout_s=5.0) == 4  # one delta per committed step
    params = sub.params_like(out["state"]["params"])
    np.testing.assert_allclose(
        sub.weights(), _flat(out["state"]["params"]), rtol=1e-4, atol=1e-5)

    with compat.set_mesh(mesh):
        eng = Engine(model, params, ServeConfig(max_seq=32))
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (2, 8), 0, arch.vocab_size, jnp.int32)
        toks1 = eng.generate(prompts, max_new_tokens=4)
        toks2 = eng.generate(prompts, max_new_tokens=4)
    assert toks1.shape == (2, 12)
    np.testing.assert_array_equal(np.asarray(toks1), np.asarray(toks2))
    assert bool(jnp.all((toks1 >= 0) & (toks1 < arch.vocab_size)))
