"""Serving engine: batched generation, greedy determinism, cache reuse."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.serve import Engine, ServeConfig

KEY = jax.random.PRNGKey(0)


def _engine(name="gemma2_2b", **kw):
    cfg = registry.get_config(name).reduced()
    model = registry.build(cfg)
    params = model.init(KEY)
    return cfg, Engine(model, params, ServeConfig(max_seq=64, **kw))


def test_generate_shapes_and_determinism():
    cfg, eng = _engine()
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size, jnp.int32)
    out1 = eng.generate(prompts, max_new_tokens=6)
    out2 = eng.generate(prompts, max_new_tokens=6)
    assert out1.shape == (2, 14)
    np.testing.assert_array_equal(np.array(out1), np.array(out2))  # greedy
    np.testing.assert_array_equal(np.array(out1[:, :8]), np.array(prompts))


def test_generate_matches_teacher_forcing():
    """Greedy generation replayed teacher-forced yields the same argmaxes."""
    cfg, eng = _engine()
    model = registry.build(cfg)
    prompts = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size, jnp.int32)
    out = eng.generate(prompts, max_new_tokens=5)
    logits, _ = model.forward(eng.params, out)
    for t in range(8, 12):
        pred = int(jnp.argmax(logits[0, t - 1]))
        assert pred == int(out[0, t]), f"mismatch at {t}"


def test_temperature_sampling_runs():
    cfg, eng = _engine(temperature=1.0)
    prompts = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size, jnp.int32)
    out = eng.generate(prompts, max_new_tokens=4, key=jax.random.PRNGKey(7))
    assert out.shape == (2, 8)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_ssm_engine_generates():
    cfg, eng = _engine("xlstm_1_3b")
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size, jnp.int32)
    out = eng.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 12)
