"""Unit tests for tools/check_bench.py — the BENCH_throughput.json schema
guard that used to be an untestable heredoc inside .github/workflows/ci.yml.
Covers: the committed artifact passes, every column family is individually
guarded (dropping one is caught), the overlap-engine acceptance evidence
(a streamed deep-model row with overlap_efficiency > 0) is enforced, and
the calibration section must carry positive fitted α–β for both collective
families plus calibrated-vs-static auto verdicts."""

import copy
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_bench  # noqa: E402


@pytest.fixture()
def committed():
    with open(os.path.join(REPO, "BENCH_throughput.json")) as f:
        return json.load(f)


def test_committed_artifact_passes(committed):
    assert check_bench.check(committed) == []


def test_missing_sections_reported(committed):
    for section in ("backends", "records", "schedules", "selectors",
                    "calibration"):
        data = copy.deepcopy(committed)
        del data[section]
        errors = check_bench.check(data)
        assert any(section in e for e in errors), (section, errors)


def test_dropped_backend_record_caught(committed):
    data = copy.deepcopy(committed)
    data["backends"] = [r for r in data["backends"]
                       if r["backend"] != "pallas"]
    assert any("pallas" in e for e in check_bench.check(data))
    data = copy.deepcopy(committed)
    del data["backends"][0]["compress_us"]
    assert any("compress_us" in e for e in check_bench.check(data))


def test_every_record_column_guarded(committed):
    for key in check_bench.RECORD_KEYS:
        data = copy.deepcopy(committed)
        del data["records"][0][key]
        errors = check_bench.check(data)
        assert any(key in e for e in errors), key


def test_stacked_must_price_one_collective(committed):
    data = copy.deepcopy(committed)
    data["records"][0]["model_n_collectives_stacked"] = 4
    assert any("ONE" in e for e in check_bench.check(data))


def test_streamable_rows_require_positive_overlap(committed):
    data = copy.deepcopy(committed)
    bucketed = [r for r in data["records"] if r["n_buckets"] > 1
                and r["transport"] != "allgather"]
    assert bucketed, "sweep lost its bucketed rows"
    bucketed[0]["overlap_efficiency"] = 0.0
    assert any("overlap_efficiency" in e for e in check_bench.check(data))
    # monolithic rows must stay at exactly zero
    data = copy.deepcopy(committed)
    mono = [r for r in data["records"] if r["n_buckets"] == 1]
    assert mono, "sweep lost its monolithic rows"
    mono[0]["overlap_efficiency"] = 0.5
    assert any("monolithic" in e for e in check_bench.check(data))


def test_schedules_require_a_streamed_deep_model_row(committed):
    data = copy.deepcopy(committed)
    for r in data["schedules"]:
        r["auto_schedule"] = "stacked"
    errors = check_bench.check(data)
    assert any("deep-model" in e or "streamed" in e for e in errors)
    data = copy.deepcopy(committed)
    for r in data["schedules"]:
        r["overlap_efficiency"] = 0.0
    assert check_bench.check(data)
    for key in check_bench.SCHEDULE_KEYS:
        data = copy.deepcopy(committed)
        del data["schedules"][0][key]
        assert any(key in e for e in check_bench.check(data)), key


def test_dropped_selector_record_caught(committed):
    data = copy.deepcopy(committed)
    data["selectors"] = [r for r in data["selectors"]
                         if r["selector"] != "sampled"]
    assert any("sampled" in e for e in check_bench.check(data))
    for key in check_bench.SELECTOR_KEYS:
        data = copy.deepcopy(committed)
        del data["selectors"][0][key]
        assert any(key in e for e in check_bench.check(data)), key


def test_sampled_selector_must_not_lose_to_sort(committed):
    data = copy.deepcopy(committed)
    big = {r["selector"]: r for r in data["selectors"]
           if r["n_elems"] == check_bench.SELECTOR_N_ELEMS}
    assert {"sort", "sampled"} <= set(big), "lost the 64 MB selector pair"
    big["sampled"]["compress_steady_us"] = (
        big["sort"]["compress_steady_us"] * 2.0)
    assert any("regressed" in e for e in check_bench.check(data))
    # shrinking the buffer away from the reference size is also caught
    data = copy.deepcopy(committed)
    for r in data["selectors"]:
        r["n_elems"] = 1 << 20
    assert any("64 MB" in e for e in check_bench.check(data))


def test_bad_auto_schedule_value(committed):
    data = copy.deepcopy(committed)
    data["records"][0]["auto_schedule"] = "auto"  # must be RESOLVED
    assert any("auto_schedule" in e for e in check_bench.check(data))


def test_calibration_section_guarded(committed):
    # every top-level calibration key is individually guarded
    for key in check_bench.CALIBRATION_KEYS:
        data = copy.deepcopy(committed)
        del data["calibration"][key]
        assert any(key in e for e in check_bench.check(data)), key
    # both collective families need a fit
    data = copy.deepcopy(committed)
    data["calibration"]["fits"] = [
        f for f in data["calibration"]["fits"] if f["family"] != "psum"]
    assert any("psum" in e for e in check_bench.check(data))
    # fitted constants must be positive numbers
    for field in ("alpha_s", "beta_s_per_byte"):
        for bad in (0.0, -1e-6, None):
            data = copy.deepcopy(committed)
            data["calibration"]["fits"][0][field] = bad
            errors = check_bench.check(data)
            assert any(field in e for e in errors), (field, bad)


def test_calibration_decisions_guarded(committed):
    for key in check_bench.DECISION_KEYS:
        data = copy.deepcopy(committed)
        del data["calibration"]["decisions"][0][key]
        assert any(key in e for e in check_bench.check(data)), key
    # verdicts must be RESOLVED schedule names
    data = copy.deepcopy(committed)
    data["calibration"]["decisions"][0]["auto_calibrated"] = "auto"
    assert any("auto_calibrated" in e for e in check_bench.check(data))
    # an empty decision list is not acceptance evidence
    data = copy.deepcopy(committed)
    data["calibration"]["decisions"] = []
    assert any("decision" in e for e in check_bench.check(data))


def test_main_cli(tmp_path, committed, capsys):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(committed))
    assert check_bench.main([str(good)]) == 0
    assert "schema ok" in capsys.readouterr().out
    bad = copy.deepcopy(committed)
    del bad["records"][0]["overlap_efficiency"]
    bad_path = tmp_path / "bad.json"
    bad_path.write_text(json.dumps(bad))
    assert check_bench.main([str(bad_path)]) == 1
    assert "BENCH SCHEMA FAIL" in capsys.readouterr().out
    assert check_bench.main([str(tmp_path / "missing.json")]) == 1


def test_topology_section_guarded(committed):
    # the section itself and every column are individually guarded
    data = copy.deepcopy(committed)
    del data["topology"]
    assert any("topology" in e for e in check_bench.check(data))
    for key in check_bench.TOPOLOGY_KEYS:
        data = copy.deepcopy(committed)
        del data["topology"][0][key]
        assert any(key in e for e in check_bench.check(data)), key
    # the auto transport verdict must be RESOLVED
    data = copy.deepcopy(committed)
    data["topology"][0]["auto_transport"] = "auto"
    assert any("auto_transport" in e for e in check_bench.check(data))


def test_topology_inter_wire_must_beat_flat_psum(committed):
    """ISSUE 8 acceptance gate: a record whose hierarchical per-worker
    inter-node wire reaches (or exceeds) the flat psum runtime wire is a
    schema failure — the topology-aware transport lost its point."""
    data = copy.deepcopy(committed)
    r = data["topology"][0]
    r["inter_bits_per_worker"] = r["flat_wire_bits_per_worker"]
    assert any("strictly below" in e for e in check_bench.check(data))


def test_resilience_section_guarded(committed):
    """ISSUE 9 acceptance evidence: the guarded-exchange overhead
    measurement must be present, cheap-or-better, and backed by the
    deterministic structural check (no expensive primitives added)."""
    data = copy.deepcopy(committed)
    del data["resilience"]
    assert any("resilience" in e for e in check_bench.check(data))
    for key in check_bench.RESILIENCE_KEYS:
        data = copy.deepcopy(committed)
        del data["resilience"][key]
        assert any(key in e for e in check_bench.check(data)), key
    # validation must actually be on in the measurement
    data = copy.deepcopy(committed)
    data["resilience"]["validate_level"] = "off"
    assert any("validate_level" in e for e in check_bench.check(data))
    # the overhead ratio must be a positive number
    for bad in (0.0, -1.0, None, "fast"):
        data = copy.deepcopy(committed)
        data["resilience"]["guard_overhead_ratio"] = bad
        assert any("guard_overhead_ratio" in e
                   for e in check_bench.check(data)), bad
    # the structural no-new-primitives verdict is the flake-proof gate
    data = copy.deepcopy(committed)
    data["resilience"]["deterministic_ok"] = False
    assert any("deterministic_ok" in e for e in check_bench.check(data))


def test_topology_inter_wire_must_shrink_with_island_size(committed):
    """For a fixed node count, growing `local` must strictly shrink each
    worker's share of the fabric hop (nodes*B/local)."""
    data = copy.deepcopy(committed)
    by_nodes = {}
    for r in data["topology"]:
        by_nodes.setdefault(r["nodes"], []).append(r)
    grown = next(rs for rs in by_nodes.values() if len(rs) > 1)
    grown.sort(key=lambda r: r["local"])
    # flatten the curve: the bigger island reports the smaller island's wire
    grown[-1]["inter_bits_per_worker"] = grown[0]["inter_bits_per_worker"]
    assert any("shrink" in e for e in check_bench.check(data))
    # and the committed sweep actually exercises a multi-island node count
    assert len(grown) >= 2


# ---------------------------------------------------------------------------
# BENCH_serve.json (kind == "serve"): the publish-path guard (DESIGN.md §20)
# ---------------------------------------------------------------------------


@pytest.fixture()
def serve():
    with open(os.path.join(REPO, "BENCH_serve.json")) as f:
        return json.load(f)


def test_committed_serve_artifact_passes(serve):
    assert serve["kind"] == "serve"
    assert check_bench.check(serve) == []


def test_serve_record_columns_guarded(serve):
    data = copy.deepcopy(serve)
    del data["records"]
    assert any("records" in e for e in check_bench.check(data))
    for key in check_bench.SERVE_RECORD_KEYS:
        data = copy.deepcopy(serve)
        del data["records"][0][key]
        assert any(key in e for e in check_bench.check(data)), key
    for key in check_bench.SERVE_CATCHUP_KEYS:
        data = copy.deepcopy(serve)
        del data["records"][0]["catchup"][key]
        assert any(key in e for e in check_bench.check(data)), key


def test_serve_deltas_must_beat_dense(serve):
    """ISSUE 10 acceptance gate: compressed deltas STRICTLY cheaper than
    dense snapshots at the same cadence, on every record."""
    data = copy.deepcopy(serve)
    r = data["records"][0]
    r["delta_bytes_total"] = r["dense_bytes_at_cadence"]
    assert any("STRICTLY cheaper" in e for e in check_bench.check(data))
    data = copy.deepcopy(serve)
    data["records"][0]["model"]["savings"] = 0.9
    assert any("savings" in e for e in check_bench.check(data))


def test_serve_catchup_must_cost_one_decompress(serve):
    data = copy.deepcopy(serve)
    data["records"][0]["catchup"]["decompress_count"] = 3
    assert any("ONE decompress" in e for e in check_bench.check(data))
    data = copy.deepcopy(serve)
    data["records"][0]["catchup"]["bitwise_equal"] = False
    assert any("bitwise" in e for e in check_bench.check(data))
    data = copy.deepcopy(serve)
    data["records"][0]["mirror_bitwise_equal"] = False
    assert any("mirror" in e for e in check_bench.check(data))


def test_serve_sweep_coverage_guarded(serve):
    # shrink to one cadence: coverage failure
    data = copy.deepcopy(serve)
    data["records"] = [r for r in data["records"]
                       if r["publish_every"] == 1]
    assert any("cadences" in e for e in check_bench.check(data))
    # drop every wrapped-ring record: the fallback evidence disappears
    data = copy.deepcopy(serve)
    for r in data["records"]:
        r["gap"]["detected"] = False
    assert any("snapshot fallback" in e for e in check_bench.check(data))
    # no multi-delta catch-up left
    data = copy.deepcopy(serve)
    for r in data["records"]:
        r["catchup"]["lag"] = 1
    assert any("lag" in e for e in check_bench.check(data))


def test_main_cli_dispatches_both_kinds(tmp_path, committed, serve, capsys):
    tp = tmp_path / "throughput.json"
    tp.write_text(json.dumps(committed))
    sv = tmp_path / "serve.json"
    sv.write_text(json.dumps(serve))
    assert check_bench.main([str(tp), str(sv)]) == 0
    out = capsys.readouterr().out
    assert out.count("schema ok") == 2
    assert "publish records" in out
    # one bad artifact fails the whole invocation
    bad = copy.deepcopy(serve)
    bad["records"][0]["catchup"]["decompress_count"] = 2
    bad_path = tmp_path / "bad_serve.json"
    bad_path.write_text(json.dumps(bad))
    assert check_bench.main([str(tp), str(bad_path)]) == 1
