"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import RangeQuantConfig, fit_quantizer
from repro.kernels import ops, ref


@pytest.mark.parametrize("rows,cols", [(1, 256), (4, 2049), (16, 4096), (3, 512)])
@pytest.mark.parametrize("n_bits,m_bits", [(8, 3), (8, 2), (6, 3)])
def test_quant_kernel_vs_ref(rows, cols, n_bits, m_bits):
    q = fit_quantizer(-1.5, 2.0, RangeQuantConfig(n_bits, m_bits))
    x = jax.random.normal(jax.random.PRNGKey(rows * cols), (rows, cols))
    codes_k = ops.quant_encode(x, q)
    codes_r = ref.quant_encode_ref(x, q.eps, q.p_codes, n_bits, m_bits)
    np.testing.assert_array_equal(np.array(codes_k, np.int32), np.array(codes_r, np.int32))
    dec_k = ops.quant_decode(codes_k, q)
    dec_r = ref.quant_decode_ref(codes_r, q.eps, q.p_codes, n_bits, m_bits)
    np.testing.assert_allclose(np.array(dec_k), np.array(dec_r), rtol=1e-6)


@pytest.mark.parametrize("rows,cols,k", [(2, 2049, 615), (8, 4096, 128), (1, 512, 500)])
def test_threshold_kernel_vs_ref(rows, cols, k):
    mag = jnp.abs(jax.random.normal(jax.random.PRNGKey(k), (rows, cols)))
    tau_k, cnt_k = ops.threshold_select(mag, k)
    tau_r, cnt_r = ref.threshold_ref(mag, k)
    # continuous data: bisection converges to the exact k-th order statistic
    np.testing.assert_array_equal(np.array(cnt_k).ravel(), np.array(cnt_r).ravel())
    np.testing.assert_allclose(np.array(tau_k), np.array(tau_r), rtol=1e-4)


def test_threshold_kernel_with_ties():
    """Ties at the threshold: count >= k, never < k (budget is preserved)."""
    mag = jnp.concatenate([jnp.full((1, 64), 2.0), jnp.full((1, 64), 1.0)], axis=1)
    tau, cnt = ops.threshold_select(mag, 32)
    assert int(cnt[0, 0]) >= 32
    assert float(tau[0, 0]) <= 2.0


@pytest.mark.parametrize("rows,cols,k", [(2, 2049, 615), (4, 1024, 100)])
def test_pack_unpack_kernel_vs_ref(rows, cols, k):
    x = jax.random.normal(jax.random.PRNGKey(7), (rows, cols))
    tau, _ = ops.threshold_select(jnp.abs(x), k)
    vals_k, idx_k = ops.pack_threshold(x, tau, k)
    vals_r, idx_r = ref.pack_ref(x, tau, ops.pad_k(k))
    np.testing.assert_allclose(np.array(vals_k), np.array(vals_r), atol=1e-7)
    np.testing.assert_array_equal(np.array(idx_k), np.array(idx_r))
    dense_k = ops.unpack_dense(vals_k, idx_k, cols)
    dense_r = ref.unpack_ref(vals_r, idx_r, cols)
    np.testing.assert_allclose(np.array(dense_k), np.array(dense_r), atol=1e-7)


@pytest.mark.parametrize("rows", [1, 4, 9])
@pytest.mark.parametrize("scale", [1.0, 1e-3, 1e3])
def test_fft_kernel_forward_vs_ref(rows, scale):
    x = jax.random.normal(jax.random.PRNGKey(rows), (rows, 4096)) * scale
    re_k, im_k = ops.rfft4096(x)
    z = jnp.fft.rfft(x, axis=-1)
    tol = 2e-5 * scale * 64  # fp32 matmul accumulation over 4096 points
    np.testing.assert_allclose(np.array(re_k), np.array(jnp.real(z)), atol=tol)
    np.testing.assert_allclose(np.array(im_k), np.array(jnp.imag(z)), atol=tol)


def test_fft_kernel_inverse_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(11), (4, 4096))
    re, im = ops.rfft4096(x)
    xr = ops.irfft4096(re, im)
    np.testing.assert_allclose(np.array(xr), np.array(x), atol=1e-4)


def test_fft_kernel_full_vs_ref_complex():
    """Full complex transform against jnp.fft (both directions)."""
    from repro.kernels import fft4step

    xr = jax.random.normal(jax.random.PRNGKey(1), (2, 4096))
    xi = jax.random.normal(jax.random.PRNGKey(2), (2, 4096))
    for inverse in (False, True):
        kr, ki = fft4step.fft4096_pallas(xr, xi, inverse=inverse, interpret=True)
        rr, ri = ref.fft4096_ref(xr, xi, inverse=inverse)
        np.testing.assert_allclose(np.array(kr), np.array(rr), atol=3e-3)
        np.testing.assert_allclose(np.array(ki), np.array(ri), atol=3e-3)


def test_composed_kernel_pipeline_matches_core():
    """compress_chunks/decompress_chunks == core FFTCompressor bit-for-bit."""
    from repro.core.compressor import FFTCompressor, FFTCompressorConfig

    g = jax.random.normal(jax.random.PRNGKey(3), (8 * 4096,)) * 0.05
    q = fit_quantizer(-3.0, 3.0, RangeQuantConfig(8, 3))
    payload = ops.compress_chunks(g.reshape(8, 4096), 615, q)
    ghat_k = ops.decompress_chunks(payload[0], payload[1], payload[2], q, g.shape[0])
    comp = FFTCompressor(FFTCompressorConfig(
        theta=0.7, range_mode="fixed", fixed_range=(-3.0, 3.0)))
    ghat_c = comp.decompress(comp.compress(g))
    np.testing.assert_allclose(np.array(ghat_k), np.array(ghat_c), atol=1e-5)


@pytest.mark.parametrize("k_keep", [127, 128, 129])
def test_fused_golden_at_tile_boundary_keep_counts(k_keep):
    """Golden-value check of the fused kernel vs the ref.py oracles at keep
    counts straddling the 128-lane tile: 127 (pad fills one slot), 128
    (exact), 129 (spills into a second tile).  The payload slots beyond the
    true keep count must stay code-0/index-0 padding."""
    from repro.core import fft as cfft
    from repro.kernels import fused_compress

    cols = 513  # 1024-chunk rfft bins: tests a non-4096 plane too
    q = fit_quantizer(-2.0, 2.0, RangeQuantConfig(8, 3))
    key = jax.random.PRNGKey(k_keep)
    re = jax.random.normal(key, (3, cols)) * 0.05
    im = jax.random.normal(jax.random.fold_in(key, 1), (3, cols)) * 0.05
    w = cfft.hermitian_weights(1024)

    rec_f, imc_f, idx_f, tau_f = fused_compress.fused_compress_pallas(
        re, im, w, q.eps, q.p_codes, k_keep=k_keep, interpret=True)

    # oracle: exact k-th order statistic threshold, then index-ordered pack
    mag = jnp.sqrt(re * re + im * im) * w[None, :]
    tau_r, _ = ref.threshold_ref(mag, k_keep)
    k_pad = ops.pad_k(k_keep)
    mvals, idx_r = ref.pack_ref(mag, tau_r, k_pad)
    valid = mvals != 0
    re_k = jnp.take_along_axis(re, idx_r, axis=-1) * valid
    im_k = jnp.take_along_axis(im, idx_r, axis=-1) * valid
    rec_r = jnp.where(valid, ref.quant_encode_ref(re_k, q.eps, q.p_codes), 0)
    imc_r = jnp.where(valid, ref.quant_encode_ref(im_k, q.eps, q.p_codes), 0)

    assert rec_f.shape == (3, k_pad)  # 127->128, 128->128, 129->256
    np.testing.assert_allclose(
        np.array(tau_f).ravel(), np.array(tau_r).ravel(), rtol=1e-4)
    np.testing.assert_array_equal(np.array(idx_f), np.array(idx_r))
    np.testing.assert_array_equal(np.array(rec_f), np.array(rec_r))
    np.testing.assert_array_equal(np.array(imc_f), np.array(imc_r))
    # padding slots beyond k_keep carry no payload
    n_kept = int(np.sum(np.array(mag) >= np.array(tau_r), axis=-1).max())
    assert n_kept == k_keep  # continuous data: no threshold ties
    assert not np.any(np.array(rec_f)[:, k_keep:])
    assert not np.any(np.array(idx_f)[:, k_keep:])


def test_fused_matches_unfused():
    """fused_compress (threshold+pack+quant in one VMEM pass) == unfused."""
    from repro.core import fft as cfft
    from repro.kernels import fused_compress

    q = fit_quantizer(-2.0, 2.0, RangeQuantConfig(8, 3))
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 4096)) * 0.05
    re, im = ops.rfft4096(x)
    w = cfft.hermitian_weights(4096)

    rec_f, imc_f, idx_f, tau_f = fused_compress.fused_compress_pallas(
        re, im, w, q.eps, q.p_codes, k_keep=615, interpret=True)

    mag = jnp.sqrt(re * re + im * im) * w
    tau_u, _ = ops.threshold_select(mag, 615)
    mvals, idx_u = ops.pack_threshold(mag, tau_u, 615)
    re_k = jnp.take_along_axis(re, idx_u, axis=-1) * (mvals != 0)
    im_k = jnp.take_along_axis(im, idx_u, axis=-1) * (mvals != 0)
    rec_u = ops.quant_encode(re_k, q)
    imc_u = ops.quant_encode(im_k, q)

    np.testing.assert_allclose(np.array(tau_f), np.array(tau_u), rtol=1e-5)
    np.testing.assert_array_equal(np.array(idx_f), np.array(idx_u))
    np.testing.assert_array_equal(np.array(rec_f), np.array(rec_u))
    np.testing.assert_array_equal(np.array(imc_f), np.array(imc_u))
