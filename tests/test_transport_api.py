"""The redesigned exchange API (DESIGN.md §20): ``Transport.run`` as the
single public entry point, with the historical five-method surface
(``exchange`` / ``exchange_flat`` / ``local_roundtrip*`` and the scheduler's
``*_streamed`` wrappers) demoted to deprecated shims.

Covers, all on the local (no-collective) path so tier-1 stays single-device
— the axis-bearing path rides the same ``_run_one`` dispatch and is
exercised end-to-end by tests/test_transports.py via the reducers:

* layout= and plan= are mutually exclusive and one is required;
* ``run(plan=...)`` reassembles the readiness-ordered groups bitwise equal
  to the one-shot ``run(layout=...)`` dispatch;
* every deprecated name warns ``DeprecationWarning`` AND returns bitwise
  the ``run()`` result (shims delegate, they don't fork the math);
* the new surface itself stays warning-free.
"""

import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro.comms import bucketing, scheduler
from repro.comms.transport import get_transport
from repro.core.compressor import FFTCompressor, FFTCompressorConfig

CHUNK = 256
N = 4 * 2048 + 137  # multi-bucket with a ragged tail
LAYOUT = bucketing.build_layout(N, 2048 * 4, CHUNK)
COMP = FFTCompressor(FFTCompressorConfig(theta=0.7, chunk=CHUNK,
                                         backend="reference"))
FLAT = jnp.asarray(
    np.random.default_rng(0).normal(size=(N,)).astype(np.float32))


def _t(name="allgather"):
    return get_transport(name)


def test_run_requires_exactly_one_dispatch_spec():
    t = _t()
    plan = scheduler.build_plan(LAYOUT)
    with pytest.raises(ValueError, match="layout= or a plan="):
        t.run(FLAT, comp=COMP)
    with pytest.raises(ValueError, match="not both"):
        t.run(FLAT, comp=COMP, layout=LAYOUT, plan=plan)


def test_run_plan_bitwise_equals_run_layout():
    # the bitwise streamed==stacked guarantee belongs to the PER-BUCKET
    # transports (sequenced/psum fit one quantizer per bucket, so grouping
    # cannot move a fit); allgather compresses monolithically — splitting
    # it into groups legitimately refits the quantizer per group
    t = _t("sequenced")
    one_shot = t.run(FLAT, comp=COMP, layout=LAYOUT)
    assert LAYOUT.n_buckets > 1
    for n_groups in (None, 2, 1):
        plan = scheduler.build_plan(LAYOUT, n_groups)
        streamed = t.run(FLAT, comp=COMP, plan=plan)
        np.testing.assert_array_equal(np.asarray(streamed),
                                      np.asarray(one_shot))


def test_run_emits_no_deprecation_warning():
    t = _t()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        t.run(FLAT, comp=COMP, layout=LAYOUT)
        t.run(FLAT, comp=COMP, plan=scheduler.build_plan(LAYOUT))


def test_deprecated_flat_shims_warn_and_match_run():
    t = _t()
    want = np.asarray(t.run(FLAT, comp=COMP, layout=LAYOUT))
    with pytest.deprecated_call(match="local_roundtrip_flat"):
        got = t.local_roundtrip_flat(FLAT, LAYOUT, COMP)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_deprecated_bucket_shims_warn_and_match_run():
    t = _t()
    buckets = bucketing.split_buckets(FLAT, LAYOUT)
    with pytest.deprecated_call(match="local_roundtrip"):
        got = t.local_roundtrip(buckets, COMP)
    want = t.run(FLAT, comp=COMP, layout=LAYOUT, stacked=False)
    np.testing.assert_array_equal(
        np.asarray(bucketing.concat_buckets(got, LAYOUT)), np.asarray(want))


def test_deprecated_streamed_wrappers_warn_and_match_run():
    t = _t()
    plan = scheduler.build_plan(LAYOUT, 2)
    want = np.asarray(t.run(FLAT, comp=COMP, plan=plan))
    with pytest.deprecated_call(match="local_roundtrip_streamed"):
        got = scheduler.local_roundtrip_streamed(t, FLAT, plan, COMP)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_every_transport_runs_the_local_path():
    for name in ("allgather", "sequenced", "psum"):
        t = _t(name)
        out = t.run(FLAT, comp=COMP, layout=LAYOUT)
        assert out.shape == FLAT.shape
        assert bool(jnp.all(jnp.isfinite(out)))
