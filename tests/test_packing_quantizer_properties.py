"""Property-based roundtrip tests for core/packing.py and core/quantizer.py
edge cases the PR-1 bucketed exchange exposed: all-zero buckets,
single-element buckets, denormal-range values, and the int16 index ceiling
(chunk = 32767)."""


import jax.numpy as jnp
import numpy as np
import pytest

from helpers import given, settings, st  # hypothesis or deterministic fallback

from repro.core import packing
from repro.core import quantizer as Q
from repro.core.compressor import (
    FFTCompressor,
    FFTCompressorConfig,
    TimeDomainCompressor,
)

CFG = Q.RangeQuantConfig(n_bits=8, m_bits=3)


# ---------------------------------------------------------------------------
# all-zero buckets: a bucket whose gradient slice is exactly zero must
# round-trip to exactly zero through every layer (quantizer fit included —
# the degenerate [0, 0] range may not produce NaNs/Infs)
# ---------------------------------------------------------------------------


def test_quantizer_fit_on_all_zero_range_is_finite():
    q = Q.fit_quantizer(0.0, 0.0, CFG)
    for leaf in (q.eps, q.vmax, q.vmin):
        assert np.isfinite(float(leaf))
    x = jnp.zeros((64,))
    np.testing.assert_array_equal(np.array(Q.decode(Q.encode(x, q), q)), 0.0)


@pytest.mark.parametrize("comp_cls", [FFTCompressor, TimeDomainCompressor])
def test_all_zero_bucket_roundtrips_to_zero(comp_cls):
    comp = comp_cls(FFTCompressorConfig(theta=0.7))
    x = jnp.zeros((4096 + 123,))
    x_hat = comp.decompress(comp.compress(x))
    assert x_hat.shape == x.shape
    np.testing.assert_allclose(np.array(x_hat), 0.0, atol=1e-30)


# ---------------------------------------------------------------------------
# single-element buckets (the smallest legal bucket content: one scalar leaf)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(value=st.floats(-100.0, 100.0))
def test_single_element_roundtrip_fft(value):
    comp = FFTCompressor(FFTCompressorConfig(theta=0.0, quantize=False))
    x = jnp.asarray([value], jnp.float32)
    x_hat = comp.decompress(comp.compress(x))
    assert x_hat.shape == (1,)
    np.testing.assert_allclose(np.array(x_hat), np.array(x), atol=1e-4, rtol=1e-5)


def test_single_element_pack_unpack_by_indices():
    x2d = jnp.asarray([[3.5]])
    idx = jnp.asarray([[0]])
    vals = packing.pack_by_indices(x2d, idx)
    dense = packing.unpack_by_indices(vals, idx, 1)
    np.testing.assert_array_equal(np.array(dense), np.array(x2d))


# ---------------------------------------------------------------------------
# denormal-range values: ranges near the f32 denormal boundary must fit and
# round-trip without NaN/Inf (eps clamping in solve_eps)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(scale=st.sampled_from([1e-30, 1e-37, 1e-40, 1e-44]))
def test_denormal_range_fit_and_roundtrip(scale):
    q = Q.fit_quantizer(-scale, scale, CFG)
    assert np.isfinite(float(q.eps)) and float(q.eps) > 0.0
    x = jnp.asarray([-scale, -scale / 2, 0.0, scale / 2, scale], jnp.float32)
    xr = Q.decode(Q.encode(x, q), q)
    assert bool(jnp.all(jnp.isfinite(xr)))
    # zero still maps to exactly zero and signs are preserved (or flushed to 0)
    assert float(xr[2]) == 0.0
    assert bool(jnp.all(xr[:2] <= 0.0)) and bool(jnp.all(xr[3:] >= 0.0))


def test_denormal_values_in_normal_range_flush_to_zero_or_eps():
    """Values below eps encode to 0 or the smallest code — never garbage."""
    q = Q.fit_quantizer(-1.0, 1.0, CFG)
    tiny = jnp.asarray([1e-38, -1e-38, 5e-41], jnp.float32)
    xr = Q.decode(Q.encode(tiny, q), q)
    assert bool(jnp.all(jnp.abs(xr) <= float(q.eps) + 1e-30))


# ---------------------------------------------------------------------------
# int16 index ceiling: chunk = 32767 is the largest legal chunk (PR 1 unified
# payload indices to int16); 32768 must be rejected, and a 32767-chunk
# time-domain payload must round-trip with indices intact at the top end
# ---------------------------------------------------------------------------


def test_chunk_beyond_int16_ceiling_rejected():
    with pytest.raises(ValueError, match="int16"):
        FFTCompressorConfig(chunk=32768)
    # the ceiling itself is legal
    FFTCompressorConfig(chunk=32767)


def test_int16_ceiling_chunk_roundtrips_top_indices():
    """Top-k survivors at the very top of a 32767 chunk keep exact positions
    (an int16 overflow would wrap them negative and scatter elsewhere)."""
    chunk = 32767
    comp = TimeDomainCompressor(
        FFTCompressorConfig(theta=0.99, chunk=chunk, quantize=False))
    x = jnp.zeros((chunk,)).at[chunk - 1].set(7.0).at[chunk - 2].set(-5.0).at[0].set(3.0)
    payload = comp.compress(x)
    assert payload.idx.dtype == jnp.int16
    assert int(payload.idx.max()) == chunk - 1  # no wraparound
    x_hat = comp.decompress(payload)
    np.testing.assert_allclose(
        np.array(x_hat)[[0, chunk - 2, chunk - 1]], [3.0, -5.0, 7.0], atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 200))
def test_bitmap_pack_unpack_roundtrip_ragged_counts(n):
    """Bitmap payload round-trips exactly for any nonzero count <= k."""
    chunk = 256
    x = jnp.zeros((1, chunk)).at[0, jnp.arange(n) * (chunk // max(n, 1))].set(1.0)
    mask = x != 0
    payload = packing.pack_bitmap(x, mask, k=200)
    dense = packing.unpack_bitmap(payload, chunk)
    np.testing.assert_array_equal(np.array(dense), np.array(x))
