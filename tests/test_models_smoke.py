"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import pytest

from repro.models import registry
from repro.models.sharding import count_params
from repro.optim import OptConfig, apply_updates, init_opt_state

KEY = jax.random.PRNGKey(0)

# published sizes (billions) the FULL configs must land near
EXPECTED_B = {
    "internlm2_20b": (18, 22),
    "qwen1_5_110b": (100, 120),
    "gemma2_2b": (2.2, 3.0),
    "phi3_medium_14b": (13, 16),
    "mixtral_8x22b": (130, 150),
    "qwen3_moe_235b_a22b": (220, 250),
    "llama3_2_vision_11b": (8, 12),
}


@pytest.mark.parametrize("name", registry.ARCH_NAMES)
def test_forward_and_train_step(name):
    cfg = registry.get_config(name).reduced()
    model = registry.build(cfg)
    params = model.init(KEY)
    batch = registry.make_batch(KEY, cfg, batch=2, seq=32)

    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"

    # one SGD step must change params and keep everything finite
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    opt_cfg = OptConfig(kind="sgd", lr=1e-2)
    new_params, _ = apply_updates(opt_cfg, params, grads, init_opt_state(opt_cfg, params))
    leaves = jax.tree_util.tree_leaves(new_params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)
    loss2, _ = model.loss(new_params, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("name", registry.ARCH_NAMES)
def test_logits_shape(name):
    cfg = registry.get_config(name).reduced()
    model = registry.build(cfg)
    params = model.init(KEY)
    batch = registry.make_batch(KEY, cfg, batch=2, seq=16)
    memory = None
    if cfg.n_encoder_layers:
        memory = model.encode(params, batch["frontend"])
    elif cfg.frontend != "none":
        memory = batch["frontend"].astype(jnp.bfloat16)
    logits, aux = model.forward(params, batch["tokens"], memory=memory)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", sorted(EXPECTED_B))
def test_full_config_param_count(name):
    lo, hi = EXPECTED_B[name]
    n = count_params(registry.build(registry.get_config(name)).spec())
    assert lo * 1e9 <= n <= hi * 1e9, f"{name}: {n/1e9:.1f}B outside [{lo},{hi}]B"


def test_unrolled_matches_scanned():
    """scan_layers=False (dry-run cost sampling) is numerically identical."""
    import dataclasses

    cfg = registry.get_config("gemma2_2b").reduced()
    model_s = registry.build(cfg)
    model_u = registry.build(dataclasses.replace(cfg, scan_layers=False))
    params = model_s.init(KEY)
    batch = registry.make_batch(KEY, cfg, batch=2, seq=32)
    l1, _ = model_s.loss(params, batch)
    l2, _ = model_u.loss(params, batch)
    # bf16 accumulation order differs between scan and straight-line HLO
    assert abs(float(l1) - float(l2)) < 1e-3
