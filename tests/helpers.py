"""Test helpers: subprocess runner for multi-device (fake CPU devices) tests,
plus a hypothesis compatibility shim.

XLA_FLAGS=--xla_force_host_platform_device_count must be set before jax
imports, and the main test process must keep its single device (per the
dry-run instructions), so multi-device tests run in a child process.

Hypothesis shim: property tests import ``given``/``settings``/``st`` from
here.  When hypothesis is installed they are the real thing; on a clean
environment they fall back to a deterministic mini-runner that exercises each
strategy's boundary examples, so the suite still runs (and still covers the
properties at a few fixed points) without the dependency.
"""

from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ---------------------------------------------------------------------------
# hypothesis-or-fallback: deterministic boundary examples when absent
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Examples:
        """A 'strategy' that is just a fixed list of boundary examples."""

        def __init__(self, xs):
            self.xs = list(xs)

    class _FallbackStrategies:
        @staticmethod
        def integers(lo, hi):
            return _Examples([lo, (lo + hi) // 2, hi])

        @staticmethod
        def floats(lo, hi):
            return _Examples([lo, (lo + hi) / 2.0, hi])

        @staticmethod
        def sampled_from(xs):
            return _Examples(xs)

    st = _FallbackStrategies()

    def settings(**_kwargs):  # noqa: D401 - mirrors hypothesis.settings
        return lambda fn: fn

    def given(**strategies):
        """Run the test once per zipped-and-cycled boundary example set."""
        n = max(len(s.xs) for s in strategies.values())
        cases = [
            {k: s.xs[i % len(s.xs)] for k, s in strategies.items()}
            for i in range(n)
        ]

        def deco(fn):
            def wrapper():
                for case in cases:
                    fn(**case)

            # no functools.wraps: pytest would follow __wrapped__ back to the
            # original signature and demand its parameters as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco


def run_with_devices(code: str, devices: int = 8, timeout: int = 480) -> str:
    """Run python ``code`` in a subprocess with N fake CPU devices.

    The code should print results; raises on nonzero exit with full output.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout
