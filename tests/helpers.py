"""Test helpers: subprocess runner for multi-device (fake CPU devices) tests.

XLA_FLAGS=--xla_force_host_platform_device_count must be set before jax
imports, and the main test process must keep its single device (per the
dry-run instructions), so multi-device tests run in a child process.
"""

from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, devices: int = 8, timeout: int = 480) -> str:
    """Run python ``code`` in a subprocess with N fake CPU devices.

    The code should print results; raises on nonzero exit with full output.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout
