"""analysis/hlo.py replica-group parsing against captured HLO text fixtures.

The fixtures are post-optimization HLO lines in the two replica-group formats
XLA prints — explicit ``{{0,1},{2,3}}`` lists and the iota
``[8,64]<=[512]`` form — plus scalar-shape operands and async ``-start``
variants (the shapes/attributes mirror real ``compiled.as_text()`` dumps from
the dry-run path)."""

import pytest

from repro.analysis import hlo

EXPLICIT_FIXTURE = """\
HloModule jit_step, entry_computation_layout={(f32[1024]{0})->f32[1024]{0}}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add.1 = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main {
  %p0 = f32[1024]{0} parameter(0)
  %all-reduce.1 = f32[1024]{0} all-reduce(f32[1024]{0} %p0), replica_groups={{0,1},{2,3}}, to_apply=%add
  %mul = f32[1024]{0} multiply(f32[1024]{0} %all-reduce.1, f32[1024]{0} %p0)
  ROOT %copy = f32[1024]{0} copy(f32[1024]{0} %mul)
}
"""

IOTA_FIXTURE = """\
ENTRY %main {
  %p0 = f32[1,128]{1,0} parameter(0)
  %all-gather.7 = f32[8,128]{1,0} all-gather(f32[1,128]{1,0} %p0), channel_id=1, replica_groups=[8,64]<=[512], dimensions={0}, use_global_device_ids=true
  %reduce-scatter.2 = f32[1,128]{1,0} reduce-scatter(f32[8,128]{1,0} %all-gather.7), channel_id=2, replica_groups=[64,8]<=[512], dimensions={0}, to_apply=%add
  ROOT %copy = f32[1,128]{1,0} copy(f32[1,128]{1,0} %reduce-scatter.2)
}
"""

SCALAR_FIXTURE = """\
ENTRY %main {
  %loss = f32[] parameter(0)
  %all-reduce.3 = f32[] all-reduce(f32[] %loss), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %all-reduce-start.1 = f32[512]{0} all-reduce-start(f32[512]{0} %g), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-reduce-done.1 = f32[512]{0} all-reduce-done(f32[512]{0} %all-reduce-start.1)
  %cp = f32[2,4]{1,0} collective-permute(f32[2,4]{1,0} %x), source_target_pairs={{0,1},{1,0}}
}
"""


def test_explicit_replica_groups_and_ring_model():
    stats = hlo.parse_collectives(EXPLICIT_FIXTURE)
    assert set(stats) == {"all-reduce"}
    ar = stats["all-reduce"]
    assert ar.count == 1
    assert ar.raw_bytes == 1024 * 4
    # group size 2 -> ring all-reduce moves 2*B*(n-1)/n = B bytes per device
    assert ar.link_bytes == pytest.approx(2 * 1024 * 4 * (2 - 1) / 2)


def test_iota_replica_groups_group_size():
    stats = hlo.parse_collectives(IOTA_FIXTURE)
    ag, rs = stats["all-gather"], stats["reduce-scatter"]
    assert ag.count == 1 and rs.count == 1
    # iota [8,64]<=[512]: 8 groups of size 64
    assert ag.raw_bytes == 8 * 128 * 4
    assert ag.link_bytes == pytest.approx(8 * 128 * 4 * (64 - 1) / 64)
    # reduce-scatter result is the scattered shard; iota [64,8]: group size 8
    assert rs.raw_bytes == 1 * 128 * 4
    assert rs.link_bytes == pytest.approx(1 * 128 * 4 * (8 - 1))


def test_scalar_shapes_async_starts_and_permute():
    stats = hlo.parse_collectives(SCALAR_FIXTURE)
    ar = stats["all-reduce"]
    # the scalar all-reduce AND the -start count; the -done must NOT
    assert ar.count == 2
    assert ar.raw_bytes == 4 + 512 * 4
    scalar_link = 2 * 4 * (8 - 1) / 8
    start_link = 2 * 512 * 4 * (4 - 1) / 4
    assert ar.link_bytes == pytest.approx(scalar_link + start_link)
    cp = stats["collective-permute"]
    assert cp.count == 1
    assert cp.raw_bytes == 2 * 4 * 4
    assert cp.link_bytes == 2 * 4 * 4  # permute: payload crosses one link


def test_default_group_size_applies_when_unannotated():
    text = "  %ar = f32[100]{0} all-reduce(f32[100]{0} %x), to_apply=%add\n"
    stats = hlo.parse_collectives(text, default_group=4)
    assert stats["all-reduce"].link_bytes == pytest.approx(2 * 400 * 3 / 4)
    # group size 1 (no annotation, default 1): nothing crosses links
    stats1 = hlo.parse_collectives(text)
    assert stats1["all-reduce"].link_bytes == 0.0


def test_summarize_shape():
    out = hlo.summarize(hlo.parse_collectives(EXPLICIT_FIXTURE))
    assert out == {
        "all-reduce": {
            "count": 1,
            "raw_bytes": 1024 * 4.0,
            "link_bytes": pytest.approx(4096.0),
        }
    }
