"""Elastic remesh: checkpoints restore onto a different mesh (DESIGN.md §5)."""

from helpers import run_with_devices


def test_save_on_2x4_restore_on_8_and_4x2():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np, tempfile, os
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.jaxcompat import make_auto_mesh
from repro.train import checkpoint as ckpt

mesh_a = make_auto_mesh((2, 4), ("data", "model"))
state = {
    "params": {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
               "b": jnp.ones((8,))},
    "step": jnp.int32(5),
}
sharded = jax.device_put(state, jax.tree.map(
    lambda _: NamedSharding(mesh_a, P()), state))
sharded["params"]["w"] = jax.device_put(
    state["params"]["w"], NamedSharding(mesh_a, P("data", "model")))

d = tempfile.mkdtemp()
ckpt.save(d, 5, sharded)

# restore onto a 1-D 8-way mesh with a different layout
mesh_b = make_auto_mesh((8,), ("x",))
sh_b = jax.tree.map(lambda _: NamedSharding(mesh_b, P()), state)
sh_b["params"]["w"] = NamedSharding(mesh_b, P("x", None))
restored, step = ckpt.restore(d, state, shardings=sh_b)
assert step == 5
np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                              np.asarray(state["params"]["w"]))
assert restored["params"]["w"].sharding.spec == P("x", None)

# and onto a transposed 4x2 mesh
mesh_c = make_auto_mesh((4, 2), ("data", "model"))
sh_c = jax.tree.map(lambda _: NamedSharding(mesh_c, P()), state)
sh_c["params"]["w"] = NamedSharding(mesh_c, P("model", "data"))
restored_c, _ = ckpt.restore(d, state, shardings=sh_c)
np.testing.assert_array_equal(np.asarray(restored_c["params"]["w"]),
                              np.asarray(state["params"]["w"]))
print("REMESH_OK")
""")
    assert "REMESH_OK" in out


def test_train_on_4_resume_on_2_devices():
    """Full loop handoff across fleet sizes: same result as uninterrupted."""
    code_template = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig
from repro.data import SyntheticConfig, SyntheticStream
from repro.jaxcompat import make_auto_mesh, set_mesh
from repro.models.transformer import LM
from repro.optim import OptConfig
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train.step import StepConfig

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=64, remat="none")
mesh = make_auto_mesh((len(jax.devices()),), ("data",))
model = LM(TINY)
opt = OptConfig(kind="adamw", lr=1e-3)
stream = SyntheticStream(SyntheticConfig(vocab_size=64, seq_len=16, global_batch=8))
state = init_state(jax.random.PRNGKey(0), model, opt)
with set_mesh(mesh):
    out = train_loop(model, opt, StepConfig(mode="pjit"), mesh, state, stream,
                     TrainLoopConfig(total_steps=%(steps)d, ckpt_dir=%(ckpt)r,
                                     ckpt_every=5, log_every=100))
w = jax.tree_util.tree_leaves(out["state"]["params"])[0]
print("SUM", float(jnp.sum(jnp.abs(w))))
"""
    import tempfile

    d = tempfile.mkdtemp()
    out4 = run_with_devices(code_template % {"steps": 5, "ckpt": d}, devices=4)
    out2 = run_with_devices(code_template % {"steps": 10, "ckpt": d}, devices=2)
    # uninterrupted reference on 2 devices (data order is device-count
    # independent because batches are functions of the step only)
    ref = run_with_devices(
        code_template % {"steps": 10, "ckpt": tempfile.mkdtemp()}, devices=2)
    got = float(out2.split("SUM")[1].split()[0])
    want = float(ref.split("SUM")[1].split()[0])
    # cross-replica reduction ORDER differs between 4- and 2-device meshes, so
    # equality is to within accumulated f32 rounding, not bitwise
    assert abs(got - want) < 5e-3, (got, want)
