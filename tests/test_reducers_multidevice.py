"""Reducer correctness across 8 fake devices (subprocess; see helpers.py)."""


from helpers import run_with_devices


def test_compressed_reducers_approximate_dense():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import ReducerConfig, make_reducer

from repro.jaxcompat import make_auto_mesh, shard_map
mesh = make_auto_mesh((8,), ("data",))
grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 4096)) * 0.1,
         "b": jax.random.normal(jax.random.PRNGKey(1), (8, 16)) * 0.1}
expect = jax.tree.map(lambda x: x.mean(0), grads)

def run(cfg):
    r = make_reducer(cfg)
    f = shard_map(lambda g: r(jax.tree.map(lambda x: x[0], g)),
                      mesh=mesh, in_specs=P("data"), out_specs=P())
    return jax.jit(f)(grads)

dense = run(ReducerConfig(kind="dense", axis="data"))
assert all(np.allclose(np.asarray(dense[k]), np.asarray(expect[k]), atol=1e-6) for k in dense)

def global_rel(got):
    # Assumption 3.1 bounds the error of the CONCATENATED bucket, not of each
    # tiny leaf individually (a 16-element bias inside a 4096 chunk can be
    # relatively worse while the global bound holds)
    ge = np.concatenate([np.asarray(got[k]).ravel() for k in sorted(got)])
    ex = np.concatenate([np.asarray(expect[k]).ravel() for k in sorted(expect)])
    return np.linalg.norm(ge - ex) / np.linalg.norm(ex)

for kind, theta, tol in [("fft", 0.3, 0.31), ("fft", 0.7, 0.66), ("timedomain", 0.3, 0.31)]:
    got = run(ReducerConfig(kind=kind, axis="data", theta=theta))
    rel = global_rel(got)
    assert rel < tol, (kind, theta, rel)
print("REDUCERS_OK")
""")
    assert "REDUCERS_OK" in out


def test_hierarchical_reducer_on_pod_mesh():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import ReducerConfig, make_reducer

from repro.jaxcompat import make_auto_mesh, shard_map
mesh = make_auto_mesh((2, 4), ("pod", "data"))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 2048)) * 0.1
expect = np.asarray(g.mean(0))

r = make_reducer(ReducerConfig(kind="hierarchical", axis="data",
                               pod_axis="pod", theta=0.3))
f = shard_map(lambda v: r({"g": v[0]})["g"],
                  mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(),
                 )
got = np.asarray(jax.jit(f)(g))
rel = np.linalg.norm(got - expect) / np.linalg.norm(expect)
# intra-pod mean is exact; only the pod-axis exchange is lossy
assert rel < 0.35, rel
print("HIER_OK", rel)
""")
    assert "HIER_OK" in out


def test_ring_collectives_match_builtins():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms.collectives import ring_all_reduce, ring_all_gather, ring_reduce_scatter

from repro.jaxcompat import make_auto_mesh, shard_map
mesh = make_auto_mesh((8,), ("d",))
x = jax.random.normal(jax.random.PRNGKey(2), (8, 64))

f = shard_map(lambda v: ring_all_reduce(v[0], "d")[None],
                  mesh=mesh, in_specs=P("d"), out_specs=P("d"))
out = np.asarray(jax.jit(f)(x))
assert np.allclose(out, np.asarray(x.sum(0))[None].repeat(8, 0), atol=1e-5)

g = shard_map(lambda v: ring_all_gather(v[0], "d"),
                  mesh=mesh, in_specs=P("d"), out_specs=P(None))
got = np.asarray(jax.jit(g)(x))
assert np.allclose(got, np.asarray(x), atol=1e-6)

rs = shard_map(lambda v: ring_reduce_scatter(v[0], "d")[None],
                   mesh=mesh, in_specs=P("d"), out_specs=P("d"))
xs = jax.random.normal(jax.random.PRNGKey(3), (8, 8, 4))
got = np.asarray(jax.jit(rs)(xs))
expect = np.asarray(xs.sum(0)).reshape(8, 1, 4)
assert np.allclose(got, expect, atol=1e-5)
print("RING_OK")
""")
    assert "RING_OK" in out


def test_error_feedback_recovers_aggressive_compression():
    """With theta=0.97, plain compression stalls; EF accumulates the residual
    so the average error over steps shrinks (DGC-style, beyond paper)."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms.reducers import ReducerConfig, make_reducer, flatten_tree

from repro.jaxcompat import make_auto_mesh, shard_map
mesh = make_auto_mesh((4,), ("data",))
cfg = ReducerConfig(kind="fft", axis="data", theta=0.97, error_feedback=True)
r = make_reducer(cfg)
g = {"w": jnp.tile(jnp.sin(jnp.arange(4096) / 50.0)[None] * 0.1, (4, 1))}
expect = np.asarray(g["w"][0])

def step(res, grads):
    out, new_res = r(jax.tree.map(lambda x: x[0], grads), res[0])
    return out["w"], new_res[None]

f = shard_map(step, mesh=mesh, in_specs=(P("data"), P("data")),
                  out_specs=(P(), P("data")))
f = jax.jit(f)
res = jnp.zeros((4, 4096))
errs = []
acc = np.zeros(4096)
for i in range(8):
    got, res = f(res, g)
    acc += np.asarray(got)
    errs.append(np.linalg.norm(acc / (i + 1) - expect) / np.linalg.norm(expect))
assert errs[-1] < errs[0] * 0.7, errs
print("EF_OK", errs[0], errs[-1])
""", devices=4)
    assert "EF_OK" in out
